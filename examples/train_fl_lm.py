"""End-to-end FL LM training driver: the paper's biased wireless collective
as a first-class feature of distributed LM training.

Trains a small decoder-only LM over simulated wireless FL clients laid out
on the (data, model) mesh: each client computes local gradients on its
token shard, the OTA (or digital) wireless collective aggregates them with
the offline-designed {gamma_m}/{rho_m, nu_m, r_m}, and the PS applies the
projected SGD update — the full Sec. II pipeline at LM scale.

    PYTHONPATH=src XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        python examples/train_fl_lm.py --aggregator ota --steps 120

(defaults are sized for a single-CPU container; pass --d-model/--layers to
scale up — the same script drives the 256-chip production mesh.)
"""
import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.bounds import ObjectiveWeights
from repro.core.channel import WirelessConfig, make_deployment, FadingProcess
from repro.core import ota_design, digital_design
from repro.launch.mesh import make_host_mesh, client_axes, n_clients
from repro.launch.steps import make_train_step, fl_round_arrays
from repro.models import make_model, param_count
from repro.models.common import ModelConfig
from repro.optim.sgd import SGDConfig


def synthetic_token_batch(rng, vocab, batch, seq):
    """Markov-ish token stream: learnable bigram structure + noise."""
    succ = (np.arange(vocab) * 7 + 3) % vocab       # deterministic bigram map
    toks = np.empty((batch, seq), np.int32)
    toks[:, 0] = rng.integers(0, vocab, batch)
    for t in range(1, seq):
        follow = rng.random(batch) < 0.8
        toks[:, t] = np.where(follow, succ[toks[:, t - 1]],
                              rng.integers(0, vocab, batch))
    return {"tokens": jnp.asarray(toks)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--aggregator", default="ota",
                    choices=("ideal", "ota", "digital"))
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=6)
    ap.add_argument("--vocab", type=int, default=1024)
    ap.add_argument("--eta", type=float, default=1.0)
    ap.add_argument("--arch", default=None,
                    help="use an assigned arch's reduced variant instead")
    args = ap.parse_args()

    if args.arch:
        cfg = get_config(args.arch).scaled_down()
    else:
        cfg = ModelConfig(
            name="fl-lm", arch_type="dense", n_layers=args.layers,
            d_model=args.d_model, n_heads=8, n_kv_heads=4,
            d_ff=3 * args.d_model, vocab_size=args.vocab,
            dtype=jnp.float32)
    model = make_model(cfg)
    mesh = make_host_mesh(model_axis=1, data_axis=len(jax.devices()))
    nc = n_clients(mesh)
    print(f"mesh={dict(mesh.shape)} clients={nc}")

    # wireless deployment + offline design (statistical CSI only)
    dep = make_deployment(WirelessConfig(n_devices=nc, seed=1))
    g_max = 10.0
    w = ObjectiveWeights.non_convex(eta=args.eta, smooth_l=10.0,
                                    kappa_nc=0.5 * g_max, n=nc)
    spec = ota_design.OTADesignSpec(
        lambdas=dep.lambdas, dim=100_000, g_max=g_max,
        e_s=dep.cfg.energy_per_symbol, n0=dep.cfg.noise_power, weights=w)
    ota_params, _ = ota_design.design_ota_direct(spec)
    p = ota_params.participation_levels(dep.lambdas)
    print("designed participation p_m:", np.round(p, 3))

    sb = make_train_step(model, mesh, aggregator=args.aggregator,
                         sgd=SGDConfig(eta=args.eta),
                         batch=args.batch, seq=args.seq, use_kernel=True)
    step = jax.jit(sb.fn, in_shardings=sb.in_shardings,
                   out_shardings=sb.out_shardings,
                   donate_argnums=(0,))
    params = model.init(jax.random.key(0))
    print(f"model: {cfg.name}  params={param_count(params):,}")

    fading = FadingProcess(dep, seed=7)
    taus = ota_params.thresholds()
    rng = np.random.default_rng(0)
    t0 = time.time()
    for t in range(args.steps):
        batch = synthetic_token_batch(rng, cfg.vocab_size, args.batch,
                                      args.seq)
        h = fading.gains(t)
        chis = (h >= taus).astype(np.float64)
        fl = fl_round_arrays(
            mesh, gammas=ota_params.gammas / np.mean(ota_params.gammas),
            chis=chis,
            alpha=ota_params.alpha / np.mean(ota_params.gammas),
            noise_scale=np.sqrt(ota_params.noise_psd) / ota_params.alpha
            * 1e-2,
            levels=255.0)
        params, loss = step(params, batch, fl, jax.random.key(t))
        if t % 10 == 0 or t == args.steps - 1:
            print(f"step {t:4d}  loss {float(loss):.4f}  "
                  f"participants {int(chis.sum())}/{nc}  "
                  f"({time.time() - t0:.1f}s)")
    print("done.")


if __name__ == "__main__":
    main()
