"""Serve a small model with batched requests: prefill + token-by-token
decode with temperature sampling, using the production serve steps.

    PYTHONPATH=src python examples/serve.py --arch gemma3-4b --tokens 32
"""
import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import make_decode_step, make_prefill_step
from repro.models import make_model, make_batch, effective_seq


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b", choices=ARCH_IDS)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.8)
    args = ap.parse_args()

    cfg = get_config(args.arch).scaled_down()
    model = make_model(cfg)
    mesh = make_host_mesh()
    params = model.init(jax.random.key(0))

    prompt_len = effective_seq(cfg, args.prompt_len)
    cache_len = prompt_len + (cfg.vision_prefix or 0) + args.tokens + 1
    pb = make_prefill_step(model, mesh, batch=args.batch, seq=prompt_len,
                           cache_len=cache_len)
    db = make_decode_step(model, mesh, batch=args.batch,
                          cache_len=cache_len)
    prefill = jax.jit(pb.fn, in_shardings=pb.in_shardings,
                      out_shardings=pb.out_shardings)
    decode = jax.jit(db.fn, in_shardings=db.in_shardings,
                     out_shardings=db.out_shardings)

    batch = make_batch(cfg, args.batch, prompt_len, jax.random.key(1))
    t0 = time.time()
    logits, caches, memory = prefill(params, batch)
    print(f"[{args.arch}] prefill({args.batch}x{prompt_len}) "
          f"in {time.time() - t0:.2f}s")

    prefix = batch["tokens"].shape[1] + (cfg.vision_prefix or 0)
    key = jax.random.key(2)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    generated = [np.asarray(tok)]
    t0 = time.time()
    for i in range(args.tokens):
        pos = jnp.full((args.batch,), prefix + i, jnp.int32)
        logits, caches = decode(params, tok, pos, caches, memory)
        key, sub = jax.random.split(key)
        tok = jax.random.categorical(
            sub, logits / args.temperature, axis=-1)[:, None].astype(jnp.int32)
        generated.append(np.asarray(tok))
    dt = time.time() - t0
    out = np.concatenate(generated, axis=1)
    print(f"decoded {args.tokens} tokens x {args.batch} requests "
          f"in {dt:.2f}s ({args.tokens * args.batch / dt:.1f} tok/s)")
    for b in range(args.batch):
        print(f"  request {b}: {out[b][:16].tolist()} ...")


if __name__ == "__main__":
    main()
