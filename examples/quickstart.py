"""Quickstart: the paper's core loop in ~60 seconds on CPU.

Designs biased OTA-FL parameters with the SCA framework (Sec. IV-A), then
trains softmax regression over a heterogeneous wireless deployment and
compares against zero-bias Vanilla OTA-FL and the noiseless ideal.

    PYTHONPATH=src python examples/quickstart.py

Backends: ``FLTrainer.run(..., backend=...)`` selects the simulation
engine. Both replay identical random streams, so the trajectories match to
~1e-5 — the engine is just much faster at Monte-Carlo scale.

    backend   | what runs                          | covers
    ----------+------------------------------------+---------------------
    "numpy"   | reference Python-loop oracle       | every scheme + all
              | (core/baselines.py)                | trainer options
    "jax"     | vmap/scan engine (fl/engine.py);   | all 14 paper schemes
              | Pallas epilogue/quantizer/scoring  | (OTA + digital);
              | kernels; streaming counter-based   | full batch or SGD
              | dither + batch indices             | mini-batches; time
              | (O(N*d)/round)                     | budgets (in-scan
              |                                    | freeze mask)
    "auto"    | the engine whenever the scheme has | everything (falls
    (default) | a registered port                  | back to NumPy
              |                                    | otherwise)
"""
import numpy as np

from repro.core import baselines as B
from repro.core.bounds import ObjectiveWeights
from repro.core.channel import WirelessConfig, make_deployment
from repro.core.ota import lemma1_variance
from repro.core import ota_design
from repro.data.loader import FLDataset
from repro.data.partition import partition_by_class
from repro.data.synthetic import SyntheticSpec, make_classification_dataset
from repro.fl.tasks import SoftmaxRegressionTask
from repro.fl.trainer import FLTrainer


def main():
    n_devices = 10
    spec = SyntheticSpec(n_train_per_class=300, n_test_per_class=100,
                         noise_sigma=1.5)
    x_tr, y_tr, x_te, y_te = make_classification_dataset(spec)
    shards = partition_by_class(x_tr, y_tr, n_devices, 1, 300, seed=3)
    ds = FLDataset.from_shards(shards, x_te, y_te)
    task = SoftmaxRegressionTask(n_features=784, mu=0.01, g_max=20.0)

    dep = make_deployment(WirelessConfig(n_devices=n_devices, seed=1))
    print("device avg channel gains (dB):",
          np.round(10 * np.log10(dep.lambdas), 1))

    eta = 2.0 / (task.mu + task.smooth_l)
    weights = ObjectiveWeights.strongly_convex(eta=eta, mu=task.mu,
                                               kappa_sc=3.0, n=n_devices)
    dspec = ota_design.OTADesignSpec(
        lambdas=dep.lambdas, dim=task.dim, g_max=task.g_max,
        e_s=dep.cfg.energy_per_symbol, n0=dep.cfg.noise_power,
        weights=weights)
    params, res = ota_design.design_ota_sca(dspec)
    p = params.participation_levels(dep.lambdas)
    print(f"\nSCA design: objective={res.objective:.3f} "
          f"({res.n_iters} iterations)")
    print("participation levels p_m:", np.round(p, 4))
    print("Lemma-1 variance:", lemma1_variance(params, dep.lambdas))

    # The same design through the batched JAX solver (solver="jax" in the
    # benchmark pipelines): a whole omega sweep solves in ONE jit — here the
    # fig2-style bias-variance trade-off grid around the operating point.
    import dataclasses
    import time
    sweep = [dataclasses.replace(
        dspec, weights=ObjectiveWeights(omega_var=weights.omega_var,
                                        omega_bias=weights.omega_bias * s))
        for s in (0.1, 1.0, 10.0)]
    t0 = time.perf_counter()
    _, objs = ota_design.design_ota_batch(sweep)
    print(f"\nbatched JAX design (3-point omega_bias sweep, "
          f"{time.perf_counter() - t0:.2f}s incl. jit):")
    print("  objectives:", np.round(objs, 3),
          f"(middle point vs SCA: {objs[1] - res.objective:+.2e})")

    trainer = FLTrainer(task, ds, dep, eta=eta)
    for agg in (B.IdealFedAvg(), B.ProposedOTA(params),
                B.VanillaOTA(task.dim, task.g_max,
                             dep.cfg.energy_per_symbol,
                             dep.cfg.noise_power)):
        # backend="auto" (default) routes ported schemes through the JAX
        # vmap/scan engine; backend="numpy" forces the reference loop
        log = trainer.run(agg, rounds=80, trials=2, eval_every=20, seed=5,
                          backend="auto")
        acc, _ = log.mean_std("accuracy")
        print(f"{agg.name:25s} accuracy per 20 rounds: {np.round(acc, 3)}")

    # SGD mini-batches + a per-round latency budget, still backend="jax":
    # batch indices are counter-based (threefry on seed/trial/round/device,
    # core.rngstream.batch_block) and regenerated inside the engine's scan,
    # and the budget freezes training in-scan once the cumulative uplink
    # airtime is spent — both bit-identical to the NumPy oracle loop.
    sgd = FLTrainer(task, ds, dep, eta=eta, batch_size=32)
    budget = 50 * task.dim / dep.cfg.bandwidth_hz   # airtime for 50 rounds
    log = sgd.run(B.ProposedOTA(params), rounds=80, trials=2, eval_every=20,
                  seed=5, time_budget_s=budget, backend="jax")
    acc, _ = log.mean_std("accuracy")
    print(f"\nSGD (|B|=32) under a {budget * 1e3:.0f} ms uplink budget "
          f"(froze at {np.asarray(log.wall_time_s)[-1] * 1e3:.0f} ms):")
    print(f"{log.scheme:25s} accuracy per 20 rounds: {np.round(acc, 3)}")


if __name__ == "__main__":
    main()
