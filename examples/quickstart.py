"""Quickstart: declare a sweep, execute it, read the results — ~60s on CPU.

The repo's front door is the declarative scenario API (``repro.api``): an
experiment is a pure-data ``ScenarioSpec`` (task + data partition +
wireless deployment + scheme suite + Sec.-IV design policy + run options)
and a parameter study is a ``SweepSpec`` — a grid over any spec axis by
dotted path. The planner compiles the grid so every Sec.-IV design across
it solves in ONE batched ``jit(vmap(...))`` call per scheme family, runs
the Monte-Carlo simulations through the vmap/scan JAX engine
(``FLTrainer.run(backend="auto")``), and lands a cached, manifest-tracked
``ResultSet``: re-running a finished sweep is a no-op.

    PYTHONPATH=src python examples/quickstart.py

The same sweeps drive the figure pipelines and the CLI:

    PYTHONPATH=src python -m repro.api.cli list
    PYTHONPATH=src python -m repro.api.cli describe snr_het
    PYTHONPATH=src python -m repro.api.cli run sweep_smoke
"""
import tempfile
import time

import numpy as np

from repro.api import (DataSpec, DesignPolicy, RunSpec, ScenarioSpec,
                       SweepSpec, execute, plan)
from repro.core.channel import WirelessConfig


def main():
    # One declarative scenario: softmax regression over a heterogeneous
    # wireless deployment (1 class/device), the proposed biased OTA design
    # vs the zero-bias Vanilla OTA baseline and the noiseless ideal.
    # kappa is pinned to the paper's constant (3.0) to skip estimation.
    base = ScenarioSpec(
        name="quickstart",
        data=DataSpec(n_train_per_class=300, n_test_per_class=100,
                      samples_per_device=300),
        wireless=WirelessConfig(n_devices=10, seed=1),
        design=DesignPolicy(kappa=3.0),
        run=RunSpec(rounds=80, trials=2, eval_every=20, etas=(1.0,)),
        schemes=("ideal", "proposed_ota", "vanilla_ota"))

    # ... and a sweep: the bias-variance trade-off (omega_bias) x SNR grid.
    # Any dotted spec path is a sweepable axis.
    sweep = SweepSpec(name="quickstart", base=base,
                      axes={"design.omega_bias_scale": (0.1, 1.0, 10.0),
                            "wireless.tx_power_dbm": (0.0,)})

    # The plan shows the compiled work before anything runs: 3 cells, and
    # ONE batched design solve covering all of them.
    print(plan(sweep).describe(), "\n")

    with tempfile.TemporaryDirectory() as out:
        t0 = time.perf_counter()
        rs = execute(sweep, out_dir=out,
                     progress=lambda m: print(f"  {m}"))
        print(f"\nexecuted in {time.perf_counter() - t0:.1f}s "
              f"(git {rs.manifest['git_rev'][:10]})")

        for cell in rs:
            p = cell.payload
            scale = p["overrides"]["design.omega_bias_scale"]
            accs = {r["scheme_key"]: r["acc_mean"][-1] for r in p["logs"]}
            print(f"omega_bias x{scale:<5g} design_obj="
                  f"{p['design']['ota']['objective']:9.3f}  "
                  + "  ".join(f"{k}={v:.3f}" for k, v in accs.items()))

        # content-hash caching: the same sweep again is a cache no-op
        t0 = time.perf_counter()
        rs2 = execute(sweep, out_dir=out)
        print(f"\nre-run: all {len(rs2)} cells cached={rs2.all_cached} "
              f"in {time.perf_counter() - t0:.2f}s")

    # The trained trajectories are plain arrays — e.g. the bias-variance
    # trade-off: more omega_bias weight pushes the design toward uniform
    # participation (less bias, more noise), and vice versa.
    rec = rs.cell(1).log("proposed_ota")
    print("\nproposed OTA acc trajectory (omega x1):",
          np.round(rec["acc_mean"], 3))


if __name__ == "__main__":
    main()
