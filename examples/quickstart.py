"""Quickstart: declare a sweep, execute it, read the results — ~60s on CPU.

The repo's front door is the declarative scenario API (``repro.api``): an
experiment is a pure-data ``ScenarioSpec`` (task + data partition +
wireless deployment + scheme suite + Sec.-IV design policy + run options)
and a parameter study is a ``SweepSpec`` — a grid over any spec axis by
dotted path. The planner compiles the grid so every Sec.-IV design across
it solves in ONE batched ``jit(vmap(...))`` call per scheme family, runs
the Monte-Carlo simulations through the vmap/scan JAX engine
(``FLTrainer.run(backend="auto")``), and lands a cached, manifest-tracked
``ResultSet``: re-running a finished sweep is a no-op.

Two execution knobs matter at scale (see ROADMAP.md "RNG modes"):
``run.rng`` — "replay" is byte-compatible with the NumPy oracle's random
streams, "fast" regenerates every stream counter-based inside the scan
(zero host-side per-trial precompute; same laws, different stream) — and
``execute(..., jobs=K)``, which runs non-cached cells on a K-worker
process pool with serial-identical artifacts. Both are demoed below:
``run.rng`` is swept as an ordinary axis and the grid executes with
``jobs=2``.

The wireless fault layer (ROADMAP.md "Fault model") rides the same
rails: declare ``fault=FaultSpec(...)`` on the scenario and sweep
``fault.dropout_prob`` / ``fault.deep_fade_thresh`` / ``fault.*`` like
any other dotted axis — e.g.

    SweepSpec(name="faults", base=base,
              axes={"fault.dropout_prob": (0.0, 0.2, 0.5)})

``fault.on_missing`` picks the aggregation policy for devices that miss
a round ("reweight" = unbiased inverse-propensity, "zero" =
participation bias the Sec.-IV bound prices, "stale" = last-gradient
replay); ``benchmarks/sweep_fault.py`` is the worked example.

    PYTHONPATH=src python examples/quickstart.py

The same sweeps drive the figure pipelines and the CLI:

    PYTHONPATH=src python -m repro.api.cli list
    PYTHONPATH=src python -m repro.api.cli describe snr_het
    PYTHONPATH=src python -m repro.api.cli run sweep_smoke --jobs 2
"""
import tempfile
import time

import numpy as np

from repro.api import (DataSpec, DesignPolicy, RunSpec, ScenarioSpec,
                       SweepSpec, execute, plan)
from repro.core.channel import WirelessConfig


def main():
    # One declarative scenario: softmax regression over a heterogeneous
    # wireless deployment (1 class/device), the proposed biased OTA design
    # vs the zero-bias Vanilla OTA baseline and the noiseless ideal.
    # kappa is pinned to the paper's constant (3.0) to skip estimation.
    base = ScenarioSpec(
        name="quickstart",
        data=DataSpec(n_train_per_class=300, n_test_per_class=100,
                      samples_per_device=300),
        wireless=WirelessConfig(n_devices=10, seed=1),
        design=DesignPolicy(kappa=3.0),
        run=RunSpec(rounds=80, trials=2, eval_every=20, etas=(1.0,)),
        schemes=("ideal", "proposed_ota", "vanilla_ota"))

    # ... and a sweep: the bias-variance trade-off (omega_bias) crossed
    # with the RNG execution mode. Any dotted spec path is a sweepable
    # axis — run.rng="fast" here runs the exact same protocol on in-scan
    # counter-based streams (the at-scale mode).
    sweep = SweepSpec(name="quickstart", base=base,
                      axes={"design.omega_bias_scale": (0.1, 10.0),
                            "run.rng": ("replay", "fast")})

    # The plan shows the compiled work before anything runs: 4 cells, and
    # ONE batched design solve covering all of them.
    print(plan(sweep).describe(), "\n")

    with tempfile.TemporaryDirectory() as out:
        # jobs=2: non-cached cells run on a 2-worker process pool (the CLI
        # spelling is `run ... --jobs 2`); artifacts match a serial run
        t0 = time.perf_counter()
        rs = execute(sweep, out_dir=out, jobs=2,
                     progress=lambda m: print(f"  {m}"))
        print(f"\nexecuted in {time.perf_counter() - t0:.1f}s "
              f"(git {rs.manifest['git_rev'][:10]})")

        for cell in rs:
            p = cell.payload
            scale = p["overrides"]["design.omega_bias_scale"]
            rng = p["overrides"]["run.rng"]
            accs = {r["scheme_key"]: r["acc_mean"][-1] for r in p["logs"]}
            print(f"omega_bias x{scale:<5g} rng={rng:6s} design_obj="
                  f"{p['design']['ota']['objective']:9.3f}  "
                  + "  ".join(f"{k}={v:.3f}" for k, v in accs.items()))

        # content-hash caching: the same sweep again is a cache no-op
        t0 = time.perf_counter()
        rs2 = execute(sweep, out_dir=out)
        print(f"\nre-run: all {len(rs2)} cells cached={rs2.all_cached} "
              f"in {time.perf_counter() - t0:.2f}s")

    # The trained trajectories are plain arrays — e.g. the bias-variance
    # trade-off: more omega_bias weight pushes the design toward uniform
    # participation (less bias, more noise), and vice versa. Cell 1 is
    # the fast-RNG run of the omega x0.1 point: same law, different
    # stream, statistically equivalent trajectory.
    rec = rs.cell(1).log("proposed_ota")
    print("\nproposed OTA acc trajectory (omega x0.1, rng=fast):",
          np.round(rec["acc_mean"], 3))


if __name__ == "__main__":
    main()
