"""Step factories: FL train step (wireless collective over client axes),
serve prefill step, and single-token decode step — each returned as a
``StepBundle`` (fn + shardings + abstract inputs) consumed by the dry-run,
benchmarks and the real drivers alike.

Client layout: FL clients are the ("pod","data") mesh slices. The train
step runs under ``jax.shard_map`` with those axes manual and the "model"
axis automatic, so tensor-parallel math inside the model is partitioned by
XLA SPMD while the gradient aggregation is the explicit wireless collective
(core/collectives.wireless_psum).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .. import compat
from ..core.collectives import WirelessRound, wireless_psum
from ..models import api
from ..models.transformer import Transformer
from ..optim.sgd import SGDConfig, sgd_update
from .mesh import client_axes, n_clients
from .sharding import ShardingRules, batch_axes, cache_axes, decode_rules


@dataclasses.dataclass
class StepBundle:
    name: str
    fn: Callable
    in_shardings: Any
    out_shardings: Any
    abstract_inputs: tuple     # positional args as ShapeDtypeStructs

    def lower(self):
        jitted = jax.jit(self.fn, in_shardings=self.in_shardings,
                         out_shardings=self.out_shardings)
        return jitted.lower(*self.abstract_inputs)


def _named(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def _abstract(tree):
    return jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


# ------------------------------------------------------------- train step

def fl_round_arrays(mesh: Mesh, *, gammas=None, chis=None, nus=None,
                    alpha: float = 1.0, noise_scale: float = 0.0,
                    levels: float = 255.0):
    """Build the per-round FL arrays shaped like the client mesh axes.

    Defaults give an ideal round (all participate, weight 1).
    """
    caxes = client_axes(mesh)
    shape = tuple(mesh.shape[a] for a in caxes)
    n = int(np.prod(shape))
    if gammas is None:
        gammas = np.ones(n)
    if chis is None:
        chis = np.ones(n)
    if nus is None:
        nus = np.ones(n)
    weight = (np.asarray(chis) * np.asarray(gammas)
              / np.asarray(nus)).reshape(shape)
    return {
        "weight": jnp.asarray(weight, jnp.float32),
        "alpha": jnp.asarray(alpha, jnp.float32),
        "noise_scale": jnp.asarray(noise_scale, jnp.float32),
        "levels": jnp.full(shape, levels, jnp.float32),
    }


def _restrict_spec(spec: P, manual: tuple) -> P:
    """Keep only manual-axis entries of a PartitionSpec (auto axes dropped)."""
    out = []
    for entry in spec:
        if entry is None:
            out.append(None)
        elif isinstance(entry, tuple):
            kept = tuple(a for a in entry if a in manual)
            out.append(kept if kept else None)
        else:
            out.append(entry if entry in manual else None)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def make_train_step(model: Transformer, mesh: Mesh, *,
                    aggregator: str = "ota",
                    sgd: SGDConfig = SGDConfig(eta=1e-2),
                    batch: int = 8, seq: int = 128,
                    rules: Optional[ShardingRules] = None,
                    flags: Optional[dict] = None,
                    use_kernel: bool = True) -> StepBundle:
    cfg = model.cfg
    rules = rules or ShardingRules.default()
    caxes = client_axes(mesh)
    nc = n_clients(mesh)
    flags = dict(flags or {})
    flags.setdefault("mesh", mesh)
    expert_parallel = flags.get("moe_impl") == "ep"
    if expert_parallel:
        flags["_in_manual"] = True      # model runs inside client shard_map

    aparams = model.abstract_params()
    pspecs = rules.tree_specs(mesh, aparams, model.axes)
    # Params enter the client-manual shard_map replicated over client axes
    # (every FL client holds the full model), EXCEPT expert-parallel
    # weights in "ep" mode: those stay manual-sharded over "data" and their
    # gradients are globally aggregated by the backward all_to_all already.
    if expert_parallel:
        pspecs_manual = jax.tree.map(lambda s: _restrict_spec(s, caxes),
                                     pspecs, is_leaf=lambda x: isinstance(x, P))
    else:
        pspecs_manual = jax.tree.map(lambda s: P(), pspecs,
                                     is_leaf=lambda x: isinstance(x, P))
    skip_psum = jax.tree.map(lambda s: len(s) > 0, pspecs_manual,
                             is_leaf=lambda x: isinstance(x, P))
    abatch = api.batch_spec(cfg, batch, seq)
    bspecs = rules.tree_specs(mesh, abatch, batch_axes(abatch))
    caxes_shape = tuple(mesh.shape[a] for a in caxes)
    fl_specs = {
        "weight": P(*caxes),
        "alpha": P(),
        "noise_scale": P(),
        "levels": P(*caxes),
    }
    afl = {
        "weight": jax.ShapeDtypeStruct(caxes_shape, jnp.float32),
        "alpha": jax.ShapeDtypeStruct((), jnp.float32),
        "noise_scale": jax.ShapeDtypeStruct((), jnp.float32),
        "levels": jax.ShapeDtypeStruct(caxes_shape, jnp.float32),
    }
    akey = jax.ShapeDtypeStruct((), jax.random.key(0).dtype)

    # inside the body, batch leaves keep only their non-client dims sharded;
    # manual axes are stripped from the body-visible specs automatically
    def body(params, batch_in, fl, key):
        w_client = fl["weight"].reshape(())

        def local_loss(p):
            loss, metrics = api.loss_fn(model, p, batch_in, flags)
            # per-client wireless weight applied to the LOSS: grad is
            # linear, so grad(w*loss) = w*grad — and this stays correct
            # when expert-parallel routing spreads a client's tokens
            # across expert shards (the weight follows the tokens).
            return loss * w_client.astype(loss.dtype), loss

        (_, loss), grads = jax.value_and_grad(
            local_loss, has_aux=True)(params)
        rinfo = WirelessRound(weight=jnp.ones(()), alpha=fl["alpha"],
                              noise_scale=fl["noise_scale"],
                              levels=fl["levels"])
        ghat = wireless_psum(grads, rinfo, caxes, key, mode=aggregator,
                             use_kernel=use_kernel, skip_psum=skip_psum)
        new_params, _ = sgd_update(sgd, params, ghat,
                                   jax.tree.map(jnp.zeros_like, params))
        loss_mean = jax.lax.psum(loss, caxes) / nc
        return new_params, loss_mean

    shard_body = compat.shard_map(
        body, mesh,
        in_specs=(pspecs_manual, bspecs, fl_specs, P()),
        out_specs=(pspecs_manual, P()),
        manual_axes=caxes)

    in_sh = (_named(mesh, pspecs), _named(mesh, bspecs),
             _named(mesh, fl_specs), NamedSharding(mesh, P()))
    out_sh = (_named(mesh, pspecs), NamedSharding(mesh, P()))
    return StepBundle(
        name=f"train[{aggregator}]", fn=shard_body,
        in_shardings=in_sh, out_shardings=out_sh,
        abstract_inputs=(aparams, abatch, afl, akey))


# ------------------------------------------------------------ serve steps

def make_prefill_step(model: Transformer, mesh: Mesh, *, batch: int,
                      seq: int, cache_len: Optional[int] = None,
                      rules: Optional[ShardingRules] = None,
                      flags: Optional[dict] = None) -> StepBundle:
    cfg = model.cfg
    seq = api.effective_seq(cfg, seq)
    cache_len = cache_len or seq
    rules = rules or decode_rules(batch, mesh)
    flags = dict(flags or {})
    flags.setdefault("mesh", mesh)
    aparams = model.abstract_params()
    pspecs = rules.tree_specs(mesh, aparams, model.axes)
    abatch = api.batch_spec(cfg, batch, seq)
    bspecs = rules.tree_specs(mesh, abatch, batch_axes(abatch))

    def fn(params, batch_in):
        logits, caches, memory = api.prefill(model, params, batch_in,
                                             cache_len, flags)
        return logits, caches, memory

    acaches = jax.eval_shape(
        lambda: model.init_cache(batch, cache_len, dtype=cfg.dtype))
    cspecs = rules.tree_specs(mesh, acaches, cache_axes(acaches))
    batch_axes_tuple = (("pod", "data") if "pod" in mesh.axis_names
                        else ("data",))
    logit_spec = (P(batch_axes_tuple) if batch % n_clients(mesh) == 0
                  else P())
    mem_spec = (rules.spec_for(mesh, (batch, cfg.encoder_positions,
                                      cfg.d_model),
                               ("batch", "enc_seq", "embed"))
                if cfg.arch_type == "audio" else P())
    in_sh = (_named(mesh, pspecs), _named(mesh, bspecs))
    out_sh = (NamedSharding(mesh, logit_spec), _named(mesh, cspecs),
              NamedSharding(mesh, mem_spec))
    return StepBundle("prefill", fn, in_sh, out_sh, (aparams, abatch))


def make_decode_step(model: Transformer, mesh: Mesh, *, batch: int,
                     cache_len: int,
                     rules: Optional[ShardingRules] = None,
                     flags: Optional[dict] = None) -> StepBundle:
    cfg = model.cfg
    rules = rules or decode_rules(batch, mesh)
    flags = dict(flags or {})
    flags.setdefault("mesh", mesh)
    aparams = model.abstract_params()
    pspecs = rules.tree_specs(mesh, aparams, model.axes)
    acaches = jax.eval_shape(
        lambda: model.init_cache(batch, cache_len, dtype=cfg.dtype))
    cspecs = rules.tree_specs(mesh, acaches, cache_axes(acaches))
    batch_shardable = batch % n_clients(mesh) == 0
    bspec = (P(("pod", "data") if "pod" in mesh.axis_names else ("data",))
             if batch_shardable else P())

    def fn(params, token, position, caches, memory):
        logits, new_caches = api.decode_step(model, params, token, position,
                                             caches, memory=memory,
                                             flags=flags)
        return logits, new_caches

    atok = jax.ShapeDtypeStruct((batch, 1), jnp.int32)
    apos = jax.ShapeDtypeStruct((batch,), jnp.int32)
    amem = (jax.ShapeDtypeStruct((batch, cfg.encoder_positions, cfg.d_model),
                                 cfg.dtype)
            if cfg.arch_type == "audio" else None)
    mem_spec = (rules.spec_for(mesh, (batch, cfg.encoder_positions,
                                      cfg.d_model),
                               ("batch", "enc_seq", "embed"))
                if cfg.arch_type == "audio" else P())
    in_sh = (_named(mesh, pspecs), NamedSharding(mesh, bspec),
             NamedSharding(mesh, bspec), _named(mesh, cspecs),
             NamedSharding(mesh, mem_spec))
    out_sh = (NamedSharding(mesh, bspec), _named(mesh, cspecs))
    return StepBundle("decode", fn, in_sh, out_sh,
                      (aparams, atok, apos, acaches, amem))
