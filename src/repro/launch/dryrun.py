import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# the two lines above MUST be first: jax locks the device count on first
# init, and only the dry-run wants 512 placeholder host devices.

"""Multi-pod dry-run: lower + compile every (architecture x input-shape)
combination on the production mesh, and record memory/cost/collective
analysis for the roofline (EXPERIMENTS.md §Dry-run / §Roofline).

The XLA_FLAGS lines below MUST stay the first statements (before any other
import, including ``from repro...``): jax locks the device count on first
init, and only the dry-run wants 512 placeholder host devices.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out DIR]
"""
import argparse
import json
import time
import traceback
from pathlib import Path

import jax

from ..configs import ARCH_IDS, get_config
from ..models import make_model, active_param_count, param_count
from .mesh import make_production_mesh
from .shapes import SHAPES, SHAPE_IDS, applicable, config_for
from .steps import make_train_step, make_decode_step, make_prefill_step
from .analysis import collective_stats, cost_summary, memory_summary
from .hlo_cost import analyze_hlo

DEFAULT_OUT = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def build_bundle(arch: str, shape_id: str, mesh, *, aggregator: str = "ota",
                 flags=None):
    """flags: runtime options threaded to the model/step (moe_impl,
    attn_impl, mamba_fused, scan_chunk, ...)."""
    shape = SHAPES[shape_id]
    cfg0 = get_config(arch)
    ok, reason = applicable(cfg0, shape)
    if not ok:
        return None, reason
    cfg = config_for(cfg0, shape)
    model = make_model(cfg)
    if shape.kind == "train":
        # use_kernel=False: on CPU the Pallas kernels run in interpret mode,
        # which lowers each grid step to a full-buffer dynamic-update-slice
        # loop — that would pollute the FLOP/byte accounting with artifacts
        # a real Mosaic kernel doesn't have. The jnp epilogue is the
        # cost-faithful stand-in for analysis.
        return make_train_step(model, mesh, aggregator=aggregator,
                               batch=shape.global_batch, seq=shape.seq_len,
                               flags=flags, use_kernel=False), ""
    if shape.kind == "prefill":
        return make_prefill_step(model, mesh, batch=shape.global_batch,
                                 seq=shape.seq_len, flags=flags), ""
    if shape.kind == "decode":
        from ..models.api import effective_seq
        cache_len = effective_seq(cfg, shape.seq_len)
        return make_decode_step(model, mesh, batch=shape.global_batch,
                                cache_len=cache_len, flags=flags), ""
    raise ValueError(shape.kind)


def run_one(arch: str, shape_id: str, *, multi_pod: bool = False,
            aggregator: str = "ota", out_dir: Path = DEFAULT_OUT,
            flags=None, tag: str = "", mesh_data=None) -> dict:
    mesh_name = "2pod" if multi_pod else "pod"
    rec = {"arch": arch, "shape": shape_id, "mesh": mesh_name,
           "aggregator": aggregator, "status": "ok", "tag": tag,
           "flags": {k: v for k, v in (flags or {}).items()},
           "mesh_data": mesh_data}
    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod,
                                    data_axis=mesh_data)
        bundle, reason = build_bundle(arch, shape_id, mesh,
                                      aggregator=aggregator, flags=flags)
        if bundle is None:
            rec["status"] = "skipped"
            rec["reason"] = reason
            rec["elapsed_s"] = round(time.time() - t0, 1)
            out_dir.mkdir(parents=True, exist_ok=True)
            suffix = f"_{tag}" if tag else ""
            (out_dir / f"{arch}_{shape_id}_{mesh_name}{suffix}.json"
             ).write_text(json.dumps(rec, indent=1, default=str))
            return rec
        lowered = bundle.lower()
        compiled = lowered.compile()
        hlo_text = compiled.as_text()
        rec["memory"] = memory_summary(compiled)
        rec["cost"] = cost_summary(compiled)          # XLA (scan body once)
        rec["collectives"] = collective_stats(hlo_text).summary()
        # trip-count-aware per-device cost (launch/hlo_cost.py)
        rec["hlo_cost"] = analyze_hlo(hlo_text).summary()
        cfg = config_for(get_config(arch), SHAPES[shape_id])
        model = make_model(cfg)
        aparams = model.abstract_params()
        rec["param_count"] = param_count(aparams)
        rec["active_param_count"] = active_param_count(cfg, aparams)
        rec["n_devices"] = mesh.devices.size
    except Exception as e:
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    rec["elapsed_s"] = round(time.time() - t0, 1)
    out_dir.mkdir(parents=True, exist_ok=True)
    suffix = f"_{tag}" if tag else ""
    path = out_dir / f"{arch}_{shape_id}_{mesh_name}{suffix}.json"
    path.write_text(json.dumps(rec, indent=1, default=str))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=SHAPE_IDS)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--aggregator", default="ota",
                    choices=("ideal", "ota", "digital"))
    ap.add_argument("--out", type=Path, default=DEFAULT_OUT)
    ap.add_argument("--tag", default="")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--flag", action="append", default=[],
                    help="runtime flag key=value (e.g. moe_impl=ep)")
    ap.add_argument("--mesh-data", type=int, default=None,
                    help="override data-axis size (model = 256/data)")
    args = ap.parse_args()
    flags = {}
    for kv in args.flag:
        k, v = kv.split("=", 1)
        flags[k] = int(v) if v.isdigit() else (v == "true" if v in
                                               ("true", "false") else v)

    combos = []
    if args.all:
        for a in ARCH_IDS:
            for s in SHAPE_IDS:
                combos.append((a, s))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all required"
        combos = [(args.arch, args.shape)]
    meshes = [args.multi_pod]
    if args.both_meshes:
        meshes = [False, True]
    for arch, shape in combos:
        for mp in meshes:
            mesh_name = "2pod" if mp else "pod"
            suffix = f"_{args.tag}" if args.tag else ""
            path = args.out / f"{arch}_{shape}_{mesh_name}{suffix}.json"
            if args.skip_existing and path.exists():
                prev = json.loads(path.read_text())
                if prev.get("status") in ("ok", "skipped"):
                    print(f"[skip] {arch} {shape} {mesh_name} (cached)")
                    continue
            rec = run_one(arch, shape, multi_pod=mp,
                          aggregator=args.aggregator, out_dir=args.out,
                          tag=args.tag, flags=flags or None,
                          mesh_data=args.mesh_data)
            flops = (rec.get("cost") or {}).get("flops")
            print(f"[{rec['status']:7s}] {arch:22s} {shape:12s} {mesh_name:4s}"
                  f" {rec['elapsed_s']:7.1f}s flops={flops}"
                  + (f" err={rec.get('error','')[:120]}"
                     if rec["status"] == "error" else ""))


if __name__ == "__main__":
    main()
