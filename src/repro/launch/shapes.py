"""The four assigned input shapes + per-arch applicability.

Decode shapes lower ``decode_step`` (one new token against a KV/state cache
of ``seq_len``), not ``train_step``.  ``long_500k`` requires sub-quadratic
attention: SSM/hybrid archs run natively; dense/MoE/VLM archs run the
sliding-window decode variant (``long_context_variant``); whisper-tiny is
capped at its 448-token decoder context so long_500k is skipped
(DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses

from ..models.common import ModelConfig


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # "train" | "prefill" | "decode"


SHAPES: dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}

SHAPE_IDS = tuple(SHAPES)

# long-context window for archs that need the sliding-window decode variant
LONG_WINDOW = 8192


def long_context_variant(cfg: ModelConfig) -> ModelConfig:
    """Sliding-window decode variant for long_500k (DESIGN.md §5)."""
    if cfg.supports_long_decode:
        return cfg
    pat = tuple("local" if k == "global" else k for k in cfg.layer_pattern)
    window = cfg.window_size if "local" in cfg.layer_pattern else LONG_WINDOW
    return dataclasses.replace(cfg, layer_pattern=pat, window_size=window)


def applicable(cfg: ModelConfig, shape: InputShape) -> tuple[bool, str]:
    """(runs?, reason-if-skipped)."""
    if shape.name == "long_500k" and cfg.max_target_positions:
        return False, (f"{cfg.name}: decoder context capped at "
                       f"{cfg.max_target_positions} (enc-dec ASR model); "
                       "long_500k skipped per DESIGN.md §5")
    return True, ""


def config_for(cfg: ModelConfig, shape: InputShape) -> ModelConfig:
    if shape.name == "long_500k":
        return long_context_variant(cfg)
    return cfg
