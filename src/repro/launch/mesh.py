"""Production mesh builders.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state; the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before any jax import.

Target hardware (roofline constants live in benchmarks/roofline.py):
  TPU v5e pod: 16x16 = 256 chips, (data=16, model=16)
  2 pods     : (pod=2, data=16, model=16) = 512 chips
"""
from __future__ import annotations

import jax

from ..compat import make_auto_mesh


def _mesh(shape, axes):
    return make_auto_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False, data_axis=None):
    """(data=16, model=16) per pod; 512 chips with multi_pod.

    ``data_axis`` reshapes the LOGICAL (data, model) factorization of the
    same 256 chips/pod (perf-iteration knob; the default is the baseline).
    """
    chips = 256
    data = data_axis or 16
    assert chips % data == 0, data
    model = chips // data
    shape = (2, data, model) if multi_pod else (data, model)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_host_mesh(model_axis: int = 1, data_axis: int = 1,
                   multi_pod: bool = False):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    data_axis = min(data_axis, n // model_axis) or 1
    if multi_pod:
        return _mesh((1, data_axis, model_axis), ("pod", "data", "model"))
    return _mesh((data_axis, model_axis), ("data", "model"))


def client_axes(mesh) -> tuple:
    """Mesh axes along which FL clients are laid out."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def n_clients(mesh) -> int:
    n = 1
    for a in client_axes(mesh):
        n *= mesh.shape[a]
    return n
