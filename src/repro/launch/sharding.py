"""Logical-axis -> PartitionSpec resolution (MaxText-style rules).

Model code records a tuple of logical axis names per parameter dimension
(see models/common.ParamBuilder). This module maps those names to mesh
axes with two safety rules:
  * divisibility — a mesh axis is only used if the dimension size is a
    multiple of the (product of) mesh axis size(s); otherwise fall through
    to the next candidate (usually replication),
  * uniqueness — one mesh axis may appear at most once per tensor; if a
    later dimension requests an axis already consumed, it is replicated.

Default rules (tensor-parallel over "model", expert/FSDP over "data"):
  heads/kv_heads/mlp/expert_mlp/ssm_inner/lru/vocab -> "model"
  experts -> "data"   (expert parallelism; kimi-scale weights must shard
                       over both data and model to fit HBM)
  batch -> ("pod","data")
  cache_seq -> "data" only when the batch is not shardable (decode bs=1)
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


# candidate mesh axes per logical axis, in priority order; each candidate is
# a tuple of mesh axes used together on that dimension
DEFAULT_RULES: dict[str, tuple] = {
    "batch": (("pod", "data"), ("data",)),
    "vocab": (("model",),),
    "embed": (),
    "heads": (("model",),),
    "kv_heads": (("model",),),
    "head_dim": (),
    "mlp": (("model",),),
    "experts": (("data",),),
    "expert_mlp": (("model",),),
    "ssm_inner": (("model",),),
    "ssm_state": (),
    "dt_rank": (),
    "lru": (("model",),),
    "conv": (),
    "layers": (),
    "seq": (),
    "cache_seq": (),
    "enc_seq": (),
}


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    rules: dict

    @classmethod
    def default(cls, overrides: Optional[dict] = None):
        r = dict(DEFAULT_RULES)
        if overrides:
            r.update(overrides)
        return cls(rules=r)

    def spec_for(self, mesh: Mesh, shape: tuple, axes: tuple) -> P:
        assert len(shape) == len(axes), (shape, axes)
        used: set = set()
        out = []
        for dim, name in zip(shape, axes):
            chosen = None
            for mesh_axes in self.rules.get(name, ()):
                if any(a not in mesh.axis_names for a in mesh_axes):
                    continue
                if any(a in used for a in mesh_axes):
                    continue
                size = int(np.prod([mesh.shape[a] for a in mesh_axes]))
                if dim % size != 0:
                    continue
                chosen = tuple(mesh_axes)
                used.update(mesh_axes)
                break
            out.append(chosen if chosen is None or len(chosen) > 1
                       else chosen[0])
        # strip trailing None for a tidy spec
        while out and out[-1] is None:
            out.pop()
        return P(*out)

    def tree_specs(self, mesh: Mesh, shapes_tree, axes_tree):
        """PartitionSpec pytree for (abstract) params + axes trees."""
        def leaf(s, a):
            return self.spec_for(mesh, tuple(s.shape), tuple(a))
        return jax.tree.map(leaf, shapes_tree, axes_tree,
                            is_leaf=lambda x: isinstance(x, tuple)
                            and all(isinstance(e, str) for e in x))

    def tree_shardings(self, mesh: Mesh, shapes_tree, axes_tree):
        specs = self.tree_specs(mesh, shapes_tree, axes_tree)
        return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                            is_leaf=lambda x: isinstance(x, P))


def params_specs(mesh: Mesh, abstract_params, axes_tree,
                 rules: Optional[ShardingRules] = None):
    rules = rules or ShardingRules.default()
    # tree.map over two trees: axes leaves are tuples of str — guard is_leaf
    def leaf(s, a):
        return rules.spec_for(mesh, tuple(s.shape), tuple(a))
    return jax.tree.map(leaf, abstract_params, axes_tree)


def cache_axes(cache_tree):
    """Logical axes for a cache pytree, derived from leaf names/shapes."""
    def walk(path, x):
        names = [p.key for p in path if hasattr(p, "key")]
        leafname = names[-1] if names else ""
        nd = x.ndim
        lead = ("layers",) if names and names[0] == "groups" else ()
        body_nd = nd - len(lead)
        if leafname in ("k", "v"):
            body = ("batch", "cache_seq", "kv_heads", "head_dim")
        elif leafname == "pos":
            body = ("batch", "cache_seq")
        elif leafname == "conv":
            body = ("batch", "conv", "ssm_inner")
        elif leafname == "h" and body_nd == 3:
            body = ("batch", "ssm_inner", "ssm_state")
        elif leafname == "h":
            body = ("batch", "lru")
        else:
            body = tuple(None for _ in range(body_nd))
        assert len(body) == body_nd, (names, x.shape)
        return lead + body
    return jax.tree_util.tree_map_with_path(walk, cache_tree)


def batch_axes(batch_tree):
    """Logical axes for a model-input batch dict."""
    def leaf_axes(path, x):
        name = path[-1].key
        if name == "tokens":
            return ("batch", "seq")
        if name == "patches":
            return ("batch", "seq", "embed")
        if name == "frames":
            return ("batch", "enc_seq", "embed")
        return tuple(None for _ in range(x.ndim))
    return jax.tree_util.tree_map_with_path(leaf_axes, batch_tree)


def decode_rules(batch: int, mesh: Mesh) -> ShardingRules:
    """Rules for decode: shard cache sequence when batch can't shard."""
    client = [a for a in ("pod", "data") if a in mesh.axis_names]
    n_client = int(np.prod([mesh.shape[a] for a in client]))
    if batch % n_client == 0:
        return ShardingRules.default()
    # batch unshardable (e.g. long_500k bs=1): sequence-shard the KV cache
    return ShardingRules.default(overrides={
        "batch": (),
        "cache_seq": (("data",),),
        "seq": (("data",),),
    })
