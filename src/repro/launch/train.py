"""FL LM training launcher (module CLI).

Drives the full pipeline on whatever devices exist: offline SCA design
from the channel statistics -> per-round fading -> wireless collective
train step -> checkpointing. The same code path scales from the 1-CPU
container to the 256-chip production mesh (launch with
XLA_FLAGS=--xla_force_host_platform_device_count=N to simulate N chips).

    PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
        --aggregator ota --steps 100 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from ..configs import ARCH_IDS, get_config
from ..checkpoint import save_checkpoint
from ..core.bounds import ObjectiveWeights
from ..core.channel import FadingProcess, WirelessConfig, make_deployment
from ..core import ota_design, digital_design
from ..models import make_model, param_count
from ..models.common import ModelConfig
from ..optim.sgd import SGDConfig
from .mesh import make_host_mesh, n_clients
from .steps import fl_round_arrays, make_train_step


def synthetic_token_batch(rng, vocab, batch, seq):
    """Markov token stream with learnable bigram structure."""
    succ = (np.arange(vocab) * 7 + 3) % vocab
    toks = np.empty((batch, seq), np.int32)
    toks[:, 0] = rng.integers(0, vocab, batch)
    for t in range(1, seq):
        follow = rng.random(batch) < 0.8
        toks[:, t] = np.where(follow, succ[toks[:, t - 1]],
                              rng.integers(0, vocab, batch))
    return {"tokens": jnp.asarray(toks)}


def build_cfg(args) -> ModelConfig:
    if args.arch:
        cfg = get_config(args.arch)
        return cfg.scaled_down() if args.reduced else cfg
    return ModelConfig(name="fl-lm", arch_type="dense",
                       n_layers=args.layers, d_model=args.d_model,
                       n_heads=8, n_kv_heads=4, d_ff=3 * args.d_model,
                       vocab_size=args.vocab, dtype=jnp.float32)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default=None)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--aggregator", default="ota",
                    choices=("ideal", "ota", "digital"))
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=6)
    ap.add_argument("--vocab", type=int, default=1024)
    ap.add_argument("--eta", type=float, default=1.0)
    ap.add_argument("--momentum", type=float, default=0.0)
    ap.add_argument("--g-max", type=float, default=10.0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--moe-impl", default="auto", choices=("auto", "ep"))
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = build_cfg(args)
    model = make_model(cfg)
    mesh = make_host_mesh(model_axis=1, data_axis=len(jax.devices()))
    nc = n_clients(mesh)
    dep = make_deployment(WirelessConfig(n_devices=nc, seed=1))
    w = ObjectiveWeights.non_convex(eta=args.eta, smooth_l=10.0,
                                    kappa_nc=0.5 * args.g_max, n=nc)
    spec = ota_design.OTADesignSpec(
        lambdas=dep.lambdas, dim=100_000, g_max=args.g_max,
        e_s=dep.cfg.energy_per_symbol, n0=dep.cfg.noise_power, weights=w)
    ota_params, _ = ota_design.design_ota_direct(spec)
    print(f"mesh={dict(mesh.shape)} clients={nc} "
          f"p_m={np.round(ota_params.participation_levels(dep.lambdas), 3)}")

    flags = {"moe_impl": args.moe_impl} if args.moe_impl != "auto" else None
    sb = make_train_step(model, mesh, aggregator=args.aggregator,
                         sgd=SGDConfig(eta=args.eta,
                                       momentum=args.momentum),
                         batch=args.batch, seq=args.seq, flags=flags)
    step = jax.jit(sb.fn, in_shardings=sb.in_shardings,
                   out_shardings=sb.out_shardings, donate_argnums=(0,))
    params = model.init(jax.random.key(args.seed))
    print(f"model: {cfg.name}  params={param_count(params):,}")

    fading = FadingProcess(dep, seed=7)
    taus = ota_params.thresholds()
    rng = np.random.default_rng(args.seed)
    gam_scale = float(np.mean(ota_params.gammas))
    t0 = time.time()
    for t in range(args.steps):
        batch = synthetic_token_batch(rng, cfg.vocab_size, args.batch,
                                      args.seq)
        chis = (fading.gains(t) >= taus).astype(np.float64)
        fl = fl_round_arrays(
            mesh, gammas=ota_params.gammas / gam_scale, chis=chis,
            alpha=ota_params.alpha / gam_scale,
            noise_scale=np.sqrt(ota_params.noise_psd) / ota_params.alpha
            * 1e-2, levels=255.0)
        params, loss = step(params, batch, fl, jax.random.key(t))
        if t % 10 == 0 or t == args.steps - 1:
            print(f"step {t:4d}  loss {float(loss):.4f}  "
                  f"({time.time() - t0:.1f}s)", flush=True)
        if args.ckpt_dir and (t + 1) % args.ckpt_every == 0:
            path = save_checkpoint(args.ckpt_dir, t + 1, params)
            print(f"checkpoint -> {path}")
    print("done.")


if __name__ == "__main__":
    main()
