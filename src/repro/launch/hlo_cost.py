"""Trip-count-aware cost model over optimized HLO text.

``compiled.cost_analysis()`` counts a ``while`` body ONCE regardless of its
trip count, which makes every scanned-layer model look ~n_layers/1 cheaper
than it is. This module re-derives per-device FLOPs / HBM bytes /
collective link-bytes by walking the HLO call graph:

  * computations are parsed from ``compiled.as_text()``;
  * call edges (fusion calls / while body+cond / to_apply) carry a
    multiplier; ``while`` multipliers come from the loop condition's
    ``compare(iv, constant(N)), direction=LT`` pattern (fallback 1);
  * per op:   dot  -> 2 * prod(result_dims) * K   (K from contracting dims)
              conv -> 2 * prod(result) * prod(kernel_spatial) * in_features
              elementwise/other -> prod(result)   (1 flop per element)
    (counted in the computation where the op lives, then scaled by the
    product of multipliers on the call path);
  * HBM bytes: for top-level ops, sum of operand + result sizes; ops inside
    fusions are free (XLA's own model); parameters of a fusion are counted
    at the fusion call site;
  * collectives: payload converted to effective per-device link bytes with
    ring factors (see ``COLL_FACTORS``).

This is an analytic approximation (it ignores layout padding and assumes
ring algorithms) but it is *consistent* across configurations, which is
what the §Roofline comparisons need.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "token": 0, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HDR_RE = re.compile(r"^\s*(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(")
_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+)$")
_CALLED_RE = re.compile(
    r"(?:to_apply|body|condition|calls)=%?([\w\.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CONST_CMP_RE = re.compile(r"constant\((\d+)\)")

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_IOTA_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_LIST_GROUPS_RE = re.compile(r"replica_groups=\{\{([\d,]*)\}")


def _shape_elems_bytes(text: str):
    elems, nbytes = 0, 0
    for m in _SHAPE_RE.finditer(text):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        elems += n
        nbytes += n * _DTYPE_BYTES[dt]
    return elems, nbytes


@dataclasses.dataclass
class _Op:
    name: str
    rhs: str               # full right-hand side text
    opcode: str
    result_text: str       # result type(s) portion


@dataclasses.dataclass
class _Computation:
    name: str
    ops: list


_OPCODE_RE = re.compile(
    r"\)?\s*([a-z][\w\-]*)\(")


def parse_computations(hlo: str) -> dict:
    comps: dict[str, _Computation] = {}
    cur = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        if cur is None:
            if line.endswith("{") and "->" in line:
                m = _COMP_HDR_RE.match(line)
                if m:
                    cur = _Computation(m.group(1), [])
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        name, rhs = m.group(1), m.group(2)
        # opcode = first word after the result type: find "<type> opcode("
        op_pos = None
        opcode = None
        om = re.search(r"\}?\s([a-z][a-z0-9\-]*)\(", rhs)
        if om:
            opcode = om.group(1)
            op_pos = om.start(1)
        else:
            continue
        result_text = rhs[:op_pos]
        cur.ops.append(_Op(name, rhs, opcode, result_text))
    return comps


_OPERAND_RE = re.compile(r"\(%?([\w\.\-]+)")


def _operand_args(op: _Op) -> str:
    after = op.rhs.split(op.opcode + "(", 1)
    if len(after) < 2:
        return ""
    return after[1].split(")", 1)[0]


def _operand_names(op: _Op) -> list:
    args = _operand_args(op)
    # optimized HLO writes typed operands ("f32[128,128]{1,0} %dot.0"):
    # the %-sigiled token is the name; fall back to bare tokens for
    # scheduled HLO, skipping dtype keywords
    names = re.findall(r"%([\w\.\-]+)", args)
    if names:
        return names
    return [tok for tok in re.findall(r"([\w\.\-]+)", args)
            if tok not in _DTYPE_BYTES]


def _operand_shapes(op: _Op) -> list:
    """Inline operand dims lists, when the HLO carries typed operands."""
    shapes = []
    for m in _SHAPE_RE.finditer(_operand_args(op)):
        dims = m.group(2)
        shapes.append([int(x) for x in dims.split(",")] if dims else [])
    return shapes


def _dot_flops(op: _Op, shape_of) -> float:
    """2 * prod(result) * K, K = product of lhs contracting dims.

    Operand shapes come from the inline operand types when present
    (optimized HLO) and from the ``shape_of`` symbol table (op name ->
    dims list) otherwise (scheduled HLO omits inline types).
    """
    shapes = _operand_shapes(op)
    if shapes:
        lhs_dims = shapes[0]
    else:
        names = _operand_names(op)
        lhs_dims = shape_of(names[0]) if names else None
    mc = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.rhs)
    k = 1
    if lhs_dims and mc and mc.group(1):
        for i in mc.group(1).split(","):
            idx = int(i)
            if idx < len(lhs_dims):
                k *= lhs_dims[idx]
    out_elems, _ = _shape_elems_bytes(op.result_text)
    return 2.0 * out_elems * k


def _conv_flops(op: _Op, shape_of) -> float:
    out_elems, _ = _shape_elems_bytes(op.result_text)
    shapes = _operand_shapes(op)
    if len(shapes) > 1:
        kdims = shapes[1]
    else:
        names = _operand_names(op)
        kdims = shape_of(names[1]) if len(names) > 1 else None
    if kdims:
        kernel = 1
        for d in kdims:
            kernel *= d
        odims = kdims[-1] if kdims else 1
        return 2.0 * out_elems * max(kernel // max(odims, 1), 1)
    return 2.0 * out_elems


def _group_size(rhs: str) -> int:
    m = _IOTA_GROUPS_RE.search(rhs)
    if m:
        return max(int(m.group(2)), 1)
    m = _LIST_GROUPS_RE.search(rhs)
    if m and m.group(1):
        return len(m.group(1).split(","))
    return 2


COLL_FACTORS = {
    "all-reduce": lambda size, g: 2.0 * size * (g - 1) / g,
    "all-gather": lambda size, g: size * (g - 1) / g,
    "reduce-scatter": lambda size, g: size * (g - 1) / g,
    "all-to-all": lambda size, g: size * (g - 1) / g,
    "collective-permute": lambda size, g: float(size),
}

_SKIP_BYTES_OPS = {"parameter", "constant", "get-tuple-element", "tuple",
                   "bitcast", "copy", "copy-start", "copy-done"}


@dataclasses.dataclass
class HLOCost:
    flops: float
    hbm_bytes: float
    collective_bytes: float
    per_collective: dict
    n_while: int
    while_trips: dict

    def summary(self) -> dict:
        return {"flops": self.flops, "hbm_bytes": self.hbm_bytes,
                "collective_bytes": self.collective_bytes,
                "per_collective": dict(self.per_collective),
                "while_trips": dict(self.while_trips)}


def _trip_count(cond: _Computation) -> int:
    """Parse `compare(iv, constant(N)) direction=LT` style conditions."""
    for op in cond.ops:
        if op.opcode == "compare" and "direction=LT" in op.rhs:
            m = _CONST_CMP_RE.search(op.rhs)
            if m:
                return max(int(m.group(1)), 1)
    # constants may be hoisted: look for any constant in the condition
    for op in cond.ops:
        m = _CONST_CMP_RE.search(op.rhs)
        if m and int(m.group(1)) > 1:
            return int(m.group(1))
    return 1


def analyze_hlo(hlo: str, entry_hint: str = "main") -> HLOCost:
    comps = parse_computations(hlo)
    # entry computation: the one named like *main* or the last ENTRY parsed
    entry = None
    for name in comps:
        if entry_hint in name:
            entry = name
    if entry is None and comps:
        entry = list(comps)[-1]

    flops = defaultdict(float)        # per computation (local)
    hbm = defaultdict(float)
    coll = defaultdict(lambda: defaultdict(float))
    calls = defaultdict(list)         # comp -> [(callee, multiplier)]
    while_trips = {}

    # symbol table: op name -> result dims (first array shape in result)
    shape_tab: dict[str, list] = {}
    for comp in comps.values():
        for op in comp.ops:
            m = _SHAPE_RE.search(op.result_text)
            if m:
                dims = [int(d) for d in m.group(2).split(",")] if m.group(2) \
                    else []
                shape_tab.setdefault(op.name, dims)

    def shape_of(name):
        return shape_tab.get(name)

    for cname, comp in comps.items():
        in_fusion = cname.startswith("fused") or ".fused" in cname
        for op in comp.ops:
            out_elems, out_bytes = _shape_elems_bytes(op.result_text)
            if op.opcode == "dot":
                flops[cname] += _dot_flops(op, shape_of)
            elif op.opcode == "convolution":
                flops[cname] += _conv_flops(op, shape_of)
            elif op.opcode in ("while",):
                body = cond = None
                bm = re.search(r"body=%?([\w\.\-]+)", op.rhs)
                cm = re.search(r"condition=%?([\w\.\-]+)", op.rhs)
                if bm:
                    body = bm.group(1)
                if cm:
                    cond = cm.group(1)
                tm = _TRIP_RE.search(op.rhs)
                if tm:
                    trips = max(int(tm.group(1)), 1)
                elif cond in comps:
                    trips = _trip_count(comps[cond])
                else:
                    trips = 1
                while_trips[op.name] = trips
                if body in comps:
                    calls[cname].append((body, float(trips)))
                if cond in comps:
                    calls[cname].append((cond, float(trips)))
                continue
            elif op.opcode in ("fusion", "call", "custom-call", "map",
                               "reduce", "reduce-window", "sort", "scatter",
                               "select-and-scatter", "conditional"):
                for callee in _CALLED_RE.findall(op.rhs):
                    if callee in comps:
                        calls[cname].append((callee, 1.0))
                if op.opcode == "fusion":
                    # fusion body flops counted via callee; HBM: params+result
                    hbm[cname] += out_bytes
                    _, arg_bytes = _shape_elems_bytes(
                        op.rhs.split("fusion(", 1)[1].split(")", 1)[0]
                        if "fusion(" in op.rhs else "")
                    hbm[cname] += arg_bytes
                    continue
            elif op.opcode in _COLLECTIVES or any(
                    op.opcode == f"{c}-start" for c in _COLLECTIVES):
                kind = op.opcode.replace("-start", "")
                g = _group_size(op.rhs)
                coll[cname][kind] += COLL_FACTORS[kind](out_bytes, g)
                continue
            else:
                if op.opcode not in _SKIP_BYTES_OPS:
                    flops[cname] += out_elems
            # HBM accounting for non-fusion top-level ops: result bytes
            if not in_fusion and op.opcode not in _SKIP_BYTES_OPS and \
               op.opcode != "fusion":
                hbm[cname] += out_bytes

    # accumulate over the call graph with multipliers (memoized)
    memo_f, memo_h, memo_c = {}, {}, {}

    def total(cname, depth=0):
        if cname in memo_f:
            return memo_f[cname], memo_h[cname], memo_c[cname]
        if depth > 64:
            return 0.0, 0.0, defaultdict(float)
        f, h = flops[cname], hbm[cname]
        c = defaultdict(float, coll[cname])
        for callee, mult in calls[cname]:
            cf, ch, cc = total(callee, depth + 1)
            f += mult * cf
            h += mult * ch
            for k, v in cc.items():
                c[k] += mult * v
        memo_f[cname], memo_h[cname], memo_c[cname] = f, h, c
        return f, h, c

    f, h, c = (0.0, 0.0, defaultdict(float))
    if entry is not None:
        f, h, c = total(entry)
    return HLOCost(flops=f, hbm_bytes=h,
                   collective_bytes=float(sum(c.values())),
                   per_collective=dict(c), n_while=len(while_trips),
                   while_trips=while_trips)
