"""Compiled-HLO analysis: FLOPs/bytes (cost_analysis) + collective traffic.

``collective_bytes`` is not part of XLA's cost_analysis, so we parse the
optimized HLO (``compiled.as_text()``) and sum the payload of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute,
converted to *per-device link bytes* with the standard ring-algorithm
factors:

    all-reduce      2 * size * (g-1)/g     (reduce-scatter + all-gather)
    all-gather      size * (g-1)/g         (size = gathered result)
    reduce-scatter  size * (g-1)/g         (size = input)
    all-to-all      size * (g-1)/g
    collective-permute  size

where g is the replica-group size parsed from the op's replica_groups.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_ARRAY_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
# iota replica groups: [16,32]<=[512] — 16 groups of 32
_IOTA_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_LIST_GROUPS_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")


def _shape_bytes(text: str) -> int:
    """Sum bytes of all array shapes in a result-type string."""
    total = 0
    for m in _ARRAY_RE.finditer(text):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _IOTA_GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _LIST_GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 2


@dataclasses.dataclass
class CollectiveStats:
    per_op_bytes: dict            # op kind -> effective link bytes (global)
    per_op_count: dict
    total_bytes: float            # sum of effective link bytes

    def summary(self) -> dict:
        return {"total_bytes": self.total_bytes,
                "per_op_bytes": dict(self.per_op_bytes),
                "per_op_count": dict(self.per_op_count)}


def collective_stats(hlo_text: str) -> CollectiveStats:
    per_bytes = defaultdict(float)
    per_count = defaultdict(int)
    for line in hlo_text.splitlines():
        s = line.strip()
        if "=" not in s:
            continue
        kind = None
        for k in _COLLECTIVES:
            # match op invocation " kind(" or "kind-start("
            if f" {k}(" in s or f" {k}-start(" in s:
                kind = k
                break
        if kind is None:
            continue
        lhs, rhs = s.split("=", 1)
        # result type(s) are at the start of rhs, before the op name
        op_pos = rhs.find(kind)
        result_text = rhs[:op_pos]
        size = _shape_bytes(result_text)
        g = _group_size(s)
        if kind == "all-reduce":
            eff = 2.0 * size * (g - 1) / g
        elif kind in ("all-gather", "reduce-scatter", "all-to-all"):
            eff = size * (g - 1) / g
        else:
            eff = float(size)
        per_bytes[kind] += eff
        per_count[kind] += 1
    return CollectiveStats(per_op_bytes=dict(per_bytes),
                           per_op_count=dict(per_count),
                           total_bytes=float(sum(per_bytes.values())))


def cost_summary(compiled) -> dict:
    """flops / bytes from compiled.cost_analysis(), robust to backend quirks."""
    out = {"flops": None, "bytes_accessed": None, "transcendentals": None}
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        if ca:
            out["flops"] = float(ca.get("flops", 0.0))
            out["bytes_accessed"] = float(ca.get("bytes accessed", 0.0))
            out["transcendentals"] = float(ca.get("transcendentals", 0.0))
    except Exception as e:       # pragma: no cover - backend specific
        out["error"] = str(e)
    return out


def memory_summary(compiled) -> dict:
    out = {}
    try:
        ma = compiled.memory_analysis()
        if ma is None:
            return {"unavailable": True}
        for field in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "alias_size_in_bytes",
                      "generated_code_size_in_bytes"):
            v = getattr(ma, field, None)
            if v is not None:
                out[field] = int(v)
    except Exception as e:       # pragma: no cover
        out["error"] = str(e)
    return out
