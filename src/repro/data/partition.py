"""Non-i.i.d. data partitioners (Sec. V's heterogeneous splits)."""
from __future__ import annotations

import numpy as np


def partition_by_class(x: np.ndarray, y: np.ndarray, n_devices: int,
                       classes_per_device: int, samples_per_device: int,
                       seed: int = 0) -> list[tuple[np.ndarray, np.ndarray]]:
    """Assign each device `classes_per_device` classes and draw its samples
    only from those classes (paper: 1 for MNIST/N=10..50, 2 for CIFAR).

    Classes are assigned round-robin so every class is covered when
    n_devices >= n_classes (e.g. N=50, 10 classes -> 5 devices per class).
    """
    rng = np.random.default_rng(seed)
    n_classes = int(y.max()) + 1
    idx_by_class = [np.flatnonzero(y == c) for c in range(n_classes)]
    for idx in idx_by_class:
        rng.shuffle(idx)
    cursors = [0] * n_classes
    shards = []
    for m in range(n_devices):
        classes = [(m * classes_per_device + j) % n_classes
                   for j in range(classes_per_device)]
        per_cls = samples_per_device // classes_per_device
        xs, ys = [], []
        for c in classes:
            idx = idx_by_class[c]
            take = idx[cursors[c]:cursors[c] + per_cls]
            if take.shape[0] < per_cls:     # wrap around (re-use) if exhausted
                cursors[c] = 0
                take = idx[:per_cls]
            cursors[c] += per_cls
            xs.append(x[take])
            ys.append(y[take])
        shards.append((np.concatenate(xs), np.concatenate(ys)))
    return shards


def partition_iid(x: np.ndarray, y: np.ndarray, n_devices: int,
                  samples_per_device: int, seed: int = 0):
    """Homogeneous split (used in ablations)."""
    rng = np.random.default_rng(seed)
    perm = rng.permutation(x.shape[0])
    shards = []
    for m in range(n_devices):
        take = perm[m * samples_per_device:(m + 1) * samples_per_device]
        shards.append((x[take], y[take]))
    return shards
