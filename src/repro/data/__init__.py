from .synthetic import SyntheticSpec, make_classification_dataset
from .partition import partition_by_class, partition_iid
from .loader import DeviceDataset, FLDataset

__all__ = ["SyntheticSpec", "make_classification_dataset",
           "partition_by_class", "partition_iid", "DeviceDataset",
           "FLDataset"]
