"""Per-device dataset handles and mini-batch sampling."""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass
class DeviceDataset:
    x: np.ndarray
    y: np.ndarray

    def __len__(self):
        return self.x.shape[0]

    def batch(self, batch_size: Optional[int], rng: np.random.Generator):
        """Full-batch when batch_size is None (paper Sec. V: |B|=|D|)."""
        if batch_size is None or batch_size >= len(self):
            return self.x, self.y
        idx = rng.choice(len(self), size=batch_size, replace=False)
        return self.x[idx], self.y[idx]


@dataclasses.dataclass
class FLDataset:
    devices: list          # list[DeviceDataset]
    x_test: np.ndarray
    y_test: np.ndarray

    @property
    def n_devices(self):
        return len(self.devices)

    @classmethod
    def from_shards(cls, shards, x_test, y_test):
        return cls([DeviceDataset(x, y) for x, y in shards], x_test, y_test)
