"""Per-device dataset handles and mini-batch sampling."""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass
class DeviceDataset:
    x: np.ndarray
    y: np.ndarray

    def __len__(self):
        return self.x.shape[0]

    def batch(self, batch_size: Optional[int],
              rng: Optional[np.random.Generator] = None, *,
              indices: Optional[np.ndarray] = None):
        """Full-batch when batch_size is None (paper Sec. V: |B|=|D|).

        Mini-batches are drawn from the counter-based sampler
        (``core.rngstream.batch_indices_np``) via ``indices`` — the draw the
        JAX engine regenerates bit-identically inside its scan. Passing a
        sequential ``rng`` instead is the legacy path (not replayable by the
        engine) and requires ``indices`` to be None.
        """
        if rng is not None and indices is not None:
            raise ValueError("pass counter-based indices OR a legacy rng, "
                             "not both (the rng would be silently unused)")
        if batch_size is None or batch_size >= len(self):
            return self.x, self.y
        if indices is None:
            if rng is None:
                raise ValueError(
                    "mini-batch draw needs counter-based indices "
                    "(core.rngstream.batch_indices_np) or a legacy rng")
            idx = rng.choice(len(self), size=batch_size, replace=False)
        else:
            idx = np.asarray(indices)
        return self.x[idx], self.y[idx]


@dataclasses.dataclass
class FLDataset:
    devices: list          # list[DeviceDataset]
    x_test: np.ndarray
    y_test: np.ndarray

    @property
    def n_devices(self):
        return len(self.devices)

    @classmethod
    def from_shards(cls, shards, x_test, y_test):
        return cls([DeviceDataset(x, y) for x, y in shards], x_test, y_test)
