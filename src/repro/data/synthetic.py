"""Deterministic synthetic image-classification datasets.

The container has no network access, so MNIST/CIFAR-10 are replaced by
class-conditional Gaussian image datasets with matched shapes and per-class
structure ("mnist-like": 28x28x1, 10 classes; "cifar-like": 32x32x3,
10 classes). Each class c has a smooth prototype image mu_c (random
low-frequency pattern) and samples x = clip(mu_c + sigma * eps).

What matters for the paper's phenomena is preserved exactly:
  * classification is non-trivial but learnable by softmax regression,
  * the single-class / two-class per-device splits create the extreme data
    heterogeneity (large kappa) that drives the bias-variance trade-off.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class SyntheticSpec:
    name: str = "mnist-like"
    n_classes: int = 10
    image_shape: tuple = (28, 28, 1)
    n_train_per_class: int = 1200
    n_test_per_class: int = 200
    noise_sigma: float = 0.45
    seed: int = 0

    @property
    def dim(self) -> int:
        return int(np.prod(self.image_shape))


def _low_freq_prototype(rng: np.random.Generator, shape: tuple) -> np.ndarray:
    """Smooth random prototype: low-frequency Fourier mixture, in [0,1]."""
    h, w = shape[0], shape[1]
    c = shape[2] if len(shape) > 2 else 1
    yy, xx = np.meshgrid(np.linspace(0, 1, h), np.linspace(0, 1, w),
                         indexing="ij")
    img = np.zeros((h, w, c))
    for ch in range(c):
        acc = np.zeros((h, w))
        for _ in range(6):
            fy, fx = rng.integers(1, 4, size=2)
            phase = rng.uniform(0, 2 * np.pi, size=2)
            amp = rng.uniform(0.4, 1.0)
            acc += amp * np.sin(2 * np.pi * fy * yy + phase[0]) \
                       * np.cos(2 * np.pi * fx * xx + phase[1])
        acc = (acc - acc.min()) / (acc.max() - acc.min() + 1e-9)
        img[..., ch] = acc
    return img


def make_classification_dataset(spec: SyntheticSpec):
    """Returns (x_train, y_train, x_test, y_test), images flattened to (n,d)."""
    rng = np.random.default_rng(spec.seed)
    protos = [_low_freq_prototype(rng, spec.image_shape)
              for _ in range(spec.n_classes)]
    def sample(n_per_class, rng):
        xs, ys = [], []
        for cls in range(spec.n_classes):
            eps = rng.normal(size=(n_per_class,) + tuple(spec.image_shape))
            x = np.clip(protos[cls][None] + spec.noise_sigma * eps, 0.0, 1.0)
            xs.append(x.reshape(n_per_class, -1))
            ys.append(np.full(n_per_class, cls, dtype=np.int64))
        x = np.concatenate(xs).astype(np.float32)
        y = np.concatenate(ys)
        perm = rng.permutation(x.shape[0])
        return x[perm], y[perm]

    x_tr, y_tr = sample(spec.n_train_per_class, rng)
    x_te, y_te = sample(spec.n_test_per_class, rng)
    # standardize features (helps conditioning; deterministic)
    mean, std = x_tr.mean(0, keepdims=True), x_tr.std(0, keepdims=True) + 1e-6
    x_tr = (x_tr - mean) / std
    x_te = (x_te - mean) / std
    return x_tr, y_tr, x_te, y_te


# noise_sigma calibrated so Ideal-FedAvg softmax regression lands ~86%
# (comparable to the paper's MNIST softmax ceiling ~90%), leaving headroom
# for the wireless schemes to separate.
MNIST_LIKE = SyntheticSpec(name="mnist-like", image_shape=(28, 28, 1),
                           n_train_per_class=1200, n_test_per_class=200,
                           noise_sigma=1.5)
CIFAR_LIKE = SyntheticSpec(name="cifar-like", image_shape=(32, 32, 3),
                           n_train_per_class=200, n_test_per_class=100,
                           noise_sigma=1.8, seed=7)
