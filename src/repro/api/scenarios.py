"""Named scenario/sweep builders: the paper figures + beyond-paper sweeps.

Each builder returns a pure-data ``ScenarioSpec``/``SweepSpec``; the figure
entries reproduce the legacy hand-rolled pipelines' protocols exactly
(same seeds, sizes, suites, tuning grids), so executing them yields the
pre-refactor trajectories. ``REGISTRY`` backs the CLI
(``python -m repro.api.cli run/list/describe``).
"""
from __future__ import annotations

from ..core.async_fl import AsyncSpec
from ..core.channel import WirelessConfig
from ..core.faults import FaultSpec
from .spec import (DataSpec, DesignPolicy, RunSpec, ScenarioSpec, SweepSpec,
                   TaskSpec)


def fig2_ota_sc(quick: bool = True, n_devices: int = 50) -> ScenarioSpec:
    """Paper Fig. 2a/2b: strongly convex OTA-FL comparison (Sec. V-A-1)."""
    return ScenarioSpec(
        name="fig2_ota_sc",
        data=DataSpec(
            n_train_per_class=((n_devices * 300) // 10 if quick else 6000),
            samples_per_device=300 if quick else 1000),
        wireless=WirelessConfig(n_devices=n_devices, seed=1),
        design=DesignPolicy(),
        run=RunSpec(rounds=80 if quick else 300, trials=2 if quick else 4,
                    eval_every=10,
                    etas=(1.0, 0.25) if quick else (1.0, 0.5, 0.25, 0.1)),
        schemes=("suite:fig2_ota",))


def fig2_digital_sc(quick: bool = True, n_devices: int = 10) -> ScenarioSpec:
    """Paper Fig. 2c/2d: digital FL vs wall-clock latency (Sec. V-A-2)."""
    return ScenarioSpec(
        name="fig2_digital_sc",
        data=DataSpec(n_train_per_class=600 if quick else 1200,
                      samples_per_device=300 if quick else 1000),
        wireless=WirelessConfig(n_devices=n_devices, seed=1),
        design=DesignPolicy(t_max_s=0.2),
        run=RunSpec(rounds=400 if quick else 1500,
                    trials=2 if quick else 4, eval_every=20,
                    time_budget_s=40.0 if quick else 150.0,
                    etas=(1.0, 0.25) if quick else (1.0, 0.5, 0.25, 0.1)),
        schemes=("suite:fig2_digital",))


def fig3_nonconvex(quick: bool = True, n_devices: int = 10) -> ScenarioSpec:
    """Paper Fig. 3: non-convex OTA-FL (MLP, two classes/device)."""
    return ScenarioSpec(
        name="fig3_nonconvex",
        task=TaskSpec(kind="mlp", n_features=3072, hidden=48, mu=0.01,
                      g_max=49.0),
        data=DataSpec(name="cifar-like", image_shape=(32, 32, 3),
                      n_train_per_class=120, n_test_per_class=100,
                      noise_sigma=1.8, dataset_seed=7,
                      classes_per_device=2, samples_per_device=100,
                      partition_seed=5),
        wireless=WirelessConfig(n_devices=n_devices, seed=1),
        design=DesignPolicy(objective="non_convex", smooth_l=10.0),
        run=RunSpec(rounds=100 if quick else 400, trials=2 if quick else 3,
                    eval_every=10, seed=9, eta_max=0.08,
                    etas=(1.0, 0.5) if quick else (1.5, 1.0, 0.5, 0.25)),
        schemes=("suite:fig3_ota",))


def snr_het(quick: bool = True, n_devices: int = 10) -> SweepSpec:
    """Beyond-paper workload: SNR x path-loss-heterogeneity sweep.

    Compares the proposed biased OTA and digital schemes against their
    zero-bias baselines (Vanilla OTA-FL; proportional-fairness selection)
    over a grid of transmit power (SNR) and path-loss exponent
    (heterogeneity level) — the benchmark axes of the OTA-FL literature
    (Zhu et al.; Sery et al.). The whole grid's Sec.-IV designs solve as
    ONE batched jit per scheme family.
    """
    base = ScenarioSpec(
        name="snr_het",
        data=DataSpec(n_train_per_class=300 if quick else 1200,
                      samples_per_device=150 if quick else 600),
        wireless=WirelessConfig(n_devices=n_devices, seed=1),
        design=DesignPolicy(t_max_s=0.2),
        run=RunSpec(rounds=60 if quick else 200, trials=2,
                    eval_every=10, etas=(1.0, 0.25)),
        schemes=("ideal", "proposed_ota", "vanilla_ota",
                 "proposed_digital", "prop_fairness"))
    if quick:
        axes = {"wireless.tx_power_dbm": (-5.0, 5.0),
                "wireless.pl_exponent": (2.2, 2.6)}
    else:
        axes = {"wireless.tx_power_dbm": (-10.0, 0.0, 10.0),
                "wireless.pl_exponent": (2.0, 2.2, 2.6)}
    return SweepSpec(name="snr_het", base=base, axes=axes)


def sweep_smoke(quick: bool = True) -> SweepSpec:
    """CI smoke: a 2x2 SNR x omega_bias sweep at toy scale (~1 min).

    Exercises the whole scenario layer — planning, one batched design
    solve for the grid, engine-backed runs, manifest + content-hash cache
    — with fixed kappa (no estimation) and a single-point eta grid.
    """
    base = ScenarioSpec(
        name="sweep_smoke",
        data=DataSpec(n_train_per_class=60, n_test_per_class=30,
                      samples_per_device=60),
        wireless=WirelessConfig(n_devices=6, seed=1),
        design=DesignPolicy(kappa=3.0),
        run=RunSpec(rounds=8, trials=1, eval_every=4, etas=(1.0,)),
        schemes=("proposed_ota", "vanilla_ota"))
    return SweepSpec(name="sweep_smoke", base=base,
                     axes={"wireless.tx_power_dbm": (-3.0, 3.0),
                           "design.omega_bias_scale": (0.5, 2.0)})


def sweep_fault(quick: bool = True, n_devices: int = 10) -> SweepSpec:
    """Fault injection: outage rate x heterogeneity grid (``core.faults``).

    Sweeps the per-round dropout probability against the path-loss
    exponent (heterogeneity level), with a deep-fade cutoff active
    throughout, comparing the proposed biased OTA design — whose solver
    sees the outage-adjusted effective channel statistics — against the
    zero-bias Vanilla OTA baseline. The thesis cell-by-cell: biased
    designs degrade gracefully with rising fault rates where zero-bias
    aggregation collapses (``benchmarks/sweep_fault.py`` reduces this
    grid to that figure).
    """
    base = ScenarioSpec(
        name="sweep_fault",
        data=DataSpec(n_train_per_class=60 if quick else 600,
                      n_test_per_class=30 if quick else 200,
                      samples_per_device=60 if quick else 300),
        wireless=WirelessConfig(n_devices=6 if quick else n_devices, seed=1),
        design=DesignPolicy(kappa=3.0 if quick else None),
        run=RunSpec(rounds=8 if quick else 100, trials=1 if quick else 2,
                    eval_every=4 if quick else 10,
                    etas=(1.0,) if quick else (1.0, 0.25)),
        fault=FaultSpec(deep_fade_thresh=1e-6, on_missing="reweight"),
        schemes=("proposed_ota", "vanilla_ota"))
    if quick:
        axes = {"fault.dropout_prob": (0.0, 0.3),
                "wireless.pl_exponent": (2.2, 2.6)}
    else:
        axes = {"fault.dropout_prob": (0.0, 0.2, 0.5),
                "wireless.pl_exponent": (2.0, 2.2, 2.6)}
    return SweepSpec(name="sweep_fault", base=base, axes=axes)


def sweep_participation(quick: bool = True, n_devices: int = 50) -> SweepSpec:
    """Partial participation: N x S grid, uniform vs co-designed sampling.

    Every cell runs under heterogeneous channel-dependent deep fades with
    ``on_missing="zero"`` (each device holds ONE class, so a device that
    rarely delivers drags the model away from its class — a structured
    bias), sampling an expected S = ``run.clients_per_round`` devices per
    round. The axes compare the zero-bias ``"uniform"`` policy (pi = S/N)
    against the bound-driven ``"designed"`` policy at the SAME S — equal
    expected airtime — where the capped-simplex solver
    (``core.sca_jax.solve_participation_batch``) tilts pi toward the
    devices that actually deliver, buying post-normalization SNR with a
    priced sampling bias. The cells sit at the variance-limited
    operating point (``omega_bias_scale`` shrinks the footnote-4 bias
    weight — the declared bias-variance trade-off axis): there the
    extra delivered mass outweighs the tilt, and designed sampling
    strictly beats uniform at equal airtime.
    ``benchmarks/sweep_participation.py`` reduces this grid to the
    designed-vs-uniform domination figure.
    """
    base = ScenarioSpec(
        name="sweep_participation",
        data=DataSpec(n_train_per_class=80 if quick else 600,
                      n_test_per_class=30 if quick else 200,
                      samples_per_device=60 if quick else 120),
        wireless=WirelessConfig(n_devices=12 if quick else n_devices,
                                seed=1, pl_exponent=2.6,
                                tx_power_dbm=10.0),
        design=DesignPolicy(kappa=3.0 if quick else None,
                            omega_bias_scale=1e-4),
        run=RunSpec(rounds=20 if quick else 100, trials=2,
                    eval_every=5 if quick else 10,
                    etas=(1.0,) if quick else (1.0, 0.25),
                    clients_per_round=6),
        fault=FaultSpec(deep_fade_thresh=4.5e-7, on_missing="zero"),
        schemes=("proposed_ota", "vanilla_ota"))
    if quick:
        axes = {"wireless.n_devices": (8, 12),
                "run.clients_per_round": (4, 8),
                "run.participation": ("uniform", "designed")}
    else:
        axes = {"wireless.n_devices": (max(n_devices // 2, 2), n_devices),
                "run.clients_per_round": (8, 16),
                "run.participation": ("uniform", "designed")}
    return SweepSpec(name="sweep_participation", base=base, axes=axes)


def sweep_async(quick: bool = True, n_devices: int = 10) -> SweepSpec:
    """Buffered-async FL: arrival-het x buffer x discount grid
    (``core.async_fl``), staleness-priced design point.

    Every cell runs ``run.mode="async"``: devices deliver their round-t
    gradient with heterogeneous per-round arrival probabilities r_m
    (``async_.arrival_rate`` spread by ``async_.rate_heterogeneity``;
    each device holds ONE class, so a slow-arriving device starves its
    class — a structured bias), late updates land from a last-K
    staleness buffer (``async_.buffer_rounds``) discounted by
    ``delta^staleness`` (``async_.staleness_discount``), and the PS
    applies the bound-driven aggregation weights v from
    ``core.sca_jax.solve_async_batch`` (``async_.weighting="designed"``)
    that re-balance the effective participation p_m * c_m * v_m the
    Theorem-1/2 bound prices (``bounds.async_effective_participation``).
    ``benchmarks/sweep_async.py`` derives the naive-async
    (uniform v, delta=1) and synchronous-with-deadline comparison sweeps
    from this base and reduces all three to the equal-wall-clock
    domination figure.
    """
    base = ScenarioSpec(
        name="sweep_async",
        data=DataSpec(n_train_per_class=80 if quick else 600,
                      n_test_per_class=30 if quick else 200,
                      samples_per_device=60 if quick else 120),
        wireless=WirelessConfig(n_devices=8 if quick else n_devices,
                                seed=1, pl_exponent=2.2, tx_power_dbm=10.0),
        design=DesignPolicy(kappa=3.0 if quick else None),
        run=RunSpec(rounds=24 if quick else 100, trials=2,
                    eval_every=6 if quick else 10,
                    etas=(1.0,) if quick else (1.0, 0.25),
                    mode="async"),
        async_=AsyncSpec(buffer_rounds=4, arrival_rate=0.55,
                         rate_heterogeneity=3.0, staleness_discount=0.8,
                         on_missing="zero", weighting="designed"),
        schemes=("proposed_ota",))
    if quick:
        axes = {"async_.rate_heterogeneity": (1.0, 3.0),
                "async_.buffer_rounds": (2, 5),
                "async_.staleness_discount": (0.7, 1.0)}
    else:
        axes = {"async_.rate_heterogeneity": (0.5, 1.5, 3.0),
                "async_.buffer_rounds": (2, 4, 8),
                "async_.staleness_discount": (0.6, 0.8, 1.0)}
    return SweepSpec(name="sweep_async", base=base, axes=axes)


def fig2_batch(quick: bool = True, n_devices: int = 50) -> SweepSpec:
    """Fig. 2a/2b protocol over a ``run.batch_size`` grid (SGD scale).

    The paper's Monte-Carlo uses full-batch device gradients; this sweep
    re-runs the Fig.-2 OTA comparison with minibatch SGD at increasing
    batch sizes (None = full batch) to show the designed bias-variance
    trade-off is preserved under gradient noise — one ``cli run
    fig2_batch`` away instead of a hand-rolled loop.
    """
    base = fig2_ota_sc(quick=quick, n_devices=n_devices).replace(
        name="fig2_batch")
    sizes = (16, 64, None) if quick else (16, 64, 256, None)
    return SweepSpec(name="fig2_batch", base=base,
                     axes={"run.batch_size": sizes})


REGISTRY = {
    "fig2_ota_sc": fig2_ota_sc,
    "fig2_digital_sc": fig2_digital_sc,
    "fig3_nonconvex": fig3_nonconvex,
    "snr_het": snr_het,
    "sweep_smoke": sweep_smoke,
    "sweep_fault": sweep_fault,
    "sweep_participation": sweep_participation,
    "sweep_async": sweep_async,
    "fig2_batch": fig2_batch,
}


def names() -> list[str]:
    return sorted(REGISTRY)


def get(name: str, *, quick: bool = True):
    if name not in REGISTRY:
        raise KeyError(f"unknown scenario {name!r}; registered: {names()}")
    return REGISTRY[name](quick=quick)
