"""Scenario/sweep command line:

    PYTHONPATH=src python -m repro.api.cli list
    PYTHONPATH=src python -m repro.api.cli describe fig2_ota_sc
    PYTHONPATH=src python -m repro.api.cli run sweep_smoke [--out DIR]
    PYTHONPATH=src python -m repro.api.cli run sweep_smoke --jobs 2
    PYTHONPATH=src python -m repro.api.cli run my_sweep.json --full

``run``/``describe`` accept a registered name (``list`` shows them) or a
path to a JSON spec file (a ``ScenarioSpec`` dict, or a ``SweepSpec``
dict with ``base``/``axes``). ``run --expect-cached`` exits non-zero if
any cell actually computed — the CI guard that a re-run of a finished
sweep is a cache no-op.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from . import scenarios
from .execute import default_out_dir, execute
from .plan import plan
from .spec import spec_from_dict


def _load_spec(ref: str, *, quick: bool):
    if ref.endswith(".json") or "/" in ref:
        path = Path(ref)
        if not path.exists():
            raise SystemExit(f"spec file not found: {ref}")
        return spec_from_dict(json.loads(path.read_text()))
    try:
        return scenarios.get(ref, quick=quick)
    except KeyError:
        print(f"unknown scenario/sweep {ref!r}; registered:",
              file=sys.stderr)
        for name in scenarios.names():
            print(f"  {name}", file=sys.stderr)
        raise SystemExit(2)


def _cmd_list(_args) -> int:
    print("registered scenarios/sweeps (run/describe by name):")
    for name in scenarios.names():
        doc = (scenarios.REGISTRY[name].__doc__ or "").strip()
        first = doc.splitlines()[0] if doc else ""
        print(f"  {name:18s} {first}")
    return 0


def _cmd_describe(args) -> int:
    spec = _load_spec(args.spec, quick=not args.full)
    print(plan(spec).describe())
    return 0


def _cmd_run(args) -> int:
    spec = _load_spec(args.spec, quick=not args.full)
    pl = plan(spec)
    out_dir = Path(args.out) if args.out else default_out_dir(pl.name)
    rs = execute(pl, out_dir=out_dir, force=args.force, jobs=args.jobs,
                 cell_timeout_s=args.cell_timeout, retries=args.retries,
                 progress=lambda msg: print(msg, flush=True))
    computed = sum(c.status == "computed" for c in rs.cells)
    cached = sum(c.status == "cached" for c in rs.cells)
    timeout = sum(c.status == "timeout" for c in rs.cells)
    extra = f", {timeout} timed out" if timeout else ""
    print(f"{rs.name}: {computed} computed, {cached} cached{extra} "
          f"-> {out_dir}")
    if args.expect_cached and computed:
        print(f"FAIL: --expect-cached but {computed} cell(s) recomputed "
              "(cache key drift?)", file=sys.stderr)
        return 1
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.api.cli",
                                 description=__doc__)
    sub = ap.add_subparsers(dest="cmd", required=True)

    sub.add_parser("list", help="registered scenario/sweep names")

    p = sub.add_parser("describe",
                       help="print a spec's plan (cells, design groups)")
    p.add_argument("spec", help="registered name or JSON spec path")
    p.add_argument("--full", action="store_true",
                   help="paper-scale variant of a registered spec")

    p = sub.add_parser("run", help="execute a scenario/sweep")
    p.add_argument("spec", help="registered name or JSON spec path")
    p.add_argument("--out", default=None, help="ResultSet directory "
                   "(default experiments/results/scenarios/<name>)")
    p.add_argument("--full", action="store_true",
                   help="paper-scale variant of a registered spec")
    p.add_argument("--force", action="store_true",
                   help="recompute cached cells")
    p.add_argument("--jobs", type=int, default=1, metavar="K",
                   help="run non-cached cells on a K-worker process pool "
                        "(same manifest and resume semantics as serial)")
    p.add_argument("--cell-timeout", type=float, default=None, metavar="S",
                   help="per-cell compute timeout on the worker pool; "
                        'exhausted cells finalize as status="timeout" '
                        "(parallel runs only)")
    p.add_argument("--retries", type=int, default=2, metavar="N",
                   help="extra attempts a timed-out or worker-crashed "
                        "cell gets before finalizing (default 2)")
    p.add_argument("--expect-cached", action="store_true",
                   help="exit 1 if any cell was (re)computed")

    args = ap.parse_args(argv)
    return {"list": _cmd_list, "describe": _cmd_describe,
            "run": _cmd_run}[args.cmd](args)


if __name__ == "__main__":
    sys.exit(main())
