"""Result schema, strict JSON serialization, and the ``ResultSet`` artifact.

One schema for every artifact the repo writes: the per-figure payloads
under ``experiments/results/*.json``, the benchmark records, and the
scenario-sweep ``ResultSet`` directories produced by ``repro.api.execute``.
Every payload is stamped with ``schema_version`` (``result_payload``) and
serialized through a *strict* encoder: numpy scalars/arrays are converted
explicitly, anything else unknown raises instead of being silently coerced
(the legacy ``json.dumps(..., default=float)`` used to turn stray objects
into nonsense floats — e.g. ``np.bool_`` into ``1.0``).

A ``ResultSet`` is the versioned on-disk artifact of one executed sweep:

    <dir>/manifest.json          sweep spec + hash, git rev, schema
                                 version, per-cell status/timings
    <dir>/cells/<hash>.json      one payload per scenario cell, keyed by
                                 the cell's content hash (the cache key)
"""
from __future__ import annotations

import dataclasses
import json
import os
import subprocess
from pathlib import Path
from typing import Iterator, Optional

import numpy as np

#: Bumped whenever the result payload layout changes; cached scenario
#: cells from older schema versions are recomputed, not reused.
#: v3: RunSpec gained ``rng`` (replay|fast execution mode) — spec dicts,
#: and therefore every content hash, changed layout.
#: v4: RunSpec gained ``payload_dtype`` (f32|bf16 uplink payloads) — spec
#: dicts, and therefore every content hash, changed layout again.
#: v5: ScenarioSpec gained ``fault`` (``core.faults.FaultSpec`` — wireless
#: fault injection + graceful-degradation policy), adding a top-level
#: "fault" block to every spec dict.
#: v6: RunSpec gained ``clients_per_round`` + ``participation``
#: (partial-participation client sampling, ``core.participation``).
#: v7: RunSpec gained ``mode`` ("sync"|"async") and ScenarioSpec gained
#: ``async_`` (``core.async_fl.AsyncSpec`` — buffered-asynchronous
#: aggregation with staleness priced as structured bias).
SCHEMA_VERSION = 7

_REPO_ROOT = Path(__file__).resolve().parents[3]
DEFAULT_RESULTS_ROOT = Path(os.environ.get(
    "REPRO_RESULTS_DIR", _REPO_ROOT / "experiments" / "results"))


# ------------------------------------------------------- strict encoding

def json_default(obj):
    """Explicit JSON fallback: numpy scalars/arrays only, else TypeError.

    Shared by ``benchmarks.common.save_result`` and the ``ResultSet``
    writer. Raising on unknown types is the point — the old
    ``default=float`` coerced anything float()-accepts (``np.bool_``,
    0-d arrays, stray objects with ``__float__``) without complaint.
    """
    if isinstance(obj, np.integer):
        return int(obj)
    if isinstance(obj, np.floating):
        return float(obj)
    if isinstance(obj, np.bool_):
        return bool(obj)
    if isinstance(obj, np.ndarray):
        return obj.tolist()
    raise TypeError(
        f"result payloads must be JSON-native (+ numpy scalars/arrays); "
        f"got {type(obj).__name__!r} — convert it explicitly")


def dump_json(payload: dict, *, indent: int = 1) -> str:
    return json.dumps(payload, indent=indent, default=json_default)


def result_payload(kind: str, **fields) -> dict:
    """Assemble a schema-stamped result payload (the one payload helper)."""
    return {"schema_version": SCHEMA_VERSION, "kind": kind, **fields}


def log_record(log, **extra) -> dict:
    """One ``TrainLog`` as a JSON record (mean/std over MC trials).

    The single source of the per-scheme log schema — the figure pipelines'
    former per-module ``log_to_dict`` copies all route here. ``extra``
    merges additional fields (tuned eta, scheme key, timings).
    """
    d = {
        "scheme": log.scheme,
        "rounds": np.asarray(log.rounds).tolist(),
        "wall_time_s": np.asarray(log.wall_time_s).tolist(),
        "loss_mean": log.global_loss.mean(0).tolist(),
        "loss_std": log.global_loss.std(0).tolist(),
        "acc_mean": log.accuracy.mean(0).tolist(),
        "acc_std": log.accuracy.std(0).tolist(),
    }
    if log.opt_error is not None:
        d["opt_err_mean"] = log.opt_error.mean(0).tolist()
    d.update(extra)
    return d


def git_rev() -> str:
    """Current git revision for result provenance ("unknown" outside git)."""
    try:
        return subprocess.run(
            ["git", "rev-parse", "HEAD"], cwd=_REPO_ROOT, timeout=10,
            capture_output=True, text=True, check=True).stdout.strip()
    except Exception:
        return "unknown"


# ------------------------------------------------------------- ResultSet

@dataclasses.dataclass
class CellResult:
    """One scenario cell of an executed sweep."""

    index: int
    cell_hash: str
    overrides: dict               # sweep-axis values applied to the base
    status: str                   # "computed" | "cached" | "timeout"
    path: Optional[Path]          # cell payload file (None if unsaved)
    payload: dict

    @property
    def logs(self) -> list[dict]:
        return self.payload.get("logs", [])

    def log(self, scheme_key: str) -> dict:
        for rec in self.logs:
            if rec.get("scheme_key") == scheme_key or \
                    rec.get("scheme") == scheme_key:
                return rec
        raise KeyError(f"scheme {scheme_key!r} not in cell {self.index}")


@dataclasses.dataclass
class ResultSet:
    """Versioned artifact of one executed scenario/sweep."""

    manifest: dict
    cells: list[CellResult]
    directory: Optional[Path] = None

    @property
    def name(self) -> str:
        return self.manifest["name"]

    @property
    def all_cached(self) -> bool:
        return all(c.status == "cached" for c in self.cells)

    def __iter__(self) -> Iterator[CellResult]:
        return iter(self.cells)

    def __len__(self) -> int:
        return len(self.cells)

    def cell(self, index: int) -> CellResult:
        return self.cells[index]

    def save(self, directory: Path) -> Path:
        """Write manifest + per-cell payloads (content-hash filenames).

        Cells already on disk at their target path — cache hits, and
        computed cells the executor persisted incrementally — are not
        re-serialized. Cells without a payload (``status="timeout"``)
        are recorded in the manifest but get no payload file.
        """
        directory = Path(directory)
        (directory / "cells").mkdir(parents=True, exist_ok=True)
        for c in self.cells:
            if not c.payload:
                c.path = None
                continue
            path = directory / "cells" / f"{c.cell_hash}.json"
            if c.path != path or not path.exists():
                path.write_text(dump_json(c.payload))
            c.path = path
        (directory / "manifest.json").write_text(dump_json(self.manifest))
        self.directory = directory
        return directory

    @classmethod
    def load(cls, directory: Path) -> "ResultSet":
        directory = Path(directory)
        manifest = json.loads((directory / "manifest.json").read_text())
        cells = []
        for entry in manifest["cells"]:
            path = directory / "cells" / f"{entry['cell_hash']}.json"
            # timeout cells have no payload file; keep the manifest row
            has_payload = path.exists()
            cells.append(CellResult(
                index=entry["index"], cell_hash=entry["cell_hash"],
                overrides=entry.get("overrides", {}),
                status=entry.get("status", "cached"),
                path=path if has_payload else None,
                payload=json.loads(path.read_text()) if has_payload else {}))
        return cls(manifest=manifest, cells=cells, directory=directory)
