"""Sweep executor: grouped batched design solves, cached cell runs.

``execute()`` turns a plan into a versioned ``ResultSet``:

1. **Cache check** — each cell's content hash (spec + schema version) is
   looked up under ``<out_dir>/cells/<hash>.json``; hits short-circuit the
   whole cell (no design solve, no simulation).
2. **Grouped design** — the remaining cells' design problems solve as ONE
   ``design_ota_batch``/``design_digital_batch`` call per plan group
   (family x device count), hitting the vmapped ``core.sca_jax`` solvers
   the way they were built to be used. Non-batched solver policies
   ("sca"/"scipy"/"direct") fall back to per-point oracle calls.
3. **Simulation** — every scheme runs through the tuned-MC protocol with
   ``FLTrainer.run(backend=...)`` ("auto" = the vmap/scan JAX engine for
   all ported schemes).
4. **Artifact** — per-cell payloads + a manifest (sweep spec + hash, git
   rev, per-cell status/timings) land under ``out_dir``; re-running a
   half-finished sweep recomputes only the missing cells.

``execute(..., jobs=K)`` runs independent cells on a supervised pool of
``K`` persistent spawn workers: the main process still does the cache
check and the grouped batched design solves (walking ``Plan.schedule()``
so every group lands before its dependents), then ships each cell to a
worker as pure data — the scenario dict, the solved design parameters
("design pack") and the memoized kappa estimates — because live contexts
hold jitted closures and don't pickle. Workers write ``cells/<hash>.json``
the moment a cell finishes and errors are collected (not fail-fast), so a
crashed or cancelled parallel sweep resumes exactly like a serial one;
the manifest is byte-identical to serial execution (modulo wall-clock
timings).

The supervisor also hardens the pool against wireless-lab realities:
a worker that dies mid-cell (OOM kill, segfault) gets its cell requeued
on a fresh worker with exponential backoff (``retries`` extra attempts);
a cell still running ``cell_timeout_s`` seconds after its worker
*started* it (spawn + JAX import time excluded) has the worker
terminated and, once retries are exhausted, surfaces as
``status="timeout"`` with an empty payload instead of hanging the sweep.
Deterministic Python exceptions are never retried.
"""
from __future__ import annotations

import dataclasses
import json
import logging
import os
import signal
import time
import traceback
from pathlib import Path
from typing import Callable, Optional

from ..core import digital_design, ota_design
from . import materialize as mat
from . import schemes
from .plan import Cell, Plan, plan as make_plan
from .results import (DEFAULT_RESULTS_ROOT, SCHEMA_VERSION, CellResult,
                      ResultSet, dump_json, git_rev, log_record,
                      result_payload)
from .spec import ScenarioSpec


logger = logging.getLogger(__name__)


def default_out_dir(name: str) -> Path:
    return DEFAULT_RESULTS_ROOT / "scenarios" / name


def _load_cached(path: Path) -> Optional[dict]:
    if not path.exists():
        return None
    try:
        payload = json.loads(path.read_text())
    except json.JSONDecodeError:
        # corrupt cache cell (truncated write, disk hiccup): quarantine it
        # under <name>.json.bad so the evidence survives, and recompute
        bad = path.with_name(path.name + ".bad")
        try:
            path.replace(bad)
        except OSError:
            return None
        logger.warning("quarantined corrupt result cell %s -> %s; "
                       "the cell will be recomputed", path, bad.name)
        return None
    except OSError:
        return None
    if payload.get("schema_version") != SCHEMA_VERSION:
        return None
    return payload


def _solve_group(group, contexts) -> None:
    """One design group: a single batched jit call (or per-point oracle)."""
    members = [contexts[i] for i in group.cell_indices]
    specs = [ctx.design_spec(group.family) for ctx in members]
    if group.family == "ota":
        batch, sca, direct = (ota_design.design_ota_batch,
                              ota_design.design_ota_sca,
                              ota_design.design_ota_direct)
    else:
        batch, sca, direct = (digital_design.design_digital_batch,
                              digital_design.design_digital_sca,
                              digital_design.design_digital_direct)
    if group.batched:
        params, objs = batch(specs)
        solved = list(zip(params, objs))
    elif group.solver in ("sca", "scipy"):
        solved = []
        for s in specs:
            p, res = sca(s, n_iters=8)
            solved.append((p, res.objective))
    elif group.solver == "direct":
        solved = [direct(s) for s in specs]
    else:
        raise ValueError(f"unknown design solver {group.solver!r}")
    for ctx, (p, obj) in zip(members, solved):
        ctx.set_design(group.family, "designed", p, obj)
        if group.solver == "direct":
            # the designed variant IS the direct solve; don't solve twice
            ctx.set_design(group.family, "direct", p, obj)
    if group.solver != "direct":
        for idx in group.needs_direct:
            ctx = contexts[idx]
            p, obj = direct(ctx.design_spec(group.family))
            ctx.set_design(group.family, "direct", p, obj)


def _run_cell(cell, ctx) -> dict:
    """All schemes of one cell through the tuned Monte-Carlo protocol."""
    scenario = ctx.scenario
    t0 = time.perf_counter()
    logs = []
    for key in schemes.expand_schemes(scenario.schemes):
        t1 = time.perf_counter()
        agg = schemes.build_scheme(key, ctx)
        log, best_eta = mat.run_cell_scheme(ctx, agg)
        logs.append(log_record(log, scheme_key=key, eta=best_eta,
                               elapsed_s=time.perf_counter() - t1))
    design = {}
    if ctx.ota_objective is not None:
        design["ota"] = {"objective": ctx.ota_objective,
                         "solver": scenario.design.solver}
        if ctx.ota_objective_direct is not None:
            design["ota"]["objective_direct"] = ctx.ota_objective_direct
    if ctx.dig_objective is not None:
        design["digital"] = {"objective": ctx.dig_objective,
                             "solver": scenario.design.solver}
        if ctx.dig_objective_direct is not None:
            design["digital"]["objective_direct"] = ctx.dig_objective_direct
    return result_payload(
        "scenario_cell", name=scenario.name, cell_hash=cell.cell_hash,
        overrides=cell.overrides, scenario=scenario.to_dict(),
        n_devices=scenario.n_devices, eta_max=ctx.eta_max, kappa=ctx.kappa,
        omega_var=ctx.weights.omega_var, omega_bias=ctx.weights.omega_bias,
        design=design, logs=logs, elapsed_s=time.perf_counter() - t0)


def _design_pack(ctx) -> tuple:
    """A cell's solved design parameters as picklable pure data.

    Parameter dataclasses hold only numpy arrays/scalars, so they cross
    the spawn boundary; workers replay the pack with ``set_design`` and
    never touch a design solver.
    """
    pack = []
    for prefix, family in (("ota", "ota"), ("dig", "digital")):
        for variant, suffix in (("designed", ""), ("direct", "_direct")):
            params = getattr(ctx, f"{prefix}_params{suffix}")
            if params is not None:
                pack.append((family, variant, params,
                             getattr(ctx, f"{prefix}_objective{suffix}")))
    return tuple(pack)


#: process-global memo so one worker builds each dataset/task/deployment
#: once across all the cells it is handed
_WORKER_MEMO = None


def _chaos_hook(cell_hash: str) -> None:
    """Test-only fault injection for the supervisor (env-gated, inert
    otherwise; spawn workers inherit the parent environment).

    ``REPRO_CHAOS_KILL_DIR=<dir>`` — SIGKILL exactly one worker, once per
    directory (atomic ``O_CREAT|O_EXCL`` marker), simulating an OOM kill.
    ``REPRO_CHAOS_HANG_HASH=<prefix>`` — cells whose hash matches the
    prefix hang, exercising the per-cell timeout path.
    """
    kill_dir = os.environ.get("REPRO_CHAOS_KILL_DIR")
    if kill_dir:
        try:
            fd = os.open(os.path.join(kill_dir, "killed"),
                         os.O_CREAT | os.O_EXCL | os.O_WRONLY)
        except FileExistsError:
            pass
        else:
            os.close(fd)
            os.kill(os.getpid(), signal.SIGKILL)
    hang = os.environ.get("REPRO_CHAOS_HANG_HASH")
    if hang and cell_hash.startswith(hang):
        time.sleep(3600)


def _worker_run_cell(job):
    """Pool worker: re-materialize one cell from pure data and run it."""
    (scenario_dict, index, overrides, cell_hash, design_pack, memo_seed,
     cells_dir) = job
    _chaos_hook(cell_hash)
    global _WORKER_MEMO
    if _WORKER_MEMO is None:
        _WORKER_MEMO = mat.new_memo()
    # seed the sweep-level kappa estimates so workers never re-run the
    # w*-GD estimation the main process (or a sibling) already did
    _WORKER_MEMO._store.update(memo_seed)
    scenario = ScenarioSpec.from_dict(scenario_dict)
    ctx = mat.materialize(scenario, _WORKER_MEMO)
    for family, variant, params, objective in design_pack:
        ctx.set_design(family, variant, params, objective)
    cell = Cell(index=index, overrides=overrides, scenario=scenario,
                cell_hash=cell_hash)
    payload = _run_cell(cell, ctx)
    if cells_dir is not None:
        d = Path(cells_dir)
        d.mkdir(parents=True, exist_ok=True)
        (d / f"{cell_hash}.json").write_text(dump_json(payload))
    return index, payload


def _pool_worker(wid: int, jobq, resq) -> None:
    """Persistent parallel-sweep worker: drain jobs until the sentinel.

    Announces ``("start", wid, index)`` *before* running a cell so the
    supervisor's per-cell timeout clock starts at actual work start —
    process spawn and the first JAX import are never billed to a cell.
    """
    while True:
        job = jobq.get()
        if job is None:
            return
        index = job[1]
        resq.put(("start", wid, index))
        try:
            _, payload = _worker_run_cell(job)
        except BaseException:              # noqa: BLE001 — shipped to parent
            resq.put(("error", wid, index, traceback.format_exc()))
        else:
            resq.put(("ok", wid, index, payload))


def _run_parallel(pl: Plan, todo, contexts, memo, cells_dir: Path,
                  save: bool, jobs: int, say, results,
                  cell_timeout_s: Optional[float] = None,
                  retries: int = 2) -> None:
    """Dispatch non-cached cells to supervised persistent spawn workers,
    designs solved inline in the main process.

    Spawn (not fork): the parent has long since initialized JAX, and
    forking a process with a live XLA runtime is undefined behavior.

    Degradation ladder per cell (supervisor loop):

    * worker raises a Python exception — deterministic, never retried;
      collected (not fail-fast) and re-raised after the sweep drains, so
      completed cells persist their ``cells/<hash>.json`` and a re-run
      resumes from them;
    * worker process dies mid-cell — the cell is requeued on a fresh
      worker with exponential backoff (0.25 * 2^attempt s), up to
      ``retries`` extra attempts; exhausted crashes raise;
    * cell exceeds ``cell_timeout_s`` (measured from the worker's
      "start" message) — the worker is terminated and the cell retried
      the same way; exhausted timeouts finalize as ``status="timeout"``
      with an empty payload instead of raising (the sweep's other cells
      stay usable).

    A late result that arrives after its cell was requeued is accepted
    if the cell is not yet finalized and ignored as a duplicate if it is.
    """
    import multiprocessing as mp
    import queue as queue_mod

    todo_idx = {c.index for c in todo}
    memo_seed = {k: v for k, v in memo._store.items()
                 if isinstance(k, tuple) and k and k[0] == "kappa"}
    cell_by_index = {c.index: c for c in todo}

    # walk the dependency-ordered schedule: every design group solves (one
    # batched jit) before its first dependent cell's job is enqueued
    queue_jobs = []
    for kind, item in pl.schedule():
        if kind == "design":
            live = [i for i in item.cell_indices if i in todo_idx]
            if not live:
                continue
            say(f"design {item.family} (N={item.n_devices}): "
                f"{len(live)} point(s), "
                + ("one batched jit" if item.batched else item.solver))
            _solve_group(_filtered(item, live), contexts)
        elif item.index in todo_idx:
            cell = item
            job = (cell.scenario.to_dict(), cell.index, cell.overrides,
                   cell.cell_hash, _design_pack(contexts[cell.index]),
                   memo_seed, str(cells_dir) if save else None)
            say(f"cell {cell.index} [{cell.cell_hash}] -> worker "
                f"({len(schemes.expand_schemes(cell.scenario.schemes))} "
                "schemes)")
            queue_jobs.append(job)

    total = len(queue_jobs)
    ctx_mp = mp.get_context("spawn")
    resq = ctx_mp.Queue()
    n_workers = min(jobs, total)

    def _spawn_worker(wid):
        jobq = ctx_mp.Queue()
        proc = ctx_mp.Process(target=_pool_worker, args=(wid, jobq, resq),
                              daemon=True)
        proc.start()
        return {"proc": proc, "jobq": jobq, "index": None, "job": None,
                "started": None}

    ready = list(queue_jobs)       # FIFO of jobs awaiting a worker
    delayed = []                   # [(not_before, job)] backoff requeues
    attempts = {job[1]: 0 for job in queue_jobs}
    finalized: set[int] = set()
    errors = []
    workers = {wid: _spawn_worker(wid) for wid in range(n_workers)}
    next_wid = n_workers

    def _finish_ok(index, payload):
        cell = cell_by_index[index]
        results[index] = CellResult(
            index=index, cell_hash=cell.cell_hash,
            overrides=cell.overrides, status="computed",
            path=cells_dir / f"{cell.cell_hash}.json" if save else None,
            payload=payload)
        finalized.add(index)
        say(f"cell {cell.index} [{cell.cell_hash}] done")

    try:
        while len(finalized) < total:
            now = time.monotonic()
            ready.extend(j for t, j in delayed if t <= now)
            delayed = [(t, j) for t, j in delayed if t > now]

            # hand ready jobs to idle live workers (skip jobs finalized by
            # a late result that landed while they waited in the queue)
            for w in workers.values():
                while ready and ready[0][1] in finalized:
                    ready.pop(0)
                if not ready:
                    break
                if w["index"] is None and w["proc"].is_alive():
                    job = ready.pop(0)
                    w["index"], w["job"], w["started"] = job[1], job, None
                    w["jobq"].put(job)

            try:
                msg = resq.get(timeout=0.1)
            except queue_mod.Empty:
                msg = None
            if msg is not None:
                tag, wid, index = msg[0], msg[1], msg[2]
                w = workers.get(wid)
                if tag == "start":
                    if w is not None and w["index"] == index:
                        w["started"] = time.monotonic()
                else:
                    if index not in finalized:
                        if tag == "ok":
                            _finish_ok(index, msg[3])
                        else:   # deterministic Python error: never retried
                            errors.append((cell_by_index[index], msg[3]))
                            finalized.add(index)
                    if w is not None and w["index"] == index:
                        w["index"] = w["job"] = w["started"] = None
                continue        # drain results before liveness checks

            # liveness + per-cell deadline sweep
            now = time.monotonic()
            for wid in list(workers):
                w = workers[wid]
                alive = w["proc"].is_alive()
                timed_out = (alive and cell_timeout_s is not None
                             and w["started"] is not None
                             and now - w["started"] > cell_timeout_s)
                if alive and not timed_out:
                    continue
                index, job = w["index"], w["job"]
                if timed_out:
                    w["proc"].kill()
                w["proc"].join(timeout=5)
                del workers[wid]
                if index is not None and index not in finalized:
                    cell = cell_by_index[index]
                    attempts[index] += 1
                    why = ("timed out" if timed_out
                           else "lost its worker")
                    if attempts[index] > retries:
                        if timed_out:
                            say(f"cell {cell.index} [{cell.cell_hash}] "
                                f"{why}; retries exhausted -> "
                                'status="timeout"')
                            results[index] = CellResult(
                                index=index, cell_hash=cell.cell_hash,
                                overrides=cell.overrides, status="timeout",
                                path=None, payload={})
                            finalized.add(index)
                        else:
                            errors.append((
                                cell,
                                f"cell {why} {attempts[index]} time(s) "
                                "with no result"))
                            finalized.add(index)
                    else:
                        backoff = 0.25 * 2.0 ** (attempts[index] - 1)
                        say(f"cell {cell.index} [{cell.cell_hash}] {why}; "
                            f"retry {attempts[index]}/{retries} in "
                            f"{backoff:.2f}s")
                        delayed.append((now + backoff, job))
                if len(finalized) < total and len(workers) < n_workers:
                    workers[next_wid] = _spawn_worker(next_wid)
                    next_wid += 1
    finally:
        for w in workers.values():
            if w["proc"].is_alive():
                w["jobq"].put(None)
        for w in workers.values():
            w["proc"].join(timeout=5)
            if w["proc"].is_alive():
                w["proc"].kill()
                w["proc"].join(timeout=5)

    if errors:
        cell, detail = errors[0]
        raise RuntimeError(
            f"{len(errors)} of {total} sweep cell(s) failed in "
            f"workers (first: cell {cell.index} [{cell.cell_hash}]); "
            "completed cells are cached — re-run to resume"
        ) from RuntimeError(str(detail))


def execute(spec_or_plan, *, out_dir: Optional[Path] = None,
            force: bool = False, save: bool = True, jobs: int = 1,
            cell_timeout_s: Optional[float] = None, retries: int = 2,
            progress: Optional[Callable[[str], None]] = None) -> ResultSet:
    """Execute a scenario/sweep/plan into a ``ResultSet``.

    ``force=True`` ignores (and overwrites) cached cells; ``save=False``
    keeps the result in memory only (used by tests); ``jobs=K`` (K > 1)
    runs non-cached cells on a supervised K-worker process pool — same
    manifest, same per-cell artifacts, same resume semantics as serial.
    ``cell_timeout_s`` bounds one cell's compute time on the pool (the
    clock starts when a worker picks the cell up; exhausted cells finalize
    as ``status="timeout"``); ``retries`` is the number of *extra*
    attempts a timed-out or worker-crashed cell gets before finalizing.
    Both apply to the parallel path only — serial execution runs in-process
    and cannot be preempted.
    """
    say = progress if progress is not None else (lambda msg: None)
    pl = (spec_or_plan if isinstance(spec_or_plan, Plan)
          else make_plan(spec_or_plan))
    if jobs < 1:
        raise ValueError(f"jobs must be >= 1, got {jobs}")
    if retries < 0:
        raise ValueError(f"retries must be >= 0, got {retries}")
    if cell_timeout_s is not None and cell_timeout_s <= 0:
        raise ValueError(
            f"cell_timeout_s must be positive, got {cell_timeout_s}")
    out_dir = Path(out_dir) if out_dir is not None else \
        default_out_dir(pl.name)
    cells_dir = out_dir / "cells"
    t0 = time.perf_counter()

    results: dict[int, CellResult] = {}
    todo = []
    for cell in pl.cells:
        cached = None if force else _load_cached(
            cells_dir / f"{cell.cell_hash}.json")
        if cached is not None:
            say(f"cell {cell.index} [{cell.cell_hash}] cached")
            results[cell.index] = CellResult(
                index=cell.index, cell_hash=cell.cell_hash,
                overrides=cell.overrides, status="cached",
                path=cells_dir / f"{cell.cell_hash}.json", payload=cached)
        else:
            todo.append(cell)

    # materialize every non-cached cell (memoized across the sweep), then
    # walk the dependency-ordered schedule: each design group's grid
    # solves in one batched call right before its first dependent cell
    memo = mat.new_memo()
    contexts = {c.index: mat.materialize(c.scenario, memo) for c in todo}
    todo_idx = set(contexts)
    if jobs > 1 and todo:
        _run_parallel(pl, todo, contexts, memo, cells_dir, save, jobs,
                      say, results, cell_timeout_s=cell_timeout_s,
                      retries=retries)
    else:
        for kind, item in pl.schedule():
            if kind == "design":
                live = [i for i in item.cell_indices if i in todo_idx]
                if not live:
                    continue
                say(f"design {item.family} (N={item.n_devices}): "
                    f"{len(live)} point(s), "
                    + ("one batched jit" if item.batched else item.solver))
                _solve_group(_filtered(item, live), contexts)
                continue
            cell = item
            if cell.index not in todo_idx:
                continue
            say(f"cell {cell.index} [{cell.cell_hash}] running "
                f"{len(schemes.expand_schemes(cell.scenario.schemes))} "
                "schemes")
            payload = _run_cell(cell, contexts[cell.index])
            path = None
            if save:
                # persist each cell the moment it completes so an
                # interrupted sweep resumes from the finished cells, not
                # from scratch
                path = cells_dir / f"{cell.cell_hash}.json"
                cells_dir.mkdir(parents=True, exist_ok=True)
                path.write_text(dump_json(payload))
            results[cell.index] = CellResult(
                index=cell.index, cell_hash=cell.cell_hash,
                overrides=cell.overrides, status="computed",
                path=path, payload=payload)

    ordered = [results[c.index] for c in pl.cells]
    manifest = result_payload(
        "result_set", name=pl.name, spec=pl.sweep.to_dict(),
        sweep_hash=pl.sweep.spec_hash(), git_rev=git_rev(),
        n_cells=len(ordered),
        axes={p: list(v) for p, v in pl.sweep.axes},
        cells=[{"index": c.index, "cell_hash": c.cell_hash,
                "overrides": c.overrides, "status": c.status,
                "elapsed_s": c.payload.get("elapsed_s")}
               for c in ordered],
        elapsed_s=time.perf_counter() - t0)
    rs = ResultSet(manifest=manifest, cells=ordered)
    if save:
        rs.save(out_dir)
        say(f"manifest -> {out_dir / 'manifest.json'}")
    return rs


def _filtered(group, live):
    """A design group restricted to its non-cached member cells."""
    return dataclasses.replace(
        group, cell_indices=tuple(live),
        needs_direct=tuple(i for i in group.needs_direct if i in live))
