"""Sweep executor: grouped batched design solves, cached cell runs.

``execute()`` turns a plan into a versioned ``ResultSet``:

1. **Cache check** — each cell's content hash (spec + schema version) is
   looked up under ``<out_dir>/cells/<hash>.json``; hits short-circuit the
   whole cell (no design solve, no simulation).
2. **Grouped design** — the remaining cells' design problems solve as ONE
   ``design_ota_batch``/``design_digital_batch`` call per plan group
   (family x device count), hitting the vmapped ``core.sca_jax`` solvers
   the way they were built to be used. Non-batched solver policies
   ("sca"/"scipy"/"direct") fall back to per-point oracle calls.
3. **Simulation** — every scheme runs through the tuned-MC protocol with
   ``FLTrainer.run(backend=...)`` ("auto" = the vmap/scan JAX engine for
   all ported schemes).
4. **Artifact** — per-cell payloads + a manifest (sweep spec + hash, git
   rev, per-cell status/timings) land under ``out_dir``; re-running a
   half-finished sweep recomputes only the missing cells.
"""
from __future__ import annotations

import dataclasses
import json
import time
from pathlib import Path
from typing import Callable, Optional

from ..core import digital_design, ota_design
from . import materialize as mat
from . import schemes
from .plan import Plan, plan as make_plan
from .results import (DEFAULT_RESULTS_ROOT, SCHEMA_VERSION, CellResult,
                      ResultSet, dump_json, git_rev, log_record,
                      result_payload)


def default_out_dir(name: str) -> Path:
    return DEFAULT_RESULTS_ROOT / "scenarios" / name


def _load_cached(path: Path) -> Optional[dict]:
    if not path.exists():
        return None
    try:
        payload = json.loads(path.read_text())
    except (OSError, json.JSONDecodeError):
        return None
    if payload.get("schema_version") != SCHEMA_VERSION:
        return None
    return payload


def _solve_group(group, contexts) -> None:
    """One design group: a single batched jit call (or per-point oracle)."""
    members = [contexts[i] for i in group.cell_indices]
    specs = [ctx.design_spec(group.family) for ctx in members]
    if group.family == "ota":
        batch, sca, direct = (ota_design.design_ota_batch,
                              ota_design.design_ota_sca,
                              ota_design.design_ota_direct)
    else:
        batch, sca, direct = (digital_design.design_digital_batch,
                              digital_design.design_digital_sca,
                              digital_design.design_digital_direct)
    if group.batched:
        params, objs = batch(specs)
        solved = list(zip(params, objs))
    elif group.solver in ("sca", "scipy"):
        solved = []
        for s in specs:
            p, res = sca(s, n_iters=8)
            solved.append((p, res.objective))
    elif group.solver == "direct":
        solved = [direct(s) for s in specs]
    else:
        raise ValueError(f"unknown design solver {group.solver!r}")
    for ctx, (p, obj) in zip(members, solved):
        ctx.set_design(group.family, "designed", p, obj)
        if group.solver == "direct":
            # the designed variant IS the direct solve; don't solve twice
            ctx.set_design(group.family, "direct", p, obj)
    if group.solver != "direct":
        for idx in group.needs_direct:
            ctx = contexts[idx]
            p, obj = direct(ctx.design_spec(group.family))
            ctx.set_design(group.family, "direct", p, obj)


def _run_cell(cell, ctx) -> dict:
    """All schemes of one cell through the tuned Monte-Carlo protocol."""
    scenario = ctx.scenario
    t0 = time.perf_counter()
    logs = []
    for key in schemes.expand_schemes(scenario.schemes):
        t1 = time.perf_counter()
        agg = schemes.build_scheme(key, ctx)
        log, best_eta = mat.run_cell_scheme(ctx, agg)
        logs.append(log_record(log, scheme_key=key, eta=best_eta,
                               elapsed_s=time.perf_counter() - t1))
    design = {}
    if ctx.ota_objective is not None:
        design["ota"] = {"objective": ctx.ota_objective,
                         "solver": scenario.design.solver}
        if ctx.ota_objective_direct is not None:
            design["ota"]["objective_direct"] = ctx.ota_objective_direct
    if ctx.dig_objective is not None:
        design["digital"] = {"objective": ctx.dig_objective,
                             "solver": scenario.design.solver}
        if ctx.dig_objective_direct is not None:
            design["digital"]["objective_direct"] = ctx.dig_objective_direct
    return result_payload(
        "scenario_cell", name=scenario.name, cell_hash=cell.cell_hash,
        overrides=cell.overrides, scenario=scenario.to_dict(),
        n_devices=scenario.n_devices, eta_max=ctx.eta_max, kappa=ctx.kappa,
        omega_var=ctx.weights.omega_var, omega_bias=ctx.weights.omega_bias,
        design=design, logs=logs, elapsed_s=time.perf_counter() - t0)


def execute(spec_or_plan, *, out_dir: Optional[Path] = None,
            force: bool = False, save: bool = True,
            progress: Optional[Callable[[str], None]] = None) -> ResultSet:
    """Execute a scenario/sweep/plan into a ``ResultSet``.

    ``force=True`` ignores (and overwrites) cached cells; ``save=False``
    keeps the result in memory only (used by tests).
    """
    say = progress if progress is not None else (lambda msg: None)
    pl = (spec_or_plan if isinstance(spec_or_plan, Plan)
          else make_plan(spec_or_plan))
    out_dir = Path(out_dir) if out_dir is not None else \
        default_out_dir(pl.name)
    cells_dir = out_dir / "cells"
    t0 = time.perf_counter()

    results: dict[int, CellResult] = {}
    todo = []
    for cell in pl.cells:
        cached = None if force else _load_cached(
            cells_dir / f"{cell.cell_hash}.json")
        if cached is not None:
            say(f"cell {cell.index} [{cell.cell_hash}] cached")
            results[cell.index] = CellResult(
                index=cell.index, cell_hash=cell.cell_hash,
                overrides=cell.overrides, status="cached",
                path=cells_dir / f"{cell.cell_hash}.json", payload=cached)
        else:
            todo.append(cell)

    # materialize every non-cached cell (memoized across the sweep), then
    # solve each design group's grid in one batched call
    memo = mat.new_memo()
    contexts = {c.index: mat.materialize(c.scenario, memo) for c in todo}
    todo_idx = set(contexts)
    for group in pl.design_groups:
        live = [i for i in group.cell_indices if i in todo_idx]
        if not live:
            continue
        say(f"design {group.family} (N={group.n_devices}): "
            f"{len(live)} point(s), "
            + ("one batched jit" if group.batched else group.solver))
        _solve_group(_filtered(group, live), contexts)

    for cell in todo:
        say(f"cell {cell.index} [{cell.cell_hash}] running "
            f"{len(schemes.expand_schemes(cell.scenario.schemes))} schemes")
        payload = _run_cell(cell, contexts[cell.index])
        path = None
        if save:
            # persist each cell the moment it completes so an interrupted
            # sweep resumes from the finished cells, not from scratch
            path = cells_dir / f"{cell.cell_hash}.json"
            cells_dir.mkdir(parents=True, exist_ok=True)
            path.write_text(dump_json(payload))
        results[cell.index] = CellResult(
            index=cell.index, cell_hash=cell.cell_hash,
            overrides=cell.overrides, status="computed",
            path=path, payload=payload)

    ordered = [results[c.index] for c in pl.cells]
    manifest = result_payload(
        "result_set", name=pl.name, spec=pl.sweep.to_dict(),
        sweep_hash=pl.sweep.spec_hash(), git_rev=git_rev(),
        n_cells=len(ordered),
        axes={p: list(v) for p, v in pl.sweep.axes},
        cells=[{"index": c.index, "cell_hash": c.cell_hash,
                "overrides": c.overrides, "status": c.status,
                "elapsed_s": c.payload.get("elapsed_s")}
               for c in ordered],
        elapsed_s=time.perf_counter() - t0)
    rs = ResultSet(manifest=manifest, cells=ordered)
    if save:
        rs.save(out_dir)
        say(f"manifest -> {out_dir / 'manifest.json'}")
    return rs


def _filtered(group, live):
    """A design group restricted to its non-cached member cells."""
    return dataclasses.replace(
        group, cell_indices=tuple(live),
        needs_direct=tuple(i for i in group.needs_direct if i in live))
