"""Materialize declarative specs into live objects (tasks, data, designs).

The bridge between the pure-data ``ScenarioSpec`` layer and the existing
substrate: builds datasets/partitions, tasks, wireless deployments,
estimates the heterogeneity constants kappa on the actual data, constructs
the Sec.-IV design-problem specs, and runs the per-scheme tuned Monte-Carlo
protocol. This module owns the pipeline logic that used to be copy-pasted
across ``benchmarks/common.py`` and the per-figure scripts (which now
delegate here).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from ..core import async_fl, digital_design, ota_design, sca_jax
from ..core.bounds import ObjectiveWeights
from ..core.channel import Deployment, make_deployment
from ..core.faults import effective_lambdas, survival_prob
from ..data.loader import FLDataset
from ..data.partition import partition_by_class
from ..data.synthetic import SyntheticSpec, make_classification_dataset
from ..fl.tasks import MLPTask, SoftmaxRegressionTask
from ..fl.trainer import FLTrainer
from .spec import ScenarioSpec


# --------------------------------------------------------------- setup

def build_task(spec: ScenarioSpec):
    t = spec.task
    if t.kind == "softmax":
        return SoftmaxRegressionTask(n_features=t.n_features,
                                     n_classes=t.n_classes, mu=t.mu,
                                     g_max=t.g_max)
    if t.kind == "mlp":
        return MLPTask(n_features=t.n_features, hidden=t.hidden,
                       n_classes=t.n_classes, mu_nc=t.mu, g_max=t.g_max)
    raise ValueError(f"unknown task kind {t.kind!r}")


def build_dataset(spec: ScenarioSpec) -> FLDataset:
    d = spec.data
    syn = SyntheticSpec(name=d.name, image_shape=tuple(d.image_shape),
                        n_train_per_class=d.n_train_per_class,
                        n_test_per_class=d.n_test_per_class,
                        noise_sigma=d.noise_sigma, seed=d.dataset_seed)
    x_tr, y_tr, x_te, y_te = make_classification_dataset(syn)
    shards = partition_by_class(x_tr, y_tr, spec.n_devices,
                                d.classes_per_device, d.samples_per_device,
                                seed=d.partition_seed)
    return FLDataset.from_shards(shards, x_te, y_te)


def build_deployment(spec: ScenarioSpec) -> Deployment:
    return make_deployment(spec.wireless)


def resolve_eta_max(spec: ScenarioSpec, task) -> float:
    if spec.run.eta_max is not None:
        return float(spec.run.eta_max)
    if spec.task.kind == "softmax":
        return 2.0 / (task.mu + task.smooth_l)
    raise ValueError("run.eta_max is required for non-softmax tasks "
                     "(no closed-form 2/(mu+L) rule)")


# -------------------------------------------------- kappa estimation

def estimate_kappa_sc(task, ds, iters: int = 1500) -> float:
    """kappa_sc^2 = (1/N) sum ||grad f_m(w*)||^2, with w* from full GD.

    The paper treats kappa as a known constant of the task (Fig. 2 uses 3
    for their MNIST); we estimate it on the synthetic data so the design
    weights (omega_bias) match the actual heterogeneity.
    """
    from ..fl.trainer import solve_w_star
    x_all = np.concatenate([d.x for d in ds.devices])
    y_all = np.concatenate([d.y for d in ds.devices])
    w_star = solve_w_star(task, x_all, y_all, iters=iters)
    xs = np.stack([d.x for d in ds.devices])
    ys = np.stack([d.y for d in ds.devices])
    g = task.device_grads(w_star, xs, ys)
    return float(np.sqrt(np.mean(np.linalg.norm(g, axis=1) ** 2)))


def estimate_kappa_nc(task, ds, n_probes: int = 3) -> float:
    """kappa_nc: gradient dissimilarity max over a few probe points."""
    xs = np.stack([d.x for d in ds.devices])
    ys = np.stack([d.y for d in ds.devices])
    worst = 0.0
    for i in range(n_probes):
        w = task.init_params(seed=100 + i)
        g = task.device_grads(w, xs, ys)
        gbar = g.mean(axis=0, keepdims=True)
        worst = max(worst, float(np.sqrt(
            np.mean(np.sum((g - gbar) ** 2, axis=1)))))
    return worst


def resolve_kappa(spec: ScenarioSpec, task, ds) -> float:
    pol = spec.design
    if pol.kappa is not None:
        return float(pol.kappa)
    if pol.objective == "strongly_convex":
        return estimate_kappa_sc(task, ds, iters=pol.kappa_iters)
    return estimate_kappa_nc(task, ds, n_probes=pol.kappa_probes)


def design_weights(spec: ScenarioSpec, *, eta_max: float,
                   kappa: float, n_devices: int) -> ObjectiveWeights:
    """Footnote-4 weights at the scenario's operating point, omega-scaled."""
    pol = spec.design
    if pol.objective == "strongly_convex":
        w = ObjectiveWeights.strongly_convex(eta=eta_max, mu=spec.task.mu,
                                             kappa_sc=kappa, n=n_devices)
    elif pol.objective == "non_convex":
        w = ObjectiveWeights.non_convex(eta=eta_max, smooth_l=pol.smooth_l,
                                        kappa_nc=kappa, n=n_devices)
    else:
        raise ValueError(f"unknown design objective {pol.objective!r}")
    return ObjectiveWeights(omega_var=w.omega_var * pol.omega_var_scale,
                            omega_bias=w.omega_bias * pol.omega_bias_scale)


# ------------------------------------------------- materialized context

@dataclasses.dataclass
class CellContext:
    """Live objects of one scenario cell, ready to build schemes against.

    Design parameters (``ota_params``/``dig_params`` + direct variants)
    are filled in by the executor after the *grouped* batched solves —
    materialization itself never calls a design solver.
    """

    scenario: ScenarioSpec
    task: object
    ds: FLDataset
    dep: Deployment
    eta_max: float
    kappa: float
    weights: ObjectiveWeights
    ota_params: Optional[object] = None
    ota_objective: Optional[float] = None
    ota_params_direct: Optional[object] = None
    ota_objective_direct: Optional[float] = None
    dig_params: Optional[object] = None
    dig_objective: Optional[float] = None
    dig_params_direct: Optional[object] = None
    dig_objective_direct: Optional[float] = None

    @property
    def top_k(self) -> int:
        return self.scenario.design.top_k

    def design_spec(self, family: str):
        """The Sec.-IV design-problem spec of one family for this cell.

        Under fault injection the solvers see the *outage-adjusted*
        effective channel statistics (``core.faults.effective_lambdas``),
        so the designed bias prices the deep-fade survival regime; with
        faults disabled this is the identity and the spec is unchanged.
        """
        cfg = self.dep.cfg
        lam = effective_lambdas(self.dep.lambdas, self.scenario.fault)
        if family == "ota":
            return ota_design.OTADesignSpec(
                lambdas=lam, dim=self.task.dim,
                g_max=self.task.g_max, e_s=cfg.energy_per_symbol,
                n0=cfg.noise_power, weights=self.weights)
        if family == "digital":
            return digital_design.DigitalDesignSpec(
                lambdas=lam, dim=self.task.dim,
                g_max=self.task.g_max, e_s=cfg.energy_per_symbol,
                n0=cfg.noise_power, bandwidth_hz=cfg.bandwidth_hz,
                t_max_s=self.scenario.design.t_max_s, weights=self.weights)
        raise ValueError(f"unknown design family {family!r}")

    def set_design(self, family: str, variant: str, params, objective):
        prefix = "ota" if family == "ota" else "dig"
        suffix = "_direct" if variant == "direct" else ""
        setattr(self, f"{prefix}_params{suffix}", params)
        setattr(self, f"{prefix}_objective{suffix}", float(objective))

    def participation_probs(self, agg) -> Optional[np.ndarray]:
        """Co-designed sampling probabilities for one scheme, or None.

        Only the ``run.participation == "designed"`` policy solves
        anything: pi comes from the bound-driven capped-simplex solver
        (``core.sca_jax.solve_participation_batch``) at this cell's
        (omega_var, omega_bias) operating point, pricing the scheme's own
        participation levels p (``params.participation_levels``; uniform
        1/N when the scheme carries no wireless design) and the fault
        layer's survival probabilities q — the p*pi*q composition of
        ``bounds.effective_participation``. "uniform" and "channel" are
        resolved inside ``core.participation`` without a solver.
        """
        run = self.scenario.run
        if run.clients_per_round is None or run.participation != "designed":
            return None
        lam = self.dep.lambdas
        n = lam.shape[0]
        params = getattr(agg, "params", None)
        if params is not None and hasattr(params, "participation_levels"):
            p = np.asarray(params.participation_levels(lam), np.float64)
        else:
            p = np.full(n, 1.0 / n)
        q = survival_prob(self.scenario.fault, lam)
        pi, _ = sca_jax.solve_participation_batch(
            p[None], q[None], [run.clients_per_round],
            [self.weights.omega_var], [self.weights.omega_bias])
        return pi[0]

    def async_weights(self, agg) -> Optional[np.ndarray]:
        """Staleness-aware designed aggregation weights v, or None.

        Only ``run.mode == "async"`` with ``async_.weighting ==
        "designed"`` solves anything: v comes from the bound-driven
        capped-simplex solver (``core.sca_jax.solve_async_batch``) at
        this cell's (omega_var, omega_bias) operating point, pricing the
        scheme's own participation levels p (uniform 1/N when the scheme
        carries no wireless design), the stationary delivery weights
        c_m = r_m * E[delta^S | in window] of the arrival model
        (``core.async_fl.delivery_weight``), and the expected staleness
        that inflates each device's variance contribution
        (``core.async_fl.expected_staleness``). "uniform" keeps v = 1
        without a solver (resolved inside ``core.async_fl``).
        """
        run = self.scenario.run
        asp = self.scenario.async_
        if run.mode != "async" or asp.weighting != "designed":
            return None
        lam = self.dep.lambdas
        n = lam.shape[0]
        params = getattr(agg, "params", None)
        if params is not None and hasattr(params, "participation_levels"):
            p = np.asarray(params.participation_levels(lam), np.float64)
        else:
            p = np.full(n, 1.0 / n)
        c = async_fl.delivery_weight(asp, n)
        sbar = async_fl.expected_staleness(asp, n)
        v, _ = sca_jax.solve_async_batch(
            p[None], c[None], sbar[None],
            [self.weights.omega_var], [self.weights.omega_bias])
        return v[0]


class _Memo:
    """Per-execute cache of expensive sub-materializations.

    Sweeps share everything their axes don't touch: the dataset is keyed
    on (data, task-kind-irrelevant) + device count, the deployment on the
    wireless config, kappa on (task, data, estimator knobs). An SNR sweep
    therefore builds the dataset and estimates kappa exactly once.
    """

    def __init__(self):
        self._store: dict = {}

    def get(self, key, build):
        if key not in self._store:
            self._store[key] = build()
        return self._store[key]


def materialize(spec: ScenarioSpec, memo: Optional[_Memo] = None
                ) -> CellContext:
    """Build the live setup of one cell (design params left unsolved)."""
    memo = memo if memo is not None else _Memo()
    task_key = ("task", tuple(sorted(dataclasses.asdict(spec.task).items())))
    task = memo.get(task_key, lambda: build_task(spec))
    data_key = ("data",
                tuple(sorted(dataclasses.asdict(spec.data).items())),
                spec.n_devices)
    ds = memo.get(data_key, lambda: build_dataset(spec))
    dep_key = ("dep", tuple(sorted(dataclasses.asdict(spec.wireless).items())))
    dep = memo.get(dep_key, lambda: build_deployment(spec))
    eta_max = resolve_eta_max(spec, task)
    pol = spec.design
    kappa_key = ("kappa", task_key, data_key, pol.objective, pol.kappa,
                 pol.kappa_iters, pol.kappa_probes)
    kappa = memo.get(kappa_key, lambda: resolve_kappa(spec, task, ds))
    weights = design_weights(spec, eta_max=eta_max, kappa=kappa,
                             n_devices=spec.n_devices)
    return CellContext(scenario=spec, task=task, ds=ds, dep=dep,
                       eta_max=eta_max, kappa=kappa, weights=weights)


new_memo = _Memo


# ------------------------------------------------------------ running

def tune_and_run(task, ds, dep, agg, *, eta_max, rounds, trials, eval_every,
                 seed=5, time_budget_s=None, etas=(1.0, 0.5, 0.25, 0.1),
                 backend="auto", batch_size=None, rng="replay",
                 payload_dtype="f32", fault=None, clients_per_round=None,
                 participation="uniform", participation_probs=None,
                 mode="sync", async_spec=None, async_weights=None):
    """Per-scheme step-size grid search (paper Sec. V: 'step sizes for all
    schemes are tuned via a small grid search'), then the full MC run.

    The probe runs use an independent seed (``seed + 91``) and never feed
    the final run, so a single-point grid skips probing with an identical
    result. ``backend="auto"`` routes every ported scheme through the JAX
    engine.
    """
    if len(etas) == 1:
        best_eta = etas[0] * eta_max
    else:
        best_eta, best_acc = None, -1.0
        for frac in etas:
            tr = FLTrainer(task, ds, dep, eta=frac * eta_max,
                           batch_size=batch_size,
                           payload_dtype=payload_dtype, fault=fault,
                           clients_per_round=clients_per_round,
                           participation=participation,
                           participation_probs=participation_probs,
                           mode=mode, async_spec=async_spec,
                           async_weights=async_weights)
            probe = tr.run(agg, rounds=rounds, trials=1,
                           eval_every=max(rounds // 4, 1), seed=seed + 91,
                           time_budget_s=time_budget_s, backend=backend,
                           rng=rng)
            acc = float(probe.accuracy[:, -2:].mean())   # 2-pt avg vs MC noise
            if acc > best_acc:
                best_acc, best_eta = acc, frac * eta_max
    tr = FLTrainer(task, ds, dep, eta=best_eta, batch_size=batch_size,
                   payload_dtype=payload_dtype, fault=fault,
                   clients_per_round=clients_per_round,
                   participation=participation,
                   participation_probs=participation_probs,
                   mode=mode, async_spec=async_spec,
                   async_weights=async_weights)
    log = tr.run(agg, rounds=rounds, trials=trials, eval_every=eval_every,
                 seed=seed, time_budget_s=time_budget_s, backend=backend,
                 rng=rng)
    return log, best_eta


def run_cell_scheme(ctx: CellContext, agg):
    """One scheme's tuned MC run under the cell's RunSpec."""
    r = ctx.scenario.run
    return tune_and_run(ctx.task, ctx.ds, ctx.dep, agg,
                        eta_max=ctx.eta_max, rounds=r.rounds,
                        trials=r.trials, eval_every=r.eval_every,
                        seed=r.seed, time_budget_s=r.time_budget_s,
                        etas=tuple(r.etas), backend=r.backend,
                        batch_size=r.batch_size, rng=r.rng,
                        payload_dtype=r.payload_dtype,
                        fault=ctx.scenario.fault,
                        clients_per_round=r.clients_per_round,
                        participation=r.participation,
                        participation_probs=ctx.participation_probs(agg),
                        mode=r.mode, async_spec=ctx.scenario.async_,
                        async_weights=ctx.async_weights(agg))
