"""Sweep planner: compile a declarative spec into batched work.

``plan()`` expands a ``ScenarioSpec``/``SweepSpec`` into scenario cells
(content-hashed — the executor's cache key) and groups the Sec.-IV design
work so a whole grid solves in single ``design_ota_batch`` /
``design_digital_batch`` calls: cells needing a designed scheme are
bucketed by (family, device count, solver) — the batched solvers vmap
over grid points but require a shared N (``stack_*_specs``) — giving
exactly one batched solve per scheme family for any fixed-N grid.

Every dotted spec axis sweeps through here generically — including the
``fault.*`` axes (``fault.dropout_prob``, ``fault.deep_fade_thresh``,
...): a fault override lands in the cell's content hash like any other
field, and the cell's design group sees it because
``CellContext.design_spec`` feeds the solvers the outage-adjusted
effective channel statistics (``core.faults.effective_lambdas``).

The plan is pure metadata: nothing is materialized or solved until
``repro.api.execute.execute``.
"""
from __future__ import annotations

import dataclasses

from . import schemes
from .spec import ScenarioSpec, SweepSpec, as_sweep, spec_hash


@dataclasses.dataclass(frozen=True)
class Cell:
    """One grid point: override-applied scenario + its content hash."""

    index: int
    overrides: dict
    scenario: ScenarioSpec
    cell_hash: str


@dataclasses.dataclass(frozen=True)
class DesignGroup:
    """One batched design solve: all member cells in a single jit."""

    family: str                  # "ota" | "digital"
    n_devices: int
    solver: str                  # policy solver of the member cells
    cell_indices: tuple          # cells whose design spec joins this batch
    needs_direct: tuple          # subset also needing the per-point direct solve

    @property
    def batched(self) -> bool:
        """Whether the group compiles to one batched jit call (vs per-point
        SciPy oracle calls for solver="sca"/"scipy"/"direct")."""
        return self.solver in ("auto", "jax")


@dataclasses.dataclass(frozen=True)
class Plan:
    sweep: SweepSpec
    cells: tuple                 # tuple[Cell, ...]
    design_groups: tuple         # tuple[DesignGroup, ...]

    @property
    def name(self) -> str:
        return self.sweep.name

    def describe(self) -> str:
        lines = [f"sweep {self.name!r}: {len(self.cells)} cell(s), "
                 f"hash {self.sweep.spec_hash()}"]
        for path, vals in self.sweep.axes:
            lines.append(f"  axis {path} = {list(vals)}")
        for c in self.cells:
            keys = schemes.expand_schemes(c.scenario.schemes)
            ov = ", ".join(f"{k}={v}" for k, v in c.overrides.items()) or "-"
            lines.append(f"  cell {c.index} [{c.cell_hash}] {ov} "
                         f"({len(keys)} schemes)")
        for g in self.design_groups:
            kind = ("1 batched jit" if g.batched
                    else f"{len(g.cell_indices)} per-point {g.solver} solves")
            lines.append(f"  design {g.family} (N={g.n_devices}): "
                         f"{len(g.cell_indices)} point(s) -> {kind}"
                         + (f", direct cross-check on {len(g.needs_direct)}"
                            if g.needs_direct else ""))
        return "\n".join(lines)

    def schedule(self) -> tuple:
        """Dependency-ordered work list: ``("design", group)`` /
        ``("cell", cell)`` entries, each design group placed immediately
        before its first member cell. Because a group's first member is
        its minimum cell index, *every* group a cell belongs to precedes
        that cell — so a walk in schedule order (serial executor) or a
        solve-then-dispatch walk (parallel executor) never reaches a cell
        whose batched design is still unsolved.
        """
        first: dict = {}
        for g in sorted(self.design_groups,
                        key=lambda g: (min(g.cell_indices), g.family)):
            first.setdefault(min(g.cell_indices), []).append(g)
        entries = []
        for cell in self.cells:
            for g in first.get(cell.index, ()):
                entries.append(("design", g))
            entries.append(("cell", cell))
        return tuple(entries)


def plan(spec) -> Plan:
    """Compile a scenario/sweep into cells + grouped design work."""
    sweep = as_sweep(spec)
    cells = []
    for i, (overrides, scenario) in enumerate(sweep.points()):
        cells.append(Cell(index=i, overrides=overrides, scenario=scenario,
                          cell_hash=spec_hash(scenario.to_dict())))

    groups: dict = {}
    for cell in cells:
        fams = schemes.design_families(cell.scenario.schemes)
        for family, needs_direct in fams.items():
            key = (family, cell.scenario.n_devices,
                   cell.scenario.design.solver)
            members, direct = groups.setdefault(key, ([], []))
            members.append(cell.index)
            if needs_direct:
                direct.append(cell.index)
    design_groups = tuple(
        DesignGroup(family=family, n_devices=n, solver=solver,
                    cell_indices=tuple(members), needs_direct=tuple(direct))
        for (family, n, solver), (members, direct) in groups.items())
    return Plan(sweep=sweep, cells=tuple(cells), design_groups=design_groups)
