"""Declarative experiment specs: ``ScenarioSpec`` and ``SweepSpec``.

A ``ScenarioSpec`` is a pure-data description of one FL experiment cell —
task + data partition + wireless deployment + scheme suite + Sec.-IV
design policy + run options. It is JSON/dict round-trippable
(``to_dict``/``from_dict``), hashable by content (``spec_hash``), and
carries *no* arrays or live objects: everything heavy (datasets, design
parameters, trainers) is materialized by the planner/executor
(``repro.api.plan`` / ``repro.api.execute``).

A ``SweepSpec`` declares grids over any spec axis by dotted path —
``wireless.tx_power_dbm`` (SNR), ``wireless.n_devices``,
``wireless.pl_exponent`` (path-loss heterogeneity),
``design.omega_bias_scale``, ``run.batch_size``, ``run.time_budget_s``,
``run.rng`` (replay vs fast execution), ``run.payload_dtype`` (f32 vs
bf16 uplink payloads), ``fault.dropout_prob`` / ``fault.deep_fade_thresh``
/ ``fault.erasure_prob`` / ``fault.straggler_prob`` / ``fault.deadline_s``
(wireless fault injection, ``core.faults``),
``run.clients_per_round`` / ``run.participation`` (per-round client
sampling, ``core.participation``), ``run.mode`` /
``async_.buffer_rounds`` / ``async_.arrival_rate`` /
``async_.rate_heterogeneity`` / ``async_.staleness_discount`` /
``async_.weighting`` (buffered-asynchronous execution,
``core.async_fl``), ... — and expands to the cross product of
override-applied scenarios (``points()``).
"""
from __future__ import annotations

import dataclasses
import hashlib
import itertools
import json
from typing import Optional

from ..core.async_fl import MODES, AsyncSpec
from ..core.channel import WirelessConfig
from ..core.faults import FaultSpec
from .results import SCHEMA_VERSION, json_default


@dataclasses.dataclass(frozen=True)
class TaskSpec:
    """Learning task (Sec. V): softmax regression or the MLP stand-in."""

    kind: str = "softmax"            # "softmax" | "mlp"
    n_features: int = 784
    n_classes: int = 10
    hidden: int = 48                 # mlp only
    mu: float = 0.01                 # softmax: strong convexity; mlp: l2 reg
    g_max: float = 20.0              # Assumption 1 gradient clip


@dataclasses.dataclass(frozen=True)
class DataSpec:
    """Synthetic dataset + non-i.i.d. partition (Sec. V splits)."""

    name: str = "mnist-like"         # synthetic family ("mnist-like"/...)
    image_shape: tuple = (28, 28, 1)
    n_train_per_class: int = 1200
    n_test_per_class: int = 200
    noise_sigma: float = 1.5
    dataset_seed: int = 0
    classes_per_device: int = 1
    samples_per_device: int = 1000
    partition_seed: int = 3


@dataclasses.dataclass(frozen=True)
class DesignPolicy:
    """Sec.-IV bias-variance design knobs shared by every designed scheme.

    ``kappa=None`` estimates the heterogeneity constant from the actual
    task data (``estimate_kappa_sc``/``estimate_kappa_nc``); the omega
    scales multiply the footnote-4 weights, exposing the bias-variance
    trade-off as a sweepable axis.
    """

    objective: str = "strongly_convex"   # | "non_convex" (footnote 4 rule)
    kappa: Optional[float] = None        # None -> estimate on the data
    kappa_iters: int = 1500              # sc: GD iters for w* in estimation
    kappa_probes: int = 3                # nc: probe points
    smooth_l: float = 10.0               # nc: smoothness L in omega_var
    omega_var_scale: float = 1.0
    omega_bias_scale: float = 1.0
    t_max_s: float = 0.2                 # digital latency budget (17b)
    top_k: int = 4                       # digital selection baselines' K
    solver: str = "auto"                 # auto|jax|sca|scipy|direct


@dataclasses.dataclass(frozen=True)
class RunSpec:
    """Monte-Carlo run options (rounds/trials/tuning/backend)."""

    rounds: int = 100
    trials: int = 2
    eval_every: int = 10
    seed: int = 5
    etas: tuple = (1.0, 0.5, 0.25, 0.1)  # step-size grid, fractions of eta_max
    eta_max: Optional[float] = None      # None -> 2/(mu+L) (softmax rule)
    batch_size: Optional[int] = None     # None -> full batch (|B|=|D|)
    time_budget_s: Optional[float] = None
    backend: str = "auto"
    rng: str = "replay"                  # "replay" (oracle-exact) | "fast"
    payload_dtype: str = "f32"           # uplink gradient payload: f32|bf16
    clients_per_round: Optional[int] = None  # S: partial participation (off)
    participation: str = "uniform"       # uniform|channel|designed|loss|datasize
    mode: str = "sync"                   # "sync" | "async" (core.async_fl)

    def __post_init__(self):
        if self.mode not in MODES:
            raise ValueError(
                f"run.mode must be one of {MODES}, got {self.mode!r}")


@dataclasses.dataclass(frozen=True)
class ScenarioSpec:
    """One declarative FL experiment cell (pure data, dict round-trippable).

    ``schemes`` lists scheme keys from ``repro.api.schemes`` and/or
    ``"suite:<name>"`` aliases expanded in declaration order.
    """

    name: str = "scenario"
    task: TaskSpec = TaskSpec()
    data: DataSpec = DataSpec()
    wireless: WirelessConfig = WirelessConfig()
    design: DesignPolicy = DesignPolicy()
    run: RunSpec = RunSpec()
    fault: FaultSpec = FaultSpec()       # wireless fault injection (off)
    async_: AsyncSpec = AsyncSpec()      # buffered-async knobs (run.mode)
    schemes: tuple = ("suite:fig2_ota",)

    @property
    def n_devices(self) -> int:
        return self.wireless.n_devices

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "ScenarioSpec":
        data = dict(d["data"])
        data["image_shape"] = tuple(data["image_shape"])
        run = dict(d["run"])
        run["etas"] = tuple(run["etas"])
        return cls(
            name=d["name"],
            task=TaskSpec(**d["task"]),
            data=DataSpec(**data),
            wireless=WirelessConfig(**d["wireless"]),
            design=DesignPolicy(**d["design"]),
            run=RunSpec(**run),
            # pre-v5 dicts have no "fault" key: default to disabled
            fault=FaultSpec(**d["fault"]) if d.get("fault") else FaultSpec(),
            # pre-v7 dicts have no "async_" key: default knobs (run.mode
            # also defaults to "sync" via RunSpec, keeping them inert)
            async_=(AsyncSpec(**d["async_"]) if d.get("async_")
                    else AsyncSpec()),
            schemes=tuple(d["schemes"]))

    def replace(self, **kw) -> "ScenarioSpec":
        return dataclasses.replace(self, **kw)

    def override(self, path: str, value) -> "ScenarioSpec":
        """Return a copy with the dotted-path field replaced."""
        return _apply_override(self, path, value)

    def spec_hash(self) -> str:
        return spec_hash(self.to_dict())


@dataclasses.dataclass(frozen=True)
class SweepSpec:
    """A grid over scenario axes: base spec + ordered (path, values) axes."""

    name: str
    base: ScenarioSpec
    axes: tuple = ()                 # ((dotted_path, (v0, v1, ...)), ...)

    def __post_init__(self):
        # accept {path: values} mappings in declarations; normalize to the
        # ordered tuple-of-pairs form (dict insertion order preserved)
        if isinstance(self.axes, dict):
            object.__setattr__(self, "axes", tuple(
                (k, tuple(v)) for k, v in self.axes.items()))
        else:
            object.__setattr__(self, "axes", tuple(
                (k, tuple(v)) for k, v in self.axes))

    @property
    def shape(self) -> tuple:
        return tuple(len(vals) for _, vals in self.axes)

    @property
    def n_points(self) -> int:
        n = 1
        for _, vals in self.axes:
            n *= len(vals)
        return n

    def points(self) -> list[tuple[dict, ScenarioSpec]]:
        """Cross product of the axes: [(overrides, scenario), ...]."""
        paths = [p for p, _ in self.axes]
        grids = [vals for _, vals in self.axes]
        out = []
        for combo in itertools.product(*grids):
            overrides = dict(zip(paths, combo))
            sc = self.base
            for path, value in overrides.items():
                sc = _apply_override(sc, path, value)
            out.append((overrides, sc))
        return out

    def to_dict(self) -> dict:
        return {"name": self.name, "base": self.base.to_dict(),
                "axes": {p: list(v) for p, v in self.axes}}

    @classmethod
    def from_dict(cls, d: dict) -> "SweepSpec":
        return cls(name=d["name"], base=ScenarioSpec.from_dict(d["base"]),
                   axes=d.get("axes", ()))

    def spec_hash(self) -> str:
        return spec_hash(self.to_dict())


def as_sweep(spec) -> SweepSpec:
    """Promote a single scenario to a one-cell sweep (planner entry)."""
    if isinstance(spec, SweepSpec):
        return spec
    if isinstance(spec, ScenarioSpec):
        return SweepSpec(name=spec.name, base=spec, axes=())
    raise TypeError(f"expected ScenarioSpec or SweepSpec, got {type(spec)}")


def spec_from_dict(d: dict):
    """Dispatch a parsed JSON object to the matching spec class."""
    return SweepSpec.from_dict(d) if "base" in d else ScenarioSpec.from_dict(d)


def spec_hash(d: dict) -> str:
    """Content hash of a spec dict (cache key; schema-version salted).

    Serialized through the strict result encoder so numpy scalars in spec
    fields or sweep grids (np.arange/np.linspace axes) hash like their
    Python equivalents instead of raising.
    """
    canon = json.dumps({"schema_version": SCHEMA_VERSION, "spec": d},
                       sort_keys=True, separators=(",", ":"),
                       default=json_default)
    return hashlib.sha256(canon.encode()).hexdigest()[:16]


def _apply_override(node, path: str, value):
    """Replace a (possibly nested) frozen-dataclass field by dotted path."""
    head, _, rest = path.partition(".")
    if not hasattr(node, head):
        raise KeyError(f"unknown spec field {head!r} in override {path!r}")
    if rest:
        value = _apply_override(getattr(node, head), rest, value)
    else:
        current = getattr(node, head)
        if isinstance(current, tuple) and isinstance(value, list):
            value = tuple(value)
    return dataclasses.replace(node, **{head: value})
