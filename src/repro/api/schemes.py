"""Scheme registry: spec keys -> Sec.-V aggregator constructors.

Maps the short scheme keys used in ``ScenarioSpec.schemes`` onto the
``core.baselines`` constructors, records which keys need a Sec.-IV design
solve (and of which family), and defines the named suites the figure
pipelines declare (``"suite:fig2_ota"`` etc.), preserving the legacy
pipelines' scheme ordering exactly.
"""
from __future__ import annotations

from ..core import baselines as B

#: scheme key -> (design family, variant) for schemes that consume designed
#: parameters; "designed" routes through the (batched) sweep solver, while
#: "direct" uses the per-point reduced SciPy solver (fig2's cross-check).
DESIGN_NEEDS = {
    "proposed_ota": ("ota", "designed"),
    "proposed_ota_direct": ("ota", "direct"),
    "proposed_digital": ("digital", "designed"),
    "proposed_digital_direct": ("digital", "direct"),
}

#: Named suites (legacy pipeline ordering, proposed-first conventions).
SUITES = {
    # fig2 a/b: all Sec. V-A-1 OTA baselines + the direct-solver variant
    "fig2_ota": ("ideal", "proposed_ota", "proposed_ota_direct",
                 "opc_ota_fl", "opc_ota_comp", "lcpc_ota_comp",
                 "vanilla_ota", "bbfl_interior", "bbfl_alternative"),
    # fig2 c/d: Sec. V-A-2 digital selection suite + direct variant
    "fig2_digital": ("proposed_digital", "proposed_digital_direct",
                     "fedtoe", "prop_fairness", "best_channel_norm",
                     "best_channel", "uqos", "qml"),
    # fig3: OTA suite minus the genie OPC OTA-FL (PL condition + future
    # CSI; paper excludes it in the non-convex comparison), no direct
    "fig3_ota": ("ideal", "proposed_ota", "opc_ota_comp", "lcpc_ota_comp",
                 "vanilla_ota", "bbfl_interior", "bbfl_alternative"),
}


def _wargs(ctx):
    cfg = ctx.dep.cfg
    return (ctx.task.dim, ctx.task.g_max, cfg.energy_per_symbol,
            cfg.noise_power)


def _dargs(ctx):
    return _wargs(ctx) + (ctx.dep.cfg.bandwidth_hz,)


_BUILDERS = {
    "ideal": lambda c: B.IdealFedAvg(),
    "proposed_ota": lambda c: B.ProposedOTA(c.ota_params),
    "proposed_ota_direct": lambda c: B.ProposedOTA(
        c.ota_params_direct, label="Proposed OTA-FL (direct)"),
    "opc_ota_fl": lambda c: B.OPCOTAFL(*_wargs(c)),
    "opc_ota_comp": lambda c: B.OPCOTAComp(*_wargs(c)),
    "lcpc_ota_comp": lambda c: B.LCPCOTAComp(c.dep, *_wargs(c)),
    "vanilla_ota": lambda c: B.VanillaOTA(*_wargs(c)),
    "bbfl_interior": lambda c: B.BBFLInterior(c.dep, *_wargs(c)),
    "bbfl_alternative": lambda c: B.BBFLAlternative(c.dep, *_wargs(c)),
    "proposed_digital": lambda c: B.ProposedDigital(c.dig_params),
    "proposed_digital_direct": lambda c: B.ProposedDigital(
        c.dig_params_direct, label="Proposed Digital FL (direct)"),
    "fedtoe": lambda c: B.FedTOE(c.dep, *_dargs(c), k=c.top_k),
    "prop_fairness": lambda c: B.PropFairness(c.dep, *_dargs(c), k=c.top_k),
    "best_channel_norm": lambda c: B.BestChannelNorm(c.dep, *_dargs(c),
                                                     k=c.top_k),
    "best_channel": lambda c: B.BestChannel(c.dep, *_dargs(c), k=c.top_k),
    "uqos": lambda c: B.UQOS(c.dep, *_dargs(c), k=c.top_k),
    "qml": lambda c: B.QML(c.dep, *_dargs(c), k=c.top_k),
}


def scheme_keys() -> tuple:
    return tuple(_BUILDERS)


def expand_schemes(schemes) -> tuple:
    """Resolve ``suite:*`` aliases and validate keys, preserving order."""
    out = []
    for entry in schemes:
        if entry.startswith("suite:"):
            suite = entry[len("suite:"):]
            if suite not in SUITES:
                raise KeyError(f"unknown suite {suite!r}; "
                               f"have {sorted(SUITES)}")
            out.extend(SUITES[suite])
        elif entry in _BUILDERS:
            out.append(entry)
        else:
            raise KeyError(f"unknown scheme key {entry!r}; "
                           f"have {sorted(_BUILDERS)}")
    return tuple(out)


def design_families(schemes) -> dict:
    """{family: needs_direct} over the (expanded) scheme keys."""
    fams: dict = {}
    for key in expand_schemes(schemes):
        need = DESIGN_NEEDS.get(key)
        if need is None:
            continue
        family, variant = need
        fams[family] = fams.get(family, False) or (variant == "direct")
    return fams


def build_scheme(key: str, ctx):
    """Instantiate one aggregator against a materialized cell context."""
    return _BUILDERS[key](ctx)
