"""Declarative scenario/sweep API over the design solvers, the JAX FL
engine, and the figure pipelines.

    spec        ScenarioSpec / SweepSpec — pure-data experiment declarations
    plan        compile a sweep into cells + grouped batched design solves
    execute     run a plan into a versioned, content-hash-cached ResultSet
    results     result schema, strict JSON encoding, ResultSet artifact
    scenarios   named builders (paper figures, beyond-paper sweeps)
    cli         python -m repro.api.cli run/list/describe

Quick tour::

    from repro.api import ScenarioSpec, SweepSpec, plan, execute
    sweep = SweepSpec(name="snr", base=ScenarioSpec(...),
                      axes={"wireless.tx_power_dbm": [-10, 0, 10]})
    print(plan(sweep).describe())       # cells + one batched design solve
    rs = execute(sweep)                 # cached, manifest-tracked
"""
from .execute import execute
from .plan import Cell, DesignGroup, Plan, plan
from .results import (SCHEMA_VERSION, CellResult, ResultSet, dump_json,
                      json_default, log_record, result_payload)
from .spec import (DataSpec, DesignPolicy, RunSpec, ScenarioSpec, SweepSpec,
                   TaskSpec, as_sweep, spec_from_dict, spec_hash)

__all__ = [
    "SCHEMA_VERSION", "Cell", "CellResult", "DataSpec", "DesignGroup",
    "DesignPolicy", "Plan", "ResultSet", "RunSpec", "ScenarioSpec",
    "SweepSpec", "TaskSpec", "as_sweep", "dump_json", "execute",
    "json_default", "log_record", "plan", "result_payload",
    "spec_from_dict", "spec_hash",
]
