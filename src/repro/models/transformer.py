"""Model assembly: decoder-only LMs (dense/MoE/SSM/hybrid/VLM) and the
encoder-decoder (whisper-style) — all built from `layers.py` blocks.

Depth is organized as scanned *pattern groups*: one group = one pass of
``cfg.layer_pattern``. ``n_groups = n_layers // len(pattern)`` groups are
stacked (leading "layers" axis) and executed with ``jax.lax.scan`` to keep
the lowered HLO small across the 40-combination dry-run; remainder layers
(`n_layers % len(pattern)`) run unrolled as the "tail".

Params / caches are nested dicts:
    params = {embed, groups: {b0: {...}, b1: ...}, tail: {"0": {b0:...}},
              final_norm, lm_head [, enc_groups, enc_tail]}
Axes trees mirror params exactly (tuples of logical axis names).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from .common import ModelConfig, ParamBuilder, rms_norm, stack_axes
from . import layers as L


# ----------------------------------------------------------- layer builds

def _init_layer(pb: ParamBuilder, cfg: ModelConfig, kind: str,
                cross: bool, moe: bool) -> tuple[dict, dict]:
    p, a = {}, {}
    pb.param(p, a, "ln1", (cfg.d_model,), ("embed",), init="ones")
    if kind in ("global", "local", "encoder"):
        sp, sa = pb.scope(p, a, "attn")
        L.init_attention(pb, sp, sa, cfg)
    elif kind == "mamba":
        sp, sa = pb.scope(p, a, "mamba")
        L.init_mamba(pb, sp, sa, cfg)
    elif kind == "rglru":
        sp, sa = pb.scope(p, a, "rec")
        L.init_rglru(pb, sp, sa, cfg)
    else:
        raise ValueError(kind)
    if cross and kind != "encoder":
        pb.param(p, a, "ln_cross", (cfg.d_model,), ("embed",), init="ones")
        sp, sa = pb.scope(p, a, "cross")
        L.init_attention(pb, sp, sa, cfg, cross=True)
    if kind != "mamba" and cfg.d_ff > 0:
        pb.param(p, a, "ln2", (cfg.d_model,), ("embed",), init="ones")
        if moe:
            sp, sa = pb.scope(p, a, "moe")
            L.init_moe(pb, sp, sa, cfg)
        else:
            sp, sa = pb.scope(p, a, "mlp")
            L.init_mlp(pb, sp, sa, cfg)
    return p, a


def _layer_apply(cfg: ModelConfig, kind: str, p: dict, x, positions, *,
                 cache=None, mode="train", flags=None, memory=None):
    """One transformer layer. Returns (x, new_cache, aux)."""
    aux = jnp.zeros((), jnp.float32)
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if kind in ("global", "local", "encoder"):
        y, new_cache = L.attention_apply(
            cfg, p["attn"], h, positions, kind=kind,
            cache=None if cache is None else cache.get("attn"),
            mode=mode, flags=flags)
        new_cache = None if new_cache is None else {"attn": new_cache}
    elif kind == "mamba":
        y, nc = L.mamba_apply(cfg, p["mamba"], h,
                              cache=None if cache is None else cache.get("mamba"),
                              mode=mode, flags=flags)
        new_cache = {"mamba": nc} if (mode != "train") else None
    elif kind == "rglru":
        y, nc = L.rglru_apply(cfg, p["rec"], h,
                              cache=None if cache is None else cache.get("rec"),
                              mode=mode, flags=flags)
        new_cache = {"rec": nc} if (mode != "train") else None
    else:
        raise ValueError(kind)
    x = x + y
    if "cross" in p:
        h = rms_norm(x, p["ln_cross"], cfg.norm_eps)
        y, _ = L.attention_apply(cfg, p["cross"], h, positions, kind="global",
                                 mode="train", flags=flags, cross_kv=memory)
        x = x + y
    if "ln2" in p:
        h = rms_norm(x, p["ln2"], cfg.norm_eps)
        if "moe" in p:
            y, aux = L.moe_apply(cfg, p["moe"], h, flags=flags)
        else:
            y = L.mlp_apply(cfg, p["mlp"], h)
        x = x + y
    return x, new_cache, aux


def _init_layer_cache(cfg: ModelConfig, kind: str, batch: int,
                      cache_len: int, dtype, cross_len: int = 0) -> dict:
    c = {}
    if kind in ("global", "local"):
        eff = min(cache_len, cfg.window_size) if kind == "local" else cache_len
        c["attn"] = L.init_attention_cache(cfg, batch, eff, dtype)
    elif kind == "mamba":
        c["mamba"] = L.init_mamba_cache(cfg, batch, dtype)
    elif kind == "rglru":
        c["rec"] = L.init_rglru_cache(cfg, batch, dtype)
    return c


# ------------------------------------------------------------ full model

class Transformer:
    """Functional model wrapper for one ModelConfig."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        pat = cfg.layer_pattern
        self.n_groups = cfg.n_layers // len(pat)
        self.n_tail = cfg.n_layers % len(pat)
        self.cross = cfg.encoder_layers > 0
        self._axes = None

    # ----- init ---------------------------------------------------------

    def _init_fn(self, key: jax.Array):
        cfg = self.cfg
        pat = cfg.layer_pattern
        moe = cfg.n_experts > 0
        pb = ParamBuilder(key, dtype=cfg.dtype)
        params, axes = {}, {}
        pb.param(params, axes, "embed", (cfg.vocab_size, cfg.d_model),
                 ("vocab", "embed"), scale=0.02)
        pb.param(params, axes, "final_norm", (cfg.d_model,), ("embed",),
                 init="ones")
        pb.param(params, axes, "lm_head", (cfg.d_model, cfg.vocab_size),
                 ("embed", "vocab"), scale=0.02)

        def init_group(k):
            gpb = ParamBuilder(k, dtype=cfg.dtype)
            gp, ga = {}, {}
            for i, kind in enumerate(pat):
                p_i, a_i = _init_layer(gpb, cfg, kind, self.cross, moe)
                gp[f"b{i}"] = p_i
                ga[f"b{i}"] = a_i
            return gp, ga

        if self.n_groups > 0:
            keys = jax.random.split(pb._next(), self.n_groups)
            params["groups"] = jax.vmap(lambda k: init_group(k)[0])(keys)
            axes["groups"] = stack_axes(self._recorded_axes(init_group))
        tail = {}
        tail_axes = {}
        for j in range(self.n_tail):
            p_j, a_j = _init_layer(pb, cfg, pat[j], self.cross, moe)
            tail[str(j)] = p_j
            tail_axes[str(j)] = a_j
        if tail:
            params["tail"] = tail
            axes["tail"] = tail_axes
        if self.cross:
            def init_enc_group(k):
                gpb = ParamBuilder(k, dtype=cfg.dtype)
                gp, ga = {}, {}
                p_i, a_i = _init_layer(gpb, cfg, "encoder", False, False)
                gp["b0"] = p_i
                ga["b0"] = a_i
                return gp, ga
            keys = jax.random.split(pb._next(), cfg.encoder_layers)
            params["enc_groups"] = jax.vmap(lambda k: init_enc_group(k)[0])(keys)
            axes["enc_groups"] = stack_axes(
                self._recorded_axes(init_enc_group))
            pb.param(params, axes, "enc_norm", (cfg.d_model,), ("embed",),
                     init="ones")
        self._axes = axes
        return params

    @staticmethod
    def _recorded_axes(init_group_fn):
        """Trace the group init abstractly to recover its axes tree."""
        holder = {}

        def run(k):
            gp, ga = init_group_fn(k)
            holder["axes"] = ga
            return gp

        jax.eval_shape(run, jax.random.key(0))
        return holder["axes"]

    def init(self, key: jax.Array):
        return self._init_fn(key)

    def abstract_params(self):
        return jax.eval_shape(self._init_fn, jax.random.key(0))

    @property
    def axes(self):
        if self._axes is None:
            self.abstract_params()
        return self._axes

    # ----- caches -------------------------------------------------------

    def init_cache(self, batch: int, cache_len: int, dtype=None,
                   encoder_len: int = 0):
        cfg = self.cfg
        dtype = dtype or cfg.dtype
        pat = cfg.layer_pattern
        cache = {}
        if self.n_groups > 0:
            one = {f"b{i}": _init_layer_cache(cfg, kind, batch, cache_len, dtype)
                   for i, kind in enumerate(pat)}
            # stack over groups, preserving fill values (-1 position buffers)
            cache["groups"] = jax.tree.map(
                lambda o: jnp.broadcast_to(o, (self.n_groups,) + o.shape), one)
        if self.n_tail:
            cache["tail"] = {str(j): _init_layer_cache(cfg, pat[j], batch,
                                                       cache_len, dtype)
                             for j in range(self.n_tail)}
        return cache

    # ----- forward ------------------------------------------------------

    def _embed(self, params, tokens):
        return params["embed"][tokens]

    def encode(self, params, enc_embeds, flags=None):
        """Run the (stub-fed) encoder stack. enc_embeds: (B, S_enc, d)."""
        cfg = self.cfg
        x = enc_embeds
        B, S, _ = x.shape
        pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

        def body(carry, gp):
            h, _, _ = _layer_apply(cfg, "encoder", gp["b0"], carry, pos,
                                   mode="train", flags=None)
            return h, None

        x, _ = jax.lax.scan(body, x, params["enc_groups"])
        return rms_norm(x, params["enc_norm"], cfg.norm_eps)

    def forward(self, params, x, positions, *, mode="train", caches=None,
                flags=None, memory=None, remat=True):
        """Backbone over embeddings x (B,S,d). Returns (hidden, caches, aux)."""
        cfg = self.cfg
        pat = cfg.layer_pattern
        flags = dict(flags or {})
        aux_total = jnp.zeros((), jnp.float32)

        def group_body(x, gp, gc, mem):
            new_gc = {} if gc is not None else None
            aux_sum = jnp.zeros((), jnp.float32)
            for i, kind in enumerate(pat):
                c_i = None if gc is None else gc.get(f"b{i}")
                x, nc, aux = _layer_apply(
                    cfg, kind, gp[f"b{i}"], x, positions, cache=c_i,
                    mode=mode, flags=flags, memory=mem)
                aux_sum = aux_sum + aux
                if new_gc is not None:
                    new_gc[f"b{i}"] = nc if nc is not None else c_i
            return x, new_gc, aux_sum

        if self.n_groups > 0:
            gc_all = None if caches is None else caches["groups"]
            mem_all = memory
            if self.cross and memory is not None:
                # per-group cross K/V: same encoder memory for every layer
                pass

            def scan_body(carry, xs):
                x = carry
                if gc_all is None:
                    gp = xs
                    gc = None
                else:
                    gp, gc = xs
                x, new_gc, aux = group_body(x, gp, gc, mem_all)
                return x, (new_gc, aux)

            if remat and mode == "train":
                scan_body = jax.checkpoint(scan_body)
            xs = (params["groups"] if gc_all is None
                  else (params["groups"], gc_all))
            x, (new_gcs, auxs) = jax.lax.scan(scan_body, x, xs)
            aux_total = aux_total + jnp.sum(auxs)
            if caches is not None:
                caches = dict(caches)
                caches["groups"] = new_gcs
        if self.n_tail:
            new_tail = {}
            for j in range(self.n_tail):
                c_j = None if caches is None else caches["tail"][str(j)]
                x, nc, aux = _layer_apply(
                    cfg, pat[j], params["tail"][str(j)], x, positions,
                    cache=c_j, mode=mode, flags=flags, memory=memory)
                aux_total = aux_total + aux
                new_tail[str(j)] = nc if nc is not None else c_j
            if caches is not None:
                caches["tail"] = new_tail
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        return x, caches, aux_total

    def logits(self, params, hidden):
        return jnp.einsum("bsd,dv->bsv", hidden, params["lm_head"])
