"""Composable pure-JAX blocks: attention, MLP, MoE, Mamba-1, RG-LRU.

Every block provides
    init_<block>(pb, p, a, cfg, ...)          — create params + logical axes
    <block>_apply(cfg, p, x, ..., cache=None) — forward (train/prefill/decode)

Conventions:
  * x is (B, S, d).  Decode calls use S == 1 plus a cache.
  * caches are dicts of arrays; attention caches are ring buffers of length
    ``cache_len`` (== window for sliding-window decode, == max-seq else),
    with stored absolute positions for masking, so the same code serves
    full-context decode (decode_32k) and windowed long-context decode
    (long_500k sliding-window variant).
  * logical axes used here: "embed" (d_model), "heads", "kv_heads",
    "head_dim", "mlp" (d_ff), "vocab", "experts", "expert_mlp",
    "ssm_inner", "ssm_state", "dt_rank", "lru", "conv", "layers" (stacking).
  * flags: dict of runtime options; flags["attn_impl"] in
    {"einsum", "chunked"} selects the attention materialization strategy
    (chunked = online-softmax flash-style, used by the perf pass).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .common import ModelConfig, ParamBuilder, rms_norm, rope

NEG_INF = -1e30


# =============================================================== attention

def init_attention(pb: ParamBuilder, p: dict, a: dict, cfg: ModelConfig,
                   cross: bool = False):
    d, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    pb.param(p, a, "wq", (d, H, hd), ("embed", "heads", "head_dim"))
    pb.param(p, a, "wk", (d, KV, hd), ("embed", "kv_heads", "head_dim"))
    pb.param(p, a, "wv", (d, KV, hd), ("embed", "kv_heads", "head_dim"))
    pb.param(p, a, "wo", (H, hd, d), ("heads", "head_dim", "embed"))
    if cfg.qk_norm:
        pb.param(p, a, "q_norm", (hd,), ("head_dim",), init="ones")
        pb.param(p, a, "k_norm", (hd,), ("head_dim",), init="ones")


def _qk_normalize(cfg, p, q, k):
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    return q, k


def _attend_einsum(q, k, v, mask):
    """q:(B,S,H,hd) k/v:(B,T,KV,hd) mask:(B,1,S,T) -> (B,S,H,hd)."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, S, KV, G, hd)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, k) / jnp.sqrt(hd).astype(q.dtype)
    scores = scores.astype(jnp.float32)
    scores = jnp.where(mask[:, 0][:, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v)
    return out.reshape(B, S, H, hd)


def _attend_chunked(q, k, v, mask, chunk: int = 512):
    """Flash-style online softmax over key chunks (no SxT materialization)."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    T = k.shape[1]
    G = H // KV
    chunk = min(chunk, T)
    n_chunks = -(-T // chunk)
    pad = n_chunks * chunk - T
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        mask = jnp.pad(mask, ((0, 0), (0, 0), (0, 0), (0, pad)))
    qg = (q.reshape(B, S, KV, G, hd) / jnp.sqrt(hd).astype(q.dtype))
    kc = k.reshape(B, n_chunks, chunk, KV, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, n_chunks, chunk, KV, hd).transpose(1, 0, 2, 3, 4)
    mc = mask.reshape(B, 1, S, n_chunks, chunk).transpose(3, 0, 1, 2, 4)

    def step(carry, xs):
        m_run, l_run, o_run = carry
        k_i, v_i, msk = xs                      # (B,c,KV,hd), (B,1,S,c)
        s = jnp.einsum("bskgd,btkd->bkgst", qg, k_i).astype(jnp.float32)
        s = jnp.where(msk[:, 0][:, None, None], s, NEG_INF)
        m_new = jnp.maximum(m_run, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_run - m_new)
        pexp = jnp.exp(s - m_new[..., None])
        l_new = l_run * alpha + jnp.sum(pexp, axis=-1)
        o_i = jnp.einsum("bkgst,btkd->bkgsd", pexp.astype(q.dtype), v_i)
        o_new = o_run * alpha[..., None].astype(q.dtype) + o_i
        return (m_new, l_new, o_new), None

    m0 = jnp.full((B, KV, G, S), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, KV, G, S), jnp.float32)
    o0 = jnp.zeros((B, KV, G, S, hd), q.dtype)
    (m, l, o), _ = jax.lax.scan(step, (m0, l0, o0), (kc, vc, mc))
    out = o / jnp.maximum(l, 1e-30)[..., None].astype(q.dtype)
    return out.transpose(0, 3, 1, 2, 4).reshape(B, S, H, hd)


def _causal_mask(positions_q: jnp.ndarray, positions_k: jnp.ndarray,
                 window: Optional[int]) -> jnp.ndarray:
    """(B,1,S,T) mask: causal, optionally sliding-window, k-pos >= 0 valid."""
    m = positions_k[:, None, None, :] <= positions_q[:, None, :, None]
    m &= positions_k[:, None, None, :] >= 0
    if window is not None:
        m &= (positions_q[:, None, :, None] - positions_k[:, None, None, :]
              < window)
    return m


def attention_apply(cfg: ModelConfig, p: dict, x: jnp.ndarray,
                    positions: jnp.ndarray, *, kind: str = "global",
                    cache: Optional[dict] = None, mode: str = "train",
                    flags: Optional[dict] = None,
                    cross_kv: Optional[tuple] = None):
    """Self- (or cross-) attention. Returns (y, new_cache)."""
    flags = flags or {}
    B, S, d = x.shape
    window = cfg.window_size if kind == "local" else None
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    if cross_kv is not None:
        # cross-attention to the encoder memory (B, S_enc, d): K/V computed
        # from the memory, no causal mask, no rope
        k = jnp.einsum("bsd,dhk->bshk", cross_kv, p["wk"])
        v = jnp.einsum("bsd,dhk->bshk", cross_kv, p["wv"])
        mask = jnp.ones((B, 1, S, k.shape[1]), bool)
        impl = flags.get("attn_impl", "einsum")
        out = (_attend_chunked if impl == "chunked" else _attend_einsum)(q, k, v, mask)
        y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
        return y, cache
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    q, k = _qk_normalize(cfg, p, q, k)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)

    if mode == "decode":
        assert cache is not None and S == 1
        L = cache["k"].shape[1]
        slot = (positions[:, 0] % L).astype(jnp.int32)      # ring slot per batch
        bidx = jnp.arange(B)
        ck = cache["k"].at[bidx, slot].set(k[:, 0])
        cv = cache["v"].at[bidx, slot].set(v[:, 0])
        cpos = cache["pos"].at[bidx, slot].set(positions[:, 0].astype(jnp.int32))
        mask = _causal_mask(positions, cpos, window)
        impl = (flags or {}).get("attn_impl", "einsum")
        out = (_attend_chunked if impl == "chunked" else _attend_einsum)(
            q, ck, cv, mask)
        y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
        return y, {"k": ck, "v": cv, "pos": cpos}

    # train / prefill over the full sequence
    mask = _causal_mask(positions, positions.astype(jnp.int32), window)
    if kind == "encoder":                                    # bidirectional
        mask = jnp.ones_like(mask)
    impl = flags.get("attn_impl", "einsum")
    out = (_attend_chunked if impl == "chunked" else _attend_einsum)(q, k, v, mask)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    new_cache = None
    if mode == "prefill":
        cache_len = flags.get("cache_len", S)
        if cache_len >= S:
            pad = cache_len - S
            ck = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
            cv = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
            cpos = jnp.pad(positions.astype(jnp.int32), ((0, 0), (0, pad)),
                           constant_values=-1)
        else:
            # keep only the last `cache_len` keys, scattered to their ring
            # slot (slot = pos % cache_len) so decode writes line up
            ck0, cv0 = k[:, -cache_len:], v[:, -cache_len:]
            cpos0 = positions[:, -cache_len:].astype(jnp.int32)
            bidx = jnp.arange(B)[:, None]
            slots = cpos0 % cache_len
            ck = jnp.zeros_like(ck0).at[bidx, slots].set(ck0)
            cv = jnp.zeros_like(cv0).at[bidx, slots].set(cv0)
            cpos = jnp.full_like(cpos0, -1).at[bidx, slots].set(cpos0)
        new_cache = {"k": ck, "v": cv, "pos": cpos}
    return y, new_cache


def init_attention_cache(cfg: ModelConfig, batch: int, cache_len: int,
                         dtype) -> dict:
    KV, hd = cfg.n_kv_heads, cfg.hd
    return {
        "k": jnp.zeros((batch, cache_len, KV, hd), dtype),
        "v": jnp.zeros((batch, cache_len, KV, hd), dtype),
        "pos": jnp.full((batch, cache_len), -1, jnp.int32),
    }


# ==================================================================== MLP

def init_mlp(pb, p, a, cfg: ModelConfig):
    d, f = cfg.d_model, cfg.d_ff
    pb.param(p, a, "w_gate", (d, f), ("embed", "mlp"))
    pb.param(p, a, "w_up", (d, f), ("embed", "mlp"))
    pb.param(p, a, "w_down", (f, d), ("mlp", "embed"))


def mlp_apply(cfg, p, x):
    h = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, p["w_gate"]))
    h = h * jnp.einsum("bsd,df->bsf", x, p["w_up"])
    return jnp.einsum("bsf,fd->bsd", h, p["w_down"])


# ==================================================================== MoE

def init_moe(pb, p, a, cfg: ModelConfig):
    d, f, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    # the router is replicated ("experts_router" has no sharding rule):
    # routing needs the full expert axis on every shard under EP
    pb.param(p, a, "router", (d, E), ("embed", "experts_router"), scale=0.02)
    pb.param(p, a, "w_gate", (E, d, f), ("experts", "embed", "expert_mlp"))
    pb.param(p, a, "w_up", (E, d, f), ("experts", "embed", "expert_mlp"))
    pb.param(p, a, "w_down", (E, f, d), ("experts", "expert_mlp", "embed"))


def _moe_dispatch(cfg: ModelConfig, router, xf: jnp.ndarray, C: int):
    """Shared routing: returns (buf (E,C,d), combine-info, aux)."""
    T, d = xf.shape
    E, k = cfg.n_experts, cfg.n_experts_per_tok
    logits = jnp.einsum("td,de->te", xf, router).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, eids = jax.lax.top_k(probs, k)                     # (T,k)
    gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)
    flat_e = eids.reshape(-1).astype(jnp.int32)                   # (T*k,)
    order = jnp.argsort(flat_e)
    se = flat_e[order]
    tok = order // k
    starts = jnp.searchsorted(se, jnp.arange(E, dtype=se.dtype))
    pos = jnp.arange(T * k, dtype=jnp.int32) - starts[se].astype(jnp.int32)
    valid = pos < C
    dest = se * C + jnp.where(valid, pos, 0)
    src = jnp.where(valid[:, None], xf[tok], jnp.zeros((1, d), xf.dtype))
    buf = jnp.zeros((E * C, d), xf.dtype).at[dest].add(src)
    dispatch_frac = jnp.mean(
        (jax.nn.one_hot(eids[:, 0], E, dtype=jnp.float32)), axis=0)
    prob_frac = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(dispatch_frac * prob_frac)
    combine = (tok, dest, valid, gate_vals.reshape(-1)[order])
    return buf.reshape(E, C, d), combine, aux


def _moe_combine(combine, out_buf: jnp.ndarray, T: int, dtype):
    tok, dest, valid, gates = combine
    d = out_buf.shape[-1]
    flat = out_buf.reshape(-1, d)
    gathered = flat[dest] * (valid[:, None] * gates[:, None]).astype(dtype)
    return jnp.zeros((T, d), dtype).at[tok].add(gathered)


def _capacity(cfg: ModelConfig, T: int) -> int:
    E, k = cfg.n_experts, cfg.n_experts_per_tok
    if T * k <= 256:
        # dropless small-batch path (decode): full capacity so routing is
        # exactly consistent with the large-batch forward pass
        return T * k
    return max(1, int(T * k * cfg.moe_capacity_factor / E))


def _expert_ffn(p, buf):
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["w_gate"]))
    h = h * jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
    return jnp.einsum("ecf,efd->ecd", h, p["w_down"])


def moe_apply(cfg: ModelConfig, p: dict, x: jnp.ndarray,
              flags: Optional[dict] = None):
    """Top-k MoE with sort-based dispatch and fixed per-expert capacity.

    Two implementations (flags["moe_impl"]):
      * "auto" (default): routing/scatter expressed in plain jnp and left
        to the XLA SPMD partitioner. Correct everywhere, but the scatter
        from token-sharded operands into the expert-sharded buffer lowers
        to a full-buffer all-reduce — the dominant collective cost on MoE
        shapes (see EXPERIMENTS.md §Perf).
      * "ep": explicit expert parallelism — tokens are dispatched into a
        per-source-shard capacity buffer and exchanged with a single
        ``all_to_all`` over the "data" mesh axis (and back), the canonical
        TPU MoE schedule. Requires E %% data-shards == 0. Used via
        ``jax.shard_map`` (serve) or directly when the caller is already
        manual over "data" (the FL train step).
    Returns (y, aux_loss) with the standard switch load-balance auxiliary.
    """
    flags = flags or {}
    impl = flags.get("moe_impl", "auto")
    B, S, d = x.shape
    T = B * S
    if impl == "ep":
        mesh = flags.get("mesh")
        axis = "data"
        quant = bool(flags.get("moe_a2a_quant", False))
        # the FL train step runs the model inside a client-manual shard_map
        # and marks it via flags; there we can all_to_all directly
        if flags.get("_in_manual"):
            return _moe_apply_ep(cfg, p, x, axis, quant=quant)
        if mesh is not None and axis in mesh.axis_names \
                and cfg.n_experts % mesh.shape[axis] == 0 \
                and B % mesh.shape[axis] == 0:
            from jax.sharding import PartitionSpec as P
            pspecs = {"router": P(), "w_gate": P(axis), "w_up": P(axis),
                      "w_down": P(axis)}
            from ..compat import shard_map as _shard_map
            fn = _shard_map(
                lambda p_, x_: _moe_apply_ep(cfg, p_, x_, axis, quant=quant),
                mesh, in_specs=(pspecs, P(axis)),
                out_specs=(P(axis), P()), manual_axes=(axis,))
            return fn(p, x)
        # fall through to auto when EP preconditions fail
    C = _capacity(cfg, T)
    xf = x.reshape(T, d)
    buf, combine, aux = _moe_dispatch(cfg, p["router"], xf, C)
    out_buf = _expert_ffn(p, buf)
    y = _moe_combine(combine, out_buf, T, x.dtype)
    return y.reshape(B, S, d), aux


def _a2a_quantized(t: jnp.ndarray, axis: str):
    """int8-quantized all_to_all: halves the link payload vs bf16 (the
    paper's quantized-uplink idea applied to the EP dispatch). Per-slice
    absmax scales ride along as a tiny side channel. The backward pass is a
    plain all_to_all (straight-through; the a2a permutation is its own
    adjoint for split=concat=0), so the flag is safe under jax.grad."""

    @jax.custom_vjp
    def qa2a(u):
        scale = jnp.max(jnp.abs(u), axis=tuple(range(1, u.ndim)),
                        keepdims=True).astype(jnp.float32)      # (n,1,..)
        q = jnp.clip(jnp.round(u.astype(jnp.float32)
                               / jnp.maximum(scale, 1e-30) * 127.0),
                     -127, 127).astype(jnp.int8)
        q = jax.lax.all_to_all(q, axis, split_axis=0, concat_axis=0)
        scale = jax.lax.all_to_all(scale, axis, split_axis=0, concat_axis=0)
        return (q.astype(jnp.float32) * scale / 127.0).astype(u.dtype)

    def fwd(u):
        return qa2a(u), None

    def bwd(_, g):
        return (jax.lax.all_to_all(g, axis, split_axis=0, concat_axis=0),)

    qa2a.defvjp(fwd, bwd)
    return qa2a(t)


def _moe_apply_ep(cfg: ModelConfig, p: dict, x: jnp.ndarray, axis: str,
                  quant: bool = False):
    """Expert-parallel body: local routing -> all_to_all -> local experts ->
    inverse all_to_all -> local combine. Called with "data"-manual scope;
    p holds the LOCAL expert shard (E_loc = E/n_shards)."""
    n = jax.lax.axis_size(axis)
    B, S, d = x.shape
    T = B * S
    E = cfg.n_experts
    E_loc = E // n
    C = _capacity(cfg, T)                      # capacity per (src, expert)
    xf = x.reshape(T, d)
    buf, combine, aux = _moe_dispatch(cfg, p["router"], xf, C)
    # (E, C, d) -> (n, E_loc, C, d) -> exchange -> (n_src, E_loc, C, d)
    buf = buf.reshape(n, E_loc, C, d)
    if quant:
        buf = _a2a_quantized(buf, axis)
    else:
        buf = jax.lax.all_to_all(buf, axis, split_axis=0, concat_axis=0,
                                 tiled=False)
    # experts see all sources: (E_loc, n*C, d)
    buf = buf.transpose(1, 0, 2, 3).reshape(E_loc, n * C, d)
    out = _expert_ffn(p, buf)
    # NOTE (§Perf iteration 2, refuted): forcing a d-sharded layout here
    # (with_sharding_constraint P(None,None,"model")) was tried to turn the
    # model-axis all-reduce of this buffer into a reduce-scatter; XLA kept
    # the all-reduce AND added an all-gather (+74% collective bytes).
    # Exploiting the linearity of the combine needs the model axis manual
    # too (full-manual MoE) — left as future work.
    out = out.reshape(E_loc, n, C, d).transpose(1, 0, 2, 3)
    if quant:
        out = _a2a_quantized(out, axis)
    else:
        out = jax.lax.all_to_all(out, axis, split_axis=0, concat_axis=0,
                                 tiled=False)
    out_buf = out.reshape(E, C, d)
    y = _moe_combine(combine, out_buf, T, x.dtype)
    aux = jax.lax.pmean(aux, axis)
    return y.reshape(B, S, d), aux


# ================================================= chunked linear scans

def linear_scan_chunked(a: jnp.ndarray, b: jnp.ndarray, h0: jnp.ndarray,
                        chunk: int = 128):
    """h_t = a_t * h_{t-1} + b_t elementwise, over axis 1 of (B, S, ...).

    TPU adaptation: sequential lax.scan over chunks (carry in VMEM-sized
    state) with a parallel associative scan inside each chunk — bounds the
    materialized (B, chunk, ...) working set instead of (B, S, ...).
    Returns (h_all (B,S,...), h_last (B,...)).
    """
    B, S = a.shape[0], a.shape[1]
    chunk = min(chunk, S)
    n_chunks = -(-S // chunk)
    pad = n_chunks * chunk - S
    if pad:
        a = jnp.pad(a, ((0, 0), (0, pad)) + ((0, 0),) * (a.ndim - 2),
                    constant_values=1.0)
        b = jnp.pad(b, ((0, 0), (0, pad)) + ((0, 0),) * (b.ndim - 2))
    ac = a.reshape((B, n_chunks, chunk) + a.shape[2:]).transpose(
        (1, 0, 2) + tuple(range(3, a.ndim + 1)))
    bc = b.reshape((B, n_chunks, chunk) + b.shape[2:]).transpose(
        (1, 0, 2) + tuple(range(3, b.ndim + 1)))

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br

    def step(h, xs):
        a_i, b_i = xs                       # (B, chunk, ...)
        A, Bv = jax.lax.associative_scan(combine, (a_i, b_i), axis=1)
        h_all = A * h[:, None] + Bv
        return h_all[:, -1], h_all

    h_last, chunks = jax.lax.scan(step, h0, (ac, bc))
    out = chunks.transpose((1, 0, 2) + tuple(range(3, a.ndim + 1)))
    out = out.reshape((B, n_chunks * chunk) + a.shape[2:])[:, :S]
    return out, h_last


# ============================================================ conv1d state

def causal_conv1d(x: jnp.ndarray, w: jnp.ndarray, bias: jnp.ndarray,
                  state: Optional[jnp.ndarray] = None):
    """Depthwise causal conv over seq. x:(B,S,D), w:(K,D). Returns (y, state')
    where state' holds the last K-1 inputs for streaming decode."""
    K = w.shape[0]
    B, S, D = x.shape
    if state is None:
        state = jnp.zeros((B, K - 1, D), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)       # (B, S+K-1, D)
    y = sum(xp[:, i:i + S] * w[i] for i in range(K)) + bias
    new_state = xp[:, -(K - 1):] if K > 1 else state
    return y, new_state


# ================================================================= Mamba-1

def init_mamba(pb, p, a, cfg: ModelConfig):
    d, di, n, dr, K = (cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.dt_rank,
                       cfg.ssm_conv)
    pb.param(p, a, "in_proj", (d, 2 * di), ("embed", "ssm_inner"))
    pb.param(p, a, "conv_w", (K, di), ("conv", "ssm_inner"), scale=0.5)
    pb.param(p, a, "conv_b", (di,), ("ssm_inner",), init="zeros")
    pb.param(p, a, "x_proj", (di, dr + 2 * n), ("ssm_inner", "dt_rank"))
    pb.param(p, a, "dt_proj", (dr, di), ("dt_rank", "ssm_inner"))
    pb.param(p, a, "dt_bias", (di,), ("ssm_inner",), init="zeros")
    pb.param(p, a, "a_log", (di, n), ("ssm_inner", "ssm_state"), init="ssm_a")
    pb.param(p, a, "d_skip", (di,), ("ssm_inner",), init="ones")
    pb.param(p, a, "out_proj", (di, d), ("ssm_inner", "embed"))


def _selective_scan_fused(dt, Bmat, xb, A, Cmat, h0, chunk: int):
    """Chunked selective scan with the C-projection FUSED into the chunk
    loop: neither the (B,S,di,n) transition tensors nor the state history
    are materialized over the full sequence — the loop carries h (B,di,n)
    and stores only y (B,S,di). This is the memory-roofline optimization
    recorded in EXPERIMENTS.md §Perf (the same restructuring the Mamba CUDA
    kernel performs in registers, re-thought as a chunked TPU loop).
    """
    B, S, di = dt.shape
    n = A.shape[-1]
    chunk = min(chunk, S)
    n_chunks = -(-S // chunk)
    pad = n_chunks * chunk - S
    if pad:
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bmat = jnp.pad(Bmat, ((0, 0), (0, pad), (0, 0)))
        Cmat = jnp.pad(Cmat, ((0, 0), (0, pad), (0, 0)))
        xb = jnp.pad(xb, ((0, 0), (0, pad), (0, 0)))

    def to_chunks(t):
        return t.reshape((B, n_chunks, chunk) + t.shape[2:]).transpose(
            (1, 0, 2) + tuple(range(3, t.ndim + 1)))

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br

    def step(h, xs):
        dt_c, B_c, C_c, x_c = xs                      # (B, c, ...)
        a_c = jnp.exp(dt_c[..., None] * A)            # (B,c,di,n) transient
        b_c = (dt_c[..., None] * B_c[:, :, None, :]
               * x_c.astype(jnp.float32)[..., None])
        A_cum, B_cum = jax.lax.associative_scan(combine, (a_c, b_c), axis=1)
        h_all = A_cum * h[:, None] + B_cum
        y_c = jnp.einsum("bcdn,bcn->bcd", h_all, C_c)
        return h_all[:, -1], y_c

    h_last, ys = jax.lax.scan(
        step, h0, (to_chunks(dt), to_chunks(Bmat), to_chunks(Cmat),
                   to_chunks(xb)))
    y = ys.transpose(1, 0, 2, 3).reshape(B, n_chunks * chunk, di)[:, :S]
    return y, h_last


def mamba_apply(cfg: ModelConfig, p: dict, x: jnp.ndarray,
                cache: Optional[dict] = None, mode: str = "train",
                flags: Optional[dict] = None):
    """Mamba-1 selective SSM. cache = {"conv": (B,K-1,di), "h": (B,di,n)}.

    flags["mamba_fused"] (default True) fuses the C-projection into the
    chunk loop (see _selective_scan_fused); False keeps the naive
    materialized path (the paper-faithful §Perf baseline).
    """
    flags = flags or {}
    B, S, _ = x.shape
    di, n, dr = cfg.d_inner, cfg.ssm_state, cfg.dt_rank
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    xb, z = jnp.split(xz, 2, axis=-1)
    conv_state = cache["conv"] if cache is not None else None
    xb, conv_state = causal_conv1d(xb, p["conv_w"], p["conv_b"], conv_state)
    xb = jax.nn.silu(xb)
    proj = jnp.einsum("bse,ef->bsf", xb, p["x_proj"])
    dt_raw = proj[..., :dr]
    Bmat = proj[..., dr:dr + n].astype(jnp.float32)          # (B,S,n)
    Cmat = proj[..., dr + n:].astype(jnp.float32)
    dt = jax.nn.softplus(jnp.einsum("bsf,fe->bse", dt_raw, p["dt_proj"])
                         + p["dt_bias"]).astype(jnp.float32)  # (B,S,di)
    A = -jnp.exp(p["a_log"].astype(jnp.float32))              # (di,n)
    h0 = (cache["h"] if cache is not None
          else jnp.zeros((B, di, n), jnp.float32))
    if mode == "decode" and S == 1:
        a_1 = jnp.exp(dt[:, 0, :, None] * A)
        b_1 = (dt[:, 0, :, None] * Bmat[:, 0, None, :]
               * xb.astype(jnp.float32)[:, 0, :, None])
        h_last = a_1 * h0 + b_1
        y = jnp.einsum("bdn,bn->bd", h_last, Cmat[:, 0])[:, None]
    elif flags.get("mamba_kernel", False):
        # Pallas fused selective-scan kernel (kernels/selective_scan.py):
        # HBM traffic = inputs + outputs only (TPU target; interpret on CPU)
        from ..kernels import ops as kops
        y, h_last = kops.selective_scan(dt, xb.astype(jnp.float32), Bmat,
                                        Cmat, A, h0)
    elif flags.get("mamba_fused", True):
        y, h_last = _selective_scan_fused(dt, Bmat, xb, A, Cmat, h0,
                                          chunk=flags.get("scan_chunk", 128))
    else:
        a_seq = jnp.exp(dt[..., None] * A)                    # (B,S,di,n)
        b_seq = (dt[..., None] * Bmat[:, :, None, :]
                 * xb.astype(jnp.float32)[..., None])
        h_all, h_last = linear_scan_chunked(
            a_seq, b_seq, h0, chunk=flags.get("scan_chunk", 128))
        y = jnp.einsum("bsdn,bsn->bsd", h_all, Cmat)
    y = y.astype(x.dtype) + p["d_skip"] * xb
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    new_cache = {"conv": conv_state, "h": h_last}
    return out, new_cache


def init_mamba_cache(cfg: ModelConfig, batch: int, dtype) -> dict:
    return {"conv": jnp.zeros((batch, cfg.ssm_conv - 1, cfg.d_inner), dtype),
            "h": jnp.zeros((batch, cfg.d_inner, cfg.ssm_state), jnp.float32)}


# ================================================================== RG-LRU

def init_rglru(pb, p, a, cfg: ModelConfig):
    d, w, K = cfg.d_model, cfg.lru_dim, cfg.conv1d_width
    pb.param(p, a, "w_branch", (d, w), ("embed", "lru"))
    pb.param(p, a, "w_gate_branch", (d, w), ("embed", "lru"))
    pb.param(p, a, "conv_w", (K, w), ("conv", "lru"), scale=0.5)
    pb.param(p, a, "conv_b", (w,), ("lru",), init="zeros")
    pb.param(p, a, "w_a", (w, w), ("lru", "lru"), scale=0.02)
    pb.param(p, a, "b_a", (w,), ("lru",), init="zeros")
    pb.param(p, a, "w_i", (w, w), ("lru", "lru"), scale=0.02)
    pb.param(p, a, "b_i", (w,), ("lru",), init="zeros")
    pb.param(p, a, "lambda_p", (w,), ("lru",), init="lru_a")
    pb.param(p, a, "out_proj", (w, d), ("lru", "embed"))


def rglru_apply(cfg: ModelConfig, p: dict, x: jnp.ndarray,
                cache: Optional[dict] = None, mode: str = "train",
                flags: Optional[dict] = None):
    """Griffin recurrent block: conv1d + RG-LRU gated diagonal recurrence.

    cache = {"conv": (B,K-1,w), "h": (B,w)}.
    """
    flags = flags or {}
    B, S, _ = x.shape
    xb = jnp.einsum("bsd,dw->bsw", x, p["w_branch"])
    gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", x, p["w_gate_branch"]))
    conv_state = cache["conv"] if cache is not None else None
    xb, conv_state = causal_conv1d(xb, p["conv_w"], p["conv_b"], conv_state)
    r = jax.nn.sigmoid(jnp.einsum("bsw,wv->bsv", xb, p["w_a"]) + p["b_a"])
    i = jax.nn.sigmoid(jnp.einsum("bsw,wv->bsv", xb, p["w_i"]) + p["b_i"])
    c = 8.0
    log_a = (-c * jax.nn.softplus(p["lambda_p"].astype(jnp.float32))
             * r.astype(jnp.float32))
    a = jnp.exp(log_a)
    mult = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-9))
    b = mult * (i * xb).astype(jnp.float32)
    h0 = cache["h"] if cache is not None else jnp.zeros((B, cfg.lru_dim),
                                                        jnp.float32)
    if mode == "decode" and S == 1:
        h_last = a[:, 0] * h0 + b[:, 0]
        h_all = h_last[:, None]
    else:
        h_all, h_last = linear_scan_chunked(a, b, h0,
                                            chunk=flags.get("scan_chunk", 256))
    y = (h_all.astype(x.dtype) * gate)
    out = jnp.einsum("bsw,wd->bsd", y, p["out_proj"])
    return out, {"conv": conv_state, "h": h_last}


def init_rglru_cache(cfg: ModelConfig, batch: int, dtype) -> dict:
    return {"conv": jnp.zeros((batch, cfg.conv1d_width - 1, cfg.lru_dim), dtype),
            "h": jnp.zeros((batch, cfg.lru_dim), jnp.float32)}
