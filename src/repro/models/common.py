"""Shared model primitives: config, param builder with logical axes,
norms, RoPE, initializers.

Every parameter leaf is created through ``ParamBuilder`` which records a
tuple of *logical axis names* per dimension (MaxText-style). The launcher
maps logical names -> mesh axes (with divisibility fallbacks) to build
PartitionSpecs, so model code never mentions the mesh.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import numpy as np
import jax
import jax.numpy as jnp


# --------------------------------------------------------------- config

@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    qk_norm: bool = False
    # layer pattern, cycled over depth: entries in {"global","local","rglru","mamba"}
    layer_pattern: tuple = ("global",)
    window_size: int = 4096
    # MoE
    n_experts: int = 0
    n_experts_per_tok: int = 0
    moe_capacity_factor: float = 1.25
    # SSM (mamba-1)
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_dt_rank: int = 0            # 0 -> d_model // 16
    # RG-LRU (hybrid)
    lru_width: int = 0              # 0 -> d_model
    conv1d_width: int = 4
    # encoder-decoder (audio)
    encoder_layers: int = 0
    encoder_positions: int = 0      # stub frame embeddings length
    max_target_positions: int = 0   # decoder context limit (0 = unlimited)
    # VLM
    vision_prefix: int = 0          # stub patch embeddings prepended
    rope_theta: float = 1e4
    norm_eps: float = 1e-6
    dtype: Any = jnp.bfloat16
    # citation / provenance
    source: str = ""

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def dt_rank(self) -> int:
        return self.ssm_dt_rank if self.ssm_dt_rank else max(1, self.d_model // 16)

    @property
    def lru_dim(self) -> int:
        return self.lru_width if self.lru_width else self.d_model

    def kind(self, layer_idx: int) -> str:
        return self.layer_pattern[layer_idx % len(self.layer_pattern)]

    @property
    def is_decoder_only(self) -> bool:
        return self.encoder_layers == 0

    @property
    def supports_long_decode(self) -> bool:
        """True iff decode cost is sub-quadratic (window / recurrent)."""
        return all(k in ("local", "rglru", "mamba") for k in self.layer_pattern)

    def scaled_down(self) -> "ModelConfig":
        """Reduced variant for CPU smoke tests (<=2 groups, d<=256, <=4 experts)."""
        pat = self.layer_pattern
        n_layers = max(len(pat), 2)
        d = min(self.d_model, 128)
        heads = min(self.n_heads, 4)
        kv = min(self.n_kv_heads, heads)
        hd = d // heads
        return dataclasses.replace(
            self, n_layers=n_layers, d_model=d, n_heads=heads, n_kv_heads=kv,
            head_dim=hd, d_ff=min(self.d_ff, 256) or 0,
            vocab_size=min(self.vocab_size, 512),
            n_experts=min(self.n_experts, 4),
            n_experts_per_tok=min(self.n_experts_per_tok, 2),
            ssm_state=min(self.ssm_state, 8), ssm_dt_rank=8 if self.ssm_state else 0,
            lru_width=min(self.lru_dim, d) if self.lru_width else 0,
            window_size=min(self.window_size, 64),
            encoder_layers=min(self.encoder_layers, 2),
            encoder_positions=min(self.encoder_positions, 32),
            vision_prefix=min(self.vision_prefix, 8),
            dtype=jnp.float32, name=self.name + "-smoke")


# --------------------------------------------------- params with axes

class ParamBuilder:
    """Creates params and records logical axes per leaf (same tree shape)."""

    def __init__(self, key: jax.Array, dtype=jnp.float32):
        self._key = key
        self.dtype = dtype
        self.params: dict = {}
        self.axes: dict = {}

    def _next(self) -> jax.Array:
        self._key, sub = jax.random.split(self._key)
        return sub

    def param(self, tree: dict, axes_tree: dict, name: str, shape: tuple,
              axes: tuple, init: str = "normal", scale: Optional[float] = None):
        assert len(shape) == len(axes), (name, shape, axes)
        if init == "normal":
            s = float(scale if scale is not None else 1.0 / np.sqrt(shape[0]))
            v = (jax.random.normal(self._next(), shape, jnp.float32)
                 * s).astype(self.dtype)
        elif init == "zeros":
            v = jnp.zeros(shape, self.dtype)
        elif init == "ones":
            v = jnp.ones(shape, self.dtype)
        elif init == "ssm_a":
            # mamba A_log init: log(1..state) broadcast over channels
            n = shape[-1]
            a = jnp.tile(jnp.arange(1, n + 1, dtype=self.dtype), (shape[0], 1))
            v = jnp.log(a)
        elif init == "lru_a":
            # RG-LRU Lambda init so that a in (0.9, 0.999)
            u = jax.random.uniform(self._next(), shape, self.dtype, 0.9, 0.999)
            v = jnp.log(jnp.exp(-jnp.log(u) * 8.0) - 1.0)  # softplus^-1(-ln u * 8)/..
        else:
            raise ValueError(init)
        tree[name] = v
        axes_tree[name] = axes
        return v

    def scope(self, tree: dict, axes_tree: dict, name: str):
        sub_p, sub_a = {}, {}
        tree[name] = sub_p
        axes_tree[name] = sub_a
        return sub_p, sub_a


def stack_trees(trees: list):
    """Stack a list of identical pytrees along a new leading 'layers' axis."""
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *trees)


def stack_axes(axes_tree: dict):
    """Prepend the 'layers' logical axis to every leaf of an axes tree."""
    return jax.tree.map(lambda a: ("layers",) + tuple(a), axes_tree,
                        is_leaf=lambda x: isinstance(x, tuple))


# ------------------------------------------------------------ functional

def rms_norm(x: jnp.ndarray, weight: jnp.ndarray, eps: float) -> jnp.ndarray:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(dt) * weight


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Rotary embedding. x: (..., S, H, D); positions: (..., S)."""
    d_half = x.shape[-1] // 2
    freq = theta ** (-jnp.arange(0, d_half, dtype=jnp.float32) / d_half)
    ang = positions[..., :, None, None].astype(jnp.float32) * freq  # (...,S,1,Dh)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :d_half], x[..., d_half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def swiglu(x: jnp.ndarray, w_gate, w_up, w_down) -> jnp.ndarray:
    h = jax.nn.silu(x @ w_gate) * (x @ w_up)
    return h @ w_down
