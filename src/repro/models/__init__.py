from .common import ModelConfig
from .transformer import Transformer
from .api import (make_model, batch_spec, make_batch, loss_fn, prefill,
                  decode_step, effective_seq, param_count,
                  active_param_count)

__all__ = ["ModelConfig", "Transformer", "make_model", "batch_spec",
           "make_batch", "loss_fn", "prefill", "decode_step",
           "effective_seq", "param_count", "active_param_count"]
