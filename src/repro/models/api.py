"""Model-level API: inputs, loss, prefill and decode steps (pure functions).

A "batch" is a dict:
  decoder LM : {"tokens": (B, S) int32}
  vlm        : {"tokens": (B, S_text) int32, "patches": (B, P, d)}
  audio      : {"tokens": (B, S_dec) int32, "frames": (B, S_enc, d)}

``effective_seq(cfg, seq)`` clamps the requested sequence to the arch's
context limit (whisper decoder: 448).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .common import ModelConfig
from .transformer import Transformer

MOE_AUX_COEF = 0.01


def make_model(cfg: ModelConfig) -> Transformer:
    return Transformer(cfg)


def effective_seq(cfg: ModelConfig, seq: int) -> int:
    if cfg.max_target_positions:
        return min(seq, cfg.max_target_positions)
    return seq


def batch_spec(cfg: ModelConfig, batch: int, seq: int) -> dict:
    """ShapeDtypeStruct stand-ins for every model input (dry-run path)."""
    s = effective_seq(cfg, seq)
    spec = {}
    if cfg.arch_type == "vlm":
        text = max(s - cfg.vision_prefix, 1)
        spec["tokens"] = jax.ShapeDtypeStruct((batch, text), jnp.int32)
        spec["patches"] = jax.ShapeDtypeStruct(
            (batch, cfg.vision_prefix, cfg.d_model), cfg.dtype)
    elif cfg.arch_type == "audio":
        spec["tokens"] = jax.ShapeDtypeStruct((batch, s), jnp.int32)
        spec["frames"] = jax.ShapeDtypeStruct(
            (batch, cfg.encoder_positions, cfg.d_model), cfg.dtype)
    else:
        spec["tokens"] = jax.ShapeDtypeStruct((batch, s), jnp.int32)
    return spec


def make_batch(cfg: ModelConfig, batch: int, seq: int, key: jax.Array) -> dict:
    """Concrete random batch matching batch_spec (smoke tests/examples)."""
    spec = batch_spec(cfg, batch, seq)
    out = {}
    k1, k2 = jax.random.split(key)
    out["tokens"] = jax.random.randint(k1, spec["tokens"].shape, 0,
                                       cfg.vocab_size, jnp.int32)
    if "patches" in spec:
        out["patches"] = jax.random.normal(k2, spec["patches"].shape,
                                           spec["patches"].dtype)
    if "frames" in spec:
        out["frames"] = jax.random.normal(k2, spec["frames"].shape,
                                          spec["frames"].dtype)
    return out


def _embed_inputs(model: Transformer, params, batch: dict):
    """Returns (x (B,S,d), positions (B,S), loss_mask (B,S), memory|None)."""
    cfg = model.cfg
    tok_emb = params["embed"][batch["tokens"]]
    memory = None
    if cfg.arch_type == "vlm":
        x = jnp.concatenate([batch["patches"].astype(tok_emb.dtype), tok_emb],
                            axis=1)
        B, S = x.shape[0], x.shape[1]
        mask = jnp.concatenate(
            [jnp.zeros((B, cfg.vision_prefix), bool),
             jnp.ones((B, batch["tokens"].shape[1]), bool)], axis=1)
    elif cfg.arch_type == "audio":
        memory = model.encode(params, batch["frames"])
        x = tok_emb
        B, S = x.shape[0], x.shape[1]
        mask = jnp.ones((B, S), bool)
    else:
        x = tok_emb
        B, S = x.shape[0], x.shape[1]
        mask = jnp.ones((B, S), bool)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    return x, positions, mask, memory


def loss_fn(model: Transformer, params, batch: dict,
            flags: Optional[dict] = None):
    """Mean next-token CE (+ MoE aux). Returns (loss, metrics)."""
    cfg = model.cfg
    x, positions, mask, memory = _embed_inputs(model, params, batch)
    hidden, _, aux = model.forward(params, x, positions, mode="train",
                                   flags=flags, memory=memory)
    logits = model.logits(params, hidden)            # (B,S,V)
    # next-token prediction over text positions
    tgt_tok = batch["tokens"]
    n_prefix = logits.shape[1] - tgt_tok.shape[1]    # vision prefix length
    logits_txt = logits[:, n_prefix:, :]
    lp = jax.nn.log_softmax(logits_txt[:, :-1].astype(jnp.float32), axis=-1)
    tgt = tgt_tok[:, 1:]
    nll = -jnp.take_along_axis(lp, tgt[..., None], axis=-1)[..., 0]
    m = mask[:, n_prefix + 1:].astype(jnp.float32)
    ce = jnp.sum(nll * m) / jnp.maximum(jnp.sum(m), 1.0)
    loss = ce + MOE_AUX_COEF * aux
    return loss, {"ce": ce, "aux": aux}


def prefill(model: Transformer, params, batch: dict, cache_len: int,
            flags: Optional[dict] = None):
    """Process the prompt, build the KV/state cache, return last logits.

    Returns (logits_last (B,V), caches, memory).
    """
    cfg = model.cfg
    x, positions, _, memory = _embed_inputs(model, params, batch)
    B, S = x.shape[0], x.shape[1]
    caches = model.init_cache(B, cache_len, dtype=cfg.dtype)
    fl = dict(flags or {})
    fl["cache_len"] = cache_len
    hidden, caches, _ = model.forward(params, x, positions, mode="prefill",
                                      caches=caches, flags=fl, memory=memory)
    logits = model.logits(params, hidden[:, -1:, :])[:, 0]
    return logits, caches, memory


def decode_step(model: Transformer, params, token: jnp.ndarray,
                position: jnp.ndarray, caches, memory=None,
                flags: Optional[dict] = None):
    """One-token decode. token: (B,1) int32; position: (B,) absolute index.

    Returns (logits (B,V), new_caches).
    """
    cfg = model.cfg
    x = params["embed"][token]
    positions = position[:, None].astype(jnp.int32)
    hidden, caches, _ = model.forward(params, x, positions, mode="decode",
                                      caches=caches, flags=flags,
                                      memory=memory)
    logits = model.logits(params, hidden[:, 0:1, :])[:, 0]
    return logits, caches


def param_count(params) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(params))


def active_param_count(cfg: ModelConfig, params) -> int:
    """Active params per token (MoE: only top-k routed experts count)."""
    total = param_count(params)
    if cfg.n_experts == 0:
        return total
    expert_elems = 0
    for x in jax.tree.leaves(params):
        # routed expert weights: (..., E, d, f) — expert dim is axis -3
        if x.ndim >= 3 and x.shape[-3] == cfg.n_experts:
            expert_elems += int(x.size)
    return int(total - expert_elems
               + expert_elems * cfg.n_experts_per_tok / cfg.n_experts)
