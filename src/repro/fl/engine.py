"""JAX-native vectorized FL simulation engine.

The NumPy trainer (``fl/trainer.py`` + ``core/baselines.py``) runs the
paper's Monte-Carlo protocol with Python-level ``for trial / for t`` loops —
the reference oracle, but slow. This engine runs the same (trials, rounds)
recursion of eq. (2)/(13) as ``vmap(lax.scan)`` over a *functional*
aggregator protocol, with the PS epilogue (post-scale + AWGN, eq. (6))
dispatched through the fused Pallas kernel ``kernels/ota_combine.py`` and
the digital payload compressor through ``kernels/dithered_quant.py``
(interpret mode on CPU, Mosaic on TPU).

RNG contract — the engine *replays the NumPy trainer's random streams*:

  * fading: ``channel.sample_fading_batch`` reproduces
    ``FadingProcess(dep, seed*1000 + trial).sample(t)`` bit-for-bit;
  * PS AWGN: every OTA aggregator draws exactly one ``normal(d)`` per round
    from ``default_rng((seed, trial, 17))``, so one ``standard_normal((T, d))``
    block per trial replays the stream;
  * dither: digital aggregators consume one ``uniform(d)`` per *participating*
    device per round, in device order; participation is a deterministic
    function of the precomputed fading, so the stream is replayed offline.

Model state is carried in float64 (via the scoped x64 context) while local
gradients/losses are computed in float32 — exactly the NumPy trainer's mixed
precision — so the two backends agree per round to ~1e-5 over hundreds of
rounds. ``tests/test_engine_parity.py`` pins this.

Caveats: dither replay assumes participating gradients are nonzero
(``quantize_np`` skips its dither draw on an exactly-zero gradient, which is
measure-zero for the paper's tasks); and digital schemes materialize the
full (trials, T, N, d) dither tensor up front — O(trials*T*N*d*8) bytes —
so very long digital horizons belong on the NumPy backend until the replay
is chunked per eval segment (see ROADMAP).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import numpy as np
import jax
import jax.numpy as jnp
from jax.experimental import enable_x64

from ..core import baselines as B
from ..core.channel import Deployment, sample_fading_batch
from ..core.digital import digital_round_jax
from ..core.ota import ota_round_jax
from ..kernels import ops
from .trainer import TrainLog

#: AggregatorFn protocol: (grads (N,d) f64, h (N,) complex, z01 (d,) f64,
#: u (N,d) f64, t i64) -> (ghat (d,), latency scalar). Latency is in channel
#: uses for OTA schemes (converted to seconds by the engine via 1/B) and in
#: seconds for digital schemes, matching ``core.baselines.RoundResult``.
AggregatorFn = Callable[..., tuple]


@dataclasses.dataclass(eq=False)
class JaxAggregator:
    """A wireless aggregation scheme in functional form.

    ``round_fn`` must be pure and jit/vmap/scan-able; scheme constants
    (pre-scalers, thresholds, post-scalers) are baked in as closure
    constants, mirroring the paper's offline-designed, time-invariant
    parameters.
    """

    name: str
    is_ota: bool
    round_fn: AggregatorFn
    needs_noise: bool = True
    needs_dither: bool = False
    # habs (T, N) -> bool (T, N): which (round, device) slots consume a
    # dither draw in the NumPy reference (only used when needs_dither)
    dither_mask_np: Optional[Callable[[np.ndarray], np.ndarray]] = None
    # jitted trial runners keyed on (task id, shapes, schedule); kept on the
    # aggregator so step-size grid searches across trainer instances reuse
    # the compiled scan
    _runner_cache: dict = dataclasses.field(default_factory=dict, repr=False)


# ------------------------------------------------------- functional ports

def _ideal_fedavg() -> JaxAggregator:
    def round_fn(grads, h, z01, u, t):
        return jnp.mean(grads, axis=0), 0.0

    return JaxAggregator(name=B.IdealFedAvg.name, is_ota=True,
                         round_fn=round_fn, needs_noise=False)


def _from_ota_params(params, name: str, use_kernel: bool) -> JaxAggregator:
    def round_fn(grads, h, z01, u, t):
        ghat, _ = ota_round_jax(params, grads, h, z01, use_kernel=use_kernel)
        return ghat, float(params.dim)

    return JaxAggregator(name=name, is_ota=True, round_fn=round_fn)


def _vanilla_ota(agg: "B.VanillaOTA", use_kernel: bool) -> JaxAggregator:
    dim, g_max, e_s, n0 = agg.dim, agg.g_max, agg.e_s, agg.n0
    root_des = np.sqrt(dim * e_s)
    root_n0 = np.sqrt(n0)

    def round_fn(grads, h, z01, u, t):
        n = grads.shape[0]
        gamma_t = root_des * jnp.min(jnp.abs(h)) / g_max
        acc = gamma_t * jnp.sum(grads, axis=0)
        ghat = ops.ota_combine_with_noise(acc, n * gamma_t, root_n0 * z01,
                                          use_kernel=use_kernel)
        return ghat, float(dim)

    return JaxAggregator(name=agg.name, is_ota=True, round_fn=round_fn)


def _opc_ota_comp(agg: "B.OPCOTAComp", use_kernel: bool) -> JaxAggregator:
    dim, g_max, e_s, n0 = agg.dim, agg.g_max, agg.e_s, agg.n0
    n_grid = agg.n_grid
    b_bar = np.sqrt(dim * e_s) / g_max
    root_n0 = np.sqrt(n0)

    def round_fn(grads, h, z01, u, t):
        habs = jnp.abs(h)
        n = grads.shape[0]
        lo = jnp.maximum((b_bar * jnp.min(habs)) ** 2 * 1e-4, 1e-300)
        hi = (b_bar * jnp.max(habs)) ** 2 * 1e4
        etas = jnp.geomspace(lo, hi, n_grid)                       # (n_grid,)
        b = jnp.minimum(b_bar, jnp.sqrt(etas)[:, None] / habs)     # (n_grid,N)
        c = b * habs / jnp.sqrt(etas)[:, None]
        mses = (g_max ** 2 * jnp.sum((c - 1.0) ** 2, axis=1) / n ** 2
                + dim * n0 / (n ** 2 * etas))
        eta = etas[jnp.argmin(mses)]
        b_t = jnp.minimum(b_bar, jnp.sqrt(eta) / habs)
        acc = (b_t * habs) @ grads
        ghat = ops.ota_combine_with_noise(acc, n * jnp.sqrt(eta),
                                          root_n0 * z01,
                                          use_kernel=use_kernel)
        return ghat, float(dim)

    return JaxAggregator(name=agg.name, is_ota=True, round_fn=round_fn)


def _proposed_digital(params, name: str, use_kernel: bool) -> JaxAggregator:
    rhos = np.asarray(params.rhos)

    def round_fn(grads, h, z01, u, t):
        ghat, _, latency = digital_round_jax(params, grads, h, u,
                                             use_kernel=use_kernel)
        return ghat, latency

    return JaxAggregator(name=name, is_ota=False, round_fn=round_fn,
                         needs_noise=False, needs_dither=True,
                         dither_mask_np=lambda habs: habs >= rhos[None, :])


def as_functional(agg, use_kernel: bool = True) -> Optional[JaxAggregator]:
    """Functional port of a NumPy ``Aggregator`` instance, or None when the
    scheme has no JAX port yet (the trainer then falls back to NumPy).

    Ports are memoized on the aggregator instance so repeated runs (e.g.
    the benchmarks' step-size grid search) share compiled scans.
    """
    if isinstance(agg, JaxAggregator):
        return agg
    cache = agg.__dict__.setdefault("_jax_ports", {})
    if use_kernel in cache:
        return cache[use_kernel]
    port = None
    if isinstance(agg, B.IdealFedAvg):
        port = _ideal_fedavg()
    elif isinstance(agg, (B.ProposedOTA, B.LCPCOTAComp)):
        port = _from_ota_params(agg.params, agg.name, use_kernel)
    elif isinstance(agg, B.VanillaOTA):
        port = _vanilla_ota(agg, use_kernel)
    elif isinstance(agg, B.OPCOTAComp):
        port = _opc_ota_comp(agg, use_kernel)
    elif isinstance(agg, B.ProposedDigital):
        port = _proposed_digital(agg.params, agg.name, use_kernel)
    cache[use_kernel] = port
    return port


# ----------------------------------------------------------------- engine

def _project(w, radius):
    nrm = jnp.linalg.norm(w)
    scale = jnp.minimum(1.0, radius / jnp.maximum(nrm, 1e-300))
    return w * scale


class FLEngine:
    """vmap(lax.scan) Monte-Carlo FL simulator (same protocol as FLTrainer).

    One jitted call runs all trials of all rounds: fading/noise/dither come
    in as batched (trials, T, ...) tensors, rounds advance under a two-level
    ``lax.scan`` (outer: eval segments, inner: rounds) so only the model
    states at eval points are materialized, and trials are batched with
    ``vmap`` — including through the Pallas epilogue kernels.
    """

    def __init__(self, task, dataset, deployment: Deployment, eta: float, *,
                 project_radius: Optional[float] = None,
                 use_kernel: bool = True):
        self.task = task
        self.ds = dataset
        self.dep = deployment
        self.eta = eta
        self.project_radius = project_radius
        self.use_kernel = use_kernel
        self.xs = np.stack([d.x for d in dataset.devices]).astype(np.float32)
        self.ys = np.stack([d.y for d in dataset.devices]).astype(np.int32)
        self.x_all = np.concatenate(
            [d.x for d in dataset.devices]).astype(np.float32)
        self.y_all = np.concatenate(
            [d.y for d in dataset.devices]).astype(np.int32)
        self.x_test = np.asarray(dataset.x_test, np.float32)
        self.y_test = np.asarray(dataset.y_test, np.int32)
        # built once so repeated run() calls hit the jit cache
        self._loss_v = jax.jit(jax.vmap(task.loss_fn, in_axes=(0, None, None)))
        self._acc_v = jax.jit(jax.vmap(task.accuracy_fn,
                                       in_axes=(0, None, None)))

    # ------------------------------------------------ randomness replay

    def _dither_block(self, jagg: JaxAggregator, habs: np.ndarray,
                      seed: int, trial: int, d: int) -> np.ndarray:
        """(T, N, d) dither uniforms replaying the trainer's stream: one
        uniform(d) per participating device per round, in (t, m) order."""
        T, N = habs.shape
        mask = jagg.dither_mask_np(habs)
        rng = np.random.default_rng((seed, trial, 17))
        u = np.zeros((T, N, d))
        for t in range(T):
            for m in range(N):
                if mask[t, m]:
                    u[t, m] = rng.uniform(size=d)
        return u

    # ------------------------------------------------------- scan runner

    def _get_runner(self, jagg: JaxAggregator, trials: int, n_seg: int,
                    eval_every: int):
        d, N = self.task.dim, self.dep.n_devices
        # the task object itself keys (and pins) the gradient function;
        # everything else closed over by trial_fn is shape-static, and all
        # run-varying scalars (eta, radius, lat_scale) are traced arguments
        key = (self.task, trials, n_seg, eval_every, d, N,
               self.xs.shape, self.use_kernel)
        if key in jagg._runner_cache:
            return jagg._runner_cache[key]

        grads_fn = self.task.device_grads_fn
        round_fn = jagg.round_fn

        def trial_fn(w0, eta, radius, lat_scale, xs, ys, H, Z, U, Ts):
            # H: (n_seg, eval_every, N) complex; Z: (n_seg, eval_every, dz);
            # U: (n_seg, eval_every, Nu, du); Ts: (n_seg, eval_every)
            def step(carry, inp):
                w, t_wall = carry
                h, z, u, t = inp
                g = grads_fn(w.astype(jnp.float32), xs, ys
                             ).astype(jnp.float64)
                ghat, lat = round_fn(g, h, z, u, t)
                w_new = _project(w - eta * ghat, radius)
                return (w_new, t_wall + lat * lat_scale), None

            def segment(carry, seg_inp):
                out, _ = jax.lax.scan(step, carry, seg_inp)
                return out, out

            carry0 = (w0, jnp.zeros((), jnp.float64))
            _, (ws, walls) = jax.lax.scan(segment, carry0, (H, Z, U, Ts))
            ws = jnp.concatenate([w0[None], ws], axis=0)          # (E, d)
            walls = jnp.concatenate([jnp.zeros((1,)), walls], axis=0)
            return ws, walls

        runner = jax.jit(jax.vmap(
            trial_fn,
            in_axes=(None, None, None, None, None, None, 0, 0, 0, None)))
        jagg._runner_cache[key] = runner
        return runner

    # --------------------------------------------------------------- run

    def run(self, aggregator, *, rounds: int, trials: int = 3,
            eval_every: int = 10, seed: int = 0,
            w_star: Optional[np.ndarray] = None) -> TrainLog:
        jagg = as_functional(aggregator, use_kernel=self.use_kernel)
        if jagg is None:
            raise ValueError(
                f"no JAX port for {type(aggregator).__name__}; "
                "use FLTrainer.run(..., backend='numpy')")
        eval_rounds = list(range(0, rounds + 1, eval_every))
        n_seg = len(eval_rounds) - 1
        T = n_seg * eval_every      # rounds past the last eval are unobserved
        d, N = self.task.dim, self.dep.n_devices

        H = np.stack([sample_fading_batch(self.dep.lambdas,
                                          seed * 1000 + tr, T)
                      for tr in range(trials)])               # (trials, T, N)
        if jagg.needs_noise:
            Z = np.stack([np.random.default_rng((seed, tr, 17))
                          .standard_normal((T, d)) for tr in range(trials)])
        else:
            Z = np.zeros((trials, T, 1))
        if jagg.needs_dither:
            U = np.stack([self._dither_block(jagg, np.abs(H[tr]), seed, tr, d)
                          for tr in range(trials)])
        else:
            U = np.zeros((trials, T, 1, 1))

        with enable_x64():
            runner = self._get_runner(jagg, trials, n_seg, eval_every)
            w0 = jnp.asarray(self.task.init_params(), jnp.float64)
            eta = jnp.asarray(self.eta, jnp.float64)
            radius = jnp.asarray(
                np.inf if self.project_radius is None else self.project_radius,
                jnp.float64)
            lat_scale = jnp.asarray(
                1.0 / self.dep.cfg.bandwidth_hz if jagg.is_ota else 1.0,
                jnp.float64)
            seg = lambda a: jnp.asarray(a).reshape(
                (trials, n_seg, eval_every) + a.shape[2:])
            Ts = jnp.arange(T).reshape(n_seg, eval_every)
            ws, walls = runner(w0, eta, radius, lat_scale,
                               jnp.asarray(self.xs), jnp.asarray(self.ys),
                               seg(H), seg(Z), seg(U), Ts)
            losses, accs = self._evaluate(ws)
            opt_err = (np.sum((np.asarray(ws) - w_star) ** 2, axis=-1)
                       if w_star is not None else None)
        return TrainLog(scheme=jagg.name,
                        rounds=np.asarray(eval_rounds, dtype=np.int64),
                        wall_time_s=np.asarray(walls).mean(axis=0),
                        global_loss=np.asarray(losses, np.float64),
                        accuracy=np.asarray(accs, np.float64),
                        opt_error=opt_err)

    def _evaluate(self, ws):
        """Global loss + test accuracy at every eval point, vmapped over
        (trials * E) model states in the trainer's float32 eval precision."""
        trials, E, d = ws.shape
        wf = ws.reshape(trials * E, d).astype(jnp.float32)
        losses = self._loss_v(wf, self.x_all, self.y_all)
        accs = self._acc_v(wf, self.x_test, self.y_test)
        return (np.asarray(losses).reshape(trials, E),
                np.asarray(accs).reshape(trials, E))
