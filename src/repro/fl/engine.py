"""JAX-native vectorized FL simulation engine.

The NumPy trainer (``fl/trainer.py`` + ``core/baselines.py``) runs the
paper's Monte-Carlo protocol with Python-level ``for trial / for t`` loops —
the reference oracle, but slow. This engine runs the same (trials, rounds)
recursion of eq. (2)/(13) as ``vmap(lax.scan)`` over a *functional*
aggregator protocol, with the PS epilogue (post-scale + AWGN, eq. (6))
dispatched through the fused Pallas kernel ``kernels/ota_combine.py``, the
digital payload compressor through ``kernels/dithered_quant.py``, and the
per-device gradient scoring (norm/quantization-MSE selection) through
``kernels/row_reduce.py`` (interpret mode on CPU, Mosaic on TPU). Every
scheme in ``core.baselines`` has a port registered in ``_PORT_FACTORIES``,
so ``backend="jax"`` covers the paper's full Sec. V comparison suite.

Two RNG execution modes (``run(..., rng=...)``):

  * ``rng="replay"`` (default) — bit-reproduces the NumPy oracle's random
    streams (contract below), at the cost of O(T*(d+S)) host-side NumPy
    precompute per trial (AWGN blocks, fading stacks, selection replays)
    before the jitted scan starts;
  * ``rng="fast"`` — every stream (AWGN, fading, dither, selection, batch
    indices) is generated counter-based *inside* the scan, threefry-keyed
    on ``(seed, trial, round, stream)`` (``core.rngstream`` tags), with
    zero host-side per-trial precompute and O(N*d) live memory. Same
    distributions, different stream: statistically equivalent to replay
    (mean trajectories match within MC tolerance,
    ``tests/test_rng_fast.py``), not bit-equal — the mode for
    population-scale N / trial counts where the replay tax dominates.

RNG-replay contract — the engine reproduces the NumPy trainer's random
streams, so the two backends agree per round to ~1e-5 over hundreds of
rounds (``tests/test_engine_parity.py``):

  * fading: ``channel.sample_fading_batch`` reproduces
    ``FadingProcess(dep, seed*1000 + trial).sample(t)`` bit-for-bit;
  * PS AWGN: every OTA aggregator draws exactly one ``normal(d)`` per round
    from the sequential trial rng ``default_rng((seed, trial, 17))``, so one
    ``standard_normal((T, d))`` block per trial replays the stream;
  * quantization dither is *counter-based* (``core.rngstream``): the (N, d)
    uniform block of round ``t`` is a pure threefry function of
    ``(seed, trial, t)``, generated eagerly by the oracle and regenerated
    inside the scan from a scan-carried per-trial key — O(N*d) live memory
    per round, no materialized (trials, T, N, d) tensor, which is what makes
    1500-round digital horizons feasible;
  * device-selection draws (UQOS' sampling permutation/keys, QML's and
    FedTOE's ``rng.choice``) stay on the sequential trial rng; each port's
    ``sel_stream_np`` replays them offline into a small (T, S) array that
    rides into the scan alongside the fading;
  * mini-batch indices are counter-based like the dither
    (``rngstream.batch_block``, threefry keyed on seed/trial/round/device):
    the engine regenerates each round's (N, B) index block from a
    scan-carried key and gathers the batches through the task's
    ``device_grads_at_fn`` — the exact compiled program the NumPy trainer
    calls on the same indices, so stochastic gradients are bit-identical.

Fault injection (``core.faults.FaultSpec``) runs in-scan too: one (3, N)
counter-based uniform block per round (FAULT_TAG — bit-identical across
both rng modes and both backends) drives dropout/erasure/straggler masks,
deep fades evaluate through ``digital.outage_mask``, and the
``on_missing`` degradation policy (reweight/zero/stale) transforms the
gradient payloads *before* the scheme's ``round_fn`` so every registered
port inherits it; "stale" carries the last received (N, d) gradients in
the scan carry. With faults disabled the scan traces the exact pre-fault
program — disabled-fault runs are bit-identical to a fault-free build.

Partial participation (``core.participation``) runs in-scan the same way:
one (N,) counter-based uniform block per round (PARTICIPATE_TAG —
bit-identical across both rng modes and both backends) draws the Bernoulli
cohort ``chi_m = u_m < pi_m``; excluded payloads zero out and included
ones carry the uniform inverse-propensity scale N/S, upstream of the
fault layer and every scheme's combiner. ``clients_per_round=None``
traces the exact pre-participation program (bit-identical runs).

Buffered-async mode (``core.async_fl``, ``mode="async"``) runs in-scan as
well: the scan carries a (K, N, d) last-K gradient buffer, one (2, N)
counter-based uniform block per round (ARRIVAL_TAG — bit-identical across
both rng modes and both backends) draws each device's delivery event and
staleness against precomputed float64 rate/CDF tables, and the delivered
payload ``delta^S * v_m * (N/sum(cv)) * g_m(w_{t-S})`` replaces the fresh
gradient upstream of the fault layer and every scheme's combiner
(missing devices zero-fill or replay their last delivered payload through
``async_fl.stale_replace`` — the same code path as
``fault.on_missing="stale"``). ``mode="sync"`` (default) traces the exact
pre-async program (bit-identical runs).

Time budgets run in-scan: cumulative wall-clock rides in the scan carry,
every round is masked by ``t_wall < budget`` (``jnp.where``), and each eval
segment reports the last *live* model state — replicating the trainer's
freeze-at-last-written-eval semantics exactly, including the wall-clock
pinned at the budget-exhaustion time (``tests/test_trainer_budget.py``).

Model state is carried in float64 (via the scoped x64 context) while local
gradients/losses are computed in float32 — exactly the NumPy trainer's mixed
precision. Caveat: dither replay assumes participating gradients are nonzero
(``quantize_np`` skips its quantization on an exactly-zero gradient, which
is measure-zero for the paper's tasks).

Multi-host scaling: ``FLEngine(..., shard_trials=True)`` lays the
(embarrassingly parallel) trials axis over all visible devices with
``shard_map`` — a flag, not a rewrite; trials must divide the device count.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import numpy as np
import jax
import jax.numpy as jnp
from jax.experimental import enable_x64

from ..core import async_fl
from ..core import baselines as B
from ..core import participation as participation_lib
from ..core import rngstream
from ..core.channel import Deployment, sample_fading_batch, sample_fading_jax
from ..core.digital import (capacity_rate_jnp, digital_round_jax,
                            greedy_bit_alloc_jax, outage_mask, topk_mask)
from ..core.faults import FaultSpec, fault_masks, survival_prob
from ..core.ota import bbfl_round_jax, opc_ota_fl_round_jax, ota_round_jax
from ..core.quantize import payload_bits
from ..kernels import ops
from .trainer import TrainLog

#: AggregatorFn protocol: (grads (N,d) f64, h (N,) complex, z01 (d,) f64,
#: u (N,d) f32 dither, sel (S,) f64 replayed selection draws, t i64) ->
#: (ghat (d,), latency scalar). Latency is in channel uses for OTA schemes
#: (converted to seconds by the engine via 1/B) and in seconds for digital
#: schemes, matching ``core.baselines.RoundResult``. ``t`` carries the round
#: index for parity-scheduled schemes (BB-FL Alternative's ``t % 2``).
AggregatorFn = Callable[..., tuple]


@dataclasses.dataclass(eq=False)
class JaxAggregator:
    """A wireless aggregation scheme in functional form.

    ``round_fn`` must be pure and jit/vmap/scan-able; scheme constants
    (pre-scalers, thresholds, post-scalers) are baked in as closure
    constants, mirroring the paper's offline-designed, time-invariant
    parameters.
    """

    name: str
    is_ota: bool
    round_fn: AggregatorFn
    needs_noise: bool = True
    needs_dither: bool = False
    # (seed, trial, T) -> (T, S) float64 replay of the per-round selection
    # draws the NumPy scheme consumes from the sequential trial rng (see
    # core.rngstream.replay_rounds); None when the scheme draws none
    sel_stream_np: Optional[Callable[[int, int, int], np.ndarray]] = None
    # fast-mode analog of sel_stream_np: (round-folded threefry key) ->
    # (S,) float64 row with the exact layout ``round_fn`` consumes, drawn
    # in-scan from the SELECT_TAG stream. None when the scheme draws no
    # selection randomness; a scheme with sel_stream_np but no fast
    # sampler rejects rng="fast" instead of silently diverging
    sel_stream_jax: Optional[Callable] = None
    # jitted trial runners keyed on (task id, shapes, schedule); kept on the
    # aggregator so step-size grid searches across trainer instances reuse
    # the compiled scan
    _runner_cache: dict = dataclasses.field(default_factory=dict, repr=False)


# ------------------------------------------------------------ port registry

#: Routing table: NumPy Aggregator type -> functional port factory. The
#: trainer's backend="auto" consults this (via ``as_functional``) instead of
#: a hard-coded fallback list; registering a port here is all it takes to
#: route a new scheme through the engine.
_PORT_FACTORIES: dict = {}


def register_port(cls):
    def deco(factory):
        _PORT_FACTORIES[cls] = factory
        return factory
    return deco


# ------------------------------------------------------- OTA scheme ports

@register_port(B.IdealFedAvg)
def _ideal_fedavg(agg, use_kernel: bool) -> JaxAggregator:
    def round_fn(grads, h, z01, u, sel, t):
        return jnp.mean(grads, axis=0), 0.0

    return JaxAggregator(name=agg.name, is_ota=True,
                         round_fn=round_fn, needs_noise=False)


def _from_ota_params(agg, use_kernel: bool) -> JaxAggregator:
    params = agg.params

    def round_fn(grads, h, z01, u, sel, t):
        ghat, _ = ota_round_jax(params, grads, h, z01, use_kernel=use_kernel)
        return ghat, float(params.dim)

    return JaxAggregator(name=agg.name, is_ota=True, round_fn=round_fn)


register_port(B.ProposedOTA)(_from_ota_params)
register_port(B.LCPCOTAComp)(_from_ota_params)


@register_port(B.VanillaOTA)
def _vanilla_ota(agg: "B.VanillaOTA", use_kernel: bool) -> JaxAggregator:
    dim, g_max, e_s, n0 = agg.dim, agg.g_max, agg.e_s, agg.n0
    root_des = np.sqrt(dim * e_s)
    root_n0 = np.sqrt(n0)

    def round_fn(grads, h, z01, u, sel, t):
        n = grads.shape[0]
        gamma_t = root_des * jnp.min(jnp.abs(h)) / g_max
        acc = gamma_t * jnp.sum(grads, axis=0)
        ghat = ops.ota_combine_with_noise(acc, n * gamma_t, root_n0 * z01,
                                          use_kernel=use_kernel)
        return ghat, float(dim)

    return JaxAggregator(name=agg.name, is_ota=True, round_fn=round_fn)


@register_port(B.OPCOTAComp)
def _opc_ota_comp(agg: "B.OPCOTAComp", use_kernel: bool) -> JaxAggregator:
    dim, g_max, e_s, n0 = agg.dim, agg.g_max, agg.e_s, agg.n0
    n_grid = agg.n_grid
    b_bar = np.sqrt(dim * e_s) / g_max
    root_n0 = np.sqrt(n0)

    def round_fn(grads, h, z01, u, sel, t):
        habs = jnp.abs(h)
        n = grads.shape[0]
        lo = jnp.maximum((b_bar * jnp.min(habs)) ** 2 * 1e-4, 1e-300)
        hi = (b_bar * jnp.max(habs)) ** 2 * 1e4
        etas = jnp.geomspace(lo, hi, n_grid)                       # (n_grid,)
        b = jnp.minimum(b_bar, jnp.sqrt(etas)[:, None] / habs)     # (n_grid,N)
        c = b * habs / jnp.sqrt(etas)[:, None]
        mses = (g_max ** 2 * jnp.sum((c - 1.0) ** 2, axis=1) / n ** 2
                + dim * n0 / (n ** 2 * etas))
        eta = etas[jnp.argmin(mses)]
        b_t = jnp.minimum(b_bar, jnp.sqrt(eta) / habs)
        acc = (b_t * habs) @ grads
        ghat = ops.ota_combine_with_noise(acc, n * jnp.sqrt(eta),
                                          root_n0 * z01,
                                          use_kernel=use_kernel)
        return ghat, float(dim)

    return JaxAggregator(name=agg.name, is_ota=True, round_fn=round_fn)


@register_port(B.OPCOTAFL)
def _opc_ota_fl(agg: "B.OPCOTAFL", use_kernel: bool) -> JaxAggregator:
    dim, g_max, e_s, n0 = agg.dim, agg.g_max, agg.e_s, agg.n0

    def round_fn(grads, h, z01, u, sel, t):
        ghat, _ = opc_ota_fl_round_jax(grads, h, z01, dim=dim, g_max=g_max,
                                       e_s=e_s, n0=n0, use_kernel=use_kernel)
        return ghat, float(dim)

    return JaxAggregator(name=agg.name, is_ota=True, round_fn=round_fn)


@register_port(B.BBFLInterior)
def _bbfl_interior(agg: "B.BBFLInterior", use_kernel: bool) -> JaxAggregator:
    interior = np.asarray(agg.interior, dtype=np.float64)
    dim, g_max, e_s, n0 = agg.dim, agg.g_max, agg.e_s, agg.n0

    def round_fn(grads, h, z01, u, sel, t):
        ghat, _ = bbfl_round_jax(grads, h, z01, t, dim=dim, g_max=g_max,
                                 e_s=e_s, n0=n0,
                                 gamma_odd=agg.gamma, mask_odd=interior,
                                 gamma_even=agg.gamma, mask_even=interior,
                                 use_kernel=use_kernel)
        return ghat, float(dim)

    return JaxAggregator(name=agg.name, is_ota=True, round_fn=round_fn)


@register_port(B.BBFLAlternative)
def _bbfl_alternative(agg: "B.BBFLAlternative",
                      use_kernel: bool) -> JaxAggregator:
    interior = np.asarray(agg.interior_agg.interior, dtype=np.float64)
    all_mask = np.asarray(agg.all_mask, dtype=np.float64)
    dim, g_max, e_s, n0 = agg.dim, agg.g_max, agg.e_s, agg.n0

    def round_fn(grads, h, z01, u, sel, t):
        ghat, _ = bbfl_round_jax(
            grads, h, z01, t, dim=dim, g_max=g_max, e_s=e_s, n0=n0,
            gamma_odd=agg.interior_agg.gamma, mask_odd=interior,
            gamma_even=agg.gamma_all, mask_even=all_mask,
            use_kernel=use_kernel)
        return ghat, float(dim)

    return JaxAggregator(name=agg.name, is_ota=True, round_fn=round_fn)


# --------------------------------------------------- digital scheme ports

@register_port(B.ProposedDigital)
def _proposed_digital(agg, use_kernel: bool) -> JaxAggregator:
    params = agg.params

    def round_fn(grads, h, z01, u, sel, t):
        ghat, _, latency = digital_round_jax(params, grads, h, u,
                                             use_kernel=use_kernel)
        return ghat, latency

    return JaxAggregator(name=agg.name, is_ota=False, round_fn=round_fn,
                         needs_noise=False, needs_dither=True)


def _quantized_mean(grads, chi, bits, u, k, use_kernel, r_max=None):
    """sum_{m in sel} dequant(quant(g_m, r_m)) / k and the payload levels.

    ``r_max``: the scheme's static upper bound on any device's bit-width —
    lets the payload-scale fused pack path (quantize straight into a
    uint32 code buffer, O(d) dequant-accumulate) kick in at large d.
    """
    levels = chi * (jnp.exp2(bits) - 1.0)
    return ops.quantized_weighted_sum(grads, levels, u, chi / k,
                                      r_max=r_max, use_kernel=use_kernel)


@register_port(B.BestChannel)
def _best_channel(agg: "B.BestChannel", use_kernel: bool) -> JaxAggregator:
    dim, e_s, n0, bw = agg.dim, agg.e_s, agg.n0, agg.B
    k, r = agg.k, agg.r
    payload = float(payload_bits(dim, r))

    def round_fn(grads, h, z01, u, sel, t):
        habs = jnp.abs(h)
        chi = topk_mask(habs, k).astype(grads.dtype)
        rate = capacity_rate_jnp(habs, e_s, n0)
        lat = jnp.sum(chi * payload / (bw * jnp.maximum(rate, 1e-9)))
        acc = _quantized_mean(grads, chi, chi * r, u, k, use_kernel,
                              r_max=r)
        return acc, lat

    return JaxAggregator(name=agg.name, is_ota=False, round_fn=round_fn,
                         needs_noise=False, needs_dither=True)


@register_port(B.BestChannelNorm)
def _best_channel_norm(agg: "B.BestChannelNorm",
                       use_kernel: bool) -> JaxAggregator:
    dim, e_s, n0, bw = agg.dim, agg.e_s, agg.n0, agg.B
    k, kp, r_total = agg.k, agg.kp, agg.r_total

    def round_fn(grads, h, z01, u, sel, t):
        habs = jnp.abs(h)
        cand = topk_mask(habs, kp)
        # per-device scoring through the fused Pallas row reduction
        _, sumsq = ops.row_maxabs_sumsq(grads, use_kernel=use_kernel)
        norms = jnp.sqrt(sumsq)
        chi = topk_mask(jnp.where(cand > 0, norms, -jnp.inf), k
                        ).astype(grads.dtype)
        share = (chi * norms) / jnp.maximum(jnp.sum(chi * norms), 1e-12)
        bits = chi * jnp.maximum(1.0, jnp.round(r_total * share))
        rate = capacity_rate_jnp(habs, e_s, n0)
        lat = jnp.sum(chi * (64.0 + dim * bits)
                      / (bw * jnp.maximum(rate, 1e-9)))
        acc = _quantized_mean(grads, chi, bits, u, k, use_kernel,
                              r_max=r_total)
        return acc, lat

    return JaxAggregator(name=agg.name, is_ota=False, round_fn=round_fn,
                         needs_noise=False, needs_dither=True)


@register_port(B.PropFairness)
def _prop_fairness(agg: "B.PropFairness", use_kernel: bool) -> JaxAggregator:
    dim, e_s, n0, bw = agg.dim, agg.e_s, agg.n0, agg.B
    k, r = agg.k, agg.r
    lambdas = np.asarray(agg.dep.lambdas)
    payload = float(payload_bits(dim, r))

    def round_fn(grads, h, z01, u, sel, t):
        habs = jnp.abs(h)
        chi = topk_mask(habs ** 2 / lambdas, k).astype(grads.dtype)
        rate = capacity_rate_jnp(habs, e_s, n0)
        lat = jnp.sum(chi * payload / (bw * jnp.maximum(rate, 1e-9)))
        acc = _quantized_mean(grads, chi, chi * r, u, k, use_kernel,
                              r_max=r)
        return acc, lat

    return JaxAggregator(name=agg.name, is_ota=False, round_fn=round_fn,
                         needs_noise=False, needs_dither=True)


@register_port(B.UQOS)
def _uqos(agg: "B.UQOS", use_kernel: bool) -> JaxAggregator:
    dim, e_s, n0, bw = agg.dim, agg.e_s, agg.n0, agg.B
    k, r, rate_c = agg.k, agg.r, agg.rate
    pi = np.asarray(agg.pi)
    p_succ = np.asarray(agg.p_succ)
    n = pi.shape[0]
    payload = float(payload_bits(dim, r))

    def sel_stream(seed, trial, T):
        # per round: sampling permutation + inclusion keys, in draw order
        def draw(rng):
            return np.concatenate([rng.permutation(n).astype(np.float64),
                                   rng.uniform(size=n)])
        return rngstream.replay_rounds(seed, trial, T, draw)

    def sel_stream_jax(key):
        # same row layout as the replay draw: permutation then uniforms
        kp, ku = jax.random.split(key)
        return jnp.concatenate([
            jax.random.permutation(kp, n).astype(jnp.float64),
            jax.random.uniform(ku, (n,), dtype=jnp.float64)])

    def round_fn(grads, h, z01, u, sel, t):
        order = sel[:n].astype(jnp.int32)
        keys = sel[n:] ** (1.0 / jnp.asarray(pi)[order])
        chosen = order[jnp.argsort(keys)[::-1][:k]]
        cmask = jnp.zeros(n, grads.dtype).at[chosen].set(1.0)
        habs = jnp.abs(h)
        snr_ok = capacity_rate_jnp(habs, e_s, n0) >= rate_c
        active = cmask * snr_ok
        levels = active * (2.0 ** r - 1.0)
        acc = ops.quantized_weighted_sum(            # unbiased reweight
            grads, levels, u, active / (n * pi * p_succ),
            r_max=r, use_kernel=use_kernel)
        lat = jnp.sum(active) * payload / (bw * rate_c)
        return acc, lat

    return JaxAggregator(name=agg.name, is_ota=False, round_fn=round_fn,
                         needs_noise=False, needs_dither=True,
                         sel_stream_np=sel_stream,
                         sel_stream_jax=sel_stream_jax)


@register_port(B.QML)
def _qml(agg: "B.QML", use_kernel: bool) -> JaxAggregator:
    dim, e_s, n0, bw = agg.dim, agg.e_s, agg.n0, agg.B
    k = agg.k
    n = agg.dep.n_devices
    # smallest r meeting the per-device variance cap (static, as the oracle)
    r = 1
    while (dim * agg.g_max ** 2 / (2.0 ** r - 1.0) ** 2 > agg.var_cap
           and r < agg.r_max):
        r += 1
    payload = float(payload_bits(dim, r))

    def sel_stream(seed, trial, T):
        return rngstream.replay_rounds(
            seed, trial, T, lambda rng: rng.choice(n, size=k, replace=False))

    def sel_stream_jax(key):
        return jax.random.choice(key, n, (k,),
                                 replace=False).astype(jnp.float64)

    def round_fn(grads, h, z01, u, sel, t):
        chi = jnp.zeros(n, grads.dtype).at[sel.astype(jnp.int32)].set(1.0)
        rate = capacity_rate_jnp(jnp.abs(h), e_s, n0)
        lat = jnp.sum(chi * payload / (bw * jnp.maximum(rate, 1e-9)))
        acc = _quantized_mean(grads, chi, chi * r, u, k, use_kernel,
                              r_max=r)
        return acc, lat

    return JaxAggregator(name=agg.name, is_ota=False, round_fn=round_fn,
                         needs_noise=False, needs_dither=True,
                         sel_stream_np=sel_stream,
                         sel_stream_jax=sel_stream_jax)


@register_port(B.FedTOE)
def _fedtoe(agg: "B.FedTOE", use_kernel: bool) -> JaxAggregator:
    dim, bw = agg.dim, agg.B
    k, p_out, t_budget, r_max = agg.k, agg.p_out, agg.t_budget, agg.r_max
    rates = np.asarray(agg.rates)
    thr = np.asarray(agg.thr)
    n = rates.shape[0]

    def sel_stream(seed, trial, T):
        return rngstream.replay_rounds(
            seed, trial, T, lambda rng: rng.choice(n, size=k, replace=False))

    def sel_stream_jax(key):
        return jax.random.choice(key, n, (k,),
                                 replace=False).astype(jnp.float64)

    def round_fn(grads, h, z01, u, sel, t):
        bits, in_alloc = greedy_bit_alloc_jax(
            sel.astype(jnp.int32), jnp.asarray(rates), dim=dim,
            bandwidth_hz=bw, t_budget_s=t_budget, r_max=r_max)
        lat = jnp.sum(in_alloc * (64.0 + dim * bits)
                      / (bw * jnp.maximum(rates, 1e-9)))
        chi = (in_alloc * outage_mask(jnp.abs(h), thr)).astype(grads.dtype)
        k_sched = jnp.maximum(jnp.sum(in_alloc), 1.0)
        acc = _quantized_mean(grads, chi, chi * bits, u,
                              k_sched * (1.0 - p_out), use_kernel,
                              r_max=r_max)
        return acc, lat

    return JaxAggregator(name=agg.name, is_ota=False, round_fn=round_fn,
                         needs_noise=False, needs_dither=True,
                         sel_stream_np=sel_stream,
                         sel_stream_jax=sel_stream_jax)


def as_functional(agg, use_kernel: bool = True) -> Optional[JaxAggregator]:
    """Functional port of a NumPy ``Aggregator`` instance, or None when the
    scheme has no registered port (the trainer then falls back to NumPy).

    Ports are resolved through the ``_PORT_FACTORIES`` routing table and
    memoized on the aggregator instance so repeated runs (e.g. the
    benchmarks' step-size grid search) share compiled scans.
    """
    if isinstance(agg, JaxAggregator):
        return agg
    cache = agg.__dict__.setdefault("_jax_ports", {})
    if use_kernel in cache:
        return cache[use_kernel]
    factory = _PORT_FACTORIES.get(type(agg))
    port = factory(agg, use_kernel) if factory is not None else None
    cache[use_kernel] = port
    return port


# ----------------------------------------------------------------- engine

def _project(w, radius):
    nrm = jnp.linalg.norm(w)
    scale = jnp.minimum(1.0, radius / jnp.maximum(nrm, 1e-300))
    return w * scale


class FLEngine:
    """vmap(lax.scan) Monte-Carlo FL simulator (same protocol as FLTrainer).

    One jitted call runs all trials of all rounds: fading/noise/selection
    draws come in as batched (trials, T, ...) tensors, quantization dither
    and mini-batch indices stream from scan-carried per-trial keys (O(N*d)
    per round), rounds advance under a two-level ``lax.scan`` (outer: eval
    segments, inner: rounds) so only the model states at eval points are
    materialized, time budgets freeze the carry in-scan once the cumulative
    wall-clock is spent, and trials are batched with ``vmap`` — including
    through the Pallas epilogue kernels — or laid over devices with
    ``shard_map`` when ``shard_trials=True``.
    """

    def __init__(self, task, dataset, deployment: Deployment, eta: float, *,
                 project_radius: Optional[float] = None,
                 batch_size: Optional[int] = None,
                 use_kernel: bool = True, shard_trials: bool = False,
                 payload_dtype: str = "f32",
                 fault: Optional[FaultSpec] = None,
                 clients_per_round: Optional[int] = None,
                 participation: str = "uniform",
                 participation_probs=None,
                 mode: str = "sync",
                 async_spec: Optional[async_fl.AsyncSpec] = None,
                 async_weights=None):
        if payload_dtype not in ("f32", "bf16"):
            raise ValueError(
                f"payload_dtype must be 'f32' or 'bf16', got {payload_dtype!r}")
        self.task = task
        self.ds = dataset
        self.dep = deployment
        self.eta = eta
        self.project_radius = project_radius
        self.use_kernel = use_kernel
        self.shard_trials = shard_trials
        self.payload_dtype = payload_dtype
        # a disabled FaultSpec normalizes to None: the scan traces the
        # exact pre-fault program, so disabled-fault runs are bit-identical
        self.fault = fault if fault is not None and fault.enabled else None
        # clients_per_round=None likewise normalizes to None (strict
        # no-op); otherwise the validated sampling config is shared with
        # the oracle bit-for-bit (core.participation). The loss/datasize
        # policies derive their capped-simplex weights from (task,
        # dataset) — pure NumPy, identical bits on both backends.
        part_weights = None
        if (clients_per_round is not None and participation_probs is None
                and participation in participation_lib.WEIGHTED_POLICIES):
            part_weights = participation_lib.policy_weights(
                participation, task, dataset)
        self.participation = participation_lib.resolve(
            clients_per_round, participation, participation_probs,
            n_devices=deployment.n_devices, lambdas=deployment.lambdas,
            weights=part_weights)
        # mode="sync" normalizes to None the same way: the scan traces
        # the exact pre-async program (strict no-op). The resolved tables
        # (rates/CDF/discounts/weights) are float64 tuples shared with
        # the oracle bit-for-bit (core.async_fl).
        self.async_ = async_fl.resolve(mode, async_spec,
                                       deployment.n_devices, async_weights)
        sizes = tuple(len(d) for d in dataset.devices)
        if len(set(sizes)) == 1:
            self.device_sizes = None      # equal sizes: plain stacked arrays
            self.batch_size = self.effective_batch_size(batch_size, sizes[0])
            self.xs = np.stack(
                [d.x for d in dataset.devices]).astype(np.float32)
            self.ys = np.stack(
                [d.y for d in dataset.devices]).astype(np.int32)
        else:
            # unequal sizes: zero-pad each device to n_max and regenerate
            # per-device batch indices in-scan. Strictly mini-batch rounds
            # (batch_size < every size) use batch_block_ragged, whose
            # per-device keyed draws match the oracle's batch_indices_np
            # exactly and never touch the padding rows; the mixed
            # full/mini-batch regime (batch_size >= some device's size)
            # runs those devices full-batch through the weighted gradient
            # path (see _get_runner).
            if batch_size is None:
                raise ValueError(
                    "FLEngine needs a mini-batch size when device datasets "
                    f"have unequal sizes (got sizes {sorted(set(sizes))}); "
                    "use backend='numpy' for full-batch unequal runs")
            self.device_sizes = sizes
            self.batch_size = batch_size
            n_max = max(sizes)
            d0 = dataset.devices[0]
            xs = np.zeros((len(sizes), n_max) + d0.x.shape[1:], np.float32)
            ys = np.zeros((len(sizes), n_max), np.int32)
            for m, dd in enumerate(dataset.devices):
                xs[m, :len(dd)] = dd.x
                ys[m, :len(dd)] = dd.y
            self.xs, self.ys = xs, ys
        self.x_all = np.concatenate(
            [d.x for d in dataset.devices]).astype(np.float32)
        self.y_all = np.concatenate(
            [d.y for d in dataset.devices]).astype(np.int32)
        self.x_test = np.asarray(dataset.x_test, np.float32)
        self.y_test = np.asarray(dataset.y_test, np.int32)
        # built once so repeated run() calls hit the jit cache
        self._loss_v = jax.jit(jax.vmap(task.loss_fn, in_axes=(0, None, None)))
        self._acc_v = jax.jit(jax.vmap(task.accuracy_fn,
                                       in_axes=(0, None, None)))

    @staticmethod
    def effective_batch_size(batch_size: Optional[int],
                             n_data: int) -> Optional[int]:
        """batch_size >= |D_m| is full-batch (DeviceDataset.batch
        semantics). The single normalization rule shared with the trainer's
        engine-cache comparison."""
        return (None if batch_size is not None and batch_size >= n_data
                else batch_size)

    # ------------------------------------------------------- scan runner

    def _get_runner(self, jagg: JaxAggregator, trials: int, n_seg: int,
                    eval_every: int, rng_mode: str):
        d, N = self.task.dim, self.dep.n_devices
        if (rng_mode == "fast" and jagg.sel_stream_np is not None
                and jagg.sel_stream_jax is None):
            raise ValueError(
                f"{jagg.name} consumes selection randomness but its JAX "
                "port has no fast-mode sampler (sel_stream_jax); use "
                "rng='replay'")
        # the task object itself keys (and pins) the gradient function;
        # everything else closed over by trial_fn is shape-static, and all
        # run-varying scalars (eta, radius, lat_div, budget) are traced
        # arguments
        key = (self.task, trials, n_seg, eval_every, d, N,
               self.xs.shape, self.batch_size, self.device_sizes,
               self.use_kernel, self.shard_trials, rng_mode,
               self.payload_dtype, self.fault, self.participation,
               self.async_)
        if key in jagg._runner_cache:
            return jagg._runner_cache[key]

        batch_size = self.batch_size
        device_sizes = self.device_sizes
        n_data = self.xs.shape[1]
        # mixed full/mini-batch regime: unequal device sizes with the batch
        # covering some devices. Covered devices run full-batch; the batch
        # block still has batch_size columns (gather rows are clipped), so
        # per-row *weights* carry each device's true normalization — full
        # rows weight their n_m real rows by 1/n_m (clipped duplicates get
        # 0), mini rows weight by 1/batch_size — through the task's
        # weighted gradient path.
        mixed = (device_sizes is not None
                 and batch_size >= min(device_sizes))
        if batch_size is None:
            grads_fn = self.task.device_grads_fn
        elif mixed:
            grads_fn = self.task.device_grads_at_weighted_fn
            wts = np.zeros((N, batch_size), np.float32)
            for m, n_m in enumerate(device_sizes):
                if n_m <= batch_size:
                    wts[m, :n_m] = 1.0 / n_m
                else:
                    wts[m, :] = 1.0 / batch_size
            batch_wts = jnp.asarray(wts)
        else:
            grads_fn = self.task.device_grads_at_fn
        payload_bf16 = self.payload_dtype == "bf16"
        round_fn = jagg.round_fn
        needs_dither = jagg.needs_dither
        needs_noise = jagg.needs_noise
        sel_jax = jagg.sel_stream_jax
        has_sel = jagg.sel_stream_np is not None
        fast = rng_mode == "fast"
        lambdas = jnp.asarray(self.dep.lambdas, jnp.float64)
        # fault layer: trace-time static — with faults disabled (None) the
        # scan below is the exact pre-fault program (bit-identical runs)
        fault = self.fault
        stale = fault is not None and fault.on_missing == "stale"
        if fault is not None:
            q_surv = jnp.asarray(
                survival_prob(fault, np.asarray(self.dep.lambdas)),
                jnp.float64)
            has_deadline = fault.deadline_s is not None
            deadline = float(fault.deadline_s) if has_deadline else np.inf
            straggler_mult = float(fault.straggler_mult)
        # participation layer: trace-time static like the fault layer —
        # with clients_per_round=None the scan below is the exact
        # pre-participation program (bit-identical runs)
        part = self.participation
        if part is not None:
            part_probs = jnp.asarray(part.probs_array(), jnp.float64)
            part_scale = float(part.scale)
        # buffered-async layer: trace-time static like the fault and
        # participation layers — with mode="sync" (None) the scan below is
        # the exact pre-async program (bit-identical runs). All tables are
        # precomputed host-side float64, so the in-scan realization is
        # exact comparisons/gathers only (bit-identical to the oracle).
        asy = self.async_
        amode = asy is not None
        if amode:
            a_stale = asy.on_missing == "stale"
            a_k = asy.buffer_rounds
            a_rates = jnp.asarray(asy.rates_array(), jnp.float64)
            a_cdf = jnp.asarray(asy.cdf_array(), jnp.float64)
            a_disc = jnp.asarray(asy.discounts_array(), jnp.float64)
            a_pscale = jnp.asarray(asy.payload_scale_array(), jnp.float64)
        else:
            a_stale = False

        def trial_fn(w0, eta, radius, lat_div, budget, xs, ys, dkey, bkey,
                     fkey, pkey, akey, A, B_, C, Ts):
            # dkey/bkey/fkey/pkey/akey: scan-carried / closed-over
            # per-trial dither, batch-index, fault-, participation- and
            # arrival-stream keys (counter-based in both modes).
            # replay: A=H (n_seg, eval_every, N) complex, B_=Z
            # (n_seg, eval_every, dz), C=SEL (n_seg, eval_every, S) — host
            # precomputed tensors fed through the scan.
            # fast: A/B_/C are the trial's fading/noise/selection threefry
            # base keys (uint32 (2,)); every draw is regenerated in-scan
            # from (key, t), so nothing is precomputed and Ts is the only
            # scan input. Same arity either way, so the vmap/shard_map
            # plumbing below is mode-blind.
            def step(carry, inp):
                # fixed base carry + trace-time-static optional extras, in
                # order: [async last-K buffer, async last-delivered
                # payloads, fault "stale" last-received gradients]
                w, t_wall, _, dkey, bkey = carry[:5]
                ext = list(carry[5:])
                if amode:
                    a_buf = ext.pop(0)
                    if a_stale:
                        g_alast = ext.pop(0)
                if stale:
                    # "stale" carries the last *received* per-device
                    # gradients so missing payloads replay them
                    g_stale = ext.pop(0)
                if fast:
                    t = inp
                    h = sample_fading_jax(A, t, lambdas)
                    z = (rngstream.noise_block(B_, t, d) if needs_noise
                         else jnp.zeros((1,), jnp.float64))
                    selrow = (sel_jax(jax.random.fold_in(C, t)) if has_sel
                              else jnp.zeros((1,), jnp.float64))
                else:
                    h, z, selrow, t = inp
                # the trainer breaks on the first round whose *preceding*
                # cumulative wall-clock hit the budget; past that round the
                # carry freezes (w and t_wall stop advancing)
                active = t_wall < budget
                if batch_size is None:
                    g = grads_fn(w.astype(jnp.float32), xs, ys
                                 ).astype(jnp.float64)
                else:
                    # (N, B) counter-based indices regenerated in-scan —
                    # bit-identical to the oracle's batch_block_np /
                    # batch_indices_np draws (ragged rows key on each
                    # device's own size and never hit the padding)
                    if mixed:
                        idx = rngstream.batch_block_mixed(
                            bkey, t, device_sizes, batch_size)
                        g = grads_fn(w.astype(jnp.float32), xs, ys, idx,
                                     batch_wts).astype(jnp.float64)
                    elif device_sizes is not None:
                        idx = rngstream.batch_block_ragged(
                            bkey, t, device_sizes, batch_size)
                        g = grads_fn(w.astype(jnp.float32), xs, ys, idx
                                     ).astype(jnp.float64)
                    else:
                        idx = rngstream.batch_block(bkey, t, N, n_data,
                                                    batch_size)
                        g = grads_fn(w.astype(jnp.float32), xs, ys, idx
                                     ).astype(jnp.float64)
                if payload_bf16:
                    # mixed-precision uplink: the gradient payload leaves
                    # the device truncated to bf16; aggregation stays in
                    # the engine's wide accumulators
                    g = g.astype(jnp.bfloat16).astype(jnp.float64)
                if part is not None:
                    # Bernoulli client sampling (counter-based PARTICIPATE
                    # stream, bit-identical across backends/rng modes):
                    # excluded payloads zero out, included ones carry the
                    # uniform inverse-propensity scale N/S — applied
                    # upstream of the fault layer and the scheme's
                    # combiner (non-participants keep their reserved
                    # slots, like faulted devices)
                    up = rngstream.participation_block(pkey, t, N)
                    chi = up.astype(jnp.float64) < part_probs
                    g = g * (chi.astype(jnp.float64) * part_scale)[:, None]
                if amode:
                    # buffered-async delivery (counter-based ARRIVAL
                    # stream, bit-identical across backends/rng modes):
                    # the last-K buffer shifts, each device delivers a
                    # staleness-S discounted payload drawn against the
                    # precomputed rate/CDF tables, and missing devices
                    # zero-fill or replay their last delivered payload —
                    # applied upstream of the fault layer and the
                    # scheme's combiner, like the layers around it
                    ua = rngstream.arrival_block(akey, t, N)
                    ua = ua.astype(jnp.float64)   # exact widen (x64 on)
                    g, ok_a, a_buf = async_fl.async_round(
                        g, a_buf, ua, a_rates, a_cdf, a_disc, a_pscale)
                    if a_stale:
                        g, g_alast = async_fl.stale_replace(g, ok_a,
                                                            g_alast)
                    else:
                        g = g * ok_a.astype(jnp.float64)[:, None]
                if fault is not None:
                    # counter-based fault draws + degradation policy,
                    # applied to the payloads *upstream* of the scheme's
                    # combiner so every registered port inherits it
                    # (faulted devices keep their reserved slots; a zeroed
                    # payload quantizes to exact zeros on both backends)
                    uf = rngstream.fault_block(fkey, t, N)
                    uf = uf.astype(jnp.float64)   # exact widen (x64 on)
                    okb, straggler = fault_masks(uf, jnp.abs(h), fault)
                    if fault.on_missing == "zero":
                        g = g * okb.astype(jnp.float64)[:, None]
                    elif fault.on_missing == "reweight":
                        g = g * (okb.astype(jnp.float64) / q_surv)[:, None]
                    else:
                        # stale: replay the last received gradient — the
                        # single last-gradient code path shared with the
                        # async buffer (core.async_fl)
                        g, g_stale = async_fl.stale_replace(g, okb,
                                                            g_stale)
                if needs_dither:
                    # one (N, d) block regenerated per round — the whole
                    # dither stream never exists in memory at once
                    u = rngstream.dither_block(dkey, t, N, d)
                else:
                    u = jnp.zeros((1, 1), jnp.float32)
                ghat, lat = round_fn(g, h, z, u, selrow, t)
                # division (not reciprocal-multiply) so OTA wall-clock is
                # bit-equal to the trainer's ``latency_s / bandwidth`` and
                # budget comparisons freeze on the same round
                w_new = jnp.where(active, _project(w - eta * ghat, radius), w)
                if fault is not None:
                    # delivering stragglers stretch the round; a deadline
                    # instead caps it (stragglers then miss via the mask)
                    lat_s = lat / lat_div
                    slow = jnp.any(straggler & okb)
                    lat_s = jnp.where(slow, lat_s * straggler_mult, lat_s)
                    if has_deadline:
                        lat_s = jnp.minimum(lat_s, deadline)
                    t_wall = jnp.where(active, t_wall + lat_s, t_wall)
                else:
                    t_wall = jnp.where(active, t_wall + lat / lat_div,
                                       t_wall)
                out = (w_new, t_wall, active, dkey, bkey)
                if amode:
                    out = out + (a_buf,)
                    if a_stale:
                        out = out + (g_alast,)
                if stale:
                    out = out + (g_stale,)
                return out, None

            def segment(carry, seg_inp):
                w_eval, inner = carry[0], carry[1:]
                inner, _ = jax.lax.scan(step, inner, seg_inp)
                w, t_wall, live = inner[0], inner[1], inner[2]
                # the eval at this segment's end is written by the trainer
                # iff the segment's last round still ran; otherwise the slot
                # freezes at the last written eval state
                w_eval = jnp.where(live, w, w_eval)
                return (w_eval,) + inner, (w_eval, t_wall)

            carry0 = (w0, w0, jnp.zeros((), jnp.float64),
                      jnp.asarray(True), dkey, bkey)
            if amode:
                # pre-start buffer slots are zeros: a staleness draw that
                # reaches past round 0 delivers nothing (the device had
                # not computed yet), matching the oracle exactly
                carry0 = carry0 + (jnp.zeros((a_k, N, d), jnp.float64),)
                if a_stale:
                    carry0 = carry0 + (jnp.zeros((N, d), jnp.float64),)
            if stale:
                # until a device's first delivery, "stale" replays zeros
                carry0 = carry0 + (jnp.zeros((N, d), jnp.float64),)
            seg_xs = Ts if fast else (A, B_, C, Ts)
            _, (ws, walls) = jax.lax.scan(segment, carry0, seg_xs)
            ws = jnp.concatenate([w0[None], ws], axis=0)          # (E, d)
            walls = jnp.concatenate([jnp.zeros((1,)), walls], axis=0)
            return ws, walls

        vmapped = jax.vmap(
            trial_fn,
            in_axes=(None, None, None, None, None, None, None,
                     0, 0, 0, 0, 0, 0, 0, 0, None))
        if self.shard_trials:
            from ..compat import shard_map as shard_map_compat
            n_hw = len(jax.devices())
            if trials % n_hw != 0:
                raise ValueError(
                    f"shard_trials needs trials ({trials}) divisible by the "
                    f"device count ({n_hw})")
            mesh = jax.make_mesh((n_hw,), ("trials",))
            P = jax.sharding.PartitionSpec
            vmapped = shard_map_compat(
                vmapped, mesh,
                in_specs=(P(), P(), P(), P(), P(), P(), P(),
                          P("trials"), P("trials"), P("trials"), P("trials"),
                          P("trials"), P("trials"), P("trials"), P("trials"),
                          P()),
                out_specs=(P("trials"), P("trials")),
                manual_axes=("trials",))
        runner = jax.jit(vmapped)
        jagg._runner_cache[key] = runner
        return runner

    # --------------------------------------------------------------- run

    def run(self, aggregator, *, rounds: int, trials: int = 3,
            eval_every: int = 10, seed: int = 0,
            w_star: Optional[np.ndarray] = None,
            time_budget_s: Optional[float] = None,
            rng: str = "replay") -> TrainLog:
        if rng not in ("replay", "fast"):
            raise ValueError(f"rng must be 'replay' or 'fast', got {rng!r}")
        jagg = as_functional(aggregator, use_kernel=self.use_kernel)
        if jagg is None:
            raise ValueError(
                f"no JAX port for {type(aggregator).__name__}; "
                "use FLTrainer.run(..., backend='numpy')")
        eval_rounds = list(range(0, rounds + 1, eval_every))
        n_seg = len(eval_rounds) - 1
        T = n_seg * eval_every      # rounds past the last eval are unobserved
        d, N = self.task.dim, self.dep.n_devices

        if rng == "fast":
            # zero host-side precompute: only three (2,)-uint32 base keys
            # per trial; fading/noise/selection regenerate in-scan
            H = jnp.stack([rngstream.stream_base_key(
                seed, tr, rngstream.FADING_TAG) for tr in range(trials)])
            Z = jnp.stack([rngstream.stream_base_key(
                seed, tr, rngstream.NOISE_TAG) for tr in range(trials)])
            SEL = jnp.stack([rngstream.stream_base_key(
                seed, tr, rngstream.SELECT_TAG) for tr in range(trials)])
        else:
            H = np.stack([sample_fading_batch(self.dep.lambdas,
                                              seed * 1000 + tr, T)
                          for tr in range(trials)])           # (trials, T, N)
            if jagg.needs_noise:
                Z = np.stack([rngstream.trial_rng(seed, tr)
                              .standard_normal((T, d))
                              for tr in range(trials)])
            else:
                Z = np.zeros((trials, T, 1))
            if jagg.sel_stream_np is not None:
                SEL = np.stack([jagg.sel_stream_np(seed, tr, T)
                                for tr in range(trials)])     # (trials, T, S)
            else:
                SEL = np.zeros((trials, T, 1))
        keys = jnp.stack([rngstream.dither_base_key(seed, tr)
                          for tr in range(trials)])
        bkeys = jnp.stack([rngstream.batch_base_key(seed, tr)
                           for tr in range(trials)])
        # fault-, participation- and arrival-stream base keys ride along
        # unconditionally (cheap, and keeps trial_fn's arity mode-,
        # fault-, participation- and async-blind); when the matching
        # layer is disabled the traced program never consumes them
        fkeys = jnp.stack([rngstream.fault_base_key(seed, tr)
                           for tr in range(trials)])
        pkeys = jnp.stack([rngstream.participate_base_key(seed, tr)
                           for tr in range(trials)])
        akeys = jnp.stack([rngstream.arrival_base_key(seed, tr)
                           for tr in range(trials)])

        with enable_x64():
            runner = self._get_runner(jagg, trials, n_seg, eval_every, rng)
            w0 = jnp.asarray(self.task.init_params(), jnp.float64)
            eta = jnp.asarray(self.eta, jnp.float64)
            radius = jnp.asarray(
                np.inf if self.project_radius is None else self.project_radius,
                jnp.float64)
            lat_div = jnp.asarray(
                self.dep.cfg.bandwidth_hz if jagg.is_ota else 1.0,
                jnp.float64)
            budget = jnp.asarray(
                np.inf if time_budget_s is None else time_budget_s,
                jnp.float64)
            Ts = jnp.arange(T).reshape(n_seg, eval_every)
            if rng == "fast":
                A, B_, C = H, Z, SEL          # per-trial base keys as-is
            else:
                seg = lambda a: jnp.asarray(a).reshape(
                    (trials, n_seg, eval_every) + a.shape[2:])
                A, B_, C = seg(H), seg(Z), seg(SEL)
            ws, walls = runner(w0, eta, radius, lat_div, budget,
                               jnp.asarray(self.xs), jnp.asarray(self.ys),
                               keys, bkeys, fkeys, pkeys, akeys,
                               A, B_, C, Ts)
            losses, accs = self._evaluate(ws)
            opt_err = (np.sum((np.asarray(ws) - w_star) ** 2, axis=-1)
                       if w_star is not None else None)
        return TrainLog(scheme=jagg.name,
                        rounds=np.asarray(eval_rounds, dtype=np.int64),
                        wall_time_s=np.asarray(walls).mean(axis=0),
                        global_loss=np.asarray(losses, np.float64),
                        accuracy=np.asarray(accs, np.float64),
                        opt_error=opt_err)

    def _evaluate(self, ws):
        """Global loss + test accuracy at every eval point, vmapped over
        (trials * E) model states in the trainer's float32 eval precision."""
        trials, E, d = ws.shape
        wf = ws.reshape(trials * E, d).astype(jnp.float32)
        losses = self._loss_v(wf, self.x_all, self.y_all)
        accs = self._acc_v(wf, self.x_test, self.y_test)
        return (np.asarray(losses).reshape(trials, E),
                np.asarray(accs).reshape(trials, E))
