"""FL simulation loop (eq. (2)/(13)): broadcast -> local grads -> wireless
aggregation -> projected SGD step, with Monte-Carlo trials over fading/noise.

Matches Sec. V's protocol:
  * fixed device deployment (fixed {Lambda_m}) across trials,
  * independent fading + PS noise per trial,
  * full-batch local gradients (|B| = |D|, sigma_m = 0) by default, or SGD
    mini-batches via ``batch_size`` (counter-based index draws shared
    bit-for-bit with the JAX engine),
  * projection onto the ball W = {||w|| <= D/2} in the strongly convex case,
  * per-round latency accounting (OTA: d/B; digital: realized TDMA time),
  * optional wireless fault injection (``core.faults``): dropouts, erasures,
    deep fades and stragglers drawn from the counter-based FAULT stream
    (bit-shared with the JAX engine), with graceful-degradation policies
    applied to the gradients before the aggregation scheme runs,
  * optional partial participation (``core.participation``): Bernoulli
    client sampling with static inclusion probabilities drawn from the
    counter-based PARTICIPATE stream (bit-shared with the JAX engine),
    payloads scaled by the uniform inverse propensity N/S,
  * optional buffered-async aggregation (``core.async_fl``,
    ``mode="async"``): per-device delivery/staleness events drawn from the
    counter-based ARRIVAL stream (bit-shared with the JAX engine) against
    precomputed rate/CDF tables; the PS consumes staleness-discounted
    payloads from a last-K gradient buffer, missing devices zero-fill or
    replay their last delivered payload.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import numpy as np

from ..core import async_fl
from ..core import participation as participation_lib
from ..core import rngstream
from ..core.baselines import Aggregator
from ..core.channel import Deployment, FadingProcess
from ..core.faults import FaultSpec, fault_masks, survival_prob


@dataclasses.dataclass
class TrainLog:
    scheme: str
    rounds: np.ndarray          # (T_eval,)
    wall_time_s: np.ndarray     # cumulative uplink latency at eval points
    global_loss: np.ndarray     # (trials, T_eval)
    accuracy: np.ndarray        # (trials, T_eval)
    opt_error: Optional[np.ndarray] = None   # ||w_t - w*||^2 if w* known

    def mean_std(self, field: str):
        v = getattr(self, field)
        return v.mean(axis=0), v.std(axis=0)

    def final_accuracy(self) -> float:
        return float(self.accuracy[:, -1].mean())


class FLTrainer:
    def __init__(self, task, dataset, deployment: Deployment,
                 eta: float, *, project_radius: Optional[float] = None,
                 batch_size: Optional[int] = None,
                 payload_dtype: str = "f32",
                 fault: Optional[FaultSpec] = None,
                 clients_per_round: Optional[int] = None,
                 participation: str = "uniform",
                 participation_probs=None,
                 mode: str = "sync",
                 async_spec: Optional[async_fl.AsyncSpec] = None,
                 async_weights=None):
        if payload_dtype not in ("f32", "bf16"):
            raise ValueError(
                f"payload_dtype must be 'f32' or 'bf16', got {payload_dtype!r}")
        self.task = task
        self.ds = dataset
        self.dep = deployment
        self.eta = eta
        self.project_radius = project_radius
        self.batch_size = batch_size
        self.payload_dtype = payload_dtype
        # a disabled FaultSpec normalizes to None so fault-free runs take
        # the exact pre-fault code path (bit-identical trajectories) and
        # hit the same engine cache entry as a no-fault trainer
        self.fault = fault if fault is not None and fault.enabled else None
        # same normalization for client sampling: clients_per_round=None
        # -> None (strict no-op); otherwise the shared validated config
        # (core.participation) both backends consume bit-for-bit. The
        # loss/datasize policies derive their capped-simplex weights from
        # (task, dataset) — pure NumPy, identical bits on both backends.
        part_weights = None
        if (clients_per_round is not None and participation_probs is None
                and participation in participation_lib.WEIGHTED_POLICIES):
            part_weights = participation_lib.policy_weights(
                participation, task, dataset)
        self.participation = participation_lib.resolve(
            clients_per_round, participation, participation_probs,
            n_devices=deployment.n_devices, lambdas=deployment.lambdas,
            weights=part_weights)
        # mode="sync" normalizes the async layer to None (strict no-op);
        # otherwise the resolved tables (core.async_fl) are shared with
        # the JAX engine bit-for-bit
        self.async_ = async_fl.resolve(mode, async_spec,
                                       deployment.n_devices, async_weights)
        self._mode = mode
        self._async_spec = async_spec
        self._async_weights = async_weights
        self._engine = None
        # stack device data once whenever sizes allow: (N, n, feat). The
        # stacked view serves the full-batch path AND the counter-based
        # mini-batch fast path (task.device_grads_at on a (N, B) index
        # block); unequal-sized devices fall back to per-device gathers.
        if len({len(d) for d in dataset.devices}) == 1:
            self.xs = np.stack([d.x for d in dataset.devices])
            self.ys = np.stack([d.y for d in dataset.devices])
        else:
            if batch_size is None:
                raise ValueError(
                    "full-batch training needs equal-sized device datasets "
                    "(stacked (N, n, feat) gradients); set batch_size")
            self.xs = self.ys = None

    def _project(self, w: np.ndarray) -> np.ndarray:
        if self.project_radius is None:
            return w
        nrm = np.linalg.norm(w)
        if nrm <= self.project_radius:
            return w
        return w * (self.project_radius / nrm)

    def run(self, aggregator: Aggregator, *, rounds: int, trials: int = 3,
            eval_every: int = 10, seed: int = 0,
            w_star: Optional[np.ndarray] = None,
            time_budget_s: Optional[float] = None,
            backend: str = "auto", rng: str = "replay") -> TrainLog:
        """Run the Monte-Carlo FL protocol.

        backend: "numpy" — reference Python-loop path; "jax" — vectorized
        vmap/scan engine (``fl.engine``), errors if the scheme has no JAX
        port; "auto" (default) — the engine whenever the scheme is
        registered in its port routing table (all 14 paper baselines are),
        NumPy otherwise. Mini-batching, time budgets and unequal-sized
        device datasets run natively in the engine — including the mixed
        full/mini-batch regime (batch_size >= some |D_m|), where full
        devices take weighted full-data gradients and mini devices the
        counter-based draw: batch indices are counter-based
        (``core.rngstream``, ragged per-device rows when sizes differ) and
        the budget-freeze mask is evaluated in-scan, so both backends
        replay the same random streams and trajectories agree to ~1e-5
        (tests/test_engine_parity.py; mixed rounds to ~1e-4 — the weighted
        sum reorders the oracle's mean reduction).

        rng: "replay" (default) — byte-compatible with the NumPy oracle's
        sequential streams (fading/AWGN/selection precomputed per trial);
        "fast" — every stream is counter-based threefry generated inside
        the scan, zero host-side per-trial precompute and O(N*d) memory.
        Fast draws come from the same laws but a different stream:
        statistically equivalent to replay, not bit-equal. Engine-only —
        errors on the NumPy path.
        """
        if backend not in ("auto", "jax", "numpy"):
            raise ValueError(f"unknown backend {backend!r}")
        if rng not in ("replay", "fast"):
            raise ValueError(f"rng must be 'replay' or 'fast', got {rng!r}")
        if backend == "numpy" and rng == "fast":
            raise ValueError(
                "rng='fast' runs only on the JAX engine; the NumPy backend "
                "is the replay oracle by definition")
        if backend == "numpy" and self.payload_dtype != "f32":
            raise ValueError(
                "payload_dtype='bf16' runs only on the JAX engine (the "
                "mixed-precision uplink cast lives in its scan); the NumPy "
                "backend is the f32/f64 replay oracle by definition")
        if backend != "numpy":
            from .engine import FLEngine, as_functional
            supported = as_functional(aggregator) is not None
            if supported:
                if self.xs is not None:
                    # normalized like FLEngine (batch_size >= |D_m| is full
                    # batch) so the degenerate case still reuses the cache
                    bs = FLEngine.effective_batch_size(self.batch_size,
                                                       self.xs.shape[1])
                else:
                    bs = self.batch_size
                if (self._engine is None
                        or self._engine.eta != self.eta
                        or self._engine.project_radius != self.project_radius
                        or self._engine.batch_size != bs
                        or self._engine.payload_dtype != self.payload_dtype
                        or self._engine.fault != self.fault
                        or self._engine.participation != self.participation
                        or self._engine.async_ != self.async_):
                    part = self.participation
                    self._engine = FLEngine(
                        self.task, self.ds, self.dep, self.eta,
                        project_radius=self.project_radius,
                        batch_size=bs, payload_dtype=self.payload_dtype,
                        fault=self.fault,
                        clients_per_round=(part.clients if part else None),
                        participation=(part.policy if part else "uniform"),
                        participation_probs=(part.probs_array()
                                             if part else None),
                        mode=self._mode, async_spec=self._async_spec,
                        async_weights=self._async_weights)
                return self._engine.run(aggregator, rounds=rounds,
                                        trials=trials, eval_every=eval_every,
                                        seed=seed, w_star=w_star,
                                        time_budget_s=time_budget_s,
                                        rng=rng)
            if backend == "jax":
                raise ValueError(
                    f"backend='jax' unsupported here: scheme "
                    f"{type(aggregator).__name__} has no JAX port")
        if rng == "fast":
            raise ValueError(
                "rng='fast' needs the JAX engine, but this run dispatches "
                f"to the NumPy path (scheme {type(aggregator).__name__})")
        if self.payload_dtype != "f32":
            raise ValueError(
                "payload_dtype='bf16' needs the JAX engine, but this run "
                "dispatches to the NumPy path (scheme "
                f"{type(aggregator).__name__})")
        eval_rounds = list(range(0, rounds + 1, eval_every))
        losses = np.zeros((trials, len(eval_rounds)))
        accs = np.zeros((trials, len(eval_rounds)))
        opt_err = (np.zeros((trials, len(eval_rounds)))
                   if w_star is not None else None)
        wall = np.zeros((trials, len(eval_rounds)))
        x_all = np.concatenate([d.x for d in self.ds.devices])
        y_all = np.concatenate([d.y for d in self.ds.devices])
        # fault layer (counter-based FAULT stream, shared bit-for-bit with
        # the JAX engine); q/deadline are static per-run quantities
        fault = self.fault
        if fault is not None:
            q_surv = survival_prob(fault, self.dep.lambdas)
            straggler_mult = float(fault.straggler_mult)
            deadline = fault.deadline_s
        # client sampling (counter-based PARTICIPATE stream, shared
        # bit-for-bit with the JAX engine); probabilities are static
        part = self.participation
        if part is not None:
            part_probs = part.probs_array()
            part_scale = float(part.scale)
        # buffered-async layer (counter-based ARRIVAL stream, shared
        # bit-for-bit with the JAX engine); the rate/CDF/discount tables
        # are static float64, so the in-loop realization is exact
        # comparisons/gathers only
        asy = self.async_
        if asy is not None:
            a_rates = asy.rates_array()
            a_cdf = asy.cdf_array()
            a_disc = asy.discounts_array()
            a_pscale = asy.payload_scale_array()

        for trial in range(trials):
            rng = np.random.default_rng((seed, trial, 17))
            fading = FadingProcess(self.dep, seed=seed * 1000 + trial)
            if fault is not None and fault.on_missing == "stale":
                g_stale = np.zeros((self.dep.n_devices, self.task.dim))
            if asy is not None:
                # pre-start buffer slots are zeros: staleness draws that
                # reach past round 0 deliver nothing
                a_buf = np.zeros((asy.buffer_rounds, self.dep.n_devices,
                                  self.task.dim))
                if asy.on_missing == "stale":
                    g_alast = np.zeros((self.dep.n_devices, self.task.dim))
            w = self.task.init_params()
            t_wall, ei = 0.0, 0
            for t in range(rounds + 1):
                if t in eval_rounds:
                    losses[trial, ei] = self.task.global_loss(w, x_all, y_all)
                    accs[trial, ei] = self.task.accuracy(
                        w, self.ds.x_test, self.ds.y_test)
                    if opt_err is not None:
                        opt_err[trial, ei] = float(np.sum((w - w_star) ** 2))
                    wall[trial, ei] = t_wall
                    ei += 1
                if t == rounds or (time_budget_s is not None
                                   and t_wall >= time_budget_s):
                    # budget hit / horizon reached: freeze remaining evals
                    # at the last *written* eval. The t=0 eval always runs
                    # before the first budget check, so ei >= 1 here and
                    # slot ei-1 is never stale/unwritten.
                    assert ei > 0, "freeze before any eval was written"
                    last = ei - 1
                    for j in range(ei, len(eval_rounds)):
                        losses[trial, j] = losses[trial, last]
                        accs[trial, j] = accs[trial, last]
                        wall[trial, j] = t_wall
                        if opt_err is not None:
                            opt_err[trial, j] = opt_err[trial, last]
                    break
                # mini-batch indices are counter-based (threefry on
                # (seed, trial, t, m), core.rngstream) so the JAX engine
                # regenerates bit-identical batches in-scan, and the
                # sequential trial rng stays reserved for AWGN/selection
                if self.batch_size is None:
                    grads = self.task.device_grads(w, self.xs, self.ys)
                elif (self.xs is not None
                      and self.batch_size < self.xs.shape[1]):
                    idx = rngstream.batch_block_np(
                        seed, trial, t, self.dep.n_devices,
                        self.xs.shape[1], self.batch_size)
                    grads = self.task.device_grads_at(w, self.xs, self.ys,
                                                      idx)
                elif self.xs is not None:
                    # batch_size >= |D_m|: full batch, no draw consumed
                    grads = self.task.device_grads(w, self.xs, self.ys)
                else:
                    bx, by = [], []
                    for m, d in enumerate(self.ds.devices):
                        ind = (rngstream.batch_indices_np(
                                   seed, trial, t, m, len(d),
                                   self.batch_size)
                               if self.batch_size < len(d) else None)
                        x_b, y_b = d.batch(self.batch_size, indices=ind)
                        bx.append(x_b)
                        by.append(y_b)
                    if len({b.shape[0] for b in bx}) == 1:
                        grads = self.task.device_grads(w, np.stack(bx),
                                                       np.stack(by))
                    else:
                        # mixed full/mini regime (batch_size >= some |D_m|):
                        # batches can't stack, so take per-device gradients
                        grads = np.stack(
                            [self.task.device_grads(w, x_b[None],
                                                    y_b[None])[0]
                             for x_b, y_b in zip(bx, by)])
                h = fading.sample(t)
                # client sampling: Bernoulli cohort + uniform inverse
                # propensity N/S, applied BEFORE the fault layer (same
                # ordering as the engine scan: payload cast ->
                # participation -> fault policy -> dither)
                if part is not None:
                    up = rngstream.participation_block_np(
                        seed, trial, t, self.dep.n_devices)
                    chi = up < part_probs
                    grads = grads * (chi.astype(np.float64)
                                     * part_scale)[:, None]
                # buffered-async delivery: the last-K buffer shifts and
                # each device delivers a staleness-discounted payload (or
                # nothing), upstream of the fault layer and the scheme —
                # the same ordering as the engine scan (payload cast ->
                # participation -> async delivery -> fault -> dither)
                if asy is not None:
                    ua = rngstream.arrival_block_np(
                        seed, trial, t, self.dep.n_devices)
                    grads, ok_a, a_buf = async_fl.async_round(
                        grads, a_buf, ua, a_rates, a_cdf, a_disc, a_pscale)
                    if asy.on_missing == "stale":
                        grads, g_alast = async_fl.stale_replace(
                            grads, ok_a, g_alast)
                    else:
                        grads = grads * ok_a.astype(np.float64)[:, None]
                # graceful degradation: transform the gradients BEFORE the
                # aggregation scheme sees them (same ordering as the engine
                # scan: payload cast -> fault policy -> dither), so every
                # scheme inherits the policy without per-scheme code
                if fault is not None:
                    uf = rngstream.fault_block_np(seed, trial, t,
                                                  self.dep.n_devices)
                    okb, straggler = fault_masks(uf, np.abs(h), fault)
                    if fault.on_missing == "zero":
                        grads = grads * okb.astype(np.float64)[:, None]
                    elif fault.on_missing == "reweight":
                        grads = grads * (okb.astype(np.float64)
                                         / q_surv)[:, None]
                    else:
                        # stale: replay the last received gradient — the
                        # single last-gradient code path shared with the
                        # async buffer (core.async_fl)
                        grads, g_stale = async_fl.stale_replace(
                            grads, okb, g_stale)
                # digital schemes consume counter-based dither (one (N, d)
                # block per round, bit-replayable by the JAX engine); OTA
                # schemes only draw AWGN from the sequential trial rng
                # the kwarg is only passed when a block exists, so custom
                # OTA aggregators with the pre-dither 4-arg round() keep
                # working
                if aggregator.is_ota:
                    res = aggregator.round(list(grads), h, t, rng)
                else:
                    u_t = rngstream.dither_block_np(seed, trial, t,
                                                    self.dep.n_devices,
                                                    self.task.dim)
                    res = aggregator.round(list(grads), h, t, rng,
                                           dither=u_t)
                lat_s = (res.latency_s / self.dep.cfg.bandwidth_hz
                         if aggregator.is_ota else res.latency_s)
                if fault is not None:
                    # delivering stragglers stretch the round; a deadline
                    # instead caps it (stragglers then count as missing,
                    # see core.faults.fault_masks)
                    if bool(np.any(straggler & okb)):
                        lat_s = lat_s * straggler_mult
                    if deadline is not None:
                        lat_s = min(lat_s, float(deadline))
                t_wall += lat_s
                w = self._project(w - self.eta * res.ghat)
        return TrainLog(scheme=aggregator.name,
                        rounds=np.asarray(eval_rounds, dtype=np.int64),
                        wall_time_s=wall.mean(axis=0), global_loss=losses,
                        accuracy=accs, opt_error=opt_err)


def solve_w_star(task, x_all: np.ndarray, y_all: np.ndarray,
                 iters: int = 4000, eta: Optional[float] = None) -> np.ndarray:
    """Reference minimizer w* of the (strongly convex) global objective via
    full-batch GD to high precision."""
    w = task.init_params()
    eta = eta if eta is not None else 2.0 / (task.mu + task.smooth_l)
    xs = x_all[None]
    ys = y_all[None]
    for _ in range(iters):
        g = task.device_grads(w, xs, ys)[0]
        w = w - eta * g
    return w
