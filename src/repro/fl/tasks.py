"""Learning tasks for the FL simulation experiments (Sec. V).

Every task exposes a *flat-vector* parameter interface (the aggregators in
``core.baselines`` operate on d-dimensional numpy gradients, mirroring the
paper's w in R^d):

  init_params() -> np.ndarray (d,)
  device_grads(w, xs, ys)  -> (losses (N,), grads (N, d))   [vmapped, jit]
  global_loss(w, x, y)     -> float   (the global objective F(w))
  accuracy(w, x, y)        -> float

Tasks:
  * SoftmaxRegressionTask — l2-regularized softmax regression; mu-strongly
    convex, L = 2 + mu smooth (paper Sec. V-A, [17]). d = C*(features+1).
  * MLPTask — one-hidden-layer MLP with l2 regularization (the smooth
    non-convex task standing in for ResNet-18 at CPU scale; Sec. V-B).

Assumption 1 (||g|| <= G_max) is enforced the standard way, by clipping the
per-device stochastic gradient to norm G_max (cf. [34] in the paper).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp


def _clip_to(g: jnp.ndarray, g_max: float) -> jnp.ndarray:
    nrm = jnp.linalg.norm(g)
    return g * jnp.minimum(1.0, g_max / jnp.maximum(nrm, 1e-12))


def _device_grad_at(device_grad):
    """Mini-batch view of a per-device gradient: gather the batch rows by
    index inside the jit, then run the same clipped-gradient program. Both
    simulation backends call this one compiled function (vmapped over the
    device axis, gradients vmapped over the gathered batch axis), so their
    stochastic gradients are bit-identical given identical indices."""
    def grad_at(w_flat, x, y, idx):
        return device_grad(w_flat, x[idx], y[idx])
    return grad_at


def _device_grad_at_weighted(device_grad_w):
    """Weighted-gather view for the *mixed* full/mini-batch regime: gather
    ``batch_size`` rows by index, then a clipped gradient of the
    *weighted-sum* loss. With weights 1/n_m on a full device's n_m real
    rows (0 on the clipped duplicates) or 1/B on a mini device's B drawn
    rows, this equals the mean-loss gradient up to fp summation order."""
    def grad_at(w_flat, x, y, idx, wt):
        return device_grad_w(w_flat, x[idx], y[idx], wt)
    return grad_at


class SoftmaxRegressionTask:
    """phi(w,(x,l)) = mu/2 ||w||^2 - log softmax_l(x^T W); strongly convex."""

    def __init__(self, n_features: int, n_classes: int = 10, mu: float = 0.01,
                 g_max: float = 20.0):
        self.n_features = n_features
        self.n_classes = n_classes
        self.mu = mu
        self.smooth_l = 2.0 + mu
        self.g_max = g_max
        self.dim = n_classes * (n_features + 1)

        def loss(w_flat, x, y):
            W = w_flat.reshape(n_classes, n_features + 1)
            logits = x @ W[:, :-1].T + W[:, -1]
            logp = jax.nn.log_softmax(logits, axis=-1)
            nll = -jnp.mean(logp[jnp.arange(x.shape[0]), y])
            return nll + 0.5 * mu * jnp.sum(w_flat ** 2)

        self._loss = jax.jit(loss)
        grad1 = jax.grad(loss)

        def device_grad(w_flat, x, y):
            return _clip_to(grad1(w_flat, x, y), g_max)

        self._device_grads = jax.jit(jax.vmap(device_grad, in_axes=(None, 0, 0)))
        self._device_losses = jax.jit(jax.vmap(loss, in_axes=(None, 0, 0)))
        self._device_grads_at = jax.jit(
            jax.vmap(_device_grad_at(device_grad), in_axes=(None, 0, 0, 0)))

        def loss_w(w_flat, x, y, wt):
            W = w_flat.reshape(n_classes, n_features + 1)
            logits = x @ W[:, :-1].T + W[:, -1]
            logp = jax.nn.log_softmax(logits, axis=-1)
            nll = -(wt * logp[jnp.arange(x.shape[0]), y]).sum()
            return nll + 0.5 * mu * jnp.sum(w_flat ** 2)

        grad1_w = jax.grad(loss_w)

        def device_grad_w(w_flat, x, y, wt):
            return _clip_to(grad1_w(w_flat, x, y, wt), g_max)

        self._device_grads_at_w = jax.jit(
            jax.vmap(_device_grad_at_weighted(device_grad_w),
                     in_axes=(None, 0, 0, 0, 0)))

        def acc(w_flat, x, y):
            W = w_flat.reshape(n_classes, n_features + 1)
            logits = x @ W[:, :-1].T + W[:, -1]
            return jnp.mean((jnp.argmax(logits, -1) == y).astype(jnp.float32))

        self._acc = jax.jit(acc)

    def init_params(self, seed: int = 0) -> np.ndarray:
        return np.zeros(self.dim, dtype=np.float64)

    @property
    def loss_fn(self):
        """Jitted pure loss (w32, x, y) -> scalar, for jit/vmap composition."""
        return self._loss

    @property
    def accuracy_fn(self):
        """Jitted pure accuracy (w32, x, y) -> scalar."""
        return self._acc

    @property
    def device_grads_fn(self):
        """Jitted vmapped per-device clipped gradient (w32, xs, ys) -> (N,d)."""
        return self._device_grads

    @property
    def device_grads_at_fn(self):
        """Jitted mini-batch gradient (w32, xs (N,n,f), ys, idx (N,B)) ->
        (N,d): gathers each device's batch by index, then the clipped grad."""
        return self._device_grads_at

    @property
    def device_grads_at_weighted_fn(self):
        """Jitted weighted mini-batch gradient for the mixed full/mini
        regime: (w32, xs, ys, idx (N,B), wt (N,B)) -> (N,d). Per-row
        weights replace the mean so full devices (weight 1/n_m on real
        rows, 0 on duplicates) and mini devices (1/B) share one program."""
        return self._device_grads_at_w

    def device_grads(self, w, xs, ys):
        """xs: (N, n, feat), ys: (N, n) stacked device batches."""
        g = self._device_grads(jnp.asarray(w, jnp.float32),
                               jnp.asarray(xs), jnp.asarray(ys))
        return np.asarray(g, dtype=np.float64)

    def device_grads_at(self, w, xs, ys, idx):
        """Mini-batch gradients on stacked full data + (N, B) indices."""
        g = self._device_grads_at(jnp.asarray(w, jnp.float32),
                                  jnp.asarray(xs), jnp.asarray(ys),
                                  jnp.asarray(idx))
        return np.asarray(g, dtype=np.float64)

    def device_losses(self, w, xs, ys):
        return np.asarray(self._device_losses(jnp.asarray(w, jnp.float32),
                                              jnp.asarray(xs), jnp.asarray(ys)))

    def global_loss(self, w, x, y) -> float:
        return float(self._loss(jnp.asarray(w, jnp.float32),
                                jnp.asarray(x), jnp.asarray(y)))

    def accuracy(self, w, x, y) -> float:
        return float(self._acc(jnp.asarray(w, jnp.float32),
                               jnp.asarray(x), jnp.asarray(y)))

    def grad_norm_at_zero(self, xs, ys) -> np.ndarray:
        """||grad f_m(0)|| per device — for the projection radius D."""
        g = self.device_grads(np.zeros(self.dim), xs, ys)
        return np.linalg.norm(g, axis=1)


class MLPTask:
    """One-hidden-layer MLP + l2 reg: smooth non-convex task (Sec. V-B)."""

    def __init__(self, n_features: int, hidden: int = 64, n_classes: int = 10,
                 mu_nc: float = 0.01, g_max: float = 49.0, seed: int = 0):
        self.n_features, self.hidden, self.n_classes = n_features, hidden, n_classes
        self.mu_nc, self.g_max = mu_nc, g_max
        self.dim = (n_features * hidden + hidden) + (hidden * n_classes + n_classes)
        self._seed = seed

        def unpack(w):
            i = 0
            W1 = w[i:i + n_features * hidden].reshape(n_features, hidden)
            i += n_features * hidden
            b1 = w[i:i + hidden]; i += hidden
            W2 = w[i:i + hidden * n_classes].reshape(hidden, n_classes)
            i += hidden * n_classes
            b2 = w[i:i + n_classes]
            return W1, b1, W2, b2

        def loss(w_flat, x, y):
            W1, b1, W2, b2 = unpack(w_flat)
            hdn = jax.nn.relu(x @ W1 + b1)
            logits = hdn @ W2 + b2
            logp = jax.nn.log_softmax(logits, axis=-1)
            nll = -jnp.mean(logp[jnp.arange(x.shape[0]), y])
            return nll + 0.5 * mu_nc * jnp.sum(w_flat ** 2)

        self._loss = jax.jit(loss)
        grad1 = jax.grad(loss)

        def device_grad(w_flat, x, y):
            return _clip_to(grad1(w_flat, x, y), g_max)

        self._device_grads = jax.jit(jax.vmap(device_grad, in_axes=(None, 0, 0)))
        self._device_grads_at = jax.jit(
            jax.vmap(_device_grad_at(device_grad), in_axes=(None, 0, 0, 0)))

        def loss_w(w_flat, x, y, wt):
            W1, b1, W2, b2 = unpack(w_flat)
            hdn = jax.nn.relu(x @ W1 + b1)
            logits = hdn @ W2 + b2
            logp = jax.nn.log_softmax(logits, axis=-1)
            nll = -(wt * logp[jnp.arange(x.shape[0]), y]).sum()
            return nll + 0.5 * mu_nc * jnp.sum(w_flat ** 2)

        grad1_w = jax.grad(loss_w)

        def device_grad_w(w_flat, x, y, wt):
            return _clip_to(grad1_w(w_flat, x, y, wt), g_max)

        self._device_grads_at_w = jax.jit(
            jax.vmap(_device_grad_at_weighted(device_grad_w),
                     in_axes=(None, 0, 0, 0, 0)))

        def acc(w_flat, x, y):
            W1, b1, W2, b2 = unpack(w_flat)
            logits = jax.nn.relu(x @ W1 + b1) @ W2 + b2
            return jnp.mean((jnp.argmax(logits, -1) == y).astype(jnp.float32))

        self._acc = jax.jit(acc)
        self._unpack = unpack

    def init_params(self, seed: Optional[int] = None) -> np.ndarray:
        rng = np.random.default_rng(self._seed if seed is None else seed)
        w = np.zeros(self.dim)
        w1 = rng.normal(scale=np.sqrt(2.0 / self.n_features),
                        size=self.n_features * self.hidden)
        w2 = rng.normal(scale=np.sqrt(2.0 / self.hidden),
                        size=self.hidden * self.n_classes)
        w[:w1.shape[0]] = w1
        w[self.n_features * self.hidden + self.hidden:
          self.n_features * self.hidden + self.hidden + w2.shape[0]] = w2
        return w

    @property
    def loss_fn(self):
        """Jitted pure loss (w32, x, y) -> scalar, for jit/vmap composition."""
        return self._loss

    @property
    def accuracy_fn(self):
        """Jitted pure accuracy (w32, x, y) -> scalar."""
        return self._acc

    @property
    def device_grads_fn(self):
        """Jitted vmapped per-device clipped gradient (w32, xs, ys) -> (N,d)."""
        return self._device_grads

    @property
    def device_grads_at_fn(self):
        """Jitted mini-batch gradient (w32, xs (N,n,f), ys, idx (N,B)) ->
        (N,d): gathers each device's batch by index, then the clipped grad."""
        return self._device_grads_at

    @property
    def device_grads_at_weighted_fn(self):
        """Jitted weighted mini-batch gradient for the mixed full/mini
        regime: (w32, xs, ys, idx (N,B), wt (N,B)) -> (N,d). Per-row
        weights replace the mean so full devices (weight 1/n_m on real
        rows, 0 on duplicates) and mini devices (1/B) share one program."""
        return self._device_grads_at_w

    def device_grads(self, w, xs, ys):
        g = self._device_grads(jnp.asarray(w, jnp.float32),
                               jnp.asarray(xs), jnp.asarray(ys))
        return np.asarray(g, dtype=np.float64)

    def device_grads_at(self, w, xs, ys, idx):
        """Mini-batch gradients on stacked full data + (N, B) indices."""
        g = self._device_grads_at(jnp.asarray(w, jnp.float32),
                                  jnp.asarray(xs), jnp.asarray(ys),
                                  jnp.asarray(idx))
        return np.asarray(g, dtype=np.float64)

    def global_loss(self, w, x, y) -> float:
        return float(self._loss(jnp.asarray(w, jnp.float32),
                                jnp.asarray(x), jnp.asarray(y)))

    def accuracy(self, w, x, y) -> float:
        return float(self._acc(jnp.asarray(w, jnp.float32),
                               jnp.asarray(x), jnp.asarray(y)))


class SyntheticHighDimTask:
    """Payload-scale synthetic task: f_m(w) = 1/2 ||w - c_m||^2 per device.

    Built for the large-d kernel harness (d up to 10^7): gradients are
    O(d) closed-form (``clip(w - c_m)``), so the bench can stream per-device
    gradient chunks without holding a dataset of comparable size. The
    device "data" is just its integer id — ``device_data`` returns
    (N, 1, 1) xs carrying the id and dummy (N, 1) ys — and each center
    c_m is a counter-based threefry normal keyed on (seed, m), generated
    on demand inside the jit. Exposes the same ``device_grads_fn`` /
    ``device_grads_at_fn`` protocol as the learning tasks so it can drive
    the engine or the bench interchangeably.
    """

    def __init__(self, dim: int, g_max: float = 1e9, seed: int = 0):
        self.dim = dim
        self.g_max = g_max
        self._seed = seed
        base = jax.random.PRNGKey(seed)

        def center(dev_id):
            return jax.random.normal(jax.random.fold_in(base, dev_id),
                                     (dim,), dtype=jnp.float32)

        def loss(w_flat, x, y):
            c = center(x[0, 0].astype(jnp.int32))
            return 0.5 * jnp.sum((w_flat - c) ** 2)

        def device_grad(w_flat, x, y):
            c = center(x[0, 0].astype(jnp.int32))
            return _clip_to(w_flat - c, g_max)

        self._loss = jax.jit(loss)
        self._device_grads = jax.jit(jax.vmap(device_grad,
                                              in_axes=(None, 0, 0)))
        self._device_grads_at = jax.jit(
            jax.vmap(_device_grad_at(device_grad), in_axes=(None, 0, 0, 0)))
        self._acc = jax.jit(lambda w_flat, x, y: jnp.float32(0.0))

    def init_params(self, seed: int = 0) -> np.ndarray:
        return np.zeros(self.dim, dtype=np.float64)

    def device_data(self, n_devices: int):
        """(xs, ys) stand-in dataset: xs[m] = [[m]] (the id), ys dummy."""
        xs = np.arange(n_devices, dtype=np.float32).reshape(n_devices, 1, 1)
        ys = np.zeros((n_devices, 1), dtype=np.int32)
        return xs, ys

    @property
    def loss_fn(self):
        return self._loss

    @property
    def accuracy_fn(self):
        return self._acc

    @property
    def device_grads_fn(self):
        return self._device_grads

    @property
    def device_grads_at_fn(self):
        return self._device_grads_at

    def device_grads(self, w, xs, ys):
        g = self._device_grads(jnp.asarray(w, jnp.float32),
                               jnp.asarray(xs), jnp.asarray(ys))
        return np.asarray(g, dtype=np.float64)

    def global_loss(self, w, x, y) -> float:
        return float(self._loss(jnp.asarray(w, jnp.float32),
                                jnp.asarray(x), jnp.asarray(y)))

    def accuracy(self, w, x, y) -> float:
        return 0.0
