from .tasks import SoftmaxRegressionTask, MLPTask
from .trainer import FLTrainer, TrainLog

__all__ = ["SoftmaxRegressionTask", "MLPTask", "FLTrainer", "TrainLog"]
