from .tasks import SoftmaxRegressionTask, MLPTask
from .trainer import FLTrainer, TrainLog
from .engine import FLEngine, JaxAggregator, as_functional, register_port

__all__ = ["SoftmaxRegressionTask", "MLPTask", "FLTrainer", "TrainLog",
           "FLEngine", "JaxAggregator", "as_functional", "register_port"]
