"""Dithered stochastic uniform quantization (QSGD-style) — Sec. II-B.

Device m normalizes its gradient by ||g||_inf and quantizes every entry with
r_m bits using a dithered *stochastic uniform* quantizer.  The payload is
``64 + d*r`` bits (norm in fp64 + d quantized entries).

Quantizer (per coordinate x in [-M, M], M = ||g||_inf, s = 2^r - 1 levels):
    Delta = 2*M / s
    q(x)  = -M + Delta * round_stochastic((x + M) / Delta)
Stochastic rounding makes the quantizer unbiased: E[q(x)|x] = x, and the
error variance is bounded by Delta^2/4 per coordinate, i.e.
    var(g_q | g) <= d * ||g||_inf^2 / (2^r - 1)^2,
which is exactly the bound used in Lemma 2.

Two implementations are provided:
- ``quantize_np``   : numpy (FL simulation path, bit-true payload counting)
- ``quantize_jnp``  : jax.numpy (jit-able; used by the distributed digital
                      aggregator and as the kernel oracle in kernels/ref.py)
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp


def payload_bits(d: int, r: int) -> int:
    """L_m = 64 + d*r bits (norm scalar + quantized entries)."""
    return 64 + d * int(r)


def _levels(r_bits: int) -> int:
    return (1 << int(r_bits)) - 1


def quantize_np(g: np.ndarray, r_bits: int, rng: np.random.Generator) -> np.ndarray:
    """Dithered stochastic uniform quantization, numpy reference.

    Draws the dither from ``rng`` (sequential stream). The FL trainer path
    instead supplies counter-based dither explicitly via
    :func:`quantize_np_dither` so the JAX engine can regenerate the same
    stream per round (see ``core.rngstream``).
    """
    g = np.asarray(g, dtype=np.float64)
    if np.max(np.abs(g)) == 0.0 or r_bits <= 0:
        return np.zeros_like(g)
    return quantize_np_dither(g, r_bits, rng.uniform(size=g.shape))


def quantize_np_dither(g: np.ndarray, r_bits: int,
                       u: np.ndarray) -> np.ndarray:
    """Quantize-dequantize with an explicit dither operand ``u`` (g's shape).

    Same arithmetic as :func:`quantize_np`; ``u`` holds the per-entry
    stochastic-rounding uniforms, so callers control the dither stream.
    """
    g = np.asarray(g, dtype=np.float64)
    m = np.max(np.abs(g))
    if m == 0.0 or r_bits <= 0:
        return np.zeros_like(g)
    s = _levels(r_bits)
    delta = 2.0 * m / s
    x = (g + m) / delta                      # in [0, s]
    lo = np.floor(x)
    frac = x - lo
    up = np.asarray(u, dtype=np.float64) < frac    # stochastic rounding
    q_idx = np.clip(lo + up, 0, s)
    return -m + delta * q_idx


def quantize_jnp(g: jnp.ndarray, r_bits: int, key: jax.Array) -> jnp.ndarray:
    """Dithered stochastic uniform quantization, jax reference (unbiased)."""
    m = jnp.max(jnp.abs(g))
    s = float(_levels(r_bits))
    delta = 2.0 * m / s
    safe_delta = jnp.where(delta > 0, delta, 1.0)
    x = (g + m) / safe_delta
    lo = jnp.floor(x)
    frac = x - lo
    up = (jax.random.uniform(key, g.shape, dtype=g.dtype) < frac).astype(g.dtype)
    q_idx = jnp.clip(lo + up, 0.0, s)
    out = -m + delta * q_idx
    return jnp.where(delta > 0, out, jnp.zeros_like(g))


def quantization_variance_bound(d: int, r_bits: int, g_inf_norm: float) -> float:
    """var(g_q | g) <= d * ||g||_inf^2 / (2^r - 1)^2 (Lemma 2 ingredient)."""
    s = _levels(r_bits)
    return d * (g_inf_norm ** 2) / float(s * s)
