"""Wireless channel substrate: geometry, path loss, Rayleigh block fading.

Implements the network model of Sec. V of the paper:

- N devices deployed i.i.d. uniformly on a disk of radius ``rho_max`` with
  the PS at the center (polar sampling: theta ~ U[0, 2pi), s = rho_max*sqrt(U)).
- Log-distance path loss  PL(s) = PL0 + 10*Omega*log10(s/s0)  [dB], so the
  average channel gain is  Lambda_m = 10^{-PL(s_m)/10}.
- Rayleigh flat block fading: h_{m,t} ~ CN(0, Lambda_m), i.i.d. over rounds,
  constant within a round.  |h|^2 ~ Exp(mean Lambda_m), hence the
  participation probability of a threshold rule |h| >= tau is
  P(|h| >= tau) = exp(-tau^2 / Lambda_m).

Everything is deterministic given a seed; the PS only ever consumes the
*statistical* CSI {Lambda_m} (paper Sec. II footnote 2).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class WirelessConfig:
    """Physical-layer constants (paper Sec. V defaults)."""

    n_devices: int = 50
    rho_max_m: float = 1750.0          # deployment disk radius [m]
    pl0_db: float = 50.0               # reference path loss at s0 [dB]
    pl_exponent: float = 2.2           # Omega
    s0_m: float = 1.0                  # reference distance [m]
    bandwidth_hz: float = 1.0e6        # B
    carrier_hz: float = 2.4e9          # f_c (informational)
    tx_power_dbm: float = 0.0          # P_tx -> E_s = P_tx / B  [J/symbol]
    noise_psd_dbm_hz: float = -173.0   # N0
    seed: int = 0

    @property
    def energy_per_symbol(self) -> float:
        """E_s [Joule/symbol]: average transmit energy per (complex) symbol."""
        p_tx_w = 10.0 ** (self.tx_power_dbm / 10.0) * 1e-3
        return p_tx_w / self.bandwidth_hz

    @property
    def noise_power(self) -> float:
        """N0 [W/Hz] spectral density in linear scale."""
        return 10.0 ** (self.noise_psd_dbm_hz / 10.0) * 1e-3


@dataclasses.dataclass(frozen=True)
class Deployment:
    """A fixed device deployment: distances and average channel gains."""

    distances_m: np.ndarray     # (N,)
    lambdas: np.ndarray         # (N,) average channel gains Lambda_m
    cfg: WirelessConfig

    @property
    def n_devices(self) -> int:
        return int(self.lambdas.shape[0])


def path_loss_db(distance_m: np.ndarray, cfg: WirelessConfig) -> np.ndarray:
    d = np.maximum(np.asarray(distance_m, dtype=np.float64), cfg.s0_m)
    return cfg.pl0_db + 10.0 * cfg.pl_exponent * np.log10(d / cfg.s0_m)


def make_deployment(cfg: WirelessConfig, seed: Optional[int] = None) -> Deployment:
    """Sample a device deployment (fixed for the whole FL run, as in Sec. V)."""
    rng = np.random.default_rng(cfg.seed if seed is None else seed)
    u = rng.uniform(size=cfg.n_devices)
    s = cfg.rho_max_m * np.sqrt(u)
    # polar angle is sampled for completeness/reproducibility of the paper's
    # geometry even though only the radius enters the path loss
    _theta = rng.uniform(0.0, 2.0 * np.pi, size=cfg.n_devices)
    lambdas = 10.0 ** (-path_loss_db(s, cfg) / 10.0)
    return Deployment(distances_m=s, lambdas=lambdas, cfg=cfg)


def sample_fading(lambdas: np.ndarray, seed: int, t: int) -> np.ndarray:
    """Complex h_{m,t} ~ CN(0, Lambda_m) for one round, deterministic in
    (seed, t). Single source of truth for the fading law: the per-round
    ``FadingProcess`` and the batched tensor sampler both call this."""
    rng = np.random.default_rng(np.random.SeedSequence(entropy=(int(seed), int(t))))
    n = lambdas.shape[0]
    scale = np.sqrt(lambdas / 2.0)
    re = rng.normal(size=n) * scale
    im = rng.normal(size=n) * scale
    return re + 1j * im


def sample_fading_jax(key, t, lambdas):
    """Counter-based h_{m,t} ~ CN(0, Lambda_m) for fast-mode engine scans.

    ``key`` is the trial's ``rngstream.stream_base_key(seed, trial,
    FADING_TAG)``; ``t`` may be a traced scalar, so the draw is a pure
    threefry function of ``(seed, trial, t)`` computable inside
    ``lax.scan`` — no per-trial ``sample_fading_batch`` host tensor.
    Same Rayleigh law as :func:`sample_fading` (|h|^2 ~ Exp(Lambda_m)),
    different stream: statistically equivalent to replay, not bit-equal.
    """
    import jax
    import jax.numpy as jnp
    z = jax.random.normal(jax.random.fold_in(key, t),
                          (2,) + jnp.shape(lambdas), dtype=jnp.float64)
    scale = jnp.sqrt(jnp.asarray(lambdas) / 2.0)
    return (z[0] + 1j * z[1]) * scale


def sample_fading_batch(lambdas: np.ndarray, seed: int,
                        rounds: int) -> np.ndarray:
    """Batched fading tensor (T, N): rows t = 0..rounds-1 of the same stream
    ``FadingProcess(dep, seed).sample(t)`` draws, bit-identical.  The JAX
    engine consumes one (trials, T, N) stack of these per Monte-Carlo run."""
    if rounds == 0:
        return np.zeros((0, lambdas.shape[0]), dtype=np.complex128)
    return np.stack([sample_fading(lambdas, seed, t) for t in range(rounds)])


class FadingProcess:
    """Rayleigh block-fading generator, i.i.d. across rounds.

    ``sample(t)`` returns the complex h_{m,t} for round t, deterministic in
    (seed, t) so that independent Monte-Carlo trials just use different
    seeds and rounds never need to be stored.
    """

    def __init__(self, deployment: Deployment, seed: int = 0):
        self._lambdas = deployment.lambdas
        self._seed = seed

    def sample(self, t: int) -> np.ndarray:
        return sample_fading(self._lambdas, self._seed, t)

    def gains(self, t: int) -> np.ndarray:
        """|h_{m,t}| magnitudes for round t."""
        return np.abs(self.sample(t))


def participation_probability(threshold: np.ndarray, lambdas: np.ndarray) -> np.ndarray:
    """P(|h_m| >= threshold_m) = exp(-threshold^2/Lambda) under Rayleigh fading.

    Shared by the digital design statistics (eq. (9) thresholds) and the
    fault layer's deep-fade survival term (``core.faults.survival_prob``).
    """
    thr = np.asarray(threshold, dtype=np.float64)
    return np.exp(-(thr ** 2) / np.asarray(lambdas, dtype=np.float64))
