"""Wireless fault model: outages, erasures, and stragglers as priced bias.

The paper designs a *structured, time-invariant bias* and prices it with
the Theorem-1/2 optimality-error bound; this module supplies the fault
layer that makes the pricing bite. Each round, each device independently

  * **drops out** with probability ``dropout_prob`` (device-side failure:
    compute crash, battery, backhaul loss),
  * suffers a payload **erasure** with probability ``erasure_prob``
    (decoding failure after transmission — latency is still paid),
  * hits a **deep fade** when ``|h_{m,t}| < deep_fade_thresh`` (the
    channel outage the digital threshold rule eq. (9) normally excludes),
  * becomes a **straggler** with probability ``straggler_prob``: its
    uplink takes ``straggler_mult``x longer. With a round deadline
    (``deadline_s``) the straggler's payload misses the round (and the
    round latency is capped at the deadline); without one, the round
    stretches to the straggler's finish time.

The draws are counter-based threefry streams (``core.rngstream.FAULT_TAG``)
— pure functions of ``(seed, trial, round)`` — so both simulation backends
and both RNG execution modes (``rng="replay"``/``"fast"``) see the exact
same fault realizations, bit for bit.

A device that misses the round is handled by the ``on_missing`` policy at
aggregation (implemented gradient-side in ``fl/engine.py`` and
``fl/trainer.py``, upstream of every scheme's combiner so all registered
schemes inherit it):

  * ``"reweight"`` — inverse-propensity weighting: surviving gradients are
    scaled by ``1/q_m`` with ``q_m`` the static survival probability
    (:func:`survival_prob`). Unbiased in expectation (the fault layer adds
    variance, not bias): effective participation stays ``p_m``.
  * ``"zero"`` — the missing payload is zero-filled. The update shrinks
    toward 0 and the effective participation becomes ``p_m * q_m`` — a
    *structured participation bias* the Sec.-IV bound prices via
    ``bounds.effective_participation`` / ``bounds.bias_sum``.
  * ``"stale"`` — the PS reuses the device's last received gradient
    (staleness-as-bias): same participation level, but a time-correlated
    gradient bias the bound does not model — the empirical comparison
    point. Both backends route the replay through the single
    last-gradient code path ``core.async_fl.stale_replace``, shared with
    the buffered-async subsystem that generalizes this policy to a
    last-K staleness buffer with a *priced* stationary staleness
    distribution (``run.mode="async"``, ``core.async_fl``).

Faulted devices keep their reserved TDMA slots / OTA symbols, so
scheme-side latency accounting is unchanged (erasures pay for airtime
they waste); only straggler slowdown and deadline capping modify the
realized round latency.

``FaultSpec`` defaults are a strict no-op: with every knob at its default
both backends take their exact pre-fault code paths, so trajectories are
bit-identical to a build without this module.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from .channel import participation_probability
from .digital import outage_mask

_POLICIES = ("reweight", "zero", "stale")


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """Declarative wireless fault model (pure data, sweepable by axis).

    All probabilities are per device per round, i.i.d. across both.
    """

    dropout_prob: float = 0.0        # device silently absent this round
    erasure_prob: float = 0.0        # payload transmitted but undecodable
    deep_fade_thresh: float = 0.0    # |h| < thresh -> channel outage
    straggler_prob: float = 0.0      # device uplink slowed this round
    straggler_mult: float = 1.0      # straggler slowdown factor (>= 1)
    deadline_s: Optional[float] = None   # round deadline: stragglers miss
    on_missing: str = "reweight"     # "reweight" | "zero" | "stale"

    def __post_init__(self):
        for f in ("dropout_prob", "erasure_prob", "straggler_prob"):
            v = getattr(self, f)
            if not 0.0 <= float(v) <= 1.0:
                raise ValueError(f"fault.{f} must be in [0, 1], got {v!r}")
        if self.deep_fade_thresh < 0.0:
            raise ValueError("fault.deep_fade_thresh must be >= 0, got "
                             f"{self.deep_fade_thresh!r}")
        if self.straggler_mult < 1.0:
            raise ValueError("fault.straggler_mult must be >= 1, got "
                             f"{self.straggler_mult!r}")
        if self.deadline_s is not None and self.deadline_s <= 0.0:
            raise ValueError("fault.deadline_s must be positive or None, "
                             f"got {self.deadline_s!r}")
        if self.on_missing not in _POLICIES:
            raise ValueError(f"fault.on_missing must be one of {_POLICIES}, "
                             f"got {self.on_missing!r}")

    @property
    def enabled(self) -> bool:
        """True iff any knob can change a trajectory. ``straggler_mult``
        alone is inert (it scales the latency of stragglers that never
        occur), preserving the strict-no-op contract for defaults."""
        return (self.dropout_prob > 0.0 or self.erasure_prob > 0.0
                or self.deep_fade_thresh > 0.0 or self.straggler_prob > 0.0
                or self.deadline_s is not None)


def survival_prob(fault: FaultSpec, lambdas: np.ndarray) -> np.ndarray:
    """(N,) per-device round-survival probability q_m.

    Independent fault components compose multiplicatively:
    ``(1 - dropout)(1 - erasure) * P(|h| >= t_f)`` with the Rayleigh
    deep-fade survival ``exp(-t_f^2/Lambda_m)``; under a deadline,
    stragglers also miss, contributing ``(1 - straggler_prob)``. This is
    the static propensity the "reweight" policy inverts and the
    participation factor ``bounds.effective_participation`` prices.
    Floored at 1e-12 so inverse-propensity weights stay finite.
    """
    q = (1.0 - fault.dropout_prob) * (1.0 - fault.erasure_prob)
    q = q * participation_probability(fault.deep_fade_thresh,
                                      np.asarray(lambdas, np.float64))
    if fault.deadline_s is not None:
        q = q * (1.0 - fault.straggler_prob)
    return np.maximum(q, 1e-12)


def effective_lambdas(lambdas: np.ndarray, fault: FaultSpec) -> np.ndarray:
    """Outage-adjusted average channel energies for fault-aware design.

    The design solvers consume statistical CSI {Lambda_m}; under the fault
    layer the energy a device actually *delivers* per round is
    ``E[|h|^2 1{survives}] = q_u (Lambda + t_f^2) exp(-t_f^2/Lambda)``
    (the deep-fade-truncated exponential mean, scaled by the channel-
    independent survival factor q_u). Feeding these into
    ``CellContext.design_spec`` makes the Sec.-IV solves fault-aware
    without touching the solvers. Exactly ``lambdas`` when faults are
    disabled (the strict-no-op contract). Floored at ``1e-12 * Lambda_m``
    so a fade threshold far above a device's channel scale (survival
    underflows to 0) still hands the solvers finite, positive energies —
    the design then just prices that device out.
    """
    lam = np.asarray(lambdas, np.float64)
    if not fault.enabled:
        return lam
    tf2 = float(fault.deep_fade_thresh) ** 2
    q_u = (1.0 - fault.dropout_prob) * (1.0 - fault.erasure_prob)
    if fault.deadline_s is not None:
        q_u = q_u * (1.0 - fault.straggler_prob)
    return np.maximum(q_u * (lam + tf2) * np.exp(-tf2 / lam), 1e-12 * lam)


def fault_masks(u, habs, fault: FaultSpec):
    """Per-round delivery masks from one (3, N) uniform block.

    ``u`` rows are the FAULT-stream uniforms (dropout, erasure, straggler
    — see ``rngstream.fault_block``); ``habs`` the round's |h|. Written
    with operators only, so it runs identically on numpy arrays (oracle)
    and traced jnp arrays (engine scan) — the cross-backend parity point.

    Returns ``(ok, straggler)`` boolean (N,) masks: ``ok`` marks devices
    whose payload reaches the PS this round (deep fades route through the
    same ``digital.outage_mask`` primitive as the threshold rule eq. (9),
    so injected outages and scheme-side in-allocation rules compose in
    one place); ``straggler`` marks slowed devices (they only miss the
    round when a deadline is set).
    """
    dropped = u[0] < fault.dropout_prob
    erased = u[1] < fault.erasure_prob
    straggler = u[2] < fault.straggler_prob
    faded = ~outage_mask(habs, 0.0, deep_fade_thresh=fault.deep_fade_thresh)
    missed = dropped | erased | faded
    if fault.deadline_s is not None:
        missed = missed | straggler
    return ~missed, straggler
