"""Convergence bounds of Theorems 1 and 2 and their bias/variance pieces.

Both theorems share the structure
    error <= initialization + 2 * BIAS + VARWEIGHT * zeta
with
    BIAS        = N * kappa^2 * sum_m (p_m - 1/N)^2     (model-bias term)
    strongly convex (Thm 1):
        E||w_t - w*||^2 <= 2 D^2 (1-eta*mu)^{2t}
                         + 2 N kappa_sc^2/mu^2 * sum (1/N - p)^2
                         + 2 eta/mu * zeta
    non-convex (Thm 2):
        (1/T) sum E||grad F||^2 <= 4 max_m(f_m(w0)-f_m^inf)/(eta T)
                                 + 2 N kappa_nc^2 sum (p-1/N)^2
                                 + 2 eta L zeta

The design objective (15a)/(17a) is  omega_var * zeta + omega_bias * bias_sum
with (Sec. IV footnote 4):
    strongly convex:  (omega_var, omega_bias) = (eta/mu,  N kappa_sc^2/mu^2)
    non-convex:       (omega_var, omega_bias) = (eta L,   N kappa_nc^2)
"""
from __future__ import annotations

import dataclasses

import numpy as np


def bias_sum(p: np.ndarray) -> float:
    """sum_m (p_m - 1/N)^2 — the structured model-bias magnitude."""
    p = np.asarray(p, dtype=np.float64)
    n = p.shape[0]
    return float(np.sum((p - 1.0 / n) ** 2))


def effective_participation(p: np.ndarray, q: np.ndarray,
                            on_missing: str = "reweight",
                            pi=None) -> np.ndarray:
    """Participation levels under the fault + sampling layers.

    ``p`` are the designed participation levels (E[chi]/nu), ``q`` the
    per-device round-survival probabilities
    (``core.faults.survival_prob``), ``pi`` the optional Bernoulli
    client-sampling inclusion probabilities
    (``core.participation``, sum_m pi_m = S). The Theorem-1/2 bias term
    prices every participation shift by evaluating :func:`bias_sum` on
    the *effective* levels returned here.

    Fault degradation policy (``on_missing``):

      * ``"reweight"`` — inverse-propensity weighting restores the mean:
        the fault factor is 1 (faults add variance, not bias).
      * ``"zero"`` — missing payloads are zero-filled, shrinking device m
        by its survival rate: factor ``q`` — the priced outage bias.
      * ``"stale"`` — the last received gradient stands in, so the
        participation *level* keeps factor 1; the staleness of the
        gradient itself is a time-correlated bias outside the bound's
        model (see ``core.faults`` — the empirical comparison point).
        The buffered-async mode (``core.async_fl``) is the regime where
        staleness *is* priced: its stationary staleness distribution
        tilts the levels by a static factor, see
        :func:`async_effective_participation`.

    Sampling factor: included payloads are scaled by the uniform inverse
    propensity N/S, so device m's level tilts by ``pi_m * N / S``
    (exactly 1 under the zero-bias uniform policy pi = S/N). Faults and
    sampling are independent per round, so the factors compose
    multiplicatively — ``p * pi * q`` up to the N/S scale.
    """
    p = np.asarray(p, dtype=np.float64)
    q = np.asarray(q, dtype=np.float64)
    if on_missing == "zero":
        eff = p * q
    elif on_missing in ("reweight", "stale"):
        eff = p.copy()
    else:
        raise ValueError(f"unknown on_missing policy {on_missing!r}")
    if pi is not None:
        pi = np.asarray(pi, dtype=np.float64)
        eff = eff * pi * (pi.shape[0] / np.sum(pi))
    return eff


def async_effective_participation(p: np.ndarray, c: np.ndarray,
                                  weights=None) -> np.ndarray:
    """Participation levels under buffered-async delivery.

    ``p`` are the (possibly fault/sampling-tilted) participation levels,
    ``c`` the per-device async delivery weights
    ``c_m = E[delta^S ; delivered]`` (``core.async_fl.delivery_weight``)
    and ``weights`` the optional PS per-device weights v (uniform 1 when
    None). The async layer scales device m's payload by
    ``v_m * N / sum(c v)`` — expected delivered mass normalized to N —
    so the *stationary* staleness distribution shifts the levels to

        e_m = p_m * c_m * v_m * N / sum_j(c_j v_j),

    a static, structured tilt the Theorem-1/2 bias term prices via
    :func:`bias_sum` on the levels returned here, composing with the
    fault (q) and sampling (pi) factors of
    :func:`effective_participation` that already shaped ``p``.
    """
    p = np.asarray(p, dtype=np.float64)
    c = np.asarray(c, dtype=np.float64)
    n = p.shape[0]
    v = np.ones(n) if weights is None else np.asarray(weights, np.float64)
    return p * c * v * (n / float(np.sum(c * v)))


def async_bias_sum(p: np.ndarray, c: np.ndarray, weights=None) -> float:
    """:func:`bias_sum` of the async effective levels — the model-bias
    magnitude the buffered-async mode's staleness distribution induces."""
    return bias_sum(async_effective_participation(p, c, weights))


@dataclasses.dataclass(frozen=True)
class ObjectiveWeights:
    """(omega_var, omega_bias) per Sec. IV footnote 4."""

    omega_var: float
    omega_bias: float

    @classmethod
    def strongly_convex(cls, eta: float, mu: float, kappa_sc: float, n: int):
        return cls(omega_var=eta / mu, omega_bias=n * kappa_sc ** 2 / mu ** 2)

    @classmethod
    def non_convex(cls, eta: float, smooth_l: float, kappa_nc: float, n: int):
        return cls(omega_var=eta * smooth_l, omega_bias=n * kappa_nc ** 2)


def design_objective(p: np.ndarray, zeta: float, w: ObjectiveWeights) -> float:
    """omega_var * zeta + omega_bias * sum (p - 1/N)^2 (eq. (15a)/(17a))."""
    return w.omega_var * zeta + w.omega_bias * bias_sum(p)


def theorem1_bound(t: int, *, eta: float, mu: float, diam: float,
                   kappa_sc: float, p: np.ndarray, zeta: float) -> dict:
    """Theorem 1 optimality-error bound after t rounds (strongly convex)."""
    n = np.asarray(p).shape[0]
    init = 2.0 * diam ** 2 * (1.0 - eta * mu) ** (2 * t)
    bias = 2.0 * n * kappa_sc ** 2 / mu ** 2 * bias_sum(p)
    var = 2.0 * eta / mu * zeta
    return {"initialization": init, "bias": bias, "variance": var,
            "total": init + bias + var}


def theorem2_bound(T: int, *, eta: float, smooth_l: float, f_gap0: float,
                   kappa_nc: float, p: np.ndarray, zeta: float) -> dict:
    """Theorem 2 average-stationarity bound after T rounds (non-convex)."""
    n = np.asarray(p).shape[0]
    init = 4.0 * f_gap0 / (eta * T)
    bias = 2.0 * n * kappa_nc ** 2 * bias_sum(p)
    var = 2.0 * eta * smooth_l * zeta
    return {"initialization": init, "bias": bias, "variance": var,
            "total": init + bias + var}


def projection_radius(grad_norms_at_zero: np.ndarray, mu: float) -> float:
    """D = 2 max_m ||grad f_m(0)||/mu — diameter of the feasible ball W."""
    return 2.0 * float(np.max(grad_norms_at_zero)) / mu
