"""Digital-FL parameter design — problem (17) and its SCA surrogate (18).

Variables (flat): x = [p(N), nu(N), r'(N), R(N), z(N), varpi(N), t(N)].

Physical couplings used by the projection step (restoring exact
feasibility of (17c)-(17d) after each inner solve):
    beta = p * nu  (clipped to (0,1)),   rho = sqrt(-Lambda ln beta),
    R = log2(1 + E_s rho^2/N0),          nu = beta / p,
    t = (64 + d(r'+1)) beta / (B R),     z = p/nu,
    varpi = p / (nu (2*2^{r'} - 1)^2).
If the projected point violates the latency budget (17b), thresholds are
raised (rho^2 *= kappa, bisected) — this lowers beta and raises R, both of
which shrink latency, while p (and hence the designed bias) is unchanged
since nu re-compensates.

Solvers:
  * ``design_digital_sca``    — paper-faithful Sec. IV-B SCA on (18).
  * ``design_digital_direct`` — beyond-paper: SLSQP on the original (17)
    over the reduced variables (p, beta, r) (nu, R, t are pinned by the
    couplings), relaxing r to a continuum.
  * ``design_digital_batch``  — a whole sweep grid of (17) instances in
    one batched jit (``core.sca_jax`` penalty solver over the same reduced
    variables); specs stacked via ``stack_digital_specs``. The SciPy
    paths stay the trusted oracle.
All finalize r_m = floor(r') + 1 (paper's rule) and re-verify latency.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np
from scipy import optimize

from .bounds import ObjectiveWeights, bias_sum
from .digital import DigitalParams
from .sca import SCAResult, SurrogateProblem, run_sca, simplex_projection

_LN2 = float(np.log(2.0))


@dataclasses.dataclass(frozen=True)
class DigitalDesignSpec:
    lambdas: np.ndarray
    dim: int
    g_max: float
    e_s: float
    n0: float
    bandwidth_hz: float
    t_max_s: float
    weights: ObjectiveWeights
    r_max: int = 16
    sigma_sq: Optional[np.ndarray] = None

    @property
    def n(self) -> int:
        return int(self.lambdas.shape[0])

    @property
    def sigmas2(self) -> np.ndarray:
        if self.sigma_sq is None:
            return np.zeros(self.n)
        return np.asarray(self.sigma_sq, dtype=np.float64)

    @property
    def snr_gain(self) -> np.ndarray:
        """Lambda_m * E_s / N0 — SNR at |h|^2 = Lambda."""
        return np.asarray(self.lambdas) * self.e_s / self.n0


# ------------------------------------------------------------ primitives

def _rate_from_beta(spec: DigitalDesignSpec, beta: np.ndarray) -> np.ndarray:
    """R = log2(1 + E_s rho^2/N0) with rho^2 = -Lambda ln beta."""
    snr = -spec.snr_gain * np.log(np.clip(beta, 1e-300, 1.0))
    return np.log2(1.0 + np.maximum(snr, 0.0))


def _latency(spec: DigitalDesignSpec, beta: np.ndarray,
             r_cont: np.ndarray) -> float:
    """Expected round latency (12) with continuous bits r'=r-1."""
    payload = 64.0 + spec.dim * (r_cont + 1.0)
    rate = np.maximum(_rate_from_beta(spec, beta), 1e-9)
    return float(np.sum(beta * payload / (spec.bandwidth_hz * rate)))


def true_objective(spec: DigitalDesignSpec, p: np.ndarray, beta: np.ndarray,
                   r_cont: np.ndarray) -> float:
    """Original objective (17a) at integer-relaxed bits r = r'+1."""
    g2 = spec.g_max ** 2
    s = (2.0 ** (r_cont + 1.0) - 1.0) ** 2
    zeta = np.sum(p ** 2 * g2 * (1.0 / beta - 1.0 + spec.dim / (beta * s)))
    zeta += np.sum(p ** 2 * spec.sigmas2)
    return spec.weights.omega_var * float(zeta) + spec.weights.omega_bias * bias_sum(p)


def _fit_latency(spec: DigitalDesignSpec, beta: np.ndarray,
                 r_cont: np.ndarray) -> np.ndarray:
    """Raise thresholds (scale rho^2) until the latency budget (17b) holds."""
    if _latency(spec, beta, r_cont) <= spec.t_max_s:
        return beta
    lo, hi = 1.0, 1.0
    while _latency(spec, beta ** hi, r_cont) > spec.t_max_s and hi < 1e6:
        hi *= 2.0
    for _ in range(100):
        mid = 0.5 * (lo + hi)
        if _latency(spec, beta ** mid, r_cont) > spec.t_max_s:
            lo = mid
        else:
            hi = mid
    return beta ** hi


def params_from(spec: DigitalDesignSpec, p: np.ndarray, beta: np.ndarray,
                r_bits: np.ndarray) -> DigitalParams:
    beta = np.clip(beta, 1e-12, 1.0 - 1e-12)
    rhos = np.sqrt(-np.asarray(spec.lambdas) * np.log(beta))
    nus = beta / p
    return DigitalParams(rhos=rhos, nus=nus,
                         r_bits=np.asarray(r_bits, dtype=np.int64),
                         g_max=spec.g_max, dim=spec.dim,
                         energy_per_symbol=spec.e_s, noise_psd=spec.n0,
                         bandwidth_hz=spec.bandwidth_hz)


def finalize(spec: DigitalDesignSpec, p: np.ndarray, beta: np.ndarray,
             r_cont: np.ndarray) -> DigitalParams:
    """Paper's integer rule r = floor(r')+1, then re-fit latency."""
    r_bits = np.clip(np.floor(r_cont).astype(np.int64) + 1, 1, spec.r_max)
    beta = _fit_latency(spec, np.clip(beta, 1e-12, 1 - 1e-12),
                        r_bits.astype(np.float64) - 1.0)
    return params_from(spec, p, beta, r_bits)


# ---------------------------------------------------------------- anchors

def anchor_uniform(spec: DigitalDesignSpec, beta0: float = 0.8
                   ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """p = 1/N, common beta, max bits fitting 0.9*Tmax."""
    n = spec.n
    p = np.full(n, 1.0 / n)
    beta = np.full(n, beta0)
    r_cont = np.full(n, 0.5)
    for r in range(spec.r_max - 1, 0, -1):
        cand = np.full(n, float(r) - 0.5)
        if _latency(spec, beta, cand) <= 0.9 * spec.t_max_s:
            r_cont = cand
            break
    beta = _fit_latency(spec, beta, r_cont)
    return p, beta, r_cont


def anchor_channel_weighted(spec: DigitalDesignSpec, expo: float = 0.3
                            ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Bias participation toward strong channels: p ∝ Lambda^expo."""
    p = np.asarray(spec.lambdas) ** expo
    p = p / np.sum(p)
    _, beta, r_cont = anchor_uniform(spec)
    return p, beta, r_cont


# ------------------------------------------------------------- SCA (paper)

def _pack(p, nu, r, R, z, w, t):
    return np.concatenate([p, nu, r, R, z, w, t])


def _unpack(x, n):
    return (x[:n], x[n:2 * n], x[2 * n:3 * n], x[3 * n:4 * n],
            x[4 * n:5 * n], x[5 * n:6 * n], x[6 * n:7 * n])


def design_digital_sca(spec: DigitalDesignSpec, *, n_iters: int = 12,
                       anchor: Optional[tuple] = None
                       ) -> tuple[DigitalParams, SCAResult]:
    n = spec.n
    g2 = spec.g_max ** 2
    wv, wb = spec.weights.omega_var, spec.weights.omega_bias
    s2 = spec.sigmas2
    d = float(spec.dim)
    B = spec.bandwidth_hz
    snr_gain = spec.snr_gain

    def project(x: np.ndarray) -> np.ndarray:
        p, nu, r, R, z, w, t = _unpack(x, n)
        p = simplex_projection(np.clip(p, 1e-8, 1.0))
        p = np.clip(p, 1e-10, 1.0)
        p = p / np.sum(p)
        r = np.clip(r, 0.5, spec.r_max - 1.0)
        beta = np.clip(p * np.clip(nu, 1e-9, None), 1e-9, 1.0 - 1e-9)
        beta = _fit_latency(spec, beta, r)
        nu = beta / p
        R = np.maximum(_rate_from_beta(spec, beta), 1e-6)
        t = (64.0 + d * (r + 1.0)) * beta / (B * R)
        z = p / nu
        w = p / (nu * (2.0 * 2.0 ** r - 1.0) ** 2)
        return _pack(p, nu, r, R, z, w, t)

    def true_obj(x: np.ndarray) -> float:
        p, nu, r, _R, _z, _w, _t = _unpack(x, n)
        beta = np.clip(p * nu, 1e-12, 1.0 - 1e-12)
        return true_objective(spec, p, beta, r)

    def build(xbar: np.ndarray) -> SurrogateProblem:
        pb, nub, rb, Rb, zb, wbar, tb = _unpack(xbar, n)
        payload_b = 64.0 + d + d * rb

        def f(x):
            p, nu, r, R, z, w, t = _unpack(x, n)
            return (wv * (np.sum(g2 * (z + d * w)) + np.sum(p ** 2 * s2)
                          - np.sum(g2 * pb * (2 * p - pb)))
                    + wb * np.sum((p - 1.0 / n) ** 2))

        def fgrad(x):
            p, nu, r, R, z, w, t = _unpack(x, n)
            gr = np.zeros_like(x)
            gr[:n] = wv * (2 * p * s2 - 2 * g2 * pb) + 2 * wb * (p - 1.0 / n)
            gr[4 * n:5 * n] = wv * g2
            gr[5 * n:6 * n] = wv * g2 * d
            return gr

        def cb(x):   # (18b)
            p, nu, r, R, z, w, t = _unpack(x, n)
            return np.log(z) + np.log(nu) - np.log(pb) - (p - pb) / pb

        def cbj(x):
            p, nu, r, R, z, w, t = _unpack(x, n)
            J = np.zeros((n, 7 * n))
            J[:, :n] = np.diag(-1.0 / pb)
            J[:, n:2 * n] = np.diag(1.0 / nu)
            J[:, 4 * n:5 * n] = np.diag(1.0 / z)
            return J

        def cc(x):   # (18c)
            p, nu, r, R, z, w, t = _unpack(x, n)
            u = 2.0 * 2.0 ** r - 1.0
            return (np.log(w) + np.log(nu) + 2.0 * np.log(u)
                    - np.log(pb) - (p - pb) / pb)

        def ccj(x):
            p, nu, r, R, z, w, t = _unpack(x, n)
            u = 2.0 * 2.0 ** r - 1.0
            J = np.zeros((n, 7 * n))
            J[:, :n] = np.diag(-1.0 / pb)
            J[:, n:2 * n] = np.diag(1.0 / nu)
            J[:, 2 * n:3 * n] = np.diag(2.0 * (2.0 * 2.0 ** r * _LN2) / u)
            J[:, 5 * n:6 * n] = np.diag(1.0 / w)
            return J

        def cd(x):   # (18d) latency per-device epigraph
            p, nu, r, R, z, w, t = _unpack(x, n)
            lhs = (np.log(nub) + np.log(payload_b) + np.log(pb)
                   + (nu - nub) / nub + d * (r - rb) / payload_b
                   + (p - pb) / pb)
            return np.log(t) + np.log(R * B) - lhs

        def cdj(x):
            p, nu, r, R, z, w, t = _unpack(x, n)
            J = np.zeros((n, 7 * n))
            J[:, :n] = np.diag(-1.0 / pb)
            J[:, n:2 * n] = np.diag(-1.0 / nub)
            J[:, 2 * n:3 * n] = np.diag(-d / payload_b)
            J[:, 3 * n:4 * n] = np.diag(1.0 / R)
            J[:, 6 * n:7 * n] = np.diag(1.0 / t)
            return J

        def ce(x):   # (18e) rate-SNR coupling
            p, nu, r, R, z, w, t = _unpack(x, n)
            lin = np.log(nub) + nu / nub + np.log(pb) + p / pb - 2.0
            return 1.0 - snr_gain * lin - 2.0 ** R

        def cej(x):
            p, nu, r, R, z, w, t = _unpack(x, n)
            J = np.zeros((n, 7 * n))
            J[:, :n] = np.diag(-snr_gain / pb)
            J[:, n:2 * n] = np.diag(-snr_gain / nub)
            J[:, 3 * n:4 * n] = np.diag(-(2.0 ** R) * _LN2)
            return J

        def cf(x):   # (18f)
            return np.array([spec.t_max_s - np.sum(_unpack(x, n)[6])])

        def cfj(x):
            J = np.zeros((1, 7 * n))
            J[0, 6 * n:7 * n] = -1.0
            return J

        def cg(x):   # (18g) nu <= (2 pb - p)/pb^2
            p, nu, r, R, z, w, t = _unpack(x, n)
            return (2.0 * pb - p) / pb ** 2 - nu

        def cgj(x):
            J = np.zeros((n, 7 * n))
            J[:, :n] = np.diag(-1.0 / pb ** 2)
            J[:, n:2 * n] = -np.eye(n)
            return J

        def eq(x):
            return np.array([np.sum(x[:n]) - 1.0])

        def eqj(x):
            J = np.zeros((1, 7 * n))
            J[0, :n] = 1.0
            return J

        bnds = ([(1e-8, 1.0)] * n                       # p
                + [(1e-6, 4.0 * n)] * n                 # nu
                + [(0.5, spec.r_max - 1.0)] * n         # r'
                + [(1e-3, 40.0)] * n                    # R
                + [(1e-12, 2.0)] * n                    # z
                + [(1e-16, 2.0)] * n                    # varpi
                + [(1e-9, spec.t_max_s)] * n)           # t
        return SurrogateProblem(
            objective=f, grad=fgrad,
            ineq_constraints=[
                {"type": "ineq", "fun": cb, "jac": cbj},
                {"type": "ineq", "fun": cc, "jac": ccj},
                {"type": "ineq", "fun": cd, "jac": cdj},
                {"type": "ineq", "fun": ce, "jac": cej},
                {"type": "ineq", "fun": cf, "jac": cfj},
                {"type": "ineq", "fun": cg, "jac": cgj},
            ],
            eq_constraints=[{"type": "eq", "fun": eq, "jac": eqj}],
            bounds=bnds, x0=xbar.copy())

    if anchor is None:
        anchor = anchor_uniform(spec)
    p0, beta0, r0 = anchor
    nu0 = beta0 / p0
    R0 = np.maximum(_rate_from_beta(spec, beta0), 1e-6) * (1.0 - 1e-9)
    t0 = (64.0 + d * (r0 + 1.0)) * beta0 / (B * R0)
    z0 = p0 / nu0
    w0 = p0 / (nu0 * (2.0 * 2.0 ** r0 - 1.0) ** 2)
    x0 = _pack(p0, nu0, r0, R0, z0, w0, t0)
    res = run_sca(build, true_obj, project, x0, n_iters=n_iters)
    p, nu, r, _, _, _, _ = _unpack(res.x, n)
    beta = np.clip(p * nu, 1e-12, 1 - 1e-12)
    return finalize(spec, p, beta, r), res


# -------------------------------------------------------- direct (beyond)

def design_digital_direct(spec: DigitalDesignSpec, *, maxiter: int = 400
                          ) -> tuple[DigitalParams, float]:
    """Beyond-paper: SLSQP on the original (17) over (p, beta, r')."""
    n = spec.n
    d = float(spec.dim)
    B = spec.bandwidth_hz

    def split(x):
        return x[:n], np.clip(x[n:2 * n], 1e-9, 1 - 1e-9), x[2 * n:3 * n]

    def f(x):
        p, beta, r = split(x)
        return true_objective(spec, p, beta, r)

    def lat(x):
        p, beta, r = split(x)
        return np.array([spec.t_max_s - _latency(spec, beta, r)])

    def eq(x):
        return np.array([np.sum(x[:n]) - 1.0])

    def solve_from(p0, b0, r0):
        x0 = np.concatenate([p0, b0, r0])
        scale = 1.0 / max(abs(f(x0)), 1e-30)
        # anchor betas from _fit_latency can undershoot the box (SLSQP
        # would clip internally, warning); the scale above is evaluated at
        # the raw anchor so the explicit clip is solution-preserving
        lo = np.array([1e-8] * n + [1e-6] * n + [0.5] * n)
        hi = np.array([1.0] * n + [1 - 1e-9] * n + [spec.r_max - 1.0] * n)
        x0 = np.clip(x0, lo, hi)
        res = optimize.minimize(
            lambda x: scale * f(x), x0, method="SLSQP",
            bounds=([(1e-8, 1.0)] * n + [(1e-6, 1 - 1e-9)] * n
                    + [(0.5, spec.r_max - 1.0)] * n),
            constraints=[{"type": "ineq", "fun": lat},
                         {"type": "eq", "fun": eq}],
            options={"maxiter": maxiter, "ftol": 1e-14})
        return res.fun / scale, res.x

    # anchors: uniform, channel-weighted, and a few bit-widths with fitted
    # thresholds — the reduced problem is still non-convex and SLSQP is local
    anchors = [anchor_uniform(spec), anchor_channel_weighted(spec)]
    for r_try in (4.5, 7.5, 10.5):
        b0 = _fit_latency(spec, np.full(n, 0.5), np.full(n, r_try))
        anchors.append((np.full(n, 1.0 / n), b0, np.full(n, r_try)))
    best_x, best_f = None, np.inf
    for p0, b0, r0 in anchors:
        fv, xv = solve_from(p0, b0, r0)
        if fv < best_f and np.all(np.isfinite(xv)):
            best_f, best_x = float(fv), xv
    p, beta, r = split(best_x)
    p = simplex_projection(p)
    p = np.clip(p, 1e-10, 1)
    p /= p.sum()
    return finalize(spec, p, beta, r), best_f


# ------------------------------------------------------- batched (jax)

def default_anchors(spec: DigitalDesignSpec) -> np.ndarray:
    """(A, 3N) packed (p, beta, r') anchors: the direct solver's set."""
    n = spec.n
    anchors = [anchor_uniform(spec), anchor_channel_weighted(spec)]
    for r_try in (4.5, 7.5, 10.5):
        b0 = _fit_latency(spec, np.full(n, 0.5), np.full(n, r_try))
        anchors.append((np.full(n, 1.0 / n), b0, np.full(n, r_try)))
    return np.stack([np.concatenate(a) for a in anchors])


def stack_digital_specs(specs: Sequence[DigitalDesignSpec]) -> dict:
    """Stack B design specs along a leading axis for the batched solver."""
    n = specs[0].n
    if any(s.n != n for s in specs):
        raise ValueError("all specs in a batch must share the device count")
    return {
        "lambdas": np.stack([np.asarray(s.lambdas, np.float64)
                             for s in specs]),
        "dim": np.array([float(s.dim) for s in specs]),
        "g_max": np.array([s.g_max for s in specs]),
        "e_s": np.array([s.e_s for s in specs]),
        "n0": np.array([s.n0 for s in specs]),
        "bandwidth_hz": np.array([s.bandwidth_hz for s in specs]),
        "t_max_s": np.array([s.t_max_s for s in specs]),
        "r_max": np.array([float(s.r_max) for s in specs]),
        "omega_var": np.array([s.weights.omega_var for s in specs]),
        "omega_bias": np.array([s.weights.omega_bias for s in specs]),
        "sigma_sq": np.stack([s.sigmas2 for s in specs]),
    }


def design_digital_batch(specs: Sequence[DigitalDesignSpec],
                         anchors: Optional[np.ndarray] = None
                         ) -> tuple[list[DigitalParams], np.ndarray]:
    """Solve a grid of digital design problems (17) in one batched jit.

    The JAX counterpart of calling ``design_digital_sca`` per point:
    penalty/projection Adam on the reduced variables (p, beta, r') with
    the latency budget (17b) restored exactly after every stage
    (``core.sca_jax``). Per-point params go through the same ``finalize``
    integer-bits rule as the SciPy solvers.

    Returns (params, objectives): per-point ``DigitalParams`` and the (B,)
    continuous-relaxed true objectives (17a) — the same convention as
    ``design_digital_sca``'s ``SCAResult.objective``.
    """
    from . import sca_jax

    if anchors is None:
        anchors = np.stack([default_anchors(s) for s in specs])
    stk = stack_digital_specs(specs)
    xs, objs = sca_jax.solve_digital_batch(
        stk["lambdas"], stk["dim"], stk["g_max"], stk["e_s"], stk["n0"],
        stk["bandwidth_hz"], stk["t_max_s"], stk["r_max"],
        stk["omega_var"], stk["omega_bias"], stk["sigma_sq"], anchors)
    n = specs[0].n
    params = []
    for s, x in zip(specs, xs):
        p, beta, r = x[:n], x[n:2 * n], x[2 * n:]
        params.append(finalize(s, p, np.clip(beta, 1e-12, 1 - 1e-12), r))
    return params, objs


def design_digital_participation(spec: DigitalDesignSpec,
                                 params: DigitalParams, clients: int, *,
                                 survival=None) -> tuple[np.ndarray, float]:
    """Co-designed Bernoulli inclusion probabilities pi, digital family.

    Same sampling problem as ``ota_design.design_ota_participation`` but
    with the digital scheme's effective levels ``p_m = beta_m/nu_m``
    (``DigitalParams.participation_levels``). Returns (pi, objective).
    """
    from . import sca_jax

    p = np.asarray(params.participation_levels(spec.lambdas), np.float64)
    q = (np.ones_like(p) if survival is None
         else np.asarray(survival, np.float64))
    pi, obj = sca_jax.solve_participation_batch(
        p[None], q[None], [clients],
        [spec.weights.omega_var], [spec.weights.omega_bias])
    return pi[0], float(obj[0])
