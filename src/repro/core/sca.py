"""Successive convex approximation (SCA) driver — Sec. IV.

The paper solves problems (15) (OTA) and (17) (digital) by iteratively
solving the convex surrogates (16)/(18) obtained by linearizing the
non-convex pieces around the current iterate ("anchor"), then re-anchoring
at the solution (Marks & Wright inner approximation; converges to a
stationary point of the original problem).

The paper uses CVX; offline here we solve each (smooth, small) surrogate
with SciPy SLSQP, which handles nonlinear inequality + equality constraints
directly. Each design module supplies:
  - ``build(anchor) -> SurrogateProblem``  (objective/constraints/bounds)
  - ``true_objective(x) -> float``          (original objective (15a)/(17a))
  - ``project(x) -> x``                     (restore exact feasibility of the
                                             physical couplings, e.g. (15b))
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Callable, Optional, Sequence

import numpy as np
from scipy import optimize


@dataclasses.dataclass
class SurrogateProblem:
    """A convex surrogate in flat-vector form for SLSQP."""

    objective: Callable[[np.ndarray], float]
    grad: Optional[Callable[[np.ndarray], np.ndarray]]
    ineq_constraints: Sequence[dict]     # scipy format, fun(x) >= 0
    eq_constraints: Sequence[dict]
    bounds: Sequence[tuple]
    x0: np.ndarray


@dataclasses.dataclass
class SCAResult:
    x: np.ndarray
    objective: float
    history: list
    converged: bool
    n_iters: int


def solve_surrogate(prob: SurrogateProblem, maxiter: int = 200) -> np.ndarray:
    cons = list(prob.ineq_constraints) + list(prob.eq_constraints)
    lo = np.array([b[0] if b[0] is not None else -np.inf for b in prob.bounds])
    hi = np.array([b[1] if b[1] is not None else np.inf for b in prob.bounds])
    # Re-anchored starts can sit (marginally) outside the box — the design
    # modules' ``project()`` floors differ from the SLSQP bounds — and SLSQP
    # warns ("Values in x were outside bounds...") before clipping
    # internally. Clip the start into the box up front so the solve begins
    # feasible and the warning never fires.
    x0 = np.clip(np.asarray(prob.x0, dtype=np.float64), lo, hi)
    # Normalize the objective to O(1) at the anchor — SLSQP's line search is
    # not scale invariant and the raw design objectives span ~1e5 (the paper
    # itself flags the ill-conditioning of (15)). The scale is evaluated at
    # the *raw* anchor: SLSQP always optimized from the clipped point (it
    # clipped internally), so keeping the old scale makes the explicit clip
    # solution-preserving to the last bit.
    f0 = abs(float(prob.objective(prob.x0)))
    scale = 1.0 / max(f0, 1e-30)
    fun = lambda x: scale * prob.objective(x)
    jac = None if prob.grad is None else (lambda x: scale * prob.grad(x))
    with warnings.catch_warnings():
        # Even from an in-box start, SLSQP's Fortran line search can propose
        # trial points marginally outside the box mid-iteration; SciPy clips
        # them before evaluating (its ScalarFunction wrapper) and emits a
        # RuntimeWarning from inside the solve loop. The clipping is exactly
        # the behaviour we rely on — and we clip the returned x again below —
        # so the warning carries no signal here. Scoped to this one message;
        # every other RuntimeWarning still propagates (tier-1 runs with
        # RuntimeWarning-as-error).
        warnings.filterwarnings(
            "ignore", message="Values in x were outside bounds",
            category=RuntimeWarning)
        res = optimize.minimize(
            fun, x0, jac=jac, method="SLSQP",
            bounds=prob.bounds, constraints=cons,
            options={"maxiter": maxiter, "ftol": 1e-14})
    x = np.asarray(res.x, dtype=np.float64)
    return np.clip(x, lo, hi)


def run_sca(build: Callable[[np.ndarray], SurrogateProblem],
            true_objective: Callable[[np.ndarray], float],
            project: Callable[[np.ndarray], np.ndarray],
            x0: np.ndarray, *, n_iters: int = 15, tol: float = 1e-9,
            inner_maxiter: int = 200) -> SCAResult:
    """Run SCA from anchor ``x0``; returns the best (projected) iterate."""
    anchor = project(np.asarray(x0, dtype=np.float64))
    best_x, best_f = anchor, true_objective(anchor)
    history = [best_f]
    converged = False
    k = 0
    for k in range(n_iters):
        prob = build(anchor)
        x = solve_surrogate(prob, maxiter=inner_maxiter)
        x = project(x)
        f = true_objective(x)
        history.append(f)
        if f < best_f:
            best_x, best_f = x, f
        if abs(history[-2] - f) <= tol * max(1.0, abs(f)) and k > 0:
            converged = True
            anchor = x
            break
        anchor = x
    return SCAResult(x=best_x, objective=best_f, history=history,
                     converged=converged, n_iters=k + 1)


def simplex_projection(v: np.ndarray) -> np.ndarray:
    """Euclidean projection of v onto the probability simplex."""
    v = np.asarray(v, dtype=np.float64)
    n = v.shape[0]
    u = np.sort(v)[::-1]
    css = np.cumsum(u)
    rho = np.nonzero(u * np.arange(1, n + 1) > (css - 1.0))[0][-1]
    theta = (css[rho] - 1.0) / (rho + 1.0)
    return np.maximum(v - theta, 0.0)
