"""wireless_psum — the paper's biased aggregation as a mesh collective.

TPU adaptation (DESIGN.md §2): the OTA MAC superposition *is* an
all-reduce; the biased OTA-FL update (6) becomes

    ghat = ( psum_m( chi_m * gamma_m * g_m )  +  z ) / alpha

executed inside ``shard_map`` with the FL clients laid out along the
("pod","data") mesh axes and the model axis left automatic.  Digital FL
quantizes each client's payload (dithered stochastic uniform quantizer —
the Pallas kernel in kernels/dithered_quant.py) before the reduce:

    ghat = psum_m( chi_m * dequant(quant(g_m, r_m)) / nu_m )

Per-round randomness (fading indicators chi, client weights) is computed
*outside* jit from the channel model and fed in as small arrays, so the
lowered step is shape-stable across rounds.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from .. import compat
from ..kernels import ops as kops


@dataclasses.dataclass(frozen=True)
class WirelessRound:
    """Per-round, per-client aggregation inputs (leading dim = clients,
    reshaped to the client mesh axes by the caller)."""

    weight: jnp.ndarray        # chi_m*gamma_m (OTA) or chi_m/nu_m (digital)
    alpha: jnp.ndarray         # scalar post-scaler (OTA; 1.0 for digital)
    noise_scale: jnp.ndarray   # scalar: sqrt(N0)/alpha (OTA; 0 for digital)
    levels: jnp.ndarray        # quantizer levels 2^r - 1 per client (digital)


def wireless_psum(grads, round_info: WirelessRound, client_axes: tuple,
                  key: jax.Array, *, mode: str = "ota",
                  use_kernel: bool = True, skip_psum=None):
    """Biased wireless aggregation of per-client gradient pytrees.

    Must be called inside shard_map with ``client_axes`` manual.
    ``round_info.weight`` etc. are the *local* (already sliced) scalars.

    ``skip_psum``: optional bool pytree (same structure as grads) marking
    leaves that are *manual-sharded over a client axis* (expert-parallel
    weights): their gradients are already globally aggregated by the
    backward all_to_all, so the reduce is skipped and only the epilogue
    (post-scale / noise / quantize) applies.
    """
    # Aggregation happens in f32 regardless of the model dtype: (a) the
    # paper's update is real-valued analog superposition, and low-precision
    # reduction would add an unmodeled quantization term to Lemma 1; (b) the
    # XLA CPU backend miscompiles bf16 all-reduce under partial-auto
    # shard_map ("Invalid binary instruction opcode copy"), so the f32 cast
    # also keeps the dry-run healthy. Cast back to the leaf dtype after.
    w = round_info.weight.reshape(()).astype(jnp.float32)
    dtypes = jax.tree.map(lambda g: g.dtype, grads)
    if skip_psum is None:
        skip_psum = jax.tree.map(lambda _: False, grads)

    def cast_back(tree):
        return jax.tree.map(lambda g, dt: g.astype(dt), tree, dtypes)

    def reduce_leaf(g, skip):
        g = g.astype(jnp.float32)
        return g if skip else jax.lax.psum(g, client_axes)

    if mode == "ideal":
        n = 1
        for a in client_axes:
            n *= compat.axis_size(a)
        return cast_back(jax.tree.map(
            lambda g, s: reduce_leaf(g, s) / n, grads, skip_psum))
    if mode == "ota":
        summed = jax.tree.map(
            lambda g, s: reduce_leaf(g * w.astype(g.dtype), s),
            grads, skip_psum)
        leaves = jax.tree.leaves(summed)
        keys = jax.random.split(key, len(leaves))
        keys = jax.tree.unflatten(jax.tree.structure(summed), keys)

        def epilogue(g, k):
            # fused post-scale + AWGN injection (Pallas kernel on TPU)
            return kops.ota_combine(g, round_info.alpha,
                                    round_info.noise_scale, k,
                                    use_kernel=use_kernel)
        return cast_back(jax.tree.map(epilogue, summed, keys))
    if mode == "digital":
        levels = round_info.levels.reshape(())
        # fold the client index into the dither key so clients draw
        # independent dither even though the key operand is replicated
        cidx = jnp.zeros((), jnp.int32)
        for a in client_axes:
            cidx = cidx * compat.axis_size(a) + jax.lax.axis_index(a)
        key = jax.random.fold_in(key, cidx)
        leaves = jax.tree.leaves(grads)
        keys = jax.random.split(key, len(leaves))
        keys = jax.tree.unflatten(jax.tree.structure(grads), keys)

        def quantize(g, k):
            gq = kops.dithered_quantize(g.astype(jnp.float32), levels, k,
                                        use_kernel=use_kernel)
            return gq * w
        quantized = jax.tree.map(quantize, grads, keys)
        return cast_back(jax.tree.map(reduce_leaf, quantized, skip_psum))
    raise ValueError(mode)
