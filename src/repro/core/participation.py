"""Partial device participation: per-round client sampling as priced bias.

The paper's Sec.-IV designs pick time-invariant participation *levels*
p_m for a cohort that shows up every round; at population scale (N in the
thousands) the PS instead samples a cohort of expected size
S = ``clients_per_round`` each round. This module supplies the sampling
layer both simulation backends share:

  * **Poisson (independent Bernoulli) sampling** with static per-device
    inclusion probabilities pi_m, sum_m pi_m = S. Each round device m is
    included iff ``u_m < pi_m`` with ``u`` one (N,) uniform block from the
    counter-based PARTICIPATE stream (``core.rngstream``) — a pure
    threefry function of ``(seed, trial, round)``, so the NumPy oracle
    and the JAX engine (in both ``rng="replay"`` and ``rng="fast"``
    modes) see bit-identical cohort realizations.
  * Included gradients are scaled by the **uniform inverse propensity**
    N/S (not 1/pi_m): under the ``"uniform"`` policy (pi_m = S/N) this is
    the exact Horvitz–Thompson correction — zero sampling bias — while a
    non-uniform pi tilts the effective participation level of device m to
    ``p_m * pi_m * (N/S)``: a *structured, static sampling bias* the
    Theorem-1/2 bound prices through ``bounds.effective_participation`` /
    ``bounds.bias_sum``, exactly like the fault layer's outage bias
    (the two compose multiplicatively, ``p * pi * q``).

Policies (``POLICIES``):

  * ``"uniform"``  — pi_m = S/N: zero-bias reference point.
  * ``"channel"``  — pi proportional to the average channel energies
    Lambda_m, scaled onto the capped simplex {sum pi = S, pi <= 1}
    (:func:`capped_proportional`): the classic channel-aware heuristic.
  * ``"designed"`` — pi from the bound-driven co-design solver
    (``core.sca_jax.solve_participation_batch`` via the family wrappers
    ``ota_design.design_ota_participation`` /
    ``digital_design.design_digital_participation``); requires explicit
    probabilities at the trainer/engine layer.
  * ``"datasize"`` — pi proportional to the device dataset sizes |D_m|
    (FedAvg's classic importance weighting recast as a sampling tilt);
    the trainer/engine compute the sizes from their dataset
    (:func:`datasize_weights`).
  * ``"loss"``     — pi proportional to each device's local loss at the
    initial model (loss-based importance sampling: hard devices sampled
    more); the weights are a deterministic function of (task, dataset)
    (:func:`loss_weights`), so both backends resolve identical pi bits.

Both new policies are just another static capped-simplex pi: the
Theorem-1/2 bound prices their sampling tilt through
``bounds.effective_participation`` exactly like "channel".

Arbitrary static probabilities are supported directly: pass
``participation_probs`` (any (N,) vector on the capped simplex) to the
trainer/engine and it overrides the policy's construction.

``clients_per_round=None`` disables the layer entirely —
:func:`resolve` returns None and both backends take their exact
pre-participation code paths (bit-identical trajectories, mirroring the
``FaultSpec`` strict-no-op contract). ``clients_per_round == N`` is
allowed: pi = 1, every device always participates, scale N/S = 1.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

POLICIES = ("uniform", "channel", "designed", "loss", "datasize")

#: Policies whose pi needs per-device weights the trainer/engine derive
#: from their task/dataset (:func:`policy_weights`).
WEIGHTED_POLICIES = ("loss", "datasize")


@dataclasses.dataclass(frozen=True)
class ResolvedParticipation:
    """Validated, backend-shared sampling configuration (hashable).

    ``probs`` is a float64 tuple so the object keys the engine's jitted
    runner cache and compares by content across trainer rebuilds.
    """

    clients: int                 # S — expected cohort size per round
    policy: str                  # provenance: "uniform"|"channel"|"designed"
    probs: tuple                 # (N,) inclusion probabilities, sum == S

    @property
    def n_devices(self) -> int:
        return len(self.probs)

    @property
    def scale(self) -> float:
        """The uniform inverse-propensity payload scale N/S."""
        return self.n_devices / self.clients

    def probs_array(self) -> np.ndarray:
        return np.asarray(self.probs, dtype=np.float64)


def capped_proportional(weights: np.ndarray, clients: int,
                        tol: float = 1e-12) -> np.ndarray:
    """Scale ``weights`` onto the capped simplex {sum pi = S, pi <= 1}.

    Water-filling bisection on the scalar c in ``pi = min(c * w, 1)``:
    the sum is monotone non-decreasing in c, so the root is bracketed by
    doubling and closed by bisection. Deterministic pure NumPy — both
    backends resolve the identical pi bits.
    """
    w = np.asarray(weights, dtype=np.float64)
    n = w.shape[0]
    if np.any(w < 0) or not np.all(np.isfinite(w)):
        raise ValueError("participation weights must be finite and >= 0")
    s = float(clients)
    if s >= n:
        return np.ones(n)
    pos = w > 0
    if int(pos.sum()) < clients:
        raise ValueError(
            f"clients_per_round={clients} exceeds the {int(pos.sum())} "
            "devices with positive participation weight")
    total = lambda c: float(np.sum(np.minimum(c * w, 1.0)))
    hi = 1.0 / float(np.max(w))
    while total(hi) < s:
        hi *= 2.0
    lo = 0.0
    for _ in range(200):
        mid = 0.5 * (lo + hi)
        if total(mid) < s:
            lo = mid
        else:
            hi = mid
        if hi - lo <= tol * max(hi, 1.0):
            break
    pi = np.minimum(hi * w, 1.0)
    # bisection leaves an O(tol) gap on sum(pi); close it on the uncapped
    # coordinates so sum == S holds to float64 round-off
    free = pi < 1.0
    gap = s - float(pi.sum())
    if np.any(free):
        pi[free] += gap * (pi[free] / max(float(pi[free].sum()), 1e-300))
    return np.clip(pi, 0.0, 1.0)


def datasize_weights(dataset) -> np.ndarray:
    """(N,) float64 device dataset sizes |D_m| — the "datasize" policy's
    proportionality weights."""
    return np.asarray([float(len(d)) for d in dataset.devices], np.float64)


def loss_weights(task, dataset) -> np.ndarray:
    """(N,) float64 per-device local loss at the initial model — the
    "loss" policy's proportionality weights.

    ``task.init_params()`` is deterministic, so the weights (and the pi
    they resolve to) are identical bits on both backends.
    """
    w0 = task.init_params()
    return np.asarray(
        [float(task.global_loss(w0, d.x, d.y)) for d in dataset.devices],
        np.float64)


def policy_weights(policy: str, task=None, dataset=None):
    """The per-device weights a :data:`WEIGHTED_POLICIES` policy scales
    onto the capped simplex, or None for the policies that need none."""
    if policy not in WEIGHTED_POLICIES:
        return None
    if task is None or dataset is None:
        raise ValueError(
            f"participation={policy!r} needs the task and dataset to "
            "derive its sampling weights")
    if policy == "datasize":
        return datasize_weights(dataset)
    return loss_weights(task, dataset)


def resolve(clients_per_round: Optional[int], policy: str = "uniform",
            probs=None, *, n_devices: int, lambdas=None,
            weights=None) -> Optional[ResolvedParticipation]:
    """Normalize the (clients, policy, probs) knobs both backends take.

    Returns None when ``clients_per_round`` is None (the strict no-op);
    otherwise a validated :class:`ResolvedParticipation`. Explicit
    ``probs`` override the policy's construction (that is how "designed"
    probabilities reach the trainer); the "channel" policy needs
    ``lambdas``, the "loss"/"datasize" policies need ``weights``
    (:func:`policy_weights` — the trainer/engine derive them from their
    task/dataset).
    """
    if clients_per_round is None:
        if probs is not None:
            raise ValueError(
                "participation_probs given but clients_per_round is None; "
                "set clients_per_round to enable partial participation")
        return None
    if policy not in POLICIES:
        raise ValueError(
            f"participation must be one of {POLICIES}, got {policy!r}")
    s = int(clients_per_round)
    if not 1 <= s <= n_devices:
        raise ValueError(
            f"clients_per_round must be in [1, n_devices={n_devices}], "
            f"got {clients_per_round!r}")
    if probs is not None:
        pi = np.asarray(probs, dtype=np.float64)
        if pi.shape != (n_devices,):
            raise ValueError(
                f"participation_probs must have shape ({n_devices},), "
                f"got {pi.shape}")
        if np.any(pi <= 0.0) or np.any(pi > 1.0):
            raise ValueError(
                "participation_probs must lie in (0, 1] per device")
        if abs(float(pi.sum()) - s) > 1e-6 * s:
            raise ValueError(
                f"participation_probs must sum to clients_per_round={s}, "
                f"got sum {float(pi.sum()):.9g}")
    elif policy == "uniform":
        pi = np.full(n_devices, s / n_devices)
    elif policy == "channel":
        if lambdas is None:
            raise ValueError(
                "participation='channel' needs the deployment lambdas")
        pi = capped_proportional(np.asarray(lambdas, np.float64), s)
    elif policy in WEIGHTED_POLICIES:
        if weights is None:
            raise ValueError(
                f"participation={policy!r} needs its per-device weights "
                "(policy_weights(policy, task, dataset) — the "
                "trainer/engine derive them from their task/dataset)")
        pi = capped_proportional(np.asarray(weights, np.float64), s)
    else:   # "designed" without explicit probabilities
        raise ValueError(
            "participation='designed' needs explicit participation_probs "
            "(solve them with core.sca_jax.solve_participation_batch or "
            "the design-module wrappers)")
    return ResolvedParticipation(clients=s, policy=policy,
                                 probs=tuple(pi.tolist()))
