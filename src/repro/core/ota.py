"""Biased over-the-air (OTA) FL aggregation — Sec. II-A of the paper.

Uplink model (eq. (3)-(6)):
    y_t    = sum_m h_{m,t} x_{m,t} + z_t,         z_t ~ CN(0, N0 I)
    x_{m,t}= (1/h_{m,t}) * chi^A_{m,t} * gamma_m * g_{m,t}     (truncated inversion)
    chi^A  = 1{ |h_{m,t}| >= G_max * gamma_m / sqrt(d E_s) }   (eq. (5))
    ghat_t = y_t / alpha                                        (eq. (6))

Statistics:
    alpha_m(gamma_m) = gamma_m * exp(-gamma_m^2 G^2 / (d Lambda_m E_s))
    p_m = alpha_m / alpha,  alpha = sum_m alpha_m  (convex-combination bias)
    Lemma 1:  var(ghat|w) <= zeta_A
            = sum p_m^2 G^2 (gamma_m/alpha_m - 1)   [transmission]
            + sum p_m^2 sigma_m^2                   [mini-batch]
            + d N0 / alpha^2                        [AWGN]

The real-valued gradient of dimension d is carried over d/2 complex symbols
in practice; following the paper's notation we keep everything in the
d-dimensional real domain with noise variance d*N0/alpha^2 after
post-scaling (the per-component noise is N0/alpha^2).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from .channel import Deployment, participation_probability


@dataclasses.dataclass(frozen=True)
class OTAParams:
    """Offline-designed OTA-FL parameters (time-invariant during training)."""

    gammas: np.ndarray          # (N,) device pre-scalers gamma_m >= 0
    alpha: float                # PS post-scaler
    g_max: float                # gradient norm bound G_max (Assumption 1)
    dim: int                    # model dimension d
    energy_per_symbol: float    # E_s
    noise_psd: float            # N0

    def thresholds(self) -> np.ndarray:
        """Participation thresholds tau_m = G_max*gamma_m/sqrt(d E_s) (eq. (5))."""
        return self.g_max * self.gammas / np.sqrt(self.dim * self.energy_per_symbol)

    def alpha_m(self, lambdas: np.ndarray) -> np.ndarray:
        """alpha_m = gamma_m * exp(-gamma_m^2 G^2/(d Lambda_m E_s))."""
        ex = -(self.gammas ** 2) * self.g_max ** 2 / (
            self.dim * np.asarray(lambdas) * self.energy_per_symbol)
        return self.gammas * np.exp(ex)

    def participation_levels(self, lambdas: np.ndarray) -> np.ndarray:
        """p_m = alpha_m / alpha."""
        return self.alpha_m(lambdas) / self.alpha


def alpha_m_max(lambdas: np.ndarray, dim: int, e_s: float, g_max: float) -> np.ndarray:
    """max_gamma alpha_m(gamma) = sqrt(d Lambda E_s / (2 e G^2)) (Sec. IV-A)."""
    return np.sqrt(np.asarray(lambdas) * dim * e_s / (2.0 * np.e * g_max ** 2))


def gamma_m_max(lambdas: np.ndarray, dim: int, e_s: float, g_max: float) -> np.ndarray:
    """argmax_gamma alpha_m(gamma) = sqrt(d Lambda E_s / (2 G^2)) (Sec. IV-A)."""
    return np.sqrt(np.asarray(lambdas) * dim * e_s / (2.0 * g_max ** 2))


def lemma1_variance(params: OTAParams, lambdas: np.ndarray,
                    sigma_sq: Optional[np.ndarray] = None) -> dict:
    """Lemma 1 variance bound, decomposed into its three terms."""
    a_m = params.alpha_m(lambdas)
    p = a_m / params.alpha
    with np.errstate(divide="ignore", invalid="ignore"):
        ratio = np.where(a_m > 0, params.gammas / a_m, 1.0)
    transmission = float(np.sum(p ** 2 * params.g_max ** 2 * (ratio - 1.0)))
    if sigma_sq is None:
        minibatch = 0.0
    else:
        minibatch = float(np.sum(p ** 2 * np.asarray(sigma_sq)))
    noise = float(params.dim * params.noise_psd / params.alpha ** 2)
    return {
        "transmission": transmission,
        "minibatch": minibatch,
        "noise": noise,
        "total": transmission + minibatch + noise,
    }


def ota_round(params: OTAParams, grads: Sequence[np.ndarray], h: np.ndarray,
              rng: np.random.Generator) -> tuple[np.ndarray, np.ndarray]:
    """One OTA-FL uplink round (simulation path).

    Args:
      params: offline-designed OTA parameters.
      grads:  list of N local stochastic gradients g_{m,t} (dim d each).
      h:      complex fading realizations h_{m,t}, shape (N,).
      rng:    numpy RNG for the PS AWGN.

    Returns:
      (ghat, chi): the PS global-gradient estimate (eq. (6)) and the
      participation indicators chi^A_{m,t}.
    """
    d = params.dim
    taus = params.thresholds()
    chi = (np.abs(h) >= taus).astype(np.float64)
    acc = np.zeros(d, dtype=np.float64)
    for m, g in enumerate(grads):
        if chi[m]:
            # h_m x_m = chi * gamma_m * g_m exactly (perfect inversion above
            # the threshold); the energy constraint ||x||^2/d <= E_s holds by
            # construction of the threshold.
            acc += params.gammas[m] * np.asarray(g, dtype=np.float64)
    # Effective real-domain noise: each of the d real entries sees N(0, N0/2)
    # per complex dimension pair; following the paper's bound we use total
    # noise energy d*N0 i.e. per-entry variance N0.
    z = rng.normal(scale=np.sqrt(params.noise_psd), size=d)
    ghat = (acc + z) / params.alpha
    return ghat, chi


def ota_round_jax(params: OTAParams, grads, h, z01, *, use_kernel: bool = True):
    """One OTA-FL uplink round, pure-JAX (jit/vmap/scan-able).

    Numerically mirrors :func:`ota_round` — same thresholds, same truncated
    inversion, same post-scale — with the PS epilogue (post-scale + AWGN
    injection, eq. (6)) dispatched through the fused Pallas kernel
    ``kernels/ota_combine.py`` (interpret mode on CPU).

    Args:
      params: offline-designed OTA parameters (static under jit).
      grads:  (N, d) stacked local gradients.
      h:      (N,) complex fading realizations.
      z01:    (d,) standard-normal AWGN draws (scaled by sqrt(N0) here, so
              callers can replay the NumPy trainer's noise stream exactly).

    Returns:
      (ghat, chi): PS estimate (d,) and participation indicators (N,).
    """
    import jax.numpy as jnp

    from ..kernels import ops

    taus = jnp.asarray(params.thresholds())
    chi = (jnp.abs(h) >= taus).astype(grads.dtype)
    weights = chi * jnp.asarray(params.gammas, grads.dtype)
    acc = weights @ grads
    z = np.sqrt(params.noise_psd) * z01
    ghat = ops.ota_combine_with_noise(acc, params.alpha, z,
                                      use_kernel=use_kernel)
    return ghat, chi


def opc_ota_fl_round_jax(grads, h, z01, *, dim: int, g_max: float,
                         e_s: float, n0: float, use_kernel: bool = True):
    """[20] genie-aided OPC OTA-FL round, pure-JAX (jit/vmap/scan-able).

    Mirrors ``baselines.OPCOTAFL.round``: evaluate the include-k-strongest
    bias/noise proxy on every k = 1..N threshold candidate at once, pick the
    first minimizer (matching the oracle's strict-< scan), and aggregate the
    selected set with the common inversion pre-scaler. The PS epilogue goes
    through the fused Pallas combine kernel.
    """
    import jax.numpy as jnp

    from ..kernels import ops

    habs = jnp.abs(h)
    n = habs.shape[0]
    order = jnp.argsort(habs)[::-1]
    habs_desc = habs[order]
    ks = jnp.arange(1, n + 1, dtype=jnp.float64)
    gammas = np.sqrt(dim * e_s) * habs_desc / g_max
    scores = (g_max ** 2 * (1.0 - ks / n) ** 2
              + dim * n0 / (ks * gammas) ** 2)
    kidx = jnp.argmin(scores)             # first minimum, as the oracle
    k = (kidx + 1).astype(jnp.float64)
    gamma = gammas[kidx]
    chi = jnp.zeros(n, grads.dtype).at[order].set(
        (jnp.arange(n) <= kidx).astype(grads.dtype))
    acc = gamma * (chi @ grads)
    ghat = ops.ota_combine_with_noise(acc, k * gamma,
                                      np.sqrt(n0) * z01,
                                      use_kernel=use_kernel)
    return ghat, chi


def bbfl_round_jax(grads, h, z01, t, *, dim: int, g_max: float, e_s: float,
                   n0: float, gamma_odd: float, mask_odd,
                   gamma_even: float, mask_even,
                   use_kernel: bool = True):
    """[16] broadband analog aggregation round, pure-JAX.

    Covers both BB-FL variants through the round-parity input ``t``:
    odd rounds use (``gamma_odd``, ``mask_odd``), even rounds
    (``gamma_even``, ``mask_even``). BB-FL *Interior* passes the same
    interior policy for both parities; BB-FL *Alternative* passes the
    all-device policy for even rounds, matching the oracle's ``t % 2``
    schedule. Truncated inversion inside the scheduled mask, PS divides by
    ``max(|S_t|, 1) * gamma``.
    """
    import jax.numpy as jnp

    from ..kernels import ops

    odd = (t % 2) == 1
    gamma = jnp.where(odd, gamma_odd, gamma_even)
    mask = jnp.where(odd, jnp.asarray(mask_odd), jnp.asarray(mask_even))
    tau = g_max * gamma / np.sqrt(dim * e_s)
    chi = ((jnp.abs(h) >= tau) & (mask > 0)).astype(grads.dtype)
    k = jnp.sum(chi)
    acc = gamma * (chi @ grads)
    denom = jnp.maximum(k, 1.0) * gamma
    ghat = ops.ota_combine_with_noise(acc, denom, np.sqrt(n0) * z01,
                                      use_kernel=use_kernel)
    return ghat, chi


def expected_participation(params: OTAParams, lambdas: np.ndarray) -> np.ndarray:
    """E[chi^A_m] = exp(-tau_m^2/Lambda_m)."""
    return participation_probability(params.thresholds(), lambdas)


def uniform_gamma_min_variance(lambdas: np.ndarray, dim: int, e_s: float,
                               g_max: float, n0: float,
                               n_grid: int = 4096) -> float:
    """Common pre-scaler minimizing the Lemma-1 variance bound.

    Used by the LCPC OTA-Comp baseline: all devices share one gamma; returns
    the scalar grid-minimizer of the Lemma-1 bound (statistical CSI only).
    """
    lambdas = np.asarray(lambdas)
    g_hi = float(np.min(gamma_m_max(lambdas, dim, e_s, g_max)))
    grid = np.linspace(1e-4 * g_hi, g_hi, n_grid)
    best, best_v = grid[0], np.inf
    for gmm in grid:
        gam = np.full(lambdas.shape, gmm)
        ex = -(gam ** 2) * g_max ** 2 / (dim * lambdas * e_s)
        a_m = gam * np.exp(ex)
        alpha = float(np.sum(a_m))
        p = a_m / alpha
        v = float(np.sum(p ** 2 * g_max ** 2 * (gam / a_m - 1.0))
                  + dim * n0 / alpha ** 2)
        if v < best_v:
            best, best_v = gmm, v
    return float(best)
