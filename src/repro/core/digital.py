"""Biased digital (TDMA + quantized) FL aggregation — Sec. II-B of the paper.

Uplink model (eq. (9)-(12)):
    chi^D_{m,t} = 1{ |h_{m,t}| >= rho_m }              (eq. (9))
    device m transmits its dithered-quantized gradient (r_m bits/entry,
    payload L_m = 64 + d r_m) at fixed spectral efficiency
        R_m = log2(1 + E_s rho_m^2 / N0)   [bits/s/Hz]
    (outage-free by the threshold rule — unless the fault layer injects
    deep fades below ``core.faults.FaultSpec.deep_fade_thresh``; both the
    in-allocation rule and injected outages evaluate through the single
    :func:`outage_mask` primitive); uplink latency L_m/(B R_m).
    ghat_t = sum_m chi^D_{m,t} g^q_{m,t} / nu_m        (eq. (10))

Statistics:
    beta_m = E[chi^D] = exp(-rho_m^2/Lambda_m),  p_m = beta_m / nu_m
    Lemma 2: var(ghat|w) <= zeta_D
           = sum p^2 G^2 (1/beta - 1)                    [transmission]
           + sum p^2 sigma^2                             [mini-batch]
           + sum p^2 G^2 d / (beta (2^r - 1)^2)          [quantization]
    Expected per-round latency (12): sum_m beta_m L_m / (B R_m).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from .quantize import payload_bits, quantize_np, quantize_np_dither


def outage_mask(habs, thr, deep_fade_thresh: float = 0.0):
    """The one threshold rule: 1{ |h| >= max(thr, deep_fade_thresh) }.

    Every "no outage" comparison — the digital in-allocation rule eq. (9)
    and the fault layer's injected deep fades — routes through this
    primitive so the two masks compose in one place. ``thr`` and
    ``deep_fade_thresh`` are static (numpy/Python) values; ``habs`` may be
    a numpy array (oracle) or a traced jnp array (engine scan), and the
    comparison dispatches accordingly. With ``deep_fade_thresh=0`` the
    effective threshold is exactly ``thr`` (thresholds are nonnegative),
    preserving bit-identical pre-fault behavior.
    """
    return habs >= np.maximum(thr, deep_fade_thresh)


@dataclasses.dataclass(frozen=True)
class DigitalParams:
    """Offline-designed digital-FL parameters (time-invariant)."""

    rhos: np.ndarray            # (N,) participation thresholds rho_m
    nus: np.ndarray             # (N,) PS post-scalers nu_m
    r_bits: np.ndarray          # (N,) quantization bits r_m (ints >= 1)
    g_max: float
    dim: int
    energy_per_symbol: float
    noise_psd: float
    bandwidth_hz: float

    def betas(self, lambdas: np.ndarray) -> np.ndarray:
        """beta_m = exp(-rho_m^2 / Lambda_m)."""
        return np.exp(-(self.rhos ** 2) / np.asarray(lambdas))

    def participation_levels(self, lambdas: np.ndarray) -> np.ndarray:
        """p_m = beta_m / nu_m."""
        return self.betas(lambdas) / self.nus

    def rates(self) -> np.ndarray:
        """R_m = log2(1 + E_s rho_m^2/N0) [bits/s/Hz] (eq. (17c))."""
        snr = self.energy_per_symbol * self.rhos ** 2 / self.noise_psd
        return np.log2(1.0 + snr)

    def payloads(self) -> np.ndarray:
        return np.array([payload_bits(self.dim, int(r)) for r in self.r_bits],
                        dtype=np.float64)

    def expected_latency(self, lambdas: np.ndarray) -> float:
        """Expected per-round uplink latency (eq. (12)) [s]."""
        rates = np.maximum(self.rates(), 1e-12)
        return float(np.sum(self.betas(lambdas) * self.payloads()
                            / (self.bandwidth_hz * rates)))


def lemma2_variance(params: DigitalParams, lambdas: np.ndarray,
                    sigma_sq: Optional[np.ndarray] = None) -> dict:
    """Lemma 2 variance bound, decomposed into its three terms."""
    beta = params.betas(lambdas)
    p = beta / params.nus
    g2 = params.g_max ** 2
    transmission = float(np.sum(p ** 2 * g2 * (1.0 / beta - 1.0)))
    minibatch = 0.0 if sigma_sq is None else float(np.sum(p ** 2 * np.asarray(sigma_sq)))
    s = (2.0 ** params.r_bits.astype(np.float64) - 1.0) ** 2
    quant = float(np.sum(p ** 2 * g2 * params.dim / (beta * s)))
    return {
        "transmission": transmission,
        "minibatch": minibatch,
        "quantization": quant,
        "total": transmission + minibatch + quant,
    }


def digital_round(params: DigitalParams, grads: Sequence[np.ndarray],
                  h: np.ndarray, rng: np.random.Generator,
                  dither: Optional[np.ndarray] = None
                  ) -> tuple[np.ndarray, np.ndarray, float]:
    """One digital-FL uplink round (simulation path).

    ``dither``: optional (N, d) per-device dither uniforms (the trainer
    passes the counter-based ``core.rngstream`` block so the JAX engine can
    replay the stream per round); when None, dither is drawn sequentially
    from ``rng`` as in standalone use.

    Returns (ghat, chi, latency_s): PS estimate (eq. (10)), participation
    indicators, and the realized round latency (sum over participating
    devices of L_m/(B R_m), TDMA).
    """
    d = params.dim
    chi = outage_mask(np.abs(h), params.rhos).astype(np.float64)
    acc = np.zeros(d, dtype=np.float64)
    rates = np.maximum(params.rates(), 1e-12)
    payloads = params.payloads()
    latency = 0.0
    for m, g in enumerate(grads):
        if chi[m]:
            g64 = np.asarray(g, dtype=np.float64)
            if dither is None:
                gq = quantize_np(g64, int(params.r_bits[m]), rng)
            else:
                gq = quantize_np_dither(g64, int(params.r_bits[m]), dither[m])
            acc += gq / params.nus[m]
            latency += payloads[m] / (params.bandwidth_hz * rates[m])
    return acc, chi, float(latency)


def digital_round_jax(params: DigitalParams, grads, h, u,
                      *, use_kernel: bool = True):
    """One digital-FL uplink round, pure-JAX (jit/vmap/scan-able).

    Numerically mirrors :func:`digital_round` — same threshold rule, same
    PS reweighting, same TDMA latency — with each device's dithered
    quantize-dequantize dispatched through the fused Pallas kernel
    ``kernels/dithered_quant.py`` (interpret mode on CPU).

    Args:
      params: offline-designed digital parameters (static under jit).
      grads:  (N, d) stacked local gradients.
      h:      (N,) complex fading realizations.
      u:      (N, d) dither uniforms, one row per device. Passing the NumPy
              trainer's dither stream row-for-row reproduces its quantized
              payloads bit-for-bit (up to 1-ulp kernel rounding).

    Returns:
      (ghat, chi, latency_s): PS estimate (d,), participation indicators
      (N,), and the realized TDMA round latency [s].
    """
    import jax.numpy as jnp

    from ..kernels import ops

    chi = outage_mask(jnp.abs(h), params.rhos).astype(grads.dtype)
    rates = np.maximum(params.rates(), 1e-12)
    lat_m = jnp.asarray(params.payloads() / (params.bandwidth_hz * rates))
    levels = (2.0 ** params.r_bits.astype(np.float64) - 1.0)
    # static r_max bound lets the payload-scale fused pack path engage at
    # large d (quantize straight into uint32 codes, O(d) accumulate)
    acc = ops.quantized_weighted_sum(
        grads, jnp.asarray(levels), u, chi / jnp.asarray(params.nus),
        r_max=int(np.max(params.r_bits)), use_kernel=use_kernel)
    latency = jnp.sum(chi * lat_m)
    return acc, chi, latency


# ----------------------------------------- jittable selection primitives
#
# The digital baseline suite (Sec. V-A-2) is built from three reusable
# jit/vmap/scan-able pieces: instantaneous capacity rates, top-K device
# selection as a 0/1 mask, and FedTOE's greedy bit allocation. The NumPy
# oracle implementations live in ``core.baselines``; these mirror them
# op-for-op so trajectories replay to float64 round-off.

def capacity_rate_jnp(habs, e_s: float, n0: float):
    """Instantaneous spectral efficiency log2(1 + E_s|h|^2/N0) [b/s/Hz]."""
    import jax.numpy as jnp

    return jnp.log2(1.0 + e_s * habs ** 2 / n0)


def topk_mask(score, k: int):
    """0/1 mask of the k highest-scoring devices.

    Mirrors the oracle's ``np.argsort(score)[::-1][:k]`` (ties broken by
    sort order — measure-zero for the continuous channel scores used here).
    """
    import jax.numpy as jnp

    n = score.shape[0]
    order = jnp.argsort(score)[::-1]
    return jnp.zeros(n, score.dtype).at[order[:k]].set(1.0)


def greedy_bit_alloc_jax(sel, rates, *, dim: int, bandwidth_hz: float,
                         t_budget_s: float, r_max: int):
    """FedTOE's greedy RB/bit allocation as a jittable scan + while_loop.

    Mirrors ``baselines.FedTOE._alloc_bits``: walk the scheduled set in
    decreasing-rate order giving each device 1 bit while its minimum
    payload fits the round budget (``lax.scan``), then greedily grant +1
    bit to the device with the best variance-reduction-per-latency gain
    until the budget or ``r_max`` saturates (``lax.while_loop``).

    Args:
      sel:   (k,) int device indices scheduled this round (replayed draw).
      rates: (N,) static per-device spectral efficiencies R_m.

    Returns:
      (bits, in_alloc): (N,) float bit-widths (0 for devices outside the
      allocation) and the 0/1 allocation mask.
    """
    import jax
    import jax.numpy as jnp

    n = rates.shape[0]
    rates = jnp.asarray(rates, jnp.float64)
    safe_rates = jnp.maximum(rates, 1e-9)
    # stable descending-rate order over the scheduled set, mirroring
    # ``sorted(sel, key=lambda m: -rates[m])``
    order = jnp.argsort(-rates[sel])
    sel_sorted = sel[order]
    t_one = (64.0 + dim) / (bandwidth_hz * safe_rates[sel_sorted])

    def fill(used, t1):
        fits = used + t1 <= t_budget_s
        return used + jnp.where(fits, t1, 0.0), fits

    _, fits = jax.lax.scan(fill, jnp.zeros((), jnp.float64), t_one)
    in_alloc = jnp.zeros(n, jnp.float64).at[sel_sorted].add(
        fits.astype(jnp.float64))
    bits0 = in_alloc.copy()
    per_bit_s = dim / (bandwidth_hz * safe_rates)

    def latency(bits):
        return jnp.sum(in_alloc * (64.0 + dim * bits)
                       / (bandwidth_hz * safe_rates))

    def cond(state):
        _, done = state
        return jnp.logical_not(done)

    def body(state):
        # under vmap the loop runs until every lane is done, so ``done``
        # must freeze a lane's state (accept is forced False once done)
        bits, done = state
        eligible = (in_alloc > 0) & (bits < r_max)
        b_safe = jnp.where(in_alloc > 0, bits, 1.0)
        dv = (1.0 / (2.0 ** b_safe - 1.0) ** 2
              - 1.0 / (2.0 ** (b_safe + 1.0) - 1.0) ** 2)
        gain = jnp.where(eligible, dv / per_bit_s, 0.0)
        best = jnp.argmax(gain)
        bits_new = bits.at[best].add(1.0)
        accept = ((gain[best] > 0.0) & (latency(bits_new) <= t_budget_s)
                  & jnp.logical_not(done))
        return jnp.where(accept, bits_new, bits), jnp.logical_not(accept)

    bits, _ = jax.lax.while_loop(cond, body,
                                 (bits0, jnp.sum(in_alloc) == 0))
    return bits, in_alloc
