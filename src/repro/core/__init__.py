"""Core library: the paper's biased wireless-FL contribution.

Public surface:
  channel     — deployment geometry, path loss, Rayleigh fading
  ota         — biased OTA aggregation (Sec. II-A) + Lemma 1
  digital     — biased digital aggregation (Sec. II-B) + Lemma 2
  quantize    — dithered stochastic uniform quantizer
  bounds      — Theorem 1/2 convergence bounds
  sca         — successive convex approximation driver (SciPy oracle)
  sca_jax     — batched jit/vmap design solver over whole sweep grids
  ota_design / digital_design — Sec. IV parameter design (SCA + direct +
                batched jax)
  baselines   — SOTA OTA/digital comparison schemes (Sec. V)
  collectives — wireless_psum: the technique as a distributed collective
"""
from .channel import (WirelessConfig, Deployment, FadingProcess,
                      make_deployment, participation_probability)
from .ota import OTAParams, lemma1_variance, ota_round
from .digital import DigitalParams, lemma2_variance, digital_round
from .bounds import (ObjectiveWeights, bias_sum, design_objective,
                     theorem1_bound, theorem2_bound)
from .ota_design import (OTADesignSpec, design_ota_sca, design_ota_direct,
                         design_ota_batch)
from .digital_design import (DigitalDesignSpec, design_digital_sca,
                             design_digital_direct, design_digital_batch)

__all__ = [
    "WirelessConfig", "Deployment", "FadingProcess", "make_deployment",
    "participation_probability", "OTAParams", "lemma1_variance", "ota_round",
    "DigitalParams", "lemma2_variance", "digital_round", "ObjectiveWeights",
    "bias_sum", "design_objective", "theorem1_bound", "theorem2_bound",
    "OTADesignSpec", "design_ota_sca", "design_ota_direct",
    "design_ota_batch", "DigitalDesignSpec", "design_digital_sca",
    "design_digital_direct", "design_digital_batch",
]
