"""Batched JAX SCA design solver — Sec. IV on a whole sweep grid at once.

``core/sca.py`` drives one SciPy SLSQP solve per surrogate per anchor —
trusted, but a Python loop per grid point: the paper's sweeps (omega
trade-off grids, SNR points, heterogeneity levels, Monte-Carlo
deployments) multiply 12–15 SLSQP solves by dozens of embarrassingly
parallel design problems. This module solves the *whole grid in one jit*:

  * OTA (15): the exact gamma-only reduction proven out by
    ``design_ota_direct`` — under the simplex constraint (15e), the
    coupling (15b) pins ``alpha = sum_m alpha_m(gamma_m)`` and
    ``p_m = alpha_m/alpha``, so the original objective is a smooth
    box-constrained function of gamma alone. The solver is projected
    Adam with an SCA-style outer ``lax.scan`` of re-anchored stages at
    decreasing step sizes.

  * Digital (17): projected Adam on the reduced variables
    ``(p, beta, r')`` with the latency constraint (17b) folded in as a
    hinge penalty; the outer ``lax.scan`` escalates the penalty weight
    (classic penalty-method SCA analogue). After every stage the iterate
    is projected to *exact* feasibility — simplex projection for ``p``
    and the same raise-thresholds bisection as
    ``digital_design._fit_latency`` — and the true objective (17a) of
    the feasible point is tracked, so the returned solution is always
    feasible and its objective directly comparable to the SciPy oracle.

Everything is float64 (``jax.experimental.enable_x64``) and vmapped over
``anchors × grid points``; the SciPy path in ``sca.py`` remains the
trusted oracle (``benchmarks/design_bench.py`` records wall-clock and
objective parity).
"""
from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp
from jax.experimental import enable_x64

# Inner-solver schedule: SCA-style outer stages (re-anchor at the best
# iterate, shrink the step) x Adam steps per stage. The variables are
# pre-scaled to O(1), so the rates are problem-independent.
_OTA_LRS = (0.1, 0.03, 0.01, 0.003)
_OTA_STEPS = 300
# Digital: penalty escalation mu_k with matching step-size decay.
_DIG_MUS = (1.0, 10.0, 100.0, 1e3, 1e4)
_DIG_LRS = (0.05, 0.02, 0.01, 0.005, 0.002)
_DIG_STEPS = 400
# Participation co-design: projected Adam on the capped simplex.
_PART_LRS = (0.1, 0.03, 0.01)
_PART_STEPS = 300
_PART_PI_MIN = 1e-6

_B1, _B2, _ADAM_EPS = 0.9, 0.999, 1e-12


def simplex_projection_jax(v: jnp.ndarray) -> jnp.ndarray:
    """Euclidean projection onto the probability simplex (jit/vmap-able).

    Mirrors ``sca.simplex_projection`` (sort + cumsum threshold rule).
    """
    n = v.shape[0]
    u = jnp.sort(v)[::-1]
    css = jnp.cumsum(u)
    cond = u * jnp.arange(1, n + 1) > (css - 1.0)
    rho = jnp.max(jnp.where(cond, jnp.arange(n), -1))
    theta = (css[rho] - 1.0) / (rho + 1.0)
    return jnp.maximum(v - theta, 0.0)


def _adam_descent(value_and_grad, x0, lo, hi, *, lr, n_steps, track_best):
    """``n_steps`` of Adam projected onto the box [lo, hi] via clipping.

    ``track_best=True`` additionally records the best objective seen at the
    (already clipped) iterates — used where the objective IS the true
    objective (OTA reduction); penalty objectives skip it.
    """
    m0 = jnp.zeros_like(x0)
    v0 = jnp.zeros_like(x0)
    f0 = value_and_grad(x0)[0]

    def step(carry, i):
        x, m, v, bx, bf = carry
        f, g = value_and_grad(x)
        if track_best:
            bx = jnp.where(f < bf, x, bx)
            bf = jnp.minimum(f, bf)
        m = _B1 * m + (1.0 - _B1) * g
        v = _B2 * v + (1.0 - _B2) * g * g
        mhat = m / (1.0 - _B1 ** (i + 1))
        vhat = v / (1.0 - _B2 ** (i + 1))
        x = jnp.clip(x - lr * mhat / (jnp.sqrt(vhat) + _ADAM_EPS), lo, hi)
        return (x, m, v, bx, bf), None

    (x, _, _, bx, bf), _ = jax.lax.scan(
        step, (x0, m0, v0, x0, f0), jnp.arange(n_steps))
    return x, bx, bf


def capped_simplex_projection_jax(v: jnp.ndarray, s, lo=_PART_PI_MIN,
                                  hi=1.0) -> jnp.ndarray:
    """Euclidean projection onto {sum x = s, lo <= x <= hi} (jittable).

    Bisection on the dual shift tau in ``x = clip(v - tau, lo, hi)``: the
    coordinate sum is monotone non-increasing in tau, bracketed by
    [min(v) - hi, max(v) - lo]. A fixed iteration count (no data-dependent
    loop) keeps the projection scan/vmap-friendly; 100 halvings close the
    bracket far below float64 resolution.
    """
    def body(carry, _):
        lo_t, hi_t = carry
        mid = 0.5 * (lo_t + hi_t)
        tot = jnp.sum(jnp.clip(v - mid, lo, hi))
        return (jnp.where(tot > s, mid, lo_t),
                jnp.where(tot > s, hi_t, mid)), None

    bracket = (jnp.min(v) - hi, jnp.max(v) - lo)
    (_, tau), _ = jax.lax.scan(body, bracket, None, length=100)
    return jnp.clip(v - tau, lo, hi)


# -------------------------------------------- participation co-design

def _solve_participation_one(p, q, s, wv, wb):
    """One participation design point: Bernoulli inclusion probs pi.

    Minimizes the bound-shaped objective over the capped simplex
    {sum pi = S, pi_min <= pi <= 1}: with the *effective participation
    levels* ``e = p * pi * q * (N/S)`` — exactly
    ``bounds.effective_participation`` under zero-fill degradation, the
    regime where sampling bias is priced —

        J(pi) = omega_bias * sum (e - 1/N)^2             (priced bias)
              + omega_var  / (sum e)^2                   (noise inflation)

    The variance term is the post-normalization noise proxy of a wireless
    aggregate: the PS noise is per-round and common, so the effective
    noise power after dividing by the delivered signal mass scales as
    1/(sum_m e_m)^2 — a cohort that samples devices the fades starve
    delivers less mass and amplifies noise. The solver therefore trades
    tilting pi toward reliably-delivering devices (throughput / variance)
    against leveling the effective participation at 1/N (bias), the same
    bias-variance structure as (15a)/(17a). Three anchors (uniform,
    proportional to p*q, proportional to sqrt(p*q)) feed projected Adam
    stages at decreasing step sizes; best feasible iterate wins.
    """
    n = p.shape[0]
    w = jnp.maximum(p * q, 1e-30)

    def obj(pi):
        e = (n / s) * w * pi
        return (wb * jnp.sum((e - 1.0 / n) ** 2)
                + wv / jnp.sum(e) ** 2)

    proj = lambda x: capped_simplex_projection_jax(x, s)
    anchors = jnp.stack([
        jnp.full((n,), s / n),
        proj(w * (s / jnp.sum(w))),
        proj(jnp.sqrt(w) * (s / jnp.sum(jnp.sqrt(w)))),
    ])
    vg = jax.value_and_grad(obj)
    scale = 1.0 / jnp.maximum(jnp.abs(obj(anchors[0])), 1e-30)

    def run_anchor(x0):
        def stage(carry, lr):
            x, bx, bf = carry

            def step(inner, i):
                x, m, v = inner
                f, g = vg(x)
                g = g * scale
                m = _B1 * m + (1.0 - _B1) * g
                v = _B2 * v + (1.0 - _B2) * g * g
                mhat = m / (1.0 - _B1 ** (i + 1))
                vhat = v / (1.0 - _B2 ** (i + 1))
                x = proj(x - lr * mhat / (jnp.sqrt(vhat) + _ADAM_EPS))
                return (x, m, v), None

            (x, _, _), _ = jax.lax.scan(
                step, (x, jnp.zeros_like(x), jnp.zeros_like(x)),
                jnp.arange(_PART_STEPS))
            f = obj(x)
            bx = jnp.where(f < bf, x, bx)
            bf = jnp.minimum(f, bf)
            return (bx, bx, bf), None           # re-anchor at the best

        (_, bx, bf), _ = jax.lax.scan(stage, (x0, x0, obj(x0)),
                                      jnp.asarray(_PART_LRS))
        return bx, bf

    bxs, bfs = jax.vmap(run_anchor)(anchors)
    i = jnp.argmin(bfs)
    return bxs[i], bfs[i]


@functools.lru_cache(maxsize=None)
def _participation_solver_jit():
    return jax.jit(jax.vmap(_solve_participation_one))


def solve_participation_batch(p, q, clients, omega_var, omega_bias):
    """Solve a batch of participation co-design problems in one jit.

    Args (leading batch axis B; N devices): p (B, N) effective scheme
    participation levels, q (B, N) fault survival probabilities (ones when
    faults are off), clients (B,) expected cohort sizes S, omega_var /
    omega_bias (B,) the cell's bound weights.

    Returns:
      (pi, objectives): (B, N) float64 inclusion probabilities on the
      capped simplex {sum pi = S, pi <= 1} and (B,) objective values.
    """
    with enable_x64():
        args = [jnp.asarray(np.asarray(a, dtype=np.float64))
                for a in (p, q, clients, omega_var, omega_bias)]
        pi, obj = _participation_solver_jit()(*args)
        return np.asarray(pi), np.asarray(obj)


# ---------------------------------------------------- async co-design

def _solve_async_one(p, c, sbar, wv, wb):
    """One buffered-async design point: PS per-device weights v.

    Minimizes the bound-shaped objective over {sum v = N,
    v_min <= v <= N}: with the *async effective participation levels*
    ``e = p * c * v * (N / sum(c v))`` — exactly
    ``bounds.async_effective_participation``, where ``c`` is the per-device
    staleness-discounted delivery weight
    (``core.async_fl.delivery_weight``) —

        J(v) = omega_bias * sum (e - 1/N)^2            (priced stale bias)
             + omega_var * (1/(sum e)^2               (noise inflation)
                            + sum e^2 * sbar)         (staleness drift)

    The first variance piece is the participation solver's delivered-mass
    noise proxy; the second weights each device's squared effective level
    by its expected staleness ``sbar_m`` (E[S | delivered],
    ``core.async_fl.expected_staleness``) — a staleness-S gradient drifts
    from the fresh one by O(S) optimization progress, so leaning on
    chronically-stale devices injects drift variance. The solver therefore
    trades up-weighting slow devices (leveling e at 1/N — killing the
    structured staleness bias) against the drift noise of doing so, the
    same bias-variance structure as (15a)/(17a). Three anchors (uniform,
    inverse delivery weight, inverse expected staleness) feed projected
    Adam stages at decreasing step sizes; best feasible iterate wins.
    """
    n = p.shape[0]
    cw = jnp.maximum(c, 1e-30)

    def obj(v):
        e = p * cw * v * (n / jnp.sum(cw * v))
        return (wb * jnp.sum((e - 1.0 / n) ** 2)
                + wv * (1.0 / jnp.sum(e) ** 2 + jnp.sum(e ** 2 * sbar)))

    proj = lambda x: capped_simplex_projection_jax(x, 1.0 * n, hi=1.0 * n)
    inv_c = 1.0 / cw
    inv_s = 1.0 / (1.0 + sbar)
    anchors = jnp.stack([
        jnp.ones((n,)),
        proj(inv_c * (n / jnp.sum(inv_c))),
        proj(inv_s * (n / jnp.sum(inv_s))),
    ])
    vg = jax.value_and_grad(obj)
    scale = 1.0 / jnp.maximum(jnp.abs(obj(anchors[0])), 1e-30)

    def run_anchor(x0):
        def stage(carry, lr):
            x, bx, bf = carry

            def step(inner, i):
                x, m, v = inner
                f, g = vg(x)
                g = g * scale
                m = _B1 * m + (1.0 - _B1) * g
                v = _B2 * v + (1.0 - _B2) * g * g
                mhat = m / (1.0 - _B1 ** (i + 1))
                vhat = v / (1.0 - _B2 ** (i + 1))
                x = proj(x - lr * mhat / (jnp.sqrt(vhat) + _ADAM_EPS))
                return (x, m, v), None

            (x, _, _), _ = jax.lax.scan(
                step, (x, jnp.zeros_like(x), jnp.zeros_like(x)),
                jnp.arange(_PART_STEPS))
            f = obj(x)
            bx = jnp.where(f < bf, x, bx)
            bf = jnp.minimum(f, bf)
            return (bx, bx, bf), None           # re-anchor at the best

        (_, bx, bf), _ = jax.lax.scan(stage, (x0, x0, obj(x0)),
                                      jnp.asarray(_PART_LRS))
        return bx, bf

    bxs, bfs = jax.vmap(run_anchor)(anchors)
    i = jnp.argmin(bfs)
    return bxs[i], bfs[i]


@functools.lru_cache(maxsize=None)
def _async_solver_jit():
    return jax.jit(jax.vmap(_solve_async_one))


def solve_async_batch(p, c, sbar, omega_var, omega_bias):
    """Solve a batch of buffered-async weight design problems in one jit.

    Args (leading batch axis B; N devices): p (B, N) effective scheme
    participation levels (fault/sampling tilts folded in), c (B, N) async
    delivery weights (``core.async_fl.delivery_weight``), sbar (B, N)
    expected staleness (``core.async_fl.expected_staleness``), omega_var /
    omega_bias (B,) the cell's bound weights.

    Returns:
      (v, objectives): (B, N) float64 PS per-device weights on
      {sum v = N, v <= N} and (B,) objective values.
    """
    with enable_x64():
        args = [jnp.asarray(np.asarray(a, dtype=np.float64))
                for a in (p, c, sbar, omega_var, omega_bias)]
        v, obj = _async_solver_jit()(*args)
        return np.asarray(v), np.asarray(obj)


# ------------------------------------------------------------- OTA (15)

def _solve_ota_one(lambdas, dim, g_max, e_s, n0, wv, wb, s2, anchors):
    """One OTA design point, all anchors: gamma-reduced objective (15a)."""
    n = lambdas.shape[0]
    c = g_max ** 2 / (dim * lambdas * e_s)
    gmax = jnp.sqrt(lambdas * dim * e_s / (2.0 * g_max ** 2))
    u_g = jnp.median(gmax)                       # O(1) scaling, as the oracle
    g2 = g_max ** 2
    lo, hi = 1e-6, gmax / u_g

    def obj(gs):
        gam = gs * u_g
        x = c * gam ** 2
        a = gam * jnp.exp(-x)
        alpha = jnp.sum(a)
        p = a / alpha
        # exp clip mirrors true_objective_from_gamma's overflow guard
        trans = jnp.sum(p ** 2 * g2 * (jnp.exp(jnp.minimum(x, 700.0)) - 1.0))
        noise = dim * n0 / alpha ** 2
        return (wv * (trans + jnp.sum(p ** 2 * s2) + noise)
                + wb * jnp.sum((p - 1.0 / n) ** 2))

    vg = jax.value_and_grad(obj)
    scale = 1.0 / jnp.maximum(jnp.abs(obj(jnp.clip(
        anchors[0] / u_g, lo, hi))), 1e-30)

    def scaled_vg(x):
        f, g = vg(x)
        return f, g * scale                      # scale-free Adam steps

    def run_anchor(a0):
        x0 = jnp.clip(a0 / u_g, lo, hi)

        def stage(carry, lr):
            x, bx, bf = carry
            _, sbx, sbf = _adam_descent(scaled_vg, x, lo, hi, lr=lr,
                                        n_steps=_OTA_STEPS, track_best=True)
            bx = jnp.where(sbf < bf, sbx, bx)
            bf = jnp.minimum(sbf, bf)
            return (bx, bx, bf), None            # re-anchor at the best

        (_, bx, bf), _ = jax.lax.scan(stage, (x0, x0, obj(x0)),
                                      jnp.asarray(_OTA_LRS))
        return bx, bf

    bxs, bfs = jax.vmap(run_anchor)(anchors)
    i = jnp.argmin(bfs)
    return bxs[i] * u_g, bfs[i]


@functools.lru_cache(maxsize=None)
def _ota_solver_jit():
    return jax.jit(jax.vmap(_solve_ota_one))


def solve_ota_gamma_batch(lambdas, dim, g_max, e_s, n0, omega_var,
                          omega_bias, sigma_sq, anchors):
    """Solve a batch of OTA design problems (15) in one jit.

    Args (leading batch axis B everywhere; N devices, A anchors):
      lambdas (B, N), dim/g_max/e_s/n0/omega_var/omega_bias (B,),
      sigma_sq (B, N), anchors (B, A, N) gamma starting points.

    Returns:
      (gammas, objectives): (B, N) float64 designed pre-scalers and (B,)
      true objectives (15a) at the physically-coupled points.
    """
    with enable_x64():
        args = [jnp.asarray(np.asarray(a, dtype=np.float64))
                for a in (lambdas, dim, g_max, e_s, n0, omega_var,
                          omega_bias, sigma_sq, anchors)]
        gam, obj = _ota_solver_jit()(*args)
        return np.asarray(gam), np.asarray(obj)


# --------------------------------------------------------- digital (17)

def _solve_digital_one(lambdas, dim, g_max, e_s, n0, bw, t_max, r_max,
                       wv, wb, s2, anchors):
    """One digital design point, all anchors: reduced (p, beta, r')."""
    n = lambdas.shape[0]
    g2 = g_max ** 2
    snr_gain = lambdas * e_s / n0

    def latency(nlb_s, r):
        """Expected latency (12) from nlb_s = -ln(beta_s) (rho^2/Lambda)."""
        rate = jnp.maximum(jnp.log2(1.0 + snr_gain * nlb_s), 1e-9)
        payload = 64.0 + dim * (r + 1.0)
        return jnp.sum(jnp.exp(-nlb_s) * payload / (bw * rate))

    def fit_latency(beta, r):
        """Raise thresholds (beta -> beta**s) until (17b) holds.

        Same monotone bisection as ``digital_design._fit_latency``, on the
        log scale nlb = -ln(beta) so beta**s never over/underflows.
        """
        nlb = -jnp.log(jnp.clip(beta, 1e-300, 1.0))
        feasible = latency(nlb, r) <= t_max

        def cond(carry):
            lo_s, hi_s = carry
            return (hi_s - lo_s) > 1e-12 * hi_s

        def body(carry):
            lo_s, hi_s = carry
            mid = 0.5 * (lo_s + hi_s)
            bad = latency(mid * nlb, r) > t_max
            return jnp.where(bad, mid, lo_s), jnp.where(bad, hi_s, mid)

        _, hi_s = jax.lax.while_loop(cond, body, (1.0, 1e6))
        s = jnp.where(feasible, 1.0, hi_s)       # oracle keeps the hi end
        return jnp.exp(-s * nlb)

    def true_obj(p, beta, r):
        """(17a) at integer-relaxed bits r = r'+1 (= oracle convention)."""
        s = (2.0 ** (r + 1.0) - 1.0) ** 2
        zeta = (jnp.sum(p ** 2 * g2 * (1.0 / beta - 1.0 + dim / (beta * s)))
                + jnp.sum(p ** 2 * s2))
        return wv * zeta + wb * jnp.sum((p - 1.0 / n) ** 2)

    def split(x):
        return x[:n], x[n:2 * n], x[2 * n:]

    def project(x):
        """Exact feasibility: simplex p, latency-fitted beta, boxed r."""
        p, beta, r = split(x)
        p = simplex_projection_jax(jnp.clip(p, 1e-8, 1.0))
        p = jnp.clip(p, 1e-10, 1.0)
        p = p / jnp.sum(p)
        r = jnp.clip(r, 0.5, r_max - 1.0)
        beta = fit_latency(jnp.clip(beta, 1e-9, 1.0 - 1e-9), r)
        return jnp.concatenate([p, beta, r])

    lo = jnp.concatenate([jnp.full(n, 1e-8), jnp.full(n, 1e-6),
                          jnp.full(n, 0.5)])
    hi = jnp.concatenate([jnp.ones(n), jnp.full(n, 1.0 - 1e-9),
                          jnp.full(n, r_max - 1.0)])

    def run_anchor(x0):
        x0 = project(jnp.clip(x0, lo, hi))
        p0, b0, r0 = split(x0)
        f0 = true_obj(p0, b0, r0)
        scale = 1.0 / jnp.maximum(jnp.abs(f0), 1e-30)

        def pen_obj(x, mu):
            p, beta, r = split(x)
            beta = jnp.clip(beta, 1e-9, 1.0 - 1e-9)
            hinge = jnp.maximum(
                latency(-jnp.log(beta), r) / t_max - 1.0, 0.0)
            psum = jnp.sum(p) - 1.0
            return (scale * true_obj(p, beta, r)
                    + mu * (hinge ** 2 + psum ** 2))

        def stage(carry, stage_args):
            mu, lr = stage_args
            x, bx, bf = carry
            vg = jax.value_and_grad(lambda y: pen_obj(y, mu))
            x, _, _ = _adam_descent(vg, x, lo, hi, lr=lr,
                                    n_steps=_DIG_STEPS, track_best=False)
            xp = project(x)
            f = true_obj(*split(xp))
            bx = jnp.where(f < bf, xp, bx)
            bf = jnp.minimum(f, bf)
            return (xp, bx, bf), None

        (_, bx, bf), _ = jax.lax.scan(
            stage, (x0, x0, f0),
            (jnp.asarray(_DIG_MUS), jnp.asarray(_DIG_LRS)))
        return bx, bf

    bxs, bfs = jax.vmap(run_anchor)(anchors)
    i = jnp.argmin(bfs)
    return bxs[i], bfs[i]


@functools.lru_cache(maxsize=None)
def _digital_solver_jit():
    return jax.jit(jax.vmap(_solve_digital_one))


def solve_digital_batch(lambdas, dim, g_max, e_s, n0, bandwidth_hz, t_max_s,
                        r_max, omega_var, omega_bias, sigma_sq, anchors):
    """Solve a batch of digital design problems (17) in one jit.

    Args (leading batch axis B; N devices, A anchors): lambdas (B, N),
    scalars (B,), sigma_sq (B, N), anchors (B, A, 3N) packed (p, beta, r').

    Returns:
      (x, objectives): (B, 3N) feasible packed solutions and (B,) true
      objectives (17a) at the continuous (integer-relaxed) points —
      directly comparable to ``design_digital_sca``'s ``SCAResult.objective``.
    """
    with enable_x64():
        args = [jnp.asarray(np.asarray(a, dtype=np.float64))
                for a in (lambdas, dim, g_max, e_s, n0, bandwidth_hz,
                          t_max_s, r_max, omega_var, omega_bias, sigma_sq,
                          anchors)]
        x, obj = _digital_solver_jit()(*args)
        return np.asarray(x), np.asarray(obj)
