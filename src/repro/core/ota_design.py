"""OTA-FL parameter design — problem (15) and its SCA surrogate (16).

Variables (flat vector, scaled for conditioning):
    x = [gamma'(N), p(N), z'(N), alpha'(1)]
with physical values gamma = u_g * gamma', alpha = u_a * alpha',
z = u_z * z' (u_z = u_g/u_a).  The scales u_g/u_a are set from the
channel statistics (gamma_max / sum alpha_max), which keeps all variables
O(1) — the paper itself notes the raw problem is ill-conditioned.

Three solvers:
  * ``design_ota_sca``    — paper-faithful Sec. IV-A SCA on surrogate (16).
  * ``design_ota_direct`` — beyond-paper: note that under the simplex
    constraint (15e), (15b) forces alpha = sum_m alpha_m(gamma_m) and
    p_m = alpha_m/alpha, i.e. gamma fully determines the design. The
    original problem reduces to a smooth box-constrained minimization over
    gamma alone, solved with L-BFGS-B + jax gradients. Used as a
    cross-check/upper-bound on the SCA solution quality.
  * ``design_ota_batch``  — a whole sweep grid of (15) instances solved in
    one ``jit(vmap(...))`` (``core.sca_jax``, same gamma reduction as the
    direct solver); specs stacked along a leading axis via
    ``stack_ota_specs``. The SciPy paths stay the trusted oracle.

Heuristic anchors (from the authors' prior work [1]):
  * min-noise-variance:  gamma_m = gamma_{m,max}  (maximizes alpha).
  * zero-bias min-noise: alpha_m identical = min_m alpha_{m,max}
    (p = 1/N exactly; smaller root of alpha_m(gamma) = c).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np
import jax
import jax.numpy as jnp
from scipy import optimize

from .bounds import ObjectiveWeights, bias_sum
from .channel import Deployment
from .ota import OTAParams, alpha_m_max, gamma_m_max
from .sca import SCAResult, SurrogateProblem, run_sca

_EPS = 1e-9


@dataclasses.dataclass(frozen=True)
class OTADesignSpec:
    """Immutable inputs of the OTA design problem."""

    lambdas: np.ndarray
    dim: int
    g_max: float
    e_s: float
    n0: float
    weights: ObjectiveWeights
    sigma_sq: Optional[np.ndarray] = None   # mini-batch variances (None -> 0)

    @property
    def n(self) -> int:
        return int(self.lambdas.shape[0])

    @property
    def sigmas2(self) -> np.ndarray:
        if self.sigma_sq is None:
            return np.zeros(self.n)
        return np.asarray(self.sigma_sq, dtype=np.float64)

    def c_m(self) -> np.ndarray:
        """c_m = G^2/(d Lambda_m E_s): alpha_m = gamma exp(-c_m gamma^2)."""
        return self.g_max ** 2 / (self.dim * self.lambdas * self.e_s)

    def gamma_max(self) -> np.ndarray:
        return gamma_m_max(self.lambdas, self.dim, self.e_s, self.g_max)

    def alpha_max(self) -> np.ndarray:
        return alpha_m_max(self.lambdas, self.dim, self.e_s, self.g_max)


def _alpha_m(spec: OTADesignSpec, gammas: np.ndarray) -> np.ndarray:
    return gammas * np.exp(-spec.c_m() * gammas ** 2)


def true_objective_from_gamma(spec: OTADesignSpec, gammas: np.ndarray) -> float:
    """Original objective (15a) evaluated at the physically-coupled point."""
    a = _alpha_m(spec, gammas)
    # Past the stationary point (gamma >> gamma_max, e.g. under extreme
    # path-loss heterogeneity) c_m*gamma^2 exceeds 709 and exp overflows to
    # inf while p underflows to 0, yielding 0*inf = nan. Clipping the
    # exponent keeps exp finite; the term still blows up smoothly (p^2
    # dominates), so minimizers are unaffected. The alpha floor keeps the
    # fully-degenerate input (every device past overflow) a huge-but-finite
    # objective instead of a ZeroDivisionError.
    alpha = max(float(np.sum(a)), 1e-150)
    p = a / alpha
    ratio = np.exp(np.minimum(spec.c_m() * gammas ** 2, 700.0))  # gamma/alpha_m
    trans = float(np.sum(p ** 2 * spec.g_max ** 2 * (ratio - 1.0)))
    mb = float(np.sum(p ** 2 * spec.sigmas2))
    noise = spec.dim * spec.n0 / alpha ** 2
    return (spec.weights.omega_var * (trans + mb + noise)
            + spec.weights.omega_bias * bias_sum(p))


def params_from_gamma(spec: OTADesignSpec, gammas: np.ndarray) -> OTAParams:
    a = _alpha_m(spec, gammas)
    return OTAParams(gammas=np.asarray(gammas, dtype=np.float64),
                     alpha=float(np.sum(a)), g_max=spec.g_max, dim=spec.dim,
                     energy_per_symbol=spec.e_s, noise_psd=spec.n0)


# ---------------------------------------------------------------- anchors

def anchor_min_noise(spec: OTADesignSpec) -> np.ndarray:
    """gamma = gamma_max: maximize alpha -> minimum noise variance [1]."""
    return spec.gamma_max().copy()


def anchor_zero_bias(spec: OTADesignSpec) -> np.ndarray:
    """Equalize alpha_m at min_m alpha_max -> p = 1/N exactly [1]."""
    c = spec.c_m()
    target = float(np.min(spec.alpha_max())) * (1.0 - 1e-9)
    # alpha_m is increasing on [0, gamma_max]; bisect the smaller root of
    # alpha_m(gamma) = target over all devices at once
    lo = np.zeros(spec.n)
    hi = spec.gamma_max().copy()
    for _ in range(200):
        mid = 0.5 * (lo + hi)
        below = mid * np.exp(-c * mid ** 2) < target
        lo = np.where(below, mid, lo)
        hi = np.where(below, hi, mid)
    return 0.5 * (lo + hi)


# ------------------------------------------------------------- SCA (paper)

def _pack(g, p, z, a):
    return np.concatenate([g, p, z, [a]])


def _unpack(x, n):
    return x[:n], x[n:2 * n], x[2 * n:3 * n], float(x[3 * n])


def design_ota_sca(spec: OTADesignSpec, *, n_iters: int = 12,
                   anchor: Optional[np.ndarray] = None) -> tuple[OTAParams, SCAResult]:
    """Paper-faithful SCA (Sec. IV-A): iterate convex surrogate (16)."""
    n = spec.n
    c = spec.c_m()
    gmax = spec.gamma_max()
    amax = spec.alpha_max()
    u_g = float(np.median(gmax))               # gamma scale
    u_a = float(np.sum(amax))                  # alpha scale
    u_z = u_g / u_a
    g2 = spec.g_max ** 2
    wv, wb = spec.weights.omega_var, spec.weights.omega_bias
    s2 = spec.sigmas2

    def project(x: np.ndarray) -> np.ndarray:
        """Restore exact physical coupling (15b)+(15e) from gamma alone."""
        gam = np.clip(x[:n] * u_g, _EPS * u_g, gmax)
        a_m = _alpha_m(spec, gam)
        alpha = float(np.sum(a_m))
        p = a_m / alpha
        z = p * gam / alpha
        return _pack(gam / u_g, p, z / u_z, alpha / u_a)

    def true_obj(x: np.ndarray) -> float:
        return true_objective_from_gamma(spec, np.clip(x[:n] * u_g, 0, gmax))

    def build(xbar: np.ndarray) -> SurrogateProblem:
        gb, pb, zb, ab = _unpack(xbar, n)
        gb_p, ab_p = gb * u_g, ab * u_a         # physical anchors

        def f(x):
            g, p, z, a = _unpack(x, n)
            a_p = a * u_a
            return (wv * (np.sum(g2 * z * u_z) + spec.dim * spec.n0 / a_p ** 2
                          + np.sum(p ** 2 * s2)
                          - np.sum(g2 * pb * (2 * p - pb)))
                    + wb * np.sum((p - 1.0 / n) ** 2))

        def fgrad(x):
            g, p, z, a = _unpack(x, n)
            a_p = a * u_a
            gr = np.zeros_like(x)
            gr[2 * n:3 * n] = wv * g2 * u_z
            gr[n:2 * n] = wv * (2 * p * s2 - 2 * g2 * pb) + 2 * wb * (p - 1.0 / n)
            gr[3 * n] = wv * (-2 * spec.dim * spec.n0 / a_p ** 3) * u_a
            return gr

        # (16b): ln z + ln a - ln(gb pb) - g/gb - p/pb + 2 >= 0 (physical vars)
        def c1(x):
            g, p, z, a = _unpack(x, n)
            return (np.log(np.maximum(z * u_z, 1e-300))
                    + np.log(max(a * u_a, 1e-300))
                    - np.log(gb_p * pb) - (g * u_g) / gb_p - p / pb + 2.0)

        def c1j(x):
            g, p, z, a = _unpack(x, n)
            J = np.zeros((n, 3 * n + 1))
            J[:, :n] = np.diag(-1.0 / gb)
            J[:, n:2 * n] = np.diag(-1.0 / pb)
            J[:, 2 * n:3 * n] = np.diag(1.0 / np.maximum(z, 1e-300))
            J[:, 3 * n] = 1.0 / max(a, 1e-300)
            return J

        # (16c): ln g - c g^2 - ln(ab pb) - a/ab - p/pb + 2 >= 0
        def c2(x):
            g, p, z, a = _unpack(x, n)
            gp = g * u_g
            return (np.log(np.maximum(gp, 1e-300)) - c * gp ** 2
                    - np.log(ab_p * pb) - (a * u_a) / ab_p - p / pb + 2.0)

        def c2j(x):
            g, p, z, a = _unpack(x, n)
            gp = g * u_g
            J = np.zeros((n, 3 * n + 1))
            J[:, :n] = np.diag((1.0 / np.maximum(gp, 1e-300) - 2 * c * gp) * u_g)
            J[:, n:2 * n] = np.diag(-1.0 / pb)
            J[:, 3 * n] = -1.0 / ab
            return J

        # (16d): (2 ab - a)/ab^2 - p/amax >= 0
        def c3(x):
            g, p, z, a = _unpack(x, n)
            return (2 * ab_p - a * u_a) / ab_p ** 2 - p / amax

        def c3j(x):
            J = np.zeros((n, 3 * n + 1))
            J[:, n:2 * n] = np.diag(-1.0 / amax)
            J[:, 3 * n] = -u_a / ab_p ** 2
            return J

        def eq(x):
            return np.array([np.sum(x[n:2 * n]) - 1.0])

        def eqj(x):
            J = np.zeros((1, 3 * n + 1))
            J[0, n:2 * n] = 1.0
            return J

        bnds = ([(1e-6, gmax[m] / u_g) for m in range(n)]
                + [(1e-8, 1.0)] * n
                + [(1e-12, 1e6)] * n
                + [(1e-6, 2.0)])
        return SurrogateProblem(
            objective=f, grad=fgrad,
            ineq_constraints=[
                {"type": "ineq", "fun": c1, "jac": c1j},
                {"type": "ineq", "fun": c2, "jac": c2j},
                {"type": "ineq", "fun": c3, "jac": c3j},
            ],
            eq_constraints=[{"type": "eq", "fun": eq, "jac": eqj}],
            bounds=bnds, x0=xbar.copy())

    anchors = [anchor] if anchor is not None else [
        anchor_min_noise(spec), anchor_zero_bias(spec)]
    best_res = None
    for a0 in anchors:
        a_m0 = _alpha_m(spec, a0)
        x0 = _pack(a0 / u_g, a_m0 / np.sum(a_m0),
                   (a_m0 / np.sum(a_m0)) * a0 / np.sum(a_m0) / u_z,
                   np.sum(a_m0) / u_a)
        res = run_sca(build, true_obj, project, x0, n_iters=n_iters)
        if best_res is None or res.objective < best_res.objective:
            best_res = res
    gam = np.clip(best_res.x[:n] * u_g, 0.0, gmax)
    return params_from_gamma(spec, gam), best_res


# -------------------------------------------------------- direct (beyond)

def design_ota_direct(spec: OTADesignSpec, *, anchor: Optional[np.ndarray] = None,
                      maxiter: int = 500) -> tuple[OTAParams, float]:
    """Beyond-paper: reduce (15) to box-constrained min over gamma, L-BFGS-B.

    Under the simplex constraint, (15b) pins alpha = sum alpha_m(gamma) and
    p = alpha_m/alpha, so gamma is the only free variable.  Smooth objective
    + jax gradient; global structure is still non-convex, so we start from
    both heuristic anchors and keep the best.
    """
    n = spec.n
    c = jnp.asarray(spec.c_m())
    s2 = jnp.asarray(spec.sigmas2)
    gmax = spec.gamma_max()
    g2 = spec.g_max ** 2
    wv, wb = spec.weights.omega_var, spec.weights.omega_bias
    u_g = np.median(gmax)

    def obj(gs: jnp.ndarray) -> jnp.ndarray:
        gam = gs * u_g
        x = c * gam ** 2
        a = gam * jnp.exp(-x)
        alpha = jnp.sum(a)
        p = a / alpha
        trans = jnp.sum(p ** 2 * g2 * (jnp.exp(x) - 1.0))
        mb = jnp.sum(p ** 2 * s2)
        noise = spec.dim * spec.n0 / alpha ** 2
        return (wv * (trans + mb + noise) + wb * jnp.sum((p - 1.0 / n) ** 2))

    val_and_grad = jax.jit(jax.value_and_grad(obj))

    def f(gs64):
        v, g = val_and_grad(jnp.asarray(gs64))
        return float(v), np.asarray(g, dtype=np.float64)

    anchors = [anchor] if anchor is not None else [
        anchor_min_noise(spec), anchor_zero_bias(spec)]
    best_g, best_f = None, np.inf
    for a0 in anchors:
        # start inside the box (heuristic anchors can graze its edges)
        x0 = np.clip(a0 / u_g, 1e-6, gmax / u_g)
        res = optimize.minimize(f, x0, jac=True, method="L-BFGS-B",
                                bounds=[(1e-6, gmax[m] / u_g) for m in range(n)],
                                options={"maxiter": maxiter})
        if res.fun < best_f:
            best_f, best_g = float(res.fun), np.clip(res.x * u_g, 0, gmax)
    return params_from_gamma(spec, best_g), best_f


# ------------------------------------------------------- batched (jax)

def default_anchors(spec: OTADesignSpec) -> np.ndarray:
    """(A, N) heuristic gamma anchors: min-noise + zero-bias (Sec. IV-A)."""
    return np.stack([anchor_min_noise(spec), anchor_zero_bias(spec)])


def stack_ota_specs(specs: Sequence[OTADesignSpec]) -> dict:
    """Stack B design specs along a leading axis for the batched solver.

    All specs must share the device count N; everything else (channel
    gains, dimension, energy, noise, objective weights) may vary per point
    — they enter the solve as traced data, so one jit covers the sweep.
    """
    n = specs[0].n
    if any(s.n != n for s in specs):
        raise ValueError("all specs in a batch must share the device count")
    return {
        "lambdas": np.stack([np.asarray(s.lambdas, np.float64)
                             for s in specs]),
        "dim": np.array([float(s.dim) for s in specs]),
        "g_max": np.array([s.g_max for s in specs]),
        "e_s": np.array([s.e_s for s in specs]),
        "n0": np.array([s.n0 for s in specs]),
        "omega_var": np.array([s.weights.omega_var for s in specs]),
        "omega_bias": np.array([s.weights.omega_bias for s in specs]),
        "sigma_sq": np.stack([s.sigmas2 for s in specs]),
    }


def design_ota_batch(specs: Sequence[OTADesignSpec],
                     anchors: Optional[np.ndarray] = None
                     ) -> tuple[list[OTAParams], np.ndarray]:
    """Solve a grid of OTA design problems (15) in one batched jit.

    The JAX counterpart of calling ``design_ota_sca`` per point: same
    heuristic anchors, same true objective (15a), but the whole batch
    solves as one ``jit(vmap(...))`` (``core.sca_jax``). The SciPy SCA
    path remains the trusted oracle; ``benchmarks/design_bench.py``
    records the wall-clock gap and objective parity.

    Returns (params, objectives): per-point ``OTAParams`` and the (B,)
    true objectives at the returned designs.
    """
    from . import sca_jax

    if anchors is None:
        anchors = np.stack([default_anchors(s) for s in specs])
    stk = stack_ota_specs(specs)
    gammas, objs = sca_jax.solve_ota_gamma_batch(
        stk["lambdas"], stk["dim"], stk["g_max"], stk["e_s"], stk["n0"],
        stk["omega_var"], stk["omega_bias"], stk["sigma_sq"], anchors)
    params = [params_from_gamma(s, np.clip(g, 0.0, s.gamma_max()))
              for s, g in zip(specs, gammas)]
    return params, objs


def design_ota_participation(spec: OTADesignSpec, params: OTAParams,
                             clients: int, *, survival=None
                             ) -> tuple[np.ndarray, float]:
    """Co-designed Bernoulli inclusion probabilities pi for OTA schemes.

    Given a solved OTA design (its effective participation levels
    ``p_m = alpha_m/alpha``) and an expected cohort size S, solves the
    bound-shaped sampling problem (``core.sca_jax.
    solve_participation_batch``) under the cell's bias/variance weights;
    ``survival`` are the fault-layer survival probabilities q_m (ones
    when faults are off), so outage and sampling bias are priced jointly
    (effective levels ~ p * pi * q).

    Returns (pi, objective): the (N,) probabilities on the capped simplex
    {sum pi = S, pi <= 1} and the sampling objective value.
    """
    from . import sca_jax

    p = np.asarray(params.participation_levels(spec.lambdas), np.float64)
    q = (np.ones_like(p) if survival is None
         else np.asarray(survival, np.float64))
    pi, obj = sca_jax.solve_participation_batch(
        p[None], q[None], [clients],
        [spec.weights.omega_var], [spec.weights.omega_bias])
    return pi[0], float(obj[0])
