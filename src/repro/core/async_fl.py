"""Buffered-asynchronous FL: stationary staleness as priced structured bias.

Everything else in the repo is round-synchronous: every device's round-``t``
gradient is computed at the round-``t`` model. This module supplies the
buffered-async execution mode (``run.mode="async"``) both simulation
backends share, built on the same counter-based-stream / strict-no-op
contracts as the fault and participation layers:

  * **Heterogeneous arrivals.** Device ``m`` completes a local update in a
    given round with static per-round probability ``r_m``
    (:func:`arrival_rates`: a log-spread around ``arrival_rate`` controlled
    by ``rate_heterogeneity`` — the straggler distribution). Per round, one
    (2, N) uniform block from the counter-based ARRIVAL stream
    (``core.rngstream``, a pure threefry function of
    ``(seed, trial, round)``) drives a delivery event (``u0 < r_m``) and a
    staleness draw for the delivered update.
  * **Stationary staleness.** A delivered update was computed ``S`` rounds
    ago with ``S`` geometric(``r_m``): slow devices deliver stale
    gradients. The PS buffers the last ``K = buffer_rounds`` rounds of
    per-device gradients (a scan-carried (K, N, d) window in the JAX
    engine); draws with ``S >= K`` fall outside the buffer window and are
    discarded. The staleness CDF thresholds (:func:`staleness_cdf`) and
    rates are precomputed host-side in float64, so the realized
    delivery/staleness pattern is *bit-identical* across the NumPy oracle,
    the JAX engine, and both rng modes — only exact comparisons against
    shared tables, never transcendentals, happen inside the round loop.
  * **Staleness-discounted delivery.** The payload entering every
    registered scheme's combiner is ``delta^S * v_m * (N / sum(c v)) *
    g_m(w_{t-S})``: the staleness discount ``delta = staleness_discount``,
    a per-device PS weight ``v_m`` (uniform 1, or the co-designed weights
    from ``core.sca_jax.solve_async_batch``), and a global normalization
    that keeps the expected delivered mass at N. Missing devices zero-fill
    (``on_missing="zero"``, the priced default) or replay their last
    delivered payload (``"stale"`` — the same single last-gradient code
    path, :func:`stale_replace`, that backs ``fault.on_missing="stale"``).

Because the staleness distribution is *stationary*, the induced shift is a
structured, time-invariant tilt of the effective participation levels:
``e_m = p_m * c_m * v_m * (N / sum(c v))`` with
``c_m = E[delta^S; delivered within the window]``
(:func:`delivery_weight`) — exactly the kind of bias the Theorem-1/2
bound prices through ``bounds.async_effective_participation`` /
``bounds.bias_sum``, composing with the fault (q) and sampling (pi)
factors that already tilt ``p``.

``run.mode="sync"`` (the default) disables the layer entirely:
:func:`resolve` returns None and both backends trace/execute their exact
pre-async programs (bit-identical trajectories, the ``FaultSpec`` /
``core.participation`` strict-no-op contract).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np
import jax.numpy as jnp

MODES = ("sync", "async")
ON_MISSING = ("zero", "stale")
WEIGHTINGS = ("uniform", "designed")

#: Floor on per-device arrival rates (a rate of 0 would make the staleness
#: geometry degenerate and the device silent forever).
RATE_MIN = 1e-3


@dataclasses.dataclass(frozen=True)
class AsyncSpec:
    """Buffered-async knobs (``async_.*`` sweep axes; inert under
    ``run.mode="sync"``).

    buffer_rounds       K — staleness buffer depth; delivered updates carry
                        staleness S in {0, ..., K-1}, older draws are
                        discarded (fell out of the buffer window).
    arrival_rate        mean per-round completion probability r of a device
                        (1.0 = every device delivers a fresh update every
                        round — the synchronous limit).
    rate_heterogeneity  log-spread of the per-device rates: device rates
                        span ``arrival_rate * (1+h)^{±1}`` across the
                        population (0 = homogeneous; the straggler axis).
    staleness_discount  delta — multiplicative weight ``delta^S`` on a
                        staleness-S payload (1.0 = undiscounted).
    on_missing          "zero" (priced bias, default) | "stale" (replay the
                        last delivered payload, :func:`stale_replace`).
    weighting           "uniform" — v = 1; "designed" — per-device PS
                        weights from ``sca_jax.solve_async_batch`` (must be
                        passed explicitly to the trainer/engine).
    """

    buffer_rounds: int = 4
    arrival_rate: float = 0.7
    rate_heterogeneity: float = 0.0
    staleness_discount: float = 1.0
    on_missing: str = "zero"
    weighting: str = "uniform"

    def __post_init__(self):
        if int(self.buffer_rounds) < 1:
            raise ValueError(
                f"buffer_rounds must be >= 1, got {self.buffer_rounds!r}")
        if not 0.0 < float(self.arrival_rate) <= 1.0:
            raise ValueError(
                f"arrival_rate must be in (0, 1], got {self.arrival_rate!r}")
        if float(self.rate_heterogeneity) < 0.0:
            raise ValueError(
                "rate_heterogeneity must be >= 0, got "
                f"{self.rate_heterogeneity!r}")
        if not 0.0 < float(self.staleness_discount) <= 1.0:
            raise ValueError(
                "staleness_discount must be in (0, 1], got "
                f"{self.staleness_discount!r}")
        if self.on_missing not in ON_MISSING:
            raise ValueError(
                f"async on_missing must be one of {ON_MISSING}, got "
                f"{self.on_missing!r}")
        if self.weighting not in WEIGHTINGS:
            raise ValueError(
                f"async weighting must be one of {WEIGHTINGS}, got "
                f"{self.weighting!r}")


def arrival_rates(spec: AsyncSpec, n_devices: int) -> np.ndarray:
    """(N,) float64 per-round completion probabilities r_m.

    Log-spread around the mean rate: ``r_m = arrival_rate * (1+h)^{x_m}``
    with x_m linearly spaced on [-1, 1] — device 0 is the slowest
    straggler, device N-1 the fastest. Deterministic pure NumPy, so both
    backends (and the bound/solver side) share the identical rate bits.
    """
    n = int(n_devices)
    x = np.linspace(-1.0, 1.0, n) if n > 1 else np.zeros(1)
    g = 1.0 + float(spec.rate_heterogeneity)
    return np.clip(float(spec.arrival_rate) * g ** x, RATE_MIN, 1.0)


def staleness_cdf(rates: np.ndarray, buffer_rounds: int) -> np.ndarray:
    """(K, N) float64 staleness CDF thresholds: row j is P(S <= j).

    ``S ~ geometric(r_m)`` (support {0, 1, ...}): ``P(S <= j) =
    1 - (1-r)^{j+1}``. The round loop compares the staleness uniform
    against these *precomputed* thresholds — counting crossed rows gives
    the staleness integer with exact float64 comparisons only, so the
    realization is bit-identical across NumPy/JAX (no in-loop logs whose
    last ulp could differ between libm and XLA). A uniform at or above
    row K-1 means S >= K: the update fell out of the buffer window.
    """
    r = np.asarray(rates, dtype=np.float64)
    j = np.arange(1, int(buffer_rounds) + 1, dtype=np.float64)[:, None]
    return 1.0 - (1.0 - r)[None, :] ** j


def staleness_pmf(rates: np.ndarray, buffer_rounds: int) -> np.ndarray:
    """(K, N) float64 in-window staleness pmf: row s is P(S = s)."""
    cdf = staleness_cdf(rates, buffer_rounds)
    n = cdf.shape[1]
    return np.diff(np.concatenate([np.zeros((1, n)), cdf], axis=0), axis=0)


def delivery_weight(spec: AsyncSpec, n_devices: int) -> np.ndarray:
    """(N,) c_m = E[delta^S ; delivered within the window] per round.

    The static multiplicative tilt the async layer applies to device m's
    participation level: delivery happens with probability r_m, the draw
    stays inside the K-round window with probability P(S < K), and a
    staleness-S payload carries weight delta^S. Computed from the same
    pmf/CDF tables the round loop realizes, so the bound prices exactly
    the simulated process.
    """
    r = arrival_rates(spec, n_devices)
    pmf = staleness_pmf(r, spec.buffer_rounds)
    disc = float(spec.staleness_discount) ** np.arange(int(spec.buffer_rounds))
    return r * np.sum(disc[:, None] * pmf, axis=0)


def expected_staleness(spec: AsyncSpec, n_devices: int) -> np.ndarray:
    """(N,) E[S | delivered within the window] — the solver's per-device
    staleness penalty weight (stale payloads inject drift variance)."""
    r = arrival_rates(spec, n_devices)
    pmf = staleness_pmf(r, spec.buffer_rounds)
    s = np.arange(int(spec.buffer_rounds), dtype=np.float64)
    mass = np.maximum(pmf.sum(axis=0), 1e-300)
    return np.sum(s[:, None] * pmf, axis=0) / mass


@dataclasses.dataclass(frozen=True)
class ResolvedAsync:
    """Validated, backend-shared async configuration (hashable).

    All tables are float64 tuples so the object keys the engine's jitted
    runner cache and compares by content across trainer rebuilds — the
    ``ResolvedParticipation`` pattern.
    """

    buffer_rounds: int           # K — buffer depth / max staleness + 1
    on_missing: str              # "zero" | "stale"
    staleness_discount: float    # delta
    weighting: str               # provenance: "uniform" | "designed"
    rates: tuple                 # (N,) per-round completion probabilities
    weights: tuple               # (N,) PS per-device weights v, sum == N

    @property
    def n_devices(self) -> int:
        return len(self.rates)

    def rates_array(self) -> np.ndarray:
        return np.asarray(self.rates, dtype=np.float64)

    def weights_array(self) -> np.ndarray:
        return np.asarray(self.weights, dtype=np.float64)

    def cdf_array(self) -> np.ndarray:
        """(K, N) staleness CDF thresholds (:func:`staleness_cdf`)."""
        return staleness_cdf(self.rates_array(), self.buffer_rounds)

    def discounts_array(self) -> np.ndarray:
        """(K,) staleness discount table delta^s."""
        return (float(self.staleness_discount)
                ** np.arange(int(self.buffer_rounds), dtype=np.float64))

    def delivery_weight_array(self) -> np.ndarray:
        """(N,) c_m — see :func:`delivery_weight`."""
        r = self.rates_array()
        pmf = staleness_pmf(r, self.buffer_rounds)
        return r * np.sum(self.discounts_array()[:, None] * pmf, axis=0)

    def payload_scale_array(self) -> np.ndarray:
        """(N,) per-device payload scale ``v_m * N / sum(c v)``.

        The global factor normalizes the *expected* delivered mass to N
        (the synchronous all-deliver reference), so async runs stay on the
        trainer's step-size scale and only the per-device tilt — the
        priced bias — differs across weightings.
        """
        c = self.delivery_weight_array()
        v = self.weights_array()
        return v * (self.n_devices / float(np.sum(c * v)))


def resolve(mode: str, spec: Optional[AsyncSpec], n_devices: int,
            weights=None) -> Optional[ResolvedAsync]:
    """Normalize the (mode, spec, weights) knobs both backends take.

    Returns None under ``mode="sync"`` (the strict no-op); otherwise a
    validated :class:`ResolvedAsync`. Explicit ``weights`` override the
    weighting policy's construction (that is how "designed" weights from
    ``sca_jax.solve_async_batch`` reach the trainer); they must lie on
    {sum v = N, v > 0}.
    """
    if mode not in MODES:
        raise ValueError(f"run mode must be one of {MODES}, got {mode!r}")
    if mode == "sync":
        if weights is not None:
            raise ValueError(
                "async_weights given but run mode is 'sync'; set "
                "mode='async' to enable buffered-async aggregation")
        return None
    spec = spec if spec is not None else AsyncSpec()
    n = int(n_devices)
    if weights is not None:
        v = np.asarray(weights, dtype=np.float64)
        if v.shape != (n,):
            raise ValueError(
                f"async_weights must have shape ({n},), got {v.shape}")
        if np.any(v <= 0.0) or not np.all(np.isfinite(v)):
            raise ValueError("async_weights must be finite and > 0")
        if abs(float(v.sum()) - n) > 1e-6 * n:
            raise ValueError(
                f"async_weights must sum to n_devices={n}, got sum "
                f"{float(v.sum()):.9g}")
    elif spec.weighting == "uniform":
        v = np.ones(n)
    else:   # "designed" without explicit weights
        raise ValueError(
            "async weighting='designed' needs explicit async_weights "
            "(solve them with core.sca_jax.solve_async_batch, e.g. via "
            "api.materialize.CellContext.async_weights)")
    return ResolvedAsync(buffer_rounds=int(spec.buffer_rounds),
                         on_missing=spec.on_missing,
                         staleness_discount=float(spec.staleness_discount),
                         weighting=spec.weighting,
                         rates=tuple(arrival_rates(spec, n).tolist()),
                         weights=tuple(v.tolist()))


def _xp(a):
    """Backend namespace sniff: NumPy arrays stay NumPy, everything else
    (jnp arrays and tracers) routes to jnp — the where/concatenate calls
    below are the only ops the two array APIs don't share operator-wise."""
    return np if isinstance(a, np.ndarray) else jnp


def async_round(g, buf, u, rates, cdf, discounts, pay_scale):
    """One buffered-async delivery step, shared by both backends.

    ``g`` (N, d) is the round's fresh per-device gradients (already
    payload-cast and participation-scaled), ``buf`` (K, N, d) the
    staleness buffer (slot s = gradients computed s rounds ago, before
    this round's shift), ``u`` the round's (2, N) ARRIVAL uniforms widened
    to float64, and ``rates`` (N,) / ``cdf`` (K, N) / ``discounts`` (K,) /
    ``pay_scale`` (N,) the resolved tables in the *caller's* backend dtype
    (the NumPy oracle passes float64 ndarrays, the engine jnp constants).

    Returns ``(payload, ok, buf_new)``: the staleness-discounted delivered
    payloads ``delta^S * v * (N/sum(cv)) * g(w_{t-S})``, the (N,) boolean
    delivery mask (False = no completion this round, or the draw fell out
    of the buffer window), and the shifted buffer. Every operation is an
    exact comparison / gather / multiply against the shared float64
    tables, so the realized mask and staleness integers are bit-identical
    across NumPy/JAX and both rng modes.
    """
    xp = _xp(g)
    buf = xp.concatenate([g[None], buf[:-1]], axis=0)
    k = buf.shape[0]
    n = g.shape[0]
    deliver = u[0] < rates
    crossed = (u[1][None, :] >= cdf).sum(axis=0)      # (N,) staleness int
    ok = deliver & (crossed < k)
    s = xp.minimum(crossed, k - 1)
    g_sel = buf[s, xp.arange(n)]
    payload = g_sel * (discounts[s] * pay_scale)[:, None]
    return payload, ok, buf


def stale_replace(g, ok, g_last):
    """Missing payloads replay the last received ones; returns
    ``(g_new, g_last_new)``.

    The single last-gradient code path behind both staleness fallbacks:
    ``fault.on_missing="stale"`` (the PR-8 policy, now routed through
    here) and the async layer's ``on_missing="stale"``. ``ok`` is the
    (N,) boolean delivery mask; the updated carry is the post-replacement
    payload matrix itself (a device's slot always holds the last payload
    the PS actually consumed).
    """
    g_new = _xp(g).where(ok[:, None], g, g_last)
    return g_new, g_new
