"""SOTA wireless-FL baselines reproduced for Sec. V comparisons.

All baselines implement the ``Aggregator`` protocol used by the FL
simulation loop (``repro.fl.trainer``): given the per-device local gradients
and the round's fading realization, produce the PS global-gradient estimate
plus round metadata (latency, participants).

OTA baselines (Sec. V-A-1):
  * IdealFedAvg        — noiseless mean (upper bound).
  * ProposedOTA        — our biased OTA update with offline-designed params.
  * VanillaOTA   [13]  — zero-instantaneous-bias common pre-scaler, needs
                         global instantaneous CSI (min-gain inversion).
  * OPCOTAComp   [19]  — per-round MSE-optimal power control (global CSI).
  * LCPCOTAComp  [19]  — common tunable pre-scaler, statistical CSI.
  * OPCOTAFL     [20]  — genie-aided per-round threshold power control,
                         no PS post-scaler (uncontrolled bias allowed).
  * BBFLInterior [16]  — schedule devices within rho_in, trunc. inversion.
  * BBFLAlternative[16]— alternate all-device / interior rounds.

Digital baselines (Sec. V-A-2); every scheme transmits dithered-quantized
gradients and is charged channel-capacity latency, as in the paper:
  * ProposedDigital    — our biased digital update.
  * BestChannel  [7]   — top-K instantaneous |h|, equal bits.
  * BestChannelNorm[7] — top-K' by |h| then top-K by ||g||, bits ∝ norms.
  * PropFairness [9]   — top-K by |h|^2/Lambda.
  * UQOS         [32]  — optimized unbiased sampling, common fixed rate.
  * QML          [11]  — min-latency bit allocation under variance cap.
  * FedTOE       [10]  — equal-outage rates, variance-min bit allocation.

Where a published scheme depends on machinery orthogonal to this paper
(e.g. gradient sparsification in [7]), we follow the paper's own adapted
setup (Sec. V): dithered quantization everywhere, no sparsification.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from .channel import Deployment
from .digital import DigitalParams, digital_round, outage_mask
from .ota import OTAParams, ota_round, uniform_gamma_min_variance
from .quantize import payload_bits, quantize_np, quantize_np_dither


@dataclasses.dataclass
class RoundResult:
    ghat: np.ndarray
    latency_s: float
    participants: np.ndarray      # 0/1 per device
    info: dict


class Aggregator:
    """Base: one uplink round. Subclasses set ``name`` and ``is_ota``."""

    name: str = "base"
    is_ota: bool = True

    def round(self, grads: Sequence[np.ndarray], h: np.ndarray, t: int,
              rng: np.random.Generator,
              dither: Optional[np.ndarray] = None) -> RoundResult:
        """One uplink round.

        ``dither``: optional (N, d) counter-based dither uniforms for this
        round (see ``core.rngstream``); the FL trainer always supplies it
        for digital schemes so the JAX engine can replay the stream. OTA
        schemes ignore it. When None, digital schemes fall back to drawing
        dither sequentially from ``rng`` (standalone/back-compat use).
        """
        raise NotImplementedError


# --------------------------------------------------------------------- OTA

class IdealFedAvg(Aggregator):
    name = "Ideal FedAvg"

    def round(self, grads, h, t, rng, dither=None):
        g = np.mean(np.stack([np.asarray(g) for g in grads]), axis=0)
        return RoundResult(g, 0.0, np.ones(len(grads)), {})


class ProposedOTA(Aggregator):
    """Our scheme: offline-designed (gamma, alpha) biased OTA update."""

    def __init__(self, params: OTAParams, label: str = "Proposed OTA-FL (SCA)"):
        self.params = params
        self.name = label

    def round(self, grads, h, t, rng, dither=None):
        ghat, chi = ota_round(self.params, grads, h, rng)
        d = self.params.dim
        # concurrent analog upload: tau = d/B symbols (Sec. II-A), charged
        # by the trainer via its bandwidth constant; latency here is in
        # "channel uses" and converted by the caller
        return RoundResult(ghat, float(d), chi, {})


class VanillaOTA(Aggregator):
    """[13]: all devices invert with a common pre-scaler set by the weakest
    instantaneous channel (global CSI), zero instantaneous bias."""

    name = "Vanilla OTA-FL"

    def __init__(self, dim: int, g_max: float, e_s: float, n0: float):
        self.dim, self.g_max, self.e_s, self.n0 = dim, g_max, e_s, n0

    def round(self, grads, h, t, rng, dither=None):
        n = len(grads)
        gamma_t = np.sqrt(self.dim * self.e_s) * float(np.min(np.abs(h))) / self.g_max
        acc = gamma_t * np.sum(np.stack([np.asarray(g) for g in grads]), axis=0)
        z = rng.normal(scale=np.sqrt(self.n0), size=self.dim)
        ghat = (acc + z) / (n * gamma_t)
        return RoundResult(ghat, float(self.dim), np.ones(n), {"gamma_t": gamma_t})


class OPCOTAComp(Aggregator):
    """[19] optimized power control for OTA computation: per-round MSE-optimal
    (eta, {b_m}) with global instantaneous CSI. Devices below the inversion
    threshold transmit at full power (uncontrolled shrinkage bias)."""

    name = "OPC OTA-Comp"

    def __init__(self, dim: int, g_max: float, e_s: float, n0: float,
                 n_grid: int = 64):
        self.dim, self.g_max, self.e_s, self.n0 = dim, g_max, e_s, n0
        self.n_grid = n_grid

    def _mse(self, eta: float, habs: np.ndarray) -> float:
        n = habs.shape[0]
        b_bar = np.sqrt(self.dim * self.e_s) / self.g_max
        b = np.minimum(b_bar, np.sqrt(eta) / habs)
        c = b * habs / np.sqrt(eta)          # contribution weight, <= 1
        return (self.g_max ** 2 * np.sum((c - 1.0) ** 2) / n ** 2
                + self.dim * self.n0 / (n ** 2 * eta))

    def round(self, grads, h, t, rng, dither=None):
        habs = np.abs(h)
        n = len(grads)
        b_bar = np.sqrt(self.dim * self.e_s) / self.g_max
        # candidate eta: structure of [19] — optimum is at one of the
        # channel-inversion breakpoints or between; log-grid + refine
        lo = (b_bar * np.min(habs)) ** 2 * 1e-4
        hi = (b_bar * np.max(habs)) ** 2 * 1e4
        etas = np.geomspace(max(lo, 1e-300), hi, self.n_grid)
        mses = [self._mse(e, habs) for e in etas]
        eta = float(etas[int(np.argmin(mses))])
        b = np.minimum(b_bar, np.sqrt(eta) / habs)
        acc = np.zeros(self.dim)
        for m, g in enumerate(grads):
            acc += b[m] * habs[m] * np.asarray(g)     # phase-aligned
        z = rng.normal(scale=np.sqrt(self.n0), size=self.dim)
        ghat = (acc + z) / (n * np.sqrt(eta))
        return RoundResult(ghat, float(self.dim), np.ones(n), {"eta": eta})


class LCPCOTAComp(Aggregator):
    """[19] low-complexity power control: one common truncated-inversion
    pre-scaler optimized offline from channel statistics."""

    name = "LCPC OTA-Comp"

    def __init__(self, deployment: Deployment, dim: int, g_max: float,
                 e_s: float, n0: float):
        gamma = uniform_gamma_min_variance(deployment.lambdas, dim, e_s,
                                           g_max, n0)
        gammas = np.full(deployment.n_devices, gamma)
        a_m = gammas * np.exp(-(gammas ** 2) * g_max ** 2
                              / (dim * deployment.lambdas * e_s))
        self.params = OTAParams(gammas=gammas, alpha=float(np.sum(a_m)),
                                g_max=g_max, dim=dim, energy_per_symbol=e_s,
                                noise_psd=n0)

    def round(self, grads, h, t, rng, dither=None):
        ghat, chi = ota_round(self.params, grads, h, rng)
        return RoundResult(ghat, float(self.params.dim), chi, {})


class OPCOTAFL(Aggregator):
    """[20] (genie-aided) optimized OTA-FL power control: per-round common
    inversion threshold chosen with full current-round CSI, no PS
    post-scaler constraint (bias left uncontrolled)."""

    name = "OPC OTA-FL (genie)"

    def __init__(self, dim: int, g_max: float, e_s: float, n0: float):
        self.dim, self.g_max, self.e_s, self.n0 = dim, g_max, e_s, n0

    def round(self, grads, h, t, rng, dither=None):
        habs = np.abs(h)
        n = len(grads)
        order = np.argsort(habs)[::-1]
        best = None
        for k in range(1, n + 1):
            theta = habs[order[k - 1]]
            gamma = np.sqrt(self.dim * self.e_s) * theta / self.g_max
            # include-k-strongest: bias proxy (1-k/n)^2 G^2 + noise
            score = (self.g_max ** 2 * (1.0 - k / n) ** 2
                     + self.dim * self.n0 / (k * gamma) ** 2)
            if best is None or score < best[0]:
                best = (score, k, gamma)
        _, k, gamma = best
        sel = order[:k]
        chi = np.zeros(n)
        chi[sel] = 1.0
        acc = gamma * np.sum(np.stack([np.asarray(grads[m]) for m in sel]), axis=0)
        z = rng.normal(scale=np.sqrt(self.n0), size=self.dim)
        ghat = (acc + z) / (k * gamma)
        return RoundResult(ghat, float(self.dim), chi, {"k": k})


class BBFLInterior(Aggregator):
    """[16] broadband analog aggregation, cell-interior scheduling: only
    devices with distance <= rho_in participate, truncated inversion with a
    statistically-tuned common pre-scaler; PS divides by (|S_t| * gamma)."""

    name = "BB-FL Interior"

    def __init__(self, deployment: Deployment, dim: int, g_max: float,
                 e_s: float, n0: float, rho_in_frac: float = 0.7):
        self.interior = deployment.distances_m <= rho_in_frac * deployment.cfg.rho_max_m
        lam_in = deployment.lambdas[self.interior]
        self.gamma = uniform_gamma_min_variance(lam_in, dim, e_s, g_max, n0)
        self.dim, self.g_max, self.e_s, self.n0 = dim, g_max, e_s, n0

    def round(self, grads, h, t, rng, dither=None):
        n = len(grads)
        tau = self.g_max * self.gamma / np.sqrt(self.dim * self.e_s)
        chi = (np.abs(h) >= tau) & self.interior
        k = int(np.sum(chi))
        acc = np.zeros(self.dim)
        for m in range(n):
            if chi[m]:
                acc += self.gamma * np.asarray(grads[m])
        z = rng.normal(scale=np.sqrt(self.n0), size=self.dim)
        denom = max(k, 1) * self.gamma
        ghat = (acc + z) / denom
        return RoundResult(ghat, float(self.dim), chi.astype(float), {"k": k})


class BBFLAlternative(Aggregator):
    """[16] alternating scheduling: even rounds all devices, odd rounds the
    interior policy — balances data exploited vs. aggregation noise."""

    name = "BB-FL Alternative"

    def __init__(self, deployment: Deployment, dim: int, g_max: float,
                 e_s: float, n0: float, rho_in_frac: float = 0.7):
        self.interior_agg = BBFLInterior(deployment, dim, g_max, e_s, n0,
                                         rho_in_frac)
        self.all_mask = np.ones(deployment.n_devices, dtype=bool)
        self.gamma_all = uniform_gamma_min_variance(
            deployment.lambdas, dim, e_s, g_max, n0)
        self.dim, self.g_max, self.e_s, self.n0 = dim, g_max, e_s, n0

    def round(self, grads, h, t, rng, dither=None):
        if t % 2 == 1:
            return self.interior_agg.round(grads, h, t, rng)
        n = len(grads)
        tau = self.g_max * self.gamma_all / np.sqrt(self.dim * self.e_s)
        chi = np.abs(h) >= tau
        k = int(np.sum(chi))
        acc = np.zeros(self.dim)
        for m in range(n):
            if chi[m]:
                acc += self.gamma_all * np.asarray(grads[m])
        z = rng.normal(scale=np.sqrt(self.n0), size=self.dim)
        ghat = (acc + z) / (max(k, 1) * self.gamma_all)
        return RoundResult(ghat, float(self.dim), chi.astype(float), {"k": k})


# ----------------------------------------------------------------- digital

def _capacity_rate(habs: np.ndarray, e_s: float, n0: float) -> np.ndarray:
    """Instantaneous spectral efficiency log2(1 + E_s|h|^2/N0) [b/s/Hz]."""
    return np.log2(1.0 + e_s * habs ** 2 / n0)


class ProposedDigital(Aggregator):
    is_ota = False

    def __init__(self, params: DigitalParams,
                 label: str = "Proposed Digital FL (SCA)"):
        self.params = params
        self.name = label

    def round(self, grads, h, t, rng, dither=None):
        ghat, chi, latency = digital_round(self.params, grads, h, rng,
                                           dither=dither)
        return RoundResult(ghat, latency, chi, {})


class _DigitalBase(Aggregator):
    is_ota = False

    def __init__(self, deployment: Deployment, dim: int, g_max: float,
                 e_s: float, n0: float, bandwidth_hz: float):
        self.dep = deployment
        self.dim, self.g_max = dim, g_max
        self.e_s, self.n0, self.B = e_s, n0, bandwidth_hz

    def _upload(self, grads, sel, bits, habs, rng, dither=None):
        """Quantize+send the selected set; returns (sum of g^q, latency)."""
        rate = _capacity_rate(habs, self.e_s, self.n0)
        acc = np.zeros(self.dim)
        latency = 0.0
        for m in sel:
            r = int(bits[m]) if np.ndim(bits) else int(bits)
            g64 = np.asarray(grads[m], dtype=np.float64)
            gq = (quantize_np(g64, r, rng) if dither is None
                  else quantize_np_dither(g64, r, dither[m]))
            acc += gq
            latency += payload_bits(self.dim, r) / (self.B * max(rate[m], 1e-9))
        return acc, latency


class BestChannel(_DigitalBase):
    """[7]: top-K devices by instantaneous channel gain, equal bits."""

    def __init__(self, deployment, dim, g_max, e_s, n0, bandwidth_hz,
                 k: int = 4, r_bits: int = 6):
        super().__init__(deployment, dim, g_max, e_s, n0, bandwidth_hz)
        self.k, self.r = k, r_bits
        self.name = "Best Channel"

    def round(self, grads, h, t, rng, dither=None):
        habs = np.abs(h)
        sel = np.argsort(habs)[::-1][:self.k]
        acc, latency = self._upload(grads, sel, self.r, habs, rng,
                                    dither=dither)
        chi = np.zeros(len(grads))
        chi[sel] = 1.0
        return RoundResult(acc / self.k, latency, chi, {})


class BestChannelNorm(_DigitalBase):
    """[7]: top-K' by channel then top-K by gradient norm, bits ∝ norms."""

    def __init__(self, deployment, dim, g_max, e_s, n0, bandwidth_hz,
                 k: int = 4, k_prime: int = 6, r_total: int = 24):
        super().__init__(deployment, dim, g_max, e_s, n0, bandwidth_hz)
        self.k, self.kp, self.r_total = k, k_prime, r_total
        self.name = "Best Channel-Norm"

    def round(self, grads, h, t, rng, dither=None):
        habs = np.abs(h)
        cand = np.argsort(habs)[::-1][:self.kp]
        norms = np.array([np.linalg.norm(grads[m]) for m in cand])
        sel = cand[np.argsort(norms)[::-1][:self.k]]
        sel_norms = np.array([np.linalg.norm(grads[m]) for m in sel])
        share = sel_norms / max(np.sum(sel_norms), 1e-12)
        bits = np.zeros(len(grads), dtype=np.int64)
        bits[sel] = np.maximum(1, np.round(self.r_total * share)).astype(np.int64)
        acc, latency = self._upload(grads, sel, bits, habs, rng,
                                    dither=dither)
        chi = np.zeros(len(grads))
        chi[sel] = 1.0
        return RoundResult(acc / self.k, latency, chi, {})


class PropFairness(_DigitalBase):
    """[9]: top-K by normalized fading |h|^2/Lambda (zero average bias)."""

    def __init__(self, deployment, dim, g_max, e_s, n0, bandwidth_hz,
                 k: int = 4, r_bits: int = 6):
        super().__init__(deployment, dim, g_max, e_s, n0, bandwidth_hz)
        self.k, self.r = k, r_bits
        self.name = "Proportional Fairness"

    def round(self, grads, h, t, rng, dither=None):
        score = np.abs(h) ** 2 / self.dep.lambdas
        sel = np.argsort(score)[::-1][:self.k]
        acc, latency = self._upload(grads, sel, self.r, np.abs(h), rng,
                                    dither=dither)
        chi = np.zeros(len(grads))
        chi[sel] = 1.0
        return RoundResult(acc / self.k, latency, chi, {})


class UQOS(_DigitalBase):
    """[32]: unbiased quantized optimized scheduling. K devices sampled
    without replacement with probs pi minimizing (1/N) sum 1/(p_out pi)
    (=> pi ∝ 1/sqrt(p_succ), capped); common fixed rate R for all."""

    def __init__(self, deployment, dim, g_max, e_s, n0, bandwidth_hz,
                 k: int = 4, r_bits: int = 6, rate: float = 0.5):
        super().__init__(deployment, dim, g_max, e_s, n0, bandwidth_hz)
        self.k, self.r, self.rate = k, r_bits, rate
        thr2 = (2.0 ** rate - 1.0) * n0 / e_s
        self.p_succ = np.exp(-thr2 / deployment.lambdas)
        pi = 1.0 / np.sqrt(np.maximum(self.p_succ, 1e-9))
        # waterfill pi ∝ 1/sqrt(p_succ) with sum = K, pi <= 1
        pi = pi * self.k / np.sum(pi)
        for _ in range(50):
            over = pi > 1.0
            if not np.any(over):
                break
            deficit = self.k - np.sum(over)
            pi[over] = 1.0
            free = ~over
            pi[free] = pi[free] * deficit / np.sum(pi[free])
        self.pi = np.clip(pi, 1e-6, 1.0)
        self.name = "UQOS"

    def round(self, grads, h, t, rng, dither=None):
        n = len(grads)
        # sample K without replacement with inclusion ∝ pi (systematic)
        order = rng.permutation(n)
        keys = rng.uniform(size=n) ** (1.0 / self.pi[order])
        sel = order[np.argsort(keys)[::-1][:self.k]]
        habs = np.abs(h)
        snr_ok = _capacity_rate(habs, self.e_s, self.n0) >= self.rate
        active = [m for m in sel if snr_ok[m]]
        acc = np.zeros(self.dim)
        latency = 0.0
        for m in active:
            g64 = np.asarray(grads[m], dtype=np.float64)
            gq = (quantize_np(g64, self.r, rng) if dither is None
                  else quantize_np_dither(g64, self.r, dither[m]))
            acc += gq / (n * self.pi[m] * self.p_succ[m])   # unbiased reweight
            latency += payload_bits(self.dim, self.r) / (self.B * self.rate)
        chi = np.zeros(n)
        chi[active] = 1.0
        return RoundResult(acc, latency, chi, {})


class QML(_DigitalBase):
    """[11]: quantized minimum-latency FL. K random devices; minimal common
    bit-width meeting an average quantization-variance cap; capacity rates."""

    def __init__(self, deployment, dim, g_max, e_s, n0, bandwidth_hz,
                 k: int = 4, var_cap: float = 200.0, r_max: int = 16):
        super().__init__(deployment, dim, g_max, e_s, n0, bandwidth_hz)
        self.k, self.var_cap, self.r_max = k, var_cap, r_max
        self.name = "QML"

    def round(self, grads, h, t, rng, dither=None):
        n = len(grads)
        sel = rng.choice(n, size=self.k, replace=False)
        # smallest r with d*G^2/(2^r-1)^2 <= var_cap  (per-device cap)
        r = 1
        while (self.dim * self.g_max ** 2 / (2.0 ** r - 1.0) ** 2
               > self.var_cap and r < self.r_max):
            r += 1
        acc, latency = self._upload(grads, sel, r, np.abs(h), rng,
                                    dither=dither)
        chi = np.zeros(n)
        chi[sel] = 1.0
        return RoundResult(acc / self.k, latency, chi, {"r": r})


class FedTOE(_DigitalBase):
    """[10]: equal outage probability across devices; K random devices; bit
    allocation greedily minimizing average quantization variance under the
    round latency budget; unbiased success reweighting."""

    def __init__(self, deployment, dim, g_max, e_s, n0, bandwidth_hz,
                 k: int = 4, p_out: float = 0.1, t_budget_s: float = 0.22,
                 r_max: int = 16):
        super().__init__(deployment, dim, g_max, e_s, n0, bandwidth_hz)
        self.k, self.p_out, self.t_budget, self.r_max = k, p_out, t_budget_s, r_max
        # fixed per-device rates with common outage prob
        thr2 = -deployment.lambdas * np.log1p(-p_out)
        self.rates = np.log2(1.0 + e_s * thr2 / n0)
        self.thr = np.sqrt(thr2)
        self.name = "FedTOE"

    def _alloc_bits(self, sel) -> dict:
        """Greedy RB/bit allocation under the round budget. Devices whose
        minimum (1-bit) payload does not fit are deferred this round —
        transmitting anyway would blow the latency constraint (paper
        enforces feasibility through its RB optimization)."""
        order = sorted(sel, key=lambda m: -self.rates[m])
        bits, used = {}, 0.0
        for m in order:
            t1 = payload_bits(self.dim, 1) / (self.B * max(self.rates[m], 1e-9))
            if used + t1 <= self.t_budget:
                bits[m] = 1
                used += t1
        def latency():
            return sum(payload_bits(self.dim, bits[m])
                       / (self.B * max(self.rates[m], 1e-9)) for m in bits)

        while bits:
            best_m, best_gain = None, 0.0
            for m in bits:
                if bits[m] >= self.r_max:
                    continue
                dv = (1.0 / (2.0 ** bits[m] - 1) ** 2
                      - 1.0 / (2.0 ** (bits[m] + 1) - 1) ** 2)
                cost = self.dim / (self.B * max(self.rates[m], 1e-9))
                gain = dv / cost
                if gain > best_gain:
                    best_m, best_gain = m, gain
            if best_m is None:
                break
            bits[best_m] += 1
            if latency() > self.t_budget:
                bits[best_m] -= 1
                break
        return bits

    def round(self, grads, h, t, rng, dither=None):
        n = len(grads)
        sel = rng.choice(n, size=self.k, replace=False)
        bits = self._alloc_bits(sel)
        habs = np.abs(h)
        acc = np.zeros(self.dim)
        latency = 0.0
        chi = np.zeros(n)
        k_sched = max(len(bits), 1)
        no_outage = outage_mask(habs, self.thr)
        for m in bits:
            latency += payload_bits(self.dim, bits[m]) / (self.B * max(self.rates[m], 1e-9))
            if no_outage[m]:
                g64 = np.asarray(grads[m], dtype=np.float64)
                gq = (quantize_np(g64, bits[m], rng) if dither is None
                      else quantize_np_dither(g64, bits[m], dither[m]))
                acc += gq / (k_sched * (1.0 - self.p_out))
                chi[m] = 1.0
        return RoundResult(acc, latency, chi, {})
