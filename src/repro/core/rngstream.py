"""Counter-based randomness streams shared by both simulation backends.

The original oracle drew quantization dither *sequentially* from the
per-trial ``np.random.default_rng((seed, trial, 17))`` generator, which
forced the JAX engine to materialize the whole ``(trials, T, N, d)`` dither
tensor up front just to replay the stream inside ``lax.scan`` — gigabytes
for 1500-round digital horizons. Dither is therefore now *counter-based*:
the value consumed by device ``m`` in round ``t`` of trial ``trial`` is a
pure function of ``(seed, trial, t)`` computed with the threefry
``jax.random`` PRNG, identically by

  * the NumPy oracle (eagerly, via :func:`dither_block_np`, one (N, d)
    block per round), and
  * the JAX engine (inside the scan, via :func:`dither_block` on a
    scan-carried per-trial key) — O(N*d) live memory per round.

Threefry is deterministic across CPU/TPU and jit/eager, so the two
backends see bit-identical dither. Uniforms are drawn in float32 and
widened to float64 by both consumers (exact), keeping the streams equal
regardless of the oracle's x64-less default config.

Selection randomness (UQOS' sampling permutation/keys, QML's and FedTOE's
``rng.choice``) stays on the sequential trial generator — those draws are
tiny (O(N) per round) and the engine replays them offline with
:func:`replay_rounds`, feeding the raw draws into the scan as small
``(T, S)`` inputs.
"""
from __future__ import annotations

from typing import Callable

import numpy as np
import jax
import jax.numpy as jnp

#: Stream tag folded into the dither key so it can never collide with other
#: derived streams of the same (seed, trial).
DITHER_TAG = 17


def dither_base_key(seed: int, trial: int) -> jax.Array:
    """Per-trial base key for the dither stream (threefry, counter-based)."""
    key = jax.random.PRNGKey(int(seed) & 0xFFFFFFFF)
    key = jax.random.fold_in(key, int(trial))
    return jax.random.fold_in(key, DITHER_TAG)


def dither_block(key: jax.Array, t, n: int, d: int) -> jnp.ndarray:
    """(n, d) float32 dither uniforms for round ``t`` (jit/scan-traceable).

    ``key`` is the trial's :func:`dither_base_key`; ``t`` may be a traced
    scalar, so the engine folds the round index inside ``lax.scan`` and
    never stores more than one round's block.
    """
    return jax.random.uniform(jax.random.fold_in(key, t), (n, d),
                              dtype=jnp.float32)


def dither_block_np(seed: int, trial: int, t: int, n: int, d: int,
                    _key_cache: dict = {}) -> np.ndarray:
    """Oracle view of :func:`dither_block`: (n, d) float64 numpy array.

    The base key is memoized per (seed, trial) so the per-round cost in the
    Python training loop is one fold_in + uniform dispatch.
    """
    ck = (int(seed), int(trial))
    key = _key_cache.get(ck)
    if key is None:
        if len(_key_cache) > 256:
            _key_cache.clear()
        key = _key_cache[ck] = dither_base_key(seed, trial)
    return np.asarray(dither_block(key, t, n, d), dtype=np.float64)


def trial_rng(seed: int, trial: int) -> np.random.Generator:
    """The sequential per-trial generator used by the NumPy trainer."""
    return np.random.default_rng((seed, trial, 17))


def replay_rounds(seed: int, trial: int, rounds: int,
                  draw_fn: Callable[[np.random.Generator], np.ndarray]
                  ) -> np.ndarray:
    """Replay ``rounds`` per-round draws of the oracle's trial generator.

    ``draw_fn(rng)`` must consume *exactly* what the scheme's
    ``Aggregator.round`` consumes from the trial rng in one round (its
    selection draws), in the same order, and return them as a flat float64
    row. Returns the (rounds, S) stack the engine feeds into its scan.
    """
    rng = trial_rng(seed, trial)
    rows = [np.asarray(draw_fn(rng), dtype=np.float64).ravel()
            for _ in range(rounds)]
    if not rows:
        return np.zeros((0, 1))
    return np.stack(rows)
