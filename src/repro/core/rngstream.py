"""Counter-based randomness streams shared by both simulation backends.

The original oracle drew quantization dither *sequentially* from the
per-trial ``np.random.default_rng((seed, trial, 17))`` generator, which
forced the JAX engine to materialize the whole ``(trials, T, N, d)`` dither
tensor up front just to replay the stream inside ``lax.scan`` — gigabytes
for 1500-round digital horizons. Dither is therefore now *counter-based*:
the value consumed by device ``m`` in round ``t`` of trial ``trial`` is a
pure function of ``(seed, trial, t)`` computed with the threefry
``jax.random`` PRNG, identically by

  * the NumPy oracle (eagerly, via :func:`dither_block_np`, one (N, d)
    block per round), and
  * the JAX engine (inside the scan, via :func:`dither_block` on a
    scan-carried per-trial key) — O(N*d) live memory per round.

Threefry is deterministic across CPU/TPU and jit/eager, so the two
backends see bit-identical dither. Uniforms are drawn in float32 and
widened to float64 by both consumers (exact), keeping the streams equal
regardless of the oracle's x64-less default config.

Mini-batch sampling follows the same counter-based design: the batch
indices consumed by device ``m`` in round ``t`` of trial ``trial`` are a
pure threefry function of ``(seed, trial, t, m)`` (:func:`batch_indices` /
:func:`batch_block`), drawn without replacement. The NumPy trainer feeds
them to ``DeviceDataset.batch(..., indices=...)`` (or the stacked
``task.device_grads_at`` fast path) while the JAX engine regenerates the
(N, B) block inside its ``lax.scan`` from a scan-carried per-trial key —
bit-identical batches on both backends, and the sequential trial rng is
left untouched so the AWGN/selection replay below stays valid whether or
not mini-batching is on.

Selection randomness (UQOS' sampling permutation/keys, QML's and FedTOE's
``rng.choice``) stays on the sequential trial generator — those draws are
tiny (O(N) per round) and the engine replays them offline with
:func:`replay_rounds`, feeding the raw draws into the scan as small
``(T, S)`` inputs.

Fault injection (``core.faults``) draws one (3, N) uniform block per round
from its own counter-based stream (FAULT_TAG, :func:`fault_block` /
:func:`fault_block_np`). Like dither and batch indices — and unlike the
fast-mode-only tags below — the fault stream is counter-based in *both*
rng modes, so injected outages/erasures/stragglers are bit-identical
across rng="replay"/"fast" and across the NumPy/JAX backends.

Partial participation (``core.participation``) draws one (N,) uniform
block per round from its own counter-based stream (PARTICIPATE_TAG,
:func:`participation_block` / :func:`participation_block_np`). Like the
fault stream it is counter-based in *both* rng modes, so the sampled
cohort of every round is bit-identical across rng="replay"/"fast" and
across the NumPy/JAX backends.

Fast mode (``FLTrainer.run(..., rng="fast")``) extends the counter-based
design to *every* stream: PS AWGN (:func:`noise_block`, NOISE_TAG),
Rayleigh fading (FADING_TAG, sampled by ``channel.sample_fading_jax``)
and the per-round selection draws (SELECT_TAG, per-port ``sel_stream_jax``
samplers in the engine) become pure threefry functions of
``(seed, trial, round, stream)`` via :func:`stream_base_key`, generated
inside the scan with zero host-side per-trial precompute. Fast-mode draws
are i.i.d. from the same laws as the oracle's but form a *different*
stream — statistically equivalent (``tests/test_rng_fast.py``'s
mean-trajectory gate), not bit-equal to replay.
"""
from __future__ import annotations

from typing import Callable

import numpy as np
import jax
import jax.numpy as jnp

#: Stream tag folded into the dither key so it can never collide with other
#: derived streams of the same (seed, trial).
DITHER_TAG = 17

#: Stream tag for the mini-batch index stream (distinct from DITHER_TAG so
#: the two counter-based streams of a trial never alias).
BATCH_TAG = 29

#: Fast-mode stream tags (``rng="fast"`` only; replay mode never derives
#: these, so the oracle-parity streams above are untouched).
NOISE_TAG = 41    # PS AWGN z01 draws
FADING_TAG = 43   # Rayleigh fading (consumed via channel.sample_fading_jax)
SELECT_TAG = 47   # device-selection draws (per-port sel_stream_jax)

#: Fault-injection stream (``core.faults``): dropout / erasure / straggler
#: uniforms. Counter-based in BOTH rng modes (like dither and batch), so
#: fault realizations are bit-identical across rng="replay"/"fast" and
#: across the NumPy/JAX backends.
FAULT_TAG = 53

#: Partial-participation stream: the per-round client-sampling uniforms
#: (one (N,) block per round, ``fl.engine`` / ``fl.trainer``). Counter-based
#: in BOTH rng modes (like FAULT), so the sampled cohort of every round is
#: bit-identical across rng="replay"/"fast" and across the NumPy/JAX
#: backends.
PARTICIPATE_TAG = 59

#: Asynchronous-arrival stream (``core.async_fl``): the per-round delivery /
#: staleness uniforms of the buffered-async execution mode (one (2, N)
#: block per round). Counter-based in BOTH rng modes (like FAULT and
#: PARTICIPATE), so arrival realizations are bit-identical across
#: rng="replay"/"fast" and across the NumPy/JAX backends.
ARRIVAL_TAG = 61


#: Bound on the per-stream (seed, trial) -> base-key memos below.
_KEY_CACHE_MAX = 256


def _cached_base_key(cache: dict, seed: int, trial: int,
                     make: Callable[[int, int], jax.Array]) -> jax.Array:
    """Bounded-LRU memo for per-(seed, trial) base keys.

    Hits refresh recency; when full, only the least-recently-used entry is
    evicted — a sweep cycling through many (seed, trial) pairs never
    cold-restarts the keys it is actively using (the old ``.clear()``-when-
    full behavior dropped all live entries at once).
    """
    ck = (int(seed), int(trial))
    key = cache.pop(ck, None)
    if key is None:
        if len(cache) >= _KEY_CACHE_MAX:
            cache.pop(next(iter(cache)))
        key = make(seed, trial)
    cache[ck] = key          # (re)insert at the recent end
    return key


def stream_base_key(seed: int, trial: int, tag: int) -> jax.Array:
    """Per-(trial, stream) threefry base key: fold (seed, trial, tag).

    The one key-derivation rule behind every counter-based stream; round
    (and optionally device) indices are folded in later by the samplers,
    so any draw is a pure function of ``(seed, trial, tag, t[, m])``.
    """
    key = jax.random.PRNGKey(int(seed) & 0xFFFFFFFF)
    key = jax.random.fold_in(key, int(trial))
    return jax.random.fold_in(key, int(tag))


def noise_block(key: jax.Array, t, d: int) -> jnp.ndarray:
    """(d,) float64 standard-normal AWGN draws for round ``t`` (fast mode).

    ``key`` is the trial's ``stream_base_key(seed, trial, NOISE_TAG)``;
    ``t`` may be a traced scalar, so the engine folds the round index
    inside ``lax.scan`` — the replay path's (T, d) host block never
    exists. Drawn in float32 and widened (exactly like the dither
    stream): same N(0, 1) law to well below Monte-Carlo resolution at
    half the in-scan threefry cost — fast mode never bit-matches the
    oracle's float64 ``standard_normal`` stream anyway.
    """
    return jax.random.normal(jax.random.fold_in(key, t), (d,),
                             dtype=jnp.float32).astype(jnp.float64)


def dither_base_key(seed: int, trial: int) -> jax.Array:
    """Per-trial base key for the dither stream (threefry, counter-based)."""
    return stream_base_key(seed, trial, DITHER_TAG)


def dither_block(key: jax.Array, t, n: int, d: int) -> jnp.ndarray:
    """(n, d) float32 dither uniforms for round ``t`` (jit/scan-traceable).

    ``key`` is the trial's :func:`dither_base_key`; ``t`` may be a traced
    scalar, so the engine folds the round index inside ``lax.scan`` and
    never stores more than one round's block.
    """
    return jax.random.uniform(jax.random.fold_in(key, t), (n, d),
                              dtype=jnp.float32)


_DITHER_KEY_CACHE: dict = {}


def dither_block_np(seed: int, trial: int, t: int, n: int,
                    d: int) -> np.ndarray:
    """Oracle view of :func:`dither_block`: (n, d) float64 numpy array.

    The base key is memoized per (seed, trial) (bounded LRU) so the
    per-round cost in the Python training loop is one fold_in + uniform
    dispatch.
    """
    key = _cached_base_key(_DITHER_KEY_CACHE, seed, trial, dither_base_key)
    return np.asarray(dither_block(key, t, n, d), dtype=np.float64)


def fault_base_key(seed: int, trial: int) -> jax.Array:
    """Per-trial base key for the fault-injection stream (threefry)."""
    return stream_base_key(seed, trial, FAULT_TAG)


def fault_block(key: jax.Array, t, n: int) -> jnp.ndarray:
    """(3, n) float32 fault uniforms for round ``t`` (jit/scan-traceable).

    Row 0 drives dropouts, row 1 erasures, row 2 stragglers
    (``core.faults.fault_masks``). ``key`` is the trial's
    :func:`fault_base_key`; ``t`` may be a traced scalar, so the engine
    folds the round index inside ``lax.scan``. Drawn in float32; both
    consumers widen to float64 (exact, the dither-block pattern) so they
    compare the identical value against the float64 fault probabilities.
    """
    return jax.random.uniform(jax.random.fold_in(key, t), (3, n),
                              dtype=jnp.float32)


_FAULT_KEY_CACHE: dict = {}


def fault_block_np(seed: int, trial: int, t: int, n: int) -> np.ndarray:
    """Oracle view of :func:`fault_block`: (3, n) float64 numpy array.

    The base key is memoized per (seed, trial) (bounded LRU) so the
    per-round cost in the Python training loop is one fold_in + uniform
    dispatch (the dither-block pattern).
    """
    key = _cached_base_key(_FAULT_KEY_CACHE, seed, trial, fault_base_key)
    return np.asarray(fault_block(key, t, n), dtype=np.float64)


def participate_base_key(seed: int, trial: int) -> jax.Array:
    """Per-trial base key for the client-participation stream (threefry)."""
    return stream_base_key(seed, trial, PARTICIPATE_TAG)


def participation_block(key: jax.Array, t, n: int) -> jnp.ndarray:
    """(n,) float32 participation uniforms for round ``t`` (scan-traceable).

    Device ``m`` is in round ``t``'s sampled cohort iff
    ``block[m] < pi_m`` for its static inclusion probability ``pi_m``
    (``core.participation``). ``key`` is the trial's
    :func:`participate_base_key`; ``t`` may be a traced scalar, so the
    engine folds the round index inside ``lax.scan``. Drawn in float32;
    both consumers widen to float64 (exact, the fault-block pattern) so
    they compare the identical value against the float64 probabilities.
    """
    return jax.random.uniform(jax.random.fold_in(key, t), (n,),
                              dtype=jnp.float32)


_PARTICIPATE_KEY_CACHE: dict = {}


def participation_block_np(seed: int, trial: int, t: int,
                           n: int) -> np.ndarray:
    """Oracle view of :func:`participation_block`: (n,) float64 numpy.

    The base key is memoized per (seed, trial) (bounded LRU) so the
    per-round cost in the Python training loop is one fold_in + uniform
    dispatch (the fault-block pattern).
    """
    key = _cached_base_key(_PARTICIPATE_KEY_CACHE, seed, trial,
                           participate_base_key)
    return np.asarray(participation_block(key, t, n), dtype=np.float64)


def arrival_base_key(seed: int, trial: int) -> jax.Array:
    """Per-trial base key for the async-arrival stream (threefry)."""
    return stream_base_key(seed, trial, ARRIVAL_TAG)


def arrival_block(key: jax.Array, t, n: int) -> jnp.ndarray:
    """(2, n) float32 arrival uniforms for round ``t`` (jit/scan-traceable).

    Row 0 drives the per-round delivery event (device ``m`` delivers an
    update this round iff ``block[0, m] < r_m`` for its static per-round
    completion probability), row 1 the staleness draw of the delivered
    update (compared against the device's precomputed truncated-geometric
    CDF thresholds, ``core.async_fl``). ``key`` is the trial's
    :func:`arrival_base_key`; ``t`` may be a traced scalar, so the engine
    folds the round index inside ``lax.scan``. Drawn in float32; both
    consumers widen to float64 (exact, the fault-block pattern) so they
    compare the identical value against the float64 rate/CDF tables.
    """
    return jax.random.uniform(jax.random.fold_in(key, t), (2, n),
                              dtype=jnp.float32)


_ARRIVAL_KEY_CACHE: dict = {}


def arrival_block_np(seed: int, trial: int, t: int, n: int) -> np.ndarray:
    """Oracle view of :func:`arrival_block`: (2, n) float64 numpy array.

    The base key is memoized per (seed, trial) (bounded LRU) so the
    per-round cost in the Python training loop is one fold_in + uniform
    dispatch (the fault-block pattern).
    """
    key = _cached_base_key(_ARRIVAL_KEY_CACHE, seed, trial, arrival_base_key)
    return np.asarray(arrival_block(key, t, n), dtype=np.float64)


def batch_base_key(seed: int, trial: int) -> jax.Array:
    """Per-trial base key for the mini-batch index stream (threefry)."""
    return stream_base_key(seed, trial, BATCH_TAG)


def batch_indices(key: jax.Array, t, m, n_data: int,
                  batch_size: int) -> jnp.ndarray:
    """(batch_size,) int32 without-replacement sample of range(n_data) for
    device ``m`` in round ``t`` (jit/scan-traceable).

    ``key`` is the trial's :func:`batch_base_key`; ``t`` and ``m`` may be
    traced scalars. The fold order (round, then device) matches
    :func:`batch_block`, so the block's row ``m`` equals this draw exactly.
    """
    km = jax.random.fold_in(jax.random.fold_in(key, t), m)
    return jax.random.choice(km, n_data, (batch_size,),
                             replace=False).astype(jnp.int32)


def batch_block(key: jax.Array, t, n_devices: int, n_data: int,
                batch_size: int) -> jnp.ndarray:
    """(n_devices, batch_size) int32 batch indices for round ``t``.

    Row ``m`` is :func:`batch_indices` for device ``m`` — the engine calls
    this inside ``lax.scan`` on a scan-carried key, so only one round's
    block is ever live (O(N*B) memory, mirroring the dither-block design).
    """
    kt = jax.random.fold_in(key, t)
    keys = jax.vmap(lambda m: jax.random.fold_in(kt, m))(
        jnp.arange(n_devices))
    return jax.vmap(
        lambda k: jax.random.choice(k, n_data, (batch_size,), replace=False)
    )(keys).astype(jnp.int32)


def batch_block_ragged(key: jax.Array, t, sizes: tuple,
                       batch_size: int) -> jnp.ndarray:
    """(len(sizes), batch_size) int32 batch indices for round ``t`` when
    device datasets have *unequal* sizes.

    Row ``m`` samples ``range(sizes[m])`` without replacement with the key
    ``fold_in(fold_in(key, t), m)`` — bit-identical to the per-device
    :func:`batch_indices` draw the NumPy oracle makes with each device's
    own ``n_data``, so the engine's padded-stack gather sees the exact
    oracle batches. ``sizes`` must be static (trace-time Python ints);
    every row needs ``batch_size <= sizes[m]``, and indices never reach
    the padding rows (``idx < sizes[m] <= n_max``).
    """
    kt = jax.random.fold_in(key, t)
    rows = [jax.random.choice(jax.random.fold_in(kt, m), int(n_m),
                              (batch_size,), replace=False)
            for m, n_m in enumerate(sizes)]
    return jnp.stack(rows).astype(jnp.int32)


def batch_block_mixed(key: jax.Array, t, sizes: tuple,
                      batch_size: int) -> jnp.ndarray:
    """(len(sizes), batch_size) int32 batch indices for round ``t`` in the
    *mixed* full/mini-batch regime (unequal sizes, batch_size >= some
    ``sizes[m]``).

    Mini-batch rows (``sizes[m] > batch_size``) are the exact
    :func:`batch_block_ragged` draw — ``fold_in(fold_in(key, t), m)``,
    bit-identical to the oracle's per-device :func:`batch_indices_np`.
    Full-batch rows (``sizes[m] <= batch_size``) consume *no* draw,
    mirroring the oracle's ``indices=None`` full-dataset path: the row is
    the static gather ``min(arange(batch_size), sizes[m]-1)`` — columns
    past ``sizes[m]`` duplicate the last sample and carry weight 0 in the
    engine's weighted-gradient reduction, so they never contribute.
    ``sizes`` must be static (trace-time Python ints).
    """
    kt = jax.random.fold_in(key, t)
    rows = []
    for m, n_m in enumerate(sizes):
        n_m = int(n_m)
        if n_m > batch_size:
            rows.append(jax.random.choice(jax.random.fold_in(kt, m), n_m,
                                          (batch_size,), replace=False))
        else:
            rows.append(jnp.minimum(jnp.arange(batch_size), n_m - 1))
    return jnp.stack(rows).astype(jnp.int32)


_BATCH_KEY_CACHE: dict = {}


def _batch_key_np(seed: int, trial: int) -> jax.Array:
    return _cached_base_key(_BATCH_KEY_CACHE, seed, trial, batch_base_key)


def batch_indices_np(seed: int, trial: int, t: int, m: int, n_data: int,
                     batch_size: int) -> np.ndarray:
    """Oracle view of :func:`batch_indices` (one device): (B,) int numpy.

    Used by the NumPy trainer when device datasets have unequal sizes and
    the stacked block path can't apply; keyed on this device's own
    ``n_data`` so the draw is still a pure counter function.
    """
    key = _batch_key_np(seed, trial)
    return np.asarray(batch_indices(key, t, m, n_data, batch_size))


def batch_block_np(seed: int, trial: int, t: int, n_devices: int,
                   n_data: int, batch_size: int) -> np.ndarray:
    """Oracle view of :func:`batch_block`: (N, B) int numpy array.

    The base key is memoized per (seed, trial) so the per-round cost in the
    Python training loop is one fold_in + vmapped choice dispatch.
    """
    key = _batch_key_np(seed, trial)
    return np.asarray(batch_block(key, t, n_devices, n_data, batch_size))


def trial_rng(seed: int, trial: int) -> np.random.Generator:
    """The sequential per-trial generator used by the NumPy trainer."""
    return np.random.default_rng((seed, trial, 17))


def replay_rounds(seed: int, trial: int, rounds: int,
                  draw_fn: Callable[[np.random.Generator], np.ndarray]
                  ) -> np.ndarray:
    """Replay ``rounds`` per-round draws of the oracle's trial generator.

    ``draw_fn(rng)`` must consume *exactly* what the scheme's
    ``Aggregator.round`` consumes from the trial rng in one round (its
    selection draws), in the same order, and return them as a flat float64
    row. Returns the (rounds, S) stack the engine feeds into its scan.
    """
    rng = trial_rng(seed, trial)
    rows = [np.asarray(draw_fn(rng), dtype=np.float64).ravel()
            for _ in range(rounds)]
    if not rows:
        return np.zeros((0, 1))
    return np.stack(rows)
