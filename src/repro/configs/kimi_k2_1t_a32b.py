"""kimi-k2-1t-a32b [moe] — 61L d_model=7168 64H (GQA kv=8) expert d_ff=2048
vocab=163840, MoE 384 experts top-8. Trillion-parameter paper-table config.
[arXiv:2501.kimi2]

Simplification noted in DESIGN.md: the released Kimi-K2 uses MLA attention
and one shared expert; we implement GQA (as assigned: "GQA kv=8") and
routed experts only.
"""
from ..models.common import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b", arch_type="moe", n_layers=61, d_model=7168,
    n_heads=64, n_kv_heads=8, d_ff=2048, vocab_size=163840,
    head_dim=112, n_experts=384, n_experts_per_tok=8,
    moe_capacity_factor=1.25, rope_theta=5e4,
    source="arXiv:2501.kimi2",
)
