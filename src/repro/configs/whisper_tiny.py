"""whisper-tiny [audio] — 4L encoder + 4L decoder, d_model=384 6H (kv=6)
d_ff=1536 vocab=51865, enc-dec with conv/mel frontend STUBBED: the runtime
feeds precomputed frame embeddings (B, 1500, 384). Decoder context is
capped at 448 target positions (the model's true max), so decode_32k runs
at 448 and long_500k is skipped (see DESIGN.md). [arXiv:2212.04356]"""
from ..models.common import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny", arch_type="audio", n_layers=4, d_model=384,
    n_heads=6, n_kv_heads=6, d_ff=1536, vocab_size=51865,
    head_dim=64, encoder_layers=4, encoder_positions=1500,
    max_target_positions=448,
    source="arXiv:2212.04356",
)
