"""internvl2-2b [vlm] — InternLM2-1.8B language backbone: 24L d_model=2048
16H (GQA kv=8) d_ff=8192 vocab=92553. InternViT vision encoder + projector
STUBBED: the runtime feeds 256 precomputed patch embeddings (B, 256, 2048)
prepended to the text tokens. [arXiv:2404.16821]"""
from ..models.common import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b", arch_type="vlm", n_layers=24, d_model=2048,
    n_heads=16, n_kv_heads=8, d_ff=8192, vocab_size=92553,
    head_dim=128, vision_prefix=256,
    source="arXiv:2404.16821",
)
