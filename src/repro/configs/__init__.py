"""Assigned-architecture registry: ``get_config(arch_id)``.

Every config cites its source (HF model card or arXiv) and reproduces the
exact dimensions assigned in the task brief.
"""
from __future__ import annotations

from ..models.common import ModelConfig

from .qwen3_8b import CONFIG as qwen3_8b
from .llama3_2_1b import CONFIG as llama3_2_1b
from .recurrentgemma_2b import CONFIG as recurrentgemma_2b
from .gemma3_4b import CONFIG as gemma3_4b
from .kimi_k2_1t_a32b import CONFIG as kimi_k2
from .falcon_mamba_7b import CONFIG as falcon_mamba_7b
from .tinyllama_1_1b import CONFIG as tinyllama_1_1b
from .qwen3_moe_30b_a3b import CONFIG as qwen3_moe
from .whisper_tiny import CONFIG as whisper_tiny
from .internvl2_2b import CONFIG as internvl2_2b

REGISTRY: dict[str, ModelConfig] = {
    "qwen3-8b": qwen3_8b,
    "llama3.2-1b": llama3_2_1b,
    "recurrentgemma-2b": recurrentgemma_2b,
    "gemma3-4b": gemma3_4b,
    "kimi-k2-1t-a32b": kimi_k2,
    "falcon-mamba-7b": falcon_mamba_7b,
    "tinyllama-1.1b": tinyllama_1_1b,
    "qwen3-moe-30b-a3b": qwen3_moe,
    "whisper-tiny": whisper_tiny,
    "internvl2-2b": internvl2_2b,
}

ARCH_IDS = tuple(REGISTRY)


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in REGISTRY:
        raise KeyError(f"unknown arch '{arch_id}'; known: {sorted(REGISTRY)}")
    return REGISTRY[arch_id]
