"""falcon-mamba-7b [ssm] — 64L d_model=4096 attention-free, vocab=65024,
ssm_state=16 (mamba-1 architecture, d_inner = 2*d_model, dt_rank = d/16).
[arXiv:2410.05355]"""
from ..models.common import ModelConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b", arch_type="ssm", n_layers=64, d_model=4096,
    n_heads=1, n_kv_heads=1, d_ff=0, vocab_size=65024,
    layer_pattern=("mamba",), ssm_state=16, ssm_conv=4, ssm_expand=2,
    source="arXiv:2410.05355",
)
