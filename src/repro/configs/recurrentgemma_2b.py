"""recurrentgemma-2b [hybrid] — 26L d_model=2560 10H (GQA kv=1) d_ff=7680
vocab=256000, RG-LRU + local attention at 1:2 (two recurrent blocks per
local-attention block, Griffin pattern). [arXiv:2402.19427]"""
from ..models.common import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b", arch_type="hybrid", n_layers=26, d_model=2560,
    n_heads=10, n_kv_heads=1, d_ff=7680, vocab_size=256000,
    head_dim=256, layer_pattern=("rglru", "rglru", "local"),
    window_size=2048, lru_width=2560, conv1d_width=4,
    source="arXiv:2402.19427",
)
