"""gemma3-4b [dense] — 34L d_model=2560 8H (GQA kv=4) d_ff=10240
vocab=262144, 5:1 local:global sliding-window pattern, 128k context.
[hf:google/gemma-3-1b-pt family]"""
from ..models.common import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-4b", arch_type="dense", n_layers=34, d_model=2560,
    n_heads=8, n_kv_heads=4, d_ff=10240, vocab_size=262144,
    head_dim=256, qk_norm=True,
    layer_pattern=("local", "local", "local", "local", "local", "global"),
    window_size=1024, rope_theta=1e6,
    source="hf:google/gemma-3-1b-pt",
)
