"""Measured block-rows autotuner for the Pallas kernel tier.

Every kernel wrapper used to hard-code ``BLOCK_ROWS = 512``. For toy
payloads (fig2's d=7,850 → 62 rows) the tile is clamped to the payload
anyway, but at payload scale (d=10^5–10^7 → thousands of rows) the tile
is a real knob: it trades grid-step overhead (small tiles) against
VMEM/working-set pressure (large tiles), and the right choice depends on
dtype width and on whether the kernel runs interpret-on-CPU or
Mosaic-on-TPU.

``choose_block_rows(kind, rows, dtype, bench=...)`` picks the tile:

  * ``rows`` below the legacy default → the deterministic power-of-two
    clamp the wrappers always used (``_pow2_fit``); nothing to measure,
    nothing changes for small payloads.
  * otherwise → time each candidate tile once on a small synthetic slab
    via the caller-supplied ``bench(block_rows) -> fn()`` factory and
    cache the winner under ``(kind, rows, dtype, backend)``.

The measurement is interpret-mode safe: ``bench`` closes over concrete
(non-traced) arrays, so the jitted kernel calls dispatch eagerly even
when the chooser runs while an outer ``jit`` is tracing (shapes/dtypes
are static there, which is all the cache key needs).

``REPRO_AUTOTUNE=0`` pins the legacy 512 everywhere measurement would
have run — a determinism escape hatch for debugging. ``measure_count``
counts actual measurement sweeps (the cache-determinism test hook).
"""
from __future__ import annotations

import os
import time

import jax

DEFAULT_BLOCK_ROWS = 512
# 8192 x 128 x f32 = 4 MB — about half a TPU core's VMEM, the practical
# tile ceiling; in interpret-on-CPU the per-grid-step cost is nearly
# size-independent, so the chooser measures its way to the big end.
CANDIDATES = (256, 512, 1024, 2048, 4096, 8192)
# Rows in the synthetic measurement slab: divisible by every candidate,
# small enough (8192 x 128 x 8B = 8 MB) that tuning stays cheap.
MEASURE_ROWS = 8192
_REPS = 2

_cache: dict = {}
# Bound on distinct (kind, rows, dtype, backend) winners kept live. Far
# above any real workload's shape diversity, but a sweep that walks many
# payload sizes can no longer grow the memo without bound; eviction is
# LRU-oldest-only so hot tiles survive (never a full clear).
_CACHE_MAX = 1024
measure_count = 0  # total measurement sweeps run (test hook)


def clear_cache() -> None:
    _cache.clear()


def _pow2_fit(rows: int) -> int:
    """Legacy clamp: smallest power of two >= rows, floored at 8."""
    br = 8
    while br < rows:
        br *= 2
    return br


def _enabled() -> bool:
    return os.environ.get("REPRO_AUTOTUNE", "1") != "0"


def _measure(bench, block_rows: int) -> float:
    fn = bench(block_rows)
    jax.block_until_ready(fn())  # warm-up / compile
    t0 = time.perf_counter()
    for _ in range(_REPS):
        jax.block_until_ready(fn())
    return (time.perf_counter() - t0) / _REPS


def choose_block_rows(kind: str, rows: int, dtype, bench=None) -> int:
    """Pick a block_rows tile for a kernel of the given kind.

    kind   -- kernel family ("quantize", "ota", "reduce", "pack",
              "unpack", ...); part of the cache key only.
    rows   -- total (LANES-wide) rows the kernel will process.
    dtype  -- element dtype of the payload operand.
    bench  -- callable ``bench(block_rows) -> fn`` where ``fn()`` runs
              the kernel once on a measurement slab and returns its
              output (the chooser block_until_ready's it). ``None``
              disables measurement (legacy default tile).
    """
    if rows < DEFAULT_BLOCK_ROWS:
        return _pow2_fit(rows)
    if bench is None or not _enabled():
        return DEFAULT_BLOCK_ROWS
    dtype = jax.dtypes.canonicalize_dtype(dtype)
    key = (kind, int(rows), str(dtype), jax.default_backend())
    hit = _cache.pop(key, None)
    if hit is not None:
        _cache[key] = hit          # refresh LRU recency
        return hit
    global measure_count
    measure_count += 1
    # never hand out a tile more than one pow2 above the payload's own row
    # count — the wrapper would pad the whole shortfall as dead work
    cap = _pow2_fit(rows)
    best, best_t = DEFAULT_BLOCK_ROWS, float("inf")
    for br in CANDIDATES:
        if br > cap:
            continue
        t = _measure(bench, br)
        if t < best_t:
            best, best_t = br, t
    if len(_cache) >= _CACHE_MAX:
        _cache.pop(next(iter(_cache)))
    _cache[key] = best
    return best
