"""Pallas TPU kernel: chunked first-order linear scan h_t = a_t h_{t-1} + b_t.

The recurrent hot-spot of the SSM/hybrid architectures (Mamba selective
scan, RG-LRU). GPU implementations (the Mamba CUDA kernel) fuse the scan
into registers per thread; the TPU-native shape is different (DESIGN.md §2):

  * grid = (feature_blocks, seq_chunks) with the SEQUENCE dimension as the
    fastest (sequential) grid axis — Pallas guarantees sequential execution
    order, so the carry lives in a VMEM scratch buffer across chunk steps;
  * inside a chunk, a Hillis–Steele log-depth scan over the (chunk, 128)
    block keeps everything in VREG-friendly (8,128) tiles instead of a
    length-`chunk` scalar loop.

Operands are pre-reshaped by the wrapper to (B*D/128 merged feature rows):
  a, b : (F, S, 128)   (F feature-blocks, S sequence, 128 lanes)
  h0   : (F, 1, 128)
Outputs: h_all (F, S, 128), h_last (F, 1, 128).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

CHUNK = 256
LANES = 128


def _kernel(a_ref, b_ref, h0_ref, o_ref, hlast_ref, carry):
    s = pl.program_id(1)

    @pl.when(s == 0)
    def _():
        carry[...] = h0_ref[0]

    a = a_ref[0].astype(jnp.float32)      # (CHUNK, 128)
    b = b_ref[0].astype(jnp.float32)
    # Hillis-Steele inclusive scan of the affine maps (a, b):
    # compose (a2,b2)∘(a1,b1) = (a1*a2, b1*a2 + b2)   [h -> a2(a1 h+b1)+b2]
    off = 1
    while off < CHUNK:
        a_prev = jnp.pad(a, ((off, 0), (0, 0)), constant_values=1.0)[:CHUNK]
        b_prev = jnp.pad(b, ((off, 0), (0, 0)))[:CHUNK]
        b = b_prev * a + b
        a = a_prev * a
        off *= 2
    h0 = carry[...]                        # (1, 128)
    h_all = a * h0 + b
    o_ref[0] = h_all.astype(o_ref.dtype)
    carry[...] = h_all[-1:]

    n_chunks = pl.num_programs(1)

    @pl.when(s == n_chunks - 1)
    def _():
        hlast_ref[0] = carry[...].astype(hlast_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def linear_scan_fsl(a: jnp.ndarray, b: jnp.ndarray, h0: jnp.ndarray,
                    interpret: bool = False):
    """a,b: (F,S,128) with S % CHUNK == 0; h0: (F,1,128)."""
    F, S, _ = a.shape
    grid = (F, S // CHUNK)
    out_shape = [jax.ShapeDtypeStruct(a.shape, a.dtype),
                 jax.ShapeDtypeStruct((F, 1, LANES), a.dtype)]
    h_all, h_last = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, CHUNK, LANES), lambda f, s: (f, s, 0)),
            pl.BlockSpec((1, CHUNK, LANES), lambda f, s: (f, s, 0)),
            pl.BlockSpec((1, 1, LANES), lambda f, s: (f, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, CHUNK, LANES), lambda f, s: (f, s, 0)),
            pl.BlockSpec((1, 1, LANES), lambda f, s: (f, 0, 0)),
        ],
        out_shape=out_shape,
        scratch_shapes=[pltpu.VMEM((1, LANES), jnp.float32)],
        interpret=interpret,
    )(a, b, h0)
    return h_all, h_last
