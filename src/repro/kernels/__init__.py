"""Pallas TPU kernels (+ pure-jnp oracles) for the framework's hot spots:

  dithered_quant — digital-FL gradient payload quantizer
  ota_combine    — fused OTA post-scale + noise epilogue
  linear_scan    — SSM/RG-LRU recurrence (chunked, VMEM carry)
"""
from . import ops, ref
