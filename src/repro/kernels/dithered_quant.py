"""Pallas TPU kernel: dithered stochastic uniform quantize-dequantize.

The digital-FL payload compressor (paper Sec. II-B). At LM scale the
gradient has 10^7–10^12 entries; quantization is a pure elementwise
streaming op, so the kernel is memory-bound — the win over the naive
composition is fusing (normalize, floor, compare, clip, affine) into one
HBM->VMEM pass instead of five intermediate arrays.

Layout: the caller flattens/pads the tensor to (R, 128) with R a multiple
of the block row count; grid walks row-blocks; the scalar pair
(m = ||g||_inf, levels = 2^r - 1) rides in SMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_ROWS = 512
LANES = 128


def _kernel(scal_ref, g_ref, u_ref, o_ref):
    m = scal_ref[0, 0]
    levels = scal_ref[0, 1]
    g = g_ref[...]
    u = u_ref[...]
    delta = 2.0 * m / levels
    safe = jnp.where(delta > 0, delta, 1.0)
    x = (g + m) / safe
    lo = jnp.floor(x)
    up = (u < (x - lo)).astype(g.dtype)
    q = jnp.clip(lo + up, 0.0, levels)
    out = -m + safe * q
    o_ref[...] = jnp.where(delta > 0, out, jnp.zeros_like(g))


@functools.partial(jax.jit, static_argnames=("interpret",))
def dithered_quantize_2d(g2d: jnp.ndarray, u2d: jnp.ndarray,
                         m: jnp.ndarray, levels: jnp.ndarray,
                         interpret: bool = False) -> jnp.ndarray:
    """g2d/u2d: (R, 128) with R % BLOCK_ROWS == 0; m/levels scalars."""
    R = g2d.shape[0]
    scal = jnp.stack([m.astype(g2d.dtype),
                      levels.astype(g2d.dtype)]).reshape(1, 2)
    grid = (R // BLOCK_ROWS,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 2), lambda i: (0, 0)),          # scalars
            pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(g2d.shape, g2d.dtype),
        interpret=interpret,
    )(scal, g2d, u2d)
