"""Pallas TPU kernel: dithered stochastic uniform quantize-dequantize.

The digital-FL payload compressor (paper Sec. II-B). At LM scale the
gradient has 10^7–10^12 entries; quantization is a pure elementwise
streaming op, so the kernel is memory-bound — the win over the naive
composition is fusing (normalize, floor, compare, clip, affine) into one
HBM->VMEM pass instead of five intermediate arrays.

Layout: the caller flattens/pads the tensor to (R, 128) with R a multiple
of the block row count; grid walks row-blocks; the scalar pair
(m = ||g||_inf, levels = 2^r - 1) rides in SMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_ROWS = 512
LANES = 128


def _kernel(scal_ref, g_ref, u_ref, o_ref):
    m = scal_ref[0, 0]
    levels = scal_ref[0, 1]
    g = g_ref[...]
    u = u_ref[...]
    # degenerate scalars quantize to zero: m == 0 (zero tensor) and
    # levels <= 0 (device granted no bits by the selection/bit allocation)
    valid = (levels > 0) & (m > 0)
    safe = jnp.where(valid, 2.0 * m / jnp.where(levels > 0, levels, 1.0), 1.0)
    x = (g + m) / safe
    lo = jnp.floor(x)
    up = (u < (x - lo)).astype(g.dtype)
    q = jnp.clip(lo + up, 0.0, levels)
    out = -m + safe * q
    o_ref[...] = jnp.where(valid, out, jnp.zeros_like(g))


@functools.partial(jax.jit, static_argnames=("interpret", "block_rows"))
def dithered_quantize_2d(g2d: jnp.ndarray, u2d: jnp.ndarray,
                         m: jnp.ndarray, levels: jnp.ndarray,
                         interpret: bool = False,
                         block_rows: int = BLOCK_ROWS) -> jnp.ndarray:
    """g2d/u2d: (R, 128) with R % block_rows == 0; m/levels scalars."""
    R = g2d.shape[0]
    scal = jnp.stack([m.astype(g2d.dtype),
                      levels.astype(g2d.dtype)]).reshape(1, 2)
    grid = (R // block_rows,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 2), lambda i: (0, 0)),          # scalars
            pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(g2d.shape, g2d.dtype),
        interpret=interpret,
    )(scal, g2d, u2d)


@functools.partial(jax.jit, static_argnames=("interpret", "block_rows"))
def dithered_quantize_rows_2d(g2d: jnp.ndarray, u2d: jnp.ndarray,
                              scal: jnp.ndarray,
                              interpret: bool = False,
                              block_rows: int = BLOCK_ROWS) -> jnp.ndarray:
    """Batched variant: N independent tensors quantized in one launch.

    g2d/u2d: (N*R_dev, LANES) — device i owns rows [i*R_dev, (i+1)*R_dev);
    scal: (N, 2) per-device (m_i = ||g_i||_inf, levels_i = 2^{r_i} - 1).
    Grid walks (device, row-block); each block reads its device's scalar
    row. This is the FL engine's digital uplink: all N devices' payloads
    compress in a single fused pass instead of N kernel launches per round.
    """
    NR = g2d.shape[0]
    n_dev = scal.shape[0]
    r_dev = NR // n_dev
    blocks_per_dev = r_dev // block_rows
    return pl.pallas_call(
        _kernel,
        grid=(n_dev, blocks_per_dev),
        in_specs=[
            pl.BlockSpec((1, 2), lambda i, j: (i, 0)),       # device scalars
            pl.BlockSpec((block_rows, LANES),
                         lambda i, j, b=blocks_per_dev: (i * b + j, 0)),
            pl.BlockSpec((block_rows, LANES),
                         lambda i, j, b=blocks_per_dev: (i * b + j, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, LANES),
                               lambda i, j, b=blocks_per_dev: (i * b + j, 0)),
        out_shape=jax.ShapeDtypeStruct(g2d.shape, g2d.dtype),
        interpret=interpret,
    )(scal, g2d, u2d)
