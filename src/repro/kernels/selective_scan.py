"""Pallas TPU kernel: fused Mamba-1 selective scan.

The §Perf analysis (EXPERIMENTS.md, falcon-mamba train_4k) showed the
memory roofline term of the jnp selective scan is dominated by the
(B, chunk, d_inner, n_state) transition transients that spill to HBM —
XLA's loop fusion cannot keep them resident because the chunk working set
(~1 GB) exceeds VMEM. The kernel restructures the computation so HBM
traffic is exactly inputs + outputs:

    reads : dt (S,128), x (S,128), B (S,n), C (S,n), A (128,n)
    writes: y (S,128), h_last (n,128)

i.e. per (batch, feature-block) grid cell nothing sized (chunk, 128, n)
ever leaves VMEM. The state dimension n (16 for falcon-mamba) is a static
python loop; each n-slice runs a Hillis-Steele log-depth scan on the
(CHUNK, 128) tile with the carry h (n,128) in VMEM scratch across
sequence chunks (sequential grid axis).

Layouts (wrapper in ops.py):
    dt, x : (B, F, S, 128)  F = d_inner/128 feature blocks
    Bm,Cm : (B, S, n)
    A     : (F, 128, n)
    h0    : (B, F, n, 128)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

CHUNK = 128
LANES = 128


def _kernel(n_state: int, dt_ref, x_ref, b_ref, c_ref, a_ref, h0_ref,
            y_ref, hlast_ref, h_scratch):
    s = pl.program_id(2)

    @pl.when(s == 0)
    def _():
        h_scratch[...] = h0_ref[0, 0].astype(jnp.float32)

    dt = dt_ref[0, 0].astype(jnp.float32)          # (CHUNK, 128)
    x = x_ref[0, 0].astype(jnp.float32)            # (CHUNK, 128)
    bm = b_ref[0].astype(jnp.float32)              # (CHUNK, n)
    cm = c_ref[0].astype(jnp.float32)              # (CHUNK, n)
    a_w = a_ref[0].astype(jnp.float32)             # (128, n)
    h = h_scratch[...]                             # (n, 128)
    y = jnp.zeros_like(dt)
    h_new = []
    for j in range(n_state):                       # static state loop
        a = jnp.exp(dt * a_w[:, j][None, :])       # (CHUNK, 128)
        b = dt * x * bm[:, j][:, None]
        off = 1
        while off < CHUNK:                         # Hillis-Steele scan
            a_prev = jnp.pad(a, ((off, 0), (0, 0)),
                             constant_values=1.0)[:CHUNK]
            b_prev = jnp.pad(b, ((off, 0), (0, 0)))[:CHUNK]
            b = b_prev * a + b
            a = a_prev * a
            off *= 2
        h_j = a * h[j][None, :] + b                # (CHUNK, 128)
        y = y + h_j * cm[:, j][:, None]
        h_new.append(h_j[-1])
    h_scratch[...] = jnp.stack(h_new, axis=0)
    y_ref[0, 0] = y.astype(y_ref.dtype)

    @pl.when(s == pl.num_programs(2) - 1)
    def _():
        hlast_ref[0, 0] = h_scratch[...].astype(hlast_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def selective_scan_bfsn(dt, x, bm, cm, a_w, h0, interpret: bool = False):
    """dt/x: (B,F,S,128); bm/cm: (B,S,n); a_w: (F,128,n); h0: (B,F,n,128).

    Returns (y (B,F,S,128), h_last (B,F,n,128)). S % CHUNK == 0.
    """
    B, F, S, _ = dt.shape
    n = bm.shape[-1]
    grid = (B, F, S // CHUNK)
    out_shape = [jax.ShapeDtypeStruct(dt.shape, dt.dtype),
                 jax.ShapeDtypeStruct((B, F, n, LANES), jnp.float32)]
    kern = functools.partial(_kernel, n)
    y, h_last = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, CHUNK, LANES), lambda b, f, s: (b, f, s, 0)),
            pl.BlockSpec((1, 1, CHUNK, LANES), lambda b, f, s: (b, f, s, 0)),
            pl.BlockSpec((1, CHUNK, n), lambda b, f, s: (b, s, 0)),
            pl.BlockSpec((1, CHUNK, n), lambda b, f, s: (b, s, 0)),
            pl.BlockSpec((1, LANES, n), lambda b, f, s: (f, 0, 0)),
            pl.BlockSpec((1, 1, n, LANES), lambda b, f, s: (b, f, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, CHUNK, LANES), lambda b, f, s: (b, f, s, 0)),
            pl.BlockSpec((1, 1, n, LANES), lambda b, f, s: (b, f, 0, 0)),
        ],
        out_shape=out_shape,
        scratch_shapes=[pltpu.VMEM((n, LANES), jnp.float32)],
        interpret=interpret,
    )(dt, x, bm, cm, a_w, h0)
    return y, h_last
