"""jit'd wrappers around the Pallas kernels (padding, reshapes, fallbacks).

``use_kernel=False`` routes to the pure-jnp oracle (kernels/ref.py); on CPU
the kernels execute in Pallas interpret mode, on TPU they compile to
Mosaic. All wrappers accept arbitrary-shaped operands.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from . import autotune, ref
from .dithered_quant import (dithered_quantize_2d, dithered_quantize_rows_2d,
                             BLOCK_ROWS, LANES)
from .ota_combine import ota_combine_2d
from .linear_scan import linear_scan_fsl, CHUNK
from .row_reduce import row_maxabs_sumsq_2d
from .payload import (quantize_pack_rows_2d, unpack_dequant_rows_2d,
                      packed_weighted_sum_2d, CODE_BITS_CHOICES)

# Below this payload dimension the fused pack path is not worth the extra
# kernel: the two-step quantize + matvec fits one or two tiles anyway and
# stays the bit-compared parity path for the paper-scale figures.
FUSED_MIN_DIM = 1 << 17


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def _fit_block_rows(n: int) -> int:
    """Row-tile for an n-element payload: full BLOCK_ROWS for large tensors,
    the next power of two >= the row count for small ones (interpret-mode
    cost scales with the padded block, so a d=7850 gradient should not pay
    for a 512x128 tile)."""
    rows = -(-n // LANES)
    if rows >= BLOCK_ROWS:
        return BLOCK_ROWS
    return autotune._pow2_fit(rows)


def _autotune_bench(kind: str, dtype):
    """bench(block_rows) factory for the measured tile chooser: each kernel
    family timed on a fixed (MEASURE_ROWS, LANES) zero slab."""
    dtype = jax.dtypes.canonicalize_dtype(dtype)
    rows = autotune.MEASURE_ROWS
    interp = _on_cpu()

    def bench(block_rows):
        z = jnp.zeros((rows, LANES), dtype)
        one = jnp.ones((), dtype)
        if kind == "quantize":
            scal = jnp.ones((1, 2), dtype)
            return lambda: dithered_quantize_rows_2d(
                z, z, scal, interpret=interp, block_rows=block_rows)
        if kind == "ota":
            return lambda: ota_combine_2d(
                z, z, one, interpret=interp, block_rows=block_rows)
        if kind == "reduce":
            return lambda: row_maxabs_sumsq_2d(
                z, n_dev=1, interpret=interp, block_rows=block_rows)
        if kind == "pack":
            scal = jnp.ones((1, 2), dtype)
            return lambda: quantize_pack_rows_2d(
                z, z, scal, code_bits=8, interpret=interp,
                block_rows=block_rows)
        if kind == "unpack":
            p = jnp.zeros((rows // 4, LANES), jnp.uint32)
            scal = jnp.ones((1, 3), dtype)
            return lambda: packed_weighted_sum_2d(
                p, scal, code_bits=8, n_dev=1, interpret=interp,
                block_rows=block_rows)
        raise ValueError(f"unknown autotune kind: {kind}")

    return bench


def _tuned_block_rows(kind: str, n: int, dtype) -> int:
    """Measured replacement for the fixed BLOCK_ROWS: small payloads keep
    the deterministic power-of-two clamp, large ones get the cached
    autotuned tile for (kind, rows, dtype, backend)."""
    rows = -(-n // LANES)
    return autotune.choose_block_rows(kind, rows, dtype,
                                      bench=_autotune_bench(kind, dtype))


def _to_blocks(x: jnp.ndarray, block_rows: int = BLOCK_ROWS):
    """Flatten + zero-pad to (R, LANES) with R % block_rows == 0."""
    n = x.size
    per = block_rows * LANES
    n_pad = (-n) % per
    flat = jnp.pad(x.reshape(-1), (0, n_pad))
    return flat.reshape(-1, LANES), n


def _from_blocks(y2d: jnp.ndarray, n: int, shape, dtype):
    return y2d.reshape(-1)[:n].reshape(shape).astype(dtype)


def dithered_quantize(g: jnp.ndarray, levels: jnp.ndarray, key: jax.Array,
                      *, use_kernel: bool = True) -> jnp.ndarray:
    """Dithered stochastic uniform quantize-dequantize of a full tensor."""
    m = jnp.max(jnp.abs(g)).astype(g.dtype)
    dither = jax.random.uniform(key, g.shape, dtype=jnp.float32).astype(g.dtype)
    levels = jnp.asarray(levels, g.dtype)
    if not use_kernel:
        return ref.dithered_quantize_ref(g, m, levels, dither)
    br = _tuned_block_rows("quantize", g.size, g.dtype)
    g2d, n = _to_blocks(g, br)
    u2d, _ = _to_blocks(dither, br)
    out = dithered_quantize_2d(g2d, u2d, m, levels, interpret=_on_cpu(),
                               block_rows=br)
    return _from_blocks(out, n, g.shape, g.dtype)


def dithered_quantize_with_dither(g: jnp.ndarray, levels: jnp.ndarray,
                                  dither: jnp.ndarray,
                                  *, use_kernel: bool = True) -> jnp.ndarray:
    """Quantize-dequantize with an explicit dither operand (g's shape).

    Used by the FL engine, which replays the NumPy trainer's dither stream
    for bit-parity instead of drawing from a jax PRNG key.
    """
    m = jnp.max(jnp.abs(g)).astype(g.dtype)
    levels = jnp.asarray(levels, g.dtype)
    dither = dither.astype(g.dtype)
    if not use_kernel:
        return ref.dithered_quantize_ref(g, m, levels, dither)
    br = _tuned_block_rows("quantize", g.size, g.dtype)
    g2d, n = _to_blocks(g, br)
    u2d, _ = _to_blocks(dither, br)
    out = dithered_quantize_2d(g2d, u2d, m, levels, interpret=_on_cpu(),
                               block_rows=br)
    return _from_blocks(out, n, g.shape, g.dtype)


def dithered_quantize_batch(gs: jnp.ndarray, levels: jnp.ndarray,
                            dither: jnp.ndarray,
                            *, use_kernel: bool = True) -> jnp.ndarray:
    """Quantize N independent tensors (rows of ``gs``) in one fused launch.

    gs/dither: (N, d); levels: (N,) per-device 2^{r_m} - 1. Each row is
    normalized by its own ||g_m||_inf — the digital-FL uplink where every
    device compresses with its offline-designed bit-width (Sec. II-B).
    """
    m = jnp.max(jnp.abs(gs), axis=1).astype(gs.dtype)
    levels = jnp.asarray(levels, gs.dtype)
    dither = dither.astype(gs.dtype)
    if not use_kernel:
        return jax.vmap(ref.dithered_quantize_ref)(gs, m, levels, dither)
    n_dev, d = gs.shape
    br = _tuned_block_rows("quantize", d, gs.dtype)
    per = br * LANES
    d_pad = (-d) % per
    pad = lambda x: jnp.pad(x, ((0, 0), (0, d_pad))).reshape(-1, LANES)
    scal = jnp.stack([m, levels], axis=1)
    out = dithered_quantize_rows_2d(pad(gs), pad(dither), scal,
                                    interpret=_on_cpu(), block_rows=br)
    return out.reshape(n_dev, d + d_pad)[:, :d]


def code_bits_for(r_max) -> int | None:
    """Smallest packable code width covering r_max-bit quantizers.

    Codes are integers in [0, 2^r - 1]; supported packed widths are
    CODE_BITS_CHOICES = (4, 8, 16). 16 is the ceiling on purpose: wider
    codes would not survive the f32 round-trip exactly (f32 represents
    integers only up to 2^24) and r > 16 bits/entry has no compression
    story anyway. Returns None when no fused path applies.
    """
    if r_max is None:
        return None
    r = int(r_max)
    for cb in CODE_BITS_CHOICES:
        if r <= cb:
            return cb
    return None


@dataclasses.dataclass
class PackedGrads:
    """Bit-packed device payload buffer (the digital uplink wire format).

    words holds each device's quantizer codes at ``code_bits`` per entry,
    K = 32/code_bits codes per uint32 — code_bits/32 the bytes of the
    float block it replaces. scal: (N, 2) per-device (||g||_inf, levels).
    """
    words: jnp.ndarray        # (N * R_dev / K, LANES) uint32
    scal: jnp.ndarray         # (N, 2)
    code_bits: int
    n_dev: int
    d: int
    block_rows: int


def quantize_pack(gs: jnp.ndarray, levels: jnp.ndarray, dither: jnp.ndarray,
                  *, code_bits: int) -> PackedGrads:
    """Fused dither -> quantize -> bit-pack of N device gradients.

    gs/dither: (N, d); levels: (N,) per-device 2^{r_m} - 1 with
    r_m <= code_bits. One Pallas pass per device block; the dequantized
    float tensor is never formed.
    """
    n_dev, d = gs.shape
    m = jnp.max(jnp.abs(gs), axis=1).astype(gs.dtype)
    levels = jnp.asarray(levels, gs.dtype)
    dither = dither.astype(gs.dtype)
    br = _tuned_block_rows("pack", d, gs.dtype)
    per = br * LANES
    d_pad = (-d) % per
    pad = lambda x: jnp.pad(x, ((0, 0), (0, d_pad))).reshape(-1, LANES)
    scal = jnp.stack([m, levels], axis=1)
    words = quantize_pack_rows_2d(pad(gs), pad(dither), scal,
                                  code_bits=code_bits,
                                  interpret=_on_cpu(), block_rows=br)
    return PackedGrads(words, scal, code_bits, n_dev, d, br)


def unpack_dequant(pk: PackedGrads) -> jnp.ndarray:
    """Decode a packed payload buffer back to (N, d) dequantized floats.

    The materializing decoder — bit-exact inverse of the two-step
    ``dithered_quantize_batch`` output, and the O(N*d) baseline the fused
    ``packed_weighted_sum`` is benchmarked against.
    """
    out = unpack_dequant_rows_2d(pk.words, pk.scal, code_bits=pk.code_bits,
                                 n_dev=pk.n_dev, interpret=_on_cpu(),
                                 block_rows=pk.block_rows)
    return out.reshape(pk.n_dev, -1)[:, :pk.d]


def _dev_block(n_dev: int) -> int:
    """Devices per grid step for the fused accumulate. On CPU/interpret
    the per-grid-step overhead dominates (every step copies the operand
    buffers), so group as many whole payloads per step as divide N; on
    TPU a multi-payload block would blow VMEM, so keep the tiled launch."""
    if not _on_cpu():
        return 1
    for db in (16, 8, 4, 2):
        if n_dev % db == 0:
            return db
    return 1


def packed_weighted_sum(pk: PackedGrads, weights: jnp.ndarray) -> jnp.ndarray:
    """sum_i w_i * dequant(payload_i) with an O(d) accumulator.

    Unpacks, dequantizes and accumulates per block with the device axis
    innermost — device-index order, the NumPy oracle's (and
    ``ref.quantized_weighted_sum_ref``'s) sequential association, agreeing
    to the last ulp (FMA contraction) — without materializing the (N, d)
    dequantized tensor.
    """
    w = jnp.asarray(weights, pk.scal.dtype).reshape(-1, 1)
    scal3 = jnp.concatenate([pk.scal, w], axis=1)
    out = packed_weighted_sum_2d(pk.words, scal3, code_bits=pk.code_bits,
                                 n_dev=pk.n_dev, interpret=_on_cpu(),
                                 block_rows=pk.block_rows,
                                 dev_block=_dev_block(pk.n_dev))
    return out.reshape(-1)[:pk.d]


def quantized_weighted_sum(gs: jnp.ndarray, levels: jnp.ndarray,
                           dither: jnp.ndarray, weights: jnp.ndarray,
                           *, r_max=None, use_kernel: bool = True,
                           fused="auto") -> jnp.ndarray:
    """The digital aggregation hot path: sum_i w_i * quantize(g_i).

    Dispatches between the legacy two-step path (quantize-dequantize the
    (N, d) block, then a weighted matvec — the bit-compared parity path
    for paper-scale payloads) and the fused pack path (quantize straight
    into a uint32 code buffer, then unpack-dequant-accumulate with an
    O(d) accumulator — the payload-scale path).

    ``r_max``: static upper bound on any device's bit-width this round
    (each scheme knows its own); required for the fused path since the
    packed code width is static. ``fused="auto"`` fuses only when a
    packable r_max is known and d >= FUSED_MIN_DIM; pass True/False to
    force. ``use_kernel=False`` with fused=True runs the sequential-order
    jnp reference (same accumulation order as the fused kernel).
    """
    cb = code_bits_for(r_max)
    d = gs.shape[1]
    if fused == "auto":
        fused = use_kernel and cb is not None and d >= FUSED_MIN_DIM
    if not fused:
        gq = dithered_quantize_batch(gs, levels, dither,
                                     use_kernel=use_kernel)
        return jnp.asarray(weights, gs.dtype) @ gq
    if not use_kernel:
        m = jnp.max(jnp.abs(gs), axis=1).astype(gs.dtype)
        return ref.quantized_weighted_sum_ref(
            gs, m, jnp.asarray(levels, gs.dtype), dither.astype(gs.dtype),
            jnp.asarray(weights, gs.dtype))
    if cb is None:
        raise ValueError(
            f"fused quantized_weighted_sum needs a static r_max <= "
            f"{max(CODE_BITS_CHOICES)} (got r_max={r_max})")
    pk = quantize_pack(gs, levels, dither, code_bits=cb)
    return packed_weighted_sum(pk, weights)


def row_maxabs_sumsq(gs: jnp.ndarray, *, use_kernel: bool = True,
                     acc_dtype=None):
    """Per-device gradient statistics in one fused pass.

    gs: (N, d). Returns (maxabs (N,), sumsq (N,)): ``||g_m||_inf`` (the
    quantizer scale / quantization-MSE ingredient d*maxabs^2/(2^r-1)^2)
    and ``sum g_m^2`` (norm-based scheduling scores), computed by the
    Pallas row-reduction kernel (interpret on CPU, Mosaic on TPU).
    ``acc_dtype`` widens the accumulation/output above the payload dtype
    (bf16 payloads, f32 statistics); default gs.dtype.
    """
    if not use_kernel:
        ga = gs if acc_dtype is None else gs.astype(acc_dtype)
        return jnp.max(jnp.abs(ga), axis=1), jnp.sum(ga * ga, axis=1)
    n_dev, d = gs.shape
    br = _tuned_block_rows("reduce", d, gs.dtype)
    per = br * LANES
    d_pad = (-d) % per
    g2d = jnp.pad(gs, ((0, 0), (0, d_pad))).reshape(-1, LANES)
    out = row_maxabs_sumsq_2d(g2d, n_dev=n_dev, interpret=_on_cpu(),
                              block_rows=br, acc_dtype=acc_dtype)
    return out[:, 0], out[:, 1]


def ota_combine_with_noise(g: jnp.ndarray, alpha: jnp.ndarray,
                           noise: jnp.ndarray,
                           *, use_kernel: bool = True,
                           acc_dtype=None) -> jnp.ndarray:
    """ghat = (g + noise)/alpha with an explicit AWGN operand (eq. (6)).

    ``alpha`` may be a traced per-round scalar (e.g. Vanilla OTA's n*gamma_t).
    The kernel consumes pre-scaled noise, so this computes
    g*inv_alpha + noise*inv_alpha (1-ulp from the reference (g+z)/alpha).
    ``acc_dtype`` sets a wider accumulate/output dtype than the payload
    (bf16 gradient payload, f32 combine); default g.dtype.
    """
    out_dt = g.dtype if acc_dtype is None else jnp.dtype(acc_dtype)
    inv_alpha = (1.0 / jnp.asarray(alpha)).astype(out_dt)
    z = noise.astype(out_dt) * inv_alpha
    if not use_kernel:
        return ref.ota_combine_ref(g.astype(out_dt), inv_alpha, z)
    br = _tuned_block_rows("ota", g.size, g.dtype)
    g2d, n = _to_blocks(g, br)
    z2d, _ = _to_blocks(z, br)
    out = ota_combine_2d(g2d, z2d, inv_alpha, interpret=_on_cpu(),
                         block_rows=br, acc_dtype=acc_dtype)
    return _from_blocks(out, n, g.shape, out_dt)


def ota_combine(g: jnp.ndarray, alpha: jnp.ndarray, noise_scale: jnp.ndarray,
                key: jax.Array, *, use_kernel: bool = True) -> jnp.ndarray:
    """ghat = g/alpha + noise_scale * N(0,1) (noise_scale already /alpha)."""
    inv_alpha = (1.0 / alpha).astype(g.dtype)
    z = (noise_scale.astype(jnp.float32)
         * jax.random.normal(key, g.shape, jnp.float32)).astype(g.dtype)
    if not use_kernel:
        return ref.ota_combine_ref(g, inv_alpha, z)
    br = _tuned_block_rows("ota", g.size, g.dtype)
    g2d, n = _to_blocks(g, br)
    z2d, _ = _to_blocks(z, br)
    out = ota_combine_2d(g2d, z2d, inv_alpha, interpret=_on_cpu(),
                         block_rows=br)
    return _from_blocks(out, n, g.shape, g.dtype)


def selective_scan(dt, x, bm, cm, a_w, h0, *, use_kernel: bool = True):
    """Fused Mamba-1 selective scan. dt/x: (B,S,D); bm/cm: (B,S,n);
    a_w: (D,n); h0: (B,D,n). Returns (y (B,S,D), h_last (B,D,n))."""
    if not use_kernel:
        return ref.selective_scan_ref(dt, x, bm, cm, a_w, h0)
    from .selective_scan import selective_scan_bfsn, CHUNK as SCHUNK
    B, S, D = dt.shape
    n = bm.shape[-1]
    s_pad = (-S) % SCHUNK
    d_pad = (-D) % LANES
    dt_p = jnp.pad(dt, ((0, 0), (0, s_pad), (0, d_pad)))
    x_p = jnp.pad(x, ((0, 0), (0, s_pad), (0, d_pad)))
    bm_p = jnp.pad(bm, ((0, 0), (0, s_pad), (0, 0)))
    cm_p = jnp.pad(cm, ((0, 0), (0, s_pad), (0, 0)))
    a_p = jnp.pad(a_w, ((0, d_pad), (0, 0)))
    h0_p = jnp.pad(h0, ((0, 0), (0, d_pad), (0, 0)))
    Sp, Dp = S + s_pad, D + d_pad
    F = Dp // LANES
    to_bfs = lambda t: t.reshape(B, Sp, F, LANES).transpose(0, 2, 1, 3)
    a_f = a_p.reshape(F, LANES, n)
    h0_f = h0_p.reshape(B, F, LANES, n).transpose(0, 1, 3, 2)
    y, h_last = selective_scan_bfsn(to_bfs(dt_p), to_bfs(x_p), bm_p, cm_p,
                                    a_f, h0_f, interpret=_on_cpu())
    y = y.transpose(0, 2, 1, 3).reshape(B, Sp, Dp)[:, :S, :D]
    h_last = h_last.transpose(0, 1, 3, 2).reshape(B, Dp, n)[:, :D]
    return y, h_last


def linear_scan(a: jnp.ndarray, b: jnp.ndarray, h0: jnp.ndarray,
                *, use_kernel: bool = True):
    """h_t = a_t h_{t-1} + b_t over axis 1. a,b: (B,S,D); h0: (B,D).

    Returns (h_all, h_last). Kernel path pads S to a CHUNK multiple and
    D to a LANES multiple (pad a=1, b=0 so padding is inert).
    """
    if not use_kernel:
        return ref.linear_scan_ref(a, b, h0)
    B, S, D = a.shape
    s_pad = (-S) % CHUNK
    d_pad = (-D) % LANES
    a_p = jnp.pad(a, ((0, 0), (0, s_pad), (0, d_pad)), constant_values=1.0)
    b_p = jnp.pad(b, ((0, 0), (0, s_pad), (0, d_pad)))
    h0_p = jnp.pad(h0, ((0, 0), (0, d_pad)))
    Sp, Dp = S + s_pad, D + d_pad
    # (B, Sp, Dp) -> (B*Dp/LANES, Sp, LANES): feature-major blocks
    a_f = a_p.transpose(0, 2, 1).reshape(B * Dp // LANES, LANES, Sp)
    a_f = a_f.transpose(0, 2, 1)
    b_f = b_p.transpose(0, 2, 1).reshape(B * Dp // LANES, LANES, Sp)
    b_f = b_f.transpose(0, 2, 1)
    h0_f = h0_p.reshape(B * Dp // LANES, 1, LANES)
    h_all, h_last = linear_scan_fsl(a_f, b_f, h0_f, interpret=_on_cpu())
    h_all = h_all.transpose(0, 2, 1).reshape(B, Dp, Sp).transpose(0, 2, 1)
    return h_all[:, :S, :D], h_last.reshape(B, Dp)[:, :D]
