"""jit'd wrappers around the Pallas kernels (padding, reshapes, fallbacks).

``use_kernel=False`` routes to the pure-jnp oracle (kernels/ref.py); on CPU
the kernels execute in Pallas interpret mode, on TPU they compile to
Mosaic. All wrappers accept arbitrary-shaped operands.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import ref
from .dithered_quant import (dithered_quantize_2d, dithered_quantize_rows_2d,
                             BLOCK_ROWS, LANES)
from .ota_combine import ota_combine_2d
from .linear_scan import linear_scan_fsl, CHUNK
from .row_reduce import row_maxabs_sumsq_2d


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def _fit_block_rows(n: int) -> int:
    """Row-tile for an n-element payload: full BLOCK_ROWS for large tensors,
    the next power of two >= the row count for small ones (interpret-mode
    cost scales with the padded block, so a d=7850 gradient should not pay
    for a 512x128 tile)."""
    rows = -(-n // LANES)
    if rows >= BLOCK_ROWS:
        return BLOCK_ROWS
    br = 8
    while br < rows:
        br *= 2
    return br


def _to_blocks(x: jnp.ndarray, block_rows: int = BLOCK_ROWS):
    """Flatten + zero-pad to (R, LANES) with R % block_rows == 0."""
    n = x.size
    per = block_rows * LANES
    n_pad = (-n) % per
    flat = jnp.pad(x.reshape(-1), (0, n_pad))
    return flat.reshape(-1, LANES), n


def _from_blocks(y2d: jnp.ndarray, n: int, shape, dtype):
    return y2d.reshape(-1)[:n].reshape(shape).astype(dtype)


def dithered_quantize(g: jnp.ndarray, levels: jnp.ndarray, key: jax.Array,
                      *, use_kernel: bool = True) -> jnp.ndarray:
    """Dithered stochastic uniform quantize-dequantize of a full tensor."""
    m = jnp.max(jnp.abs(g)).astype(g.dtype)
    dither = jax.random.uniform(key, g.shape, dtype=jnp.float32).astype(g.dtype)
    levels = jnp.asarray(levels, g.dtype)
    if not use_kernel:
        return ref.dithered_quantize_ref(g, m, levels, dither)
    br = _fit_block_rows(g.size)
    g2d, n = _to_blocks(g, br)
    u2d, _ = _to_blocks(dither, br)
    out = dithered_quantize_2d(g2d, u2d, m, levels, interpret=_on_cpu(),
                               block_rows=br)
    return _from_blocks(out, n, g.shape, g.dtype)


def dithered_quantize_with_dither(g: jnp.ndarray, levels: jnp.ndarray,
                                  dither: jnp.ndarray,
                                  *, use_kernel: bool = True) -> jnp.ndarray:
    """Quantize-dequantize with an explicit dither operand (g's shape).

    Used by the FL engine, which replays the NumPy trainer's dither stream
    for bit-parity instead of drawing from a jax PRNG key.
    """
    m = jnp.max(jnp.abs(g)).astype(g.dtype)
    levels = jnp.asarray(levels, g.dtype)
    dither = dither.astype(g.dtype)
    if not use_kernel:
        return ref.dithered_quantize_ref(g, m, levels, dither)
    br = _fit_block_rows(g.size)
    g2d, n = _to_blocks(g, br)
    u2d, _ = _to_blocks(dither, br)
    out = dithered_quantize_2d(g2d, u2d, m, levels, interpret=_on_cpu(),
                               block_rows=br)
    return _from_blocks(out, n, g.shape, g.dtype)


def dithered_quantize_batch(gs: jnp.ndarray, levels: jnp.ndarray,
                            dither: jnp.ndarray,
                            *, use_kernel: bool = True) -> jnp.ndarray:
    """Quantize N independent tensors (rows of ``gs``) in one fused launch.

    gs/dither: (N, d); levels: (N,) per-device 2^{r_m} - 1. Each row is
    normalized by its own ||g_m||_inf — the digital-FL uplink where every
    device compresses with its offline-designed bit-width (Sec. II-B).
    """
    m = jnp.max(jnp.abs(gs), axis=1).astype(gs.dtype)
    levels = jnp.asarray(levels, gs.dtype)
    dither = dither.astype(gs.dtype)
    if not use_kernel:
        return jax.vmap(ref.dithered_quantize_ref)(gs, m, levels, dither)
    n_dev, d = gs.shape
    br = _fit_block_rows(d)
    per = br * LANES
    d_pad = (-d) % per
    pad = lambda x: jnp.pad(x, ((0, 0), (0, d_pad))).reshape(-1, LANES)
    scal = jnp.stack([m, levels], axis=1)
    out = dithered_quantize_rows_2d(pad(gs), pad(dither), scal,
                                    interpret=_on_cpu(), block_rows=br)
    return out.reshape(n_dev, d + d_pad)[:, :d]


def row_maxabs_sumsq(gs: jnp.ndarray, *, use_kernel: bool = True):
    """Per-device gradient statistics in one fused pass.

    gs: (N, d). Returns (maxabs (N,), sumsq (N,)): ``||g_m||_inf`` (the
    quantizer scale / quantization-MSE ingredient d*maxabs^2/(2^r-1)^2)
    and ``sum g_m^2`` (norm-based scheduling scores), computed by the
    Pallas row-reduction kernel (interpret on CPU, Mosaic on TPU).
    """
    if not use_kernel:
        return jnp.max(jnp.abs(gs), axis=1), jnp.sum(gs * gs, axis=1)
    n_dev, d = gs.shape
    br = _fit_block_rows(d)
    per = br * LANES
    d_pad = (-d) % per
    g2d = jnp.pad(gs, ((0, 0), (0, d_pad))).reshape(-1, LANES)
    out = row_maxabs_sumsq_2d(g2d, n_dev=n_dev, interpret=_on_cpu(),
                              block_rows=br)
    return out[:, 0], out[:, 1]


def ota_combine_with_noise(g: jnp.ndarray, alpha: jnp.ndarray,
                           noise: jnp.ndarray,
                           *, use_kernel: bool = True) -> jnp.ndarray:
    """ghat = (g + noise)/alpha with an explicit AWGN operand (eq. (6)).

    ``alpha`` may be a traced per-round scalar (e.g. Vanilla OTA's n*gamma_t).
    The kernel consumes pre-scaled noise, so this computes
    g*inv_alpha + noise*inv_alpha (1-ulp from the reference (g+z)/alpha).
    """
    inv_alpha = (1.0 / jnp.asarray(alpha)).astype(g.dtype)
    z = noise.astype(g.dtype) * inv_alpha
    if not use_kernel:
        return ref.ota_combine_ref(g, inv_alpha, z)
    br = _fit_block_rows(g.size)
    g2d, n = _to_blocks(g, br)
    z2d, _ = _to_blocks(z, br)
    out = ota_combine_2d(g2d, z2d, inv_alpha, interpret=_on_cpu(),
                         block_rows=br)
    return _from_blocks(out, n, g.shape, g.dtype)


def ota_combine(g: jnp.ndarray, alpha: jnp.ndarray, noise_scale: jnp.ndarray,
                key: jax.Array, *, use_kernel: bool = True) -> jnp.ndarray:
    """ghat = g/alpha + noise_scale * N(0,1) (noise_scale already /alpha)."""
    inv_alpha = (1.0 / alpha).astype(g.dtype)
    z = (noise_scale.astype(jnp.float32)
         * jax.random.normal(key, g.shape, jnp.float32)).astype(g.dtype)
    if not use_kernel:
        return ref.ota_combine_ref(g, inv_alpha, z)
    br = _fit_block_rows(g.size)
    g2d, n = _to_blocks(g, br)
    z2d, _ = _to_blocks(z, br)
    out = ota_combine_2d(g2d, z2d, inv_alpha, interpret=_on_cpu(),
                         block_rows=br)
    return _from_blocks(out, n, g.shape, g.dtype)


def selective_scan(dt, x, bm, cm, a_w, h0, *, use_kernel: bool = True):
    """Fused Mamba-1 selective scan. dt/x: (B,S,D); bm/cm: (B,S,n);
    a_w: (D,n); h0: (B,D,n). Returns (y (B,S,D), h_last (B,D,n))."""
    if not use_kernel:
        return ref.selective_scan_ref(dt, x, bm, cm, a_w, h0)
    from .selective_scan import selective_scan_bfsn, CHUNK as SCHUNK
    B, S, D = dt.shape
    n = bm.shape[-1]
    s_pad = (-S) % SCHUNK
    d_pad = (-D) % LANES
    dt_p = jnp.pad(dt, ((0, 0), (0, s_pad), (0, d_pad)))
    x_p = jnp.pad(x, ((0, 0), (0, s_pad), (0, d_pad)))
    bm_p = jnp.pad(bm, ((0, 0), (0, s_pad), (0, 0)))
    cm_p = jnp.pad(cm, ((0, 0), (0, s_pad), (0, 0)))
    a_p = jnp.pad(a_w, ((0, d_pad), (0, 0)))
    h0_p = jnp.pad(h0, ((0, 0), (0, d_pad), (0, 0)))
    Sp, Dp = S + s_pad, D + d_pad
    F = Dp // LANES
    to_bfs = lambda t: t.reshape(B, Sp, F, LANES).transpose(0, 2, 1, 3)
    a_f = a_p.reshape(F, LANES, n)
    h0_f = h0_p.reshape(B, F, LANES, n).transpose(0, 1, 3, 2)
    y, h_last = selective_scan_bfsn(to_bfs(dt_p), to_bfs(x_p), bm_p, cm_p,
                                    a_f, h0_f, interpret=_on_cpu())
    y = y.transpose(0, 2, 1, 3).reshape(B, Sp, Dp)[:, :S, :D]
    h_last = h_last.transpose(0, 1, 3, 2).reshape(B, Dp, n)[:, :D]
    return y, h_last


def linear_scan(a: jnp.ndarray, b: jnp.ndarray, h0: jnp.ndarray,
                *, use_kernel: bool = True):
    """h_t = a_t h_{t-1} + b_t over axis 1. a,b: (B,S,D); h0: (B,D).

    Returns (h_all, h_last). Kernel path pads S to a CHUNK multiple and
    D to a LANES multiple (pad a=1, b=0 so padding is inert).
    """
    if not use_kernel:
        return ref.linear_scan_ref(a, b, h0)
    B, S, D = a.shape
    s_pad = (-S) % CHUNK
    d_pad = (-D) % LANES
    a_p = jnp.pad(a, ((0, 0), (0, s_pad), (0, d_pad)), constant_values=1.0)
    b_p = jnp.pad(b, ((0, 0), (0, s_pad), (0, d_pad)))
    h0_p = jnp.pad(h0, ((0, 0), (0, d_pad)))
    Sp, Dp = S + s_pad, D + d_pad
    # (B, Sp, Dp) -> (B*Dp/LANES, Sp, LANES): feature-major blocks
    a_f = a_p.transpose(0, 2, 1).reshape(B * Dp // LANES, LANES, Sp)
    a_f = a_f.transpose(0, 2, 1)
    b_f = b_p.transpose(0, 2, 1).reshape(B * Dp // LANES, LANES, Sp)
    b_f = b_f.transpose(0, 2, 1)
    h0_f = h0_p.reshape(B * Dp // LANES, 1, LANES)
    h_all, h_last = linear_scan_fsl(a_f, b_f, h0_f, interpret=_on_cpu())
    h_all = h_all.transpose(0, 2, 1).reshape(B, Dp, Sp).transpose(0, 2, 1)
    return h_all[:, :S, :D], h_last.reshape(B, Dp)[:, :D]
