"""Pallas TPU kernel: fused OTA post-scale + AWGN injection (eq. (6)).

After the ICI all-reduce produces sum_m chi_m gamma_m g_m, the PS epilogue
is ghat = sum/alpha + z/alpha. Fusing the scale and the noise add keeps the
reduced gradient in one HBM->VMEM pass (memory-bound epilogue); the noise
tile is an explicit operand (see kernels/ref.py for why).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_ROWS = 512
LANES = 128


def _kernel(scal_ref, g_ref, z_ref, o_ref):
    inv_alpha = scal_ref[0, 0]
    o_ref[...] = g_ref[...] * inv_alpha + z_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret", "block_rows"))
def ota_combine_2d(g2d: jnp.ndarray, z2d: jnp.ndarray,
                   inv_alpha: jnp.ndarray,
                   interpret: bool = False,
                   block_rows: int = BLOCK_ROWS) -> jnp.ndarray:
    """g2d/z2d: (R,128), R % block_rows == 0; z pre-scaled noise.

    ``block_rows`` tiles the grid; small tensors should pass a small tile
    (interpret-mode cost scales with the padded block, not the payload).
    """
    R = g2d.shape[0]
    scal = inv_alpha.astype(g2d.dtype).reshape(1, 1)
    return pl.pallas_call(
        _kernel,
        grid=(R // block_rows,),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(g2d.shape, g2d.dtype),
        interpret=interpret,
    )(scal, g2d, z2d)
