"""Pallas TPU kernel: fused OTA post-scale + AWGN injection (eq. (6)).

After the ICI all-reduce produces sum_m chi_m gamma_m g_m, the PS epilogue
is ghat = sum/alpha + z/alpha. Fusing the scale and the noise add keeps the
reduced gradient in one HBM->VMEM pass (memory-bound epilogue); the noise
tile is an explicit operand (see kernels/ref.py for why).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_ROWS = 512
LANES = 128


def _kernel(scal_ref, g_ref, z_ref, o_ref):
    inv_alpha = scal_ref[0, 0]
    # the payload block may be narrower than the accumulator (bf16
    # payload, f32 accumulation): widen per-block before the arithmetic
    o_ref[...] = g_ref[...].astype(inv_alpha.dtype) * inv_alpha + z_ref[...]


@functools.partial(jax.jit,
                   static_argnames=("interpret", "block_rows", "acc_dtype"))
def ota_combine_2d(g2d: jnp.ndarray, z2d: jnp.ndarray,
                   inv_alpha: jnp.ndarray,
                   interpret: bool = False,
                   block_rows: int = BLOCK_ROWS,
                   acc_dtype=None) -> jnp.ndarray:
    """g2d/z2d: (R,128), R % block_rows == 0; z pre-scaled noise.

    ``block_rows`` tiles the grid; small tensors should pass a small tile
    (interpret-mode cost scales with the padded block, not the payload).
    ``acc_dtype`` sets the accumulate/output dtype when it should be wider
    than the payload dtype (mixed-precision uplink: g2d in bf16, z2d and
    the result in f32); the payload stays narrow in HBM and widens
    per-block in VMEM. Default: g2d.dtype (unchanged legacy behavior).
    """
    R = g2d.shape[0]
    out_dtype = jnp.dtype(acc_dtype) if acc_dtype is not None else g2d.dtype
    scal = inv_alpha.astype(out_dtype).reshape(1, 1)
    return pl.pallas_call(
        _kernel,
        grid=(R // block_rows,),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(g2d.shape, out_dtype),
        interpret=interpret,
    )(scal, g2d, z2d.astype(out_dtype))
