"""Pallas TPU kernels: fused digital-payload pipeline at gradient scale.

The digital uplink's hot path used to be two full passes over (N, d):
quantize-dequantize every device's gradient (materializing the f32/f64
dequantized block), then a weighted reduction. At payload scale
(d = 10^5–10^7) that block is the dominant memory term — N=256 devices at
d=10^6 is a 1 GB f32 tensor that exists only to be summed.

Three kernels replace it:

  ``quantize_pack_rows_2d``   dither → quantize → bit-pack codes into a
                              uint32 payload buffer (K = 32/code_bits
                              codes per word), one pass per device block.
                              This *is* the wire format: r-bit codes, not
                              dequantized floats, so the payload buffer is
                              code_bits/32 the size of the float block.
  ``packed_weighted_sum_2d``  unpack → dequantize → weighted-accumulate
                              into an O(d) accumulator. The grid walks
                              (row-block, device) with the DEVICE axis
                              innermost, so each output block is revisited
                              across devices in index order — the same
                              sequential order as the NumPy oracle's
                              ``acc += chi_m/nu_m * gq_m`` loop, which
                              keeps the fused path aligned with the
                              reference scan to the last ulp (XLA FMA
                              contraction is the only divergence). The
                              dequantized (N, d) tensor is never
                              materialized.
  ``unpack_dequant_rows_2d``  unpack → dequantize, materializing the
                              (N*R, LANES) float block — the
                              "materialize-then-sum" baseline the bench
                              compares against, and the payload decoder
                              for anything that wants per-device floats.

Packing layout: codes are integers in [0, levels] with levels <= 2^16 - 1
(static ``code_bits`` in {4, 8, 16}), so K vertically-adjacent sublanes
fold into one uint32 word via shift-or; a (block_rows, LANES) code block
packs to (block_rows/K, LANES) words. Codes survive the float round-trip
exactly (f32 represents all integers < 2^24), so pack → unpack →
dequantize reproduces the two-step quantizer bit-for-bit.

Quantizer arithmetic matches ``dithered_quant._kernel`` operation-for-
operation; the ``levels <= 0`` / ``m == 0`` degenerate rows (devices
granted no bits) pack to code 0 and dequantize to exact 0.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .dithered_quant import BLOCK_ROWS, LANES

CODE_BITS_CHOICES = (4, 8, 16)


def _quantize_codes(g, u, m, levels):
    """Integer codes q in [0, levels], same arithmetic as the two-step
    kernel; degenerate (levels <= 0 or m == 0) rows code to 0."""
    valid = (levels > 0) & (m > 0)
    safe = jnp.where(valid, 2.0 * m / jnp.where(levels > 0, levels, 1.0), 1.0)
    x = (g + m) / safe
    lo = jnp.floor(x)
    up = (u < (x - lo)).astype(g.dtype)
    q = jnp.clip(lo + up, 0.0, levels)
    return jnp.where(valid, q, jnp.zeros_like(q))


def _pack_words(q_u32, code_bits):
    """(br, LANES) uint32 codes -> (br/K, LANES) packed words."""
    K = 32 // code_bits
    if K == 1:
        return q_u32
    br = q_u32.shape[0]
    qk = q_u32.reshape(br // K, K, q_u32.shape[1])
    word = qk[:, 0, :]
    for k in range(1, K):
        word = word | (qk[:, k, :] << (k * code_bits))
    return word


def _unpack_words(word, code_bits):
    """(brp, LANES) packed words -> (brp*K, LANES) uint32 codes."""
    K = 32 // code_bits
    if K == 1:
        return word
    mask = jnp.uint32((1 << code_bits) - 1)
    parts = [(word >> (k * code_bits)) & mask for k in range(K)]
    q = jnp.stack(parts, axis=1)
    return q.reshape(q.shape[0] * K, q.shape[2])


def _dequant(q_u32, m, levels, dtype):
    """Codes -> values: -m + (2m/levels) * q, degenerate rows -> 0."""
    qf = q_u32.astype(dtype)
    valid = (levels > 0) & (m > 0)
    safe = jnp.where(valid, 2.0 * m / jnp.where(levels > 0, levels, 1.0), 1.0)
    return jnp.where(valid, -m + safe * qf, jnp.zeros_like(qf))


def _pack_kernel(scal_ref, g_ref, u_ref, o_ref, *, code_bits):
    m = scal_ref[0, 0]
    levels = scal_ref[0, 1]
    q = _quantize_codes(g_ref[...], u_ref[...], m, levels)
    o_ref[...] = _pack_words(q.astype(jnp.uint32), code_bits)


def _unpack_kernel(scal_ref, p_ref, o_ref, *, code_bits):
    m = scal_ref[0, 0]
    levels = scal_ref[0, 1]
    q = _unpack_words(p_ref[...], code_bits)
    o_ref[...] = _dequant(q, m, levels, m.dtype)


def _wsum_kernel(scal_ref, p_ref, o_ref, *, code_bits):
    dev = pl.program_id(1)
    m = scal_ref[0, 0]
    levels = scal_ref[0, 1]
    w = scal_ref[0, 2]
    q = _unpack_words(p_ref[...], code_bits)
    contrib = w * _dequant(q, m, levels, m.dtype)

    @pl.when(dev == 0)
    def _init():
        o_ref[...] = contrib

    @pl.when(dev > 0)
    def _accumulate():
        o_ref[...] = o_ref[...] + contrib


def _wsum_devblock_kernel(scal_ref, p_ref, o_ref, *, code_bits, dev_block,
                          rp_words):
    """Device-blocked variant: one grid step accumulates ``dev_block``
    whole device payloads (``rp_words`` packed rows each). Grid-step
    overhead dominates the revisited-accumulator pattern (in interpret
    mode every step copies the full operand buffers), so fewer, fatter
    steps win; the inner loop still adds devices one at a time in index
    order, preserving the oracle's sequential association."""
    mb = pl.program_id(0)

    @pl.when(mb == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref[...])

    for k in range(dev_block):
        m = scal_ref[k, 0]
        levels = scal_ref[k, 1]
        w = scal_ref[k, 2]
        q = _unpack_words(p_ref[k * rp_words:(k + 1) * rp_words, :],
                          code_bits)
        o_ref[...] = o_ref[...] + w * _dequant(q, m, levels, m.dtype)


@functools.partial(jax.jit,
                   static_argnames=("code_bits", "interpret", "block_rows"))
def quantize_pack_rows_2d(g2d: jnp.ndarray, u2d: jnp.ndarray,
                          scal: jnp.ndarray, code_bits: int,
                          interpret: bool = False,
                          block_rows: int = BLOCK_ROWS) -> jnp.ndarray:
    """Fused dither-quantize-pack over N stacked device payloads.

    g2d/u2d: (N*R_dev, LANES) — device i owns rows [i*R_dev, (i+1)*R_dev);
    scal: (N, 2) per-device (m_i, levels_i) with levels_i <= 2^code_bits-1.
    Returns (N*R_dev/K, LANES) uint32, K = 32 // code_bits.
    """
    NR = g2d.shape[0]
    n_dev = scal.shape[0]
    r_dev = NR // n_dev
    blocks_per_dev = r_dev // block_rows
    K = 32 // code_bits
    return pl.pallas_call(
        functools.partial(_pack_kernel, code_bits=code_bits),
        grid=(n_dev, blocks_per_dev),
        in_specs=[
            pl.BlockSpec((1, 2), lambda i, j: (i, 0)),       # device scalars
            pl.BlockSpec((block_rows, LANES),
                         lambda i, j, b=blocks_per_dev: (i * b + j, 0)),
            pl.BlockSpec((block_rows, LANES),
                         lambda i, j, b=blocks_per_dev: (i * b + j, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows // K, LANES),
                               lambda i, j, b=blocks_per_dev: (i * b + j, 0)),
        out_shape=jax.ShapeDtypeStruct((NR // K, LANES), jnp.uint32),
        interpret=interpret,
    )(scal, g2d, u2d)


@functools.partial(jax.jit,
                   static_argnames=("code_bits", "n_dev", "interpret",
                                    "block_rows"))
def unpack_dequant_rows_2d(p2d: jnp.ndarray, scal: jnp.ndarray,
                           code_bits: int, n_dev: int = None,
                           interpret: bool = False,
                           block_rows: int = BLOCK_ROWS) -> jnp.ndarray:
    """Inverse of quantize_pack_rows_2d: packed words -> dequantized floats.

    p2d: (N*R_dev/K, LANES) uint32; scal: (N, 2) per-device (m, levels).
    Returns (N*R_dev, LANES) in scal.dtype — the materializing decoder.
    """
    K = 32 // code_bits
    NR = p2d.shape[0] * K
    r_dev = NR // n_dev
    blocks_per_dev = r_dev // block_rows
    return pl.pallas_call(
        functools.partial(_unpack_kernel, code_bits=code_bits),
        grid=(n_dev, blocks_per_dev),
        in_specs=[
            pl.BlockSpec((1, 2), lambda i, j: (i, 0)),
            pl.BlockSpec((block_rows // K, LANES),
                         lambda i, j, b=blocks_per_dev: (i * b + j, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, LANES),
                               lambda i, j, b=blocks_per_dev: (i * b + j, 0)),
        out_shape=jax.ShapeDtypeStruct((NR, LANES), scal.dtype),
        interpret=interpret,
    )(scal, p2d)


@functools.partial(jax.jit,
                   static_argnames=("code_bits", "n_dev", "interpret",
                                    "block_rows", "dev_block"))
def packed_weighted_sum_2d(p2d: jnp.ndarray, scal: jnp.ndarray,
                           code_bits: int, n_dev: int = None,
                           interpret: bool = False,
                           block_rows: int = BLOCK_ROWS,
                           dev_block: int = 1) -> jnp.ndarray:
    """Fused unpack-dequantize-weighted-sum: O(d) accumulator, no (N, d).

    p2d: (N*R_dev/K, LANES) uint32 payload buffer; scal: (N, 3) per-device
    (m_i, levels_i, w_i). Returns (R_dev, LANES) = sum_i w_i * deq(p_i).
    The device axis is the innermost grid dim, so each output block
    accumulates devices 0..N-1 in order — the oracle's sequential
    association (agreement to the last ulp; only XLA's discretionary
    FMA contraction of the multiply-accumulate differs).

    ``dev_block > 1`` (requires n_dev % dev_block == 0) switches to the
    device-blocked launch: one grid step ingests dev_block whole device
    payloads (contiguous in the device-major buffer) and the kernel loop
    accumulates them in device order. N/dev_block grid steps instead of
    N * blocks_per_dev — the payload-scale configuration, where grid-step
    overhead (interpret mode copies the operand buffers every step) is
    the entire cost. Block = dev_block whole payloads, so it is
    CPU/interpret territory; TPU launches keep dev_block=1 and tile.
    """
    K = 32 // code_bits
    NR = p2d.shape[0] * K
    r_dev = NR // n_dev
    if dev_block > 1:
        rp_words = r_dev // K
        return pl.pallas_call(
            functools.partial(_wsum_devblock_kernel, code_bits=code_bits,
                              dev_block=dev_block, rp_words=rp_words),
            grid=(n_dev // dev_block,),
            in_specs=[
                pl.BlockSpec((dev_block, 3), lambda mb: (mb, 0)),
                pl.BlockSpec((dev_block * rp_words, LANES),
                             lambda mb: (mb, 0)),
            ],
            out_specs=pl.BlockSpec((r_dev, LANES), lambda mb: (0, 0)),
            out_shape=jax.ShapeDtypeStruct((r_dev, LANES), scal.dtype),
            interpret=interpret,
        )(scal, p2d)
    blocks_per_dev = r_dev // block_rows
    return pl.pallas_call(
        functools.partial(_wsum_kernel, code_bits=code_bits),
        grid=(blocks_per_dev, n_dev),
        in_specs=[
            pl.BlockSpec((1, 3), lambda i, m: (m, 0)),       # device scalars
            pl.BlockSpec((block_rows // K, LANES),
                         lambda i, m, b=blocks_per_dev: (m * b + i, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, LANES), lambda i, m: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r_dev, LANES), scal.dtype),
        interpret=interpret,
    )(scal, p2d)
