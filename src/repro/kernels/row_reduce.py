"""Pallas TPU kernel: fused per-row (max|g|, sum g^2) reduction.

The digital-FL selection/bit-allocation schemes score every device's
gradient each round: ``||g||_inf`` feeds the quantizer scale and the
quantization-MSE proxy d*||g||_inf^2/(2^r-1)^2 (Lemma 2), ``||g||_2``
drives norm-based scheduling (BestChannel-Norm's top-K and its
bits-proportional-to-norms split). Both are single-pass row reductions
over the same (N, d) gradient block, so one fused HBM->VMEM sweep produces
the (N, 2) statistics instead of two full passes.

Layout matches ``dithered_quant.dithered_quantize_rows_2d``: the caller
flattens/pads each device's gradient to ``r_dev`` rows of 128 lanes and
stacks devices; the grid walks (device, row-block) with the row-block axis
innermost, accumulating into the (1, 2) output block that every j-step of
device i revisits.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .dithered_quant import BLOCK_ROWS, LANES


def _kernel(g_ref, o_ref, *, acc_dtype):
    j = pl.program_id(1)
    # widen the payload block before reducing (bf16 payload, f32 stats):
    # a bf16 sum-of-squares saturates after a few hundred terms
    g = g_ref[...].astype(acc_dtype)
    pmax = jnp.max(jnp.abs(g))
    psum = jnp.sum(g * g)

    @pl.when(j == 0)
    def _init():
        o_ref[0, 0] = pmax
        o_ref[0, 1] = psum

    @pl.when(j > 0)
    def _accumulate():
        o_ref[0, 0] = jnp.maximum(o_ref[0, 0], pmax)
        o_ref[0, 1] = o_ref[0, 1] + psum


@functools.partial(jax.jit,
                   static_argnames=("n_dev", "interpret", "block_rows",
                                    "acc_dtype"))
def row_maxabs_sumsq_2d(g2d: jnp.ndarray, n_dev: int = None,
                        interpret: bool = False,
                        block_rows: int = BLOCK_ROWS,
                        acc_dtype=None) -> jnp.ndarray:
    """g2d: (N*R_dev, LANES), device i owning rows [i*R_dev, (i+1)*R_dev).

    Returns (N, 2): column 0 = max|g_i|, column 1 = sum g_i^2 per device.
    Zero padding is inert for both statistics. ``acc_dtype`` widens the
    accumulate/output dtype above the payload dtype (bf16 payload, f32
    statistics); default g2d.dtype.
    """
    NR = g2d.shape[0]
    out_dtype = jnp.dtype(acc_dtype) if acc_dtype is not None else g2d.dtype
    r_dev = NR // n_dev
    blocks_per_dev = r_dev // block_rows
    return pl.pallas_call(
        functools.partial(_kernel, acc_dtype=out_dtype),
        grid=(n_dev, blocks_per_dev),
        in_specs=[
            pl.BlockSpec((block_rows, LANES),
                         lambda i, j, b=blocks_per_dev: (i * b + j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 2), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_dev, 2), out_dtype),
        interpret=interpret,
    )(g2d)
