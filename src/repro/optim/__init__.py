from .sgd import SGDConfig, sgd_init, sgd_update
from .adam import AdamConfig, adam_init, adam_update
from .projection import project_l2_ball

__all__ = ["SGDConfig", "sgd_init", "sgd_update", "AdamConfig",
           "adam_init", "adam_update", "project_l2_ball"]
