"""Projection onto the l2 ball W = {||w|| <= radius} (paper eq. (2)/(13))."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def project_l2_ball(params, radius: float):
    """Project the flattened parameter pytree onto ||w||_2 <= radius."""
    sq = sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
             for x in jax.tree.leaves(params))
    nrm = jnp.sqrt(sq)
    scale = jnp.minimum(1.0, radius / jnp.maximum(nrm, 1e-30))
    return jax.tree.map(lambda x: (x.astype(jnp.float32) * scale).astype(x.dtype),
                        params)
