"""Adam on parameter pytrees (beyond-paper LM training option)."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamConfig:
    eta: float = 1e-3
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0


def adam_init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def adam_update(cfg: AdamConfig, params, grads, state):
    step = state["step"] + 1
    b1t = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2t = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        if cfg.weight_decay:
            g32 = g32 + cfg.weight_decay * p.astype(jnp.float32)
        m = cfg.b1 * m + (1 - cfg.b1) * g32
        v = cfg.b2 * v + (1 - cfg.b2) * g32 * g32
        upd_val = (m / b1t) / (jnp.sqrt(v / b2t) + cfg.eps)
        return (p.astype(jnp.float32) - cfg.eta * upd_val).astype(p.dtype), m, v

    flat = jax.tree.map(upd, params, grads, state["m"], state["v"],
                        is_leaf=lambda x: isinstance(x, jax.Array))
    new_p = jax.tree.map(lambda t: t[0], flat,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], flat,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], flat,
                         is_leaf=lambda x: isinstance(x, tuple))
    return new_p, {"m": new_m, "v": new_v, "step": step}
