"""SGD (+momentum, weight decay) on parameter pytrees.

The paper's update (13) is plain projected SGD; momentum/weight-decay are
provided for the beyond-paper LM training driver.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SGDConfig:
    eta: float = 1e-2
    momentum: float = 0.0
    weight_decay: float = 0.0


def sgd_init(params):
    return jax.tree.map(jnp.zeros_like, params)


def sgd_update(cfg: SGDConfig, params, grads, mom):
    """Returns (new_params, new_mom)."""
    if cfg.weight_decay:
        grads = jax.tree.map(
            lambda g, p: g + cfg.weight_decay * p.astype(g.dtype),
            grads, params)
    if cfg.momentum:
        mom = jax.tree.map(lambda m, g: cfg.momentum * m + g.astype(m.dtype),
                           mom, grads)
        upd = mom
    else:
        upd = grads
    params = jax.tree.map(
        lambda p, u: (p.astype(jnp.float32)
                      - cfg.eta * u.astype(jnp.float32)).astype(p.dtype),
        params, upd)
    return params, mom
