"""Version-compat shims over the moving jax sharding API.

The production code targets the current explicit-sharding surface
(``jax.shard_map`` with ``axis_names``/``check_vma``, meshes built with
``jax.sharding.AxisType``). Pinned containers may carry an older jax
(<= 0.4.x) where ``shard_map`` lives in ``jax.experimental`` (with
``check_rep``/``auto`` instead) and meshes have no axis types. These
helpers pick whichever API exists at import time so the launch/step/engine
layers and the multi-device tests run on both.
"""
from __future__ import annotations

import re

import jax

HAS_AXIS_TYPES = hasattr(jax.sharding, "AxisType")
HAS_TOPLEVEL_SHARD_MAP = hasattr(jax, "shard_map")

#: (major, minor, patch) of the running jax, robust to dev/rc suffixes.
JAX_VERSION = tuple(int(x) for x in re.findall(r"\d+", jax.__version__)[:3])

#: Partial-auto shard_map (some mesh axes manual, the rest automatic) hits
#: an XLA SPMD partitioner check ("IsManualSubgroup") on jax<=0.4.x. The
#: API shim below still works there, but the mixed manual/auto *train step*
#: needs a jax whose bundled XLA has the fix — gate on the actual version,
#: not on which module spells ``shard_map``, so the test runs (instead of
#: silently skipping) as soon as the interpreter has jax >= 0.5.
HAS_PARTIAL_AUTO_SHARD_MAP = JAX_VERSION >= (0, 5)


def make_auto_mesh(shape, axes):
    """Mesh with every axis in Auto mode (the pre-AxisType default)."""
    if HAS_AXIS_TYPES:
        from jax.sharding import AxisType
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def axis_size(axis_name):
    """Size of a manual mesh axis from inside shard_map.

    ``jax.lax.axis_size`` is newer than 0.4.x; ``psum(1, axis)`` is the
    classic spelling (folded to a constant for a static operand).
    """
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)


def shard_map(f, mesh, in_specs, out_specs, manual_axes):
    """shard_map with ``manual_axes`` manual and the rest automatic.

    New jax: ``jax.shard_map(..., axis_names=set(manual_axes),
    check_vma=False)``. Old jax: ``jax.experimental.shard_map.shard_map(...,
    auto=<other axes>, check_rep=False)`` — the same partial-auto semantics
    under the previous parameter names.
    """
    if HAS_TOPLEVEL_SHARD_MAP:
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs,
                             axis_names=set(manual_axes), check_vma=False)
    from jax.experimental.shard_map import shard_map as _shard_map
    auto = frozenset(mesh.axis_names) - frozenset(manual_axes)
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=False, auto=auto)
