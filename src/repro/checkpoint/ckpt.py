"""Minimal npz checkpointing for param/optimizer pytrees.

Trees are flattened with '/'-joined key paths; arrays are devicehost-
transferred with jax.device_get. Restore rebuilds the exact tree structure
from a template (abstract or concrete).
"""
from __future__ import annotations

import re
from pathlib import Path

import numpy as np
import jax


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def save_checkpoint(directory, step: int, params, extra=None):
    d = Path(directory)
    d.mkdir(parents=True, exist_ok=True)
    payload = _flatten(params)
    if extra is not None:
        payload.update({f"__extra__/{k}": v for k, v in _flatten(extra).items()})
    np.savez(d / f"ckpt_{step:08d}.npz", **payload)
    return d / f"ckpt_{step:08d}.npz"


def latest_step(directory) -> int:
    d = Path(directory)
    steps = [int(m.group(1)) for f in d.glob("ckpt_*.npz")
             if (m := re.match(r"ckpt_(\d+)\.npz", f.name))]
    if not steps:
        raise FileNotFoundError(f"no checkpoints in {directory}")
    return max(steps)


def restore_checkpoint(directory, step: int, template):
    d = Path(directory)
    data = np.load(d / f"ckpt_{step:08d}.npz")
    leaves_with_path, treedef = jax.tree_util.tree_flatten_with_path(template)
    out = []
    for path, leaf in leaves_with_path:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = data[key]
        assert arr.shape == tuple(leaf.shape), (key, arr.shape, leaf.shape)
        out.append(arr)
    return jax.tree_util.tree_unflatten(treedef, out)
