"""Kernel microbenchmarks: wall time of the jnp reference vs the Pallas
kernel (interpret mode on CPU — the timing is indicative only; the real
target is TPU Mosaic, see kernels/*.py docstrings)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels import ops


def _time(fn, *args, reps=3):
    fn(*args)            # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def run(quick: bool = True):
    rows = []
    n = 1 << (18 if quick else 22)
    g = jax.random.normal(jax.random.key(0), (n,))
    key = jax.random.key(1)

    f_ref = jax.jit(lambda g: ops.dithered_quantize(g, 255.0, key,
                                                    use_kernel=False))
    f_ker = jax.jit(lambda g: ops.dithered_quantize(g, 255.0, key,
                                                    use_kernel=True))
    rows.append(("kernel/dithered_quant/ref", _time(f_ref, g), f"n={n}"))
    rows.append(("kernel/dithered_quant/pallas-interp", _time(f_ker, g),
                 f"n={n}"))

    a = jnp.asarray(3.0)
    ns = jnp.asarray(0.1)
    f_ref = jax.jit(lambda g: ops.ota_combine(g, a, ns, key,
                                              use_kernel=False))
    f_ker = jax.jit(lambda g: ops.ota_combine(g, a, ns, key,
                                              use_kernel=True))
    rows.append(("kernel/ota_combine/ref", _time(f_ref, g), f"n={n}"))
    rows.append(("kernel/ota_combine/pallas-interp", _time(f_ker, g),
                 f"n={n}"))

    B, S, D = 2, 512 if quick else 2048, 256
    aa = jax.random.uniform(jax.random.key(2), (B, S, D), minval=.5,
                            maxval=.99)
    bb = jax.random.normal(jax.random.key(3), (B, S, D)) * .1
    h0 = jnp.zeros((B, D))
    f_ref = jax.jit(lambda a, b, h: ops.linear_scan(a, b, h,
                                                    use_kernel=False))
    f_ker = jax.jit(lambda a, b, h: ops.linear_scan(a, b, h,
                                                    use_kernel=True))
    rows.append(("kernel/linear_scan/ref", _time(f_ref, aa, bb, h0),
                 f"B{B}xS{S}xD{D}"))
    rows.append(("kernel/linear_scan/pallas-interp",
                 _time(f_ker, aa, bb, h0), f"B{B}xS{S}xD{D}"))
    return rows, {}
