"""Kernel microbenchmarks: wall time of the jnp reference vs the Pallas
kernel (interpret mode on CPU — the timing is indicative only; the real
target is TPU Mosaic, see kernels/*.py docstrings).

``--payload`` runs the payload-scale suite instead: the fused
quantize->pack->dequant-aggregate pipeline at N=256 devices, d=10^6
(full mode adds d=10^7) against the materialize-then-sum baseline, with
per-kernel achieved bytes/s and FLOP/s vs the ``benchmarks.roofline``
peaks, the bf16-payload/f32-accumulate kernel rows, and the
autotuned-vs-fixed tile comparison. Writes the schema-stamped record to
the repo-root ``BENCH_kernel_payload.json`` (tracked across PRs, next to
``BENCH_engine_scale.json``). ``--rss-budget-mb`` guards the fused
phase's peak RSS (exit 1 on overrun — the scripts/verify.sh CI gate that
pins the O(d) aggregation claim)."""
from __future__ import annotations

import argparse
import resource
import sys
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import autotune, ops
from repro.kernels.payload import unpack_dequant_rows_2d


def _time(fn, *args, reps=3):
    fn(*args)            # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def run(quick: bool = True):
    rows = []
    n = 1 << (18 if quick else 22)
    g = jax.random.normal(jax.random.key(0), (n,))
    key = jax.random.key(1)

    f_ref = jax.jit(lambda g: ops.dithered_quantize(g, 255.0, key,
                                                    use_kernel=False))
    f_ker = jax.jit(lambda g: ops.dithered_quantize(g, 255.0, key,
                                                    use_kernel=True))
    rows.append(("kernel/dithered_quant/ref", _time(f_ref, g), f"n={n}"))
    rows.append(("kernel/dithered_quant/pallas-interp", _time(f_ker, g),
                 f"n={n}"))

    a = jnp.asarray(3.0)
    ns = jnp.asarray(0.1)
    f_ref = jax.jit(lambda g: ops.ota_combine(g, a, ns, key,
                                              use_kernel=False))
    f_ker = jax.jit(lambda g: ops.ota_combine(g, a, ns, key,
                                              use_kernel=True))
    rows.append(("kernel/ota_combine/ref", _time(f_ref, g), f"n={n}"))
    rows.append(("kernel/ota_combine/pallas-interp", _time(f_ker, g),
                 f"n={n}"))

    B, S, D = 2, 512 if quick else 2048, 256
    aa = jax.random.uniform(jax.random.key(2), (B, S, D), minval=.5,
                            maxval=.99)
    bb = jax.random.normal(jax.random.key(3), (B, S, D)) * .1
    h0 = jnp.zeros((B, D))
    f_ref = jax.jit(lambda a, b, h: ops.linear_scan(a, b, h,
                                                    use_kernel=False))
    f_ker = jax.jit(lambda a, b, h: ops.linear_scan(a, b, h,
                                                    use_kernel=True))
    rows.append(("kernel/linear_scan/ref", _time(f_ref, aa, bb, h0),
                 f"B{B}xS{S}xD{D}"))
    rows.append(("kernel/linear_scan/pallas-interp",
                 _time(f_ker, aa, bb, h0), f"B{B}xS{S}xD{D}"))
    return rows, {}


# ------------------------------------------------- payload-scale suite

def _rss_mb() -> float:
    return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0


def _time_s(fn, *args, reps=2):
    jax.block_until_ready(fn(*args))     # compile / warm
    best = np.inf
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def _roofline_fracs(bytes_moved: float, flops: float, secs: float) -> dict:
    """Achieved throughput vs the roofline peaks (indicative on CPU
    interpret; the fractions become meaningful on TPU Mosaic)."""
    from .roofline import HBM_BW, PEAK_FLOPS
    return {
        "bytes": bytes_moved, "flops": flops, "wall_s": secs,
        "achieved_bytes_per_s": bytes_moved / secs,
        "achieved_flops_per_s": flops / secs,
        "frac_hbm_bw": bytes_moved / secs / HBM_BW,
        "frac_peak_flops": flops / secs / PEAK_FLOPS,
    }


def _payload_case(n_dev: int, d: int, r_bits: int, seed: int = 0,
                  chunk: int = 16) -> dict:
    """One (N, d) payload-scale measurement: fused vs materialize-then-sum.

    Device gradients come from ``SyntheticHighDimTask`` (O(d) closed form)
    and are packed in ``chunk``-device slices, so the full (N, d) float
    gradient block never exists host- or device-side — only the uint32
    payload buffer (code_bits/32 of the float bytes) plus one in-flight
    chunk. The fused phase runs FIRST: ru_maxrss is a monotone high-water
    mark, so its reading excludes the baseline's (N, d) materialization.
    """
    from repro.fl.tasks import SyntheticHighDimTask

    cb = ops.code_bits_for(r_bits)
    task = SyntheticHighDimTask(d, seed=seed)
    w32 = jnp.zeros(d, jnp.float32)
    levels = jnp.full(n_dev, float(2 ** r_bits - 1), jnp.float32)
    key = jax.random.PRNGKey(seed + 1)

    t0 = time.perf_counter()
    words_parts, scal_parts = [], []
    pk = None
    for c0 in range(0, n_dev, chunk):
        c = min(chunk, n_dev - c0)
        xs = jnp.arange(c0, c0 + c, dtype=jnp.float32).reshape(c, 1, 1)
        ys = jnp.zeros((c, 1), jnp.int32)
        g = task.device_grads_fn(w32, xs, ys)
        u = jax.random.uniform(jax.random.fold_in(key, c0), g.shape,
                               dtype=jnp.float32)
        pk = ops.quantize_pack(g, levels[c0:c0 + c], u, code_bits=cb)
        words_parts.append(pk.words)
        scal_parts.append(pk.scal)
    words = jnp.concatenate(words_parts)
    scal = jnp.concatenate(scal_parts)
    jax.block_until_ready(words)
    del words_parts, scal_parts
    pack_s = time.perf_counter() - t0
    block_rows = pk.block_rows
    d_padded = words.shape[0] * (32 // cb) * 128 // n_dev
    wvec = jnp.full(n_dev, 1.0 / n_dev, jnp.float32)

    def fused_fn(wd, wv):
        return ops.packed_weighted_sum(
            ops.PackedGrads(wd, scal, cb, n_dev, d, block_rows), wv)

    fused_j = jax.jit(fused_fn)
    t_fused = _time_s(fused_j, words, wvec)
    fused_rss = _rss_mb()

    # materialize-then-sum baseline: same Pallas unpack technology, then a
    # weighted matvec over the (N, d) float block. The matvec runs on the
    # padded width and slices the (d,) result — slicing the matrix first
    # would copy another N*d floats.
    interp = jax.default_backend() == "cpu"

    def base_fn(wd, wv):
        gq = unpack_dequant_rows_2d(wd, scal, code_bits=cb, n_dev=n_dev,
                                    interpret=interp, block_rows=block_rows)
        return (wv @ gq.reshape(n_dev, -1))[:d]

    base_j = jax.jit(base_fn)
    t_base = _time_s(base_j, words, wvec)
    base_rss = _rss_mb()
    dev = float(jnp.max(jnp.abs(fused_j(words, wvec) - base_j(words, wvec))))

    payload_bytes = n_dev * d_padded * cb / 8
    # fused: read every packed word once, write the (d,) accumulator
    fused_roof = _roofline_fracs(payload_bytes + d_padded * 4,
                                 3.0 * n_dev * d_padded, t_fused)
    # baseline: read packed words, write + re-read the (N, d) float block,
    # write the accumulator
    base_roof = _roofline_fracs(payload_bytes + 2 * n_dev * d_padded * 4
                                + d_padded * 4,
                                4.0 * n_dev * d_padded, t_base)
    return {
        "n_devices": n_dev, "dim": d, "dim_padded": int(d_padded),
        "r_bits": r_bits, "code_bits": cb, "block_rows": int(block_rows),
        "packed_mb": words.nbytes / 2 ** 20,
        "materialized_mb": n_dev * d_padded * 4 / 2 ** 20,
        "pack_wall_s": pack_s,
        "fused": {**fused_roof, "peak_rss_mb": fused_rss},
        "baseline": {**base_roof, "peak_rss_mb": base_rss},
        "speedup": t_base / t_fused,
        "max_abs_deviation": dev,
    }


def _bf16_kernel_rows(d: int) -> list:
    """bf16-payload / f32-accumulate kernel rows vs the f32/f32 kernels."""
    key = jax.random.PRNGKey(3)
    g32 = jax.random.normal(key, (d,), jnp.float32)
    g16 = g32.astype(jnp.bfloat16)
    z = jax.random.normal(jax.random.fold_in(key, 1), (d,), jnp.float32)
    alpha = jnp.asarray(3.0)
    rows = []

    ota32 = jax.jit(lambda g: ops.ota_combine_with_noise(g, alpha, z))
    ota16 = jax.jit(lambda g: ops.ota_combine_with_noise(
        g, alpha, z, acc_dtype=jnp.float32))
    t32, t16 = _time_s(ota32, g32), _time_s(ota16, g16)
    err = float(jnp.max(jnp.abs(ota16(g16) - ota32(g32))))
    rows.append({"kernel": "ota_combine", "dim": d, "f32_s": t32,
                 "bf16_payload_s": t16, "payload_bytes_ratio": 0.5,
                 "max_abs_deviation": err})

    red32 = jax.jit(lambda g: ops.row_maxabs_sumsq(g[None, :]))
    red16 = jax.jit(lambda g: ops.row_maxabs_sumsq(
        g[None, :], acc_dtype=jnp.float32))
    t32, t16 = _time_s(red32, g32), _time_s(red16, g16)
    m32, s32 = red32(g32)
    m16, s16 = red16(g16)
    rel = float(jnp.abs(s16[0] - s32[0]) / s32[0])
    rows.append({"kernel": "row_maxabs_sumsq", "dim": d, "f32_s": t32,
                 "bf16_payload_s": t16, "payload_bytes_ratio": 0.5,
                 "sumsq_rel_deviation": rel})
    return rows


def _autotune_rows(d: int) -> dict:
    """Chosen tile + the measured per-candidate times it beat, per kernel
    family (the fixed-512 column is the pre-autotuner behavior)."""
    rows = -(-d // 128)
    out = {}
    for kind in ("pack", "unpack", "quantize"):
        bench = ops._autotune_bench(kind, jnp.float32)
        chosen = autotune.choose_block_rows(kind, rows, jnp.float32,
                                            bench=bench)
        times = {br: autotune._measure(bench, br)
                 for br in autotune.CANDIDATES if br <= autotune._pow2_fit(rows)}
        out[kind] = {
            "chosen_block_rows": chosen,
            "fixed_512_s": times.get(512),
            "chosen_s": times.get(chosen),
            "speedup_vs_fixed": (times[512] / times[chosen]
                                 if 512 in times and chosen in times
                                 else None),
            "candidate_s": {str(k): v for k, v in times.items()},
        }
    return out


def run_payload(quick: bool = True, *, rss_budget_mb=None):
    """Payload-scale fused-pipeline benchmark -> BENCH_kernel_payload.json.

    Measures the fused digital path (dither->quantize->bit-pack into a
    uint32 payload buffer, then unpack-dequant-weighted-accumulate with an
    O(d) accumulator) against materialize-then-sum at N=256 devices,
    d=10^6 — the regime where the (N, d) float block is a gigabyte that
    exists only to be summed. Full mode adds a d=10^7 point at N=32.
    Also records the bf16-payload/f32-accumulate kernel rows and the
    autotuned-vs-fixed-512 tile table, all schema-stamped to the repo-root
    ``BENCH_kernel_payload.json``.
    """
    from .common import dump_json, result_payload

    cases = [_payload_case(256, 1_000_000, 8)]
    if not quick:
        cases.append(_payload_case(32, 10_000_000, 8, chunk=4))
    bf16 = _bf16_kernel_rows(1_000_000)
    tune = _autotune_rows(1_000_000)
    payload = result_payload(
        "kernel_bench_payload", quick=quick, cases=cases,
        bf16_kernels=bf16, autotune=tune, rss_budget_mb=rss_budget_mb)
    out = Path(__file__).resolve().parents[1] / "BENCH_kernel_payload.json"
    out.write_text(dump_json(payload))
    rows = []
    for c in cases:
        rows.append((f"kernel_payload/N{c['n_devices']}_d{c['dim']}/fused",
                     c["fused"]["wall_s"] * 1e6,
                     f"speedup={c['speedup']:.2f}x;"
                     f"rss={c['fused']['peak_rss_mb']:.0f}MB"))
        rows.append((f"kernel_payload/N{c['n_devices']}_d{c['dim']}/baseline",
                     c["baseline"]["wall_s"] * 1e6,
                     f"rss={c['baseline']['peak_rss_mb']:.0f}MB"))
    return rows, payload


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--payload", action="store_true",
                    help="payload-scale fused-pipeline suite (writes "
                         "top-level BENCH_kernel_payload.json)")
    ap.add_argument("--smoke", action="store_true",
                    help="with --payload: keep the quick N=256, d=1e6 case "
                         "only (the CI gate size)")
    ap.add_argument("--full", action="store_true",
                    help="with --payload: add the d=1e7 case")
    ap.add_argument("--rss-budget-mb", type=float, default=None,
                    help="with --payload: exit 1 if the FUSED phase's peak "
                         "RSS exceeds this (the O(d) aggregation guard)")
    args = ap.parse_args()
    if not args.payload:
        rows, _ = run(quick=True)
        for r in rows:
            print(f"{r[0]},{r[1]:.1f},{r[2]}")
        return
    rows, payload = run_payload(quick=not args.full,
                                rss_budget_mb=args.rss_budget_mb)
    for c in payload["cases"]:
        f, b = c["fused"], c["baseline"]
        print(f"N={c['n_devices']} d={c['dim']} ({c['code_bits']}-bit codes, "
              f"tile {c['block_rows']}): packed {c['packed_mb']:.0f} MB vs "
              f"materialized {c['materialized_mb']:.0f} MB")
        print(f"  fused    {f['wall_s']:.2f}s  RSS {f['peak_rss_mb']:.0f} MB"
              f"  ({f['achieved_bytes_per_s'] / 1e9:.2f} GB/s, "
              f"{f['frac_hbm_bw'] * 100:.2f}% of TPU HBM roofline)")
        print(f"  baseline {b['wall_s']:.2f}s  RSS {b['peak_rss_mb']:.0f} MB"
              f"  -> fused speedup {c['speedup']:.2f}x, "
              f"max deviation {c['max_abs_deviation']:.1e}")
    for r in payload["bf16_kernels"]:
        print(f"bf16 {r['kernel']} d={r['dim']}: f32 {r['f32_s'] * 1e3:.1f}ms"
              f" vs bf16-payload {r['bf16_payload_s'] * 1e3:.1f}ms "
              f"(half the payload bytes)")
    for kind, t in payload["autotune"].items():
        if t["speedup_vs_fixed"]:
            print(f"autotune {kind}: tile {t['chosen_block_rows']} "
                  f"({t['speedup_vs_fixed']:.1f}x vs fixed 512)")
    print(f"-> BENCH_kernel_payload.json")
    gate = payload["cases"][0]
    if (args.rss_budget_mb is not None
            and gate["fused"]["peak_rss_mb"] > args.rss_budget_mb):
        print(f"FAIL: fused-phase peak RSS {gate['fused']['peak_rss_mb']:.0f}"
              f" MB exceeds budget {args.rss_budget_mb:.0f} MB — is the "
              "(N, d) dequantized block materialized on the fused path?",
              file=sys.stderr)
        sys.exit(1)
    if gate["speedup"] < 1.0 or (gate["fused"]["peak_rss_mb"]
                                 >= gate["baseline"]["peak_rss_mb"]):
        print("FAIL: fused path must beat materialize-then-sum in both "
              f"wall-clock (speedup {gate['speedup']:.2f}x) and peak RSS "
              f"({gate['fused']['peak_rss_mb']:.0f} vs "
              f"{gate['baseline']['peak_rss_mb']:.0f} MB)", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
