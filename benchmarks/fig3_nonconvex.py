"""Paper Fig. 3: non-convex OTA-FL (two-classes-per-device, N=10).

ResNet-18/CIFAR-10 is replaced by an MLP on the cifar-like synthetic set
(CPU budget) — the theory only needs smooth non-convex local objectives,
and the two-class split preserves the heterogeneity that drives the
bias-variance trade-off. kappa_nc is estimated from gradient dissimilarity
at probe points (the paper uses the bound 2*G_max). The paper excludes the
genie OPC OTA-FL here (PL condition + future CSI) — the declared
``suite:fig3_ota`` mirrors that. Protocol in
``repro.api.scenarios.fig3_nonconvex``; this module is glue.
"""
from __future__ import annotations

import time

from repro.api import execute
from repro.api.scenarios import fig3_nonconvex as make_spec

from .common import figure_rows_and_logs, save_result


def run(quick: bool = True, n_devices: int = 10, use_cache: bool = False):
    """Benchmark entry: recomputes by default (see fig2_ota_sc.run)."""
    t0 = time.time()
    spec = make_spec(quick=quick, n_devices=n_devices)
    rs = execute(spec, force=not use_cache)
    cell = rs.cell(0).payload
    rounds, trials = spec.run.rounds, spec.run.trials
    rows, logs = figure_rows_and_logs(
        "fig3_nonconvex", cell, per_call_denom=max(rounds * trials, 1))
    payload = {"n_devices": n_devices, "rounds": rounds, "trials": trials,
               "kappa_nc": cell["kappa"],
               "design_objective": cell["design"]["ota"]["objective"],
               "eta_max": cell["eta_max"], "logs": logs,
               "elapsed_s": time.time() - t0,
               "scenario": cell["scenario"], "cell_hash": cell["cell_hash"]}
    save_result("fig3_nonconvex", payload)
    return rows, payload
