"""Paper Fig. 3: non-convex OTA-FL (two-classes-per-device, N=10).

ResNet-18/CIFAR-10 is replaced by an MLP on the cifar-like synthetic set
(CPU budget; see DESIGN.md §2) — the theory only needs smooth non-convex
local objectives, and the two-class split preserves the heterogeneity that
drives the bias-variance trade-off. kappa_nc is estimated from gradient
dissimilarity at probe points (the paper uses the bound 2*G_max)."""
from __future__ import annotations

import time

from .common import (design_ota_nc, estimate_kappa_nc, log_to_dict,
                     make_nc_setup, ota_baseline_suite, run_tuned,
                     save_result)


def run(quick: bool = True, n_devices: int = 10):
    t0 = time.time()
    rounds = 100 if quick else 400
    trials = 2 if quick else 3
    task, ds, dep, eta_max = make_nc_setup(n_devices)
    kappa = estimate_kappa_nc(task, ds)
    params, obj = design_ota_nc(task, dep, eta_max,
                                kappa_frac=kappa / (2 * task.g_max))
    logs, rows = [], []
    # paper excludes genie OPC OTA-FL here (PL condition + future CSI)
    suite = [a for a in ota_baseline_suite(task, dep, params)
             if "genie" not in a.name]
    etas = (1.0, 0.5) if quick else (1.5, 1.0, 0.5, 0.25)
    for agg in suite:
        t1 = time.time()
        # backend="auto": the MLPTask fig3 sweep runs through the JAX
        # engine for every scheme (generic vmap grad path; parity pinned
        # by tests/test_engine_parity.py::test_mlp_task_parity)
        log, best_eta = run_tuned(task, ds, dep, agg, eta_max=eta_max,
                                  rounds=rounds, trials=trials,
                                  eval_every=10, seed=9, etas=etas,
                                  backend="auto")
        d = log_to_dict(log)
        d["eta"] = best_eta
        logs.append(d)
        rows.append((f"fig3_nonconvex/{agg.name}",
                     (time.time() - t1) * 1e6 / max(rounds * trials, 1),
                     f"final_acc={log.final_accuracy():.4f};eta={best_eta:.3f}"))
    payload = {"n_devices": n_devices, "rounds": rounds, "trials": trials,
               "kappa_nc": kappa, "design_objective": obj, "eta_max": eta_max,
               "logs": logs, "elapsed_s": time.time() - t0}
    save_result("fig3_nonconvex", payload)
    return rows, payload
