"""Beyond-paper workload: SNR x path-loss-heterogeneity scenario sweep.

The first workload the declarative sweep API unlocks: a grid over transmit
power (SNR) and path-loss exponent (heterogeneity level) comparing the
proposed biased OTA/digital schemes against their zero-bias baselines
(Vanilla OTA-FL; proportional-fairness selection) and the noiseless ideal.
All Sec.-IV designs across the grid solve in ONE batched jit per scheme
family; results are cached by cell content hash, so re-runs only compute
missing cells.

    PYTHONPATH=src python -m benchmarks.run --only sweep_snr_het
    PYTHONPATH=src python -m repro.api.cli run snr_het [--full]

Writes experiments/results/sweep_snr_het.json (summary) on top of the
ResultSet under experiments/results/scenarios/snr_het/.
"""
from __future__ import annotations

import time

import numpy as np

from repro.api import execute
from repro.api.scenarios import snr_het as make_spec

from .common import save_result


def _acc_at_time(rec: dict, t: float) -> float:
    """Accuracy at the last eval point whose cumulative airtime is <= t.

    Eval grids always include round 0 at zero airtime (trainer/engine
    contract), so wall[0] = 0 <= t and the index is never negative; the
    clamp is purely defensive.
    """
    wall = np.asarray(rec["wall_time_s"])
    idx = int(np.searchsorted(wall, t, side="right")) - 1
    return float(rec["acc_mean"][max(idx, 0)])


def run(quick: bool = True, n_devices: int = 10, use_cache: bool = True):
    """Sweep-workload entry. Unlike the per-figure benchmarks this keeps
    the cache ON by default — the point of the workload is the declared
    grid + resume semantics, and interrupted runs pick up missing cells;
    pass ``use_cache=False`` to force a full recompute."""
    t0 = time.time()
    sweep = make_spec(quick=quick, n_devices=n_devices)
    rs = execute(sweep, force=not use_cache)
    rows, cells = [], []
    for cell in rs:
        p = cell.payload
        recs = {rec["scheme_key"]: rec for rec in p["logs"]}
        finals = {k: rec["acc_mean"][-1] for k, rec in recs.items()}
        # OTA rounds cost identical airtime (d/B), so the fixed-round
        # comparison is already latency-matched
        ota_gain = finals["proposed_ota"] - finals["vanilla_ota"]
        # digital rounds cost scheme-dependent TDMA time; compare at the
        # largest common airtime (the paper's acc-vs-time protocol) — the
        # proposed design buys *cheaper* rounds, not better rounds
        t_common = min(recs["proposed_digital"]["wall_time_s"][-1],
                       recs["prop_fairness"]["wall_time_s"][-1])
        dig_gain = (_acc_at_time(recs["proposed_digital"], t_common)
                    - _acc_at_time(recs["prop_fairness"], t_common))
        tx = p["overrides"]["wireless.tx_power_dbm"]
        pl = p["overrides"]["wireless.pl_exponent"]
        cells.append({
            "overrides": p["overrides"], "cell_hash": p["cell_hash"],
            "kappa_sc": p["kappa"],
            "design_objectives": {f: d["objective"]
                                  for f, d in p["design"].items()},
            "final_acc": finals,
            "ota_gain_vs_zero_bias": ota_gain,
            "digital_gain_vs_zero_bias_at_equal_airtime": dig_gain,
            "digital_common_airtime_s": t_common,
            "status": cell.status,
        })
        rows.append((f"sweep_snr_het/tx{tx:+g}dBm_pl{pl:g}",
                     p["elapsed_s"] * 1e6,
                     f"ota_gain={ota_gain:+.4f};dig_gain={dig_gain:+.4f}"))
    payload = {"quick": quick, "n_devices": n_devices,
               "sweep": sweep.to_dict(), "sweep_hash": sweep.spec_hash(),
               "n_cells": len(cells), "cells": cells,
               "all_cached": rs.all_cached, "elapsed_s": time.time() - t0}
    save_result("sweep_snr_het", payload)
    return rows, payload
