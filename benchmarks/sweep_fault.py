"""Fault-injection workload: outage rate x heterogeneity sweep.

Runs the ``sweep_fault`` grid (per-round dropout probability x path-loss
exponent, with a deep-fade cutoff active throughout — ``core.faults``)
comparing the proposed biased OTA design, whose solver sees the
outage-adjusted effective channel statistics, against the zero-bias
Vanilla OTA baseline. The summary reduces each heterogeneity column to a
graceful-degradation record: how much final accuracy each scheme loses
going from the fault-free cell to the highest outage rate. The thesis:
the biased design degrades gracefully where zero-bias aggregation —
whose common pre-scaler chases the weakest instantaneous channel —
collapses.

    PYTHONPATH=src python -m benchmarks.run --only sweep_fault
    PYTHONPATH=src python -m benchmarks.sweep_fault --smoke
    PYTHONPATH=src python -m repro.api.cli run sweep_fault [--full]

Writes experiments/results/sweep_fault.json (summary) on top of the
ResultSet under experiments/results/scenarios/sweep_fault/.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

from repro.api import execute
from repro.api.scenarios import sweep_fault as make_spec

from .common import save_result


def run(quick: bool = True, n_devices: int = 10, use_cache: bool = True,
        jobs: int = 1):
    """Fault-sweep entry. Cache ON by default (sweep-workload semantics:
    interrupted runs resume from finished cells); ``use_cache=False``
    forces a full recompute."""
    t0 = time.time()
    sweep = make_spec(quick=quick, n_devices=n_devices)
    rs = execute(sweep, force=not use_cache, jobs=jobs)
    rows, cells = [], []
    by_pl: dict = {}
    for cell in rs:
        p = cell.payload
        recs = {rec["scheme_key"]: rec for rec in p["logs"]}
        finals = {k: rec["acc_mean"][-1] for k, rec in recs.items()}
        drop = p["overrides"]["fault.dropout_prob"]
        pl = p["overrides"]["wireless.pl_exponent"]
        # OTA rounds cost identical airtime (d/B), so the fixed-round
        # comparison is already latency-matched
        gain = finals["proposed_ota"] - finals["vanilla_ota"]
        by_pl.setdefault(pl, {})[drop] = finals
        cells.append({
            "overrides": p["overrides"], "cell_hash": p["cell_hash"],
            "final_acc": finals,
            "ota_gain_vs_zero_bias": gain,
            "design_objectives": {f: d["objective"]
                                  for f, d in p["design"].items()},
            "status": cell.status,
        })
        rows.append((f"sweep_fault/drop{drop:g}_pl{pl:g}",
                     p["elapsed_s"] * 1e6, f"ota_gain={gain:+.4f}"))
    # graceful-degradation summary: per heterogeneity column, accuracy
    # lost between the fault-free cell and the highest outage rate
    degradation = {}
    for pl, col in sorted(by_pl.items()):
        lo, hi = min(col), max(col)
        degradation[f"pl{pl:g}"] = {
            "dropout_lo": lo, "dropout_hi": hi,
            "proposed_acc_drop": (col[lo]["proposed_ota"]
                                  - col[hi]["proposed_ota"]),
            "vanilla_acc_drop": (col[lo]["vanilla_ota"]
                                 - col[hi]["vanilla_ota"]),
            "gain_at_hi_outage": (col[hi]["proposed_ota"]
                                  - col[hi]["vanilla_ota"]),
        }
    payload = {"quick": quick, "n_devices": n_devices,
               "sweep": sweep.to_dict(), "sweep_hash": sweep.spec_hash(),
               "fault": dataclasses.asdict(sweep.base.fault),
               "n_cells": len(cells), "cells": cells,
               "degradation": degradation,
               "all_cached": rs.all_cached, "elapsed_s": time.time() - t0}
    save_result("sweep_fault", payload)
    return rows, payload


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="toy-scale CI gate (the quick 2x2 grid; exits "
                         "non-zero on any failed cell)")
    ap.add_argument("--full", action="store_true",
                    help="paper-scale grid (slow)")
    ap.add_argument("--jobs", type=int, default=1, metavar="K",
                    help="worker-pool size for the sweep cells")
    args = ap.parse_args()
    quick = not args.full or args.smoke
    rows, payload = run(quick=quick, jobs=args.jobs)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}", flush=True)
    for pl, d in payload["degradation"].items():
        print(f"{pl}: dropout {d['dropout_lo']:g}->{d['dropout_hi']:g}: "
              f"proposed loses {d['proposed_acc_drop']:+.4f} acc, "
              f"vanilla loses {d['vanilla_acc_drop']:+.4f} "
              f"(gain at high outage {d['gain_at_hi_outage']:+.4f})")


if __name__ == "__main__":
    main()
