"""Benchmark harness: one module per paper table/figure + roofline.

Prints ``name,us_per_call,derived`` CSV rows. Full payloads are saved to
experiments/results/*.json.

Usage: PYTHONPATH=src python -m benchmarks.run [--full] [--only NAME]
       PYTHONPATH=src python -m benchmarks.run --list

``--only`` accepts an exact suite name or a name prefix (``--only fig2``
runs both fig2 suites); unknown names print the registry instead of a
KeyError.
"""
from __future__ import annotations

import argparse
import sys
import time
import types


def _registry() -> dict:
    from . import (fig2_ota_sc, fig2_digital_sc, fig3_nonconvex, roofline,
                   kernel_bench, theorem_validation, engine_bench,
                   design_bench, sweep_snr_het, sweep_fault,
                   sweep_participation, sweep_async)
    return {
        "kernel_bench": kernel_bench,
        "roofline": roofline,
        "theorem_validation": theorem_validation,
        "engine_bench": engine_bench,
        # the SGD mini-batch + time-budget engine suite shares the module
        # but runs as its own harness entry
        "engine_bench_minibatch": types.SimpleNamespace(
            run=engine_bench.run_minibatch,
            **{"__doc__": engine_bench.run_minibatch.__doc__}),
        # fast-RNG population-scale grid + fig2 replay-vs-fast record
        # (writes the top-level BENCH_engine_scale.json perf trajectory)
        "engine_bench_scale": types.SimpleNamespace(
            run=engine_bench.run_scale,
            **{"__doc__": engine_bench.run_scale.__doc__}),
        # payload-scale fused quantize->pack->aggregate pipeline
        # (writes the top-level BENCH_kernel_payload.json record)
        "kernel_bench_payload": types.SimpleNamespace(
            run=kernel_bench.run_payload,
            **{"__doc__": kernel_bench.run_payload.__doc__}),
        "design_bench": design_bench,
        "fig2_ota_sc": fig2_ota_sc,
        "fig2_digital_sc": fig2_digital_sc,
        "fig3_nonconvex": fig3_nonconvex,
        "sweep_snr_het": sweep_snr_het,
        "sweep_fault": sweep_fault,
        "sweep_participation": sweep_participation,
        "sweep_async": sweep_async,
    }


def _print_registry(modules: dict, stream=sys.stdout) -> None:
    print("registered benchmark suites:", file=stream)
    for name, mod in modules.items():
        doc = (getattr(mod, "__doc__", None)
               or getattr(getattr(mod, "run", None), "__doc__", None) or "")
        first = doc.strip().splitlines()[0] if doc.strip() else ""
        print(f"  {name:24s} {first}", file=stream)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale rounds/trials (slow)")
    ap.add_argument("--only", default=None,
                    help="run a single benchmark, or all matching a "
                         "name prefix")
    ap.add_argument("--list", action="store_true",
                    help="list registered suites and exit")
    args = ap.parse_args()
    quick = not args.full

    modules = _registry()
    if args.list:
        _print_registry(modules)
        return
    if args.only:
        selected = ({args.only: modules[args.only]} if args.only in modules
                    else {k: v for k, v in modules.items()
                          if k.startswith(args.only)})
        if not selected:
            print(f"unknown benchmark {args.only!r} (no name or prefix "
                  "match)", file=sys.stderr)
            _print_registry(modules, stream=sys.stderr)
            sys.exit(2)
        modules = selected

    print("name,us_per_call,derived")
    for name, mod in modules.items():
        t0 = time.time()
        try:
            rows, payload = mod.run(quick=quick)
        except Exception as e:
            print(f"{name},0,ERROR:{type(e).__name__}:{e}", flush=True)
            continue
        for r in rows:
            print(f"{r[0]},{r[1]:.1f},{r[2]}", flush=True)
        print(f"{name}/TOTAL,{(time.time() - t0) * 1e6:.0f},ok", flush=True)
        if name == "roofline" and payload.get("table"):
            print(mod.format_table(payload), file=sys.stderr)


if __name__ == "__main__":
    main()
