"""Benchmark harness: one module per paper table/figure + roofline.

Prints ``name,us_per_call,derived`` CSV rows. Full payloads are saved to
experiments/results/*.json.

Usage: PYTHONPATH=src python -m benchmarks.run [--full] [--only NAME]
"""
from __future__ import annotations

import argparse
import sys
import time
import types


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale rounds/trials (slow)")
    ap.add_argument("--only", default=None,
                    help="run a single benchmark module")
    args = ap.parse_args()
    quick = not args.full

    from . import (fig2_ota_sc, fig2_digital_sc, fig3_nonconvex, roofline,
                   kernel_bench, theorem_validation, engine_bench,
                   design_bench)
    modules = {
        "kernel_bench": kernel_bench,
        "roofline": roofline,
        "theorem_validation": theorem_validation,
        "engine_bench": engine_bench,
        # the SGD mini-batch + time-budget engine suite shares the module
        # but runs as its own harness entry
        "engine_bench_minibatch": types.SimpleNamespace(
            run=engine_bench.run_minibatch),
        "design_bench": design_bench,
        "fig2_ota_sc": fig2_ota_sc,
        "fig2_digital_sc": fig2_digital_sc,
        "fig3_nonconvex": fig3_nonconvex,
    }
    if args.only:
        modules = {args.only: modules[args.only]}

    print("name,us_per_call,derived")
    for name, mod in modules.items():
        t0 = time.time()
        try:
            rows, payload = mod.run(quick=quick)
        except Exception as e:
            print(f"{name},0,ERROR:{type(e).__name__}:{e}", flush=True)
            continue
        for r in rows:
            print(f"{r[0]},{r[1]:.1f},{r[2]}", flush=True)
        print(f"{name}/TOTAL,{(time.time() - t0) * 1e6:.0f},ok", flush=True)
        if name == "roofline" and payload.get("table"):
            print(roofline.format_table(payload), file=sys.stderr)


if __name__ == "__main__":
    main()
