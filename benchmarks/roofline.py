"""§Roofline: three-term roofline per (arch x shape) from the dry-run.

    compute term    = HLO_FLOPs / (chips_local * peak_FLOPs)   [s]
    memory term     = HLO_bytes / HBM_bw                        [s]
    collective term = collective_link_bytes / (links * link_bw) [s]

All numbers come from launch/hlo_cost.py's trip-count-aware analysis of the
compiled per-device HLO module (so they are already *per device*).
Hardware: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.

MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE) per step for training
(3 for fwd-only steps), D = tokens processed per device per step.
"""
from __future__ import annotations

import json
from pathlib import Path

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

DRYRUN_DIR = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"

SHAPE_TOKENS = {
    # (global_batch, seq, fwd_bwd?)
    "train_4k": (256, 4096, True),
    "prefill_32k": (32, 32768, False),
    "decode_32k": (128, 1, False),
    "long_500k": (1, 1, False),
}


def model_flops(rec: dict) -> float:
    batch, seq, fwd_bwd = SHAPE_TOKENS[rec["shape"]]
    n_active = rec.get("active_param_count") or rec.get("param_count") or 0
    # clamp seq at the arch's decoder context (whisper: 448) and add the
    # encoder pass tokens for enc-dec archs
    from repro.configs import get_config
    cfg = get_config(rec["arch"])
    eff_seq = seq if rec["shape"].startswith("decode") or \
        rec["shape"] == "long_500k" else min(
            seq, cfg.max_target_positions or seq)
    tokens = batch * eff_seq
    if cfg.encoder_positions and rec["shape"] != "decode_32k":
        tokens += batch * cfg.encoder_positions
    factor = 6.0 if fwd_bwd else 2.0
    return factor * n_active * tokens


def roofline_row(rec: dict) -> dict:
    hc = rec.get("hlo_cost") or {}
    n_dev = rec.get("n_devices", 256)
    flops = hc.get("flops", 0.0)
    hbm = hc.get("hbm_bytes", 0.0)
    coll = hc.get("collective_bytes", 0.0)
    compute_t = flops / PEAK_FLOPS
    memory_t = hbm / HBM_BW
    coll_t = coll / ICI_BW
    terms = {"compute": compute_t, "memory": memory_t, "collective": coll_t}
    dominant = max(terms, key=terms.get)
    mf = model_flops(rec)
    mf_per_dev = mf / max(n_dev, 1)
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "status": rec["status"],
        "compute_s": compute_t, "memory_s": memory_t, "collective_s": coll_t,
        "dominant": dominant,
        "model_flops_per_dev": mf_per_dev,
        "useful_flop_frac": (mf_per_dev / flops) if flops else None,
        "hlo_flops": flops, "hbm_bytes": hbm, "collective_bytes": coll,
    }


def load_records(mesh: str = "pod", tag: str = ""):
    recs = []
    suffix = f"_{tag}.json" if tag else ".json"
    for f in sorted(DRYRUN_DIR.glob(f"*_{mesh}{suffix}")):
        r = json.loads(f.read_text())
        if tag and r.get("tag") != tag:
            continue
        if not tag and r.get("tag"):
            continue
        recs.append(r)
    return recs


def run(quick: bool = True):
    rows = []
    table = []
    for rec in load_records("pod"):
        if rec["status"] == "skipped":
            table.append({"arch": rec["arch"], "shape": rec["shape"],
                          "mesh": rec["mesh"], "status": "skipped",
                          "reason": rec.get("reason", "")})
            continue
        if rec["status"] != "ok":
            table.append({"arch": rec["arch"], "shape": rec["shape"],
                          "mesh": rec["mesh"], "status": "error"})
            continue
        row = roofline_row(rec)
        table.append(row)
        rows.append((f"roofline/{rec['arch']}/{rec['shape']}",
                     row["compute_s"] * 1e6,
                     f"dom={row['dominant']},coll_s={row['collective_s']:.3e}"))
    out = {"hardware": {"peak_flops": PEAK_FLOPS, "hbm_bw": HBM_BW,
                        "ici_bw": ICI_BW}, "table": table}
    from .common import save_result
    save_result("roofline", out)
    return rows, out


def format_table(out: dict) -> str:
    lines = [f"{'arch':22s} {'shape':12s} {'compute_s':>10s} {'memory_s':>10s}"
             f" {'collect_s':>10s} {'dominant':>10s} {'useful%':>8s}"]
    for r in out["table"]:
        if r.get("status") != "ok" and "compute_s" not in r:
            lines.append(f"{r['arch']:22s} {r['shape']:12s} "
                         f"[{r.get('status')}] {r.get('reason','')[:60]}")
            continue
        uf = r.get("useful_flop_frac")
        lines.append(
            f"{r['arch']:22s} {r['shape']:12s} {r['compute_s']:10.4f} "
            f"{r['memory_s']:10.4f} {r['collective_s']:10.4f} "
            f"{r['dominant']:>10s} "
            f"{(uf * 100 if uf else 0):7.1f}%")
    return "\n".join(lines)
