"""Design-solver benchmark: per-point SciPy SCA vs batched JAX (Sec. IV).

The Sec.-IV bias-variance design (problems (15)/(17)) used to be the
slowest stage of every figure pipeline: each ``design_*_sca`` call runs
the SCA outer loop as a Python loop of SLSQP solves, and the paper's
sweeps multiply that by dozens of independent grid points. This benchmark
times both solvers on an (omega_bias, omega_var) trade-off grid around
the fig2 operating point and records objective parity — the JAX path must
match the SciPy SCA oracle to 1e-3 relative (or beat it) on every point.

    PYTHONPATH=src python -m benchmarks.design_bench            # fig2-sized
    PYTHONPATH=src python -m benchmarks.design_bench --smoke    # CI guard

Default (fig2-sized: N=50, 4x4 grid per family) writes
experiments/results/design_bench.json; ``--smoke`` runs a small grid,
writes design_bench_smoke.json, and exits 1 if the JAX path loses to the
oracle anywhere (used by scripts/verify.sh).
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np

from .common import result_payload, save_result
from repro.core.channel import WirelessConfig, make_deployment
from repro.core.bounds import ObjectiveWeights
from repro.core import ota_design, digital_design

# Objective-quality gate: jax <= scipy * (1 + PARITY_RTOL) per grid point.
PARITY_RTOL = 1e-3


def _weight_grid(n_devices: int, grid: tuple[int, int]) -> list[ObjectiveWeights]:
    """(omega_var, omega_bias) trade-off grid around the fig2 operating point.

    Base weights follow the strongly convex rule (Sec. IV footnote 4) at
    the fig2 protocol's eta_max/mu/kappa_sc; the multipliers sweep the
    bias-variance trade-off log-spaced, as in the omega sweeps of the
    authors' companion OTA paper (arXiv:2403.19849).
    """
    eta, mu, kappa = 0.1, 0.01, 3.0
    base = ObjectiveWeights.strongly_convex(eta=eta, mu=mu, kappa_sc=kappa,
                                            n=n_devices)
    sv = np.logspace(-1.0, 1.0, grid[0])
    sb = np.logspace(-1.0, 1.0, grid[1])
    return [ObjectiveWeights(omega_var=base.omega_var * a,
                             omega_bias=base.omega_bias * b)
            for a in sv for b in sb]


def _bench_family(name, specs, scipy_solve, batch_solve, oracle_iters):
    t0 = time.perf_counter()
    scipy_objs = [scipy_solve(s, oracle_iters) for s in specs]
    scipy_s = time.perf_counter() - t0

    t0 = time.perf_counter()
    _, jax_objs = batch_solve(specs)
    jax_cold_s = time.perf_counter() - t0          # includes jit compile
    t0 = time.perf_counter()
    _, jax_objs = batch_solve(specs)
    jax_warm_s = time.perf_counter() - t0

    scipy_objs = np.asarray(scipy_objs)
    jax_objs = np.asarray(jax_objs)
    rel_gap = (jax_objs - scipy_objs) / np.abs(scipy_objs)
    return {
        "family": name,
        "n_points": len(specs),
        "n_devices": specs[0].n,
        "oracle_n_iters": oracle_iters,
        "scipy_s": scipy_s,
        "scipy_s_per_point": scipy_s / len(specs),
        "jax_cold_s": jax_cold_s,
        "jax_warm_s": jax_warm_s,
        "jax_cold_s_per_point": jax_cold_s / len(specs),
        "speedup_cold": scipy_s / jax_cold_s,
        "speedup_warm": scipy_s / max(jax_warm_s, 1e-12),
        "scipy_objectives": scipy_objs.tolist(),
        "jax_objectives": jax_objs.tolist(),
        "max_rel_gap": float(np.max(rel_gap)),
        "parity_ok": bool(np.all(rel_gap <= PARITY_RTOL)),
    }


def run(quick: bool = True, *, n_devices: int = 50, grid: tuple = (4, 4),
        oracle_iters: int = 8, t_max_s: float = 0.2,
        result_name: str = "design_bench"):
    """Benchmark entry (also wired into benchmarks.run).

    Full mode is the fig2-sized sweep: N=50 devices, a 4x4
    (omega_var, omega_bias) grid (16 independent design points) per
    family, SCA oracle at the fig2 pipelines' n_iters=8. ``quick`` keeps
    the protocol but shrinks to N=20 and a 2x2 grid and records under
    ``design_bench_smoke`` so it never clobbers the fig2-sized artifact.
    """
    if quick:
        n_devices, grid, oracle_iters = 20, (2, 2), 4
        result_name = "design_bench_smoke"
    dep = make_deployment(WirelessConfig(n_devices=n_devices, seed=1))
    cfg = dep.cfg
    weights = _weight_grid(n_devices, grid)

    ota_specs = [ota_design.OTADesignSpec(
        lambdas=dep.lambdas, dim=7850, g_max=20.0,
        e_s=cfg.energy_per_symbol, n0=cfg.noise_power, weights=w)
        for w in weights]
    dig_specs = [digital_design.DigitalDesignSpec(
        lambdas=dep.lambdas, dim=7850, g_max=20.0,
        e_s=cfg.energy_per_symbol, n0=cfg.noise_power,
        bandwidth_hz=cfg.bandwidth_hz, t_max_s=t_max_s, weights=w)
        for w in weights]

    results = [
        _bench_family(
            "ota", ota_specs,
            lambda s, it: ota_design.design_ota_sca(s, n_iters=it)[1].objective,
            ota_design.design_ota_batch, oracle_iters),
        _bench_family(
            "digital", dig_specs,
            lambda s, it: digital_design.design_digital_sca(
                s, n_iters=it)[1].objective,
            digital_design.design_digital_batch, oracle_iters),
    ]
    payload = result_payload("design_bench", quick=quick, grid=list(grid),
                             n_devices=n_devices, parity_rtol=PARITY_RTOL,
                             results=results)
    save_result(result_name, payload)
    rows = [(f"design_bench/{r['family']}",
             r["jax_cold_s"] * 1e6 / r["n_points"],
             f"speedup={r['speedup_cold']:.1f}x;"
             f"max_rel_gap={r['max_rel_gap']:.1e}")
            for r in results]
    return rows, payload


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small grid CI guard: asserts the JAX path matches "
                         "or beats the SCA oracle on every point")
    args = ap.parse_args()
    rows, payload = run(quick=args.smoke)
    print("family,n_points,scipy[s],jax_cold[s],jax_warm[s],speedup_cold,"
          "max_rel_gap")
    for r in payload["results"]:
        print(f"{r['family']},{r['n_points']},{r['scipy_s']:.2f},"
              f"{r['jax_cold_s']:.2f},{r['jax_warm_s']:.2f},"
              f"{r['speedup_cold']:.1f}x,{r['max_rel_gap']:+.2e}")
    if args.smoke:
        bad = [r for r in payload["results"] if not r["parity_ok"]]
        if bad:
            print("FAIL: batched JAX design solver lost to the SciPy SCA "
                  f"oracle beyond rtol {PARITY_RTOL} on: "
                  f"{[r['family'] for r in bad]}", file=sys.stderr)
            sys.exit(1)
        print("smoke OK: jax design objectives within "
              f"{PARITY_RTOL} of (or better than) the SCA oracle")


if __name__ == "__main__":
    main()
