"""Paper Fig. 2a/2b: strongly convex OTA-FL comparison (softmax regression,
single-class-per-device, N devices, all Sec. V-A-1 baselines).

Protocol mirrors the paper: fixed deployment, Monte-Carlo fading trials,
per-scheme step-size grid search in (0, 2/(mu+L)], kappa_sc estimated on
the actual (synthetic) task data.
"""
from __future__ import annotations

import time

import numpy as np

from .common import (design_ota, estimate_kappa_sc, log_to_dict,
                     make_sc_setup, ota_baseline_suite, run_tuned,
                     save_result)


def run(quick: bool = True, n_devices: int = 50):
    t0 = time.time()
    rounds = 80 if quick else 300
    trials = 2 if quick else 4
    task, ds, dep, eta_max = make_sc_setup(
        n_devices, samples_per_device=300 if quick else 1000,
        n_train_per_class=(n_devices * 300) // 10 if quick else 6000)
    kappa = estimate_kappa_sc(task, ds)
    # batched jax design solver (core.sca_jax); solver="scipy" restores the
    # per-point SLSQP SCA oracle
    params, obj = design_ota(task, dep, eta_max, kappa_sc=kappa,
                             solver="auto")
    params_d, obj_d = design_ota(task, dep, eta_max, kappa_sc=kappa,
                                 solver="direct")
    logs, rows = [], []
    suite = ota_baseline_suite(task, dep, params)
    from repro.core.baselines import ProposedOTA
    suite.insert(2, ProposedOTA(params_d, label="Proposed OTA-FL (direct)"))
    etas = (1.0, 0.25) if quick else (1.0, 0.5, 0.25, 0.1)
    for agg in suite:
        t1 = time.time()
        log, best_eta = run_tuned(task, ds, dep, agg, eta_max=eta_max,
                                  rounds=rounds, trials=trials,
                                  eval_every=10, etas=etas)
        d = log_to_dict(log)
        d["eta"] = best_eta
        logs.append(d)
        rows.append((f"fig2_ota_sc/{agg.name}",
                     (time.time() - t1) * 1e6 / max(rounds * trials, 1),
                     f"final_acc={log.final_accuracy():.4f};eta={best_eta:.3f}"))
    payload = {"n_devices": n_devices, "rounds": rounds, "trials": trials,
               "kappa_sc": kappa, "design_objective": obj,
               "design_solver": "jax-batch",
               "design_objective_direct": obj_d, "eta_max": eta_max,
               "logs": logs, "elapsed_s": time.time() - t0}
    save_result("fig2_ota_sc", payload)
    return rows, payload
