"""Paper Fig. 2a/2b: strongly convex OTA-FL comparison (softmax regression,
single-class-per-device, N devices, all Sec. V-A-1 baselines).

Now a thin declaration over the scenario API: the protocol (fixed
deployment, MC fading trials, per-scheme step-size grid search in
(0, 2/(mu+L)], kappa_sc estimated on the task data, batched-jax design
with the SciPy-direct cross-check) lives in
``repro.api.scenarios.fig2_ota_sc`` + ``repro.api.execute``; this module
is plotting/serialization glue that keeps the legacy
``experiments/results/fig2_ota_sc.json`` payload shape.
"""
from __future__ import annotations

import time

from repro.api import execute
from repro.api.scenarios import fig2_ota_sc as make_spec

from .common import figure_rows_and_logs, save_result


def run(quick: bool = True, n_devices: int = 50, use_cache: bool = False):
    """Benchmark entry: recomputes by default so the reported rows measure
    a real run; ``use_cache=True`` (or the ``repro.api.cli`` path) reuses
    the content-hash-cached ResultSet instead."""
    t0 = time.time()
    spec = make_spec(quick=quick, n_devices=n_devices)
    rs = execute(spec, force=not use_cache)
    cell = rs.cell(0).payload
    rounds, trials = spec.run.rounds, spec.run.trials
    rows, logs = figure_rows_and_logs(
        "fig2_ota_sc", cell, per_call_denom=max(rounds * trials, 1))
    design = cell["design"]["ota"]
    payload = {"n_devices": n_devices, "rounds": rounds, "trials": trials,
               "kappa_sc": cell["kappa"], "design_objective":
               design["objective"], "design_solver": "jax-batch",
               "design_objective_direct": design["objective_direct"],
               "eta_max": cell["eta_max"], "logs": logs,
               "elapsed_s": time.time() - t0,
               "scenario": cell["scenario"], "cell_hash": cell["cell_hash"]}
    save_result("fig2_ota_sc", payload)
    return rows, payload
