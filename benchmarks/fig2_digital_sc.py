"""Paper Fig. 2c/2d: strongly convex digital-FL comparison vs wall-clock
latency (N=10, per-scheme latency accounting, Sec. V-A-2 baselines).

Each scheme is charged its own per-round uplink latency and trained under
a common wall-clock budget; the comparison is accuracy/loss vs TIME, not
rounds. The protocol is declared in
``repro.api.scenarios.fig2_digital_sc`` and executed by the scenario
layer; this module is serialization glue (legacy payload shape).
"""
from __future__ import annotations

import time

from repro.api import execute
from repro.api.scenarios import fig2_digital_sc as make_spec

from .common import figure_rows_and_logs, save_result


def run(quick: bool = True, n_devices: int = 10, use_cache: bool = False):
    """Benchmark entry: recomputes by default (see fig2_ota_sc.run)."""
    t0 = time.time()
    spec = make_spec(quick=quick, n_devices=n_devices)
    rs = execute(spec, force=not use_cache)
    cell = rs.cell(0).payload
    max_rounds, trials = spec.run.rounds, spec.run.trials
    rows, logs = figure_rows_and_logs(
        "fig2_digital_sc", cell, per_call_denom=max(max_rounds * trials, 1))
    design = cell["design"]["digital"]
    payload = {"n_devices": n_devices, "budget_s": spec.run.time_budget_s,
               "trials": trials, "kappa_sc": cell["kappa"],
               "design_objective": design["objective"],
               "design_solver": "jax-batch",
               "design_objective_direct": design["objective_direct"],
               "eta_max": cell["eta_max"], "logs": logs,
               "elapsed_s": time.time() - t0,
               "scenario": cell["scenario"], "cell_hash": cell["cell_hash"]}
    save_result("fig2_digital_sc", payload)
    return rows, payload
