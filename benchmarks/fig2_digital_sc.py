"""Paper Fig. 2c/2d: strongly convex digital-FL comparison vs wall-clock
latency (N=10, per-scheme latency accounting, Sec. V-A-2 baselines).

Each scheme is charged its own per-round uplink latency (channel-capacity
based, as in the paper) and trained under a common wall-clock budget; the
comparison is accuracy/loss vs TIME, not rounds.
"""
from __future__ import annotations

import time

import numpy as np

from .common import (design_digital, digital_baseline_suite,
                     estimate_kappa_sc, log_to_dict, make_sc_setup,
                     run_tuned, save_result)


def run(quick: bool = True, n_devices: int = 10):
    t0 = time.time()
    budget_s = 40.0 if quick else 150.0
    max_rounds = 400 if quick else 1500
    trials = 2 if quick else 4
    task, ds, dep, eta_max = make_sc_setup(
        n_devices, samples_per_device=300 if quick else 1000,
        n_train_per_class=600 if quick else 1200)
    kappa = estimate_kappa_sc(task, ds)
    # batched jax design solver (core.sca_jax); solver="scipy" restores the
    # per-point SLSQP SCA oracle
    params, obj = design_digital(task, dep, eta_max, kappa_sc=kappa,
                                 t_max_s=0.2, solver="auto")
    params_d, obj_d = design_digital(task, dep, eta_max, kappa_sc=kappa,
                                     t_max_s=0.2, solver="direct")
    logs, rows = [], []
    suite = digital_baseline_suite(task, dep, params)
    from repro.core.baselines import ProposedDigital
    suite.insert(1, ProposedDigital(params_d,
                                    label="Proposed Digital FL (direct)"))
    etas = (1.0, 0.25) if quick else (1.0, 0.5, 0.25, 0.1)
    for agg in suite:
        t1 = time.time()
        log, best_eta = run_tuned(task, ds, dep, agg, eta_max=eta_max,
                                  rounds=max_rounds, trials=trials,
                                  eval_every=20, time_budget_s=budget_s,
                                  etas=etas)
        d = log_to_dict(log)
        d["eta"] = best_eta
        logs.append(d)
        rows.append((f"fig2_digital_sc/{agg.name}",
                     (time.time() - t1) * 1e6 / max(max_rounds * trials, 1),
                     f"final_acc={log.final_accuracy():.4f};eta={best_eta:.3f}"))
    payload = {"n_devices": n_devices, "budget_s": budget_s,
               "trials": trials, "kappa_sc": kappa,
               "design_objective": obj,
               "design_solver": "jax-batch",
               "design_objective_direct": obj_d, "eta_max": eta_max,
               "logs": logs, "elapsed_s": time.time() - t0}
    save_result("fig2_digital_sc", payload)
    return rows, payload
