"""Theorem 1 validation: the bias-variance trade-off curve.

Sweeps the design family between zero-bias (p=1/N) and min-noise (gamma =
gamma_max) anchors, and for each point compares

  * the Theorem-1 steady-state bound  2*N*kappa^2/mu^2 * sum(p-1/N)^2
                                      + 2*eta/mu * zeta(gamma)
  * the MEASURED steady-state optimality error E||w_t - w*||^2 (averaged
    over the tail rounds of a long run, MC over fading/noise)

The measured error must sit below the bound everywhere, and both should
exhibit the interior minimum that motivates the paper's joint design.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import baselines as B
from repro.core import ota, ota_design
from repro.core.bounds import ObjectiveWeights, bias_sum, theorem1_bound
from repro.fl.trainer import FLTrainer, solve_w_star
from .common import make_sc_setup, estimate_kappa_sc, save_result


def run(quick: bool = True, n_devices: int = 10):
    t0 = time.time()
    rounds = 120 if quick else 400
    trials = 2 if quick else 4
    tail = 3                      # eval points averaged for steady state
    task, ds, dep, eta_max = make_sc_setup(
        n_devices, samples_per_device=200 if quick else 1000,
        n_train_per_class=400 if quick else 1200)
    eta = 0.25 * eta_max
    kappa = estimate_kappa_sc(task, ds)
    w = ObjectiveWeights.strongly_convex(eta=eta, mu=task.mu,
                                         kappa_sc=kappa, n=n_devices)
    spec = ota_design.OTADesignSpec(
        lambdas=dep.lambdas, dim=task.dim, g_max=task.g_max,
        e_s=dep.cfg.energy_per_symbol, n0=dep.cfg.noise_power, weights=w)
    g_zb = ota_design.anchor_zero_bias(spec)      # p = 1/N
    g_mn = ota_design.anchor_min_noise(spec)      # min noise variance
    x_all = np.concatenate([d.x for d in ds.devices])
    y_all = np.concatenate([d.y for d in ds.devices])
    w_star = solve_w_star(task, x_all, y_all,
                          iters=1500 if quick else 4000)
    trainer = FLTrainer(task, ds, dep, eta=eta)

    rows, curve = [], []
    for lam in (0.0, 0.25, 0.5, 0.75, 1.0):
        gammas = (1 - lam) * g_zb + lam * g_mn
        params = ota_design.params_from_gamma(spec, gammas)
        p = params.participation_levels(dep.lambdas)
        zeta = ota.lemma1_variance(params, dep.lambdas)["total"]
        bound = theorem1_bound(rounds, eta=eta, mu=task.mu, diam=0.0,
                               kappa_sc=kappa, p=p, zeta=zeta)
        log = trainer.run(B.ProposedOTA(params, label=f"lam={lam}"),
                          rounds=rounds, trials=trials,
                          eval_every=rounds // 6, seed=3, w_star=w_star)
        measured = float(log.opt_error[:, -tail:].mean())
        curve.append({"lam": lam, "bias_sum": bias_sum(p), "zeta": zeta,
                      "bound_bias": bound["bias"],
                      "bound_var": bound["variance"],
                      "bound_total": bound["bias"] + bound["variance"],
                      "measured_err": measured})
        ok = measured <= bound["bias"] + bound["variance"] + 1e-6
        rows.append((f"theorem1/lam={lam}", measured * 1e6,
                     f"bound={bound['bias'] + bound['variance']:.1f};"
                     f"holds={ok}"))
    payload = {"eta": eta, "kappa_sc": kappa, "rounds": rounds,
               "curve": curve, "elapsed_s": time.time() - t0}
    save_result("theorem1_validation", payload)
    return rows, payload
