"""Shared benchmark scaffolding, thinned to delegates over ``repro.api``.

The per-figure pipeline logic (setup -> kappa -> design -> tuned runs ->
serialize) now lives in the declarative scenario layer
(``repro.api.materialize`` / ``repro.api.execute``); this module keeps the
benchmark-facing helpers — experiment setups mirroring Sec. V, design
routing for the engine benchmarks, and schema-stamped result saving — as
thin wrappers so the bench harnesses stay terse.
"""
from __future__ import annotations

from repro.api.materialize import (estimate_kappa_nc, estimate_kappa_sc,
                                   tune_and_run)
from repro.api.results import (DEFAULT_RESULTS_ROOT, SCHEMA_VERSION,
                               dump_json, log_record, result_payload)
from repro.core.channel import WirelessConfig, make_deployment
from repro.core.bounds import ObjectiveWeights
from repro.core import ota_design, digital_design
from repro.core import baselines as B
from repro.data.synthetic import SyntheticSpec, make_classification_dataset
from repro.data.partition import partition_by_class
from repro.data.loader import FLDataset
from repro.fl.tasks import SoftmaxRegressionTask, MLPTask

__all__ = [
    "RESULTS_DIR", "save_result", "log_to_dict", "figure_rows_and_logs",
    "result_payload", "make_sc_setup", "make_nc_setup",
    "estimate_kappa_sc", "estimate_kappa_nc", "design_ota",
    "design_ota_nc", "design_digital", "run_tuned", "ota_baseline_suite",
    "digital_baseline_suite",
]

# one results root for the whole repo (honors REPRO_RESULTS_DIR, like the
# scenario layer's ResultSet directories)
RESULTS_DIR = DEFAULT_RESULTS_ROOT


def save_result(name: str, payload: dict):
    """Write a schema-stamped payload through the strict encoder.

    Unknown object types raise (``repro.api.results.json_default``) —
    the legacy ``default=float`` silently coerced them.
    """
    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    payload = dict(payload)
    payload.setdefault("schema_version", SCHEMA_VERSION)
    (RESULTS_DIR / f"{name}.json").write_text(dump_json(payload))


def log_to_dict(log):
    return log_record(log)


def figure_rows_and_logs(name: str, cell: dict, *, per_call_denom: int):
    """Harness CSV rows + log records from one scenario-cell payload."""
    rows, logs = [], []
    for rec in cell["logs"]:
        logs.append(rec)
        rows.append((f"{name}/{rec['scheme']}",
                     rec["elapsed_s"] * 1e6 / per_call_denom,
                     f"final_acc={rec['acc_mean'][-1]:.4f};"
                     f"eta={rec['eta']:.3f}"))
    return rows, logs


def make_sc_setup(n_devices: int, *, samples_per_device: int = 1000,
                  seed: int = 1, data_seed: int = 3,
                  n_train_per_class: int = 1200):
    """Strongly convex setup (Sec. V-A): softmax regression, 1 class/device."""
    spec = SyntheticSpec(n_train_per_class=n_train_per_class,
                         n_test_per_class=200, noise_sigma=1.5)
    x_tr, y_tr, x_te, y_te = make_classification_dataset(spec)
    shards = partition_by_class(x_tr, y_tr, n_devices, 1, samples_per_device,
                                seed=data_seed)
    ds = FLDataset.from_shards(shards, x_te, y_te)
    task = SoftmaxRegressionTask(n_features=784, mu=0.01, g_max=20.0)
    dep = make_deployment(WirelessConfig(n_devices=n_devices, seed=seed))
    eta = 2.0 / (task.mu + task.smooth_l)
    return task, ds, dep, eta


def make_nc_setup(n_devices: int = 10, *, seed: int = 1):
    """Non-convex setup (Sec. V-B): MLP, 2 classes/device, cifar-like."""
    spec = SyntheticSpec(name="cifar-like", image_shape=(32, 32, 3),
                         n_train_per_class=120, n_test_per_class=100,
                         noise_sigma=1.8, seed=7)
    x_tr, y_tr, x_te, y_te = make_classification_dataset(spec)
    shards = partition_by_class(x_tr, y_tr, n_devices, 2, 100, seed=5)
    ds = FLDataset.from_shards(shards, x_te, y_te)
    task = MLPTask(n_features=3072, hidden=48, mu_nc=0.01, g_max=49.0)
    dep = make_deployment(WirelessConfig(n_devices=n_devices, seed=seed))
    eta = 0.08
    return task, ds, dep, eta


def _solve_ota_spec(spec, solver: str):
    """Route one OTA design spec: jax batch / SciPy SCA / SciPy direct.

    ``solver`` is one of "auto"/"jax" (batched ``core.sca_jax`` path,
    "auto" currently resolves to it), "sca"/"scipy" (the trusted SLSQP
    SCA oracle), or "direct" (L-BFGS-B on the gamma reduction).
    """
    if solver in ("jax", "auto"):
        params, objs = ota_design.design_ota_batch([spec])
        return params[0], float(objs[0])
    if solver == "direct":
        return ota_design.design_ota_direct(spec)
    if solver in ("sca", "scipy"):
        params, res = ota_design.design_ota_sca(spec, n_iters=8)
        return params, res.objective
    raise ValueError(f"unknown design solver {solver!r}")


def _solve_digital_spec(spec, solver: str):
    """Route one digital design spec; same solver names as the OTA router."""
    if solver in ("jax", "auto"):
        params, objs = digital_design.design_digital_batch([spec])
        return params[0], float(objs[0])
    if solver == "direct":
        return digital_design.design_digital_direct(spec)
    if solver in ("sca", "scipy"):
        params, res = digital_design.design_digital_sca(spec, n_iters=8)
        return params, res.objective
    raise ValueError(f"unknown design solver {solver!r}")


def design_ota(task, dep, eta, *, kappa_sc: float = 3.0, solver: str = "auto"):
    cfg = dep.cfg
    w = ObjectiveWeights.strongly_convex(eta=eta, mu=getattr(task, "mu", 0.01),
                                         kappa_sc=kappa_sc,
                                         n=dep.n_devices)
    spec = ota_design.OTADesignSpec(
        lambdas=dep.lambdas, dim=task.dim, g_max=task.g_max,
        e_s=cfg.energy_per_symbol, n0=cfg.noise_power, weights=w)
    return _solve_ota_spec(spec, solver)


def design_ota_nc(task, dep, eta, *, smooth_l: float = 10.0,
                  kappa_frac: float = 0.25, solver: str = "auto"):
    """Non-convex weights (footnote 4): (eta*L, N*kappa_nc^2)."""
    cfg = dep.cfg
    kappa_nc = kappa_frac * 2 * task.g_max
    w = ObjectiveWeights.non_convex(eta=eta, smooth_l=smooth_l,
                                    kappa_nc=kappa_nc, n=dep.n_devices)
    spec = ota_design.OTADesignSpec(
        lambdas=dep.lambdas, dim=task.dim, g_max=task.g_max,
        e_s=cfg.energy_per_symbol, n0=cfg.noise_power, weights=w)
    return _solve_ota_spec(spec, solver)


def design_digital(task, dep, eta, *, kappa_sc: float = 3.0,
                   t_max_s: float = 0.2, solver: str = "auto"):
    cfg = dep.cfg
    w = ObjectiveWeights.strongly_convex(eta=eta, mu=task.mu,
                                         kappa_sc=kappa_sc, n=dep.n_devices)
    spec = digital_design.DigitalDesignSpec(
        lambdas=dep.lambdas, dim=task.dim, g_max=task.g_max,
        e_s=cfg.energy_per_symbol, n0=cfg.noise_power,
        bandwidth_hz=cfg.bandwidth_hz, t_max_s=t_max_s, weights=w)
    return _solve_digital_spec(spec, solver)


def run_tuned(task, ds, dep, agg, *, eta_max, rounds, trials, eval_every,
              seed=5, time_budget_s=None, etas=(1.0, 0.5, 0.25, 0.1),
              backend="auto"):
    """Per-scheme step-size grid search + full MC run (now the scenario
    layer's ``tune_and_run``; kept as the benchmark-facing name)."""
    return tune_and_run(task, ds, dep, agg, eta_max=eta_max, rounds=rounds,
                        trials=trials, eval_every=eval_every, seed=seed,
                        time_budget_s=time_budget_s, etas=etas,
                        backend=backend)


def ota_baseline_suite(task, dep, ota_params):
    """All Sec. V-A-1 OTA schemes, proposed first."""
    cfg = dep.cfg
    d, G = task.dim, task.g_max
    es, n0 = cfg.energy_per_symbol, cfg.noise_power
    return [
        B.IdealFedAvg(),
        B.ProposedOTA(ota_params),
        B.OPCOTAFL(d, G, es, n0),
        B.OPCOTAComp(d, G, es, n0),
        B.LCPCOTAComp(dep, d, G, es, n0),
        B.VanillaOTA(d, G, es, n0),
        B.BBFLInterior(dep, d, G, es, n0),
        B.BBFLAlternative(dep, d, G, es, n0),
    ]


def digital_baseline_suite(task, dep, dig_params, *, k: int = 4):
    cfg = dep.cfg
    d, G = task.dim, task.g_max
    es, n0, bw = cfg.energy_per_symbol, cfg.noise_power, cfg.bandwidth_hz
    return [
        B.ProposedDigital(dig_params),
        B.FedTOE(dep, d, G, es, n0, bw, k=k),
        B.PropFairness(dep, d, G, es, n0, bw, k=k),
        B.BestChannelNorm(dep, d, G, es, n0, bw, k=k),
        B.BestChannel(dep, d, G, es, n0, bw, k=k),
        B.UQOS(dep, d, G, es, n0, bw, k=k),
        B.QML(dep, d, G, es, n0, bw, k=k),
    ]
