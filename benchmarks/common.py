"""Shared benchmark scaffolding: experiment setups mirroring Sec. V."""
from __future__ import annotations

import dataclasses
import json
import time
from pathlib import Path

import numpy as np

from repro.core.channel import WirelessConfig, make_deployment
from repro.core.bounds import ObjectiveWeights
from repro.core import ota_design, digital_design
from repro.core import baselines as B
from repro.data.synthetic import SyntheticSpec, make_classification_dataset
from repro.data.partition import partition_by_class
from repro.data.loader import FLDataset
from repro.fl.tasks import SoftmaxRegressionTask, MLPTask
from repro.fl.trainer import FLTrainer

RESULTS_DIR = Path(__file__).resolve().parents[1] / "experiments" / "results"


def save_result(name: str, payload: dict):
    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    (RESULTS_DIR / f"{name}.json").write_text(
        json.dumps(payload, indent=1, default=float))


def log_to_dict(log):
    d = {
        "scheme": log.scheme,
        "rounds": log.rounds.tolist(),
        "wall_time_s": np.asarray(log.wall_time_s).tolist(),
        "loss_mean": log.global_loss.mean(0).tolist(),
        "loss_std": log.global_loss.std(0).tolist(),
        "acc_mean": log.accuracy.mean(0).tolist(),
        "acc_std": log.accuracy.std(0).tolist(),
    }
    if log.opt_error is not None:
        d["opt_err_mean"] = log.opt_error.mean(0).tolist()
    return d


def make_sc_setup(n_devices: int, *, samples_per_device: int = 1000,
                  seed: int = 1, data_seed: int = 3,
                  n_train_per_class: int = 1200):
    """Strongly convex setup (Sec. V-A): softmax regression, 1 class/device."""
    spec = SyntheticSpec(n_train_per_class=n_train_per_class,
                         n_test_per_class=200, noise_sigma=1.5)
    x_tr, y_tr, x_te, y_te = make_classification_dataset(spec)
    shards = partition_by_class(x_tr, y_tr, n_devices, 1, samples_per_device,
                                seed=data_seed)
    ds = FLDataset.from_shards(shards, x_te, y_te)
    task = SoftmaxRegressionTask(n_features=784, mu=0.01, g_max=20.0)
    dep = make_deployment(WirelessConfig(n_devices=n_devices, seed=seed))
    eta = 2.0 / (task.mu + task.smooth_l)
    return task, ds, dep, eta


def make_nc_setup(n_devices: int = 10, *, seed: int = 1):
    """Non-convex setup (Sec. V-B): MLP, 2 classes/device, cifar-like."""
    spec = SyntheticSpec(name="cifar-like", image_shape=(32, 32, 3),
                         n_train_per_class=120, n_test_per_class=100,
                         noise_sigma=1.8, seed=7)
    x_tr, y_tr, x_te, y_te = make_classification_dataset(spec)
    shards = partition_by_class(x_tr, y_tr, n_devices, 2, 100, seed=5)
    ds = FLDataset.from_shards(shards, x_te, y_te)
    task = MLPTask(n_features=3072, hidden=48, mu_nc=0.01, g_max=49.0)
    dep = make_deployment(WirelessConfig(n_devices=n_devices, seed=seed))
    eta = 0.08
    return task, ds, dep, eta


def estimate_kappa_sc(task, ds, iters: int = 1500) -> float:
    """kappa_sc^2 = (1/N) sum ||grad f_m(w*)||^2, with w* from full GD.

    The paper treats kappa as a known constant of the task (Fig. 2 uses 3
    for their MNIST); we estimate it on the synthetic data so the design
    weights (omega_bias) match the actual heterogeneity.
    """
    from repro.fl.trainer import solve_w_star
    x_all = np.concatenate([d.x for d in ds.devices])
    y_all = np.concatenate([d.y for d in ds.devices])
    w_star = solve_w_star(task, x_all, y_all, iters=iters)
    xs = np.stack([d.x for d in ds.devices])
    ys = np.stack([d.y for d in ds.devices])
    g = task.device_grads(w_star, xs, ys)
    return float(np.sqrt(np.mean(np.linalg.norm(g, axis=1) ** 2)))


def estimate_kappa_nc(task, ds, n_probes: int = 3) -> float:
    """kappa_nc: gradient dissimilarity max over a few probe points."""
    xs = np.stack([d.x for d in ds.devices])
    ys = np.stack([d.y for d in ds.devices])
    worst = 0.0
    for i in range(n_probes):
        w = task.init_params(seed=100 + i)
        g = task.device_grads(w, xs, ys)
        gbar = g.mean(axis=0, keepdims=True)
        worst = max(worst, float(np.sqrt(
            np.mean(np.sum((g - gbar) ** 2, axis=1)))))
    return worst


def _solve_ota_spec(spec, solver: str):
    """Route one OTA design spec: jax batch / SciPy SCA / SciPy direct.

    ``solver`` is one of "auto"/"jax" (batched ``core.sca_jax`` path,
    "auto" currently resolves to it), "sca"/"scipy" (the trusted SLSQP
    SCA oracle), or "direct" (L-BFGS-B on the gamma reduction).
    """
    if solver in ("jax", "auto"):
        params, objs = ota_design.design_ota_batch([spec])
        return params[0], float(objs[0])
    if solver == "direct":
        return ota_design.design_ota_direct(spec)
    if solver in ("sca", "scipy"):
        params, res = ota_design.design_ota_sca(spec, n_iters=8)
        return params, res.objective
    raise ValueError(f"unknown design solver {solver!r}")


def _solve_digital_spec(spec, solver: str):
    """Route one digital design spec; same solver names as the OTA router."""
    if solver in ("jax", "auto"):
        params, objs = digital_design.design_digital_batch([spec])
        return params[0], float(objs[0])
    if solver == "direct":
        return digital_design.design_digital_direct(spec)
    if solver in ("sca", "scipy"):
        params, res = digital_design.design_digital_sca(spec, n_iters=8)
        return params, res.objective
    raise ValueError(f"unknown design solver {solver!r}")


def design_ota(task, dep, eta, *, kappa_sc: float = 3.0, solver: str = "auto"):
    cfg = dep.cfg
    w = ObjectiveWeights.strongly_convex(eta=eta, mu=getattr(task, "mu", 0.01),
                                         kappa_sc=kappa_sc,
                                         n=dep.n_devices)
    spec = ota_design.OTADesignSpec(
        lambdas=dep.lambdas, dim=task.dim, g_max=task.g_max,
        e_s=cfg.energy_per_symbol, n0=cfg.noise_power, weights=w)
    return _solve_ota_spec(spec, solver)


def design_ota_nc(task, dep, eta, *, smooth_l: float = 10.0,
                  kappa_frac: float = 0.25, solver: str = "auto"):
    """Non-convex weights (footnote 4): (eta*L, N*kappa_nc^2)."""
    cfg = dep.cfg
    kappa_nc = kappa_frac * 2 * task.g_max
    w = ObjectiveWeights.non_convex(eta=eta, smooth_l=smooth_l,
                                    kappa_nc=kappa_nc, n=dep.n_devices)
    spec = ota_design.OTADesignSpec(
        lambdas=dep.lambdas, dim=task.dim, g_max=task.g_max,
        e_s=cfg.energy_per_symbol, n0=cfg.noise_power, weights=w)
    return _solve_ota_spec(spec, solver)


def design_digital(task, dep, eta, *, kappa_sc: float = 3.0,
                   t_max_s: float = 0.2, solver: str = "auto"):
    cfg = dep.cfg
    w = ObjectiveWeights.strongly_convex(eta=eta, mu=task.mu,
                                         kappa_sc=kappa_sc, n=dep.n_devices)
    spec = digital_design.DigitalDesignSpec(
        lambdas=dep.lambdas, dim=task.dim, g_max=task.g_max,
        e_s=cfg.energy_per_symbol, n0=cfg.noise_power,
        bandwidth_hz=cfg.bandwidth_hz, t_max_s=t_max_s, weights=w)
    return _solve_digital_spec(spec, solver)


def run_tuned(task, ds, dep, agg, *, eta_max, rounds, trials, eval_every,
              seed=5, time_budget_s=None, etas=(1.0, 0.5, 0.25, 0.1),
              backend="auto"):
    """Per-scheme step-size grid search (paper Sec. V: 'step sizes for all
    schemes are tuned via a small grid search'), then the full MC run.

    ``backend="auto"`` routes every scheme through the JAX engine (all 14
    baselines have ports) unless a time budget forces the NumPy loop.
    """
    best_eta, best_acc = None, -1.0
    for frac in etas:
        tr = FLTrainer(task, ds, dep, eta=frac * eta_max)
        probe = tr.run(agg, rounds=rounds, trials=1,
                       eval_every=max(rounds // 4, 1), seed=seed + 91,
                       time_budget_s=time_budget_s, backend=backend)
        acc = float(probe.accuracy[:, -2:].mean())   # 2-pt avg vs MC noise
        if acc > best_acc:
            best_acc, best_eta = acc, frac * eta_max
    tr = FLTrainer(task, ds, dep, eta=best_eta)
    log = tr.run(agg, rounds=rounds, trials=trials, eval_every=eval_every,
                 seed=seed, time_budget_s=time_budget_s, backend=backend)
    return log, best_eta


def ota_baseline_suite(task, dep, ota_params):
    """All Sec. V-A-1 OTA schemes, proposed first."""
    cfg = dep.cfg
    d, G = task.dim, task.g_max
    es, n0 = cfg.energy_per_symbol, cfg.noise_power
    return [
        B.IdealFedAvg(),
        B.ProposedOTA(ota_params),
        B.OPCOTAFL(d, G, es, n0),
        B.OPCOTAComp(d, G, es, n0),
        B.LCPCOTAComp(dep, d, G, es, n0),
        B.VanillaOTA(d, G, es, n0),
        B.BBFLInterior(dep, d, G, es, n0),
        B.BBFLAlternative(dep, d, G, es, n0),
    ]


def digital_baseline_suite(task, dep, dig_params, *, k: int = 4):
    cfg = dep.cfg
    d, G = task.dim, task.g_max
    es, n0, bw = cfg.energy_per_symbol, cfg.noise_power, cfg.bandwidth_hz
    return [
        B.ProposedDigital(dig_params),
        B.FedTOE(dep, d, G, es, n0, bw, k=k),
        B.PropFairness(dep, d, G, es, n0, bw, k=k),
        B.BestChannelNorm(dep, d, G, es, n0, bw, k=k),
        B.BestChannel(dep, d, G, es, n0, bw, k=k),
        B.UQOS(dep, d, G, es, n0, bw, k=k),
        B.QML(dep, d, G, es, n0, bw, k=k),
    ]
