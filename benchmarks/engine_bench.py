"""NumPy-vs-JAX FL engine wall-clock benchmark (ROADMAP north-star check).

Runs the same Monte-Carlo FL workload through both ``FLTrainer`` backends —
the Python-loop NumPy reference and the vmap/scan JAX engine (Pallas
epilogue kernels, interpret mode on CPU) — and reports wall-clock plus the
steady-state speedup. Both backends replay identical random streams, so the
max trajectory deviation is recorded as a built-in parity check.

    PYTHONPATH=src python -m benchmarks.engine_bench [--smoke]

Writes experiments/results/engine_bench.json.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from .common import (design_digital, design_ota, make_sc_setup, save_result)
from repro.core import baselines as B
from repro.fl.trainer import FLTrainer


def _time_backend(trainer, agg, backend, *, rounds, trials, eval_every,
                  seed, repeats=1):
    best, log = np.inf, None
    for _ in range(repeats):
        t0 = time.perf_counter()
        log = trainer.run(agg, rounds=rounds, trials=trials,
                          eval_every=eval_every, seed=seed, backend=backend)
        best = min(best, time.perf_counter() - t0)
    return best, log


def run(quick: bool = True, *, n_devices: int = 20, trials: int = 3,
        rounds: int = 200, samples_per_device: int = 1000):
    """Benchmark entry (also wired into benchmarks.run).

    Defaults are a fig2-sized run: N=20 devices, 3 Monte-Carlo trials, 200
    rounds on the strongly convex softmax task at the paper protocol's
    1000 samples/device (``make_sc_setup`` default). ``quick`` keeps that;
    full mode doubles the horizon.
    """
    if not quick:
        rounds *= 2
    eval_every = max(rounds // 20, 1) * 2
    task, ds, dep, eta_max = make_sc_setup(
        n_devices, samples_per_device=samples_per_device,
        n_train_per_class=max((n_devices * samples_per_device) // 10, 200))
    eta = 0.25 * eta_max
    params, _ = design_ota(task, dep, eta)
    dig_params, _ = design_digital(task, dep, eta)
    trainer = FLTrainer(task, ds, dep, eta=eta)

    suite = [
        ("proposed_ota", B.ProposedOTA(params), rounds),
        ("vanilla_ota", B.VanillaOTA(task.dim, task.g_max,
                                     dep.cfg.energy_per_symbol,
                                     dep.cfg.noise_power), rounds),
        # digital replays one (T, N, d) dither tensor per trial; keep its
        # horizon shorter so the benchmark stays laptop-sized
        ("proposed_digital", B.ProposedDigital(dig_params), max(rounds // 4, 1)),
    ]
    # warm the task's jitted grad/loss functions once so the NumPy timing
    # measures the backend, not shared first-call compilation
    trainer.run(suite[0][1], rounds=2, trials=1, eval_every=1, seed=1,
                backend="numpy")
    rows, results = [], []
    for key, agg, t_rounds in suite:
        t_np, log_np = _time_backend(trainer, agg, "numpy", rounds=t_rounds,
                                     trials=trials, eval_every=eval_every,
                                     seed=5)
        t_cold, _ = _time_backend(trainer, agg, "jax", rounds=t_rounds,
                                  trials=trials, eval_every=eval_every,
                                  seed=5)
        t_warm, log_jx = _time_backend(trainer, agg, "jax", rounds=t_rounds,
                                       trials=trials, eval_every=eval_every,
                                       seed=5, repeats=2)
        dev = float(np.max(np.abs(log_np.global_loss - log_jx.global_loss)))
        res = {
            "scheme": agg.name, "rounds": t_rounds, "trials": trials,
            "n_devices": n_devices, "dim": task.dim,
            "numpy_s": t_np, "jax_cold_s": t_cold, "jax_warm_s": t_warm,
            "speedup_warm": t_np / t_warm, "speedup_cold": t_np / t_cold,
            "max_loss_deviation": dev,
        }
        results.append(res)
        rows.append((f"engine_bench/{key}",
                     t_warm * 1e6 / max(t_rounds * trials, 1),
                     f"speedup={res['speedup_warm']:.1f}x;parity={dev:.1e}"))
    payload = {"quick": quick, "results": results}
    save_result("engine_bench", payload)
    return rows, payload


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes for CI (N=10, 2 trials, 40 rounds)")
    args = ap.parse_args()
    if args.smoke:
        rows, payload = run(quick=True, n_devices=10, trials=2, rounds=40,
                            samples_per_device=100)
    else:
        rows, payload = run(quick=True)
    print("scheme,backend=numpy[s],jax_cold[s],jax_warm[s],speedup,parity")
    for r in payload["results"]:
        print(f"{r['scheme']},{r['numpy_s']:.3f},{r['jax_cold_s']:.3f},"
              f"{r['jax_warm_s']:.3f},{r['speedup_warm']:.1f}x,"
              f"{r['max_loss_deviation']:.1e}")
    worst = min(r["speedup_warm"] for r in payload["results"][:2])
    print(f"min OTA steady-state speedup: {worst:.1f}x")


if __name__ == "__main__":
    main()
