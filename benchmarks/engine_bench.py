"""NumPy-vs-JAX FL engine wall-clock benchmark (ROADMAP north-star check).

Runs the same Monte-Carlo FL workload through both ``FLTrainer`` backends —
the Python-loop NumPy reference and the vmap/scan JAX engine (Pallas
epilogue kernels, interpret mode on CPU) — and reports wall-clock plus the
steady-state speedup, for the OTA schemes AND the digital selection suite
(top-K / bit-allocation schemes run as jittable ops since the full-coverage
port). Both backends replay identical random streams, so the max trajectory
deviation is recorded as a built-in parity check.

    PYTHONPATH=src python -m benchmarks.engine_bench [--smoke] [--minibatch]

Writes experiments/results/engine_bench.json.

``--minibatch`` benchmarks the SGD regime (counter-based batch indices
regenerated in-scan) plus a time-budgeted run — the two options that used
to force the NumPy fallback. Writes
experiments/results/engine_bench_minibatch.json.

``--digital-long`` runs the 1500-round digital horizon through the engine
alone and records wall-clock + peak RSS — the O(N*d) streaming-dither
memory proof (the retired (trials, T, N, d) dither tensor would add
trials*T*N*d*8 bytes on top). ``--rss-budget-mb`` turns it into a CI guard
(exit 1 on budget overrun; used by scripts/verify.sh). Writes
experiments/results/engine_bench_digital.json.

``--scale`` runs the ``rng="fast"`` population-scale grid (N up to 1024
devices at the fig2 model dimension, zero host-side RNG precompute) plus
the fig2-sized replay-vs-fast speedup record; honors ``--rss-budget-mb``
and writes the schema-stamped perf trajectory to the repo-root
``BENCH_engine_scale.json`` (tracked across PRs, unlike the
experiments/results artifacts).
"""
from __future__ import annotations

import argparse
import resource
import sys
import time

import numpy as np

from .common import (design_digital, design_ota, dump_json, make_sc_setup,
                     result_payload, save_result)
from repro.core import baselines as B
from repro.fl.trainer import FLTrainer


def _time_backend(trainer, agg, backend, *, rounds, trials, eval_every,
                  seed, repeats=1, rng="replay"):
    best, log = np.inf, None
    for _ in range(repeats):
        t0 = time.perf_counter()
        log = trainer.run(agg, rounds=rounds, trials=trials,
                          eval_every=eval_every, seed=seed, backend=backend,
                          rng=rng)
        best = min(best, time.perf_counter() - t0)
    return best, log


def _time_suite(trainer, suite, *, trials, eval_every, seed=5,
                row_prefix="engine_bench", extra=None):
    """Time every (key, aggregator, rounds) suite entry through both
    backends (numpy / jax cold / jax warm) with the built-in trajectory
    parity check; returns the harness CSV rows and the JSON result dicts.
    ``extra`` merges additional fields (e.g. batch_size) into each dict."""
    # warm the task's jitted grad/loss functions once so the NumPy timing
    # measures the backend, not shared first-call compilation
    trainer.run(suite[0][1], rounds=2, trials=1, eval_every=1, seed=1,
                backend="numpy")
    task, dep = trainer.task, trainer.dep
    rows, results = [], []
    for key, agg, t_rounds in suite:
        t_np, log_np = _time_backend(trainer, agg, "numpy", rounds=t_rounds,
                                     trials=trials, eval_every=eval_every,
                                     seed=seed)
        t_cold, _ = _time_backend(trainer, agg, "jax", rounds=t_rounds,
                                  trials=trials, eval_every=eval_every,
                                  seed=seed)
        t_warm, log_jx = _time_backend(trainer, agg, "jax", rounds=t_rounds,
                                       trials=trials, eval_every=eval_every,
                                       seed=seed, repeats=2)
        dev = float(np.max(np.abs(log_np.global_loss - log_jx.global_loss)))
        res = {
            "scheme": agg.name, "rounds": t_rounds, "trials": trials,
            "n_devices": dep.n_devices, "dim": task.dim,
            "numpy_s": t_np, "jax_cold_s": t_cold, "jax_warm_s": t_warm,
            "speedup_warm": t_np / t_warm, "speedup_cold": t_np / t_cold,
            "max_loss_deviation": dev,
            **(extra or {}),
        }
        results.append(res)
        rows.append((f"{row_prefix}/{key}",
                     t_warm * 1e6 / max(t_rounds * trials, 1),
                     f"speedup={res['speedup_warm']:.1f}x;parity={dev:.1e}"))
    return rows, results


def run(quick: bool = True, *, n_devices: int = 20, trials: int = 3,
        rounds: int = 200, samples_per_device: int = 1000,
        result_name: str = "engine_bench"):
    """Benchmark entry (also wired into benchmarks.run).

    Defaults are a fig2-sized run: N=20 devices, 3 Monte-Carlo trials, 200
    rounds on the strongly convex softmax task at the paper protocol's
    1000 samples/device (``make_sc_setup`` default). ``quick`` keeps that;
    full mode doubles the horizon.
    """
    if not quick:
        rounds *= 2
    eval_every = max(rounds // 20, 1) * 2
    task, ds, dep, eta_max = make_sc_setup(
        n_devices, samples_per_device=samples_per_device,
        n_train_per_class=max((n_devices * samples_per_device) // 10, 200))
    eta = 0.25 * eta_max
    params, _ = design_ota(task, dep, eta)
    dig_params, _ = design_digital(task, dep, eta)
    trainer = FLTrainer(task, ds, dep, eta=eta)

    cfg = dep.cfg
    wargs = (task.dim, task.g_max, cfg.energy_per_symbol, cfg.noise_power)
    # NumPy quantize loop dominates; keep the digital horizons laptop-sized.
    # Snap to the eval grid: the engine only simulates rounds up to the last
    # eval point, so a non-multiple horizon would bill the NumPy backend for
    # rounds the engine never runs and inflate the speedup.
    dig_rounds = max((rounds // 4 // eval_every) * eval_every, eval_every)
    suite = [
        ("proposed_ota", B.ProposedOTA(params), rounds),
        ("vanilla_ota", B.VanillaOTA(*wargs), rounds),
        ("opc_ota_fl", B.OPCOTAFL(*wargs), rounds),
        ("bbfl_alternative", B.BBFLAlternative(dep, *wargs), rounds),
        ("proposed_digital", B.ProposedDigital(dig_params), dig_rounds),
        ("best_channel", B.BestChannel(dep, *wargs, cfg.bandwidth_hz),
         dig_rounds),
        ("uqos", B.UQOS(dep, *wargs, cfg.bandwidth_hz), dig_rounds),
        ("fedtoe", B.FedTOE(dep, *wargs, cfg.bandwidth_hz), dig_rounds),
    ]
    rows, results = _time_suite(trainer, suite, trials=trials,
                                eval_every=eval_every)
    payload = result_payload("engine_bench", quick=quick, results=results)
    save_result(result_name, payload)
    return rows, payload


def run_minibatch(quick: bool = True, *, n_devices: int = 20, trials: int = 3,
                  rounds: int = 200, batch_size: int = 64,
                  samples_per_device: int = 1000,
                  result_name: str = "engine_bench_minibatch"):
    """Mini-batch (SGD) engine-vs-NumPy benchmark.

    Stochastic device gradients are the regime the engine used to punt to
    the NumPy oracle; since the counter-based batch-sampler port it runs
    in-scan ((N, B) index blocks regenerated per round from a scan-carried
    threefry key, gathered through the task's device_grads_at path).
    Records the wall-clock gap and the built-in trajectory-parity check,
    plus one time-budgeted engine run exercising the in-scan freeze mask.
    Writes experiments/results/engine_bench_minibatch.json.
    """
    if not quick:
        rounds *= 2
    eval_every = max(rounds // 20, 1) * 2
    task, ds, dep, eta_max = make_sc_setup(
        n_devices, samples_per_device=samples_per_device,
        n_train_per_class=max((n_devices * samples_per_device) // 10, 200))
    eta = 0.25 * eta_max
    params, _ = design_ota(task, dep, eta)
    dig_params, _ = design_digital(task, dep, eta)
    trainer = FLTrainer(task, ds, dep, eta=eta,
                        batch_size=min(batch_size, samples_per_device))

    cfg = dep.cfg
    wargs = (task.dim, task.g_max, cfg.energy_per_symbol, cfg.noise_power)
    dig_rounds = max((rounds // 4 // eval_every) * eval_every, eval_every)
    suite = [
        ("proposed_ota", B.ProposedOTA(params), rounds),
        ("vanilla_ota", B.VanillaOTA(*wargs), rounds),
        ("proposed_digital", B.ProposedDigital(dig_params), dig_rounds),
        ("best_channel", B.BestChannel(dep, *wargs, cfg.bandwidth_hz),
         dig_rounds),
    ]
    rows, results = _time_suite(trainer, suite, trials=trials,
                                eval_every=eval_every,
                                row_prefix="engine_bench_minibatch",
                                extra={"batch_size": trainer.batch_size})
    # in-scan time-budget path: freeze after ~60% of the horizon's airtime
    agg = suite[1][1]
    budget = 0.6 * rounds * task.dim / cfg.bandwidth_hz
    t0 = time.perf_counter()
    log_b = trainer.run(agg, rounds=rounds, trials=trials,
                        eval_every=eval_every, seed=5,
                        time_budget_s=budget, backend="jax")
    t_budget = time.perf_counter() - t0
    payload = result_payload(
        "engine_bench_minibatch", quick=quick,
        batch_size=trainer.batch_size, results=results,
        time_budget_run={
            "scheme": agg.name, "rounds": rounds, "trials": trials,
            "time_budget_s": budget, "jax_s": t_budget,
            "frozen_wall_s": float(np.asarray(log_b.wall_time_s)[-1]),
        })
    save_result(result_name, payload)
    return rows, payload


def run_digital_long(*, rounds: int = 1500, trials: int = 1,
                     n_devices: int = 20, eval_every: int = 100):
    """1500-round digital horizon, engine-only, with the peak-RSS record.

    The engine streams dither from scan-carried keys (O(N*d) per round);
    this run is infeasible at the old materialized-dither design, whose
    (trials, T, N, d) tensor alone would add ``dither_tensor_mb`` on top of
    the measured peak.
    """
    task, ds, dep, eta_max = make_sc_setup(
        n_devices, samples_per_device=1000,
        n_train_per_class=max(n_devices * 100, 200))
    eta = 0.25 * eta_max
    dig_params, _ = design_digital(task, dep, eta)
    trainer = FLTrainer(task, ds, dep, eta=eta)
    results = []
    for key, agg in (("proposed_digital", B.ProposedDigital(dig_params)),
                     ("fedtoe", B.FedTOE(dep, task.dim, task.g_max,
                                         dep.cfg.energy_per_symbol,
                                         dep.cfg.noise_power,
                                         dep.cfg.bandwidth_hz))):
        t0 = time.perf_counter()
        log = trainer.run(agg, rounds=rounds, trials=trials,
                          eval_every=eval_every, seed=5, backend="jax")
        elapsed = time.perf_counter() - t0
        results.append({
            "scheme": agg.name, "key": key, "rounds": rounds,
            "trials": trials, "n_devices": n_devices, "dim": task.dim,
            "jax_s": elapsed,
            "rounds_per_s": rounds * trials / elapsed,
            "final_loss": float(log.global_loss[:, -1].mean()),
            "final_acc": float(log.accuracy[:, -1].mean()),
        })
    peak_rss_mb = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0
    dither_tensor_mb = trials * rounds * n_devices * task.dim * 8 / 2 ** 20
    payload = result_payload(
        "engine_bench_digital", results=results, peak_rss_mb=peak_rss_mb,
        retired_dither_tensor_mb=dither_tensor_mb,
        streamed_dither_mb_per_round=n_devices * task.dim * 4 / 2 ** 20)
    save_result("engine_bench_digital", payload)
    return payload


def run_scale(quick: bool = True, *, n_grid=None, rounds: int = 30,
              trials: int = 1, samples_per_device: int = 50,
              fig2_rounds: int = 200, fig2_trials: int = 8,
              rss_budget_mb=None):
    """Population-scale fast-RNG benchmark -> top-level BENCH_engine_scale.json.

    Two measurements behind the ``rng="fast"`` mode (counter-based
    threefry streams generated in-scan, zero host-side per-trial
    precompute):

    1. **Scale grid** — N up to 1024 devices at the fig2 model dimension
       (d = 7850) through the engine in fast mode, with the cumulative
       peak-RSS record. Replay mode would precompute a (trials, T, d)
       AWGN block plus a (trials, T, N) fading tensor per run
       (``replay_host_mb`` records what each point dodges); fast mode
       carries three (2,)-uint32 keys per trial. Non-designed OTA
       schemes (VanillaOTA / OPC-OTA-FL) so the grid never waits on an
       N=1024 design solve nor on the interpret-mode quantize kernel.
       A population-scale partial-participation cell (N=2000 devices,
       expected cohort S=64 via ``core.participation``) rides along as
       ``participation_scale`` — the scenario the 2 GB RSS guard covers.
    2. **fig2-scale replay-vs-fast** — the same fig2-sized workload
       (N=20, d=7850) end-to-end in both modes; the recorded
       ``speedup_fast`` is the perf trajectory tracked across PRs. On
       CPU the scan dominates this horizon, so the honest number here is
       modest — the scaling win is the grid above, where replay's host
       tensors would grow with trials*T*(d+N) and fast mode's stay O(1).

    The payload is schema-stamped (``result_payload``) and written to the
    repo root — not ``experiments/results`` — so the perf trajectory is
    versioned next to the code. ``rss_budget_mb`` is recorded in the
    payload; ``main()`` enforces it (exit 1 on overrun — the
    scripts/verify.sh CI guard).
    """
    from pathlib import Path

    if n_grid is None:
        n_grid = (256, 1024) if quick else (128, 256, 512, 1024)
    if quick:
        fig2_rounds, fig2_trials = min(fig2_rounds, 120), min(fig2_trials, 6)
    eval_every = max(rounds // 2, 1)
    scale_results = []
    for n_devices in n_grid:
        task, ds, dep, eta_max = make_sc_setup(
            n_devices, samples_per_device=samples_per_device,
            n_train_per_class=max((n_devices * samples_per_device) // 10,
                                  200))
        eta = 0.25 * eta_max
        cfg = dep.cfg
        wargs = (task.dim, task.g_max, cfg.energy_per_symbol,
                 cfg.noise_power)
        trainer = FLTrainer(task, ds, dep, eta=eta)
        for key, agg in (("vanilla_ota", B.VanillaOTA(*wargs)),
                         ("opc_ota_fl", B.OPCOTAFL(*wargs))):
            t_cold, _ = _time_backend(trainer, agg, "jax", rounds=rounds,
                                      trials=trials, eval_every=eval_every,
                                      seed=5, rng="fast")
            t_warm, log = _time_backend(trainer, agg, "jax", rounds=rounds,
                                        trials=trials, eval_every=eval_every,
                                        seed=5, rng="fast")
            peak = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0
            scale_results.append({
                "scheme": agg.name, "key": key, "n_devices": n_devices,
                "dim": task.dim, "rounds": rounds, "trials": trials,
                "jax_cold_s": t_cold, "jax_warm_s": t_warm,
                "rounds_per_s": rounds * trials / t_warm,
                "final_loss": float(log.global_loss[:, -1].mean()),
                "peak_rss_mb": peak,
                # what replay mode would have materialized host-side for
                # this run: (trials, T, d) float64 AWGN + (trials, T, N)
                # complex128 fading
                "replay_host_mb": trials * rounds *
                    (task.dim * 8 + n_devices * 16) / 2 ** 20,
            })
        del trainer, task, ds, dep

    # population-scale partial participation: N=2000 devices, an expected
    # cohort of S=64 per round (core.participation), fast counter streams
    # — the cell the 2 GB RSS guard covers. The participation mask is a
    # trace-time-static (N,) Bernoulli draw + scale inside the scan, so
    # its memory footprint stays O(N) regardless of rounds/trials.
    part_n, part_s = 2000, 64
    task, ds, dep, eta_max = make_sc_setup(
        part_n, samples_per_device=20,
        n_train_per_class=max((part_n * 20) // 10, 200))
    cfg = dep.cfg
    agg = B.VanillaOTA(task.dim, task.g_max, cfg.energy_per_symbol,
                       cfg.noise_power)
    trainer = FLTrainer(task, ds, dep, eta=0.25 * eta_max,
                        clients_per_round=part_s)
    t_cold, _ = _time_backend(trainer, agg, "jax", rounds=rounds,
                              trials=trials, eval_every=eval_every,
                              seed=5, rng="fast")
    t_warm, log = _time_backend(trainer, agg, "jax", rounds=rounds,
                                trials=trials, eval_every=eval_every,
                                seed=5, rng="fast")
    participation_scale = {
        "scheme": agg.name, "key": "vanilla_ota",
        "n_devices": part_n, "clients_per_round": part_s,
        "participation": "uniform", "dim": task.dim,
        "samples_per_device": 20, "rounds": rounds, "trials": trials,
        "jax_cold_s": t_cold, "jax_warm_s": t_warm,
        "rounds_per_s": rounds * trials / t_warm,
        "final_loss": float(log.global_loss[:, -1].mean()),
        "peak_rss_mb":
            resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0,
    }
    del trainer, task, ds, dep

    # fig2-scale end-to-end: replay's per-trial host precompute + transfer
    # vs fast's in-scan streams, same scheme, same horizon
    task, ds, dep, eta_max = make_sc_setup(20, samples_per_device=1000,
                                           n_train_per_class=2000)
    cfg = dep.cfg
    agg = B.VanillaOTA(task.dim, task.g_max, cfg.energy_per_symbol,
                       cfg.noise_power)
    trainer = FLTrainer(task, ds, dep, eta=0.25 * eta_max)
    fig2_eval = max(fig2_rounds // 10, 1)
    t_replay, _ = _time_backend(trainer, agg, "jax", rounds=fig2_rounds,
                                trials=fig2_trials, eval_every=fig2_eval,
                                seed=5, repeats=3, rng="replay")
    t_fast, _ = _time_backend(trainer, agg, "jax", rounds=fig2_rounds,
                              trials=fig2_trials, eval_every=fig2_eval,
                              seed=5, repeats=3, rng="fast")
    peak_rss_mb = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0
    payload = result_payload(
        "engine_bench_scale", quick=quick,
        scale={"samples_per_device": samples_per_device,
               "n_grid": list(n_grid), "results": scale_results},
        participation_scale=participation_scale,
        fig2_speedup={
            "scheme": agg.name, "n_devices": 20, "dim": task.dim,
            "rounds": fig2_rounds, "trials": fig2_trials,
            "replay_warm_s": t_replay, "fast_warm_s": t_fast,
            "speedup_fast": t_replay / t_fast,
            "replay_host_mb": fig2_trials * fig2_rounds *
                (task.dim * 8 + 20 * 16) / 2 ** 20,
        },
        peak_rss_mb=peak_rss_mb, rss_budget_mb=rss_budget_mb)
    out = Path(__file__).resolve().parents[1] / "BENCH_engine_scale.json"
    out.write_text(dump_json(payload))
    rows = [(f"engine_bench_scale/N{r['n_devices']}/{r['key']}",
             r["jax_warm_s"] * 1e6 / max(rounds * trials, 1),
             f"rps={r['rounds_per_s']:.0f};rss={r['peak_rss_mb']:.0f}MB")
            for r in scale_results]
    ps = participation_scale
    rows.append((f"engine_bench_scale/N{ps['n_devices']}"
                 f"_S{ps['clients_per_round']}/participation",
                 ps["jax_warm_s"] * 1e6 / max(rounds * trials, 1),
                 f"rps={ps['rounds_per_s']:.0f};"
                 f"rss={ps['peak_rss_mb']:.0f}MB"))
    return rows, payload


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes for CI (N=10, 2 trials, 40 rounds)")
    ap.add_argument("--minibatch", action="store_true",
                    help="SGD mini-batch suite (engine in-scan batch "
                         "sampling vs the NumPy oracle loop)")
    ap.add_argument("--digital-long", action="store_true",
                    help="1500-round digital engine run + peak-RSS record")
    ap.add_argument("--scale", action="store_true",
                    help="population-scale fast-RNG grid (N up to 1024 at "
                         "fig2 d) + fig2 replay-vs-fast speedup; writes "
                         "top-level BENCH_engine_scale.json")
    ap.add_argument("--rss-budget-mb", type=float, default=None,
                    help="with --digital-long/--scale: exit 1 if peak RSS "
                         "exceeds")
    args = ap.parse_args()
    if args.scale:
        if args.smoke:
            rows, payload = run_scale(
                quick=True, n_grid=(1024,), rounds=20, trials=1,
                fig2_rounds=120, fig2_trials=6,
                rss_budget_mb=args.rss_budget_mb)
        else:
            rows, payload = run_scale(quick=False,
                                      rss_budget_mb=args.rss_budget_mb)
        for r in payload["scale"]["results"]:
            print(f"N={r['n_devices']} {r['key']}: {r['rounds']}x"
                  f"{r['trials']} rounds in {r['jax_warm_s']:.2f}s warm "
                  f"({r['rounds_per_s']:.0f} rounds/s, "
                  f"RSS {r['peak_rss_mb']:.0f} MB)")
        ps = payload["participation_scale"]
        print(f"N={ps['n_devices']} S={ps['clients_per_round']} "
              f"partial participation ({ps['key']}): {ps['rounds']}x"
              f"{ps['trials']} rounds in {ps['jax_warm_s']:.2f}s warm "
              f"({ps['rounds_per_s']:.0f} rounds/s, "
              f"RSS {ps['peak_rss_mb']:.0f} MB)")
        f2 = payload["fig2_speedup"]
        print(f"fig2-scale ({f2['scheme']}, {f2['rounds']}x{f2['trials']}): "
              f"replay {f2['replay_warm_s']:.2f}s vs fast "
              f"{f2['fast_warm_s']:.2f}s -> {f2['speedup_fast']:.2f}x")
        print(f"peak RSS {payload['peak_rss_mb']:.0f} MB "
              f"-> BENCH_engine_scale.json")
        if (args.rss_budget_mb is not None
                and payload["peak_rss_mb"] > args.rss_budget_mb):
            print(f"FAIL: peak RSS exceeds budget "
                  f"{args.rss_budget_mb:.0f} MB — is a replay tensor "
                  "materialized in fast mode?", file=sys.stderr)
            sys.exit(1)
        return
    if args.digital_long:
        payload = run_digital_long()
        for r in payload["results"]:
            print(f"{r['key']}: {r['rounds']}x{r['trials']} rounds in "
                  f"{r['jax_s']:.1f}s ({r['rounds_per_s']:.0f} rounds/s)")
        print(f"peak RSS {payload['peak_rss_mb']:.0f} MB (retired dither "
              f"tensor alone: {payload['retired_dither_tensor_mb']:.0f} MB)")
        if (args.rss_budget_mb is not None
                and payload["peak_rss_mb"] > args.rss_budget_mb):
            print(f"FAIL: peak RSS exceeds budget {args.rss_budget_mb:.0f} MB"
                  " — is the dither replay materialized again?",
                  file=sys.stderr)
            sys.exit(1)
        return
    if args.minibatch:
        # smoke records separately so CI never clobbers the fig2-sized
        # artifacts
        if args.smoke:
            rows, payload = run_minibatch(
                quick=True, n_devices=10, trials=2, rounds=40,
                batch_size=32, samples_per_device=100,
                result_name="engine_bench_minibatch_smoke")
        else:
            rows, payload = run_minibatch(quick=True)
    elif args.smoke:
        rows, payload = run(quick=True, n_devices=10, trials=2, rounds=40,
                            samples_per_device=100,
                            result_name="engine_bench_smoke")
    else:
        rows, payload = run(quick=True)
    print("scheme,backend=numpy[s],jax_cold[s],jax_warm[s],speedup,parity")
    for r in payload["results"]:
        print(f"{r['scheme']},{r['numpy_s']:.3f},{r['jax_cold_s']:.3f},"
              f"{r['jax_warm_s']:.3f},{r['speedup_warm']:.1f}x,"
              f"{r['max_loss_deviation']:.1e}")
    worst = min(r["speedup_warm"] for r in payload["results"][:2])
    print(f"min OTA steady-state speedup: {worst:.1f}x")
    if args.minibatch:
        tb = payload["time_budget_run"]
        print(f"time-budget run ({tb['scheme']}): froze at "
              f"{tb['frozen_wall_s']:.3f}s of {tb['time_budget_s']:.3f}s "
              f"budget in {tb['jax_s']:.2f}s wall")


if __name__ == "__main__":
    main()
