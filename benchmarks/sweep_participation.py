"""Partial-participation workload: N x S grid, uniform vs designed sampling.

Runs the ``sweep_participation`` grid (device population x expected
cohort size x sampling policy — ``core.participation``) under
heterogeneous channel-dependent deep fades with zero-fill degradation:
every cell samples an expected S = ``run.clients_per_round`` of the N
devices per round, so the "uniform" (pi = S/N, exact zero sampling bias)
and "designed" (bound-driven capped-simplex pi,
``core.sca_jax.solve_participation_batch``) policies spend EQUAL expected
airtime. The summary reduces each (N, S, scheme) cell pair to the
designed-minus-uniform final-accuracy gain. The thesis: with one class
per device, uniform sampling starves the devices the fades already
starve (effective level p*pi*q collapses), while the co-designed pi
re-balances the effective participation the Theorem-1/2 bound prices —
a strictly better model at the same sampling budget.

    PYTHONPATH=src python -m benchmarks.run --only sweep_participation
    PYTHONPATH=src python -m benchmarks.sweep_participation --smoke
    PYTHONPATH=src python -m repro.api.cli run sweep_participation [--full]

Writes experiments/results/sweep_participation.json (summary) on top of
the ResultSet under experiments/results/scenarios/sweep_participation/.
``--smoke`` exits non-zero unless the designed policy strictly beats
uniform on at least one heterogeneous cell (the PR's acceptance gate).
"""
from __future__ import annotations

import argparse
import dataclasses
import sys
import time

from repro.api import execute
from repro.api.scenarios import sweep_participation as make_spec

from .common import save_result


def run(quick: bool = True, n_devices: int = 50, use_cache: bool = True,
        jobs: int = 1):
    """Participation-sweep entry. Cache ON by default (sweep-workload
    semantics: interrupted runs resume from finished cells);
    ``use_cache=False`` forces a full recompute."""
    t0 = time.time()
    sweep = make_spec(quick=quick, n_devices=n_devices)
    rs = execute(sweep, force=not use_cache, jobs=jobs)
    schemes = tuple(sweep.base.schemes)
    rows, cells = [], []
    by_cell: dict = {}
    for cell in rs:
        p = cell.payload
        recs = {rec["scheme_key"]: rec for rec in p["logs"]}
        finals = {k: rec["acc_mean"][-1] for k, rec in recs.items()}
        n = p["overrides"]["wireless.n_devices"]
        s = p["overrides"]["run.clients_per_round"]
        policy = p["overrides"]["run.participation"]
        by_cell.setdefault((n, s), {})[policy] = finals
        cells.append({
            "overrides": p["overrides"], "cell_hash": p["cell_hash"],
            "final_acc": finals,
            "design_objectives": {f: d["objective"]
                                  for f, d in p["design"].items()},
            "status": cell.status,
        })
        rows.append((f"sweep_participation/n{n}_s{s}_{policy}",
                     p["elapsed_s"] * 1e6,
                     " ".join(f"{k}={v:.4f}" for k, v in sorted(
                         finals.items()))))
    # equal-airtime comparison: designed-minus-uniform final accuracy per
    # (N, S) cell and scheme; S == N cells sample everyone under either
    # policy, so their gain is ~0 and never carries the domination claim
    gains = {}
    for (n, s), pols in sorted(by_cell.items()):
        if "uniform" not in pols or "designed" not in pols:
            continue
        gains[f"n{n}_s{s}"] = {
            k: pols["designed"][k] - pols["uniform"][k]
            for k in schemes}
    best_gain = float(max((v for g in gains.values() for v in g.values()),
                          default=float("-inf")))
    payload = {"quick": quick, "n_devices": n_devices,
               "sweep": sweep.to_dict(), "sweep_hash": sweep.spec_hash(),
               "fault": dataclasses.asdict(sweep.base.fault),
               "n_cells": len(cells), "cells": cells,
               "designed_minus_uniform": gains,
               "best_designed_gain": best_gain,
               "all_cached": rs.all_cached, "elapsed_s": time.time() - t0}
    save_result("sweep_participation", payload)
    return rows, payload


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="toy-scale CI gate (the quick grid; exits "
                         "non-zero unless designed sampling strictly "
                         "beats uniform on >= 1 cell)")
    ap.add_argument("--full", action="store_true",
                    help="paper-scale grid (slow)")
    ap.add_argument("--jobs", type=int, default=1, metavar="K",
                    help="worker-pool size for the sweep cells")
    args = ap.parse_args()
    quick = not args.full or args.smoke
    rows, payload = run(quick=quick, jobs=args.jobs)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}", flush=True)
    for key, g in payload["designed_minus_uniform"].items():
        print(key + ": " + ", ".join(
            f"{k} designed-uniform {v:+.4f}" for k, v in sorted(g.items())))
    best = payload["best_designed_gain"]
    print(f"best designed-vs-uniform gain: {best:+.4f}")
    if args.smoke and not best > 0.0:
        print("FAIL: designed sampling never beat uniform at equal airtime",
              file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
