"""Buffered-async workload: staleness-priced design vs naive async vs sync.

Runs three equal-wall-clock variants of the ``sweep_async`` grid
(arrival-rate heterogeneity x buffer depth x staleness discount,
``core.async_fl``) with one class per device, so slow-arriving devices
starve their class — a structured staleness bias:

  * **designed**  — ``run.mode="async"`` with the bound-driven PS weights
    v from ``core.sca_jax.solve_async_batch`` and a staleness discount
    ``delta^S``: the priced operating point (the discount axis belongs to
    the design — the summary picks the best discount per cell).
  * **naive**     — the same async arrivals with uniform v and delta = 1:
    aggregate whatever lands, unweighted (the classic buffered-async
    baseline).
  * **sync**      — ``run.mode="sync"`` with a round deadline exactly one
    OTA upload long (d/B) and a straggler probability matched to the
    async grid's mean per-round miss rate: the synchronous-with-deadline
    alternative that discards every late update.

All three charge identical per-round uplink latency (OTA tau = d/B; the
deadline caps straggler stretch at exactly d/B), so equal rounds = equal
wall-clock — the summary asserts the measured ``wall_time_s`` agree and
reduces the grid to designed-minus-naive / designed-minus-sync
final-accuracy gains. A bound-validation section (the
``theorem_validation`` pattern) runs the K=1 regime — where delivery is
independent Bernoulli thinning and the Theorem-1 model is exact — and
checks the measured steady-state optimality error sits below the
Theorem-1 bound evaluated at the async effective participation levels
(``bounds.async_effective_participation``) with the analytic delivery
variance.

    PYTHONPATH=src python -m benchmarks.run --only sweep_async
    PYTHONPATH=src python -m benchmarks.sweep_async --smoke
    PYTHONPATH=src python -m repro.api.cli run sweep_async [--full]

Writes experiments/results/sweep_async.json (summary) on top of the
ResultSets under experiments/results/scenarios/sweep_async*/.
``--smoke`` exits non-zero unless the staleness-priced design strictly
beats BOTH naive async and the sync deadline on at least one cell at
equal wall-clock, the wall-clocks match, and every K=1 bound row holds
(the PR's acceptance gate).
"""
from __future__ import annotations

import argparse
import dataclasses
import sys
import time

import numpy as np

from repro.api import execute
from repro.api.scenarios import sweep_async as make_spec
from repro.api.spec import FaultSpec, SweepSpec
from repro.core import async_fl, sca_jax
from repro.core import baselines as B
from repro.core.bounds import (ObjectiveWeights, async_bias_sum,
                               async_effective_participation, theorem1_bound)
from repro.fl.trainer import FLTrainer, solve_w_star

from .common import estimate_kappa_sc, make_sc_setup, save_result


def _variants(sweep: SweepSpec):
    """Derive the naive-async and sync-deadline comparison sweeps.

    Returns ``(naive, sync, miss_by_het)``: the naive grid drops the
    discount axis (delta = 1 IS the naive policy), the sync grid maps
    each heterogeneity value to the matched mean miss rate
    ``mean_m(1 - r_m)`` as a homogeneous straggler probability under a
    d/B deadline (late = lost, wall-clock unchanged).
    """
    base = sweep.base
    axes = dict(sweep.axes)
    hets = axes["async_.rate_heterogeneity"]
    bufs = axes["async_.buffer_rounds"]
    naive = SweepSpec(
        name="sweep_async_naive",
        base=base.replace(
            name="sweep_async_naive",
            async_=dataclasses.replace(base.async_, staleness_discount=1.0,
                                       weighting="uniform")),
        axes={"async_.rate_heterogeneity": hets,
              "async_.buffer_rounds": bufs})
    n = base.wireless.n_devices
    # OTA upload: tau = dim/B seconds (softmax dim = C*(F+1)); a deadline
    # of exactly tau keeps every round's realized latency at tau
    tau = (base.task.n_classes * (base.task.n_features + 1)
           / base.wireless.bandwidth_hz)
    miss_by_het = {
        h: round(float(np.mean(1.0 - async_fl.arrival_rates(
            dataclasses.replace(base.async_, rate_heterogeneity=h), n))), 9)
        for h in hets}
    sync = SweepSpec(
        name="sweep_async_sync",
        base=base.replace(
            name="sweep_async_sync",
            run=dataclasses.replace(base.run, mode="sync"),
            fault=FaultSpec(straggler_prob=miss_by_het[hets[0]],
                            straggler_mult=16.0, deadline_s=tau,
                            on_missing="zero")),
        axes={"fault.straggler_prob": tuple(miss_by_het[h] for h in hets)})
    return naive, sync, miss_by_het


def _finals(rs, scheme: str):
    """{overrides-tuple-free key: (final acc, final wall-clock)} per cell."""
    out = {}
    for cell in rs:
        rec = cell.log(scheme)
        out[tuple(sorted(cell.payload["overrides"].items()))] = (
            float(rec["acc_mean"][-1]), float(rec["wall_time_s"][-1]))
    return out


def _validate_bound(quick: bool):
    """K=1 bound rows: measured steady-state error vs Theorem 1.

    With ``buffer_rounds=1`` only fresh updates land, so the async layer
    is independent Bernoulli thinning with per-device keep probability
    ``c_m`` and payload scale ``v_m N / sum(cv)`` — exactly the regime
    Theorem 1 models: bias from the effective levels
    ``async_effective_participation``, variance bounded by the analytic
    delivery term ``G^2/N^2 sum(scale^2 c (1-c))``. Measured tail
    optimality error must sit below the bound for uniform AND designed
    weights, and the designed weights must not increase the priced bias
    sum (the solver's whole point).
    """
    rounds = 120 if quick else 300
    trials = 2
    tail = 3
    n = 8
    task, ds, dep, eta_max = make_sc_setup(
        n, samples_per_device=150 if quick else 600,
        n_train_per_class=200 if quick else 1200)
    eta = 0.25 * eta_max
    kappa = estimate_kappa_sc(task, ds)
    x_all = np.concatenate([d.x for d in ds.devices])
    y_all = np.concatenate([d.y for d in ds.devices])
    w_star = solve_w_star(task, x_all, y_all, iters=1500)
    ow = ObjectiveWeights.strongly_convex(eta=eta, mu=task.mu,
                                         kappa_sc=kappa, n=n)
    p = np.full(n, 1.0 / n)
    rows, val = [], []
    for het in (1.0, 3.0):
        asp = async_fl.AsyncSpec(buffer_rounds=1, arrival_rate=0.7,
                                 rate_heterogeneity=het)
        c = async_fl.delivery_weight(asp, n)
        sbar = async_fl.expected_staleness(asp, n)
        v_des, _ = sca_jax.solve_async_batch(
            p[None], c[None], sbar[None], [ow.omega_var], [ow.omega_bias])
        for wname, v in (("uniform", None), ("designed", v_des[0])):
            res = async_fl.resolve("async", asp, n, v)
            scale = res.payload_scale_array()
            e = async_effective_participation(p, c, v)
            zeta_del = float(task.g_max ** 2 / n ** 2
                             * np.sum(scale ** 2 * c * (1.0 - c)))
            bound = theorem1_bound(rounds, eta=eta, mu=task.mu, diam=0.0,
                                   kappa_sc=kappa, p=e, zeta=zeta_del)
            tr = FLTrainer(task, ds, dep, eta=eta, mode="async",
                           async_spec=asp, async_weights=v)
            log = tr.run(B.IdealFedAvg(), rounds=rounds, trials=trials,
                         eval_every=rounds // 6, seed=3, w_star=w_star)
            measured = float(log.opt_error[:, -tail:].mean())
            holds = measured <= bound["total"] + 1e-6
            val.append({"het": het, "weighting": wname,
                        "bias_sum": async_bias_sum(p, c, v),
                        "zeta_delivery": zeta_del,
                        "bound_bias": bound["bias"],
                        "bound_var": bound["variance"],
                        "bound_total": bound["total"],
                        "measured_err": measured, "holds": holds})
            rows.append((f"sweep_async/bound_het{het:g}_{wname}",
                         measured * 1e6,
                         f"bound={bound['total']:.3f};holds={holds}"))
    # the designed v must not inflate the priced bias vs uniform at the
    # solver's own operating point (bias-weighted objective)
    by_het = {}
    for r in val:
        by_het.setdefault(r["het"], {})[r["weighting"]] = r
    for het, d in by_het.items():
        d["designed"]["bias_reduced"] = bool(
            d["designed"]["bias_sum"] <= d["uniform"]["bias_sum"] + 1e-12)
    return rows, val


def run(quick: bool = True, n_devices: int = 10, use_cache: bool = True,
        jobs: int = 1):
    """Async-sweep entry: three equal-wall-clock variants + bound rows.
    Cache ON by default (interrupted runs resume from finished cells);
    ``use_cache=False`` forces a full recompute."""
    t0 = time.time()
    designed = make_spec(quick=quick, n_devices=n_devices)
    naive, sync, miss_by_het = _variants(designed)
    scheme = designed.base.schemes[0]
    rs_d = execute(designed, force=not use_cache, jobs=jobs)
    rs_n = execute(naive, force=not use_cache, jobs=jobs)
    rs_s = execute(sync, force=not use_cache, jobs=jobs)
    f_d = _finals(rs_d, scheme)
    f_n = _finals(rs_n, scheme)
    f_s = _finals(rs_s, scheme)

    axes = dict(designed.axes)
    hets = axes["async_.rate_heterogeneity"]
    bufs = axes["async_.buffer_rounds"]
    discs = axes["async_.staleness_discount"]
    sync_by_het = {
        h: f_s[tuple(sorted({"fault.straggler_prob":
                             miss_by_het[h]}.items()))]
        for h in hets}

    rows, comparison = [], {}
    walls = []
    for h in hets:
        for k in bufs:
            per_disc = {}
            for d in discs:
                acc, wall = f_d[tuple(sorted({
                    "async_.rate_heterogeneity": h,
                    "async_.buffer_rounds": k,
                    "async_.staleness_discount": d}.items()))]
                per_disc[d] = acc
                walls.append(wall)
            best_disc = max(per_disc, key=per_disc.get)
            des_acc = per_disc[best_disc]
            nai_acc, nai_wall = f_n[tuple(sorted({
                "async_.rate_heterogeneity": h,
                "async_.buffer_rounds": k}.items()))]
            syn_acc, syn_wall = sync_by_het[h]
            walls += [nai_wall, syn_wall]
            comparison[f"het{h:g}_K{k}"] = {
                "designed_acc": des_acc, "best_discount": best_disc,
                "designed_by_discount": per_disc,
                "naive_acc": nai_acc, "sync_acc": syn_acc,
                "gain_vs_naive": des_acc - nai_acc,
                "gain_vs_sync": des_acc - syn_acc,
            }
            rows.append((f"sweep_async/het{h:g}_K{k}", 0.0,
                         f"designed={des_acc:.4f} naive={nai_acc:.4f} "
                         f"sync={syn_acc:.4f}"))

    wall_spread = float(np.max(walls) - np.min(walls))
    equal_wall = wall_spread <= 1e-6 * max(float(np.max(walls)), 1e-12)
    best_vs_naive = max(c["gain_vs_naive"] for c in comparison.values())
    best_vs_sync = max(c["gain_vs_sync"] for c in comparison.values())
    brows, val = _validate_bound(quick)
    rows += brows
    payload = {"quick": quick, "n_devices": n_devices,
               "sweep": designed.to_dict(),
               "sweep_hash": designed.spec_hash(),
               "naive_hash": naive.spec_hash(),
               "sync_hash": sync.spec_hash(),
               "miss_by_het": {f"{h:g}": q for h, q in miss_by_het.items()},
               "comparison": comparison,
               "best_gain_vs_naive": float(best_vs_naive),
               "best_gain_vs_sync": float(best_vs_sync),
               "wall_clock_spread_s": wall_spread,
               "equal_wall_clock": bool(equal_wall),
               "bound_validation": val,
               "all_cached": rs_d.all_cached and rs_n.all_cached
               and rs_s.all_cached,
               "elapsed_s": time.time() - t0}
    save_result("sweep_async", payload)
    return rows, payload


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="toy-scale CI gate (the quick grid; exits "
                         "non-zero unless the staleness-priced design "
                         "strictly beats naive async AND the sync "
                         "deadline on >= 1 cell at equal wall-clock, "
                         "and every K=1 bound row holds)")
    ap.add_argument("--full", action="store_true",
                    help="paper-scale grid (slow)")
    ap.add_argument("--jobs", type=int, default=1, metavar="K",
                    help="worker-pool size for the sweep cells")
    args = ap.parse_args()
    quick = not args.full or args.smoke
    rows, payload = run(quick=quick, jobs=args.jobs)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}", flush=True)
    for key, c in payload["comparison"].items():
        print(f"{key}: designed {c['designed_acc']:.4f} "
              f"(delta*={c['best_discount']:g}) vs naive "
              f"{c['naive_acc']:.4f} ({c['gain_vs_naive']:+.4f}) vs sync "
              f"{c['sync_acc']:.4f} ({c['gain_vs_sync']:+.4f})")
    print(f"best gain vs naive: {payload['best_gain_vs_naive']:+.4f}; "
          f"vs sync: {payload['best_gain_vs_sync']:+.4f}; wall spread "
          f"{payload['wall_clock_spread_s']:.3g}s")
    if args.smoke:
        failures = []
        if not payload["best_gain_vs_naive"] > 0.0:
            failures.append("designed never beat naive async")
        if not payload["best_gain_vs_sync"] > 0.0:
            failures.append("designed never beat the sync deadline")
        if not payload["equal_wall_clock"]:
            failures.append("wall-clocks diverged across variants")
        if not all(r["holds"] for r in payload["bound_validation"]):
            failures.append("a Theorem-1 bound row failed")
        if failures:
            print("FAIL: " + "; ".join(failures), file=sys.stderr)
            sys.exit(1)


if __name__ == "__main__":
    main()
