PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test verify bench

test:
	PYTHONPATH=$(PYTHONPATH) python -m pytest -x -q

verify:
	./scripts/verify.sh

bench:
	PYTHONPATH=$(PYTHONPATH) python -m benchmarks.run
