"""Wireless fault-injection contracts (``core.faults`` + both backends).

The fault layer's guarantees:

  * the FAULT stream is counter-based threefry in BOTH rng execution
    modes and on BOTH backends — fault realizations are bit-identical
    across ``rng="replay"``/``"fast"`` and numpy/jax,
  * empirical fault rates match the declared probabilities (4-sigma
    gate, mirroring the fast-RNG suite's statistical discipline),
  * each ``on_missing`` policy produces the same trajectory on the JAX
    engine as on the NumPy oracle loop,
  * a disabled ``FaultSpec`` is a strict no-op: trajectories are
    bit-identical to a run with no fault layer at all,
  * the fault knobs are sweepable spec axes that change cell hashes, and
    pre-v5 spec dicts (no "fault" key) still load.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import baselines as B
from repro.core import rngstream
from repro.core.bounds import bias_sum, effective_participation
from repro.core.channel import WirelessConfig, make_deployment
from repro.core.faults import (FaultSpec, effective_lambdas, fault_masks,
                               survival_prob)
from repro.data.loader import FLDataset
from repro.data.partition import partition_by_class
from repro.data.synthetic import SyntheticSpec, make_classification_dataset
from repro.fl.trainer import FLTrainer

N_DEVICES = 10


@pytest.fixture(scope="module")
def setup():
    from repro.fl.tasks import SoftmaxRegressionTask

    spec = SyntheticSpec(n_train_per_class=100, n_test_per_class=30,
                         noise_sigma=1.5)
    x_tr, y_tr, x_te, y_te = make_classification_dataset(spec)
    shards = partition_by_class(x_tr, y_tr, N_DEVICES, 1, 100, seed=3)
    ds = FLDataset.from_shards(shards, x_te, y_te)
    task = SoftmaxRegressionTask(n_features=784, mu=0.01, g_max=20.0)
    dep = make_deployment(WirelessConfig(n_devices=N_DEVICES, seed=1))
    eta = 0.5 / (task.mu + task.smooth_l)
    return task, ds, dep, eta


def _vanilla(setup):
    task, _, dep, _ = setup
    return B.VanillaOTA(task.dim, task.g_max, dep.cfg.energy_per_symbol,
                        dep.cfg.noise_power)


def _run(setup, agg, fault, *, backend, rng="replay", trials=2, rounds=12,
         eval_every=4, seed=5, batch_size=None):
    task, ds, dep, eta = setup
    tr = FLTrainer(task, ds, dep, eta=eta, batch_size=batch_size,
                   fault=fault)
    return tr.run(agg, rounds=rounds, trials=trials, eval_every=eval_every,
                  seed=seed, backend=backend, rng=rng)


FULL_FAULT = dict(dropout_prob=0.3, erasure_prob=0.1, deep_fade_thresh=1e-6,
                  straggler_prob=0.2, straggler_mult=2.5)


class TestFaultStream:
    def test_fault_block_np_matches_jax(self):
        """The oracle view is byte-for-byte the jitted stream."""
        for trial in (0, 1):
            for t in (0, 7, 123):
                u_np = rngstream.fault_block_np(5, trial, t, N_DEVICES)
                u_jx = rngstream.fault_block(
                    rngstream.fault_base_key(5, trial), t, N_DEVICES)
                np.testing.assert_array_equal(u_np, np.asarray(u_jx))

    def test_fault_stream_distinct_from_other_streams(self):
        """FAULT_TAG is its own stream — no collision with dither/batch."""
        u = rngstream.fault_block_np(5, 0, 0, N_DEVICES)
        d = rngstream.dither_block_np(5, 0, 0, N_DEVICES, 3)
        assert not np.allclose(u[0][:3], d[0][:3])

    def test_empirical_rates_within_4_sigma(self):
        """Dropout/erasure/straggler rates over many rounds match the
        declared probabilities within 4 standard errors."""
        f = FaultSpec(dropout_prob=0.3, erasure_prob=0.1,
                      straggler_prob=0.2)
        rounds, n = 400, N_DEVICES
        hits = np.zeros(3)
        habs = np.ones(n)        # no fades: isolate the bernoulli draws
        for t in range(rounds):
            u = rngstream.fault_block_np(11, 0, t, n)
            hits[0] += np.sum(u[0] < f.dropout_prob)
            hits[1] += np.sum(u[1] < f.erasure_prob)
            hits[2] += np.sum(u[2] < f.straggler_prob)
            ok, straggler = fault_masks(u, habs, f)
            assert ok.shape == (n,) and straggler.shape == (n,)
        total = rounds * n
        for rate, p in zip(hits / total, (0.3, 0.1, 0.2)):
            sigma = np.sqrt(p * (1 - p) / total)
            assert abs(rate - p) <= 4.0 * sigma, (rate, p)


class TestPolicyParity:
    """Each on_missing policy: JAX engine == NumPy oracle loop."""

    @pytest.mark.parametrize("policy", ["zero", "reweight", "stale"])
    def test_engine_matches_oracle(self, setup, policy):
        f = FaultSpec(on_missing=policy, **FULL_FAULT)
        agg = _vanilla(setup)
        log_np = _run(setup, agg, f, backend="numpy")
        log_jx = _run(setup, agg, f, backend="jax")
        np.testing.assert_allclose(log_jx.global_loss, log_np.global_loss,
                                   rtol=1e-6, atol=1e-9)
        np.testing.assert_allclose(log_jx.wall_time_s, log_np.wall_time_s,
                                   rtol=1e-10)

    def test_deadline_caps_latency_on_both_backends(self, setup):
        f = FaultSpec(dropout_prob=0.2, straggler_prob=0.3,
                      deadline_s=1e-4, on_missing="zero")
        agg = _vanilla(setup)
        log_np = _run(setup, agg, f, backend="numpy", trials=1)
        log_jx = _run(setup, agg, f, backend="jax", trials=1)
        np.testing.assert_allclose(log_jx.wall_time_s, log_np.wall_time_s,
                                   rtol=1e-10)
        # every round costs at most the deadline
        assert log_np.wall_time_s[-1] <= 12 * 1e-4 + 1e-12

    def test_stragglers_stretch_rounds_without_deadline(self, setup):
        base = FaultSpec(dropout_prob=0.1, on_missing="zero")
        slow = dataclasses.replace(base, straggler_prob=0.5,
                                   straggler_mult=4.0)
        agg = _vanilla(setup)
        t_base = _run(setup, agg, base, backend="jax",
                      trials=1).wall_time_s[-1]
        t_slow = _run(setup, agg, slow, backend="jax",
                      trials=1).wall_time_s[-1]
        assert t_slow > t_base

    def test_policies_actually_differ(self, setup):
        agg = _vanilla(setup)
        finals = [
            _run(setup, agg,
                 FaultSpec(on_missing=p, **FULL_FAULT),
                 backend="jax", trials=1).global_loss[:, -1].item()
            for p in ("zero", "reweight", "stale")]
        assert len({round(v, 12) for v in finals}) == 3, finals


class TestRngModes:
    def test_fault_stream_bit_identical_replay_vs_fast(self, setup):
        """IdealFedAvg + mini-batch + faults consumes only counter-based
        streams (batch + fault) — trajectories must be exactly equal
        across rng modes, pinning the FAULT stream as mode-invariant."""
        f = FaultSpec(dropout_prob=0.25, on_missing="stale")
        log_r = _run(setup, B.IdealFedAvg(), f, backend="jax",
                     rng="replay", rounds=20, batch_size=32)
        log_f = _run(setup, B.IdealFedAvg(), f, backend="jax",
                     rng="fast", rounds=20, batch_size=32)
        np.testing.assert_array_equal(log_r.global_loss, log_f.global_loss)
        np.testing.assert_array_equal(log_r.accuracy, log_f.accuracy)

    def test_faulted_fast_statistically_equivalent(self, setup):
        """With faults on, fast mode still matches replay within MC error
        (the channel-coupled deep-fade mask sees different fading draws)."""
        f = FaultSpec(on_missing="reweight", **FULL_FAULT)
        agg = _vanilla(setup)
        log_r = _run(setup, agg, f, backend="jax", rng="replay",
                     trials=12, rounds=30, eval_every=10)
        log_f = _run(setup, agg, f, backend="jax", rng="fast",
                     trials=12, rounds=30, eval_every=10)
        lr, lf = log_r.global_loss, log_f.global_loss
        stderr = np.sqrt(lr.var(axis=0, ddof=1) / lr.shape[0]
                         + lf.var(axis=0, ddof=1) / lf.shape[0])
        gap = np.abs(lr.mean(axis=0) - lf.mean(axis=0))
        assert np.all(gap <= 4.0 * stderr + 1e-7), (gap, stderr)


class TestStrictNoOp:
    def test_disabled_fault_is_bit_identical(self, setup):
        agg = _vanilla(setup)
        log_none = _run(setup, agg, None, backend="jax", trials=1)
        log_off = _run(setup, agg, FaultSpec(), backend="jax", trials=1)
        np.testing.assert_array_equal(log_none.global_loss,
                                      log_off.global_loss)
        np.testing.assert_array_equal(log_none.wall_time_s,
                                      log_off.wall_time_s)

    def test_straggler_mult_alone_is_inert(self, setup):
        """straggler_mult without straggler_prob scales nothing."""
        f = FaultSpec(straggler_mult=10.0)
        assert not f.enabled
        agg = _vanilla(setup)
        log_none = _run(setup, agg, None, backend="numpy", trials=1)
        log_off = _run(setup, agg, f, backend="numpy", trials=1)
        np.testing.assert_array_equal(log_none.global_loss,
                                      log_off.global_loss)

    def test_disabled_fault_numpy_oracle(self, setup):
        agg = _vanilla(setup)
        log_none = _run(setup, agg, None, backend="numpy", trials=1)
        log_off = _run(setup, agg, FaultSpec(), backend="numpy", trials=1)
        np.testing.assert_array_equal(log_none.global_loss,
                                      log_off.global_loss)


class TestSpecValidation:
    @pytest.mark.parametrize("kw", [
        {"dropout_prob": -0.1}, {"dropout_prob": 1.5},
        {"erasure_prob": 2.0}, {"straggler_prob": -1.0},
        {"deep_fade_thresh": -1e-3}, {"straggler_mult": 0.5},
        {"deadline_s": 0.0}, {"deadline_s": -1.0},
        {"on_missing": "drop"},
    ])
    def test_bad_values_raise(self, kw):
        with pytest.raises(ValueError, match="fault\\."):
            FaultSpec(**kw)

    def test_survival_prob_composition(self):
        lam = np.array([1e-7, 1e-9])
        f = FaultSpec(dropout_prob=0.5, erasure_prob=0.5)
        np.testing.assert_allclose(survival_prob(f, lam), 0.25)
        # deep fades hit the weak device harder
        f2 = FaultSpec(deep_fade_thresh=1e-5)
        q = survival_prob(f2, lam)
        assert q[0] > q[1]
        np.testing.assert_allclose(q, np.exp(-1e-10 / lam))
        # deadline folds stragglers into the survival propensity
        f3 = FaultSpec(straggler_prob=0.4, deadline_s=1.0)
        np.testing.assert_allclose(survival_prob(f3, lam), 0.6)
        assert np.all(survival_prob(
            FaultSpec(dropout_prob=1.0), lam) >= 1e-12)

    def test_effective_lambdas(self):
        lam = np.array([1e-7, 1e-9])
        assert effective_lambdas(lam, FaultSpec()) is not None
        np.testing.assert_array_equal(effective_lambdas(lam, FaultSpec()),
                                      lam)
        f = FaultSpec(dropout_prob=0.5)
        np.testing.assert_allclose(effective_lambdas(lam, f), 0.5 * lam)
        # a fade threshold reduces delivered energy, never below the floor
        f2 = FaultSpec(deep_fade_thresh=1e-3)
        eff = effective_lambdas(lam, f2)
        assert np.all(eff > 0.0) and np.all(eff <= lam + 1e-6)

    def test_effective_participation_policies(self):
        p = np.array([0.5, 0.3, 0.2])
        q = np.array([1.0, 0.5, 0.1])
        np.testing.assert_array_equal(
            effective_participation(p, q, "zero"), p * q)
        np.testing.assert_array_equal(
            effective_participation(p, q, "reweight"), p)
        np.testing.assert_array_equal(
            effective_participation(p, q, "stale"), p)
        # zero-filling under heterogeneous survival adds structured bias
        assert (bias_sum(effective_participation(p, q, "zero"))
                != bias_sum(p))
        with pytest.raises(ValueError, match="on_missing"):
            effective_participation(p, q, "nope")


class TestSweepAxis:
    def test_fault_axes_sweepable_and_change_hashes(self):
        from repro.api.plan import plan
        from repro.api.spec import ScenarioSpec, SweepSpec

        base = ScenarioSpec(name="fault_axis")
        sweep = SweepSpec(name="fault_axis", base=base,
                          axes={"fault.dropout_prob": (0.0, 0.2),
                                "fault.on_missing": ("zero", "reweight")})
        pts = sweep.points()
        assert len(pts) == 4
        assert {sc.fault.dropout_prob for _, sc in pts} == {0.0, 0.2}
        assert len({sc.spec_hash() for _, sc in pts}) == 4
        cells = plan(sweep).cells
        assert len({c.cell_hash for c in cells}) == 4

    def test_from_dict_back_compat_without_fault_key(self):
        from repro.api.spec import ScenarioSpec

        d = ScenarioSpec(name="compat").to_dict()
        assert "fault" in d
        d.pop("fault")
        sc = ScenarioSpec.from_dict(d)
        assert sc.fault == FaultSpec() and not sc.fault.enabled

    def test_fault_round_trips_through_dict(self):
        from repro.api.spec import ScenarioSpec

        f = FaultSpec(dropout_prob=0.2, deadline_s=0.5, on_missing="stale")
        sc = ScenarioSpec(name="rt", fault=f)
        assert ScenarioSpec.from_dict(sc.to_dict()).fault == f

    def test_registered_sweep_fault_scenario_plans(self):
        from repro.api.plan import plan
        from repro.api.scenarios import sweep_fault

        sweep = sweep_fault(quick=True)
        assert sweep.base.fault.enabled
        pl = plan(sweep)
        assert len(pl.cells) == 4
