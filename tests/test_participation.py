"""Partial-participation contracts (``core.participation`` + both backends).

The sampling layer's guarantees, mirroring the fault-layer suite:

  * the PARTICIPATE stream is counter-based and bit-shared: the NumPy
    helper and the JAX in-scan block produce identical (N,) uniforms,
    distinct from every other stream's draws,
  * ``resolve``/``capped_proportional`` validate and normalize the
    (clients, policy, probs) knobs identically for both backends,
  * engine-vs-oracle parity holds with sampling on (uniform / channel /
    designed), alone and composed with the fault layer,
  * ``clients_per_round=None`` is a strict no-op (bit-identical to a
    trainer that never heard of participation),
  * ``rng="fast"`` stays statistically equivalent to replay with
    sampling on — and bit-identical for a scheme that consumes only
    counter-based streams,
  * the co-design solver (``core.sca_jax.solve_participation_batch``)
    returns feasible capped-simplex points that beat uniform on its own
    bound-shaped objective for heterogeneous survival rates,
  * ``run.clients_per_round`` / ``run.participation`` are sweepable axes
    that change the cell hash (schema v6).
"""
import numpy as np
import pytest

from repro.core import baselines as B
from repro.core import participation as P
from repro.core import rngstream, sca_jax
from repro.core.bounds import effective_participation
from repro.core.channel import WirelessConfig, make_deployment
from repro.core.faults import FaultSpec
from repro.data.loader import FLDataset
from repro.data.partition import partition_by_class
from repro.data.synthetic import SyntheticSpec, make_classification_dataset
from repro.fl.tasks import SoftmaxRegressionTask
from repro.fl.trainer import FLTrainer

N_DEVICES = 10
ROUNDS = 20
TRIALS = 2
EVAL_EVERY = 5
CLIENTS = 6
TOL = dict(rtol=1e-5, atol=1e-5)


@pytest.fixture(scope="module")
def setup():
    spec = SyntheticSpec(n_train_per_class=100, n_test_per_class=30,
                         noise_sigma=1.5)
    x_tr, y_tr, x_te, y_te = make_classification_dataset(spec)
    shards = partition_by_class(x_tr, y_tr, N_DEVICES, 1, 100, seed=3)
    ds = FLDataset.from_shards(shards, x_te, y_te)
    task = SoftmaxRegressionTask(n_features=784, mu=0.01, g_max=20.0)
    dep = make_deployment(WirelessConfig(n_devices=N_DEVICES, seed=1))
    eta = 0.5 / (task.mu + task.smooth_l)
    return task, ds, dep, eta


def _vanilla(setup):
    task, _, dep, _ = setup
    return B.VanillaOTA(task.dim, task.g_max, dep.cfg.energy_per_symbol,
                        dep.cfg.noise_power)


# ------------------------------------------------- PARTICIPATE stream

class TestStream:
    @pytest.mark.parametrize("seed,trial,t", [(0, 0, 0), (5, 1, 7),
                                              (123, 3, 999)])
    def test_np_matches_jax_bitwise(self, seed, trial, t):
        """The NumPy oracle helper and the engine's in-scan block draw the
        SAME threefry counters — identical bits, not just close."""
        u_np = rngstream.participation_block_np(seed, trial, t, 64)
        key = rngstream.participate_base_key(seed, trial)
        u_jx = np.asarray(rngstream.participation_block(key, t, 64))
        assert u_np.dtype == np.float64
        np.testing.assert_array_equal(u_np, u_jx)
        assert np.all((u_np >= 0.0) & (u_np < 1.0))

    def test_distinct_from_other_streams(self):
        """PARTICIPATE is its own tagged stream: same (seed, trial, t)
        counters, different draws than the FAULT block."""
        u_part = rngstream.participation_block_np(5, 1, 7, 64)
        u_fault = rngstream.fault_block_np(5, 1, 7, 64)
        assert not np.array_equal(u_part, u_fault)

    def test_deterministic(self):
        a = rngstream.participation_block_np(9, 2, 13, 32)
        b = rngstream.participation_block_np(9, 2, 13, 32)
        np.testing.assert_array_equal(a, b)

    def test_bernoulli_rate(self):
        """chi = (u < pi) hits the target inclusion rate to 4 sigma."""
        pi = 0.35
        rounds, n = 400, 64
        hits = sum(
            float(np.sum(rngstream.participation_block_np(2, 0, t, n) < pi))
            for t in range(rounds))
        mean = hits / (rounds * n)
        sigma = np.sqrt(pi * (1 - pi) / (rounds * n))
        assert abs(mean - pi) <= 4.0 * sigma

    def test_key_cache_is_bounded_and_stable(self):
        """The NumPy helper's base-key cache is a bounded LRU: flooding it
        with distinct (seed, trial) pairs never grows it past the cap,
        and an evicted key recomputes to the identical block."""
        cache = rngstream._PARTICIPATE_KEY_CACHE
        before = rngstream.participation_block_np(7, 0, 3, 16)
        for s in range(rngstream._KEY_CACHE_MAX + 50):
            rngstream.participation_block_np(10_000 + s, 0, 0, 4)
        assert len(cache) <= rngstream._KEY_CACHE_MAX
        after = rngstream.participation_block_np(7, 0, 3, 16)
        np.testing.assert_array_equal(before, after)


# ------------------------------------------- resolve / capped simplex

class TestResolve:
    def test_none_is_none(self):
        assert P.resolve(None, n_devices=8) is None

    def test_probs_without_clients_rejected(self):
        with pytest.raises(ValueError, match="clients_per_round is None"):
            P.resolve(None, probs=np.full(8, 0.5), n_devices=8)

    def test_uniform(self):
        part = P.resolve(4, "uniform", n_devices=8)
        assert part.policy == "uniform" and part.clients == 4
        assert part.scale == 2.0
        np.testing.assert_allclose(part.probs_array(), 0.5)
        assert {part: "hashable"}[part] == "hashable"

    def test_channel_needs_lambdas(self):
        with pytest.raises(ValueError, match="lambdas"):
            P.resolve(4, "channel", n_devices=8)

    def test_channel_capped_simplex(self):
        lam = np.array([1.0, 1.0, 1e3, 1e-3, 2.0, 0.5, 1.0, 4.0])
        part = P.resolve(4, "channel", n_devices=8, lambdas=lam)
        pi = part.probs_array()
        assert abs(pi.sum() - 4.0) < 1e-9
        assert np.all(pi <= 1.0) and np.all(pi > 0.0)
        assert pi[2] == 1.0          # the dominant channel saturates

    def test_designed_needs_probs(self):
        with pytest.raises(ValueError, match="explicit participation_probs"):
            P.resolve(4, "designed", n_devices=8)

    def test_explicit_probs_validation(self):
        ok = np.full(8, 0.5)
        part = P.resolve(4, "designed", probs=ok, n_devices=8)
        np.testing.assert_allclose(part.probs_array(), ok)
        with pytest.raises(ValueError, match="shape"):
            P.resolve(4, "designed", probs=np.full(7, 0.5), n_devices=8)
        with pytest.raises(ValueError, match=r"\(0, 1\]"):
            bad = ok.copy(); bad[0] = 1.5
            P.resolve(4, "designed", probs=bad, n_devices=8)
        with pytest.raises(ValueError, match="sum"):
            P.resolve(4, "designed", probs=np.full(8, 0.4), n_devices=8)

    @pytest.mark.parametrize("bad_s", [0, -1, 9])
    def test_clients_out_of_range(self, bad_s):
        with pytest.raises(ValueError, match="clients_per_round"):
            P.resolve(bad_s, n_devices=8)

    def test_bad_policy(self):
        with pytest.raises(ValueError, match="participation must be"):
            P.resolve(4, "importance", n_devices=8)

    def test_full_cohort(self):
        part = P.resolve(8, "uniform", n_devices=8)
        np.testing.assert_allclose(part.probs_array(), 1.0)
        assert part.scale == 1.0

    def test_capped_proportional_properties(self):
        w = np.array([0.1, 10.0, 1.0, 1.0, 5.0, 0.01])
        pi = P.capped_proportional(w, 3)
        assert abs(pi.sum() - 3.0) < 1e-9
        assert np.all(pi <= 1.0) and pi[1] == 1.0
        np.testing.assert_allclose(P.capped_proportional(w, 6), 1.0)
        with pytest.raises(ValueError, match="positive participation"):
            P.capped_proportional(np.array([1.0, 0.0, 0.0]), 2)

    @pytest.mark.parametrize("policy", ["loss", "datasize"])
    def test_weighted_policies_need_weights(self, policy):
        with pytest.raises(ValueError, match="per-device weights"):
            P.resolve(4, policy, n_devices=8)

    def test_weighted_policy_capped_simplex(self):
        w = np.array([3.0, 1.0, 1.0, 40.0, 2.0, 1.0, 1.0, 1.0])
        part = P.resolve(4, "loss", n_devices=8, weights=w)
        pi = part.probs_array()
        assert part.policy == "loss"
        assert abs(pi.sum() - 4.0) < 1e-9
        assert np.all(pi <= 1.0) and np.all(pi > 0.0)
        assert pi[3] == 1.0          # the dominant weight saturates
        np.testing.assert_array_equal(
            pi, P.resolve(4, "datasize", n_devices=8,
                          weights=w).probs_array())

    def test_policy_weights_derivation(self, setup):
        """datasize weights are the shard sizes; loss weights are the
        per-device initial losses — deterministic on both backends."""
        task, ds, _, _ = setup
        wd = P.policy_weights("datasize", task, ds)
        np.testing.assert_array_equal(
            wd, [float(len(d)) for d in ds.devices])
        wl = P.policy_weights("loss", task, ds)
        w0 = task.init_params()
        np.testing.assert_array_equal(
            wl, [float(task.global_loss(w0, d.x, d.y))
                 for d in ds.devices])
        assert P.policy_weights("uniform") is None
        with pytest.raises(ValueError, match="task and dataset"):
            P.policy_weights("loss")


# ------------------------------------------------------ co-design solver

class TestSolver:
    def test_feasible_and_beats_uniform(self):
        """Heterogeneous survival: the designed pi is on the capped
        simplex and strictly improves the bound-shaped objective over the
        zero-bias uniform point (evaluated with the same formula)."""
        n, s = 12, 4
        p = np.full(n, 1.0 / n)
        q = np.where(np.arange(n) < 6, 0.95, 0.05)
        wv, wb = 50.0, 1e-3

        def obj(pi):
            e = (n / s) * p * pi * q
            return (wb * np.sum((e - 1.0 / n) ** 2)
                    + wv / np.sum(e) ** 2)

        pi, j = sca_jax.solve_participation_batch(
            p[None], q[None], [s], [wv], [wb])
        pi, j = pi[0], float(j[0])
        assert abs(pi.sum() - s) < 1e-6
        assert np.all(pi <= 1.0 + 1e-12) and np.all(pi > 0.0)
        np.testing.assert_allclose(j, obj(pi), rtol=1e-10)
        assert j < obj(np.full(n, s / n))

    def test_batched_shapes(self):
        n = 8
        p = np.full((3, n), 1.0 / n)
        q = np.stack([np.ones(n), np.linspace(0.1, 1.0, n),
                      np.full(n, 0.5)])
        pi, j = sca_jax.solve_participation_batch(
            p, q, [2, 4, 6], [10.0, 10.0, 10.0], [1.0, 1.0, 1.0])
        assert pi.shape == (3, n) and j.shape == (3,)
        np.testing.assert_allclose(pi.sum(axis=1), [2.0, 4.0, 6.0],
                                   atol=1e-6)


# -------------------------------------------------- bound composition

class TestBoundComposition:
    def test_effective_participation_prices_p_pi_q(self):
        rng = np.random.default_rng(0)
        n, s = 8, 4
        p = rng.uniform(0.05, 0.2, n)
        q = rng.uniform(0.3, 1.0, n)
        pi = P.capped_proportional(rng.uniform(0.5, 2.0, n), s)
        eff = effective_participation(p, q, "zero", pi=pi)
        np.testing.assert_allclose(eff, p * q * pi * (n / pi.sum()),
                                   rtol=1e-12)
        # uniform pi is the zero-bias point: the sampling factor is 1
        uni = np.full(n, s / n)
        np.testing.assert_allclose(
            effective_participation(p, q, "reweight", pi=uni), p,
            rtol=1e-12)


# --------------------------------------- backend parity + no-op + fast

def _run(setup, agg, *, backend, rng="replay", trainer_kw=None, rounds=ROUNDS,
         trials=TRIALS, seed=5):
    task, ds, dep, eta = setup
    tr = FLTrainer(task, ds, dep, eta=eta, **(trainer_kw or {}))
    return tr.run(agg, rounds=rounds, trials=trials, eval_every=EVAL_EVERY,
                  seed=seed, backend=backend, rng=rng)


def _assert_logs_match(log_np, log_jx):
    np.testing.assert_array_equal(log_np.rounds, log_jx.rounds)
    np.testing.assert_allclose(log_jx.global_loss, log_np.global_loss, **TOL)
    np.testing.assert_allclose(log_jx.accuracy, log_np.accuracy, **TOL)


class TestEngineOracleParity:
    @pytest.mark.parametrize("policy", ["uniform", "channel"])
    def test_ota_policies(self, setup, policy):
        kw = dict(clients_per_round=CLIENTS, participation=policy)
        agg = _vanilla(setup)
        _assert_logs_match(_run(setup, agg, backend="numpy", trainer_kw=kw),
                           _run(setup, agg, backend="jax", trainer_kw=kw))

    def test_designed_probs(self, setup):
        """Arbitrary static capped-simplex probabilities flow through both
        backends identically (the 'designed' transport path)."""
        _, _, dep, _ = setup
        probs = P.capped_proportional(np.sqrt(dep.lambdas), CLIENTS)
        kw = dict(clients_per_round=CLIENTS, participation="designed",
                  participation_probs=probs)
        agg = _vanilla(setup)
        _assert_logs_match(_run(setup, agg, backend="numpy", trainer_kw=kw),
                           _run(setup, agg, backend="jax", trainer_kw=kw))

    @pytest.mark.parametrize("policy", ["loss", "datasize"])
    def test_weighted_policies(self, setup, policy):
        """The trainer/engine derive the loss/datasize sampling weights
        from their own task/dataset — identically on both backends."""
        kw = dict(clients_per_round=CLIENTS, participation=policy)
        agg = _vanilla(setup)
        _assert_logs_match(_run(setup, agg, backend="numpy", trainer_kw=kw),
                           _run(setup, agg, backend="jax", trainer_kw=kw))

    def test_selection_scheme(self, setup):
        """Client sampling composes with a selection-based digital scheme
        (sampling thins the pool the per-round selection draws from)."""
        task, _, dep, _ = setup
        agg = B.UQOS(dep, task.dim, task.g_max, dep.cfg.energy_per_symbol,
                     dep.cfg.noise_power, dep.cfg.bandwidth_hz)
        kw = dict(clients_per_round=CLIENTS)
        _assert_logs_match(_run(setup, agg, backend="numpy", trainer_kw=kw),
                           _run(setup, agg, backend="jax", trainer_kw=kw))

    def test_composes_with_fault_layer(self, setup):
        """Participation x faults: the chi mask applies before the fault
        policy in BOTH backends (p * pi * q ordering)."""
        kw = dict(clients_per_round=CLIENTS,
                  fault=FaultSpec(dropout_prob=0.2, deep_fade_thresh=1e-7,
                                  on_missing="zero"))
        agg = _vanilla(setup)
        _assert_logs_match(_run(setup, agg, backend="numpy", trainer_kw=kw),
                           _run(setup, agg, backend="jax", trainer_kw=kw))


class TestStrictNoOp:
    def test_none_is_bit_identical(self, setup):
        """clients_per_round=None must take the exact pre-participation
        code path — bit-identical, not merely close."""
        agg = _vanilla(setup)
        log_off = _run(setup, agg, backend="jax",
                       trainer_kw=dict(clients_per_round=None))
        log_plain = _run(setup, agg, backend="jax")
        np.testing.assert_array_equal(log_off.global_loss,
                                      log_plain.global_loss)
        np.testing.assert_array_equal(log_off.accuracy, log_plain.accuracy)

    def test_sampling_actually_changes_the_run(self, setup):
        agg = _vanilla(setup)
        log_on = _run(setup, agg, backend="jax",
                      trainer_kw=dict(clients_per_round=CLIENTS), trials=1)
        log_plain = _run(setup, agg, backend="jax", trials=1)
        assert not np.allclose(log_on.global_loss, log_plain.global_loss,
                               rtol=1e-10)


class TestFastMode:
    def test_counter_only_scheme_bit_identical(self, setup):
        """IdealFedAvg + sampling consumes ONLY the counter-based
        PARTICIPATE stream, which replay and fast share — trajectories
        must match exactly."""
        kw = dict(clients_per_round=CLIENTS)
        log_r = _run(setup, B.IdealFedAvg(), backend="jax", rng="replay",
                     trainer_kw=kw)
        log_f = _run(setup, B.IdealFedAvg(), backend="jax", rng="fast",
                     trainer_kw=kw)
        np.testing.assert_array_equal(log_r.global_loss, log_f.global_loss)
        np.testing.assert_array_equal(log_r.accuracy, log_f.accuracy)

    def test_statistical_equivalence_with_sampling(self, setup):
        """With fading + AWGN re-keyed by fast mode and sampling on, the
        mean trajectories agree within 4x Monte-Carlo stderr."""
        kw = dict(clients_per_round=CLIENTS)
        agg = _vanilla(setup)
        log_r = _run(setup, agg, backend="jax", rng="replay",
                     trainer_kw=kw, trials=12, rounds=30)
        log_f = _run(setup, agg, backend="jax", rng="fast",
                     trainer_kw=kw, trials=12, rounds=30)
        lr, lf = log_r.global_loss, log_f.global_loss
        gap = np.abs(lr.mean(axis=0) - lf.mean(axis=0))
        stderr = np.sqrt(lr.var(axis=0, ddof=1) / lr.shape[0]
                         + lf.var(axis=0, ddof=1) / lf.shape[0])
        assert np.all(gap <= 4.0 * stderr + 1e-7), (gap, stderr)


# ---------------------------------------------------- scenario plumbing

class TestScenarioAxes:
    def test_axes_change_spec_hash(self):
        from repro.api.results import SCHEMA_VERSION
        from repro.api.scenarios import sweep_participation

        assert SCHEMA_VERSION == 7
        base = sweep_participation(quick=True).base
        h0 = base.spec_hash()
        assert base.override("run.clients_per_round", 4).spec_hash() != h0
        assert base.override("run.participation",
                             "designed").spec_hash() != h0

    def test_runspec_backcompat(self):
        """Pre-v6 payload dicts (no participation fields) still load."""
        from repro.api.spec import RunSpec

        old = {"rounds": 8, "trials": 1, "etas": (1.0,)}
        r = RunSpec(**old)
        assert r.clients_per_round is None
        assert r.participation == "uniform"
