"""End-to-end behaviour tests for the paper's FL system."""
import numpy as np
import pytest

from repro.core.channel import (WirelessConfig, make_deployment,
                                FadingProcess, participation_probability)
from repro.core.bounds import (ObjectiveWeights, bias_sum, theorem1_bound,
                               theorem2_bound)
from repro.core import ota, ota_design, digital, digital_design
from repro.core import baselines as B
from repro.data.synthetic import SyntheticSpec, make_classification_dataset
from repro.data.partition import partition_by_class
from repro.data.loader import FLDataset
from repro.fl.tasks import SoftmaxRegressionTask
from repro.fl.trainer import FLTrainer


@pytest.fixture(scope="module")
def deployment():
    return make_deployment(WirelessConfig(n_devices=10, seed=1))


@pytest.fixture(scope="module")
def ota_spec(deployment):
    cfg = deployment.cfg
    w = ObjectiveWeights.strongly_convex(eta=0.5, mu=0.01, kappa_sc=3.0, n=10)
    return ota_design.OTADesignSpec(
        lambdas=deployment.lambdas, dim=7850, g_max=20.0,
        e_s=cfg.energy_per_symbol, n0=cfg.noise_power, weights=w)


class TestChannel:
    def test_pathloss_monotone(self, deployment):
        order = np.argsort(deployment.distances_m)
        lam = deployment.lambdas[order]
        assert np.all(np.diff(lam) <= 0), "gain must decrease with distance"

    def test_fading_statistics(self, deployment):
        fading = FadingProcess(deployment, seed=0)
        h = np.stack([fading.sample(t) for t in range(4000)])
        emp = np.mean(np.abs(h) ** 2, axis=0)
        np.testing.assert_allclose(emp, deployment.lambdas, rtol=0.15)

    def test_participation_probability(self, deployment):
        lam = deployment.lambdas
        thr = np.sqrt(lam)          # tau^2 = Lambda -> P = exp(-1)
        p = participation_probability(thr, lam)
        np.testing.assert_allclose(p, np.exp(-1.0), rtol=1e-12)
        fading = FadingProcess(deployment, seed=3)
        hits = np.mean([np.abs(fading.sample(t)) >= thr
                        for t in range(4000)], axis=0)
        np.testing.assert_allclose(hits, np.exp(-1.0), atol=0.03)


class TestOTA:
    def test_alpha_m_max_consistent(self, ota_spec):
        """alpha_m(gamma_max) == alpha_m_max (Sec. IV-A closed forms)."""
        gmax = ota_spec.gamma_max()
        amax = ota_spec.alpha_max()
        c = ota_spec.c_m()
        np.testing.assert_allclose(gmax * np.exp(-c * gmax ** 2), amax,
                                   rtol=1e-10)

    def test_participation_simplex(self, ota_spec, deployment):
        params, _ = ota_design.design_ota_sca(ota_spec, n_iters=3)
        p = params.participation_levels(deployment.lambdas)
        assert np.all(p >= 0) and np.all(p <= 1)
        np.testing.assert_allclose(p.sum(), 1.0, rtol=1e-9)

    def test_lemma1_empirical(self, ota_spec, deployment):
        """Empirical estimator variance must lie below the Lemma 1 bound."""
        gam = ota_design.anchor_zero_bias(ota_spec)
        params = ota_design.params_from_gamma(ota_spec, gam)
        d = 64
        import dataclasses
        params = dataclasses.replace(params, dim=d)
        # fixed local gradients with ||g|| <= G_max
        rng = np.random.default_rng(0)
        grads = [rng.normal(size=d) for _ in range(10)]
        grads = [g / np.linalg.norm(g) * 10.0 for g in grads]
        fading = FadingProcess(deployment, seed=9)
        p = params.participation_levels(deployment.lambdas)
        target = sum(pm * g for pm, g in zip(p, grads))
        errs = []
        for t in range(800):
            ghat, _ = ota.ota_round(params, grads, fading.sample(t), rng)
            errs.append(np.sum((ghat - target) ** 2))
        bound = ota.lemma1_variance(params, deployment.lambdas)["total"]
        emp = float(np.mean(errs))
        assert emp <= bound * 1.1, (emp, bound)

    def test_true_objective_finite_at_extreme_heterogeneity(self):
        """exp-overflow guard: gammas past the stationary point of a badly
        faded device (c_m gamma^2 >> 709) must give a finite (huge)
        objective, not 0*inf = nan or a ZeroDivisionError."""
        w = ObjectiveWeights.strongly_convex(eta=0.5, mu=0.01, kappa_sc=3.0,
                                             n=2)
        spec = ota_design.OTADesignSpec(
            lambdas=np.array([1e-6, 1e-13]), dim=100, g_max=20.0,
            e_s=1e-9, n0=1e-17, weights=w)
        # uniform gamma at the strong device's stationary point: the weak
        # device's exponent is ~1e7
        g_uniform = np.full(2, float(spec.gamma_max().max()))
        v = ota_design.true_objective_from_gamma(spec, g_uniform)
        assert np.isfinite(v) and v > 0
        # fully degenerate: every device far past overflow
        v_deg = ota_design.true_objective_from_gamma(
            spec, 50.0 * spec.gamma_max())
        assert np.isfinite(v_deg)
        # the guard must not perturb in-range evaluations
        g_ok = ota_design.anchor_min_noise(spec)
        a = g_ok * np.exp(-spec.c_m() * g_ok ** 2)
        p = a / a.sum()
        expect = (w.omega_var * (np.sum(p ** 2 * spec.g_max ** 2
                                        * (np.exp(spec.c_m() * g_ok ** 2)
                                           - 1.0))
                                 + spec.dim * spec.n0 / a.sum() ** 2)
                  + w.omega_bias * np.sum((p - 0.5) ** 2))
        np.testing.assert_allclose(
            ota_design.true_objective_from_gamma(spec, g_ok), expect,
            rtol=1e-12)

    def test_design_beats_heuristics(self, ota_spec):
        j_mn = ota_design.true_objective_from_gamma(
            ota_spec, ota_design.anchor_min_noise(ota_spec))
        j_zb = ota_design.true_objective_from_gamma(
            ota_spec, ota_design.anchor_zero_bias(ota_spec))
        _, res = ota_design.design_ota_sca(ota_spec, n_iters=6)
        assert res.objective <= min(j_mn, j_zb) + 1e-9

    def test_direct_at_least_as_good(self, ota_spec):
        _, res = ota_design.design_ota_sca(ota_spec, n_iters=6)
        _, f_direct = ota_design.design_ota_direct(ota_spec)
        assert f_direct <= res.objective * 1.01


class TestDigital:
    @pytest.fixture(scope="class")
    def dig_spec(self, deployment):
        cfg = deployment.cfg
        w = ObjectiveWeights.strongly_convex(eta=0.5, mu=0.01, kappa_sc=3.0,
                                             n=10)
        return digital_design.DigitalDesignSpec(
            lambdas=deployment.lambdas, dim=7850, g_max=20.0,
            e_s=cfg.energy_per_symbol, n0=cfg.noise_power,
            bandwidth_hz=cfg.bandwidth_hz, t_max_s=0.2, weights=w)

    def test_latency_budget(self, dig_spec, deployment):
        params, _ = digital_design.design_digital_sca(dig_spec, n_iters=4)
        lat = params.expected_latency(deployment.lambdas)
        assert lat <= dig_spec.t_max_s * 1.02, lat

    def test_simplex_and_bits(self, dig_spec, deployment):
        params, _ = digital_design.design_digital_sca(dig_spec, n_iters=4)
        p = params.participation_levels(deployment.lambdas)
        np.testing.assert_allclose(p.sum(), 1.0, rtol=1e-6)
        assert np.all(params.r_bits >= 1)
        assert np.all(params.r_bits <= dig_spec.r_max)

    def test_lemma2_empirical(self, dig_spec, deployment):
        import dataclasses
        params, _ = digital_design.design_digital_sca(dig_spec, n_iters=3)
        d = 64
        params = dataclasses.replace(params, dim=d)
        rng = np.random.default_rng(0)
        grads = [rng.normal(size=d) for _ in range(10)]
        grads = [g / np.linalg.norm(g) * 10.0 for g in grads]
        p = params.participation_levels(deployment.lambdas)
        target = sum(pm * g for pm, g in zip(p, grads))
        fading = FadingProcess(deployment, seed=11)
        errs = [np.sum((digital.digital_round(params, grads,
                                              fading.sample(t), rng)[0]
                        - target) ** 2) for t in range(600)]
        bound = digital.lemma2_variance(params, deployment.lambdas)["total"]
        assert np.mean(errs) <= bound * 1.1


class TestBounds:
    def test_bias_vanishes_uniform(self):
        p = np.full(8, 1 / 8)
        assert bias_sum(p) == pytest.approx(0.0, abs=1e-16)

    def test_theorem1_structure(self):
        p = np.array([0.5, 0.3, 0.2])
        b1 = theorem1_bound(10, eta=0.1, mu=0.1, diam=10.0, kappa_sc=2.0,
                            p=p, zeta=5.0)
        b2 = theorem1_bound(1000, eta=0.1, mu=0.1, diam=10.0, kappa_sc=2.0,
                            p=p, zeta=5.0)
        assert b2["initialization"] < b1["initialization"]
        assert b2["bias"] == b1["bias"]          # time-invariant bias
        # variance term scales linearly in zeta
        b3 = theorem1_bound(10, eta=0.1, mu=0.1, diam=10.0, kappa_sc=2.0,
                            p=p, zeta=10.0)
        assert b3["variance"] == pytest.approx(2 * b1["variance"])

    def test_theorem2_structure(self):
        p = np.full(4, 0.25)
        b = theorem2_bound(100, eta=0.01, smooth_l=2.0, f_gap0=5.0,
                           kappa_nc=1.0, p=p, zeta=3.0)
        assert b["bias"] == pytest.approx(0.0)
        assert b["total"] == pytest.approx(b["initialization"] + b["variance"])


class TestEndToEnd:
    @pytest.fixture(scope="class")
    def setup(self):
        spec = SyntheticSpec(n_train_per_class=200, n_test_per_class=50,
                             noise_sigma=1.5)
        x_tr, y_tr, x_te, y_te = make_classification_dataset(spec)
        shards = partition_by_class(x_tr, y_tr, 10, 1, 200, seed=3)
        ds = FLDataset.from_shards(shards, x_te, y_te)
        task = SoftmaxRegressionTask(n_features=784, mu=0.01, g_max=20.0)
        dep = make_deployment(WirelessConfig(n_devices=10, seed=1))
        return task, ds, dep

    def test_proposed_ota_learns_and_beats_vanilla(self, setup):
        task, ds, dep = setup
        cfg = dep.cfg
        # 0.25 * eta_max: the benchmark's grid-searched choice — at eta_max
        # the OTA noise floor (2*eta/mu * zeta) dominates at this horizon
        eta = 0.5 / (task.mu + task.smooth_l)
        w = ObjectiveWeights.strongly_convex(eta=eta, mu=task.mu,
                                             kappa_sc=3.0, n=10)
        spec = ota_design.OTADesignSpec(
            lambdas=dep.lambdas, dim=task.dim, g_max=task.g_max,
            e_s=cfg.energy_per_symbol, n0=cfg.noise_power, weights=w)
        params, _ = ota_design.design_ota_sca(spec, n_iters=4)
        tr = FLTrainer(task, ds, dep, eta=eta)
        log_p = tr.run(B.ProposedOTA(params), rounds=60, trials=2,
                       eval_every=30, seed=5)
        log_v = tr.run(B.VanillaOTA(task.dim, task.g_max,
                                    cfg.energy_per_symbol, cfg.noise_power),
                       rounds=60, trials=2, eval_every=30, seed=5)
        acc_p = log_p.final_accuracy()
        acc_v = log_v.final_accuracy()
        # 60 rounds at this noise level: well above chance (0.1) and above
        # the zero-bias vanilla scheme (full convergence needs ~300 rounds,
        # exercised in benchmarks/fig2_ota_sc.py)
        assert acc_p > 0.3, f"proposed should learn, got {acc_p}"
        assert acc_p >= acc_v - 0.02, (acc_p, acc_v)

    def test_ideal_fedavg_reaches_high_accuracy(self, setup):
        task, ds, dep = setup
        eta = 2.0 / (task.mu + task.smooth_l)
        tr = FLTrainer(task, ds, dep, eta=eta)
        log = tr.run(B.IdealFedAvg(), rounds=60, trials=1, eval_every=30)
        assert log.final_accuracy() > 0.75
