"""NumPy-trainer vs JAX-engine parity: same seed -> same trajectories.

The engine (fl/engine.py) replays the NumPy trainer's random streams —
fading, PS AWGN, quantization dither — so the two backends must agree
per eval point to (r/a)tol 1e-5 on loss, accuracy, opt-error, and
wall-clock, for every ported scheme. This is the contract that lets
``FLTrainer.run(backend="auto")`` route through the engine without
changing any benchmark's numbers.
"""
import numpy as np
import pytest

from repro.core import baselines as B
from repro.core import digital_design, ota_design
from repro.core.bounds import ObjectiveWeights
from repro.core.channel import WirelessConfig, make_deployment
from repro.data.loader import FLDataset
from repro.data.partition import partition_by_class
from repro.data.synthetic import SyntheticSpec, make_classification_dataset
from repro.fl.engine import FLEngine, as_functional
from repro.fl.tasks import SoftmaxRegressionTask
from repro.fl.trainer import FLTrainer, solve_w_star

N_DEVICES = 10
ROUNDS = 40
TRIALS = 2
EVAL_EVERY = 10
TOL = dict(rtol=1e-5, atol=1e-5)


@pytest.fixture(scope="module")
def setup():
    spec = SyntheticSpec(n_train_per_class=100, n_test_per_class=30,
                         noise_sigma=1.5)
    x_tr, y_tr, x_te, y_te = make_classification_dataset(spec)
    shards = partition_by_class(x_tr, y_tr, N_DEVICES, 1, 100, seed=3)
    ds = FLDataset.from_shards(shards, x_te, y_te)
    task = SoftmaxRegressionTask(n_features=784, mu=0.01, g_max=20.0)
    dep = make_deployment(WirelessConfig(n_devices=N_DEVICES, seed=1))
    eta = 0.5 / (task.mu + task.smooth_l)
    x_all = np.concatenate([d.x for d in ds.devices])
    y_all = np.concatenate([d.y for d in ds.devices])
    w_star = solve_w_star(task, x_all, y_all, iters=600)
    return task, ds, dep, eta, w_star


@pytest.fixture(scope="module")
def ota_params(setup):
    task, ds, dep, eta, _ = setup
    w = ObjectiveWeights.strongly_convex(eta=eta, mu=task.mu, kappa_sc=3.0,
                                         n=N_DEVICES)
    spec = ota_design.OTADesignSpec(
        lambdas=dep.lambdas, dim=task.dim, g_max=task.g_max,
        e_s=dep.cfg.energy_per_symbol, n0=dep.cfg.noise_power, weights=w)
    params, _ = ota_design.design_ota_sca(spec, n_iters=3)
    return params


@pytest.fixture(scope="module")
def dig_params(setup):
    task, ds, dep, eta, _ = setup
    w = ObjectiveWeights.strongly_convex(eta=eta, mu=task.mu, kappa_sc=3.0,
                                         n=N_DEVICES)
    spec = digital_design.DigitalDesignSpec(
        lambdas=dep.lambdas, dim=task.dim, g_max=task.g_max,
        e_s=dep.cfg.energy_per_symbol, n0=dep.cfg.noise_power,
        bandwidth_hz=dep.cfg.bandwidth_hz, t_max_s=0.2, weights=w)
    params, _ = digital_design.design_digital_sca(spec, n_iters=2)
    return params


def _assert_logs_match(log_np, log_jx):
    assert log_np.scheme == log_jx.scheme
    np.testing.assert_array_equal(log_np.rounds, log_jx.rounds)
    np.testing.assert_allclose(log_jx.global_loss, log_np.global_loss, **TOL)
    np.testing.assert_allclose(log_jx.accuracy, log_np.accuracy, **TOL)
    np.testing.assert_allclose(np.asarray(log_jx.wall_time_s),
                               np.asarray(log_np.wall_time_s), **TOL)
    if log_np.opt_error is not None:
        np.testing.assert_allclose(log_jx.opt_error, log_np.opt_error, **TOL)


def _run_both(setup, agg, w_star=None):
    task, ds, dep, eta, _ = setup
    tr = FLTrainer(task, ds, dep, eta=eta)
    log_np = tr.run(agg, rounds=ROUNDS, trials=TRIALS, eval_every=EVAL_EVERY,
                    seed=5, w_star=w_star, backend="numpy")
    log_jx = tr.run(agg, rounds=ROUNDS, trials=TRIALS, eval_every=EVAL_EVERY,
                    seed=5, w_star=w_star, backend="jax")
    return log_np, log_jx


class TestTrajectoryParity:
    def test_ideal_fedavg(self, setup):
        _assert_logs_match(*_run_both(setup, B.IdealFedAvg()))

    def test_proposed_ota(self, setup, ota_params):
        _, _, dep, eta, w_star = setup
        log_np, log_jx = _run_both(setup, B.ProposedOTA(ota_params),
                                   w_star=w_star)
        _assert_logs_match(log_np, log_jx)
        assert log_jx.opt_error is not None

    def test_vanilla_ota(self, setup):
        task, _, dep, _, w_star = setup
        agg = B.VanillaOTA(task.dim, task.g_max, dep.cfg.energy_per_symbol,
                           dep.cfg.noise_power)
        _assert_logs_match(*_run_both(setup, agg, w_star=w_star))

    def test_opc_ota_comp(self, setup):
        task, _, dep, _, _ = setup
        agg = B.OPCOTAComp(task.dim, task.g_max, dep.cfg.energy_per_symbol,
                           dep.cfg.noise_power)
        _assert_logs_match(*_run_both(setup, agg))

    def test_lcpc_ota_comp(self, setup):
        task, _, dep, _, _ = setup
        agg = B.LCPCOTAComp(dep, task.dim, task.g_max,
                            dep.cfg.energy_per_symbol, dep.cfg.noise_power)
        _assert_logs_match(*_run_both(setup, agg))

    def test_proposed_digital(self, setup, dig_params):
        _, _, _, _, w_star = setup
        log_np, log_jx = _run_both(setup, B.ProposedDigital(dig_params),
                                   w_star=w_star)
        _assert_logs_match(log_np, log_jx)
        # digital wall-clock is the realized TDMA latency, not d/B: it must
        # vary with participation yet match across backends (checked above)
        assert np.all(np.diff(np.asarray(log_jx.wall_time_s)) > 0)


class TestBackendDispatch:
    def test_auto_uses_engine_for_ported_schemes(self, setup):
        task, ds, dep, eta, _ = setup
        tr = FLTrainer(task, ds, dep, eta=eta)
        tr.run(B.IdealFedAvg(), rounds=4, trials=1, eval_every=2, seed=0)
        assert tr._engine is not None

    def test_auto_falls_back_for_unported_schemes(self, setup):
        task, ds, dep, eta, _ = setup
        agg = B.BBFLInterior(dep, task.dim, task.g_max,
                             dep.cfg.energy_per_symbol, dep.cfg.noise_power)
        assert as_functional(agg) is None
        tr = FLTrainer(task, ds, dep, eta=eta)
        log = tr.run(agg, rounds=4, trials=1, eval_every=2, seed=0)
        assert tr._engine is None
        assert np.all(np.isfinite(log.global_loss))

    def test_jax_backend_rejects_unsupported(self, setup):
        task, ds, dep, eta, _ = setup
        agg = B.BBFLInterior(dep, task.dim, task.g_max,
                             dep.cfg.energy_per_symbol, dep.cfg.noise_power)
        tr = FLTrainer(task, ds, dep, eta=eta)
        with pytest.raises(ValueError, match="no JAX port"):
            tr.run(agg, rounds=4, trials=1, eval_every=2, backend="jax")
        with pytest.raises(ValueError, match="backend"):
            tr.run(B.IdealFedAvg(), rounds=4, trials=1, eval_every=2,
                   backend="nope")

    def test_engine_rejects_unported_aggregator(self, setup):
        task, ds, dep, eta, _ = setup
        eng = FLEngine(task, ds, dep, eta)
        agg = B.BBFLInterior(dep, task.dim, task.g_max,
                             dep.cfg.energy_per_symbol, dep.cfg.noise_power)
        with pytest.raises(ValueError, match="no JAX port"):
            eng.run(agg, rounds=4, trials=1, eval_every=2)

    def test_non_divisible_rounds(self, setup, ota_params):
        """rounds not a multiple of eval_every: evals stop at the last grid
        point in both backends."""
        task, ds, dep, eta, _ = setup
        tr = FLTrainer(task, ds, dep, eta=eta)
        agg = B.ProposedOTA(ota_params)
        log_np = tr.run(agg, rounds=25, trials=1, eval_every=10, seed=7,
                        backend="numpy")
        log_jx = tr.run(agg, rounds=25, trials=1, eval_every=10, seed=7,
                        backend="jax")
        assert list(log_np.rounds) == [0, 10, 20]
        _assert_logs_match(log_np, log_jx)

    def test_shared_aggregator_across_deployments(self, setup):
        """One aggregator instance run through trainers on *different*
        deployments must not reuse a stale compiled runner (latency scale
        is per-deployment): wall-clock must track each bandwidth."""
        import dataclasses

        task, ds, dep, eta, _ = setup
        agg = B.VanillaOTA(task.dim, task.g_max, dep.cfg.energy_per_symbol,
                           dep.cfg.noise_power)
        dep_fast = make_deployment(
            dataclasses.replace(dep.cfg, bandwidth_hz=dep.cfg.bandwidth_hz
                                * 10), seed=1)
        walls = {}
        for name, d in (("slow", dep), ("fast", dep_fast)):
            tr = FLTrainer(task, ds, d, eta=eta)
            lj = tr.run(agg, rounds=4, trials=1, eval_every=2, seed=1,
                        backend="jax")
            ln = tr.run(agg, rounds=4, trials=1, eval_every=2, seed=1,
                        backend="numpy")
            np.testing.assert_allclose(np.asarray(lj.wall_time_s),
                                       np.asarray(ln.wall_time_s), **TOL)
            walls[name] = np.asarray(lj.wall_time_s)[-1]
        np.testing.assert_allclose(walls["fast"], walls["slow"] / 10,
                                   rtol=1e-12)

    def test_trainer_eta_mutation_rebuilds_engine(self, setup):
        """Mutating trainer.eta after a run must be honored by the JAX
        backend too (the engine is rebuilt, not served stale)."""
        task, ds, dep, eta, _ = setup
        tr = FLTrainer(task, ds, dep, eta=eta)
        tr.run(B.IdealFedAvg(), rounds=4, trials=1, eval_every=2, seed=1)
        tr.eta = eta / 10
        lj = tr.run(B.IdealFedAvg(), rounds=4, trials=1, eval_every=2,
                    seed=1, backend="jax")
        ln = tr.run(B.IdealFedAvg(), rounds=4, trials=1, eval_every=2,
                    seed=1, backend="numpy")
        np.testing.assert_allclose(lj.global_loss, ln.global_loss, **TOL)

    def test_eval_every_exceeds_rounds(self, setup):
        """rounds < eval_every: a single t=0 eval, zero scan segments (the
        empty fading-batch regression)."""
        task, ds, dep, eta, _ = setup
        tr = FLTrainer(task, ds, dep, eta=eta)
        log_np = tr.run(B.IdealFedAvg(), rounds=3, trials=1, eval_every=10,
                        seed=7, backend="numpy")
        log_jx = tr.run(B.IdealFedAvg(), rounds=3, trials=1, eval_every=10,
                        seed=7, backend="jax")
        assert list(log_jx.rounds) == [0]
        _assert_logs_match(log_np, log_jx)
