"""NumPy-trainer vs JAX-engine parity: same seed -> same trajectories.

The engine (fl/engine.py) replays the NumPy trainer's random streams —
fading, PS AWGN, counter-based quantization dither, selection draws — so
the two backends must agree per eval point to (r/a)tol 1e-5 on loss,
accuracy, opt-error, and wall-clock, for EVERY scheme in
``core.baselines`` (the full Sec. V suite, ``test_full_suite``). This is
the contract that lets ``FLTrainer.run(backend="auto")`` route through
the engine without changing any benchmark's numbers.
"""
import numpy as np
import pytest

from repro.core import baselines as B
from repro.core import digital_design, ota_design
from repro.core.bounds import ObjectiveWeights
from repro.core.channel import WirelessConfig, make_deployment
from repro.data.loader import FLDataset
from repro.data.partition import partition_by_class
from repro.data.synthetic import SyntheticSpec, make_classification_dataset
from repro.fl.engine import FLEngine, as_functional
from repro.fl.tasks import SoftmaxRegressionTask
from repro.fl.trainer import FLTrainer, solve_w_star

N_DEVICES = 10
ROUNDS = 40
TRIALS = 2
EVAL_EVERY = 10
TOL = dict(rtol=1e-5, atol=1e-5)


@pytest.fixture(scope="module")
def setup():
    spec = SyntheticSpec(n_train_per_class=100, n_test_per_class=30,
                         noise_sigma=1.5)
    x_tr, y_tr, x_te, y_te = make_classification_dataset(spec)
    shards = partition_by_class(x_tr, y_tr, N_DEVICES, 1, 100, seed=3)
    ds = FLDataset.from_shards(shards, x_te, y_te)
    task = SoftmaxRegressionTask(n_features=784, mu=0.01, g_max=20.0)
    dep = make_deployment(WirelessConfig(n_devices=N_DEVICES, seed=1))
    eta = 0.5 / (task.mu + task.smooth_l)
    x_all = np.concatenate([d.x for d in ds.devices])
    y_all = np.concatenate([d.y for d in ds.devices])
    w_star = solve_w_star(task, x_all, y_all, iters=600)
    return task, ds, dep, eta, w_star


@pytest.fixture(scope="module")
def ota_params(setup):
    task, ds, dep, eta, _ = setup
    w = ObjectiveWeights.strongly_convex(eta=eta, mu=task.mu, kappa_sc=3.0,
                                         n=N_DEVICES)
    spec = ota_design.OTADesignSpec(
        lambdas=dep.lambdas, dim=task.dim, g_max=task.g_max,
        e_s=dep.cfg.energy_per_symbol, n0=dep.cfg.noise_power, weights=w)
    params, _ = ota_design.design_ota_sca(spec, n_iters=3)
    return params


@pytest.fixture(scope="module")
def dig_params(setup):
    task, ds, dep, eta, _ = setup
    w = ObjectiveWeights.strongly_convex(eta=eta, mu=task.mu, kappa_sc=3.0,
                                         n=N_DEVICES)
    spec = digital_design.DigitalDesignSpec(
        lambdas=dep.lambdas, dim=task.dim, g_max=task.g_max,
        e_s=dep.cfg.energy_per_symbol, n0=dep.cfg.noise_power,
        bandwidth_hz=dep.cfg.bandwidth_hz, t_max_s=0.2, weights=w)
    params, _ = digital_design.design_digital_sca(spec, n_iters=2)
    return params


def _assert_logs_match(log_np, log_jx):
    assert log_np.scheme == log_jx.scheme
    np.testing.assert_array_equal(log_np.rounds, log_jx.rounds)
    np.testing.assert_allclose(log_jx.global_loss, log_np.global_loss, **TOL)
    np.testing.assert_allclose(log_jx.accuracy, log_np.accuracy, **TOL)
    np.testing.assert_allclose(np.asarray(log_jx.wall_time_s),
                               np.asarray(log_np.wall_time_s), **TOL)
    if log_np.opt_error is not None:
        np.testing.assert_allclose(log_jx.opt_error, log_np.opt_error, **TOL)


def _run_both(setup, agg, w_star=None):
    task, ds, dep, eta, _ = setup
    tr = FLTrainer(task, ds, dep, eta=eta)
    log_np = tr.run(agg, rounds=ROUNDS, trials=TRIALS, eval_every=EVAL_EVERY,
                    seed=5, w_star=w_star, backend="numpy")
    log_jx = tr.run(agg, rounds=ROUNDS, trials=TRIALS, eval_every=EVAL_EVERY,
                    seed=5, w_star=w_star, backend="jax")
    return log_np, log_jx


def _cfg_args(setup):
    task, _, dep, _, _ = setup
    return (task.dim, task.g_max, dep.cfg.energy_per_symbol,
            dep.cfg.noise_power)


MB_ROUNDS = 20          # mini-batch parity horizon (small T, per the suite)
MB_BATCH = 32           # of 100 samples/device


#: name -> factory(setup) covering the 8 schemes ported in the full-suite
#: engine refactor (the original 6 keep their dedicated tests below)
SCHEME_FACTORIES = {
    "opc_ota_fl": lambda s: B.OPCOTAFL(*_cfg_args(s)),
    "bbfl_interior": lambda s: B.BBFLInterior(s[2], *_cfg_args(s)),
    "bbfl_alternative": lambda s: B.BBFLAlternative(s[2], *_cfg_args(s)),
    "best_channel": lambda s: B.BestChannel(
        s[2], *_cfg_args(s), s[2].cfg.bandwidth_hz),
    "best_channel_norm": lambda s: B.BestChannelNorm(
        s[2], *_cfg_args(s), s[2].cfg.bandwidth_hz),
    "prop_fairness": lambda s: B.PropFairness(
        s[2], *_cfg_args(s), s[2].cfg.bandwidth_hz),
    "uqos": lambda s: B.UQOS(s[2], *_cfg_args(s), s[2].cfg.bandwidth_hz),
    "qml": lambda s: B.QML(s[2], *_cfg_args(s), s[2].cfg.bandwidth_hz),
    "fedtoe": lambda s: B.FedTOE(s[2], *_cfg_args(s), s[2].cfg.bandwidth_hz),
}


#: name -> factory(setup, ota_params, dig_params): EVERY scheme registered
#: in the engine's port routing table (the designed Proposed* schemes need
#: the module-scoped design fixtures, hence the wider signature)
ALL_SCHEME_FACTORIES = dict(
    ideal_fedavg=lambda s, op, dp: B.IdealFedAvg(),
    proposed_ota=lambda s, op, dp: B.ProposedOTA(op),
    vanilla_ota=lambda s, op, dp: B.VanillaOTA(*_cfg_args(s)),
    opc_ota_comp=lambda s, op, dp: B.OPCOTAComp(*_cfg_args(s)),
    lcpc_ota_comp=lambda s, op, dp: B.LCPCOTAComp(s[2], *_cfg_args(s)),
    proposed_digital=lambda s, op, dp: B.ProposedDigital(dp),
    **{k: (lambda f: lambda s, op, dp: f(s))(f)
       for k, f in SCHEME_FACTORIES.items()},
)


class _UnportedAggregator(B.Aggregator):
    """A scheme with no registered JAX port (tests the NumPy fallback)."""

    name = "unported"

    def round(self, grads, h, t, rng, dither=None):
        g = np.mean(np.stack([np.asarray(g) for g in grads]), axis=0)
        return B.RoundResult(g, 0.0, np.ones(len(grads)), {})


class TestTrajectoryParity:
    def test_ideal_fedavg(self, setup):
        _assert_logs_match(*_run_both(setup, B.IdealFedAvg()))

    def test_proposed_ota(self, setup, ota_params):
        _, _, dep, eta, w_star = setup
        log_np, log_jx = _run_both(setup, B.ProposedOTA(ota_params),
                                   w_star=w_star)
        _assert_logs_match(log_np, log_jx)
        assert log_jx.opt_error is not None

    def test_vanilla_ota(self, setup):
        task, _, dep, _, w_star = setup
        agg = B.VanillaOTA(task.dim, task.g_max, dep.cfg.energy_per_symbol,
                           dep.cfg.noise_power)
        _assert_logs_match(*_run_both(setup, agg, w_star=w_star))

    def test_opc_ota_comp(self, setup):
        task, _, dep, _, _ = setup
        agg = B.OPCOTAComp(task.dim, task.g_max, dep.cfg.energy_per_symbol,
                           dep.cfg.noise_power)
        _assert_logs_match(*_run_both(setup, agg))

    def test_lcpc_ota_comp(self, setup):
        task, _, dep, _, _ = setup
        agg = B.LCPCOTAComp(dep, task.dim, task.g_max,
                            dep.cfg.energy_per_symbol, dep.cfg.noise_power)
        _assert_logs_match(*_run_both(setup, agg))

    def test_proposed_digital(self, setup, dig_params):
        _, _, _, _, w_star = setup
        log_np, log_jx = _run_both(setup, B.ProposedDigital(dig_params),
                                   w_star=w_star)
        _assert_logs_match(log_np, log_jx)
        # digital wall-clock is the realized TDMA latency, not d/B: it must
        # vary with participation yet match across backends (checked above)
        assert np.all(np.diff(np.asarray(log_jx.wall_time_s)) > 0)

    @pytest.mark.parametrize("scheme", sorted(SCHEME_FACTORIES))
    def test_full_suite(self, setup, scheme):
        """Every remaining Sec. V baseline: trajectory parity through the
        jittable selection / bit-allocation / RNG-replay machinery."""
        _assert_logs_match(*_run_both(setup, SCHEME_FACTORIES[scheme](setup)))

    def test_mlp_task_parity(self, setup):
        """Non-convex MLPTask (the fig3 path) agrees across backends for
        both an OTA and a digital selection scheme."""
        from repro.fl.tasks import MLPTask

        _, ds, dep, _, _ = setup
        task = MLPTask(n_features=784, hidden=8, mu_nc=0.01, g_max=20.0)
        tr = FLTrainer(task, ds, dep, eta=0.05)
        for agg in (B.VanillaOTA(task.dim, task.g_max,
                                 dep.cfg.energy_per_symbol,
                                 dep.cfg.noise_power),
                    B.BestChannel(dep, task.dim, task.g_max,
                                  dep.cfg.energy_per_symbol,
                                  dep.cfg.noise_power,
                                  dep.cfg.bandwidth_hz)):
            log_np = tr.run(agg, rounds=10, trials=1, eval_every=5, seed=3,
                            backend="numpy")
            log_jx = tr.run(agg, rounds=10, trials=1, eval_every=5, seed=3,
                            backend="jax")
            _assert_logs_match(log_np, log_jx)


class TestMiniBatchParity:
    """SGD mini-batch runs through the engine: counter-based batch indices
    (threefry on seed/trial/round/device) are regenerated inside the scan
    and gathered through the task's device_grads_at path — the exact program
    the NumPy oracle runs, so trajectories match for every registered
    scheme."""

    @pytest.mark.parametrize("scheme", sorted(ALL_SCHEME_FACTORIES))
    def test_minibatch_full_suite(self, setup, ota_params, dig_params,
                                  scheme):
        task, ds, dep, eta, _ = setup
        agg = ALL_SCHEME_FACTORIES[scheme](setup, ota_params, dig_params)
        tr = FLTrainer(task, ds, dep, eta=eta, batch_size=MB_BATCH)
        log_np = tr.run(agg, rounds=MB_ROUNDS, trials=TRIALS,
                        eval_every=EVAL_EVERY, seed=5, backend="numpy")
        log_jx = tr.run(agg, rounds=MB_ROUNDS, trials=TRIALS,
                        eval_every=EVAL_EVERY, seed=5, backend="jax")
        _assert_logs_match(log_np, log_jx)

    def test_minibatch_actually_subsamples(self, setup):
        """A mini-batch run must differ from the full-batch trajectory
        (guards against the sampler silently returning the full dataset)."""
        task, ds, dep, eta, _ = setup
        agg = B.IdealFedAvg()
        log_mb = FLTrainer(task, ds, dep, eta=eta, batch_size=MB_BATCH).run(
            agg, rounds=MB_ROUNDS, trials=1, eval_every=EVAL_EVERY, seed=5,
            backend="jax")
        log_fb = FLTrainer(task, ds, dep, eta=eta).run(
            agg, rounds=MB_ROUNDS, trials=1, eval_every=EVAL_EVERY, seed=5,
            backend="jax")
        assert not np.allclose(log_mb.global_loss[:, -1],
                               log_fb.global_loss[:, -1], rtol=1e-12)

    def test_batch_size_covering_dataset_is_full_batch(self, setup):
        """batch_size >= |D_m| degrades to the full-batch path in both
        backends (DeviceDataset.batch semantics) — and stays in parity."""
        task, ds, dep, eta, _ = setup
        agg = B.IdealFedAvg()
        tr = FLTrainer(task, ds, dep, eta=eta, batch_size=10 ** 6)
        log_np = tr.run(agg, rounds=MB_ROUNDS, trials=1,
                        eval_every=EVAL_EVERY, seed=5, backend="numpy")
        log_jx = tr.run(agg, rounds=MB_ROUNDS, trials=1,
                        eval_every=EVAL_EVERY, seed=5, backend="jax")
        _assert_logs_match(log_np, log_jx)
        log_fb = FLTrainer(task, ds, dep, eta=eta).run(
            agg, rounds=MB_ROUNDS, trials=1, eval_every=EVAL_EVERY, seed=5,
            backend="jax")
        np.testing.assert_allclose(log_jx.global_loss, log_fb.global_loss,
                                   **TOL)

    def test_auto_routes_minibatch_through_engine(self, setup):
        task, ds, dep, eta, _ = setup
        tr = FLTrainer(task, ds, dep, eta=eta, batch_size=MB_BATCH)
        tr.run(B.IdealFedAvg(), rounds=4, trials=1, eval_every=2, seed=0)
        assert tr._engine is not None
        assert tr._engine.batch_size == MB_BATCH


class TestTimeBudgetParity:
    """Per-round latency budgets run in-scan: cumulative wall-clock in the
    scan carry, a freeze mask past exhaustion, and eval slots reporting the
    last *live* state — same freeze round and frozen values as the trainer's
    break-and-copy loop."""

    def _run_budget_both(self, setup, agg, budget, *, batch_size=None,
                         rounds=12, eval_every=4):
        task, ds, dep, eta, _ = setup
        tr = FLTrainer(task, ds, dep, eta=eta, batch_size=batch_size)
        log_np = tr.run(agg, rounds=rounds, trials=TRIALS,
                        eval_every=eval_every, seed=0, time_budget_s=budget,
                        backend="numpy")
        log_jx = tr.run(agg, rounds=rounds, trials=TRIALS,
                        eval_every=eval_every, seed=0, time_budget_s=budget,
                        backend="jax")
        return log_np, log_jx

    def test_budget_freeze_parity_ota(self, setup):
        """Budget trips between eval grid points: identical freeze round
        (wall-clock pinned at the same exhaustion time) and frozen evals."""
        task, _, dep, _, _ = setup
        agg = B.VanillaOTA(*_cfg_args(setup))
        per_round = task.dim / dep.cfg.bandwidth_hz
        log_np, log_jx = self._run_budget_both(setup, agg, 5.5 * per_round)
        _assert_logs_match(log_np, log_jx)
        # the budget (airtime for 5.5 rounds) froze after round 6: slots at
        # t=8,12 replicate the t=4 eval, wall pinned at 6 rounds of airtime
        assert np.all(log_jx.global_loss[:, 2:]
                      == log_jx.global_loss[:, 1:2])
        np.testing.assert_allclose(np.asarray(log_jx.wall_time_s)[2:],
                                   6 * per_round, rtol=1e-12)

    def test_budget_freeze_parity_digital(self, setup, dig_params):
        """Digital schemes spend *realized* TDMA latency: the freeze round
        is data-dependent, and both backends must agree on it."""
        log_np, log_jx = self._run_budget_both(
            setup, B.ProposedDigital(dig_params), 0.05, rounds=16)
        _assert_logs_match(log_np, log_jx)

    def test_budget_with_minibatch_combined(self, setup):
        """The two new engine paths compose: SGD mini-batches under a
        latency budget stay in parity."""
        task, _, dep, _, _ = setup
        agg = B.VanillaOTA(*_cfg_args(setup))
        per_round = task.dim / dep.cfg.bandwidth_hz
        log_np, log_jx = self._run_budget_both(
            setup, agg, 5.5 * per_round, batch_size=MB_BATCH)
        _assert_logs_match(log_np, log_jx)

    def test_auto_routes_budget_through_engine(self, setup):
        task, ds, dep, eta, _ = setup
        tr = FLTrainer(task, ds, dep, eta=eta)
        tr.run(B.IdealFedAvg(), rounds=4, trials=1, eval_every=2, seed=0,
               time_budget_s=1e9)
        assert tr._engine is not None


class TestUnequalSizesParity:
    """Unequal-sized device datasets run natively in the engine: devices
    are zero-padded to n_max and per-device ragged batch indices — keyed
    on each device's *own* size — are regenerated in-scan, so the draws
    are bit-identical to the oracle's per-device ``batch_indices_np``
    loop and never touch the padding rows. This lifts the last
    engine-dispatch NumPy fallback for strictly mini-batched runs."""

    UNEQ_BATCH = 16

    @pytest.fixture(scope="class")
    def unequal(self, setup):
        from repro.data.loader import DeviceDataset

        task, ds, dep, eta, w_star = setup
        # sizes 100, 93, ..., 37 — all distinct, all > UNEQ_BATCH
        devs = [DeviceDataset(d.x[:100 - 7 * m], d.y[:100 - 7 * m])
                for m, d in enumerate(ds.devices)]
        ds_u = FLDataset(devs, ds.x_test, ds.y_test)
        assert len({len(d) for d in ds_u.devices}) == len(ds_u.devices)
        return task, ds_u, dep, eta, w_star

    @pytest.mark.parametrize("scheme",
                             ["ideal_fedavg", "vanilla_ota", "uqos"])
    def test_unequal_parity(self, unequal, scheme):
        """OTA noise, digital selection+dither, and the noiseless ideal
        path all agree with the oracle on ragged device data."""
        task, ds_u, dep, eta, _ = unequal
        agg = ALL_SCHEME_FACTORIES[scheme](unequal, None, None)
        tr = FLTrainer(task, ds_u, dep, eta=eta, batch_size=self.UNEQ_BATCH)
        log_np = tr.run(agg, rounds=MB_ROUNDS, trials=TRIALS,
                        eval_every=EVAL_EVERY, seed=5, backend="numpy")
        log_jx = tr.run(agg, rounds=MB_ROUNDS, trials=TRIALS,
                        eval_every=EVAL_EVERY, seed=5, backend="jax")
        _assert_logs_match(log_np, log_jx)

    def test_auto_routes_unequal_through_engine(self, unequal):
        task, ds_u, dep, eta, _ = unequal
        tr = FLTrainer(task, ds_u, dep, eta=eta, batch_size=self.UNEQ_BATCH)
        tr.run(B.IdealFedAvg(), rounds=4, trials=1, eval_every=2, seed=0)
        assert tr._engine is not None
        assert tr._engine.device_sizes == tuple(
            len(d) for d in ds_u.devices)

    def test_fast_mode_runs_on_ragged_data(self, unequal):
        """rng='fast' composes with the ragged path (the batch stream is
        already counter-based, so only fading/noise streams change)."""
        task, ds_u, dep, eta, _ = unequal
        agg = B.VanillaOTA(task.dim, task.g_max, dep.cfg.energy_per_symbol,
                           dep.cfg.noise_power)
        tr = FLTrainer(task, ds_u, dep, eta=eta, batch_size=self.UNEQ_BATCH)
        log = tr.run(agg, rounds=8, trials=1, eval_every=4, seed=3,
                     backend="jax", rng="fast")
        assert np.all(np.isfinite(log.global_loss))

    def test_engine_requires_batch_size_on_unequal(self, unequal):
        task, ds_u, dep, eta, _ = unequal
        with pytest.raises(ValueError, match="mini-batch size"):
            FLEngine(task, ds_u, dep, eta)

    @pytest.mark.parametrize("scheme",
                             ["ideal_fedavg", "vanilla_ota", "uqos"])
    def test_mixed_regime_parity(self, unequal, scheme):
        """batch_size >= min |D_m| mixes full- and mini-batch devices.
        Covered devices take weighted full-data gradients (1/n_m on real
        rows, 0 on the clipped duplicates), uncovered ones the exact
        counter-based draw — the oracle's per-device loop semantics, so
        both backends stay in the standard parity tolerance."""
        task, ds_u, dep, eta, _ = unequal
        agg = ALL_SCHEME_FACTORIES[scheme](unequal, None, None)
        tr = FLTrainer(task, ds_u, dep, eta=eta, batch_size=50)
        log_np = tr.run(agg, rounds=MB_ROUNDS, trials=TRIALS,
                        eval_every=EVAL_EVERY, seed=5, backend="numpy")
        log_jx = tr.run(agg, rounds=MB_ROUNDS, trials=TRIALS,
                        eval_every=EVAL_EVERY, seed=5, backend="jax")
        _assert_logs_match(log_np, log_jx)

    def test_mixed_regime_routes_to_engine(self, unequal):
        """The mixed regime is the last regime that used to fall back to
        the NumPy loop — auto must now route it through the engine."""
        task, ds_u, dep, eta, _ = unequal
        tr = FLTrainer(task, ds_u, dep, eta=eta, batch_size=50)
        log = tr.run(B.IdealFedAvg(), rounds=4, trials=1, eval_every=2,
                     seed=0)
        assert tr._engine is not None
        assert np.all(np.isfinite(log.global_loss))

    def test_mixed_regime_all_devices_covered_parity(self, unequal):
        """batch_size >= max |D_m|: every device runs full-batch through
        the weighted path, with no batch draw consumed anywhere."""
        task, ds_u, dep, eta, _ = unequal
        agg = B.VanillaOTA(task.dim, task.g_max, dep.cfg.energy_per_symbol,
                           dep.cfg.noise_power)
        tr = FLTrainer(task, ds_u, dep, eta=eta, batch_size=200)
        log_np = tr.run(agg, rounds=MB_ROUNDS, trials=TRIALS,
                        eval_every=EVAL_EVERY, seed=5, backend="numpy")
        log_jx = tr.run(agg, rounds=MB_ROUNDS, trials=TRIALS,
                        eval_every=EVAL_EVERY, seed=5, backend="jax")
        _assert_logs_match(log_np, log_jx)


class TestGreedyBitAlloc:
    def test_matches_numpy_oracle(self, setup):
        """Jittable greedy allocator == FedTOE._alloc_bits on random
        scheduled sets, including budget-deferral and r_max saturation."""
        import jax.numpy as jnp
        from jax.experimental import enable_x64

        from repro.core.digital import greedy_bit_alloc_jax

        task, _, dep, _, _ = setup
        cfg = dep.cfg
        rng = np.random.default_rng(42)
        configs = [
            dict(t_budget_s=0.22),            # paper default
            dict(t_budget_s=0.04),            # tight: 1-bit deferrals
            dict(t_budget_s=5.0, r_max=6),    # loose: r_max saturation
        ]
        with enable_x64():
            for kw in configs:
                agg = B.FedTOE(dep, task.dim, task.g_max,
                               cfg.energy_per_symbol, cfg.noise_power,
                               cfg.bandwidth_hz, k=5, **kw)
                for _ in range(10):
                    sel = rng.choice(dep.n_devices, size=agg.k,
                                     replace=False)
                    want = agg._alloc_bits(sel)
                    bits, in_alloc = greedy_bit_alloc_jax(
                        jnp.asarray(sel), jnp.asarray(agg.rates),
                        dim=task.dim, bandwidth_hz=cfg.bandwidth_hz,
                        t_budget_s=agg.t_budget, r_max=agg.r_max)
                    got = {m: int(b) for m, b in
                           enumerate(np.asarray(bits)) if b > 0}
                    assert got == want, (kw, sel)
                    assert set(np.flatnonzero(np.asarray(in_alloc))) \
                        == set(want)


class TestBackendDispatch:
    def test_auto_uses_engine_for_ported_schemes(self, setup):
        task, ds, dep, eta, _ = setup
        tr = FLTrainer(task, ds, dep, eta=eta)
        tr.run(B.IdealFedAvg(), rounds=4, trials=1, eval_every=2, seed=0)
        assert tr._engine is not None

    def test_every_baseline_scheme_is_ported(self, setup):
        """The routing table covers the paper's whole Sec. V suite — no
        scheme silently drops to the NumPy loop under backend="auto"."""
        task, _, dep, _, _ = setup
        args = (task.dim, task.g_max, dep.cfg.energy_per_symbol,
                dep.cfg.noise_power)
        suite = [B.IdealFedAvg(), B.VanillaOTA(*args), B.OPCOTAComp(*args),
                 B.OPCOTAFL(*args), B.BBFLInterior(dep, *args),
                 B.BBFLAlternative(dep, *args)]
        suite += [f(setup) for f in SCHEME_FACTORIES.values()]
        for agg in suite:
            assert as_functional(agg) is not None, agg.name

    def test_auto_falls_back_for_unported_schemes(self, setup):
        task, ds, dep, eta, _ = setup
        agg = _UnportedAggregator()
        assert as_functional(agg) is None
        tr = FLTrainer(task, ds, dep, eta=eta)
        log = tr.run(agg, rounds=4, trials=1, eval_every=2, seed=0)
        assert tr._engine is None
        assert np.all(np.isfinite(log.global_loss))

    def test_jax_backend_rejects_unsupported(self, setup):
        task, ds, dep, eta, _ = setup
        agg = _UnportedAggregator()
        tr = FLTrainer(task, ds, dep, eta=eta)
        with pytest.raises(ValueError, match="no JAX port"):
            tr.run(agg, rounds=4, trials=1, eval_every=2, backend="jax")
        with pytest.raises(ValueError, match="backend"):
            tr.run(B.IdealFedAvg(), rounds=4, trials=1, eval_every=2,
                   backend="nope")

    def test_engine_rejects_unported_aggregator(self, setup):
        task, ds, dep, eta, _ = setup
        eng = FLEngine(task, ds, dep, eta)
        with pytest.raises(ValueError, match="no JAX port"):
            eng.run(_UnportedAggregator(), rounds=4, trials=1, eval_every=2)

    def test_shard_trials_flag(self, setup):
        """shard_map over the trials axis reproduces the vmap trajectory
        (single-device mesh here; multi-host is the same flag)."""
        task, ds, dep, eta, _ = setup
        agg = B.VanillaOTA(task.dim, task.g_max, dep.cfg.energy_per_symbol,
                           dep.cfg.noise_power)
        eng = FLEngine(task, ds, dep, eta, shard_trials=True)
        log_sh = eng.run(agg, rounds=6, trials=2, eval_every=2, seed=11)
        log_vm = FLEngine(task, ds, dep, eta).run(
            agg, rounds=6, trials=2, eval_every=2, seed=11)
        np.testing.assert_allclose(log_sh.global_loss, log_vm.global_loss,
                                   **TOL)
        np.testing.assert_allclose(np.asarray(log_sh.wall_time_s),
                                   np.asarray(log_vm.wall_time_s), **TOL)

    def test_non_divisible_rounds(self, setup, ota_params):
        """rounds not a multiple of eval_every: evals stop at the last grid
        point in both backends."""
        task, ds, dep, eta, _ = setup
        tr = FLTrainer(task, ds, dep, eta=eta)
        agg = B.ProposedOTA(ota_params)
        log_np = tr.run(agg, rounds=25, trials=1, eval_every=10, seed=7,
                        backend="numpy")
        log_jx = tr.run(agg, rounds=25, trials=1, eval_every=10, seed=7,
                        backend="jax")
        assert list(log_np.rounds) == [0, 10, 20]
        _assert_logs_match(log_np, log_jx)

    def test_shared_aggregator_across_deployments(self, setup):
        """One aggregator instance run through trainers on *different*
        deployments must not reuse a stale compiled runner (latency scale
        is per-deployment): wall-clock must track each bandwidth."""
        import dataclasses

        task, ds, dep, eta, _ = setup
        agg = B.VanillaOTA(task.dim, task.g_max, dep.cfg.energy_per_symbol,
                           dep.cfg.noise_power)
        dep_fast = make_deployment(
            dataclasses.replace(dep.cfg, bandwidth_hz=dep.cfg.bandwidth_hz
                                * 10), seed=1)
        walls = {}
        for name, d in (("slow", dep), ("fast", dep_fast)):
            tr = FLTrainer(task, ds, d, eta=eta)
            lj = tr.run(agg, rounds=4, trials=1, eval_every=2, seed=1,
                        backend="jax")
            ln = tr.run(agg, rounds=4, trials=1, eval_every=2, seed=1,
                        backend="numpy")
            np.testing.assert_allclose(np.asarray(lj.wall_time_s),
                                       np.asarray(ln.wall_time_s), **TOL)
            walls[name] = np.asarray(lj.wall_time_s)[-1]
        np.testing.assert_allclose(walls["fast"], walls["slow"] / 10,
                                   rtol=1e-12)

    def test_trainer_eta_mutation_rebuilds_engine(self, setup):
        """Mutating trainer.eta after a run must be honored by the JAX
        backend too (the engine is rebuilt, not served stale)."""
        task, ds, dep, eta, _ = setup
        tr = FLTrainer(task, ds, dep, eta=eta)
        tr.run(B.IdealFedAvg(), rounds=4, trials=1, eval_every=2, seed=1)
        tr.eta = eta / 10
        lj = tr.run(B.IdealFedAvg(), rounds=4, trials=1, eval_every=2,
                    seed=1, backend="jax")
        ln = tr.run(B.IdealFedAvg(), rounds=4, trials=1, eval_every=2,
                    seed=1, backend="numpy")
        np.testing.assert_allclose(lj.global_loss, ln.global_loss, **TOL)

    def test_eval_every_exceeds_rounds(self, setup):
        """rounds < eval_every: a single t=0 eval, zero scan segments (the
        empty fading-batch regression)."""
        task, ds, dep, eta, _ = setup
        tr = FLTrainer(task, ds, dep, eta=eta)
        log_np = tr.run(B.IdealFedAvg(), rounds=3, trials=1, eval_every=10,
                        seed=7, backend="numpy")
        log_jx = tr.run(B.IdealFedAvg(), rounds=3, trials=1, eval_every=10,
                        seed=7, backend="jax")
        assert list(log_jx.rounds) == [0]
        _assert_logs_match(log_np, log_jx)
