"""Per-kernel shape/dtype sweeps: Pallas (interpret on CPU) vs ref oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

SHAPES = [(8,), (127,), (1024,), (3, 257), (2, 8, 130), (5, 1000, 7)]
DTYPES = [jnp.float32]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("levels", [1.0, 7.0, 255.0, 65535.0])
def test_dithered_quantize_matches_ref(shape, dtype, levels):
    key = jax.random.key(42)
    g = (jax.random.normal(jax.random.key(1), shape, dtype) * 3).astype(dtype)
    out_k = ops.dithered_quantize(g, levels, key, use_kernel=True)
    out_r = ops.dithered_quantize(g, levels, key, use_kernel=False)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               atol=1e-5, rtol=1e-5)
    # quantized values must be on the quantization grid (up to fp eps)
    m = float(jnp.max(jnp.abs(g)))
    delta = 2 * m / levels
    q_idx = (np.asarray(out_k) + m) / delta
    np.testing.assert_allclose(q_idx, np.round(q_idx), atol=1e-2)


def test_dithered_quantize_zero_input():
    g = jnp.zeros((64, 64))
    out = ops.dithered_quantize(g, 255.0, jax.random.key(0), use_kernel=True)
    assert float(jnp.max(jnp.abs(out))) == 0.0


def test_dithered_quantize_unbiased():
    """E[q(g)|g] = g: average over many dither draws."""
    g = jax.random.normal(jax.random.key(5), (256,)) * 2
    acc = jnp.zeros_like(g)
    n = 400
    for i in range(n):
        acc = acc + ops.dithered_quantize(g, 15.0, jax.random.key(i),
                                          use_kernel=True)
    m = float(jnp.max(jnp.abs(g)))
    delta = 2 * m / 15.0
    np.testing.assert_allclose(np.asarray(acc / n), np.asarray(g),
                               atol=4 * delta / np.sqrt(n) + 1e-3)


@pytest.mark.parametrize("shape", SHAPES)
def test_ota_combine_matches_ref(shape):
    key = jax.random.key(3)
    g = jax.random.normal(jax.random.key(2), shape)
    a = jnp.asarray(3.7)
    ns = jnp.asarray(0.25)
    out_k = ops.ota_combine(g, a, ns, key, use_kernel=True)
    out_r = ops.ota_combine(g, a, ns, key, use_kernel=False)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               atol=1e-6)


def test_ota_combine_zero_noise_is_scale():
    g = jax.random.normal(jax.random.key(2), (1000,))
    out = ops.ota_combine(g, jnp.asarray(2.0), jnp.asarray(0.0),
                          jax.random.key(0), use_kernel=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(g) / 2.0,
                               atol=1e-6)


@pytest.mark.parametrize("B,S,D", [(1, 16, 8), (2, 300, 200), (3, 256, 128),
                                   (2, 1024, 64), (1, 37, 129)])
def test_linear_scan_matches_ref(B, S, D):
    a = jax.random.uniform(jax.random.key(2), (B, S, D), minval=0.3,
                           maxval=0.999)
    b = jax.random.normal(jax.random.key(3), (B, S, D)) * 0.1
    h0 = jax.random.normal(jax.random.key(4), (B, D))
    ha, hl = ops.linear_scan(a, b, h0, use_kernel=True)
    ra, rl = ref.linear_scan_ref(a, b, h0)
    np.testing.assert_allclose(np.asarray(ha), np.asarray(ra), atol=2e-5)
    np.testing.assert_allclose(np.asarray(hl), np.asarray(rl), atol=2e-5)


def test_linear_scan_identity_dynamics():
    """a=1, b=0 -> h_t = h0 for all t."""
    B, S, D = 2, 512, 128
    a = jnp.ones((B, S, D))
    b = jnp.zeros((B, S, D))
    h0 = jax.random.normal(jax.random.key(0), (B, D))
    ha, hl = ops.linear_scan(a, b, h0, use_kernel=True)
    np.testing.assert_allclose(np.asarray(ha),
                               np.broadcast_to(np.asarray(h0)[:, None],
                                               (B, S, D)), atol=1e-6)
    np.testing.assert_allclose(np.asarray(hl), np.asarray(h0), atol=1e-6)


@pytest.mark.parametrize("B,S,D,n", [(1, 128, 128, 8), (2, 300, 200, 16),
                                     (2, 64, 100, 4)])
def test_selective_scan_matches_ref(B, S, D, n):
    k = jax.random.split(jax.random.key(7), 6)
    dt = jax.random.uniform(k[0], (B, S, D), minval=0.001, maxval=0.2)
    x = jax.random.normal(k[1], (B, S, D))
    bm = jax.random.normal(k[2], (B, S, n)) * 0.5
    cm = jax.random.normal(k[3], (B, S, n)) * 0.5
    aw = -jnp.exp(jax.random.normal(k[4], (D, n)) * 0.3)
    h0 = jax.random.normal(k[5], (B, D, n)) * 0.1
    yk, hk = ops.selective_scan(dt, x, bm, cm, aw, h0, use_kernel=True)
    yr, hr = ops.selective_scan(dt, x, bm, cm, aw, h0, use_kernel=False)
    np.testing.assert_allclose(np.asarray(yk), np.asarray(yr), atol=3e-5)
    np.testing.assert_allclose(np.asarray(hk), np.asarray(hr), atol=3e-5)


ODD_DIMS = [1, 127, 1000, 7850, 65537]   # none divisible by BLOCK_ROWS*LANES


@pytest.mark.parametrize("d", ODD_DIMS)
def test_ota_combine_with_noise_padding(d):
    """Explicit-noise epilogue (engine hot path): pad-and-slice wrapper must
    match the jnp oracle for gradient dims not divisible by a block."""
    g = jax.random.normal(jax.random.key(d), (d,))
    z = jax.random.normal(jax.random.key(d + 1), (d,))
    out_k = ops.ota_combine_with_noise(g, jnp.asarray(2.5), z, use_kernel=True)
    out_r = ops.ota_combine_with_noise(g, jnp.asarray(2.5), z, use_kernel=False)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r), atol=1e-6)
    np.testing.assert_allclose(np.asarray(out_k), (np.asarray(g)
                               + np.asarray(z)) / 2.5, atol=1e-5)


def test_ota_combine_with_noise_float64_and_traced_alpha():
    """The engine runs the epilogue in f64 under scoped x64, with per-round
    traced post-scalers (Vanilla OTA); both must survive the kernel."""
    from jax.experimental import enable_x64
    with enable_x64():
        g = jnp.asarray(np.random.default_rng(0).normal(size=777))
        z = jnp.asarray(np.random.default_rng(1).normal(size=777))
        assert g.dtype == jnp.float64

        @jax.jit
        def f(alpha):
            return ops.ota_combine_with_noise(g, alpha, z, use_kernel=True)

        out = f(jnp.asarray(3.0))
        assert out.dtype == jnp.float64
        np.testing.assert_allclose(np.asarray(out),
                                   (np.asarray(g) + np.asarray(z)) / 3.0,
                                   atol=1e-12)


@pytest.mark.parametrize("d", ODD_DIMS)
def test_dithered_quantize_with_dither_padding(d):
    """Explicit-dither quantizer vs the numpy reference on odd dims: same
    dither stream -> same payload (up to 1-ulp rounding)."""
    from repro.core.quantize import quantize_np

    class _FixedU:
        def __init__(self, u):
            self.u = u

        def uniform(self, size=None):
            return self.u

    rng = np.random.default_rng(d)
    g = rng.normal(size=d)
    u = rng.uniform(size=d)
    out_k = ops.dithered_quantize_with_dither(
        jnp.asarray(g, jnp.float32), 63.0, jnp.asarray(u, jnp.float32))
    out_r = ops.dithered_quantize_with_dither(
        jnp.asarray(g, jnp.float32), 63.0, jnp.asarray(u, jnp.float32),
        use_kernel=False)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               atol=1e-6)
    # vs the numpy simulation quantizer: compare in f64 (the engine's
    # precision) — an f32 kernel pass would see ~1e-5 of stochastic-rounding
    # boundary flips against the f64 reference, which is expected
    from jax.experimental import enable_x64
    with enable_x64():
        out64 = ops.dithered_quantize_with_dither(
            jnp.asarray(g), 63.0, jnp.asarray(u))
    q_np = quantize_np(g, 6, _FixedU(u))
    np.testing.assert_allclose(np.asarray(out64), q_np, atol=1e-12)


@pytest.mark.parametrize("n_dev,d", [(1, 130), (5, 127), (10, 7850),
                                     (3, 65537)])
def test_dithered_quantize_batch_matches_per_device(n_dev, d):
    """Batched rows-kernel == N independent per-device quantize calls, with
    heterogeneous per-device bit-widths (digital engine hot path)."""
    rng = np.random.default_rng(7)
    gs = jnp.asarray(rng.normal(size=(n_dev, d)) * (1 + np.arange(n_dev))[:, None],
                     jnp.float32)
    us = jnp.asarray(rng.uniform(size=(n_dev, d)), jnp.float32)
    levels = jnp.asarray([float(2 ** (1 + (i % 6)) - 1) for i in range(n_dev)],
                         jnp.float32)
    out_b = ops.dithered_quantize_batch(gs, levels, us, use_kernel=True)
    assert out_b.shape == (n_dev, d)
    for i in range(n_dev):
        out_i = ops.dithered_quantize_with_dither(gs[i], levels[i], us[i],
                                                  use_kernel=True)
        np.testing.assert_allclose(np.asarray(out_b[i]), np.asarray(out_i),
                                   atol=1e-6)


def test_mamba_kernel_flag_matches_jnp():
    """mamba_apply with the Pallas kernel == fused jnp path."""
    from repro.configs import REGISTRY
    from repro.models import make_model, make_batch, loss_fn
    cfg = REGISTRY["falcon-mamba-7b"].scaled_down()
    model = make_model(cfg)
    params = model.init(jax.random.key(0))
    batch = make_batch(cfg, 2, 40, jax.random.key(1))
    l_j, _ = loss_fn(model, params, batch, flags={"mamba_fused": True})
    l_k, _ = loss_fn(model, params, batch, flags={"mamba_kernel": True})
    np.testing.assert_allclose(float(l_j), float(l_k), rtol=1e-4)


# ------------------------------------------- fused payload pipeline

from repro.kernels import autotune  # noqa: E402


@pytest.fixture
def tuner_cache():
    autotune.clear_cache()
    yield
    autotune.clear_cache()


@pytest.mark.parametrize("d", ODD_DIMS)
def test_quantize_pack_roundtrip_exact(d):
    """pack -> unpack == the two-step quantize-dequantize, bit for bit:
    codes are integers < 2^24 so the uint32 round-trip through f32 is
    exact, including non-divisible dims, heterogeneous per-device
    bit-widths, and levels<=0 degenerate rows (exact zeros)."""
    rng = np.random.default_rng(d)
    n_dev = 6
    gs = jnp.asarray(rng.normal(size=(n_dev, d)), jnp.float32)
    us = jnp.asarray(rng.uniform(size=(n_dev, d)), jnp.float32)
    # device 0 granted no bits (levels=0) -> must decode to exact zeros
    levels = jnp.asarray([0.0, 1.0, 3.0, 15.0, 63.0, 255.0], jnp.float32)
    pk = ops.quantize_pack(gs, levels, us, code_bits=8)
    dec = ops.unpack_dequant(pk)
    two_step = ops.dithered_quantize_batch(gs, levels, us)
    assert dec.shape == (n_dev, d)
    np.testing.assert_array_equal(np.asarray(dec), np.asarray(two_step))
    assert not np.any(np.asarray(dec[0]))


@pytest.mark.parametrize("code_bits", [4, 8, 16])
def test_quantize_pack_roundtrip_all_code_widths(code_bits):
    """Every packable code width (K = 32/code_bits codes per word) is a
    bit-exact inverse pair at max bit-width for that word size."""
    rng = np.random.default_rng(code_bits)
    n_dev, d = 3, 5000
    gs = jnp.asarray(rng.normal(size=(n_dev, d)), jnp.float32)
    us = jnp.asarray(rng.uniform(size=(n_dev, d)), jnp.float32)
    levels = jnp.full(n_dev, float(2 ** code_bits - 1), jnp.float32)
    pk = ops.quantize_pack(gs, levels, us, code_bits=code_bits)
    assert pk.words.dtype == jnp.uint32
    two_step = ops.dithered_quantize_batch(gs, levels, us)
    np.testing.assert_array_equal(np.asarray(ops.unpack_dequant(pk)),
                                  np.asarray(two_step))


def test_quantize_pack_roundtrip_exact_f64():
    """Same bit-exactness under scoped x64 (the engine's precision)."""
    from jax.experimental import enable_x64
    with enable_x64():
        rng = np.random.default_rng(42)
        gs = jnp.asarray(rng.normal(size=(4, 3001)))
        us = jnp.asarray(rng.uniform(size=(4, 3001)))
        levels = jnp.asarray([255.0, 15.0, 0.0, 7.0])
        assert gs.dtype == jnp.float64
        pk = ops.quantize_pack(gs, levels, us, code_bits=8)
        dec = ops.unpack_dequant(pk)
        assert dec.dtype == jnp.float64
        np.testing.assert_array_equal(
            np.asarray(dec),
            np.asarray(ops.dithered_quantize_batch(gs, levels, us)))


@pytest.mark.parametrize("n_dev,d", [(4, 1000), (8, 200_000), (5, 131_073)])
def test_quantized_weighted_sum_fused_matches_two_step(n_dev, d):
    """Fused kernel == sequential jnp reference == two-step quantize +
    matvec, to accumulation-order tolerance (FMA contraction / summation
    association differ; the payload decode itself is bit-exact). Covers
    the device-blocked launch (n_dev divisible by the group) and the
    tiled fallback (n_dev=5)."""
    rng = np.random.default_rng(n_dev)
    gs = jnp.asarray(rng.normal(size=(n_dev, d)), jnp.float32)
    us = jnp.asarray(rng.uniform(size=(n_dev, d)), jnp.float32)
    levels = jnp.asarray([float(2 ** (1 + (i % 8)) - 1)
                          for i in range(n_dev)], jnp.float32)
    w = jnp.asarray(rng.uniform(0.1, 1.0, size=n_dev), jnp.float32)
    fused_k = ops.quantized_weighted_sum(gs, levels, us, w, r_max=8,
                                         fused=True)
    fused_r = ops.quantized_weighted_sum(gs, levels, us, w, r_max=8,
                                         fused=True, use_kernel=False)
    two_step = ops.quantized_weighted_sum(gs, levels, us, w, fused=False)
    np.testing.assert_allclose(np.asarray(fused_k), np.asarray(fused_r),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(fused_k), np.asarray(two_step),
                               rtol=1e-5, atol=1e-6)


def test_quantized_weighted_sum_degenerate_device_contributes_zero():
    """A device with levels<=0 must drop out of the fused sum exactly."""
    rng = np.random.default_rng(9)
    gs = jnp.asarray(rng.normal(size=(2, 4000)), jnp.float32)
    us = jnp.asarray(rng.uniform(size=(2, 4000)), jnp.float32)
    levels = jnp.asarray([0.0, 255.0], jnp.float32)
    only_dead = ops.quantized_weighted_sum(gs, levels, us,
                                           jnp.asarray([1.0, 0.0]),
                                           r_max=8, fused=True)
    assert not np.any(np.asarray(only_dead))


def test_code_bits_for_mapping():
    """Static code-width dispatch: smallest packable width covering r_max,
    None above 16 bits (no exact f32 round-trip) or when r_max unknown."""
    assert ops.code_bits_for(None) is None
    assert ops.code_bits_for(1) == 4
    assert ops.code_bits_for(4) == 4
    assert ops.code_bits_for(5) == 8
    assert ops.code_bits_for(8) == 8
    assert ops.code_bits_for(9) == 16
    assert ops.code_bits_for(16) == 16
    assert ops.code_bits_for(17) is None


def test_ota_combine_bf16_payload_f32_accumulate():
    """bf16 gradient payload with f32 combine: output is f32 and within
    bf16 representation error of the all-f32 kernel."""
    rng = np.random.default_rng(21)
    g32 = jnp.asarray(rng.normal(size=100_003), jnp.float32)
    z = jnp.asarray(rng.normal(size=100_003), jnp.float32)
    alpha = jnp.asarray(2.5)
    out32 = ops.ota_combine_with_noise(g32, alpha, z)
    out16 = ops.ota_combine_with_noise(g32.astype(jnp.bfloat16), alpha, z,
                                       acc_dtype=jnp.float32)
    assert out16.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(out16), np.asarray(out32),
                               rtol=2e-2, atol=2e-2)


def test_row_maxabs_sumsq_bf16_payload_f32_accumulate():
    """Per-device stats on a bf16 payload accumulate/return in f32 and stay
    within bf16 mantissa error of the f32 stats."""
    rng = np.random.default_rng(22)
    gs32 = jnp.asarray(rng.normal(size=(4, 70_001)), jnp.float32)
    m32, s32 = ops.row_maxabs_sumsq(gs32)
    m16, s16 = ops.row_maxabs_sumsq(gs32.astype(jnp.bfloat16),
                                    acc_dtype=jnp.float32)
    assert m16.dtype == jnp.float32 and s16.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(m16), np.asarray(m32), rtol=1e-2)
    np.testing.assert_allclose(np.asarray(s16), np.asarray(s32), rtol=1e-2)


def test_autotuner_cache_determinism(tuner_cache):
    """One measurement sweep per (kind, rows, dtype, backend); the second
    call is a pure cache hit with the same answer, and candidates above
    the payload's own pow2 row count are never measured."""
    measured = []

    def bench(br):
        def fn():
            measured.append(br)
            return np.zeros(1)
        return fn

    before = autotune.measure_count
    first = autotune.choose_block_rows("testkind", 1000, jnp.float32,
                                       bench=bench)
    n_after_sweep = len(measured)
    second = autotune.choose_block_rows("testkind", 1000, jnp.float32,
                                        bench=bench)
    assert first == second
    assert autotune.measure_count == before + 1
    assert len(measured) == n_after_sweep        # cache hit: no re-measure
    assert set(measured) <= {256, 512, 1024}     # capped at _pow2_fit(1000)
    assert first in set(measured)


def test_autotuner_small_rows_skip_measurement(tuner_cache):
    """Below the legacy tile the deterministic pow2 clamp answers without
    ever invoking the bench."""
    def bench(br):
        raise AssertionError("small payloads must not be measured")

    assert autotune.choose_block_rows("testkind", 100, jnp.float32,
                                      bench=bench) == 128


def test_autotuner_env_disable(tuner_cache, monkeypatch):
    """REPRO_AUTOTUNE=0 pins the legacy fixed tile (determinism hatch)."""
    monkeypatch.setenv("REPRO_AUTOTUNE", "0")

    def bench(br):
        raise AssertionError("disabled tuner must not measure")

    assert autotune.choose_block_rows("testkind", 100_000, jnp.float32,
                                      bench=bench) == autotune.DEFAULT_BLOCK_ROWS
