"""Scenario/Sweep API contract tests.

Covers the four planner/executor guarantees plus serialization:

  * ``ScenarioSpec``/``SweepSpec`` dict <-> object round-trip (exhaustive
    hypothesis property + a hand-written case without hypothesis),
  * planner grouping: a K-point grid issues exactly ONE batched design
    solve per scheme family (no per-point solver calls),
  * content-hash caching: re-executing a finished sweep touches neither
    the design solvers nor the trainer,
  * legacy parity: a 2-point sweep through ``execute()`` reproduces the
    hand-rolled fig2-style pipeline (make_sc_setup -> design_ota ->
    suite -> run_tuned) trajectory-for-trajectory at matching seeds,
  * the strict result encoder (numpy conversions; raises on unknown).
"""
import dataclasses
import json

import numpy as np
import pytest

from repro.api import (ScenarioSpec, SweepSpec, execute, plan,
                       spec_from_dict)
from repro.api.results import SCHEMA_VERSION, dump_json
from repro.api.spec import (DataSpec, DesignPolicy, RunSpec, TaskSpec,
                            spec_hash)
from repro.core import digital_design, ota_design
from repro.core.channel import WirelessConfig
from repro.fl.trainer import FLTrainer

N_DEVICES = 6


def _tiny_scenario(**over) -> ScenarioSpec:
    """A seconds-scale scenario: toy data, fixed kappa, single-point etas."""
    kw = dict(
        name="tiny",
        data=DataSpec(n_train_per_class=60, n_test_per_class=20,
                      samples_per_device=60),
        wireless=WirelessConfig(n_devices=N_DEVICES, seed=1),
        design=DesignPolicy(kappa=3.0),
        run=RunSpec(rounds=6, trials=1, eval_every=3, etas=(1.0,),
                    backend="numpy"),
        schemes=("proposed_ota", "vanilla_ota"))
    kw.update(over)
    return ScenarioSpec(**kw)


# ------------------------------------------------------------ round-trip

def test_round_trip_hand_written():
    spec = _tiny_scenario()
    recovered = ScenarioSpec.from_dict(json.loads(json.dumps(spec.to_dict())))
    assert recovered == spec
    assert recovered.spec_hash() == spec.spec_hash()

    sweep = SweepSpec(name="s", base=spec,
                      axes={"wireless.tx_power_dbm": (-3.0, 3.0),
                            "run.rounds": (4, 8)})
    recovered = SweepSpec.from_dict(json.loads(json.dumps(sweep.to_dict())))
    assert recovered == sweep
    assert spec_from_dict(sweep.to_dict()) == sweep
    assert spec_from_dict(spec.to_dict()) == spec


def test_round_trip_hypothesis():
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")
    given, settings = hyp.given, hyp.settings

    floats = st.floats(allow_nan=False, allow_infinity=False,
                       min_value=-1e6, max_value=1e6)
    pos = st.floats(min_value=1e-3, max_value=1e3)
    ints = st.integers(min_value=1, max_value=1000)

    scenarios = st.builds(
        ScenarioSpec,
        name=st.text(min_size=1, max_size=12),
        task=st.builds(TaskSpec, kind=st.sampled_from(("softmax", "mlp")),
                       n_features=ints, hidden=ints, mu=pos, g_max=pos),
        data=st.builds(DataSpec,
                       image_shape=st.tuples(ints, ints, ints),
                       n_train_per_class=ints, samples_per_device=ints,
                       noise_sigma=pos, dataset_seed=ints,
                       partition_seed=ints),
        wireless=st.builds(WirelessConfig, n_devices=ints,
                           tx_power_dbm=floats, pl_exponent=pos,
                           seed=ints),
        design=st.builds(DesignPolicy,
                         objective=st.sampled_from(
                             ("strongly_convex", "non_convex")),
                         kappa=st.one_of(st.none(), pos),
                         omega_bias_scale=pos, omega_var_scale=pos,
                         t_max_s=pos, top_k=ints),
        run=st.builds(RunSpec, rounds=ints, trials=ints, seed=ints,
                      etas=st.tuples(pos, pos),
                      eta_max=st.one_of(st.none(), pos),
                      batch_size=st.one_of(st.none(), ints),
                      time_budget_s=st.one_of(st.none(), pos)),
        schemes=st.tuples(st.sampled_from(
            ("ideal", "proposed_ota", "vanilla_ota", "suite:fig2_ota"))))

    @settings(max_examples=50, deadline=None)
    @given(spec=scenarios,
           axes=st.dictionaries(
               st.sampled_from(("wireless.tx_power_dbm",
                                "design.omega_bias_scale", "run.rounds")),
               st.lists(floats, min_size=1, max_size=3, unique=True),
               max_size=2))
    def check(spec, axes):
        # object -> dict -> JSON -> dict -> object is the identity
        rt = ScenarioSpec.from_dict(json.loads(json.dumps(spec.to_dict())))
        assert rt == spec
        sweep = SweepSpec(name="p", base=spec, axes=axes)
        rt = SweepSpec.from_dict(json.loads(json.dumps(sweep.to_dict())))
        assert rt == sweep
        assert rt.spec_hash() == sweep.spec_hash()
        assert len(sweep.points()) == sweep.n_points

    check()


def test_override_paths_and_hash_sensitivity():
    spec = _tiny_scenario()
    assert spec.override("wireless.tx_power_dbm", 7.0) \
               .wireless.tx_power_dbm == 7.0
    assert spec.override("run.rounds", 11).run.rounds == 11
    assert spec.override("design.omega_bias_scale", 2.0) \
               .design.omega_bias_scale == 2.0
    with pytest.raises(KeyError):
        spec.override("wireless.nope", 1)
    # content hash distinguishes any changed field
    assert spec.spec_hash() != spec.override("run.seed", 6).spec_hash()
    assert spec_hash(spec.to_dict()) == spec.spec_hash()


def test_numpy_valued_axes_hash_and_plan():
    """np.arange/np.linspace grids are the natural way to declare sweeps;
    hashing must treat numpy scalars like their Python equivalents."""
    base = _tiny_scenario()
    sweep_np = SweepSpec(name="s", base=base,
                         axes={"run.rounds": np.arange(10, 40, 10),
                               "wireless.tx_power_dbm":
                                   np.linspace(-5.0, 5.0, 2)})
    sweep_py = SweepSpec(name="s", base=base,
                         axes={"run.rounds": (10, 20, 30),
                               "wireless.tx_power_dbm": (-5.0, 5.0)})
    assert sweep_np.spec_hash() == sweep_py.spec_hash()
    pl = plan(sweep_np)
    assert len(pl.cells) == 6
    assert [c.cell_hash for c in pl.cells] == \
           [c.cell_hash for c in plan(sweep_py).cells]


# --------------------------------------------------------------- planner

def test_planner_groups_one_batched_solve_per_family():
    base = _tiny_scenario(schemes=("proposed_ota", "proposed_digital"))
    sweep = SweepSpec(name="grid", base=base,
                      axes={"design.omega_bias_scale": (0.5, 1.0, 2.0)})
    pl = plan(sweep)
    assert len(pl.cells) == 3
    assert len(pl.design_groups) == 2            # one per family
    by_family = {g.family: g for g in pl.design_groups}
    assert set(by_family) == {"ota", "digital"}
    for g in by_family.values():
        assert g.batched
        assert g.cell_indices == (0, 1, 2)
        assert g.needs_direct == ()


def test_execute_batches_designs_once_per_family(tmp_path, monkeypatch):
    """K grid points -> exactly one design_*_batch call per family, each
    carrying all K specs (the vmapped sweep-solver contract)."""
    calls = {"ota": [], "digital": []}
    real_ota, real_dig = (ota_design.design_ota_batch,
                          digital_design.design_digital_batch)
    monkeypatch.setattr(
        ota_design, "design_ota_batch",
        lambda specs, **kw: calls["ota"].append(len(specs)) or
        real_ota(specs, **kw))
    monkeypatch.setattr(
        digital_design, "design_digital_batch",
        lambda specs, **kw: calls["digital"].append(len(specs)) or
        real_dig(specs, **kw))

    base = _tiny_scenario(schemes=("proposed_ota", "proposed_digital"))
    sweep = SweepSpec(name="grid", base=base,
                      axes={"design.omega_bias_scale": (0.5, 1.0, 2.0)})
    rs = execute(sweep, out_dir=tmp_path / "rs")
    assert calls == {"ota": [3], "digital": [3]}   # one batched call each
    assert len(rs) == 3
    assert all(c.status == "computed" for c in rs)
    # designs landed per cell and differ across the omega axis
    objs = [c.payload["design"]["ota"]["objective"] for c in rs]
    assert len(set(objs)) == 3


# --------------------------------------------------------------- caching

def test_cache_hit_short_circuits(tmp_path, monkeypatch):
    base = _tiny_scenario()
    sweep = SweepSpec(name="cache", base=base,
                      axes={"design.omega_bias_scale": (1.0, 2.0)})
    out = tmp_path / "rs"
    rs1 = execute(sweep, out_dir=out)
    assert [c.status for c in rs1] == ["computed", "computed"]
    assert (out / "manifest.json").exists()

    def boom(*a, **k):
        raise AssertionError("cached re-run must not solve or simulate")

    monkeypatch.setattr(ota_design, "design_ota_batch", boom)
    monkeypatch.setattr(FLTrainer, "run", boom)
    rs2 = execute(sweep, out_dir=out)
    assert rs2.all_cached
    assert [c.payload["logs"][0]["loss_mean"] for c in rs2] == \
           [c.payload["logs"][0]["loss_mean"] for c in rs1]

    # spec change -> new cell hashes -> cache miss (and with the trainer
    # stubbed out, the miss is observable as the AssertionError)
    changed = SweepSpec(name="cache", base=base.override("run.seed", 99),
                        axes={"design.omega_bias_scale": (1.0, 2.0)})
    with pytest.raises(AssertionError):
        execute(changed, out_dir=out)


def test_interrupted_sweep_persists_finished_cells(tmp_path, monkeypatch):
    """Cells are written the moment they complete: a sweep that dies
    mid-grid resumes from the finished cells, not from scratch."""
    import importlib
    ex = importlib.import_module("repro.api.execute")   # the module (the
    # package attribute `repro.api.execute` is the function, which shadows)
    real = ex._run_cell

    def flaky(cell, ctx):
        if cell.index == 1:
            raise RuntimeError("mid-sweep crash")
        return real(cell, ctx)

    monkeypatch.setattr(ex, "_run_cell", flaky)
    sweep = SweepSpec(name="resume", base=_tiny_scenario(),
                      axes={"design.omega_bias_scale": (1.0, 2.0)})
    with pytest.raises(RuntimeError, match="mid-sweep crash"):
        execute(sweep, out_dir=tmp_path / "rs")

    monkeypatch.setattr(ex, "_run_cell", real)
    rs = execute(sweep, out_dir=tmp_path / "rs")
    assert [c.status for c in rs] == ["cached", "computed"]


def test_partial_cache_recomputes_only_missing(tmp_path):
    base = _tiny_scenario()
    one = SweepSpec(name="grow", base=base,
                    axes={"design.omega_bias_scale": (1.0,)})
    two = SweepSpec(name="grow", base=base,
                    axes={"design.omega_bias_scale": (1.0, 2.0)})
    out = tmp_path / "rs"
    execute(one, out_dir=out)
    rs = execute(two, out_dir=out)     # half-finished sweep: cell 0 cached
    assert [c.status for c in rs] == ["cached", "computed"]


# ---------------------------------------------------------- legacy parity

def test_sweep_reproduces_legacy_fig2_pipeline(tmp_path):
    """A 2-point omega sweep through ``execute()`` matches the legacy
    hand-rolled fig2_ota_sc pipeline (pre-refactor shape: make_sc_setup ->
    batched design -> suite -> run_tuned) per scheme, seed-for-seed."""
    from benchmarks.common import make_sc_setup, run_tuned
    from repro.core import baselines as B
    from repro.core.bounds import ObjectiveWeights

    n, rounds, trials, eval_every = N_DEVICES, 6, 2, 3
    etas = (1.0, 0.25)
    scales = (1.0, 4.0)

    # -- legacy path: one hand-rolled pipeline per omega_bias scale
    legacy = []
    task, ds, dep, eta_max = make_sc_setup(n, samples_per_device=60,
                                           n_train_per_class=60)
    for scale in scales:
        w = ObjectiveWeights.strongly_convex(eta=eta_max, mu=task.mu,
                                             kappa_sc=3.0, n=n)
        w = ObjectiveWeights(omega_var=w.omega_var,
                             omega_bias=w.omega_bias * scale)
        dspec = ota_design.OTADesignSpec(
            lambdas=dep.lambdas, dim=task.dim, g_max=task.g_max,
            e_s=dep.cfg.energy_per_symbol, n0=dep.cfg.noise_power,
            weights=w)
        params, _ = ota_design.design_ota_batch([dspec])
        cell_logs = {}
        for key, agg in (("ideal", B.IdealFedAvg()),
                         ("proposed_ota", B.ProposedOTA(params[0])),
                         ("vanilla_ota", B.VanillaOTA(
                             task.dim, task.g_max,
                             dep.cfg.energy_per_symbol,
                             dep.cfg.noise_power))):
            log, best_eta = run_tuned(task, ds, dep, agg, eta_max=eta_max,
                                      rounds=rounds, trials=trials,
                                      eval_every=eval_every, etas=etas,
                                      backend="numpy")
            cell_logs[key] = (log, best_eta)
        legacy.append(cell_logs)

    # -- declarative path: the same protocol as a 2-point sweep
    base = _tiny_scenario(
        name="fig2_mini",
        # exactly make_sc_setup's data protocol (incl. its 200-per-class
        # test split; _tiny_scenario shrinks it for the other tests)
        data=DataSpec(n_train_per_class=60, n_test_per_class=200,
                      samples_per_device=60),
        run=RunSpec(rounds=rounds, trials=trials, eval_every=eval_every,
                    etas=etas, backend="numpy"),
        schemes=("ideal", "proposed_ota", "vanilla_ota"))
    sweep = SweepSpec(name="fig2_mini", base=base,
                      axes={"design.omega_bias_scale": scales})
    rs = execute(sweep, out_dir=tmp_path / "rs")

    assert len(rs) == len(scales)
    for cell, cell_logs in zip(rs, legacy):
        for rec in cell.payload["logs"]:
            log, best_eta = cell_logs[rec["scheme_key"]]
            assert rec["eta"] == pytest.approx(best_eta, rel=1e-12)
            np.testing.assert_allclose(rec["loss_mean"],
                                       log.global_loss.mean(0), rtol=1e-5)
            np.testing.assert_allclose(rec["acc_mean"],
                                       log.accuracy.mean(0), rtol=1e-5)
            np.testing.assert_allclose(rec["wall_time_s"],
                                       np.asarray(log.wall_time_s),
                                       rtol=1e-5, atol=1e-12)


# --------------------------------------------------------- strict encoder

def test_strict_encoder_handles_numpy_and_raises_on_unknown():
    payload = {"i": np.int64(3), "f": np.float32(1.5), "b": np.bool_(True),
               "a": np.arange(3), "nested": {"x": np.float64(2.0)}}
    out = json.loads(dump_json(payload))
    assert out == {"i": 3, "f": 1.5, "b": True, "a": [0, 1, 2],
                   "nested": {"x": 2.0}}
    assert isinstance(out["b"], bool)      # default=float coerced to 1.0

    class Opaque:
        def __float__(self):               # float()-coercible on purpose:
            return 0.0                     # the legacy encoder ate these

    with pytest.raises(TypeError, match="Opaque"):
        dump_json({"bad": Opaque()})
    with pytest.raises(TypeError):
        dump_json({"cfg": WirelessConfig()})


def test_save_result_stamps_schema_version(tmp_path, monkeypatch):
    import benchmarks.common as common
    monkeypatch.setattr(common, "RESULTS_DIR", tmp_path)
    common.save_result("x", {"v": np.float64(1.0)})
    saved = json.loads((tmp_path / "x.json").read_text())
    assert saved["schema_version"] == SCHEMA_VERSION
    assert saved["v"] == 1.0


def test_cell_payloads_are_schema_versioned(tmp_path):
    rs = execute(_tiny_scenario(), out_dir=tmp_path / "rs")
    payload = rs.cell(0).payload
    assert payload["schema_version"] == SCHEMA_VERSION
    assert payload["kind"] == "scenario_cell"
    on_disk = json.loads(rs.cell(0).path.read_text())
    assert on_disk == json.loads(dump_json(payload))   # tuples -> lists
