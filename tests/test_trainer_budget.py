"""Regression tests for FLTrainer's time-budget freeze path.

The freeze must anchor on the last *written* eval slot — never on
uninitialized array slots — and every frozen eval must replicate that
anchor exactly (loss/accuracy/opt-error), with the wall-clock pinned at
the budget-exhaustion time.
"""
import numpy as np
import pytest

from repro.core import baselines as B
from repro.core.channel import WirelessConfig, make_deployment
from repro.data.loader import FLDataset
from repro.data.partition import partition_by_class
from repro.data.synthetic import SyntheticSpec, make_classification_dataset
from repro.fl.tasks import SoftmaxRegressionTask
from repro.fl.trainer import FLTrainer


@pytest.fixture(scope="module")
def setup():
    spec = SyntheticSpec(n_train_per_class=60, n_test_per_class=20,
                         noise_sigma=1.5)
    x_tr, y_tr, x_te, y_te = make_classification_dataset(spec)
    shards = partition_by_class(x_tr, y_tr, 6, 1, 60, seed=3)
    ds = FLDataset.from_shards(shards, x_te, y_te)
    task = SoftmaxRegressionTask(n_features=784, mu=0.01, g_max=20.0)
    dep = make_deployment(WirelessConfig(n_devices=6, seed=1))
    eta = 0.5 / (task.mu + task.smooth_l)
    return task, ds, dep, eta


def test_budget_trips_mid_grid_freezes_last_written(setup):
    """Budget exhausted at a round *between* eval points: the frozen tail
    must equal the last eval actually written, not a stale/unwritten slot."""
    task, ds, dep, eta = setup
    tr = FLTrainer(task, ds, dep, eta=eta)
    # OTA latency is d/B per round; budget for ~1.5 rounds trips at t=2,
    # strictly between the eval grid points 0 and 4 (IdealFedAvg is free,
    # so use a scheme that actually spends airtime)
    agg = B.VanillaOTA(task.dim, task.g_max, dep.cfg.energy_per_symbol,
                       dep.cfg.noise_power)
    per_round = task.dim / dep.cfg.bandwidth_hz
    log = tr.run(agg, rounds=12, trials=2, eval_every=4, seed=0,
                 w_star=np.zeros(task.dim),
                 time_budget_s=1.5 * per_round)
    assert list(log.rounds) == [0, 4, 8, 12]
    for trial in range(2):
        # only the t=0 eval ran; every later slot is frozen to it
        for j in range(1, 4):
            assert log.global_loss[trial, j] == log.global_loss[trial, 0]
            assert log.accuracy[trial, j] == log.accuracy[trial, 0]
            assert log.opt_error[trial, j] == log.opt_error[trial, 0]
    assert np.all(np.isfinite(log.global_loss))
    # frozen wall-clock records when the budget tripped (2 rounds elapsed)
    np.testing.assert_allclose(np.asarray(log.wall_time_s)[1:],
                               2 * per_round, rtol=1e-12)


def test_budget_zero_freezes_initial_eval(setup):
    """A zero budget trips immediately after the t=0 eval; all slots must
    equal the initial-model eval (the ei-1 underflow regression)."""
    task, ds, dep, eta = setup
    tr = FLTrainer(task, ds, dep, eta=eta)
    log = tr.run(B.IdealFedAvg(), rounds=8, trials=1, eval_every=2, seed=0,
                 time_budget_s=0.0)
    assert np.all(log.global_loss == log.global_loss[:, :1])
    assert np.all(log.accuracy == log.accuracy[:, :1])
    assert np.all(np.asarray(log.wall_time_s) == 0.0)


def test_budget_generous_matches_unbudgeted(setup):
    """A budget that never trips must not change the trajectory."""
    task, ds, dep, eta = setup
    tr = FLTrainer(task, ds, dep, eta=eta)
    log_a = tr.run(B.IdealFedAvg(), rounds=8, trials=1, eval_every=4, seed=3,
                   backend="numpy")
    log_b = tr.run(B.IdealFedAvg(), rounds=8, trials=1, eval_every=4, seed=3,
                   time_budget_s=1e9)
    np.testing.assert_array_equal(log_a.global_loss, log_b.global_loss)
    np.testing.assert_array_equal(np.asarray(log_a.wall_time_s),
                                  np.asarray(log_b.wall_time_s))
