"""Regression tests for FLTrainer's time-budget freeze path.

The freeze must anchor on the last *written* eval slot — never on
uninitialized array slots — and every frozen eval must replicate that
anchor exactly (loss/accuracy/opt-error), with the wall-clock pinned at
the budget-exhaustion time. Since the engine port of mini-batching/time
budgets, both backends implement these semantics (the NumPy loop by
break-and-copy, the engine by an in-scan freeze mask), so the tests run
parametrized over ``backend``.
"""
import numpy as np
import pytest

from repro.core import baselines as B
from repro.core.channel import WirelessConfig, make_deployment
from repro.data.loader import FLDataset
from repro.data.partition import partition_by_class
from repro.data.synthetic import SyntheticSpec, make_classification_dataset
from repro.fl.tasks import SoftmaxRegressionTask
from repro.fl.trainer import FLTrainer

BACKENDS = ("numpy", "jax")


@pytest.fixture(scope="module")
def setup():
    spec = SyntheticSpec(n_train_per_class=60, n_test_per_class=20,
                         noise_sigma=1.5)
    x_tr, y_tr, x_te, y_te = make_classification_dataset(spec)
    shards = partition_by_class(x_tr, y_tr, 6, 1, 60, seed=3)
    ds = FLDataset.from_shards(shards, x_te, y_te)
    task = SoftmaxRegressionTask(n_features=784, mu=0.01, g_max=20.0)
    dep = make_deployment(WirelessConfig(n_devices=6, seed=1))
    eta = 0.5 / (task.mu + task.smooth_l)
    return task, ds, dep, eta


@pytest.mark.parametrize("backend", BACKENDS)
def test_budget_trips_mid_grid_freezes_last_written(setup, backend):
    """Budget exhausted at a round *between* eval points: the frozen tail
    must equal the last eval actually written, not a stale/unwritten slot."""
    task, ds, dep, eta = setup
    tr = FLTrainer(task, ds, dep, eta=eta)
    # OTA latency is d/B per round; budget for ~1.5 rounds trips at t=2,
    # strictly between the eval grid points 0 and 4 (IdealFedAvg is free,
    # so use a scheme that actually spends airtime)
    agg = B.VanillaOTA(task.dim, task.g_max, dep.cfg.energy_per_symbol,
                       dep.cfg.noise_power)
    per_round = task.dim / dep.cfg.bandwidth_hz
    log = tr.run(agg, rounds=12, trials=2, eval_every=4, seed=0,
                 w_star=np.zeros(task.dim),
                 time_budget_s=1.5 * per_round, backend=backend)
    assert list(log.rounds) == [0, 4, 8, 12]
    for trial in range(2):
        # only the t=0 eval ran; every later slot is frozen to it
        for j in range(1, 4):
            assert log.global_loss[trial, j] == log.global_loss[trial, 0]
            assert log.accuracy[trial, j] == log.accuracy[trial, 0]
            assert log.opt_error[trial, j] == log.opt_error[trial, 0]
    assert np.all(np.isfinite(log.global_loss))
    # frozen wall-clock records when the budget tripped (2 rounds elapsed)
    np.testing.assert_allclose(np.asarray(log.wall_time_s)[1:],
                               2 * per_round, rtol=1e-12)


@pytest.mark.parametrize("backend", BACKENDS)
def test_budget_zero_freezes_initial_eval(setup, backend):
    """A zero budget trips immediately after the t=0 eval; all slots must
    equal the initial-model eval (the ei-1 underflow regression — the
    ``ei >= 1`` invariant: the t=0 eval is always written before the first
    budget check, in both backends)."""
    task, ds, dep, eta = setup
    tr = FLTrainer(task, ds, dep, eta=eta)
    log = tr.run(B.IdealFedAvg(), rounds=8, trials=1, eval_every=2, seed=0,
                 time_budget_s=0.0, backend=backend)
    assert np.all(log.global_loss == log.global_loss[:, :1])
    assert np.all(log.accuracy == log.accuracy[:, :1])
    assert np.all(np.asarray(log.wall_time_s) == 0.0)


@pytest.mark.parametrize("backend", BACKENDS)
def test_budget_generous_matches_unbudgeted(setup, backend):
    """A budget that never trips must not change the trajectory."""
    task, ds, dep, eta = setup
    tr = FLTrainer(task, ds, dep, eta=eta)
    log_a = tr.run(B.IdealFedAvg(), rounds=8, trials=1, eval_every=4, seed=3,
                   backend=backend)
    log_b = tr.run(B.IdealFedAvg(), rounds=8, trials=1, eval_every=4, seed=3,
                   time_budget_s=1e9, backend=backend)
    np.testing.assert_array_equal(log_a.global_loss, log_b.global_loss)
    np.testing.assert_array_equal(np.asarray(log_a.wall_time_s),
                                  np.asarray(log_b.wall_time_s))


def test_jax_backend_accepts_budget_and_minibatch(setup):
    """backend="jax" no longer raises for time_budget_s / batch_size — the
    regimes that used to silently fall back to the NumPy loop."""
    task, ds, dep, eta = setup
    agg = B.VanillaOTA(task.dim, task.g_max, dep.cfg.energy_per_symbol,
                       dep.cfg.noise_power)
    per_round = task.dim / dep.cfg.bandwidth_hz
    tr = FLTrainer(task, ds, dep, eta=eta, batch_size=16)
    log = tr.run(agg, rounds=8, trials=1, eval_every=4, seed=0,
                 time_budget_s=3.5 * per_round, backend="jax")
    assert tr._engine is not None and tr._engine.batch_size == 16
    assert np.all(np.isfinite(log.global_loss))
    # budget for 3.5 rounds: t=4 eval live, t=8 frozen to it
    assert log.global_loss[0, 2] == log.global_loss[0, 1]
    assert log.global_loss[0, 1] != log.global_loss[0, 0]
    np.testing.assert_allclose(np.asarray(log.wall_time_s)[-1],
                               4 * per_round, rtol=1e-12)


def test_engine_budget_freeze_matches_oracle_exactly(setup):
    """Cross-backend: identical freeze round, frozen eval values, and
    pinned wall-clock on a budget that trips mid-run."""
    task, ds, dep, eta = setup
    agg = B.VanillaOTA(task.dim, task.g_max, dep.cfg.energy_per_symbol,
                       dep.cfg.noise_power)
    per_round = task.dim / dep.cfg.bandwidth_hz
    tr = FLTrainer(task, ds, dep, eta=eta)
    logs = {bk: tr.run(agg, rounds=12, trials=2, eval_every=4, seed=1,
                       time_budget_s=6.5 * per_round, backend=bk)
            for bk in BACKENDS}
    np.testing.assert_allclose(logs["jax"].global_loss,
                               logs["numpy"].global_loss,
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(logs["jax"].wall_time_s),
                               np.asarray(logs["numpy"].wall_time_s),
                               rtol=1e-5, atol=1e-5)
    # both froze after round 7 (budget = 6.5 rounds of airtime)
    for log in logs.values():
        assert np.all(log.global_loss[:, 2:] == log.global_loss[:, 1:2])
