"""Parallel sweep executor contracts (``execute(..., jobs=K)``).

The process pool must be *invisible* in the artifacts: a ``jobs=2`` run
of a sweep produces a manifest and per-cell payloads identical to the
serial run (modulo wall-clock timings), cached re-runs stay no-ops
without spawning anything, a half-finished sweep resumes from the cells
that completed — including when the unfinished half died inside a
worker — and the dependency-ordered schedule keeps every design-group
solve ahead of its dependent cells.

(These tests live in a real file on purpose: the pool uses the spawn
start method, which re-imports ``__main__`` in each worker.)
"""
import json

import pytest

from repro.api import ScenarioSpec, SweepSpec, execute, plan
from repro.api.spec import DataSpec, DesignPolicy, RunSpec
from repro.core.channel import WirelessConfig
from repro.fl.trainer import FLTrainer

N_DEVICES = 6


def _tiny(**over) -> ScenarioSpec:
    """Seconds-scale scenario (mirrors test_scenario_api's tiny cell)."""
    kw = dict(
        name="tiny_par",
        data=DataSpec(n_train_per_class=60, n_test_per_class=20,
                      samples_per_device=60),
        wireless=WirelessConfig(n_devices=N_DEVICES, seed=1),
        design=DesignPolicy(kappa=3.0),
        run=RunSpec(rounds=6, trials=1, eval_every=3, etas=(1.0,),
                    backend="numpy"),
        schemes=("proposed_ota", "vanilla_ota"))
    kw.update(over)
    return ScenarioSpec(**kw)


def _grid() -> SweepSpec:
    """2x2 grid with a designed scheme: exercises the design-pack path."""
    return SweepSpec(name="par_grid", base=_tiny(),
                     axes={"wireless.tx_power_dbm": (-3.0, 3.0),
                           "design.omega_bias_scale": (1.0, 2.0)})


def _strip(obj):
    """Drop wall-clock fields recursively (the only sanctioned delta)."""
    if isinstance(obj, dict):
        return {k: _strip(v) for k, v in obj.items() if k != "elapsed_s"}
    if isinstance(obj, (list, tuple)):
        return [_strip(v) for v in obj]
    return obj


def test_parallel_manifest_matches_serial(tmp_path):
    sweep = _grid()
    rs_ser = execute(sweep, out_dir=tmp_path / "serial")
    rs_par = execute(sweep, out_dir=tmp_path / "par", jobs=2)
    assert [c.status for c in rs_par] == ["computed"] * 4
    assert _strip(rs_par.manifest) == _strip(rs_ser.manifest)
    for cs, cp in zip(rs_ser, rs_par):
        assert cp.cell_hash == cs.cell_hash
        assert _strip(cp.payload) == _strip(cs.payload)
    # and so are the artifacts both runs put on disk
    for cp in rs_par:
        a = json.loads(cp.path.read_text())
        b = json.loads((tmp_path / "serial" / "cells"
                        / f"{cp.cell_hash}.json").read_text())
        assert _strip(a) == _strip(b)


def test_parallel_cached_rerun_is_noop(tmp_path, monkeypatch):
    sweep = _grid()
    out = tmp_path / "rs"
    execute(sweep, out_dir=out, jobs=2)

    def boom(*a, **k):
        raise AssertionError("cached parallel re-run must not simulate")

    # with every cell cached there is nothing to pool — the stubbed
    # trainer proves no simulation happens in-process either
    monkeypatch.setattr(FLTrainer, "run", boom)
    rs = execute(sweep, out_dir=out, jobs=2)
    assert rs.all_cached


def test_parallel_resumes_partial_sweep(tmp_path):
    """Serial half-sweep, then the full grid with jobs=2: the finished
    cells load from cache, only the missing half hits the pool."""
    base = _tiny()
    half = SweepSpec(name="par_grid", base=base,
                     axes={"wireless.tx_power_dbm": (-3.0,),
                           "design.omega_bias_scale": (1.0, 2.0)})
    out = tmp_path / "rs"
    execute(half, out_dir=out)
    rs = execute(_grid(), out_dir=out, jobs=2)
    statuses = {c.overrides["wireless.tx_power_dbm"]: c.status for c in rs}
    assert [c.status for c in rs].count("cached") == 2
    assert statuses[-3.0] == "cached" and statuses[3.0] == "computed"


def test_worker_failure_is_collected_and_resumable(tmp_path):
    """One cell fails inside a worker (invalid run.rng only trips at run
    time): execute raises *after* collecting, the good cell's artifact is
    on disk, and a corrected re-run resumes from it."""
    base = _tiny(schemes=("vanilla_ota",))
    bad = SweepSpec(name="par_bad", base=base,
                    axes={"run.rng": ("replay", "bogus")})
    out = tmp_path / "rs"
    with pytest.raises(RuntimeError, match="failed in workers"):
        execute(bad, out_dir=out, jobs=2)
    good_hash = plan(SweepSpec(name="par_bad", base=base,
                               axes={"run.rng": ("replay",)})).cells[0] \
        .cell_hash
    assert (out / "cells" / f"{good_hash}.json").exists()
    rs = execute(SweepSpec(name="par_bad", base=base,
                           axes={"run.rng": ("replay",)}),
                 out_dir=out, jobs=2)
    assert [c.status for c in rs] == ["cached"]


def test_jobs_validation(tmp_path):
    with pytest.raises(ValueError, match="jobs"):
        execute(_tiny(), out_dir=tmp_path / "rs", jobs=0)


def test_schedule_orders_designs_before_dependent_cells():
    """Every design group appears in the schedule before any cell that
    needs its parameters — the invariant both executors walk."""
    pl = plan(_grid())
    assert pl.design_groups
    solved = set()
    seen_cells = set()
    for kind, item in pl.schedule():
        if kind == "design":
            assert not (set(item.cell_indices) & seen_cells), \
                "design group scheduled after a dependent cell"
            solved.add(id(item))
        else:
            seen_cells.add(item.index)
    assert len(solved) == len(pl.design_groups)
    assert len(seen_cells) == len(pl.cells)
