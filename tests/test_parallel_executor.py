"""Parallel sweep executor contracts (``execute(..., jobs=K)``).

The process pool must be *invisible* in the artifacts: a ``jobs=2`` run
of a sweep produces a manifest and per-cell payloads identical to the
serial run (modulo wall-clock timings), cached re-runs stay no-ops
without spawning anything, a half-finished sweep resumes from the cells
that completed — including when the unfinished half died inside a
worker — and the dependency-ordered schedule keeps every design-group
solve ahead of its dependent cells. The supervisor hardening rides the
same contracts: a SIGKILLed worker's cell is requeued and the manifest
still matches serial; a hung cell surfaces as ``status="timeout"``
instead of wedging the sweep; a corrupt cache cell is quarantined to
``<hash>.json.bad`` and recomputed.

(These tests live in a real file on purpose: the pool uses the spawn
start method, which re-imports ``__main__`` in each worker.)
"""
import json

import pytest

from repro.api import ScenarioSpec, SweepSpec, execute, plan
from repro.api.spec import DataSpec, DesignPolicy, RunSpec
from repro.core.channel import WirelessConfig
from repro.fl.trainer import FLTrainer

N_DEVICES = 6


def _tiny(**over) -> ScenarioSpec:
    """Seconds-scale scenario (mirrors test_scenario_api's tiny cell)."""
    kw = dict(
        name="tiny_par",
        data=DataSpec(n_train_per_class=60, n_test_per_class=20,
                      samples_per_device=60),
        wireless=WirelessConfig(n_devices=N_DEVICES, seed=1),
        design=DesignPolicy(kappa=3.0),
        run=RunSpec(rounds=6, trials=1, eval_every=3, etas=(1.0,),
                    backend="numpy"),
        schemes=("proposed_ota", "vanilla_ota"))
    kw.update(over)
    return ScenarioSpec(**kw)


def _grid() -> SweepSpec:
    """2x2 grid with a designed scheme: exercises the design-pack path."""
    return SweepSpec(name="par_grid", base=_tiny(),
                     axes={"wireless.tx_power_dbm": (-3.0, 3.0),
                           "design.omega_bias_scale": (1.0, 2.0)})


def _strip(obj):
    """Drop wall-clock fields recursively (the only sanctioned delta)."""
    if isinstance(obj, dict):
        return {k: _strip(v) for k, v in obj.items() if k != "elapsed_s"}
    if isinstance(obj, (list, tuple)):
        return [_strip(v) for v in obj]
    return obj


def test_parallel_manifest_matches_serial(tmp_path):
    sweep = _grid()
    rs_ser = execute(sweep, out_dir=tmp_path / "serial")
    rs_par = execute(sweep, out_dir=tmp_path / "par", jobs=2)
    assert [c.status for c in rs_par] == ["computed"] * 4
    assert _strip(rs_par.manifest) == _strip(rs_ser.manifest)
    for cs, cp in zip(rs_ser, rs_par):
        assert cp.cell_hash == cs.cell_hash
        assert _strip(cp.payload) == _strip(cs.payload)
    # and so are the artifacts both runs put on disk
    for cp in rs_par:
        a = json.loads(cp.path.read_text())
        b = json.loads((tmp_path / "serial" / "cells"
                        / f"{cp.cell_hash}.json").read_text())
        assert _strip(a) == _strip(b)


def test_parallel_cached_rerun_is_noop(tmp_path, monkeypatch):
    sweep = _grid()
    out = tmp_path / "rs"
    execute(sweep, out_dir=out, jobs=2)

    def boom(*a, **k):
        raise AssertionError("cached parallel re-run must not simulate")

    # with every cell cached there is nothing to pool — the stubbed
    # trainer proves no simulation happens in-process either
    monkeypatch.setattr(FLTrainer, "run", boom)
    rs = execute(sweep, out_dir=out, jobs=2)
    assert rs.all_cached


def test_parallel_resumes_partial_sweep(tmp_path):
    """Serial half-sweep, then the full grid with jobs=2: the finished
    cells load from cache, only the missing half hits the pool."""
    base = _tiny()
    half = SweepSpec(name="par_grid", base=base,
                     axes={"wireless.tx_power_dbm": (-3.0,),
                           "design.omega_bias_scale": (1.0, 2.0)})
    out = tmp_path / "rs"
    execute(half, out_dir=out)
    rs = execute(_grid(), out_dir=out, jobs=2)
    statuses = {c.overrides["wireless.tx_power_dbm"]: c.status for c in rs}
    assert [c.status for c in rs].count("cached") == 2
    assert statuses[-3.0] == "cached" and statuses[3.0] == "computed"


def test_worker_failure_is_collected_and_resumable(tmp_path):
    """One cell fails inside a worker (invalid run.rng only trips at run
    time): execute raises *after* collecting, the good cell's artifact is
    on disk, and a corrected re-run resumes from it."""
    base = _tiny(schemes=("vanilla_ota",))
    bad = SweepSpec(name="par_bad", base=base,
                    axes={"run.rng": ("replay", "bogus")})
    out = tmp_path / "rs"
    with pytest.raises(RuntimeError, match="failed in workers"):
        execute(bad, out_dir=out, jobs=2)
    good_hash = plan(SweepSpec(name="par_bad", base=base,
                               axes={"run.rng": ("replay",)})).cells[0] \
        .cell_hash
    assert (out / "cells" / f"{good_hash}.json").exists()
    rs = execute(SweepSpec(name="par_bad", base=base,
                           axes={"run.rng": ("replay",)}),
                 out_dir=out, jobs=2)
    assert [c.status for c in rs] == ["cached"]


def test_jobs_validation(tmp_path):
    with pytest.raises(ValueError, match="jobs"):
        execute(_tiny(), out_dir=tmp_path / "rs", jobs=0)
    with pytest.raises(ValueError, match="retries"):
        execute(_tiny(), out_dir=tmp_path / "rs", retries=-1)
    with pytest.raises(ValueError, match="cell_timeout_s"):
        execute(_tiny(), out_dir=tmp_path / "rs", cell_timeout_s=0.0)


def test_chaos_worker_kill_is_recovered(tmp_path, monkeypatch):
    """SIGKILL one worker mid-cell (env-gated chaos hook, fires exactly
    once): the supervisor requeues the cell on a fresh worker and the
    sweep completes with a manifest identical to the serial run."""
    sweep = _grid()
    rs_ser = execute(sweep, out_dir=tmp_path / "serial")
    kill_dir = tmp_path / "chaos"
    kill_dir.mkdir()
    monkeypatch.setenv("REPRO_CHAOS_KILL_DIR", str(kill_dir))
    rs_par = execute(sweep, out_dir=tmp_path / "par", jobs=2)
    assert (kill_dir / "killed").exists(), "chaos hook never fired"
    assert [c.status for c in rs_par] == ["computed"] * 4
    assert _strip(rs_par.manifest) == _strip(rs_ser.manifest)
    for cs, cp in zip(rs_ser, rs_par):
        assert _strip(cp.payload) == _strip(cs.payload)


def test_chaos_worker_crash_exhausts_retries_and_raises(tmp_path,
                                                        monkeypatch):
    """With retries=0, a killed worker's cell has no second chance: the
    sweep raises (crash != timeout — losing a worker with retries
    exhausted is an error, not a quietly missing cell)."""
    kill_dir = tmp_path / "chaos"
    kill_dir.mkdir()
    monkeypatch.setenv("REPRO_CHAOS_KILL_DIR", str(kill_dir))
    with pytest.raises(RuntimeError, match="failed in workers"):
        execute(_tiny(), out_dir=tmp_path / "rs", jobs=2, retries=0)


def test_chaos_hung_cell_times_out_not_hangs(tmp_path, monkeypatch):
    """A cell that never returns surfaces as status="timeout" (empty
    payload, no cells/<hash>.json, no exception) instead of wedging the
    sweep; the other cell of the grid still completes."""
    base = _tiny(schemes=("vanilla_ota",))
    sweep = SweepSpec(name="par_hang", base=base,
                      axes={"wireless.tx_power_dbm": (-3.0, 3.0)})
    hang_hash = plan(sweep).cells[0].cell_hash
    monkeypatch.setenv("REPRO_CHAOS_HANG_HASH", hang_hash)
    out = tmp_path / "rs"
    rs = execute(sweep, out_dir=out, jobs=2, cell_timeout_s=1.5, retries=0)
    by_hash = {c.cell_hash: c for c in rs}
    hung = by_hash[hang_hash]
    assert hung.status == "timeout" and hung.payload == {}
    assert hung.path is None
    assert not (out / "cells" / f"{hang_hash}.json").exists()
    others = [c for c in rs if c.cell_hash != hang_hash]
    assert [c.status for c in others] == ["computed"]
    manifest = json.loads((out / "manifest.json").read_text())
    row = next(r for r in manifest["cells"] if r["cell_hash"] == hang_hash)
    assert row["status"] == "timeout" and row["elapsed_s"] is None
    # the timed-out cell is not cached: a clean re-run computes it
    monkeypatch.delenv("REPRO_CHAOS_HANG_HASH")
    rs2 = execute(sweep, out_dir=out, jobs=2)
    assert {c.cell_hash: c.status for c in rs2} == {
        hang_hash: "computed", others[0].cell_hash: "cached"}


def test_corrupt_cache_cell_is_quarantined_and_recomputed(tmp_path):
    """A truncated/corrupt cells/<hash>.json must not poison the sweep:
    it is moved to <hash>.json.bad and the cell recomputes."""
    out = tmp_path / "rs"
    rs = execute(_tiny(), out_dir=out)
    cell = rs.cells[0]
    path = out / "cells" / f"{cell.cell_hash}.json"
    path.write_text('{"schema_version": 5, "truncated')
    rs2 = execute(_tiny(), out_dir=out)
    assert rs2.cells[0].status == "computed"
    bad = out / "cells" / f"{cell.cell_hash}.json.bad"
    assert bad.exists()
    assert bad.read_text().startswith('{"schema_version": 5, "truncated')
    # the fresh artifact is valid JSON again and a re-run is a cache hit
    json.loads(path.read_text())
    assert execute(_tiny(), out_dir=out).cells[0].status == "cached"


def test_schedule_orders_designs_before_dependent_cells():
    """Every design group appears in the schedule before any cell that
    needs its parameters — the invariant both executors walk."""
    pl = plan(_grid())
    assert pl.design_groups
    solved = set()
    seen_cells = set()
    for kind, item in pl.schedule():
        if kind == "design":
            assert not (set(item.cell_indices) & seen_cells), \
                "design group scheduled after a dependent cell"
            solved.add(id(item))
        else:
            seen_cells.add(item.index)
    assert len(solved) == len(pl.design_groups)
    assert len(seen_cells) == len(pl.cells)
