"""Substrate tests: data pipeline, checkpointing, optimizer, projection."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.data.synthetic import SyntheticSpec, make_classification_dataset
from repro.data.partition import partition_by_class, partition_iid
from repro.checkpoint import save_checkpoint, restore_checkpoint, latest_step
from repro.optim.sgd import SGDConfig, sgd_init, sgd_update
from repro.optim.projection import project_l2_ball


class TestData:
    def test_dataset_deterministic(self):
        spec = SyntheticSpec(n_train_per_class=20, n_test_per_class=5)
        a = make_classification_dataset(spec)
        b = make_classification_dataset(spec)
        for x, y in zip(a, b):
            np.testing.assert_array_equal(x, y)

    def test_single_class_partition(self):
        spec = SyntheticSpec(n_train_per_class=100, n_test_per_class=5)
        x, y, _, _ = make_classification_dataset(spec)
        shards = partition_by_class(x, y, 10, 1, 80, seed=0)
        assert len(shards) == 10
        covered = set()
        for sx, sy in shards:
            assert sx.shape[0] == 80
            assert len(np.unique(sy)) == 1         # exactly one class
            covered.add(int(sy[0]))
        assert covered == set(range(10))           # all classes present

    def test_two_class_partition(self):
        spec = SyntheticSpec(n_train_per_class=100, n_test_per_class=5)
        x, y, _, _ = make_classification_dataset(spec)
        shards = partition_by_class(x, y, 10, 2, 80, seed=0)
        for sx, sy in shards:
            assert len(np.unique(sy)) == 2

    def test_iid_partition_no_overlap(self):
        spec = SyntheticSpec(n_train_per_class=50, n_test_per_class=5)
        x, y, _, _ = make_classification_dataset(spec)
        shards = partition_iid(x, y, 5, 40, seed=0)
        seen = [tuple(s[0][i].tobytes() for i in range(5)) for s in shards]
        assert len(set(seen)) == 5


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        params = {"a": jnp.arange(6.0).reshape(2, 3),
                  "nested": {"b": jnp.ones((4,), jnp.int32)}}
        save_checkpoint(tmp_path, 3, params)
        save_checkpoint(tmp_path, 7, jax.tree.map(lambda x: x + 1, params))
        assert latest_step(tmp_path) == 7
        restored = restore_checkpoint(tmp_path, 7, params)
        np.testing.assert_allclose(np.asarray(restored["a"]),
                                   np.arange(6.0).reshape(2, 3) + 1)
        np.testing.assert_array_equal(np.asarray(restored["nested"]["b"]),
                                      np.ones(4) + 1)

    def test_missing_raises(self, tmp_path):
        with pytest.raises(FileNotFoundError):
            latest_step(tmp_path)


class TestOptim:
    def test_sgd_plain(self):
        cfg = SGDConfig(eta=0.1)
        params = {"w": jnp.ones(3)}
        grads = {"w": jnp.full(3, 2.0)}
        new, _ = sgd_update(cfg, params, grads, sgd_init(params))
        np.testing.assert_allclose(np.asarray(new["w"]), 0.8)

    def test_sgd_momentum_accumulates(self):
        cfg = SGDConfig(eta=0.1, momentum=0.9)
        params = {"w": jnp.zeros(2)}
        mom = sgd_init(params)
        grads = {"w": jnp.ones(2)}
        p1, mom = sgd_update(cfg, params, grads, mom)
        p2, mom = sgd_update(cfg, p1, grads, mom)
        # second step is larger due to momentum
        assert abs(float(p2["w"][0] - p1["w"][0])) > abs(float(p1["w"][0]))

    def test_projection_inside_ball_identity(self):
        params = {"w": jnp.ones(4)}      # ||w|| = 2
        out = project_l2_ball(params, radius=5.0)
        np.testing.assert_allclose(np.asarray(out["w"]), 1.0)

    def test_projection_scales_to_radius(self):
        params = {"w": jnp.full(4, 10.0)}    # ||w|| = 20
        out = project_l2_ball(params, radius=2.0)
        nrm = float(jnp.linalg.norm(out["w"]))
        assert nrm == pytest.approx(2.0, rel=1e-5)


class TestAdam:
    def test_adam_decreases_quadratic(self):
        from repro.optim.adam import AdamConfig, adam_init, adam_update
        cfg = AdamConfig(eta=0.1)
        params = {"w": jnp.array([5.0, -3.0])}
        state = adam_init(params)
        for _ in range(200):
            grads = {"w": 2.0 * params["w"]}       # d/dw ||w||^2
            params, state = adam_update(cfg, params, grads, state)
        assert float(jnp.abs(params["w"]).max()) < 0.5

    def test_adam_state_dtype(self):
        from repro.optim.adam import adam_init
        params = {"w": jnp.ones(3, jnp.bfloat16)}
        st = adam_init(params)
        assert st["m"]["w"].dtype == jnp.float32   # f32 master moments
