"""Batched JAX design solver (core.sca_jax) vs the SciPy SCA oracle.

Parity contract: on every point of an (omega_var, omega_bias) grid the
batched solver's best-found true objective must be within rtol 1e-3 of —
or better than — the per-point SciPy SCA solution, for both the OTA (15)
and digital (17) problems.  benchmarks/design_bench.py enforces the same
gate at fig2 scale; these tests keep it in tier-1 at N=10.
"""
import numpy as np
import pytest

from repro.core.bounds import ObjectiveWeights
from repro.core.channel import WirelessConfig, make_deployment
from repro.core import digital_design, ota_design

PARITY_RTOL = 1e-3

# The SciPy SCA oracle must run clean: re-anchored starts are clipped into
# the SLSQP box (core.sca.solve_surrogate) and the solver's internal
# mid-step clipping is scoped out at the source, so the once-ubiquitous
# "Values in x were outside bounds" RuntimeWarning escaping these solves is
# a regression. Promote exactly that message to an error here, on top of
# the repo-wide RuntimeWarning-as-error policy in pyproject.toml.
pytestmark = pytest.mark.filterwarnings(
    "error:Values in x were outside bounds:RuntimeWarning")


@pytest.fixture(scope="module")
def deployment():
    return make_deployment(WirelessConfig(n_devices=10, seed=1))


def _weight_grid(n, scales=(0.3, 3.0)):
    base = ObjectiveWeights.strongly_convex(eta=0.5, mu=0.01, kappa_sc=3.0,
                                            n=n)
    return [ObjectiveWeights(omega_var=base.omega_var * a,
                             omega_bias=base.omega_bias * b)
            for a in scales for b in scales]


def _ota_specs(dep, weights):
    cfg = dep.cfg
    return [ota_design.OTADesignSpec(
        lambdas=dep.lambdas, dim=7850, g_max=20.0,
        e_s=cfg.energy_per_symbol, n0=cfg.noise_power, weights=w)
        for w in weights]


def _dig_specs(dep, weights):
    cfg = dep.cfg
    return [digital_design.DigitalDesignSpec(
        lambdas=dep.lambdas, dim=7850, g_max=20.0,
        e_s=cfg.energy_per_symbol, n0=cfg.noise_power,
        bandwidth_hz=cfg.bandwidth_hz, t_max_s=0.2, weights=w)
        for w in weights]


class TestOTABatch:
    def test_parity_with_sca_oracle_on_grid(self, deployment):
        specs = _ota_specs(deployment, _weight_grid(deployment.n_devices))
        params, objs = ota_design.design_ota_batch(specs)
        for spec, p, f in zip(specs, params, objs):
            _, res = ota_design.design_ota_sca(spec, n_iters=6)
            assert f <= res.objective * (1.0 + PARITY_RTOL), (
                f, res.objective)
            # returned objective is the true objective at the returned design
            f_check = ota_design.true_objective_from_gamma(spec, p.gammas)
            np.testing.assert_allclose(f, f_check, rtol=1e-9)

    def test_batch_params_valid(self, deployment):
        specs = _ota_specs(deployment, _weight_grid(deployment.n_devices))
        params, _ = ota_design.design_ota_batch(specs)
        for spec, p in zip(specs, params):
            pl = p.participation_levels(deployment.lambdas)
            assert np.all(pl >= 0) and np.all(pl <= 1)
            np.testing.assert_allclose(pl.sum(), 1.0, rtol=1e-9)
            assert np.all(p.gammas <= spec.gamma_max() * (1 + 1e-12))

    def test_batch_matches_per_point_solve(self, deployment):
        """vmap must not mix grid points: batch == batch-of-one per spec.

        The specs differ in every traced field (weights, E_s, N0, dim) to
        exercise the fully-batched spec construction.
        """
        cfg = deployment.cfg
        w = _weight_grid(deployment.n_devices)[:3]
        specs = [ota_design.OTADesignSpec(
            lambdas=deployment.lambdas, dim=d, g_max=g,
            e_s=cfg.energy_per_symbol * se, n0=cfg.noise_power * sn,
            weights=wi)
            for wi, d, g, se, sn in zip(w, (7850, 3000, 500),
                                        (20.0, 10.0, 49.0),
                                        (1.0, 2.0, 0.5), (1.0, 0.5, 2.0))]
        _, objs = ota_design.design_ota_batch(specs)
        for spec, f in zip(specs, objs):
            _, f_single = ota_design.design_ota_batch([spec])
            np.testing.assert_allclose(f, f_single[0], rtol=1e-12)

    def test_stack_rejects_mismatched_device_count(self, deployment):
        specs = _ota_specs(deployment, _weight_grid(deployment.n_devices))[:1]
        cfg = deployment.cfg
        other = ota_design.OTADesignSpec(
            lambdas=deployment.lambdas[:5], dim=7850, g_max=20.0,
            e_s=cfg.energy_per_symbol, n0=cfg.noise_power,
            weights=specs[0].weights)
        with pytest.raises(ValueError, match="device count"):
            ota_design.stack_ota_specs(specs + [other])


class TestDigitalBatch:
    def test_parity_with_sca_oracle_on_grid(self, deployment):
        specs = _dig_specs(deployment, _weight_grid(deployment.n_devices))
        _, objs = digital_design.design_digital_batch(specs)
        for spec, f in zip(specs, objs):
            _, res = digital_design.design_digital_sca(spec, n_iters=4)
            assert f <= res.objective * (1.0 + PARITY_RTOL), (
                f, res.objective)

    def test_batch_params_valid(self, deployment):
        specs = _dig_specs(deployment, _weight_grid(deployment.n_devices))
        params, _ = digital_design.design_digital_batch(specs)
        for spec, p in zip(specs, params):
            pl = p.participation_levels(deployment.lambdas)
            np.testing.assert_allclose(pl.sum(), 1.0, rtol=1e-6)
            assert np.all(p.r_bits >= 1)
            assert np.all(p.r_bits <= spec.r_max)
            lat = p.expected_latency(deployment.lambdas)
            assert lat <= spec.t_max_s * 1.02, lat


class TestAnchors:
    def test_anchor_zero_bias_matches_scalar_bisection(self, deployment):
        """Vectorized bisection is bit-true to the per-device loop."""
        spec = _ota_specs(deployment,
                          _weight_grid(deployment.n_devices))[0]
        c = spec.c_m()
        target = float(np.min(spec.alpha_max())) * (1.0 - 1e-9)
        gmax = spec.gamma_max()
        expect = np.empty(spec.n)
        for m in range(spec.n):
            lo, hi = 0.0, gmax[m]
            for _ in range(200):
                mid = 0.5 * (lo + hi)
                if mid * np.exp(-c[m] * mid ** 2) < target:
                    lo = mid
                else:
                    hi = mid
            expect[m] = 0.5 * (lo + hi)
        np.testing.assert_array_equal(ota_design.anchor_zero_bias(spec),
                                      expect)

    def test_anchor_zero_bias_gives_uniform_p(self, deployment):
        spec = _ota_specs(deployment,
                          _weight_grid(deployment.n_devices))[0]
        gam = ota_design.anchor_zero_bias(spec)
        p = ota_design.params_from_gamma(
            spec, gam).participation_levels(deployment.lambdas)
        np.testing.assert_allclose(p, 1.0 / spec.n, rtol=1e-6)
