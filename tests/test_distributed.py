"""Distribution-layer tests.

The multi-device cases run in a subprocess so the main pytest process keeps
the default single CPU device (per the dry-run isolation rule).
"""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import jax
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.launch.sharding import ShardingRules, decode_rules
from repro.launch.hlo_cost import analyze_hlo, parse_computations

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# Partial-auto shard_map (client axes manual, "model" axis automatic) hits
# an XLA SPMD partitioner check ("IsManualSubgroup") on jax<=0.4.x; the
# compat shim covers the API surface but not that compiler bug, so the
# mixed-mode train step needs a current jax. Gate on the *version* (the
# bug is fixed in 0.5+), not on where shard_map lives — the old spelling
# over-skipped on every jax that still exports the experimental path.
requires_current_shard_map = pytest.mark.skipif(
    not compat.HAS_PARTIAL_AUTO_SHARD_MAP,
    reason=f"partial-auto shard_map miscompiles on jax<=0.4.x "
           f"(XLA IsManualSubgroup check; running {compat.JAX_VERSION})")


def run_sub(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


class TestShardingRules:
    def setup_method(self):
        # a mesh object is needed only for axis names/sizes; build abstractly
        self.mesh = jax.make_mesh((1, 1), ("data", "model"))

    def test_divisibility_fallback(self):
        import jax as j
        mesh = j.make_mesh((1, 1), ("data", "model"))
        # fake sizes via host mesh won't exercise divisibility; test the
        # rule logic directly with a synthetic mesh-like object
        rules = ShardingRules.default()

        class FakeMesh:
            axis_names = ("data", "model")
            shape = {"data": 16, "model": 16}

        spec = rules.spec_for(FakeMesh(), (32, 4096), ("heads", "embed"))
        assert spec == P("model")
        # 10 heads not divisible by 16 -> replicate
        spec = rules.spec_for(FakeMesh(), (10, 256), ("heads", "head_dim"))
        assert spec == P()

    def test_axis_uniqueness(self):
        rules = ShardingRules.default()

        class FakeMesh:
            axis_names = ("data", "model")
            shape = {"data": 16, "model": 16}

        # (mlp, mlp): second dim must not reuse "model"
        spec = rules.spec_for(FakeMesh(), (2560, 2560), ("lru", "lru"))
        assert spec == P("model")

    def test_decode_rules_batch_one(self):
        class FakeMesh:
            axis_names = ("data", "model")
            shape = {"data": 16, "model": 16}

        r = decode_rules(1, FakeMesh())
        spec = r.spec_for(FakeMesh(), (1, 524288, 8, 128),
                          ("batch", "cache_seq", "kv_heads", "head_dim"))
        # batch=1 unshardable -> cache sequence sharded over data
        assert spec == P(None, "data")
        r2 = decode_rules(128, FakeMesh())
        spec2 = r2.spec_for(FakeMesh(), (128, 32768, 8, 128),
                            ("batch", "cache_seq", "kv_heads", "head_dim"))
        assert spec2 == P("data")        # batch sharded, seq replicated


class TestHLOCost:
    def test_scan_trip_counts(self):
        import jax.numpy as jnp

        def f(x, w):
            def body(c, wi):
                return c @ wi, None
            out, _ = jax.lax.scan(body, x, w)
            return out

        x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
        w = jax.ShapeDtypeStruct((8, 128, 128), jnp.float32)
        c = jax.jit(f).lower(x, w).compile()
        cost = analyze_hlo(c.as_text())
        expected = 2 * 128 ** 3 * 8
        assert expected <= cost.flops <= expected * 1.1

    def test_parse_computations_nonempty(self):
        import jax.numpy as jnp
        c = jax.jit(lambda x: x @ x).lower(
            jax.ShapeDtypeStruct((64, 64), jnp.float32)).compile()
        comps = parse_computations(c.as_text())
        assert comps


@pytest.mark.slow
class TestMultiDevice:
    @requires_current_shard_map
    def test_train_step_aggregators(self):
        out = run_sub("""
            import jax, jax.numpy as jnp, numpy as np
            from repro.compat import make_auto_mesh
            from repro.configs import get_config
            from repro.models import make_model, make_batch
            from repro.launch.steps import make_train_step, fl_round_arrays
            mesh = make_auto_mesh((4,2), ("data","model"))
            cfg = get_config("qwen3-moe-30b-a3b").scaled_down()
            model = make_model(cfg)
            params = model.init(jax.random.key(0))
            batch = make_batch(cfg, 8, 32, jax.random.key(1))
            for agg in ("ideal", "ota", "digital"):
                sb = make_train_step(model, mesh, aggregator=agg,
                                     batch=8, seq=32)
                fl = fl_round_arrays(mesh, alpha=4.0, noise_scale=1e-4)
                f = jax.jit(sb.fn, in_shardings=sb.in_shardings,
                            out_shardings=sb.out_shardings)
                new_params, loss = f(params, batch, fl, jax.random.key(7))
                assert np.isfinite(float(loss)), agg
                moved = sum(float(jnp.sum(jnp.abs(a.astype(jnp.float32)
                                                  - b.astype(jnp.float32))))
                            for a, b in zip(jax.tree.leaves(params),
                                            jax.tree.leaves(new_params)))
                assert moved > 0, agg
                print("OK", agg, float(loss))
        """)
        assert out.count("OK") == 3

    def test_ota_collective_matches_simulation(self):
        """wireless_psum(ota) == numpy OTA aggregation on the same grads."""
        out = run_sub("""
            import jax, jax.numpy as jnp, numpy as np
            from jax.sharding import PartitionSpec as P
            from repro.compat import make_auto_mesh, shard_map
            from repro.core.collectives import WirelessRound, wireless_psum
            mesh = make_auto_mesh((4,), ("data",))
            grads = np.arange(4 * 6, dtype=np.float32).reshape(4, 6)
            weight = np.array([0.5, 0.0, 1.5, 1.0], np.float32)
            alpha = 2.5
            def body(g, w, key):
                r = WirelessRound(weight=w, alpha=jnp.float32(alpha),
                                  noise_scale=jnp.float32(0.0),
                                  levels=jnp.float32(255.0))
                return wireless_psum({"g": g[0]}, r, ("data",), key,
                                     mode="ota", use_kernel=False)["g"]
            f = shard_map(body, mesh,
                          in_specs=(P("data"), P("data"), P()),
                          out_specs=P(), manual_axes=("data",))
            got = jax.jit(f)(jnp.asarray(grads).reshape(4, 1, 6),
                             jnp.asarray(weight), jax.random.key(0))
            want = (weight[:, None] * grads).sum(0) / alpha
            np.testing.assert_allclose(np.asarray(got).reshape(-1), want,
                                       rtol=1e-6)
            print("OK collective")
        """, devices=4)
        assert "OK collective" in out

    def test_decode_step_multidevice(self):
        out = run_sub("""
            import jax, numpy as np
            from repro.compat import make_auto_mesh
            from repro.configs import get_config
            from repro.models import make_model
            from repro.launch.steps import make_decode_step
            mesh = make_auto_mesh((4,2), ("data","model"))
            for arch in ("gemma3-4b", "falcon-mamba-7b"):
                cfg = get_config(arch).scaled_down()
                model = make_model(cfg)
                sb = make_decode_step(model, mesh, batch=8, cache_len=64)
                sb.lower().compile()
                print("OK", arch)
        """)
        assert out.count("OK") == 2


class TestShardingCoverage:
    def test_all_arch_param_specs_resolve(self):
        """Every assigned arch's full param tree maps to valid specs on the
        production mesh shape (divisibility/uniqueness rules hold)."""
        from repro.configs import REGISTRY
        from repro.models import make_model
        from repro.launch.sharding import ShardingRules

        class FakeMesh:
            axis_names = ("data", "model")
            shape = {"data": 16, "model": 16}

        rules = ShardingRules.default()
        for arch, cfg in REGISTRY.items():
            model = make_model(cfg)
            aparams = model.abstract_params()
            specs = rules.tree_specs(FakeMesh(), aparams, model.axes)
            import jax
            from jax.sharding import PartitionSpec as P
            n_sharded = 0
            for s, leaf in zip(
                    jax.tree.leaves(specs,
                                    is_leaf=lambda x: isinstance(x, P)),
                    jax.tree.leaves(aparams)):
                for i, entry in enumerate(s):
                    if entry is None:
                        continue
                    axes = entry if isinstance(entry, tuple) else (entry,)
                    size = 1
                    for a in axes:
                        size *= FakeMesh.shape[a]
                    assert leaf.shape[i] % size == 0, (arch, s, leaf.shape)
                    n_sharded += 1
            assert n_sharded > 0, f"{arch}: nothing sharded at all"
