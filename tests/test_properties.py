"""Property-based tests (hypothesis) on system invariants."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

pytest.importorskip(
    "hypothesis",
    reason="optional dep: property tests need hypothesis; the rest of the "
           "suite must collect without it")
from hypothesis import given, settings, strategies as st
import hypothesis.extra.numpy as hnp

from repro.core import rngstream
from repro.core.sca import simplex_projection
from repro.core.quantize import quantize_np, quantization_variance_bound
from repro.core.channel import participation_probability
from repro.core.bounds import bias_sum
from repro.kernels import ops, ref

finite_floats = st.floats(-1e3, 1e3, allow_nan=False, allow_infinity=False)


@given(hnp.arrays(np.float64, st.integers(1, 40), elements=finite_floats))
@settings(max_examples=80, deadline=None)
def test_simplex_projection_valid(v):
    p = simplex_projection(v)
    assert np.all(p >= -1e-12)
    assert abs(p.sum() - 1.0) < 1e-9


@given(hnp.arrays(np.float64, st.integers(2, 30),
                  elements=st.floats(0, 1, allow_nan=False)))
@settings(max_examples=50, deadline=None)
def test_simplex_projection_idempotent_on_simplex(v):
    s = v.sum()
    if s <= 1e-9:
        return
    p0 = v / s
    p = simplex_projection(p0)
    np.testing.assert_allclose(p, p0, atol=1e-9)


@given(hnp.arrays(np.float64, st.integers(1, 40), elements=finite_floats),
       st.integers(0, 2 ** 31 - 1))
@settings(max_examples=50, deadline=None)
def test_simplex_projection_order_equivariant(v, seed):
    """Permuting the input permutes the projection: proj(Pv) == P proj(v)."""
    perm = np.random.default_rng(seed).permutation(v.shape[0])
    np.testing.assert_allclose(simplex_projection(v[perm]),
                               simplex_projection(v)[perm], atol=1e-12)


@given(hnp.arrays(np.float64, st.integers(1, 40), elements=finite_floats))
@settings(max_examples=50, deadline=None)
def test_simplex_projection_jax_matches_numpy(v):
    """The batched solver's jnp projection is the numpy rule exactly."""
    from jax.experimental import enable_x64

    from repro.core.sca_jax import simplex_projection_jax

    with enable_x64():
        pj = np.asarray(simplex_projection_jax(jnp.asarray(v)))
    np.testing.assert_allclose(pj, simplex_projection(v), atol=1e-12)


@given(hnp.arrays(np.float64, st.integers(1, 200),
                  elements=st.floats(-100, 100, allow_nan=False)),
       st.integers(1, 12), st.integers(0, 2**31 - 1))
@settings(max_examples=60, deadline=None)
def test_quantizer_range_and_grid(g, r, seed):
    """Quantized output stays within [-m, m] and on the grid."""
    rng = np.random.default_rng(seed)
    q = quantize_np(g, r, rng)
    m = np.max(np.abs(g))
    assert np.all(np.abs(q) <= m + 1e-9)
    if m > 0:
        s = 2 ** r - 1
        delta = 2 * m / s
        idx = (q + m) / delta
        np.testing.assert_allclose(idx, np.round(idx), atol=1e-6)


@given(st.integers(1, 10), st.integers(1, 16),
       st.floats(1e-6, 1e3, allow_nan=False))
@settings(max_examples=40, deadline=None)
def test_quantization_variance_bound_positive(d, r, m):
    assert quantization_variance_bound(d, r, m) >= 0


@given(hnp.arrays(np.float64, st.integers(1, 20),
                  elements=st.floats(1e-14, 1e-8)),
       st.floats(0.0, 1e-3))
@settings(max_examples=40, deadline=None)
def test_participation_probability_in_unit_interval(lam, thr):
    p = participation_probability(np.full_like(lam, thr), lam)
    assert np.all(p >= 0) and np.all(p <= 1)


@given(hnp.arrays(np.float64, st.integers(1, 30),
                  elements=st.floats(0, 1, allow_nan=False)))
@settings(max_examples=50, deadline=None)
def test_bias_sum_nonnegative_and_zero_iff_uniform(p):
    s = p.sum()
    if s <= 1e-9:
        return
    p = p / s
    b = bias_sum(p)
    assert b >= -1e-15
    n = p.shape[0]
    if np.allclose(p, 1.0 / n, atol=1e-12):
        assert b < 1e-12


@given(st.integers(0, 2 ** 31 - 1), st.integers(0, 7), st.integers(0, 300),
       st.integers(1, 5), st.integers(1, 24), st.integers(1, 24))
@settings(max_examples=15, deadline=None)
def test_batch_sampler_np_jax_bit_identical(seed, trial, t, n_devices,
                                            n_data, batch_hint):
    """The counter-based mini-batch sampler (threefry on
    seed/trial/round/device) draws bit-identical index blocks through the
    NumPy oracle view, the jitted in-scan regeneration with a traced round
    index (what the engine's lax.scan does), and the per-device fold —
    in-range and without replacement."""
    batch_size = min(batch_hint, n_data)
    block = rngstream.batch_block_np(seed, trial, t, n_devices, n_data,
                                     batch_size)
    assert block.shape == (n_devices, batch_size)
    key = rngstream.batch_base_key(seed, trial)
    jitted = jax.jit(rngstream.batch_block, static_argnums=(2, 3, 4))
    np.testing.assert_array_equal(
        np.asarray(jitted(key, jnp.asarray(t), n_devices, n_data,
                          batch_size)), block)
    for m in (0, n_devices - 1):
        np.testing.assert_array_equal(
            rngstream.batch_indices_np(seed, trial, t, m, n_data,
                                       batch_size), block[m])
    assert block.min() >= 0 and block.max() < n_data
    for row in block:
        assert len(set(row.tolist())) == batch_size   # replace=False


@given(st.integers(0, 2 ** 31 - 1), st.integers(0, 7), st.integers(0, 300))
@settings(max_examples=15, deadline=None)
def test_batch_sampler_folds_independent(seed, trial, t):
    """Adjacent (trial, round, device) key folds give distinct draws (the
    sample space 1000-choose-16 makes a collision a fold-aliasing bug), and
    the batch stream never aliases the dither stream of the same trial."""
    n_data, bs = 1000, 16
    base = rngstream.batch_indices_np(seed, trial, t, 0, n_data, bs)
    assert not np.array_equal(
        base, rngstream.batch_indices_np(seed, trial, t, 1, n_data, bs))
    assert not np.array_equal(
        base, rngstream.batch_indices_np(seed, trial, t + 1, 0, n_data, bs))
    assert not np.array_equal(
        base, rngstream.batch_indices_np(seed, trial + 1, t, 0, n_data, bs))
    assert not np.array_equal(
        rngstream.batch_base_key(seed, trial),
        rngstream.dither_base_key(seed, trial))


@given(st.integers(1, 3), st.integers(1, 300), st.integers(1, 150),
       st.integers(0, 1000))
@settings(max_examples=25, deadline=None)
def test_linear_scan_kernel_property(B, S, D, seed):
    """Kernel == sequential oracle for random stable dynamics."""
    k1, k2, k3 = jax.random.split(jax.random.key(seed), 3)
    a = jax.random.uniform(k1, (B, S, D), minval=0.0, maxval=1.0)
    b = jax.random.normal(k2, (B, S, D)) * 0.2
    h0 = jax.random.normal(k3, (B, D))
    ha, hl = ops.linear_scan(a, b, h0, use_kernel=True)
    ra, rl = ref.linear_scan_ref(a, b, h0)
    np.testing.assert_allclose(np.asarray(ha), np.asarray(ra), atol=3e-5)
    np.testing.assert_allclose(np.asarray(hl), np.asarray(rl), atol=3e-5)
