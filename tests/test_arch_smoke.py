"""Per-architecture smoke tests on REDUCED variants (2 groups, d<=128,
<=4 experts): one forward/loss, one prefill + decode, shape and finiteness
asserts, and prefill/decode consistency against the full forward pass."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import REGISTRY
from repro.models import (make_model, make_batch, loss_fn, prefill,
                          decode_step, effective_seq)

ARCHS = sorted(REGISTRY)


@pytest.fixture(scope="module")
def built():
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = REGISTRY[arch].scaled_down()
            model = make_model(cfg)
            params = model.init(jax.random.key(0))
            cache[arch] = (cfg, model, params)
        return cache[arch]

    return get


@pytest.mark.parametrize("arch", ARCHS)
def test_train_loss_finite(built, arch):
    cfg, model, params = built(arch)
    batch = make_batch(cfg, batch=2, seq=32, key=jax.random.key(1))
    loss, metrics = loss_fn(model, params, batch)
    assert np.isfinite(float(loss))
    assert float(loss) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_reduces_loss(built, arch):
    """A few full-batch SGD steps on one batch must reduce the loss."""
    cfg, model, params = built(arch)
    batch = make_batch(cfg, batch=2, seq=16, key=jax.random.key(2))

    @jax.jit
    def step(p):
        (l, _), g = jax.value_and_grad(
            lambda q: loss_fn(model, q, batch), has_aux=True)(p)
        p = jax.tree.map(lambda x, gg: x - 0.5 * gg.astype(x.dtype), p, g)
        return p, l

    losses = []
    for _ in range(4):
        params, l = step(params)
        losses.append(float(l))
    assert losses[-1] < losses[0], losses
    assert all(np.isfinite(l) for l in losses)


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_consistent_with_forward(built, arch):
    """Prefill(S) + decode(token S) == forward(S+1) at the last position."""
    cfg, model, params = built(arch)
    S = 24
    batch_full = make_batch(cfg, batch=2, seq=S + 1, key=jax.random.key(3))
    tokens = batch_full["tokens"]
    batch_prefix = dict(batch_full)
    batch_prefix["tokens"] = tokens[:, :-1]

    # full forward logits at the last position
    from repro.models.api import _embed_inputs
    x, positions, _, memory = _embed_inputs(model, params, batch_full)
    hidden, _, _ = model.forward(params, x, positions, mode="train",
                                 remat=False, memory=memory)
    ref_logits = model.logits(params, hidden[:, -1:, :])[:, 0]

    cache_len = x.shape[1] + 4
    logits_p, caches, memory = prefill(model, params, batch_prefix,
                                       cache_len=cache_len)
    pos = jnp.full((2,), x.shape[1] - 1, jnp.int32)
    logits_d, _ = decode_step(model, params, tokens[:, -1:], pos, caches,
                              memory=memory)
    np.testing.assert_allclose(np.asarray(logits_d), np.asarray(ref_logits),
                               atol=2e-3, rtol=2e-3)


@pytest.mark.parametrize("arch", ARCHS)
def test_multi_step_decode_no_nan(built, arch):
    cfg, model, params = built(arch)
    batch = make_batch(cfg, batch=2, seq=16, key=jax.random.key(4))
    S_eff = effective_seq(cfg, 16)
    prefix_len = batch["tokens"].shape[1] + (cfg.vision_prefix or 0)
    logits, caches, memory = prefill(model, params, batch,
                                     cache_len=prefix_len + 8)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    for i in range(4):
        pos = jnp.full((2,), prefix_len + i, jnp.int32)
        logits, caches = decode_step(model, params, tok, pos, caches,
                                     memory=memory)
        assert bool(jnp.isfinite(logits).all()), (arch, i)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)


def test_sliding_window_matches_full_when_window_large():
    """local attention with window >= seq == global attention."""
    cfg = REGISTRY["tinyllama-1.1b"].scaled_down()
    cfg_local = dataclasses.replace(cfg, layer_pattern=("local",),
                                    window_size=4096)
    m_g = make_model(cfg)
    m_l = make_model(cfg_local)
    params = m_g.init(jax.random.key(0))
    batch = make_batch(cfg, batch=2, seq=24, key=jax.random.key(5))
    l_g, _ = loss_fn(m_g, params, batch)
    l_l, _ = loss_fn(m_l, params, batch)
    np.testing.assert_allclose(float(l_g), float(l_l), rtol=1e-5)


def test_chunked_attention_matches_einsum():
    cfg = REGISTRY["llama3.2-1b"].scaled_down()
    model = make_model(cfg)
    params = model.init(jax.random.key(0))
    batch = make_batch(cfg, batch=2, seq=50, key=jax.random.key(6))
    l_e, _ = loss_fn(model, params, batch, flags={"attn_impl": "einsum"})
    l_c, _ = loss_fn(model, params, batch, flags={"attn_impl": "chunked"})
    np.testing.assert_allclose(float(l_e), float(l_c), rtol=1e-4)


def test_moe_routes_to_multiple_experts():
    cfg = REGISTRY["qwen3-moe-30b-a3b"].scaled_down()
    model = make_model(cfg)
    params = model.init(jax.random.key(0))
    batch = make_batch(cfg, batch=2, seq=32, key=jax.random.key(7))
    loss, metrics = loss_fn(model, params, batch)
    # switch aux loss ~ 1 when perfectly balanced; blows up if collapsed
    assert 0.5 < float(metrics["aux"]) / cfg.n_layers < 4.0
