"""rng="fast" execution-mode contracts (counter-based in-scan streams).

Fast mode regenerates every random stream — fading, PS AWGN, selection,
dither, batch indices — as pure threefry functions of
``(seed, trial, round, stream)`` inside the engine's scan. The draws come
from the *same laws* as the replay oracle's but form a different stream,
so the guarantees tested here are:

  * statistical equivalence: mean trajectories agree within Monte-Carlo
    error (the CI smoke gate for the mode),
  * distinctness: per-trial trajectories differ from replay (fast is not
    secretly replay),
  * degenerate exactness: a scheme that consumes *only* counter-based
    randomness (IdealFedAvg + mini-batch) is bit-identical across modes,
  * zero host-side precompute: fast mode never touches the oracle's
    sequential ``trial_rng`` or ``sample_fading_batch`` (monkeypatched to
    explode),
  * dispatch: fast is engine-only, and ``run.rng`` is a sweepable axis
    that changes every cell hash.
"""
import numpy as np
import pytest

from repro.core import baselines as B
from repro.core import rngstream
from repro.core.channel import WirelessConfig, make_deployment
from repro.data.loader import FLDataset
from repro.data.partition import partition_by_class
from repro.data.synthetic import SyntheticSpec, make_classification_dataset
from repro.fl import engine as engine_mod
from repro.fl.trainer import FLTrainer

N_DEVICES = 10


@pytest.fixture(scope="module")
def setup():
    from repro.fl.tasks import SoftmaxRegressionTask

    spec = SyntheticSpec(n_train_per_class=100, n_test_per_class=30,
                         noise_sigma=1.5)
    x_tr, y_tr, x_te, y_te = make_classification_dataset(spec)
    shards = partition_by_class(x_tr, y_tr, N_DEVICES, 1, 100, seed=3)
    ds = FLDataset.from_shards(shards, x_te, y_te)
    task = SoftmaxRegressionTask(n_features=784, mu=0.01, g_max=20.0)
    dep = make_deployment(WirelessConfig(n_devices=N_DEVICES, seed=1))
    eta = 0.5 / (task.mu + task.smooth_l)
    return task, ds, dep, eta


def _run(setup, agg, *, rng, trials, rounds=30, eval_every=10, seed=5,
         batch_size=None):
    task, ds, dep, eta = setup
    tr = FLTrainer(task, ds, dep, eta=eta, batch_size=batch_size)
    return tr.run(agg, rounds=rounds, trials=trials, eval_every=eval_every,
                  seed=seed, backend="jax", rng=rng)


def _assert_statistically_equivalent(log_r, log_f):
    """Mean trajectories within 4x the combined Monte-Carlo stderr."""
    lr, lf = log_r.global_loss, log_f.global_loss
    mr, mf = lr.mean(axis=0), lf.mean(axis=0)
    stderr = np.sqrt(lr.var(axis=0, ddof=1) / lr.shape[0]
                     + lf.var(axis=0, ddof=1) / lf.shape[0])
    gap = np.abs(mr - mf)
    assert np.all(gap <= 4.0 * stderr + 1e-7), (gap, stderr)


class TestStatisticalEquivalence:
    def test_ota_awgn_and_fading(self, setup):
        """VanillaOTA consumes fading + PS AWGN — the two streams fast
        mode re-keys — so its trajectory is the core equivalence gate."""
        task, _, dep, _ = setup
        args = (task.dim, task.g_max, dep.cfg.energy_per_symbol,
                dep.cfg.noise_power)
        log_r = _run(setup, B.VanillaOTA(*args), rng="replay", trials=12)
        log_f = _run(setup, B.VanillaOTA(*args), rng="fast", trials=12)
        _assert_statistically_equivalent(log_r, log_f)

    def test_digital_selection_and_dither(self, setup):
        """UQOS exercises the fast selection sampler (sel_stream_jax) plus
        the (mode-shared) counter-based dither stream."""
        task, _, dep, _ = setup
        agg_kw = (dep, task.dim, task.g_max, dep.cfg.energy_per_symbol,
                  dep.cfg.noise_power, dep.cfg.bandwidth_hz)
        log_r = _run(setup, B.UQOS(*agg_kw), rng="replay", trials=8,
                     rounds=20)
        log_f = _run(setup, B.UQOS(*agg_kw), rng="fast", trials=8,
                     rounds=20)
        _assert_statistically_equivalent(log_r, log_f)

    def test_fast_stream_actually_differs(self, setup):
        """Fast is a *different* stream, not replay under a new name."""
        task, _, dep, _ = setup
        args = (task.dim, task.g_max, dep.cfg.energy_per_symbol,
                dep.cfg.noise_power)
        log_r = _run(setup, B.VanillaOTA(*args), rng="replay", trials=2)
        log_f = _run(setup, B.VanillaOTA(*args), rng="fast", trials=2)
        assert not np.allclose(log_r.global_loss[:, -1],
                               log_f.global_loss[:, -1], rtol=1e-10)

    def test_counter_only_scheme_is_bit_identical(self, setup):
        """IdealFedAvg + mini-batch consumes *only* the batch stream,
        which is counter-based in both modes — trajectories must match
        exactly, pinning down that fast mode re-keys nothing it needn't."""
        log_r = _run(setup, B.IdealFedAvg(), rng="replay", trials=2,
                     rounds=20, batch_size=32)
        log_f = _run(setup, B.IdealFedAvg(), rng="fast", trials=2,
                     rounds=20, batch_size=32)
        np.testing.assert_array_equal(log_r.global_loss, log_f.global_loss)
        np.testing.assert_array_equal(log_r.accuracy, log_f.accuracy)


class TestZeroPrecompute:
    def _explode(self, *a, **k):
        raise AssertionError(
            "host-side per-trial RNG precompute reached in fast mode")

    def test_fast_never_touches_host_streams(self, setup, monkeypatch):
        """Fast mode's whole host-side RNG footprint is three (2,)-uint32
        base keys per trial: the oracle fading sampler and the sequential
        trial generator must never be called."""
        task, ds, dep, eta = setup
        monkeypatch.setattr(engine_mod, "sample_fading_batch", self._explode)
        monkeypatch.setattr(rngstream, "trial_rng", self._explode)
        args = (task.dim, task.g_max, dep.cfg.energy_per_symbol,
                dep.cfg.noise_power)
        log = FLTrainer(task, ds, dep, eta=eta).run(
            B.VanillaOTA(*args), rounds=8, trials=2, eval_every=4, seed=3,
            backend="jax", rng="fast")
        assert np.all(np.isfinite(log.global_loss))
        # sanity: the same patched world breaks replay, so the patch bites
        with pytest.raises(AssertionError, match="precompute"):
            FLTrainer(task, ds, dep, eta=eta).run(
                B.VanillaOTA(*args), rounds=8, trials=2, eval_every=4,
                seed=3, backend="jax", rng="replay")


class TestDispatch:
    def test_rng_validation(self, setup):
        task, ds, dep, eta = setup
        tr = FLTrainer(task, ds, dep, eta=eta)
        with pytest.raises(ValueError, match="rng must be"):
            tr.run(B.IdealFedAvg(), rounds=4, trials=1, eval_every=2,
                   rng="nope")

    def test_fast_rejected_on_numpy_backend(self, setup):
        task, ds, dep, eta = setup
        tr = FLTrainer(task, ds, dep, eta=eta)
        with pytest.raises(ValueError, match="replay oracle"):
            tr.run(B.IdealFedAvg(), rounds=4, trials=1, eval_every=2,
                   backend="numpy", rng="fast")

    def test_fast_rejected_for_unported_scheme(self, setup):
        class Unported(B.Aggregator):
            name = "unported"

            def round(self, grads, h, t, rng, dither=None):
                g = np.mean(np.stack([np.asarray(g) for g in grads]), 0)
                return B.RoundResult(g, 0.0, np.ones(len(grads)), {})

        task, ds, dep, eta = setup
        tr = FLTrainer(task, ds, dep, eta=eta)
        with pytest.raises(ValueError, match="NumPy path"):
            tr.run(Unported(), rounds=4, trials=1, eval_every=2, rng="fast")


class TestSweepAxis:
    def test_run_rng_is_sweepable_and_changes_hashes(self):
        from repro.api.plan import plan
        from repro.api.spec import ScenarioSpec, SweepSpec

        base = ScenarioSpec(name="rng_axis")
        sweep = SweepSpec(name="rng_axis", base=base,
                          axes={"run.rng": ("replay", "fast")})
        pts = sweep.points()
        assert [sc.run.rng for _, sc in pts] == ["replay", "fast"]
        hashes = {sc.spec_hash() for _, sc in pts}
        assert len(hashes) == 2
        cells = plan(sweep).cells
        assert len(cells) == 2
        assert len({c.cell_hash for c in cells}) == 2


class TestPayloadDtype:
    """run.payload_dtype="bf16": half-width uplink gradient payloads with
    f32 accumulation — a lossy knob, so the gate is the fast-RNG suite's
    statistical-equivalence test, not bit parity."""

    def _run_pd(self, setup, agg, payload_dtype, *, trials, rounds=30,
                seed=5):
        task, ds, dep, eta = setup
        tr = FLTrainer(task, ds, dep, eta=eta, payload_dtype=payload_dtype)
        return tr.run(agg, rounds=rounds, trials=trials, eval_every=10,
                      seed=seed, backend="jax")

    def test_bf16_statistically_equivalent_to_f32(self, setup):
        """bf16 payload rounding is a small perturbation next to the
        channel noise: mean trajectories agree within Monte-Carlo error."""
        task, _, dep, _ = setup
        args = (task.dim, task.g_max, dep.cfg.energy_per_symbol,
                dep.cfg.noise_power)
        log32 = self._run_pd(setup, B.VanillaOTA(*args), "f32", trials=12)
        log16 = self._run_pd(setup, B.VanillaOTA(*args), "bf16", trials=12)
        _assert_statistically_equivalent(log32, log16)

    def test_bf16_actually_differs(self, setup):
        """The cast must bite — bf16 is not silently f32."""
        task, _, dep, _ = setup
        args = (task.dim, task.g_max, dep.cfg.energy_per_symbol,
                dep.cfg.noise_power)
        log32 = self._run_pd(setup, B.VanillaOTA(*args), "f32", trials=2)
        log16 = self._run_pd(setup, B.VanillaOTA(*args), "bf16", trials=2)
        assert not np.allclose(log32.global_loss, log16.global_loss,
                               rtol=1e-10)

    def test_bf16_rejected_on_numpy_backend(self, setup):
        task, ds, dep, eta = setup
        tr = FLTrainer(task, ds, dep, eta=eta, payload_dtype="bf16")
        with pytest.raises(ValueError, match="JAX engine"):
            tr.run(B.IdealFedAvg(), rounds=4, trials=1, eval_every=2,
                   backend="numpy")

    def test_bf16_rejected_for_unported_scheme(self, setup):
        class Unported(B.Aggregator):
            name = "unported"

            def round(self, grads, h, t, rng, dither=None):
                g = np.mean(np.stack([np.asarray(g) for g in grads]), 0)
                return B.RoundResult(g, 0.0, np.ones(len(grads)), {})

        task, ds, dep, eta = setup
        tr = FLTrainer(task, ds, dep, eta=eta, payload_dtype="bf16")
        with pytest.raises(ValueError, match="NumPy path"):
            tr.run(Unported(), rounds=4, trials=1, eval_every=2)

    def test_payload_dtype_validation(self, setup):
        task, ds, dep, eta = setup
        with pytest.raises(ValueError, match="payload_dtype"):
            FLTrainer(task, ds, dep, eta=eta, payload_dtype="f16")

    def test_run_payload_dtype_is_sweepable_and_changes_hashes(self):
        from repro.api.plan import plan
        from repro.api.spec import ScenarioSpec, SweepSpec

        base = ScenarioSpec(name="pd_axis")
        sweep = SweepSpec(name="pd_axis", base=base,
                          axes={"run.payload_dtype": ("f32", "bf16")})
        pts = sweep.points()
        assert [sc.run.payload_dtype for _, sc in pts] == ["f32", "bf16"]
        assert len({sc.spec_hash() for _, sc in pts}) == 2
        cells = plan(sweep).cells
        assert len(cells) == 2
        assert len({c.cell_hash for c in cells}) == 2
