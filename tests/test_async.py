"""Buffered-async contracts (``core.async_fl`` + both backends).

The async subsystem's guarantees, mirroring the fault/participation
suites:

  * the ARRIVAL stream is counter-based and bit-shared: the NumPy helper
    and the JAX in-scan block produce identical (2, N) uniforms, distinct
    from every other stream's draws, and hit the configured delivery /
    staleness statistics,
  * ``AsyncSpec``/``resolve`` validate and normalize the async knobs
    identically for both backends; the resolved tables (staleness CDF,
    discounts, delivery weights, payload scales) are consistent with each
    other,
  * ``async_round`` realizes exactly the stationary model the tables
    price, and ``stale_replace`` is the single last-gradient path shared
    with ``fault.on_missing="stale"`` (bit-identical to the inline
    ``np.where`` replay it replaced),
  * engine-vs-oracle parity holds with async on (zero / stale /
    designed weights), alone and composed with participation + faults,
  * ``run.mode="sync"`` is a strict no-op (bit-identical to a trainer
    that never heard of async), and ``rng="fast"`` stays bit-identical
    for counter-only schemes / statistically equivalent otherwise,
  * the co-design solver (``core.sca_jax.solve_async_batch``) returns
    feasible capped-simplex weights that beat uniform on its own
    bound-shaped objective,
  * in the K=1 regime (pure Bernoulli thinning — the model Theorem 1
    covers exactly) the measured steady-state error sits below the
    Theorem-1 bound at the async effective participation levels,
  * ``run.mode`` / ``async_.*`` are sweepable axes that change the cell
    hash (schema v7), with pre-v7 dict back-compat.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import async_fl as A
from repro.core import baselines as B
from repro.core import rngstream, sca_jax
from repro.core.bounds import (async_bias_sum, async_effective_participation,
                               theorem1_bound)
from repro.core.channel import WirelessConfig, make_deployment
from repro.core.faults import FaultSpec
from repro.data.loader import FLDataset
from repro.data.partition import partition_by_class
from repro.data.synthetic import SyntheticSpec, make_classification_dataset
from repro.fl.tasks import SoftmaxRegressionTask
from repro.fl.trainer import FLTrainer, solve_w_star

N_DEVICES = 10
ROUNDS = 20
TRIALS = 2
EVAL_EVERY = 5
TOL = dict(rtol=1e-5, atol=1e-5)

ASPEC = A.AsyncSpec(buffer_rounds=3, arrival_rate=0.6,
                    rate_heterogeneity=2.0, staleness_discount=0.8)


@pytest.fixture(scope="module")
def setup():
    spec = SyntheticSpec(n_train_per_class=100, n_test_per_class=30,
                         noise_sigma=1.5)
    x_tr, y_tr, x_te, y_te = make_classification_dataset(spec)
    shards = partition_by_class(x_tr, y_tr, N_DEVICES, 1, 100, seed=3)
    ds = FLDataset.from_shards(shards, x_te, y_te)
    task = SoftmaxRegressionTask(n_features=784, mu=0.01, g_max=20.0)
    dep = make_deployment(WirelessConfig(n_devices=N_DEVICES, seed=1))
    eta = 0.5 / (task.mu + task.smooth_l)
    return task, ds, dep, eta


def _vanilla(setup):
    task, _, dep, _ = setup
    return B.VanillaOTA(task.dim, task.g_max, dep.cfg.energy_per_symbol,
                        dep.cfg.noise_power)


# ---------------------------------------------------- ARRIVAL stream

class TestStream:
    @pytest.mark.parametrize("seed,trial,t", [(0, 0, 0), (5, 1, 7),
                                              (123, 3, 999)])
    def test_np_matches_jax_bitwise(self, seed, trial, t):
        """The NumPy oracle helper and the engine's in-scan block draw
        the SAME threefry counters — identical bits, not just close."""
        u_np = rngstream.arrival_block_np(seed, trial, t, 64)
        key = rngstream.arrival_base_key(seed, trial)
        u_jx = np.asarray(rngstream.arrival_block(key, t, 64))
        assert u_np.dtype == np.float64 and u_np.shape == (2, 64)
        np.testing.assert_array_equal(u_np, u_jx)
        assert np.all((u_np >= 0.0) & (u_np < 1.0))

    def test_distinct_from_other_streams(self):
        """ARRIVAL is its own tagged stream: same (seed, trial, t)
        counters, different draws than FAULT and PARTICIPATE."""
        u_arr = rngstream.arrival_block_np(5, 1, 7, 64)
        assert not np.array_equal(u_arr[0],
                                  rngstream.participation_block_np(5, 1, 7,
                                                                   64))
        assert not np.array_equal(u_arr[:2],
                                  rngstream.fault_block_np(5, 1, 7, 64)[:2])

    def test_deterministic(self):
        a = rngstream.arrival_block_np(9, 2, 13, 32)
        b = rngstream.arrival_block_np(9, 2, 13, 32)
        np.testing.assert_array_equal(a, b)

    def test_delivery_rate(self):
        """deliver = (u0 < r) hits the target arrival rate to 4 sigma."""
        r = 0.6
        rounds, n = 400, 64
        hits = sum(
            float(np.sum(rngstream.arrival_block_np(2, 0, t, n)[0] < r))
            for t in range(rounds))
        mean = hits / (rounds * n)
        sigma = np.sqrt(r * (1 - r) / (rounds * n))
        assert abs(mean - r) <= 4.0 * sigma

    def test_staleness_distribution(self):
        """Counting crossed CDF thresholds realizes the geometric pmf:
        the fraction of fresh draws (S = 0) matches P(S=0) = r to
        4 sigma."""
        r, k = 0.45, 4
        cdf = A.staleness_cdf(np.full(16, r), k)
        rounds, n = 400, 16
        fresh = sum(
            float(np.sum((rngstream.arrival_block_np(3, 0, t, n)[1][None, :]
                          >= cdf).sum(axis=0) == 0))
            for t in range(rounds))
        mean = fresh / (rounds * n)
        sigma = np.sqrt(r * (1 - r) / (rounds * n))
        assert abs(mean - r) <= 4.0 * sigma

    def test_key_cache_is_bounded_and_stable(self):
        cache = rngstream._ARRIVAL_KEY_CACHE
        before = rngstream.arrival_block_np(7, 0, 3, 16)
        for s in range(rngstream._KEY_CACHE_MAX + 50):
            rngstream.arrival_block_np(10_000 + s, 0, 0, 4)
        assert len(cache) <= rngstream._KEY_CACHE_MAX
        after = rngstream.arrival_block_np(7, 0, 3, 16)
        np.testing.assert_array_equal(before, after)


# ----------------------------------------------- spec / resolve / tables

class TestSpecResolve:
    def test_spec_validation(self):
        with pytest.raises(ValueError, match="buffer_rounds"):
            A.AsyncSpec(buffer_rounds=0)
        with pytest.raises(ValueError, match="arrival_rate"):
            A.AsyncSpec(arrival_rate=0.0)
        with pytest.raises(ValueError, match="rate_heterogeneity"):
            A.AsyncSpec(rate_heterogeneity=-1.0)
        with pytest.raises(ValueError, match="staleness_discount"):
            A.AsyncSpec(staleness_discount=1.5)
        with pytest.raises(ValueError, match="on_missing"):
            A.AsyncSpec(on_missing="drop")
        with pytest.raises(ValueError, match="weighting"):
            A.AsyncSpec(weighting="inverse")

    def test_sync_is_none(self):
        assert A.resolve("sync", ASPEC, 8) is None
        assert A.resolve("sync", None, 8) is None
        with pytest.raises(ValueError, match="mode is 'sync'"):
            A.resolve("sync", ASPEC, 8, weights=np.ones(8))
        with pytest.raises(ValueError, match="mode must be"):
            A.resolve("semi", ASPEC, 8)

    def test_designed_needs_weights(self):
        asp = dataclasses.replace(ASPEC, weighting="designed")
        with pytest.raises(ValueError, match="explicit async_weights"):
            A.resolve("async", asp, 8)

    def test_weights_validation(self):
        with pytest.raises(ValueError, match="shape"):
            A.resolve("async", ASPEC, 8, weights=np.ones(7))
        with pytest.raises(ValueError, match="finite and > 0"):
            bad = np.ones(8); bad[0] = 0.0
            A.resolve("async", ASPEC, 8, weights=bad)
        with pytest.raises(ValueError, match="sum"):
            A.resolve("async", ASPEC, 8, weights=np.full(8, 0.5))

    def test_resolved_hashable_and_tables(self):
        res = A.resolve("async", ASPEC, 8)
        assert {res: "hashable"}[res] == "hashable"
        r = res.rates_array()
        assert np.all(r[:-1] <= r[1:] + 1e-15)       # device 0 slowest
        cdf = res.cdf_array()
        assert cdf.shape == (3, 8)
        assert np.all(np.diff(cdf, axis=0) >= 0.0)   # CDF rows increase
        pmf = A.staleness_pmf(r, 3)
        np.testing.assert_allclose(pmf.sum(axis=0), cdf[-1], rtol=1e-12)
        np.testing.assert_allclose(
            res.discounts_array(), 0.8 ** np.arange(3), rtol=1e-12)
        # the payload normalization keeps E[delivered mass] at N
        c = res.delivery_weight_array()
        np.testing.assert_allclose(
            float(np.sum(c * res.payload_scale_array())), 8.0, rtol=1e-12)

    def test_delivery_weight_monotone_in_rate(self):
        """Faster devices deliver more discounted mass: c_m increases
        with r_m, and a deeper buffer never loses mass."""
        c = A.delivery_weight(ASPEC, 8)
        assert np.all(np.diff(c) >= 0.0) and c[0] < c[-1]
        deeper = dataclasses.replace(ASPEC, buffer_rounds=6)
        assert np.all(A.delivery_weight(deeper, 8) >= c - 1e-15)

    def test_expected_staleness_decreases_with_rate(self):
        sbar = A.expected_staleness(ASPEC, 8)
        assert np.all(np.diff(sbar) <= 0.0) and sbar[0] > sbar[-1]
        assert np.all((sbar >= 0.0) & (sbar <= ASPEC.buffer_rounds - 1))

    def test_synchronous_limit(self):
        """arrival_rate=1: every device delivers fresh every round —
        c = 1, sbar = 0, payload scale = v."""
        asp = A.AsyncSpec(buffer_rounds=4, arrival_rate=1.0)
        np.testing.assert_allclose(A.delivery_weight(asp, 6), 1.0,
                                   rtol=1e-12)
        np.testing.assert_allclose(A.expected_staleness(asp, 6), 0.0,
                                   atol=1e-15)


# ------------------------------------------------- async_round semantics

class TestAsyncRound:
    def test_known_realization(self):
        """Hand-built uniforms force every path: fresh, stale, out of
        window, and no-delivery."""
        n, k, d = 4, 2, 3
        res = A.resolve("async",
                        A.AsyncSpec(buffer_rounds=k, arrival_rate=0.5,
                                    staleness_discount=0.5), n)
        rates = res.rates_array()                    # all 0.5
        cdf = res.cdf_array()                        # rows: 0.5, 0.75
        g_old = np.arange(n * d, dtype=np.float64).reshape(n, d)
        g_new = g_old + 100.0
        buf = np.zeros((k, n, d)); buf[0] = g_old
        #        dev0 fresh   dev1 stale-1  dev2 out     dev3 silent
        u = np.array([[0.1,        0.2,        0.3,        0.9],
                      [0.1,        0.6,        0.8,        0.1]])
        payload, ok, buf2 = A.async_round(g_new, buf, u, rates, cdf,
                                          res.discounts_array(),
                                          res.payload_scale_array())
        scale = res.payload_scale_array()
        np.testing.assert_array_equal(ok, [True, True, False, False])
        np.testing.assert_allclose(payload[0], g_new[0] * scale[0])
        np.testing.assert_allclose(payload[1], g_old[1] * 0.5 * scale[1])
        np.testing.assert_array_equal(buf2[0], g_new)   # shifted window
        np.testing.assert_array_equal(buf2[1], g_old)

    def test_stale_replace_matches_inline_where(self):
        """The unified last-gradient path is bit-identical to the inline
        ``np.where`` replay it replaced (fault.on_missing='stale')."""
        rng = np.random.default_rng(0)
        g_last_ref = np.zeros((6, 4))
        g_last_new = np.zeros((6, 4))
        for _ in range(20):
            g = rng.normal(size=(6, 4))
            ok = rng.random(6) < 0.6
            ref = np.where(ok[:, None], g, g_last_ref)   # PR-8 inline form
            g_last_ref = ref
            out, g_last_new = A.stale_replace(g, ok, g_last_new)
            np.testing.assert_array_equal(out, ref)
            np.testing.assert_array_equal(g_last_new, ref)


# ------------------------------------------------------ co-design solver

class TestSolver:
    def test_feasible_and_beats_uniform(self):
        """Heterogeneous arrivals: the designed v is on the capped
        simplex and strictly improves the bound-shaped objective over
        uniform weights (evaluated with the same formula)."""
        n = 12
        asp = A.AsyncSpec(buffer_rounds=4, arrival_rate=0.5,
                          rate_heterogeneity=4.0, staleness_discount=0.8)
        p = np.full(n, 1.0 / n)
        c = A.delivery_weight(asp, n)
        sbar = A.expected_staleness(asp, n)
        wv, wb = 50.0, 1e3

        def obj(v):
            e = p * c * v * (n / np.sum(c * v))
            return (wb * np.sum((e - 1.0 / n) ** 2)
                    + wv * (1.0 / np.sum(e) ** 2 + np.sum(e ** 2 * sbar)))

        v, j = sca_jax.solve_async_batch(p[None], c[None], sbar[None],
                                         [wv], [wb])
        v, j = v[0], float(j[0])
        assert abs(v.sum() - n) < 1e-6
        assert np.all(v > 0.0) and np.all(v <= n + 1e-9)
        np.testing.assert_allclose(j, obj(v), rtol=1e-8)
        assert j < obj(np.ones(n))
        # bias-dominant weights rebalance toward the slow devices
        assert v[0] > v[-1]

    def test_batched_shapes(self):
        n = 8
        asp = A.AsyncSpec(buffer_rounds=3, arrival_rate=0.6,
                          rate_heterogeneity=2.0)
        p = np.full((2, n), 1.0 / n)
        c = np.stack([np.ones(n), A.delivery_weight(asp, n)])
        s = np.stack([np.zeros(n), A.expected_staleness(asp, n)])
        v, j = sca_jax.solve_async_batch(p, c, s, [10.0, 10.0], [1.0, 1.0])
        assert v.shape == (2, n) and j.shape == (2,)
        np.testing.assert_allclose(v.sum(axis=1), [8.0, 8.0], atol=1e-6)


# -------------------------------------------------- bound composition

class TestBoundComposition:
    def test_effective_participation_prices_p_c_v(self):
        rng = np.random.default_rng(0)
        n = 8
        p = rng.uniform(0.05, 0.2, n)
        c = rng.uniform(0.3, 1.0, n)
        v = rng.uniform(0.5, 2.0, n)
        v *= n / v.sum()
        eff = async_effective_participation(p, c, v)
        np.testing.assert_allclose(eff, p * c * v * (n / np.sum(c * v)),
                                   rtol=1e-12)
        assert async_bias_sum(p, c, v) == pytest.approx(
            float(np.sum((eff - 1.0 / n) ** 2)))
        # homogeneous delivery is the zero-tilt point: e = p exactly
        np.testing.assert_allclose(
            async_effective_participation(p, np.full(n, 0.4)), p,
            rtol=1e-12)

    def test_theorem1_holds_in_k1_regime(self, setup):
        """K=1 async is independent Bernoulli thinning — the regime
        Theorem 1 models exactly. Measured steady-state optimality error
        must sit below the bound at the async effective levels with the
        analytic delivery variance."""
        task, ds, dep, eta = setup
        n = N_DEVICES
        rounds = 80
        asp = A.AsyncSpec(buffer_rounds=1, arrival_rate=0.7,
                          rate_heterogeneity=2.0)
        res = A.resolve("async", asp, n)
        c = res.delivery_weight_array()
        scale = res.payload_scale_array()
        p = np.full(n, 1.0 / n)
        e = async_effective_participation(p, c)
        zeta = float(task.g_max ** 2 / n ** 2
                     * np.sum(scale ** 2 * c * (1.0 - c)))
        x_all = np.concatenate([d.x for d in ds.devices])
        y_all = np.concatenate([d.y for d in ds.devices])
        w_star = solve_w_star(task, x_all, y_all, iters=1500)
        g = task.device_grads(w_star, np.stack([d.x for d in ds.devices]),
                              np.stack([d.y for d in ds.devices]))
        kappa = float(np.sqrt(np.mean(np.linalg.norm(g, axis=1) ** 2)))
        bound = theorem1_bound(rounds, eta=eta, mu=task.mu, diam=0.0,
                               kappa_sc=kappa, p=e, zeta=zeta)
        tr = FLTrainer(task, ds, dep, eta=eta, mode="async",
                       async_spec=asp)
        log = tr.run(B.IdealFedAvg(), rounds=rounds, trials=2,
                     eval_every=rounds // 4, seed=3, w_star=w_star)
        measured = float(log.opt_error[:, -2:].mean())
        assert measured <= bound["total"] + 1e-6


# --------------------------------------- backend parity + no-op + fast

def _run(setup, agg, *, backend, rng="replay", trainer_kw=None, rounds=ROUNDS,
         trials=TRIALS, seed=5):
    task, ds, dep, eta = setup
    tr = FLTrainer(task, ds, dep, eta=eta, **(trainer_kw or {}))
    return tr.run(agg, rounds=rounds, trials=trials, eval_every=EVAL_EVERY,
                  seed=seed, backend=backend, rng=rng)


def _assert_logs_match(log_np, log_jx):
    np.testing.assert_array_equal(log_np.rounds, log_jx.rounds)
    np.testing.assert_allclose(log_jx.global_loss, log_np.global_loss, **TOL)
    np.testing.assert_allclose(log_jx.accuracy, log_np.accuracy, **TOL)


class TestEngineOracleParity:
    @pytest.mark.parametrize("on_missing", ["zero", "stale"])
    def test_ota_policies(self, setup, on_missing):
        kw = dict(mode="async",
                  async_spec=dataclasses.replace(ASPEC,
                                                 on_missing=on_missing))
        agg = _vanilla(setup)
        _assert_logs_match(_run(setup, agg, backend="numpy", trainer_kw=kw),
                           _run(setup, agg, backend="jax", trainer_kw=kw))

    def test_designed_weights(self, setup):
        """Explicit capped-simplex PS weights flow through both backends
        identically (the 'designed' transport path)."""
        p = np.full(N_DEVICES, 1.0 / N_DEVICES)
        c = A.delivery_weight(ASPEC, N_DEVICES)
        sbar = A.expected_staleness(ASPEC, N_DEVICES)
        v, _ = sca_jax.solve_async_batch(p[None], c[None], sbar[None],
                                         [10.0], [1e3])
        kw = dict(mode="async",
                  async_spec=dataclasses.replace(ASPEC,
                                                 weighting="designed"),
                  async_weights=v[0])
        agg = _vanilla(setup)
        _assert_logs_match(_run(setup, agg, backend="numpy", trainer_kw=kw),
                           _run(setup, agg, backend="jax", trainer_kw=kw))

    def test_composes_with_participation_and_faults(self, setup):
        """Sampling -> async delivery -> fault degradation apply in that
        order in BOTH backends."""
        kw = dict(mode="async", async_spec=ASPEC, clients_per_round=8,
                  participation="channel",
                  fault=FaultSpec(dropout_prob=0.2, on_missing="stale"))
        agg = _vanilla(setup)
        _assert_logs_match(_run(setup, agg, backend="numpy", trainer_kw=kw),
                           _run(setup, agg, backend="jax", trainer_kw=kw))


class TestStrictNoOp:
    def test_sync_is_bit_identical(self, setup):
        """mode='sync' must take the exact pre-async code path — even
        with an AsyncSpec present — bit-identical, not merely close."""
        agg = _vanilla(setup)
        log_off = _run(setup, agg, backend="jax",
                       trainer_kw=dict(mode="sync", async_spec=ASPEC))
        log_plain = _run(setup, agg, backend="jax")
        np.testing.assert_array_equal(log_off.global_loss,
                                      log_plain.global_loss)
        np.testing.assert_array_equal(log_off.accuracy, log_plain.accuracy)

    def test_async_actually_changes_the_run(self, setup):
        agg = _vanilla(setup)
        log_on = _run(setup, agg, backend="jax",
                      trainer_kw=dict(mode="async", async_spec=ASPEC),
                      trials=1)
        log_plain = _run(setup, agg, backend="jax", trials=1)
        assert not np.allclose(log_on.global_loss, log_plain.global_loss,
                               rtol=1e-10)


class TestFastMode:
    def test_counter_only_scheme_bit_identical(self, setup):
        """IdealFedAvg + async consumes ONLY the counter-based ARRIVAL
        stream, which replay and fast share — trajectories must match
        exactly."""
        kw = dict(mode="async", async_spec=ASPEC)
        log_r = _run(setup, B.IdealFedAvg(), backend="jax", rng="replay",
                     trainer_kw=kw)
        log_f = _run(setup, B.IdealFedAvg(), backend="jax", rng="fast",
                     trainer_kw=kw)
        np.testing.assert_array_equal(log_r.global_loss, log_f.global_loss)
        np.testing.assert_array_equal(log_r.accuracy, log_f.accuracy)

    def test_statistical_equivalence_with_async(self, setup):
        """With fading + AWGN re-keyed by fast mode and async on, the
        mean trajectories agree within 4x Monte-Carlo stderr."""
        kw = dict(mode="async", async_spec=ASPEC)
        agg = _vanilla(setup)
        log_r = _run(setup, agg, backend="jax", rng="replay",
                     trainer_kw=kw, trials=12, rounds=30)
        log_f = _run(setup, agg, backend="jax", rng="fast",
                     trainer_kw=kw, trials=12, rounds=30)
        lr, lf = log_r.global_loss, log_f.global_loss
        gap = np.abs(lr.mean(axis=0) - lf.mean(axis=0))
        stderr = np.sqrt(lr.var(axis=0, ddof=1) / lr.shape[0]
                         + lf.var(axis=0, ddof=1) / lf.shape[0])
        assert np.all(gap <= 4.0 * stderr + 1e-7), (gap, stderr)


# ---------------------------------------------------- scenario plumbing

class TestScenarioAxes:
    def test_axes_change_spec_hash(self):
        from repro.api.results import SCHEMA_VERSION
        from repro.api.scenarios import sweep_async

        assert SCHEMA_VERSION == 7
        base = sweep_async(quick=True).base
        h0 = base.spec_hash()
        assert base.override("async_.buffer_rounds", 7).spec_hash() != h0
        assert base.override("async_.staleness_discount",
                             0.5).spec_hash() != h0
        assert base.override("run.mode", "sync").spec_hash() != h0

    def test_mode_validation(self):
        from repro.api.spec import RunSpec

        with pytest.raises(ValueError, match="run.mode"):
            RunSpec(mode="semi-async")

    def test_backcompat(self):
        """Pre-v7 spec dicts (no async_/mode fields) still load, with
        the async layer strictly off."""
        from repro.api.spec import RunSpec, ScenarioSpec

        r = RunSpec(**{"rounds": 8, "trials": 1, "etas": (1.0,)})
        assert r.mode == "sync"
        d = ScenarioSpec().to_dict()
        del d["async_"]
        del d["run"]["mode"]
        sc = ScenarioSpec.from_dict(d)
        assert sc == ScenarioSpec()
        assert sc.run.mode == "sync" and sc.async_ == A.AsyncSpec()
