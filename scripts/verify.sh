#!/usr/bin/env bash
# Repo verification: tier-1 tests + engine benchmark smoke + memory guard.
#
#   ./scripts/verify.sh          # or: make verify
#   SKIP_TIER1=1 ./scripts/verify.sh   # smoke gates only (CI runs tier-1
#                                      # as its own job step first)
#
# Mirrors ROADMAP.md's tier-1 command, then smoke-runs the NumPy-vs-JAX
# engine benchmark (records experiments/results/engine_bench.json), the
# SGD mini-batch engine suite (in-scan counter-based batch sampling + the
# time-budget freeze mask — the regimes that used to fall back to NumPy),
# the design-solver benchmark (batched JAX SCA vs the per-point SciPy
# oracle; fails if the JAX path loses objective quality anywhere), the
# 1500-round digital engine horizon under a fixed peak-RSS budget — the
# streaming-dither O(N*d) memory contract (a rematerialized
# (trials, T, N, d) dither tensor would blow the budget by ~1.9 GB) —
# the fast-RNG gates (rng="fast" statistical equivalence vs the replay
# oracle plus the population-scale grid: N=1024 at fig2 dimension under
# the same 2 GB RSS budget, recorded to BENCH_engine_scale.json),
# the payload-scale kernel bench (fused quantize->pack->dequant-aggregate
# vs materialize-then-sum at N=256, d=10^6: must win both wall-clock and
# peak RSS under the 2 GB budget, recorded to BENCH_kernel_payload.json),
# the declarative scenario-sweep smoke: a 2x2 grid through
# `python -m repro.api.cli run sweep_smoke --jobs 2` (one batched design
# solve for the grid, cells on a 2-worker spawn pool), asserting the
# ResultSet manifest is written and that re-running the finished sweep
# is a cache no-op (--expect-cached), the fault/chaos suite
# (counter-based FAULT-stream parity + on_missing policy oracle parity,
# worker-SIGKILL recovery with serial-identical manifests, hung cell ->
# status="timeout", corrupt-cache quarantine), and the fault-injection
# sweep smoke: the dropout x heterogeneity grid of
# `benchmarks/sweep_fault.py --smoke` on a 2-worker pool (fault-aware
# batched design + graceful-degradation reduction), and the
# partial-participation sweep smoke: the N x S x policy grid of
# `benchmarks/sweep_participation.py --smoke`, which fails unless the
# co-designed sampling distribution strictly beats uniform zero-bias
# sampling at equal expected airtime on >= 1 heterogeneous cell, and the
# buffered-async sweep smoke: `benchmarks/sweep_async.py --smoke`, which
# fails unless the staleness-priced designed-async configuration beats
# BOTH naive-async and synchronous-with-deadline at equal wall-clock, and
# the K=1 Theorem-1 bound rows all hold.
set -uo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

test_status=0
if [ "${SKIP_TIER1:-0}" != "1" ]; then
    echo "== tier-1 tests =="
    python -m pytest -q
    test_status=$?
fi

echo "== engine benchmark (smoke) =="
python -m benchmarks.engine_bench --smoke
bench_status=$?

echo "== engine mini-batch benchmark (smoke) =="
python -m benchmarks.engine_bench --minibatch --smoke
minibatch_status=$?

echo "== design benchmark (smoke: jax vs SCA-oracle quality) =="
python -m benchmarks.design_bench --smoke
design_status=$?

echo "== digital engine 1500-round horizon (peak-RSS guard) =="
python -m benchmarks.engine_bench --digital-long --rss-budget-mb 2048
mem_status=$?

echo "== fast-RNG statistical equivalence (rng='fast' vs replay oracle) =="
python -m pytest -q tests/test_rng_fast.py
fastrng_status=$?

echo "== fast-RNG population scale (N=1024 @ fig2 dim; peak-RSS guard) =="
python -m benchmarks.engine_bench --scale --smoke --rss-budget-mb 2048
scale_status=$?

echo "== payload kernel bench (fused O(d) aggregation; peak-RSS guard) =="
python -m benchmarks.kernel_bench --payload --smoke --rss-budget-mb 2048
payload_status=$?

echo "== scenario sweep smoke (2x2 grid, --jobs 2; manifest + cache no-op) =="
# fresh 2x2 sweep through the declarative CLI on a 2-worker pool, then
# assert the manifest landed and a re-run of the finished sweep is a pure
# cache hit (the parallel run must leave serial-identical artifacts)
sweep_dir="experiments/results/scenarios/sweep_smoke"
rm -rf "$sweep_dir"
python -m repro.api.cli run sweep_smoke --jobs 2 \
    && test -f "$sweep_dir/manifest.json" \
    && python -m repro.api.cli run sweep_smoke --expect-cached
sweep_status=$?

echo "== fault/chaos suite (FAULT-stream parity; worker-kill, timeout, quarantine) =="
python -m pytest -q tests/test_faults.py tests/test_parallel_executor.py
fault_status=$?

echo "== fault-injection sweep smoke (dropout x heterogeneity, --jobs 2) =="
rm -rf "experiments/results/scenarios/sweep_fault"
python -m benchmarks.sweep_fault --smoke --jobs 2
faultsweep_status=$?

echo "== participation sweep smoke (N x S, designed-vs-uniform, --jobs 2) =="
rm -rf "experiments/results/scenarios/sweep_participation"
python -m benchmarks.sweep_participation --smoke --jobs 2
partsweep_status=$?

echo "== async sweep smoke (designed vs naive vs sync-deadline, --jobs 2) =="
rm -rf "experiments/results/scenarios/sweep_async"*
python -m benchmarks.sweep_async --smoke --jobs 2
asyncsweep_status=$?

if [ "$test_status" -ne 0 ] || [ "$bench_status" -ne 0 ] \
        || [ "$minibatch_status" -ne 0 ] || [ "$design_status" -ne 0 ] \
        || [ "$mem_status" -ne 0 ] || [ "$fastrng_status" -ne 0 ] \
        || [ "$scale_status" -ne 0 ] || [ "$payload_status" -ne 0 ] \
        || [ "$sweep_status" -ne 0 ] || [ "$fault_status" -ne 0 ] \
        || [ "$faultsweep_status" -ne 0 ] \
        || [ "$partsweep_status" -ne 0 ] \
        || [ "$asyncsweep_status" -ne 0 ]; then
    echo "verify FAILED (tests=$test_status bench=$bench_status" \
         "minibatch=$minibatch_status design=$design_status" \
         "mem=$mem_status fastrng=$fastrng_status scale=$scale_status" \
         "payload=$payload_status sweep=$sweep_status" \
         "fault=$fault_status faultsweep=$faultsweep_status" \
         "partsweep=$partsweep_status asyncsweep=$asyncsweep_status)" >&2
    exit 1
fi
echo "verify OK"
