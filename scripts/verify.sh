#!/usr/bin/env bash
# Repo verification: tier-1 tests + engine benchmark smoke.
#
#   ./scripts/verify.sh          # or: make verify
#
# Mirrors ROADMAP.md's tier-1 command, then smoke-runs the NumPy-vs-JAX
# engine benchmark (records experiments/results/engine_bench.json).
set -uo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 tests =="
# --deselect: multi-device failures known-red since the seed (see
# ROADMAP.md "Known-red"); verify gates *new* breakage
python -m pytest -q \
    --deselect tests/test_distributed.py::TestHLOCost::test_scan_trip_counts \
    --deselect tests/test_distributed.py::TestMultiDevice::test_train_step_aggregators \
    --deselect tests/test_distributed.py::TestMultiDevice::test_ota_collective_matches_simulation \
    --deselect tests/test_distributed.py::TestMultiDevice::test_decode_step_multidevice
test_status=$?

echo "== engine benchmark (smoke) =="
python -m benchmarks.engine_bench --smoke
bench_status=$?

if [ "$test_status" -ne 0 ] || [ "$bench_status" -ne 0 ]; then
    echo "verify FAILED (tests=$test_status bench=$bench_status)" >&2
    exit 1
fi
echo "verify OK"
